"""The paper's technique at LM scale: DADA-driven MoE expert placement.

Simulates a routing history for a kimi-k2-like MoE layer (384 experts,
top-8) over 16 expert-parallel device groups, then compares:
  * round-robin placement (the standard default),
  * DADA(alpha) placement with affinity = current weight residency.

Metrics: max group load (step latency proxy) and expected all-to-all
fraction (cross-group token traffic) — performance vs transfers, the
paper's exact trade-off.

Run:  PYTHONPATH=src python examples/moe_affinity_placement.py
"""
import numpy as np

from repro.dist.sched_bridge import expected_a2a_fraction, plan_expert_placement

G, E = 16, 384
rng = np.random.default_rng(0)

# skewed routing: popular experts + per-group locality structure
base = rng.pareto(1.2, size=(G, E)) + 0.05
perm = rng.permutation(E)
for g in range(G):
    base[g, perm[g * (E // G):(g + 1) * (E // G)]] *= 12  # locality hotspots
mass = base.sum(axis=0)

rr = np.arange(E) % G
load_rr = np.array([mass[rr == g].sum() for g in range(G)])
print(f"round-robin : max-load {load_rr.max():8.1f}  "
      f"a2a {expected_a2a_fraction(base, rr)*100:5.1f}%")

dominant = base.argmax(axis=0)  # residency prior: dominant source group
for alpha in (0.0, 0.5, 1.0):
    pl = plan_expert_placement(mass, G, prev_assignment=dominant, alpha=alpha)
    a2a = expected_a2a_fraction(base, pl.assignment)
    print(f"dada({alpha:3.1f})   : max-load {pl.group_load.max():8.1f}  "
          f"a2a {a2a*100:5.1f}%  moved-vs-prior {pl.moved_experts}")
