"""Open-loop serving: 256 bursty tenants, WFQ vs HEFT tail behavior.

The serving layer (``repro.runtime.load``) turns the engine into a
trace-driven multi-tenant simulator: a seeded arrival process posts
hundreds of small DAGs from the mixed catalog onto one machine, the
incremental-rescoring scheduler (``rescore="incremental"``) keeps the
hot path cheap, and the report rolls up tenant-visible tails — makespan
slowdown vs an empty machine, queueing delay, Jain fairness.

Here the same 256-tenant bursty arrival trace is replayed under plain
HEFT (throughput-first, tenant-blind) and under WFQ (weighted fair
queueing over virtual finish times): WFQ trades a little median latency
for a fairer spread across tenants caught behind a burst.

Run:  PYTHONPATH=src python examples/serving_sim.py
"""
from repro.configs.paper_machine import paper_machine
from repro.runtime.load import make_arrivals, run_serving

TENANTS = 256
RATE = 2000.0  # arrivals/sec of simulated time: deep open-loop backlog

machine = paper_machine(n_gpus=4)
arrivals = make_arrivals("bursty", TENANTS, rate=RATE, seed=7)
print(
    f"{TENANTS} tenants, bursty arrivals over "
    f"{max(a.t for a in arrivals):.3f}s of simulated time"
)

outs = {}
for spec in ("heft", "wfq"):
    outs[spec] = run_serving(
        arrivals, machine, spec, seed=0, rescore="incremental"
    )

print(f"\n{'strategy':8s} {'p50 slow':>9s} {'p99 slow':>9s} "
      f"{'p99 queue':>10s} {'jain':>6s} {'events':>7s}")
for spec, out in outs.items():
    rep = out["report"]
    print(
        f"{spec:8s} {rep['p50_slowdown']:9.2f} {rep['p99_slowdown']:9.2f} "
        f"{rep['p99_queue_delay']:10.4f} {rep['jain_fairness']:6.3f} "
        f"{out['n_events']:7d}"
    )

heft, wfq = outs["heft"]["report"], outs["wfq"]["report"]
assert all(len(out["tenants"]) == TENANTS for out in outs.values()), (
    "every tenant must finish (no admission control in this example)"
)
assert wfq["jain_fairness"] > heft["jain_fairness"], (
    "WFQ must spread burst pain more evenly than tenant-blind HEFT"
)
print(
    f"\nWFQ fairness {wfq['jain_fairness']:.3f} vs HEFT "
    f"{heft['jain_fairness']:.3f}; "
    f"p99 slowdown {wfq['p99_slowdown']:.1f} vs {heft['p99_slowdown']:.1f}"
)
print("OK")
