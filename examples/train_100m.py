"""End-to-end driver: train a ~100M-param granite-family model.

Full production stack: registry config (scaled), deterministic sharded data
pipeline, AdamW + cosine + clipping, checkpoint/restart, loss logging.

Run (a few hundred steps):
  PYTHONPATH=src python examples/train_100m.py --steps 300
Smoke (CI-speed):
  PYTHONPATH=src python examples/train_100m.py --steps 8 --tiny
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    args = ap.parse_args()

    if args.tiny:
        argv = [
            "--arch", "granite-8b", "--smoke", "--steps", str(args.steps),
            "--seq-len", "64", "--batch", "2", "--ckpt-dir", "/tmp/ckpt_100m",
            "--log-every", "2",
        ]
    else:
        # granite family scaled to ~100M params: 12 x d512 over 8k vocab
        argv = [
            "--arch", "granite-8b", "--smoke", "--d-model", "512",
            "--steps", str(args.steps), "--seq-len", "256", "--batch", "4",
            "--ckpt-dir", "/tmp/ckpt_100m", "--ckpt-every", "100",
            "--log-every", "10",
        ]
    return train_main(argv)


if __name__ == "__main__":
    raise SystemExit(main())
