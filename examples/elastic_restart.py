"""Fault tolerance end-to-end: lose GPUs mid-run, drain vs kill recovery,
elastic re-planning with DADA affinity, and preemption-trace replay.

A Cholesky factorization runs on the 8-GPU paper machine while the pod
churns: one GPU drains out gracefully, another is killed hard (running
task aborted and requeued, dirty tiles evacuated to the host), and the
first returns late. An ``ElasticReplanner`` follows the same
detach/attach stream and re-plans the (data, model) mesh + expert
placement with affinity to the previous plan at every membership change.
The fault history is then saved as a JSONL preemption trace and replayed
on a fresh simulator, reproducing the faulted run bit-for-bit.

Run:  PYTHONPATH=src python examples/elastic_restart.py
"""
import sys
sys.path.insert(0, "src")

import os
import tempfile

from repro.configs.paper_machine import paper_machine
from repro.core import Simulator
from repro.dist.elastic import ElasticReplanner
from repro.linalg.cholesky import cholesky_graph
from repro.runtime import recovery_report, save_trace
from repro.sched import resolve

NT = 16
SPEC = "dada?alpha=0.5&use_cp=1"


def make_sim():
    return Simulator(
        cholesky_graph(NT, 512, with_fns=False), paper_machine(8),
        resolve(SPEC), seed=0, noise=0.0,
    )


def fingerprint(res):
    return (res.makespan, res.total_bytes,
            tuple((iv.tid, iv.rid, iv.start, iv.end) for iv in res.intervals))


print("== phase 1: clairvoyant baseline (no faults) ==")
base = make_sim().run()
print(f"makespan {base.makespan * 1e3:.2f} ms, "
      f"{base.total_bytes / 1e9:.3f} GB transferred")

print("\n== phase 2: GPU churn with live elastic re-planning ==")
sim = make_sim()
replanner = ElasticReplanner(
    devices_per_worker=32, n_experts=64, model_axis=16,
).attach_to(sim)
gpus = [r.rid for r in sim.machine.gpus]
# one graceful drain, one hard kill (mid-task: the running task is
# aborted and requeued), one late rejoin
sim.inject("detach", gpus[0], at=base.makespan * 0.25, mode="drain")
sim.inject("detach", gpus[1], at=base.makespan * 0.39, mode="kill")
sim.inject("attach", gpus[0], at=base.makespan * 0.60)
faulted = sim.run()

for t, event, n_devices, plan in replanner.history:
    shape = "—" if plan is None else f"mesh {plan.mesh_shape}"
    print(f"  t={t * 1e3:7.2f} ms  {event:>6}  {n_devices:3d} devices  {shape}")
print(f"re-planning moved {replanner.total_moved}/64 experts in total "
      f"(affinity kept the rest in place)")

rep = recovery_report(faulted, base)
print(f"\nrecovery report:")
print(f"  makespan {rep['makespan'] * 1e3:.2f} ms "
      f"(baseline {rep['baseline_makespan'] * 1e3:.2f} ms, "
      f"recovery +{rep['recovery_makespan'] * 1e3:.2f} ms, "
      f"slowdown {rep['slowdown']:.2f}x)")
print(f"  extra bytes {rep['extra_bytes'] / 1e6:+.1f} MB, "
      f"evacuated {rep['evacuated_bytes'] / 1e6:.1f} MB "
      f"in {rep['n_evacuations']:.0f} write-backs")
print(f"  killed {rep['n_killed']:.0f} running task(s) "
      f"({rep['wasted_s'] * 1e3:.2f} ms wasted), "
      f"requeued {rep['n_requeued']:.0f}")

print("\n== phase 3: record the preemption trace, replay it ==")
path = os.path.join(tempfile.mkdtemp(prefix="elastic_"), "preemptions.jsonl")
save_trace(sim.faults.history, path)
print(f"trace saved to {path}:")
with open(path) as f:
    for line in f:
        print(f"  {line.rstrip()}")

replayed = Simulator(
    cholesky_graph(NT, 512, with_fns=False), paper_machine(8),
    resolve(SPEC), seed=0, noise=0.0, fault_trace=path,
).run()
assert fingerprint(replayed) == fingerprint(faulted), \
    "trace replay diverged from the recorded run"
print("replay is bit-identical to the faulted run "
      f"({len(replayed.intervals)} task intervals match)")
