"""Fault tolerance + elastic scaling: train, checkpoint, lose devices,
re-plan with DADA affinity, resume bit-exactly.

Run:  PYTHONPATH=src python examples/elastic_restart.py
"""
import sys
sys.path.insert(0, "src")

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.manager import CheckpointManager
from repro.configs.registry import smoke_config
from repro.configs.shapes import ShapeSpec
from repro.data.pipeline import SyntheticPipeline
from repro.dist.elastic import replan
from repro.models.transformer import init_params
from repro.optim.adamw import adamw_init
from repro.train.step import make_train_step

cfg = smoke_config("jamba-v0.1-52b")  # MoE + hybrid: the interesting case
shape = ShapeSpec("t", 64, 2, "train")
pipe = SyntheticPipeline(cfg, shape, seed=0)
step_fn = jax.jit(make_train_step(cfg))

params = init_params(cfg, jax.random.PRNGKey(0))
opt = adamw_init(params)
ckdir = tempfile.mkdtemp(prefix="elastic_")
mgr = CheckpointManager(ckdir)

print("== phase 1: 256 devices, steps 0-4 ==")
plan = replan(256, n_experts=cfg.moe.n_experts)
print(f"mesh {plan.mesh_shape}, expert groups balanced: "
      f"{np.bincount(plan.placement.assignment).tolist()}")
for s in range(5):
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(s).items()}
    params, opt, m = step_fn(params, opt, batch)
mgr.save(5, {"params": params, "opt": opt})
print(f"checkpointed at step 5, loss={float(m['loss']):.4f}")

print("== FAILURE: 128 devices survive ==")
mass = np.random.default_rng(1).pareto(1.0, cfg.moe.n_experts) * 100
plan2 = replan(128, n_experts=cfg.moe.n_experts,
               routing_mass=mass, prev_assignment=plan.placement.assignment)
moved = int((plan2.placement.assignment != plan.placement.assignment).sum())
print(f"re-planned mesh {plan2.mesh_shape}; DADA moved only "
      f"{moved}/{cfg.moe.n_experts} experts (affinity keeps the rest)")

step, state, _ = mgr.restore({"params": params, "opt": opt})
params, opt = state["params"], state["opt"]
print(f"restored step {step}; resuming 5-9")
for s in range(step, 10):
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(s).items()}
    params, opt, m = step_fn(params, opt, batch)
print(f"resumed OK, loss={float(m['loss']):.4f}")
