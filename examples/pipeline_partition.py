"""Dual approximation (paper §3.2) as a pipeline-stage partitioner.

Partitions jamba's 32 heterogeneous layers (Mamba / attention / MoE mix)
into pipeline stages using per-layer analytic costs from the roofline
model — the guess-and-check λ binary search from DADA's balance phase.

Run:  PYTHONPATH=src python examples/pipeline_partition.py
"""
import sys
sys.path.insert(0, "src")

from repro.analysis.flops import (
    _attn_flops_per_tok, _mamba_flops_per_tok, _mlp_flops_per_tok,
    _moe_flops_per_tok,
)
from repro.configs.registry import get_config
from repro.dist.sched_bridge import partition_layers, stage_loads

# gemma-7b: uniform blocks but a 256k-vocab unembedding that loads the
# last stage — the case where equal-depth cuts are wrong
cfg = get_config("gemma-7b")
layer = _attn_flops_per_tok(cfg, 2048) + _mlp_flops_per_tok(cfg)
costs = [layer / 1e6] * cfg.n_layers
costs[0] += 2 * cfg.d_model * cfg.vocab / 1e6 * 0.1   # embed lookup (cheap)
costs[-1] += 2 * cfg.d_model * cfg.vocab / 1e6        # lm head matmul

print(f"gemma-7b: {cfg.n_layers} layers + vocab head, per-stage-unit cost "
      f"{min(costs):.0f}-{max(costs):.0f} MFLOP/tok")
for k in (2, 4, 8):
    starts = partition_layers(costs, k)
    loads = stage_loads(costs, starts)
    naive = [sum(costs[i * len(costs) // k:(i + 1) * len(costs) // k]) for i in range(k)]
    print(f"  {k} stages: cuts at {starts}")
    print(f"    dual-approx max load {max(loads):8.0f}  vs equal-depth cut "
          f"{max(naive):8.0f}  (imbalance {max(loads)/ (sum(costs)/k) - 1:+.1%} vs "
          f"{max(naive)/(sum(costs)/k) - 1:+.1%})")
