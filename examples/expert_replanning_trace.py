"""Dynamic expert re-planning under routing drift (paper §6's robustness
question, answered at LM scale).

Simulates 50 "training windows" of a kimi-shaped MoE layer whose routing
distribution drifts (expert popularity random-walks). Every window the
runtime re-plans expert placement; we compare policies over the whole trace:

  * static round-robin (never move),
  * re-balance greedily every window (alpha=0: pure balance, ignores where
    weights live),
  * DADA(alpha=1.0): balance + affinity to the current placement.

Metrics accumulated over the trace: total expert-weight movement (bytes
proxy) and mean load imbalance — the paper's transfer/performance
compromise, now across *time*.

Run:  PYTHONPATH=src python examples/expert_replanning_trace.py
"""
import sys
sys.path.insert(0, "src")

import numpy as np

from repro.dist.sched_bridge import plan_expert_placement

G, E, WINDOWS = 16, 384, 50
rng = np.random.default_rng(0)

# drifting routing popularity (log-space random walk)
logpop = rng.normal(0, 1.0, E)
traces = []
for _ in range(WINDOWS):
    logpop = logpop + rng.normal(0, 0.25, E)
    traces.append(np.exp(logpop))

rr = np.arange(E) % G


def imbalance(mass, assign):
    loads = np.bincount(assign, weights=mass, minlength=G)
    return loads.max() / mass.sum() * G - 1.0


results = {}
for label, alpha, replan in [
    ("static-rr", None, False),
    ("rebalance(a=0)", 0.0, True),
    ("dada(a=0.25)", 0.25, True),
    ("dada(a=0.5)", 0.5, True),
    ("dada(a=0.75)", 0.75, True),
    ("dada(a=1)", 1.0, True),
]:
    assign = rr.copy()
    moved_total = 0
    imbs = []
    for mass in traces:
        if replan:
            pl = plan_expert_placement(mass, G, prev_assignment=assign, alpha=alpha)
            moved_total += pl.moved_experts
            assign = pl.assignment
        imbs.append(imbalance(mass, assign))
    results[label] = (moved_total, float(np.mean(imbs)))
    print(f"{label:16s} weights moved: {moved_total:5d}   "
          f"mean load imbalance: {np.mean(imbs)*100:6.1f}%")

mv_bal, imb_bal = results["rebalance(a=0)"]
mv_mid, imb_mid = results["dada(a=0.5)"]
print(f"\nalpha traces the movement/balance frontier: alpha=0.5 reaches "
      f"{imb_mid*100:.1f}% imbalance (pure balance: {imb_bal*100:.1f}%) while "
      f"moving {mv_bal/max(mv_mid,1):.1f}x fewer weights; alpha=1 never moves "
      f"— the paper's affinity compromise, sustained under drift.")
