"""Quickstart: the paper's scheduling framework in 30 lines.

Builds a tile-Cholesky task DAG, schedules it with HEFT and DADA on the
paper's 12-CPU + 8-GPU machine model, executes the DADA schedule with real
JAX tile kernels, and verifies the numerics.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.configs.paper_machine import paper_machine
from repro.core import run_simulation
from repro.linalg import tiles as T
from repro.linalg.cholesky import cholesky_graph
from repro.linalg.execute import execute_schedule
from repro.sched import resolve

N, TILE = 1024, 128
NT = N // TILE

machine = paper_machine(n_gpus=4)
graph = cholesky_graph(NT, TILE)
print(f"Cholesky {N}x{N}: {len(graph)} tasks, {graph.n_edges} edges")

# policies come from the registry: bare names or query-string specs
for spec in ["heft", "dada?alpha=0.5&use_cp=1", "ws", "locality", "random"]:
    strat = resolve(spec)
    res = run_simulation(cholesky_graph(NT, TILE, with_fns=False), machine, strat, seed=0)
    print(f"  {res.strategy:12s} {res.gflops:7.1f} GFLOPS  "
          f"{res.gbytes*1e3:7.1f} MB moved  {res.n_steals} steals")

# execute the affinity schedule for real and check the factorization
a = T.random_spd(N, seed=0, dtype=jnp.float32)
res = run_simulation(
    cholesky_graph(NT, TILE, with_fns=False), machine,
    resolve("dada?alpha=0.5"), seed=0,
)
store = execute_schedule(graph, T.split_tiles(a, TILE), res)
L = jnp.tril(T.join_tiles(store, NT, TILE))
err = float(jnp.abs(L @ L.T - a).max() / jnp.abs(a).max())
print(f"DADA schedule executed on JAX: ||LL^T - A|| rel err = {err:.2e}")
assert err < 1e-5
print("OK")
