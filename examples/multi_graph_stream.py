"""Multi-tenant streaming: several task DAGs interleaving on one machine.

The layered runtime (``repro.runtime.Engine``) accepts task graphs before
*and during* a run — ``submit(graph, at=...)`` posts the arrival as an
event — so many tenant DAGs share the workers, links and (optionally
capacity-bounded) device memories of one machine, each getting its own
per-graph makespan and interval timeline.

Run:  PYTHONPATH=src python examples/multi_graph_stream.py
"""
from repro.configs.paper_machine import paper_machine
from repro.linalg.cholesky import cholesky_graph
from repro.linalg.lu import lu_graph
from repro.linalg.qr import qr_graph
from repro.runtime import Engine
from repro.sched import resolve

MB = 1024 * 1024

machine = paper_machine(n_gpus=4)

# Two tenants are queued at t=0; three more stream in while the machine is
# busy. Device memories are capacity-bounded, so tenants also contend for
# GPU memory and the affinity evictor earns its keep.
TENANTS = [
    ("cholesky-16", cholesky_graph(16, 256, with_fns=False), None),
    ("lu-12", lu_graph(12, 256, with_fns=False), None),
    ("qr-10", qr_graph(10, 256, with_fns=False), 0.02),
    ("cholesky-12", cholesky_graph(12, 256, with_fns=False), 0.04),
    ("lu-8", lu_graph(8, 256, with_fns=False), 0.06),
]

engine = Engine(
    machine,
    resolve("dada?alpha=0.5&use_cp=1"),
    seed=0,
    mem_capacity=64 * MB,
    eviction="affinity",
)
for name, graph, at in TENANTS:
    ctx = engine.submit(graph, at=at)
    arrival = f"t={at:.2f}s" if at is not None else "t=0 (queued)"
    print(f"submitted {name:12s} {len(graph):4d} tasks, arrives {arrival}")

results = engine.run()

print(f"\n{'tenant':12s} {'arrive':>7s} {'finish':>7s} {'makespan':>9s} {'gflops':>7s}")
for (name, graph, _), res in zip(TENANTS, results):
    print(
        f"{name:12s} "
        f"{(res.intervals[0].start if res.intervals else 0):7.3f} "
        f"{max(iv.end for iv in res.intervals):7.3f} "
        f"{res.makespan:9.4f} {res.gflops:7.1f}"
    )
print(
    f"\nmachine totals: {engine.n_events} events, "
    f"{engine.total_bytes / 1e9:.2f} GB moved, "
    f"{engine.metrics.n_evictions} evictions "
    f"({engine.metrics.writeback_bytes / 1e6:.1f} MB written back)"
)
assert all(
    sorted(iv.tid for iv in r.intervals) == list(range(len(t[1])))
    for r, t in zip(results, TENANTS)
), "every tenant task must run exactly once"
print("OK")
