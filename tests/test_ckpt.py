"""Checkpoint/restart: round trip, atomicity, resume-exactness, retention."""
import json
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.manager import CheckpointManager
from repro.configs.registry import smoke_config
from repro.configs.shapes import ShapeSpec
from repro.data.pipeline import SyntheticPipeline
from repro.models.transformer import init_params
from repro.optim.adamw import adamw_init
from repro.train.step import make_train_step


def _tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((2, 2), jnp.bfloat16), "d": jnp.zeros((5,), jnp.int32)},
    }


def test_round_trip(tmp_path):
    m = CheckpointManager(tmp_path)
    t = _tree()
    m.save(7, t)
    step, got, _ = m.restore(jax.tree.map(jnp.zeros_like, t))
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_background_save(tmp_path):
    m = CheckpointManager(tmp_path)
    m.save(1, _tree(), blocking=False)
    m.wait()
    assert m.latest_step() == 1


def test_retention(tmp_path):
    m = CheckpointManager(tmp_path, keep=2)
    for s in [1, 2, 3, 4]:
        m.save(s, _tree())
    assert m.steps() == [3, 4]


def test_no_partial_checkpoint_visible(tmp_path):
    """Tmp dirs never count as checkpoints (atomic rename contract)."""
    m = CheckpointManager(tmp_path)
    (tmp_path / ".tmp_step_9").mkdir()
    assert m.steps() == []
    m.save(9, _tree())
    assert m.steps() == [9]


def test_shape_mismatch_rejected(tmp_path):
    m = CheckpointManager(tmp_path)
    m.save(1, {"a": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        m.restore({"a": jnp.zeros((3, 3))})


def test_resume_exactness(tmp_path):
    """Train 6 steps straight == train 3, checkpoint, restart, train 3 more.

    This is the node-failure recovery contract: state + deterministic data
    pipeline make restarts bit-exact.
    """
    cfg = smoke_config("granite-8b")
    shape = ShapeSpec("t", 32, 2, "train")
    pipe = SyntheticPipeline(cfg, shape, seed=3)
    step_fn = jax.jit(make_train_step(cfg))

    def run(params, opt, s0, s1):
        for s in range(s0, s1):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch(s).items()}
            params, opt, m = step_fn(params, opt, batch)
        return params, opt, m

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)

    # straight run
    p_a, o_a, m_a = run(params, opt, 0, 6)

    # interrupted run
    p_b, o_b, _ = run(params, opt, 0, 3)
    mgr = CheckpointManager(tmp_path)
    mgr.save(3, {"params": p_b, "opt": o_b}, meta={"data": pipe.state()})
    step, restored, meta = mgr.restore({"params": p_b, "opt": o_b})
    assert meta["data"]["seed"] == 3
    p_c, o_c, m_c = run(restored["params"], restored["opt"], step, 6)

    np.testing.assert_allclose(float(m_a["loss"]), float(m_c["loss"]), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(p_a), jax.tree.leaves(p_c)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=1e-5, atol=1e-6
        )
