"""Simulator invariants: completeness, precedence, transfers, determinism."""
import pytest

from repro.configs.paper_machine import paper_machine
from repro.core import (
    DataObject,
    Mode,
    TaskGraph,
    make_strategy,
    run_simulation,
)
from repro.linalg.cholesky import cholesky_graph

STRATS = ["heft", "ws", "dual"]


def _chol(nt=6, tile=256):
    return cholesky_graph(nt, tile, with_fns=False)


@pytest.mark.parametrize("strat", STRATS + ["dada"])
def test_all_tasks_run_exactly_once(strat):
    g = _chol()
    res = run_simulation(g, paper_machine(3), strat, seed=0)
    tids = [iv.tid for iv in res.intervals]
    assert sorted(tids) == list(range(len(g)))


@pytest.mark.parametrize("strat", STRATS + ["dada"])
def test_precedence_respected(strat):
    g = _chol()
    res = run_simulation(g, paper_machine(3), strat, seed=0)
    end = {iv.tid: iv.end for iv in res.intervals}
    start = {iv.tid: iv.start for iv in res.intervals}
    for t in g.tasks:
        for p in g.pred[t.tid]:
            assert end[p] <= start[t.tid] + 1e-9


@pytest.mark.parametrize("strat", STRATS)
def test_workers_not_double_booked(strat):
    g = _chol()
    res = run_simulation(g, paper_machine(2), strat, seed=1)
    per_worker = {}
    for iv in res.intervals:
        per_worker.setdefault(iv.rid, []).append((iv.start, iv.end))
    for rid, ivs in per_worker.items():
        ivs.sort()
        for (s1, e1), (s2, e2) in zip(ivs, ivs[1:]):
            assert e1 <= s2 + 1e-9, f"worker {rid} overlaps"


def test_makespan_at_least_critical_path():
    g = _chol()
    m = paper_machine(4)
    # lower bound: every task at its best-class rate, zero transfer
    classes = m.classes()
    lb = g.critical_path_length(
        lambda t: min(c.exec_time(t.kind, t.flops) for c in classes)
    )
    for strat in STRATS:
        res = run_simulation(g, m, strat, seed=0, noise=0.0)
        assert res.makespan >= lb * (1 - 1e-9)


def test_cpu_only_machine_no_transfers():
    g = _chol()
    res = run_simulation(g, paper_machine(0), "heft", seed=0)
    assert res.total_bytes == 0
    assert res.n_transfers == 0


def test_gpu_runs_imply_transfers():
    g = _chol()
    res = run_simulation(g, paper_machine(4), "heft", seed=0)
    assert res.total_bytes > 0


def test_determinism():
    g1 = _chol()
    g2 = _chol()
    m = paper_machine(3)
    r1 = run_simulation(g1, m, make_strategy("dada", alpha=0.7), seed=42)
    r2 = run_simulation(g2, m, make_strategy("dada", alpha=0.7), seed=42)
    assert r1.makespan == r2.makespan
    assert r1.total_bytes == r2.total_bytes
    assert [iv.tid for iv in r1.intervals] == [iv.tid for iv in r2.intervals]


def test_steals_only_in_ws():
    g = _chol()
    m = paper_machine(3)
    assert run_simulation(g, m, "heft", seed=0).n_steals == 0
    assert run_simulation(g, m, "dual", seed=0).n_steals == 0
    assert run_simulation(g, m, "ws", seed=0).n_steals > 0


def test_busy_time_conservation():
    """Sum of interval lengths equals per-worker busy accounting."""
    g = _chol()
    res = run_simulation(g, paper_machine(2), "heft", seed=0)
    per = {}
    for iv in res.intervals:
        per[iv.rid] = per.get(iv.rid, 0.0) + (iv.end - iv.start)
    for rid, b in res.busy.items():
        assert abs(per.get(rid, 0.0) - b) < 1e-6


def test_write_invalidation_forces_retransfer():
    """d written on GPU0 then read on GPU1 must move (2-hop via host)."""
    g = TaskGraph()
    d = DataObject("d", 1000)
    e = DataObject("e", 1000)
    g.add_task("gemm", [(d, Mode.RW)], flops=1e9)
    g.add_task("gemm", [(d, Mode.R), (e, Mode.RW)], flops=1e9)

    class Pin:
        # force task0 -> gpu A, task1 -> gpu B
        name = "pin"
        allow_steal = False
        owner_lifo = False

        def init(self, sim):
            self.gpus = [r.rid for r in sim.machine.gpus]

        def place(self, sim, ready, src):
            for t in ready:
                sim.push(t, self.gpus[t.tid % 2])

    res = run_simulation(g, paper_machine(2), Pin(), seed=0)
    # initial H2D of d (+e) plus D2H+H2D for d after the write
    assert res.total_bytes >= 3 * 1000
