"""Sharding-rule unit tests (pure functions over a 512-device abstract mesh
are not needed — a tiny mesh with the same axis names exercises the rules)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import get_config
from repro.configs.shapes import SHAPES
from repro.dist.sharding import batch_specs, cache_specs, opt_specs, param_specs
from repro.launch.input_specs import batch_sds, decode_sds, params_sds
from repro.launch.mesh import make_smoke_mesh


@pytest.fixture(scope="module")
def mesh():
    # single real device, but axis names/sizes drive the rules; use shape
    # (1,1) so every divisibility test passes trivially? No — we want the
    # production sizes. Use an abstract mesh built from the device repeated?
    # jax requires real devices; instead we monkeypatch sizes via a fake.
    return make_smoke_mesh(1, 1)


class FakeMesh:
    """Duck-typed mesh exposing .shape/.axis_names for the spec rules."""

    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


PROD = FakeMesh(data=16, model=16)
PROD2 = FakeMesh(pod=2, data=16, model=16)


def _leaf(tree, *path):
    node = tree
    for p in path:
        node = node[p]
    return node


def test_param_specs_granite():
    cfg = get_config("granite-8b")
    p = params_sds(cfg)
    specs = param_specs(cfg, p, PROD)
    # embedding: d over model (gather-friendly), vocab whole
    assert _leaf(specs, "embed", "table") == P(None, "model")
    # lm_head: vocab over model, d FSDP over data
    assert _leaf(specs, "lm_head") == P("data", "model")
    # attention wq (stacked): leading period dim unsharded
    wq = _leaf(specs, "blocks", "p0", "attn", "wq")
    assert wq[0] is None and "model" in wq


def test_param_specs_tied_vocab_sharded():
    cfg = get_config("gemma-7b")  # tied embeddings, vocab 256000 % 16 == 0
    specs = param_specs(cfg, params_sds(cfg), PROD)
    assert _leaf(specs, "embed", "table") == P("model", None)


def test_param_specs_indivisible_vocab_replicated():
    cfg = get_config("minicpm3-4b")  # vocab 73448 % 16 != 0
    specs = param_specs(cfg, params_sds(cfg), PROD)
    spec = _leaf(specs, "lm_head")
    assert spec[1] is None  # vocab dim cannot shard


def test_moe_expert_dim_sharded_when_divisible():
    cfg = get_config("kimi-k2-1t-a32b")  # 384 experts % 16 == 0
    specs = param_specs(cfg, params_sds(cfg), PROD)
    w_up = _leaf(specs, "blocks", "p0", "moe", "w_up")
    assert w_up[1] == "model"  # EP on the expert dim


def test_moe_fallback_tp_when_experts_indivisible():
    cfg = get_config("grok-1-314b")  # 8 experts, not divisible by 16
    specs = param_specs(cfg, params_sds(cfg), PROD)
    w_up = _leaf(specs, "blocks", "p0", "moe", "w_up")
    assert w_up[1] != "model"  # expert dim not sharded
    assert "model" in tuple(w_up)  # but some dim is (TP inside experts)


def test_fsdp_off_drops_data_axis():
    cfg = get_config("granite-8b")
    specs = param_specs(cfg, params_sds(cfg), PROD, fsdp=False)
    flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for s in flat:
        assert "data" not in [a for a in s if isinstance(a, str)], s


def test_batch_specs_train_and_long_context():
    cfg = get_config("granite-8b")
    b = batch_sds(cfg, SHAPES["train_4k"])
    specs = batch_specs(cfg, PROD2, b)
    assert specs["tokens"] == P(("pod", "data"))
    # long_500k: batch=1 -> sequence dim takes the batch axes
    cfg2 = get_config("jamba-v0.1-52b")
    b2 = {"tokens": jax.ShapeDtypeStruct((1, 524288), jnp.int32)}
    specs2 = batch_specs(cfg2, PROD2, b2)
    assert specs2["tokens"] == P(None, ("pod", "data"))


def test_cache_specs_gqa_sequence_sharding():
    cfg = get_config("chatglm3-6b")  # kv=2 < 16 -> S over model
    d = decode_sds(cfg, SHAPES["decode_32k"])
    specs = cache_specs(cfg, PROD, d["cache"])
    k = _leaf(specs, "p0", "k")  # (periods, B, S, kv, hd)
    assert k[1] == ("data",) or k[1] == "data"
    assert k[2] == "model"  # sequence-sharded KV

    cfg2 = get_config("gemma-7b")  # kv=16 -> heads shard
    d2 = decode_sds(cfg2, SHAPES["decode_32k"])
    specs2 = cache_specs(cfg2, PROD, d2["cache"])
    k2 = _leaf(specs2, "p0", "k")
    assert k2[3] == "model"


def test_opt_specs_inherit():
    cfg = get_config("granite-8b")
    ps = param_specs(cfg, params_sds(cfg), PROD)
    os_ = opt_specs(ps)
    assert _leaf(os_["m"], "lm_head") == _leaf(ps, "lm_head")
    assert os_["step"] == P()


def test_ep_pods_spans_pod_axis():
    cfg = get_config("kimi-k2-1t-a32b")  # 384 % (2*16) == 0
    specs = param_specs(cfg, params_sds(cfg), PROD2, ep_pods=True)
    w_up = _leaf(specs, "blocks", "p0", "moe", "w_up")
    assert w_up[1] == ("pod", "model")
    # without the flag: model only
    specs = param_specs(cfg, params_sds(cfg), PROD2)
    assert _leaf(specs, "blocks", "p0", "moe", "w_up")[1] == "model"
