"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import (
    flash_attention_ref,
    gemm_update_ref,
    matmul_ref,
)
from repro.kernels.tile_gemm import gemm_update, matmul

RNG = np.random.default_rng(0)


def _arr(shape, dtype):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


TOL = {jnp.float32: 2e-4, jnp.bfloat16: 5e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "m,n,k", [(128, 128, 128), (256, 128, 384), (384, 256, 128), (64, 64, 64)]
)
def test_gemm_update_shapes_dtypes(m, n, k, dtype):
    c = _arr((m, n), dtype)
    a = _arr((m, k), dtype)
    b = _arr((k, n), dtype)
    out = gemm_update(c, a, b, interpret=True)
    ref = gemm_update_ref(c, a, b)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype] * k ** 0.5, rtol=TOL[dtype],
    )


@pytest.mark.parametrize("alpha", [-1.0, 1.0, 0.5])
@pytest.mark.parametrize("trans_b", [False, True])
def test_gemm_update_variants(alpha, trans_b):
    m, n, k = 256, 128, 128
    c = _arr((m, n), jnp.float32)
    a = _arr((m, k), jnp.float32)
    b = _arr((n, k) if trans_b else (k, n), jnp.float32)
    out = gemm_update(c, a, b, alpha=alpha, trans_b=trans_b, interpret=True)
    ref = gemm_update_ref(c, a, b, alpha=alpha, trans_b=trans_b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3)


def test_matmul():
    a = _arr((256, 384), jnp.float32)
    b = _arr((384, 128), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(matmul(a, b, interpret=True)),
        np.asarray(matmul_ref(a, b)),
        atol=2e-3,
    )


def test_gemm_rejects_non_tiling_shapes():
    c = _arr((100, 100), jnp.float32)
    a = _arr((100, 100), jnp.float32)
    with pytest.raises(AssertionError):
        gemm_update(c, a, a, bm=64, bn=64, bk=64, interpret=True)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize(
    "hq,hk,sq,sk,d",
    [
        (4, 4, 128, 128, 128),   # MHA
        (4, 2, 128, 128, 128),   # GQA 2:1
        (8, 1, 128, 256, 128),   # MQA, decode-style sk > sq
        (4, 2, 128, 128, 256),   # gemma-style head_dim 256
    ],
)
def test_flash_attention_sweep(hq, hk, sq, sk, d, causal, dtype):
    q = _arr((hq, sq, d), dtype)
    k = _arr((hk, sk, d), dtype)
    v = _arr((hk, sk, d), dtype)
    out = flash_attention(q, k, v, causal=causal, bq=64, bk=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(ref, np.float32),
        atol=(3e-2 if dtype == jnp.bfloat16 else 2e-5),
        rtol=(3e-2 if dtype == jnp.bfloat16 else 2e-5),
    )


def test_flash_attention_matches_on_long_context():
    q = _arr((2, 256, 128), jnp.float32)
    k = _arr((2, 1024, 128), jnp.float32)
    v = _arr((2, 1024, 128), jnp.float32)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "hq,hk,s,d,length",
    [
        (8, 2, 512, 128, 512),   # GQA 4:1, full cache
        (4, 1, 1024, 128, 700),  # MQA, partially-filled cache
        (16, 16, 256, 128, 256), # MHA
    ],
)
def test_flash_decode_sweep(hq, hk, s, d, length, dtype):
    from repro.kernels.flash_decode import flash_decode
    from repro.kernels.ref import flash_decode_ref

    B = 2
    q = _arr((B, hq, d), dtype)
    k = _arr((B, s, hk, d), dtype)
    v = _arr((B, s, hk, d), dtype)
    out = flash_decode(q, k, v, length, bk=256, interpret=True)
    ref = flash_decode_ref(q, k, v, length)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=(3e-2 if dtype == jnp.bfloat16 else 1e-5),
        rtol=(3e-2 if dtype == jnp.bfloat16 else 1e-5),
    )
