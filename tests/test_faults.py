"""Fault-injected runtime: detach/attach, drain vs kill-and-requeue,
dirty-data evacuation, trace replay, seeded churn, and the config knobs.

The zero-fault bit-for-bit contract (no fault machinery may perturb a
run without faults) is covered both here (no-op injection, churn=0) and
by the unchanged tests/test_equivalence*.py suites.
"""
import math
import os
import tempfile

import pytest

from repro.configs.paper_machine import paper_machine
from repro.core.machine import HOST_MEM
from repro.core.simulator import Simulator
from repro.linalg.cholesky import cholesky_graph
from repro.runtime import (
    FAULT_MODES,
    FaultEvent,
    load_trace,
    recovery_report,
    save_trace,
)
from repro.sched import resolve
from repro.sched.config import SchedConfig

MB = 1024 * 1024


def _graph(nt=6):
    return cholesky_graph(nt, 256, with_fns=False)


def _fp(res):
    return (
        res.makespan,
        res.total_bytes,
        tuple((iv.tid, iv.rid, iv.start, iv.end) for iv in res.intervals),
    )


def _baseline(spec="heft", nt=6, n=4, seed=0):
    return Simulator(
        _graph(nt), paper_machine(n), resolve(spec), seed=seed, noise=0.0
    ).run()


def _dead_windows(history):
    """rid -> list of [detach, attach) intervals from a fault history."""
    out = {}
    open_at = {}
    for e in history:
        if e.event == "detach":
            open_at[e.rid] = e.t
        elif e.event == "attach" and e.rid in open_at:
            out.setdefault(e.rid, []).append((open_at.pop(e.rid), e.t))
    for rid, t in open_at.items():
        out.setdefault(rid, []).append((t, math.inf))
    return out


def _assert_no_start_while_dead(res, history):
    windows = _dead_windows(history)
    for iv in res.intervals:
        for lo, hi in windows.get(iv.rid, ()):
            assert not (lo <= iv.start < hi), (
                f"task {iv.tid} started on rid {iv.rid} at {iv.start} "
                f"inside dead window [{lo}, {hi})"
            )


def _assert_all_complete_once(res, nt=6):
    n_tasks = len(_graph(nt).tasks)
    assert sorted(iv.tid for iv in res.intervals) == list(range(n_tasks))


# ---------------------------------------------------------------------------
# injection API


def test_inject_validates_event_mode_and_rid():
    sim = Simulator(_graph(), paper_machine(2), resolve("heft"), seed=0)
    with pytest.raises(ValueError, match="event"):
        sim.inject("explode", 0, at=0.0)
    with pytest.raises(ValueError, match="mode"):
        sim.inject("detach", 0, at=0.0, mode="panic")
    with pytest.raises(TypeError):
        sim.inject("detach", "gpu0", at=0.0)
    with pytest.raises(ValueError):
        sim.inject("detach", 99, at=0.0)


def test_detaching_last_worker_rejected():
    # detach every worker but one, then the last detach must be refused
    # at fire time — a machine with no resource cannot make progress
    sim = Simulator(_graph(4), paper_machine(1), resolve("heft"), seed=0)
    rids = [r.rid for r in sim.machine.resources]
    for rid in rids[:-1]:
        sim.inject("detach", rid, at=0.0, mode="drain")
    sim.inject("detach", rids[-1], at=0.0, mode="drain")
    with pytest.raises(RuntimeError, match="last alive"):
        sim.run()


def test_zero_fault_run_has_no_fault_summary():
    res = _baseline()
    assert res.faults is None


# ---------------------------------------------------------------------------
# drain vs kill


@pytest.mark.parametrize("spec", ["heft", "dada?alpha=0.5&use_cp=1", "ws"])
@pytest.mark.parametrize("mode", FAULT_MODES)
def test_detach_reattach_all_tasks_complete_once(spec, mode):
    base = _baseline(spec)
    m = paper_machine(4)
    gpus = [r.rid for r in m.gpus]
    sim = Simulator(_graph(), m, resolve(spec), seed=0, noise=0.0)
    sim.inject("detach", gpus[0], at=base.makespan * 0.25, mode=mode)
    sim.inject("detach", gpus[1], at=base.makespan * 0.4, mode=mode)
    sim.inject("attach", gpus[0], at=base.makespan * 0.6)
    res = sim.run()
    _assert_all_complete_once(res)
    _assert_no_start_while_dead(res, sim.faults.history)
    assert res.faults["n_detaches"] == 2
    assert res.faults["n_attaches"] == 1


def test_drain_lets_running_task_finish_on_dead_worker():
    """Drain: a task already running at detach time completes where it is;
    its interval belongs to the dead worker and ends inside the window."""
    base = _baseline("heft")
    # pick a task mid-execution on a GPU around 30% of the baseline run
    probe = next(
        iv for iv in base.intervals
        if iv.rid in {r.rid for r in paper_machine(4).gpus}
        and iv.end - iv.start > 1e-6
    )
    cut = (probe.start + probe.end) / 2
    sim = Simulator(_graph(), paper_machine(4), resolve("heft"), seed=0, noise=0.0)
    sim.inject("detach", probe.rid, at=cut, mode="drain")
    res = sim.run()
    _assert_all_complete_once(res)
    survivor = next(iv for iv in res.intervals if iv.tid == probe.tid)
    assert survivor.rid == probe.rid
    assert survivor.start < cut <= survivor.end
    assert res.faults["n_killed"] == 0
    assert res.faults["wasted_s"] == 0.0


def test_kill_aborts_and_requeues_running_task():
    """Kill-and-requeue: the running task is aborted (wasted work is
    accounted) and completes later on a survivor."""
    base = _baseline("heft")
    probe = next(
        iv for iv in base.intervals
        if iv.rid in {r.rid for r in paper_machine(4).gpus}
        and iv.end - iv.start > 1e-6
    )
    cut = (probe.start + probe.end) / 2
    sim = Simulator(_graph(), paper_machine(4), resolve("heft"), seed=0, noise=0.0)
    sim.inject("detach", probe.rid, at=cut, mode="kill")
    res = sim.run()
    _assert_all_complete_once(res)
    survivor = next(iv for iv in res.intervals if iv.tid == probe.tid)
    assert survivor.rid != probe.rid  # never reattached: must move
    assert survivor.start >= cut
    assert res.faults["n_killed"] >= 1
    assert res.faults["wasted_s"] > 0.0
    assert res.faults["n_requeued"] >= 1


@pytest.mark.parametrize("mode", FAULT_MODES)
def test_dirty_data_evacuated_to_host(mode):
    """Sole copies on a detached memory are written back to the host —
    no data is lost with either recovery mode."""
    base = _baseline("heft")
    m = paper_machine(4)
    gpu = m.gpus[0].rid
    sim = Simulator(_graph(), m, resolve("heft"), seed=0, noise=0.0)
    sim.inject("detach", gpu, at=base.makespan * 0.3, mode=mode)
    res = sim.run()
    _assert_all_complete_once(res)
    assert res.faults["n_evacuations"] > 0
    assert res.faults["evacuated_bytes"] > 0
    # evacuation traffic is visible in the byte ledger
    nofault = _baseline("heft")
    assert res.total_bytes >= nofault.total_bytes


@pytest.mark.parametrize(
    "spec", ["heft", "dada?alpha=0.5&use_cp=1", "ws", "locality", "random"]
)
def test_never_dispatch_to_detached_any_policy(spec):
    base = _baseline("heft")
    m = paper_machine(4)
    gpus = [r.rid for r in m.gpus]
    sim = Simulator(_graph(), m, resolve(spec), seed=2, noise=0.0)
    sim.inject("detach", gpus[0], at=base.makespan * 0.2, mode="kill")
    sim.inject("detach", gpus[1], at=base.makespan * 0.35, mode="drain")
    res = sim.run()
    _assert_all_complete_once(res)
    _assert_no_start_while_dead(res, sim.faults.history)


def test_attach_rejoins_and_takes_work():
    """A worker detached early and reattached at mid-run picks up tasks
    again — affinity-cold but alive."""
    base = _baseline("heft", nt=8)
    m = paper_machine(4)
    gpu = m.gpus[0].rid
    sim = Simulator(_graph(8), m, resolve("heft"), seed=0, noise=0.0)
    sim.inject("detach", gpu, at=base.makespan * 0.1, mode="kill")
    sim.inject("attach", gpu, at=base.makespan * 0.5)
    res = sim.run()
    _assert_all_complete_once(res, nt=8)
    rejoined = [iv for iv in res.intervals if iv.rid == gpu and iv.start >= base.makespan * 0.5]
    assert rejoined, "reattached worker never received a task"


# ---------------------------------------------------------------------------
# zero-fault equivalence of the guarded paths


def test_noop_attach_of_alive_worker_is_behavior_neutral():
    """Injecting an attach of an already-alive worker flips the fault
    machinery on but must not change a single placement or timestamp."""
    plain = _baseline("heft")
    sim = Simulator(_graph(), paper_machine(4), resolve("heft"), seed=0, noise=0.0)
    sim.inject("attach", 0, at=plain.makespan * 0.5)
    res = sim.run()
    assert _fp(res) == _fp(plain)
    assert res.faults is not None  # machinery was live, just event-free


def test_zero_churn_rate_is_identical_to_no_churn():
    plain = _baseline("dada?alpha=0.5&use_cp=1", seed=3)
    zero = Simulator(
        _graph(), paper_machine(4), resolve("dada?alpha=0.5&use_cp=1"),
        seed=3, noise=0.0, churn=0.0,
    ).run()
    assert _fp(zero) == _fp(plain)


# ---------------------------------------------------------------------------
# traces


def test_trace_save_load_roundtrip():
    evs = [
        FaultEvent(0.5, "detach", 3, "kill"),
        FaultEvent(0.1, "detach", 1, "drain"),
        FaultEvent(0.9, "attach", 3),
    ]
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.jsonl")
        save_trace(evs, path)
        back = load_trace(path)
    assert [e.t for e in back] == sorted(e.t for e in evs)  # sorted by t
    assert back[0] == FaultEvent(0.1, "detach", 1, "drain")
    assert back[2].mode is None


def test_trace_rejects_malformed_lines():
    cases = [
        ('{"t": 1.0, "event": "detach"}', "rid"),           # missing rid
        ('{"t": 1.0, "event": "melt", "rid": 0}', "event"),  # unknown event
        ('{"t": "soon", "event": "attach", "rid": 0}', "t"),  # wrong type
        ('{"t": 1.0, "event": "attach", "rid": 0, "x": 1}', "x"),  # unknown
        ("not json", r"bad\.jsonl:1"),
    ]
    for line, needle in cases:
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "bad.jsonl")
            with open(path, "w") as f:
                f.write(line + "\n")
            with pytest.raises(ValueError, match=needle):
                load_trace(path)


def test_trace_skips_blank_and_comment_lines():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.jsonl")
        with open(path, "w") as f:
            f.write("# preemption log\n\n")
            f.write('{"t": 0.5, "event": "detach", "rid": 2, "mode": "drain"}\n')
        evs = load_trace(path)
    assert evs == [FaultEvent(0.5, "detach", 2, "drain")]


def test_trace_replay_matches_programmatic_injection():
    """Replaying a recorded trace is bit-identical to injecting the same
    events by hand (the replay contract; note it is *not* required to
    match the churn run that produced the trace, whose sampler perturbs
    event-queue sequence numbers)."""
    m = paper_machine(4)
    sim = Simulator(
        _graph(), m, resolve("heft"), seed=1, noise=0.0,
        churn=150.0, fault_mode="kill",
    )
    sim.run()
    hist = sim.faults.history
    assert hist, "churn produced no events; raise the rate"
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "trace.jsonl")
        save_trace(hist, path)
        replayed = Simulator(
            _graph(), paper_machine(4), resolve("heft"), seed=1, noise=0.0,
            fault_trace=path,
        ).run()
    prog = Simulator(_graph(), paper_machine(4), resolve("heft"), seed=1, noise=0.0)
    for e in hist:
        prog.inject(e.event, e.rid, at=e.t, mode=e.mode)
    assert _fp(replayed) == _fp(prog.run())


# ---------------------------------------------------------------------------
# churn


def test_churn_same_seed_is_deterministic():
    def run():
        sim = Simulator(
            _graph(), paper_machine(4), resolve("heft"),
            seed=7, noise=0.02, churn=200.0, fault_mode="kill",
        )
        res = sim.run()
        return _fp(res), [(e.t, e.event, e.rid) for e in sim.faults.history]

    assert run() == run()


def test_churn_run_is_safe():
    sim = Simulator(
        _graph(8), paper_machine(4), resolve("dada?alpha=0.5&use_cp=1"),
        seed=11, noise=0.0, churn=300.0, fault_mode="kill",
    )
    res = sim.run()
    _assert_all_complete_once(res, nt=8)
    _assert_no_start_while_dead(res, sim.faults.history)
    assert res.faults["n_detaches"] == sum(
        1 for e in sim.faults.history if e.event == "detach"
    )


# ---------------------------------------------------------------------------
# config knobs


def test_churn_env_knob_parses_and_validates():
    cfg = SchedConfig.from_env({"REPRO_SCHED_CHURN": "2.5"})
    assert cfg.churn == 2.5
    with pytest.raises(ValueError, match="REPRO_SCHED_CHURN"):
        SchedConfig.from_env({"REPRO_SCHED_CHURN": "banana"})
    with pytest.raises(ValueError, match="REPRO_SCHED_CHURN"):
        SchedConfig.from_env({"REPRO_SCHED_CHURN": "-1"})


def test_fault_mode_env_knob_validates():
    assert SchedConfig.from_env({"REPRO_SCHED_FAULT_MODE": "KILL"}).fault_mode == "kill"
    with pytest.raises(ValueError, match="REPRO_SCHED_FAULT_MODE"):
        SchedConfig.from_env({"REPRO_SCHED_FAULT_MODE": "banana"})


def test_fault_trace_env_knob_requires_existing_file():
    with pytest.raises(ValueError, match="REPRO_SCHED_FAULT_TRACE"):
        SchedConfig.from_env({"REPRO_SCHED_FAULT_TRACE": "/nonexistent/t.jsonl"})
    with tempfile.NamedTemporaryFile(suffix=".jsonl") as f:
        cfg = SchedConfig.from_env({"REPRO_SCHED_FAULT_TRACE": f.name})
        assert cfg.fault_trace == f.name
    assert SchedConfig.from_env({"REPRO_SCHED_FAULT_TRACE": ""}).fault_trace is None


def test_churn_env_knob_drives_simulator(monkeypatch):
    monkeypatch.setenv("REPRO_SCHED_CHURN", "250")
    monkeypatch.setenv("REPRO_SCHED_FAULT_MODE", "kill")
    sim = Simulator(_graph(), paper_machine(4), resolve("heft"), seed=5, noise=0.0)
    res = sim.run()
    _assert_all_complete_once(res)
    assert res.faults is not None


# ---------------------------------------------------------------------------
# preemption notices: grace window, proactive replication, config knobs


def test_notice_grace_blocks_new_starts():
    base = _baseline("heft")
    m = paper_machine(4)
    rid = m.gpus[0].rid
    death = base.makespan * 0.5
    notice_w = base.makespan * 0.2
    sim = Simulator(_graph(), m, resolve("heft"), seed=0, noise=0.0)
    sim.inject("detach", rid, at=death, mode="drain", notice_s=notice_w)
    res = sim.run()
    _assert_all_complete_once(res)
    assert sim.metrics.n_notices == 1
    t_notice = death - notice_w
    for iv in res.intervals:
        if iv.rid == rid:
            assert not (t_notice < iv.start < death), (
                f"task {iv.tid} started on noticed rid {rid} at {iv.start} "
                f"inside grace window ({t_notice}, {death})"
            )


def test_notice_triggers_proactive_replication():
    # a generous warning on a worker holding sole copies pushes them
    # hostward inside the window, counted apart from death-time salvage
    base = _baseline("heft")
    m = paper_machine(4)
    rid = m.gpus[0].rid
    sim = Simulator(
        _graph(), m, resolve("heft"), seed=0, noise=0.0, audit=True
    )
    sim.inject(
        "detach", rid, at=base.makespan * 0.5, mode="kill",
        notice_s=base.makespan * 0.1,
    )
    res = sim.run()
    _assert_all_complete_once(res)
    assert sim.metrics.n_proactive > 0
    assert sim.metrics.proactive_bytes > 0
    fs = res.faults
    assert fs["n_notices"] == 1
    assert fs["proactive_bytes"] == sim.metrics.proactive_bytes
    from repro.verify import errors, verify_audit

    assert errors(verify_audit(sim.audit)) == []


def test_attach_before_death_cancels_notice():
    # the promised death never comes: an attach (spot reprieve) clears
    # the pending notice and the worker takes new work again
    base = _baseline("heft")
    m = paper_machine(4)
    rid = m.gpus[0].rid
    sim = Simulator(_graph(), m, resolve("heft"), seed=0, noise=0.0)
    sim.inject(
        "detach", rid, at=base.makespan * 0.4, mode="drain",
        notice_s=base.makespan * 0.2,
    )
    sim.inject("attach", rid, at=base.makespan * 0.6)
    res = sim.run()
    _assert_all_complete_once(res)
    assert rid not in sim.faults.noticed


def test_recovery_env_knobs_parse_and_validate():
    cfg = SchedConfig.from_env(
        {
            "REPRO_SCHED_NOTICE_S": "0.004",
            "REPRO_SCHED_LINK_FLAKE": "0.25",
            "REPRO_SCHED_RETRY_MAX": "4",
            "REPRO_SCHED_BACKOFF_S": "2e-4",
        }
    )
    assert cfg.notice_s == pytest.approx(0.004)
    assert cfg.link_flake == pytest.approx(0.25)
    assert cfg.retry_max == 4
    assert cfg.backoff_s == pytest.approx(2e-4)
    for var, bad in [
        ("REPRO_SCHED_NOTICE_S", "-1"),
        ("REPRO_SCHED_NOTICE_S", "banana"),
        ("REPRO_SCHED_LINK_FLAKE", "1.5"),
        ("REPRO_SCHED_LINK_FLAKE", "banana"),
        ("REPRO_SCHED_RETRY_MAX", "-2"),
        ("REPRO_SCHED_RETRY_MAX", "2.5"),
        ("REPRO_SCHED_BACKOFF_S", "-0.1"),
    ]:
        with pytest.raises(ValueError, match=var):
            SchedConfig.from_env({var: bad})


# ---------------------------------------------------------------------------
# recovery metrics + the elastic bridge


def test_recovery_report_fields():
    base = _baseline("heft")
    sim = Simulator(_graph(), paper_machine(4), resolve("heft"), seed=0, noise=0.0)
    sim.inject("detach", paper_machine(4).gpus[0].rid,
               at=base.makespan * 0.3, mode="kill")
    faulted = sim.run()
    rep = recovery_report(faulted, base)
    assert rep["baseline_makespan"] == base.makespan
    assert rep["makespan"] == faulted.makespan
    assert rep["recovery_makespan"] == pytest.approx(
        faulted.makespan - base.makespan
    )
    assert rep["slowdown"] == pytest.approx(faulted.makespan / base.makespan)
    assert rep["extra_bytes"] == faulted.total_bytes - base.total_bytes
    assert rep["n_detaches"] == 1


def test_elastic_replanner_follows_engine_faults():
    from repro.dist.elastic import ElasticReplanner

    base = _baseline("heft")
    m = paper_machine(4)
    gpus = [r.rid for r in m.gpus]
    sim = Simulator(_graph(), m, resolve("heft"), seed=0, noise=0.0)
    rp = ElasticReplanner(
        devices_per_worker=16, n_experts=32, model_axis=16
    ).attach_to(sim)
    sim.inject("detach", gpus[0], at=base.makespan * 0.25, mode="drain")
    sim.inject("attach", gpus[0], at=base.makespan * 0.6)
    sim.run()
    events = [(ev, nd) for _, ev, nd, _ in rp.history]
    n_gpus = len(gpus)
    assert events == [
        ("init", 16 * n_gpus),
        ("detach", 16 * (n_gpus - 1)),
        ("attach", 16 * n_gpus),
    ]
    assert rp.current is not None
    assert rp.current.mesh_shape[1] == 16
