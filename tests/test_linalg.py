"""Tiled linear algebra: numerics vs dense references + schedule replays."""
import jax.numpy as jnp
import pytest

from repro.configs.paper_machine import paper_machine
from repro.core import make_strategy, run_simulation
from repro.linalg import tiles as T
from repro.linalg.cholesky import cholesky_graph
from repro.linalg.execute import execute_graph, execute_schedule
from repro.linalg.lu import lu_graph
from repro.linalg.qr import qr_graph

N, TILE = 256, 64
NT = N // TILE


def _rel_err(x, y):
    return float(jnp.abs(x - y).max() / (jnp.abs(y).max() + 1e-30))


def test_cholesky_numerics():
    a = T.random_spd(N, seed=0, dtype=jnp.float32)
    store = execute_graph(cholesky_graph(NT, TILE), T.split_tiles(a, TILE))
    L = jnp.tril(T.join_tiles(store, NT, TILE))
    assert _rel_err(L @ L.T, a) < 1e-5
    # matches jnp.linalg.cholesky
    assert _rel_err(L, jnp.linalg.cholesky(a)) < 1e-4


def test_lu_numerics():
    a = T.random_dd(N, seed=1, dtype=jnp.float32)
    store = execute_graph(lu_graph(NT, TILE), T.split_tiles(a, TILE))
    M = T.join_tiles(store, NT, TILE)
    L = jnp.tril(M, -1) + jnp.eye(N)
    U = jnp.triu(M)
    assert _rel_err(L @ U, a) < 1e-5


def test_qr_numerics():
    a = T.random_dense(N, seed=2, dtype=jnp.float32)
    store = execute_graph(qr_graph(NT, TILE), T.split_tiles(a, TILE))
    R = jnp.triu(T.join_tiles(store, NT, TILE))
    assert _rel_err(R.T @ R, a.T @ a) < 1e-4


@pytest.mark.parametrize("strat_name,kw", [
    ("heft", {}),
    ("ws", {}),
    ("dada", {"alpha": 0.5}),
    ("dada", {"alpha": 1.0, "use_cp": True}),
])
@pytest.mark.parametrize("maker,matgen", [
    (cholesky_graph, T.random_spd),
    (lu_graph, T.random_dd),
    (qr_graph, T.random_dense),
])
def test_every_strategy_schedule_is_a_valid_linearization(strat_name, kw, maker, matgen):
    """Replaying any simulated schedule gives the same numerics as program
    order — i.e. schedules are valid linearizations of the data-flow DAG."""
    a = matgen(N, seed=3, dtype=jnp.float32)
    ref_store = execute_graph(maker(NT, TILE), T.split_tiles(a, TILE))
    res = run_simulation(
        maker(NT, TILE), paper_machine(2), make_strategy(strat_name, **kw), seed=7
    )
    store = execute_schedule(maker(NT, TILE), T.split_tiles(a, TILE), res)
    ref = T.join_tiles(ref_store, NT, TILE)
    got = T.join_tiles(store, NT, TILE)
    assert _rel_err(got, ref) < 1e-5


def test_graph_flop_totals_match_reference_counts():
    from repro.linalg import cholesky, lu, qr

    n, tile = 2048, 256
    nt = n // tile
    # leading-order agreement (within 20% for modest tile counts)
    assert cholesky.cholesky_graph(nt, tile).total_flops() == pytest.approx(
        cholesky.reference_flops(n), rel=0.2
    )
    assert lu.lu_graph(nt, tile).total_flops() == pytest.approx(
        lu.reference_flops(n), rel=0.2
    )
    assert qr.qr_graph(nt, tile).total_flops() == pytest.approx(
        qr.reference_flops(n), rel=0.35
    )
