"""The jax placement-scoring backend must be a pure speed refactor:
decisions, λ trajectories and score values bit-identical to the numpy
path, lazy fallback when jax is unavailable, bounded jit retraces via
padded shapes, and a Pallas transfer kernel that matches the XLA fold."""
import numpy as np
import pytest

from repro.configs.paper_machine import CPU_CLASS, GPU_CLASS, paper_machine, scaled_machine
from repro.core import DADA, HEFT, Simulator, run_simulation
from repro.core.backend import (
    _reset_backend_cache,
    backend_name,
    get_backend,
    jax_min_wide,
)
from repro.core.machine import make_machine
from repro.linalg.cholesky import cholesky_graph
from repro.linalg.lu import lu_graph
from repro.linalg.qr import qr_graph

jax = pytest.importorskip("jax")

KERNELS = {
    "cholesky": cholesky_graph,
    "lu": lu_graph,
    "qr": qr_graph,
}

STRATEGIES = {
    "heft": lambda b: HEFT(backend=b),
    "dada(0)": lambda b: DADA(alpha=0.0, backend=b),
    "dada(0.5)": lambda b: DADA(alpha=0.5, backend=b),
    "dada(0.5)+cp": lambda b: DADA(alpha=0.5, use_cp=True, backend=b),
}


@pytest.fixture
def force_jax(monkeypatch):
    """Engage the jax path at every activation width."""
    monkeypatch.setenv("REPRO_SCHED_JAX_MIN", "1")


def _fingerprint(res):
    return (
        res.makespan,
        res.total_bytes,
        res.n_transfers,
        res.n_steals,
        tuple(sorted(res.busy.items())),
        tuple((iv.tid, iv.rid, iv.start, iv.end) for iv in res.intervals),
    )


# ---------------------------------------------------------------------------
# decision identity


@pytest.mark.parametrize("kernel", sorted(KERNELS))
@pytest.mark.parametrize("strat", sorted(STRATEGIES))
@pytest.mark.parametrize("n_gpus", [0, 3, 8])
def test_jax_matches_numpy(force_jax, kernel, strat, n_gpus):
    machine = paper_machine(n_gpus)
    fac = STRATEGIES[strat]
    for seed in (0, 7):
        a = run_simulation(
            KERNELS[kernel](6, 256, with_fns=False), machine,
            fac("numpy"), seed=seed,
        )
        b = run_simulation(
            KERNELS[kernel](6, 256, with_fns=False), machine,
            fac("jax"), seed=seed,
        )
        assert _fingerprint(a) == _fingerprint(b)


def test_jax_lambda_and_loads_match(force_jax):
    """The accepted λ and the final per-resource loads must match too —
    they drive mid-simulation load_ts corrections."""
    machine = paper_machine(4)
    a = DADA(alpha=0.5, backend="numpy")
    b = DADA(alpha=0.5, backend="jax")
    run_simulation(cholesky_graph(6, 256, with_fns=False), machine, a, seed=3)
    run_simulation(cholesky_graph(6, 256, with_fns=False), machine, b, seed=3)
    assert a.last_lambda == b.last_lambda
    assert a.last_loads == b.last_loads


def test_jax_matches_numpy_all_gpu_machine(force_jax):
    machine = make_machine(
        n_cpus=4, n_gpus=4, cpu_class=CPU_CLASS, gpu_class=GPU_CLASS,
        gpu_pins_cpu=True,
    )
    a = run_simulation(
        cholesky_graph(6, 256, with_fns=False), machine,
        DADA(alpha=0.5, backend="numpy"), seed=2,
    )
    b = run_simulation(
        cholesky_graph(6, 256, with_fns=False), machine,
        DADA(alpha=0.5, backend="jax"), seed=2,
    )
    assert _fingerprint(a) == _fingerprint(b)


@pytest.mark.parametrize("affinity", ["write_resident", "all_resident",
                                      "missing_bytes", "accel_all"])
def test_jax_matches_numpy_nondefault_affinity(force_jax, affinity):
    """Fused resident-weighted scores and the missing_bytes fallback path
    must both reproduce numpy placements."""
    machine = paper_machine(3)
    a = run_simulation(
        cholesky_graph(6, 256, with_fns=False), machine,
        DADA(alpha=0.75, affinity=affinity, backend="numpy"), seed=9,
    )
    b = run_simulation(
        cholesky_graph(6, 256, with_fns=False), machine,
        DADA(alpha=0.75, affinity=affinity, backend="jax"), seed=9,
    )
    assert _fingerprint(a) == _fingerprint(b)


def test_jax_matches_numpy_area_bound(force_jax):
    machine = paper_machine(4)
    a = run_simulation(
        lu_graph(5, 256, with_fns=False), machine,
        DADA(alpha=0.5, area_bound=True, backend="numpy"), seed=1,
    )
    b = run_simulation(
        lu_graph(5, 256, with_fns=False), machine,
        DADA(alpha=0.5, area_bound=True, backend="jax"), seed=1,
    )
    assert _fingerprint(a) == _fingerprint(b)


def test_jax_matches_numpy_deep_lambda_tree(force_jax, monkeypatch):
    """depth>1 engages the vmapped speculative λ-grid — same trajectory."""
    monkeypatch.setenv("REPRO_SCHED_LAMBDA_DEPTH", "3")
    _reset_backend_cache()
    try:
        machine = paper_machine(4)
        a = run_simulation(
            cholesky_graph(6, 256, with_fns=False), machine,
            DADA(alpha=0.5, use_cp=True, backend="numpy"), seed=5,
        )
        b = run_simulation(
            cholesky_graph(6, 256, with_fns=False), machine,
            DADA(alpha=0.5, use_cp=True, backend="jax"), seed=5,
        )
        assert _fingerprint(a) == _fingerprint(b)
    finally:
        _reset_backend_cache()


# ---------------------------------------------------------------------------
# score-matrix bit-equality


def test_fused_matrices_bitwise_equal_numpy():
    from repro.core.affinity import affinity_rows

    graph = cholesky_graph(8, 256, with_fns=False)
    machine = scaled_machine(n_gpus=12, n_cpus=4)
    sim = Simulator(graph, machine, DADA(alpha=0.5, use_cp=True), seed=0)
    # seed residency so transfer hops and affinity scores are non-trivial
    for k, name in enumerate(sim.arrays.data_names):
        if k % 3 == 0:
            sim.residency.write(name, k % 12)
    ready = [t for t in graph.tasks if not graph.pred[t.tid]] + list(
        graph.tasks[:40]
    )
    tids = sorted({t.tid for t in ready})
    tasks = [graph.tasks[t] for t in tids]
    resources = machine.resources
    cpu_cls = machine.cpus[0].cls
    gpu_cls = machine.gpus[0].cls
    p_cpu = sim.predictor(cpu_cls).times(np.asarray(tids)).tolist()
    p_gpu = sim.predictor(gpu_cls).times(np.asarray(tids)).tolist()

    be = get_backend("jax")
    fused = be.score_matrices(
        sim, tids, resources, p_cpu=p_cpu, p_gpu=p_gpu,
        use_cp=True, affinity="accel_write", x_rows=True,
    )
    X_ref = np.asarray(
        sim.transfer_model.task_input_transfer_rows(
            sim.arrays, tids, [r.mem for r in resources], sim.residency
        )
    )
    S_ref = np.asarray(
        affinity_rows(
            "accel_write", sim.arrays, tids, tasks, resources, sim.residency
        )
    )
    assert (fused["X_np"] == X_ref).all()
    assert (fused["S_np"] == S_ref).all()
    # C = class duration + transfer, same op order
    gpu_col = np.asarray([r.is_accelerator for r in resources])
    base = np.where(gpu_col[None, :], np.asarray(p_gpu)[:, None],
                    np.asarray(p_cpu)[:, None])
    assert (fused["C_np"] == base + X_ref).all()


def test_pallas_transfer_kernel_matches_jnp_fold():
    jnp = jax.numpy
    from repro.kernels.sched_score import (
        transfer_matrix_jnp,
        transfer_matrix_pallas,
    )

    rng = np.random.default_rng(0)
    n_pad, r_pad, n_u = 256, 4, 25
    masks = rng.integers(0, 1 << (n_u + 1), size=(n_pad, r_pad)).astype(
        np.int32
    )
    per_read = rng.random((n_pad, r_pad))
    col_bits = np.asarray([1 << (u + 1) for u in range(n_u)], dtype=np.int32)
    host_col = np.zeros(n_u, dtype=bool)
    host_col[0] = True
    a = transfer_matrix_jnp(
        jnp.asarray(masks), jnp.asarray(per_read),
        jnp.asarray(col_bits), jnp.asarray(host_col),
    )
    b = transfer_matrix_pallas(
        jnp.asarray(masks), jnp.asarray(per_read),
        jnp.asarray(col_bits), jnp.asarray(host_col), interpret=True,
    )
    assert (np.asarray(a) == np.asarray(b)).all()


# ---------------------------------------------------------------------------
# backend selection, fallback, retrace bounds


def test_backend_name_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_SCHED_BACKEND", raising=False)
    assert backend_name() == "numpy"
    assert backend_name("jax") == "jax"
    monkeypatch.setenv("REPRO_SCHED_BACKEND", "jax")
    assert backend_name() == "jax"
    assert backend_name("numpy") == "numpy"
    with pytest.raises(ValueError):
        backend_name("cuda")


def test_numpy_backend_is_none():
    assert get_backend("numpy") is None


def test_min_wide_env(monkeypatch):
    monkeypatch.delenv("REPRO_SCHED_JAX_MIN", raising=False)
    assert jax_min_wide() == 32
    monkeypatch.setenv("REPRO_SCHED_JAX_MIN", "4")
    assert jax_min_wide() == 4
    # malformed values now fail loudly at SchedConfig.from_env() instead
    # of silently falling back to the default deep inside the backend
    monkeypatch.setenv("REPRO_SCHED_JAX_MIN", "junk")
    with pytest.raises(ValueError, match="REPRO_SCHED_JAX_MIN"):
        jax_min_wide()


def test_missing_jax_falls_back_with_warning(monkeypatch):
    """A broken/missing jax must degrade to the numpy path (satellite:
    numpy-only environments keep passing tier-1) with one warning."""
    import repro.core.backend as backend_mod

    class _Broken:
        def __init__(self):
            raise ImportError("no module named jax (simulated)")

    _reset_backend_cache()
    monkeypatch.setattr(backend_mod, "JaxScoringBackend", _Broken)
    try:
        with pytest.warns(RuntimeWarning, match="falling back"):
            assert get_backend("jax") is None
        # second resolution: silent, still numpy
        assert get_backend("jax") is None
        # simulations still run (and match numpy bit-for-bit, trivially)
        machine = paper_machine(2)
        a = run_simulation(
            cholesky_graph(4, 256, with_fns=False), machine,
            DADA(alpha=0.5, backend="jax"), seed=0,
        )
        b = run_simulation(
            cholesky_graph(4, 256, with_fns=False), machine,
            DADA(alpha=0.5, backend="numpy"), seed=0,
        )
        assert _fingerprint(a) == _fingerprint(b)
    finally:
        _reset_backend_cache()


def test_backend_does_not_leak_x64(force_jax):
    """The f64 scoring math is scoped per call: building and using the
    backend must not flip the process-wide default dtype of unrelated
    jax code (models/linalg/kernels stay f32)."""
    machine = paper_machine(3)
    run_simulation(
        cholesky_graph(5, 256, with_fns=False), machine,
        DADA(alpha=0.5, use_cp=True, backend="jax"), seed=0,
    )
    assert jax.numpy.asarray([1.0]).dtype == jax.numpy.float32


def test_padded_shapes_bound_retraces(force_jax):
    """Activation widths within one power-of-two bucket share a compiled
    search: the jit caches must stay bounded across activations."""
    be = get_backend("jax")
    n_search_before = len(be._search_fns)
    machine = paper_machine(3)
    run_simulation(
        cholesky_graph(6, 256, with_fns=False), machine,
        DADA(alpha=0.5, use_cp=True, backend="jax"), seed=0,
    )
    # ready widths 1..15 at NT=6 → buckets {8, 16} × (chain, flags) variants
    grown = len(be._search_fns) - n_search_before
    assert grown <= 8, f"unbounded retraces: {grown} new search signatures"
