"""Edge cases of the JSONL preemption-trace layer (repro.runtime.traces).

Complements tests/test_faults.py: exhaustive malformed-line rejection
(every variant must name the file *and the exact line*), and the
kill-mode fault-history round-trip — a churned run's recorded history
must survive save_trace/load_trace field-for-field and replay to the
same schedule.
"""
import os
import tempfile

import pytest

from repro.configs.paper_machine import paper_machine
from repro.core.simulator import Simulator
from repro.linalg.cholesky import cholesky_graph
from repro.runtime import FaultEvent, load_trace, save_trace
from repro.sched import resolve

GOOD = '{"t": 0.1, "event": "detach", "rid": 0, "mode": "drain"}'


def _load_lines(*lines):
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "trace.jsonl")
        with open(path, "w") as f:
            f.write("\n".join(lines) + "\n")
        try:
            return load_trace(path), path
        except ValueError as e:
            # surface the tempdir-relative location for assertions
            raise ValueError(str(e).replace(d + os.sep, "")) from None


# ---------------------------------------------------------------------------
# malformed lines: every variant names trace.jsonl:<lineno>


@pytest.mark.parametrize(
    "bad,needle",
    [
        ("{not json", "invalid JSON"),
        ("[1, 2, 3]", "expected a JSON object, got list"),
        ('"detach"', "expected a JSON object, got str"),
        ('{"t": 1.0, "event": "detach", "rid": 0, "sev": 9}', "unknown trace field"),
        ('{"event": "detach", "rid": 0}', "missing required field 't'"),
        ('{"t": 1.0, "rid": 0}', "missing required field 'event'"),
        ('{"t": 1.0, "event": "detach"}', "missing required field 'rid'"),
        ('{"t": true, "event": "detach", "rid": 0}', "'t' must be a number"),
        ('{"t": "1.0", "event": "detach", "rid": 0}', "'t' must be a number"),
        ('{"t": 1.0, "event": "detach", "rid": true}', "'rid' must be an integer"),
        ('{"t": 1.0, "event": "detach", "rid": 1.5}', "'rid' must be an integer"),
        ('{"t": 1.0, "event": "melt", "rid": 0}', "fault event must be one of"),
        ('{"t": 1.0, "event": "detach", "rid": 0, "mode": "panic"}',
         "fault mode must be one of"),
        ('{"t": -0.5, "event": "detach", "rid": 0}', "fault time must be >= 0"),
        ('{"t": 1.0, "event": "detach", "rid": -1}', "fault rid must be >= 0"),
    ],
)
def test_malformed_line_names_file_and_lineno(bad, needle):
    # the bad line sits at line 3, after two valid lines and a comment —
    # the error must carry *that* line number, not 1 or the total
    with pytest.raises(ValueError) as exc:
        _load_lines(GOOD, "# comment", bad, GOOD)
    msg = str(exc.value)
    assert "trace.jsonl:3" in msg, msg
    assert needle in msg, msg


def test_nan_time_rejected():
    with pytest.raises(ValueError, match="trace.jsonl:1.*fault time"):
        _load_lines('{"t": NaN, "event": "detach", "rid": 0}')


def test_error_is_first_bad_line_only():
    # fail-at-the-edge: parsing stops at line 2 even though line 3 is
    # also malformed (no aggregation, no partial replay)
    with pytest.raises(ValueError, match="trace.jsonl:2"):
        _load_lines(GOOD, "junk", "more junk")


# ---------------------------------------------------------------------------
# kill-mode fault-history round-trip


def _churned_sim(mode):
    sim = Simulator(
        cholesky_graph(6, 256, with_fns=False), paper_machine(4),
        resolve("heft"), seed=7, noise=0.0, churn=200.0, fault_mode=mode,
    )
    res = sim.run()
    assert sim.faults.history, "churn produced no events; raise the rate"
    return sim, res


@pytest.mark.parametrize("mode", ["drain", "kill"])
def test_fault_history_roundtrips_field_for_field(mode):
    sim, _res = _churned_sim(mode)
    hist = sim.faults.history
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "hist.jsonl")
        save_trace(hist, path)
        back = load_trace(path)
    assert len(back) == len(hist)
    assert sorted(back, key=lambda e: (e.t, e.rid)) == sorted(
        [FaultEvent(e.t, e.event, e.rid, e.mode) for e in hist],
        key=lambda e: (e.t, e.rid),
    )
    if mode == "kill":
        # the sampler tags detaches with the engine's kill mode; the
        # round-trip must not drop or default the mode field
        detaches = [e for e in back if e.event == "detach"]
        assert detaches and all(e.mode == "kill" for e in detaches)


def test_kill_history_replay_matches_programmatic_injection():
    sim, _res = _churned_sim("kill")
    hist = sim.faults.history

    def _fp(res):
        return (
            res.makespan, res.total_bytes,
            tuple((iv.tid, iv.rid, iv.start, iv.end) for iv in res.intervals),
        )

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "hist.jsonl")
        save_trace(hist, path)
        replayed = Simulator(
            cholesky_graph(6, 256, with_fns=False), paper_machine(4),
            resolve("heft"), seed=7, noise=0.0, fault_trace=path,
        ).run()
    prog = Simulator(
        cholesky_graph(6, 256, with_fns=False), paper_machine(4),
        resolve("heft"), seed=7, noise=0.0,
    )
    for e in hist:
        prog.inject(e.event, e.rid, at=e.t, mode=e.mode)
    assert _fp(replayed) == _fp(prog.run())


def test_save_trace_accepts_tuples():
    evs = [(0.2, "detach", 1, "kill"), (0.5, "attach", 1)]
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.jsonl")
        save_trace(evs, path)
        back = load_trace(path)
    assert back == [
        FaultEvent(0.2, "detach", 1, "kill"), FaultEvent(0.5, "attach", 1)
    ]
