"""Edge cases of the JSONL preemption-trace layer (repro.runtime.traces).

Complements tests/test_faults.py: exhaustive malformed-line rejection
(every variant must name the file *and the exact line*), and the
kill-mode fault-history round-trip — a churned run's recorded history
must survive save_trace/load_trace field-for-field and replay to the
same schedule.
"""
import os
import tempfile

import pytest

from repro.configs.paper_machine import paper_machine
from repro.core.simulator import Simulator
from repro.linalg.cholesky import cholesky_graph
from repro.runtime import FaultEvent, load_trace, save_trace
from repro.sched import resolve

GOOD = '{"t": 0.1, "event": "detach", "rid": 0, "mode": "drain"}'


def _load_lines(*lines):
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "trace.jsonl")
        with open(path, "w") as f:
            f.write("\n".join(lines) + "\n")
        try:
            return load_trace(path), path
        except ValueError as e:
            # surface the tempdir-relative location for assertions
            raise ValueError(str(e).replace(d + os.sep, "")) from None


# ---------------------------------------------------------------------------
# malformed lines: every variant names trace.jsonl:<lineno>


@pytest.mark.parametrize(
    "bad,needle",
    [
        ("{not json", "invalid JSON"),
        ("[1, 2, 3]", "expected a JSON object, got list"),
        ('"detach"', "expected a JSON object, got str"),
        ('{"t": 1.0, "event": "detach", "rid": 0, "sev": 9}', "unknown trace field"),
        ('{"event": "detach", "rid": 0}', "missing required field 't'"),
        ('{"t": 1.0, "rid": 0}', "missing required field 'event'"),
        ('{"t": 1.0, "event": "detach"}', "missing required field 'rid'"),
        ('{"t": true, "event": "detach", "rid": 0}', "'t' must be a number"),
        ('{"t": "1.0", "event": "detach", "rid": 0}', "'t' must be a number"),
        ('{"t": 1.0, "event": "detach", "rid": true}', "'rid' must be an integer"),
        ('{"t": 1.0, "event": "detach", "rid": 1.5}', "'rid' must be an integer"),
        ('{"t": 1.0, "event": "melt", "rid": 0}', "fault event must be one of"),
        ('{"t": 1.0, "event": "detach", "rid": 0, "mode": "panic"}',
         "fault mode must be one of"),
        ('{"t": -0.5, "event": "detach", "rid": 0}', "fault time must be >= 0"),
        ('{"t": 1.0, "event": "detach", "rid": -1}', "fault rid must be >= 0"),
        ('{"t": 1.0, "event": "detach", "rid": 0, "notice_s": true}',
         "'notice_s' must be a number"),
        ('{"t": 1.0, "event": "detach", "rid": 0, "notice_s": "0.1"}',
         "'notice_s' must be a number"),
        ('{"t": 1.0, "event": "detach", "rid": 0, "notice_s": -0.5}',
         "notice_s must be >= 0"),
        ('{"t": 1.0, "event": "detach", "rid": 0, "notice_s": NaN}',
         "notice_s must be >= 0"),
        ('{"t": 1.0, "event": "attach", "rid": 0, "notice_s": 0.1}',
         "notice_s only applies to detach events"),
    ],
)
def test_malformed_line_names_file_and_lineno(bad, needle):
    # the bad line sits at line 3, after two valid lines and a comment —
    # the error must carry *that* line number, not 1 or the total
    with pytest.raises(ValueError) as exc:
        _load_lines(GOOD, "# comment", bad, GOOD)
    msg = str(exc.value)
    assert "trace.jsonl:3" in msg, msg
    assert needle in msg, msg


def test_nan_time_rejected():
    with pytest.raises(ValueError, match="trace.jsonl:1.*fault time"):
        _load_lines('{"t": NaN, "event": "detach", "rid": 0}')


def test_error_is_first_bad_line_only():
    # fail-at-the-edge: parsing stops at line 2 even though line 3 is
    # also malformed (no aggregation, no partial replay)
    with pytest.raises(ValueError, match="trace.jsonl:2"):
        _load_lines(GOOD, "junk", "more junk")


# ---------------------------------------------------------------------------
# kill-mode fault-history round-trip


def _churned_sim(mode):
    sim = Simulator(
        cholesky_graph(6, 256, with_fns=False), paper_machine(4),
        resolve("heft"), seed=7, noise=0.0, churn=200.0, fault_mode=mode,
    )
    res = sim.run()
    assert sim.faults.history, "churn produced no events; raise the rate"
    return sim, res


@pytest.mark.parametrize("mode", ["drain", "kill"])
def test_fault_history_roundtrips_field_for_field(mode):
    sim, _res = _churned_sim(mode)
    hist = sim.faults.history
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "hist.jsonl")
        save_trace(hist, path)
        back = load_trace(path)
    assert len(back) == len(hist)
    assert sorted(back, key=lambda e: (e.t, e.rid)) == sorted(
        [FaultEvent(e.t, e.event, e.rid, e.mode) for e in hist],
        key=lambda e: (e.t, e.rid),
    )
    if mode == "kill":
        # the sampler tags detaches with the engine's kill mode; the
        # round-trip must not drop or default the mode field
        detaches = [e for e in back if e.event == "detach"]
        assert detaches and all(e.mode == "kill" for e in detaches)


def test_kill_history_replay_matches_programmatic_injection():
    sim, _res = _churned_sim("kill")
    hist = sim.faults.history

    def _fp(res):
        return (
            res.makespan, res.total_bytes,
            tuple((iv.tid, iv.rid, iv.start, iv.end) for iv in res.intervals),
        )

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "hist.jsonl")
        save_trace(hist, path)
        replayed = Simulator(
            cholesky_graph(6, 256, with_fns=False), paper_machine(4),
            resolve("heft"), seed=7, noise=0.0, fault_trace=path,
        ).run()
    prog = Simulator(
        cholesky_graph(6, 256, with_fns=False), paper_machine(4),
        resolve("heft"), seed=7, noise=0.0,
    )
    for e in hist:
        prog.inject(e.event, e.rid, at=e.t, mode=e.mode)
    assert _fp(replayed) == _fp(prog.run())


def test_save_trace_accepts_tuples():
    evs = [(0.2, "detach", 1, "kill"), (0.5, "attach", 1)]
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.jsonl")
        save_trace(evs, path)
        back = load_trace(path)
    assert back == [
        FaultEvent(0.2, "detach", 1, "kill"), FaultEvent(0.5, "attach", 1)
    ]


# ---------------------------------------------------------------------------
# schema v2: preemption notices in traces


def test_noticed_history_roundtrips_with_notice_s():
    # noticed churn records the realized warning on each detach; the v2
    # field must survive save/load field-for-field, notice_s included
    sim = Simulator(
        cholesky_graph(6, 256, with_fns=False), paper_machine(4),
        resolve("heft"), seed=7, noise=0.0, churn=200.0, fault_mode="drain",
        notice_s=0.003,
    )
    sim.run()
    hist = sim.faults.history
    detaches = [e for e in hist if e.event == "detach"]
    assert detaches, "churn produced no detaches; raise the rate"
    assert all(e.notice_s is not None and e.notice_s > 0 for e in detaches)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "hist.jsonl")
        save_trace(hist, path)
        back = load_trace(path)
    assert sorted(back, key=lambda e: (e.t, e.rid)) == sorted(
        hist, key=lambda e: (e.t, e.rid)
    )


def test_noticed_trace_replay_matches_programmatic_injection():
    trace = [
        FaultEvent(0.004, "detach", 4, "drain", notice_s=0.002),
        FaultEvent(0.009, "attach", 4),
    ]
    def _run(**kw):
        sim = Simulator(
            cholesky_graph(6, 256, with_fns=False), paper_machine(4),
            resolve("heft"), seed=7, noise=0.0, **kw,
        )
        return sim, sim.run()

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.jsonl")
        save_trace(trace, path)
        rsim, replayed = _run(fault_trace=path)
    psim = Simulator(
        cholesky_graph(6, 256, with_fns=False), paper_machine(4),
        resolve("heft"), seed=7, noise=0.0,
    )
    for e in trace:
        psim.inject(e.event, e.rid, at=e.t, mode=e.mode, notice_s=e.notice_s)
    prog = psim.run()
    assert (replayed.makespan, replayed.total_bytes) == (
        prog.makespan, prog.total_bytes
    )
    assert [
        (iv.tid, iv.rid, iv.start, iv.end) for iv in replayed.intervals
    ] == [(iv.tid, iv.rid, iv.start, iv.end) for iv in prog.intervals]
    # both saw the notice: the grace window and proactive path engaged
    assert rsim.metrics.n_notices == psim.metrics.n_notices == 1


def test_v1_trace_without_notice_saves_byte_compatibly():
    evs = [FaultEvent(0.2, "detach", 1, "kill"), FaultEvent(0.5, "attach", 1)]
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.jsonl")
        save_trace(evs, path)
        text = open(path).read()
    assert "notice_s" not in text
