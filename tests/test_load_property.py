"""Property tests for the serving load layer (repro.runtime.load):
seeded arrival streams, admission control, permutation stability, the
bit-for-bit gating of serving mode, and arrival-trace edge cases."""
import json

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.paper_machine import paper_machine
from repro.runtime.engine import Engine
from repro.runtime.load import (
    Arrival,
    bursty_arrival_times,
    default_catalog,
    diurnal_arrival_times,
    load_trace,
    make_arrivals,
    poisson_arrival_times,
    run_serving,
    save_trace,
)
from repro.sched import resolve


def _fingerprint(engine: Engine):
    return [
        (ctx.gid, iv.tid, iv.rid, iv.start, iv.end)
        for ctx in engine._ctxs
        for iv in ctx.intervals
    ]


# ---------------------------------------------------------------------------
# seeded generators


@settings(max_examples=20, deadline=None)
@given(
    st.integers(0, 10**6),
    st.sampled_from(["poisson", "bursty", "diurnal"]),
)
def test_arrival_streams_deterministic(seed, process):
    a = make_arrivals(process, 40, rate=100.0, seed=seed)
    b = make_arrivals(process, 40, rate=100.0, seed=seed)
    assert a == b
    times = [x.t for x in a]
    assert times == sorted(times)
    assert all(t >= 0.0 for t in times)
    # a different seed draws a different stream
    c = make_arrivals(process, 40, rate=100.0, seed=seed + 1)
    assert [x.t for x in c] != times


def test_generators_distinct_and_seed_streamed():
    # the three processes draw from disjoint sub-streams: same seed, same
    # n, same rate, three different point processes
    p = poisson_arrival_times(50, 100.0, seed=7).tolist()
    b = bursty_arrival_times(50, 100.0, seed=7).tolist()
    d = diurnal_arrival_times(50, 100.0, seed=7).tolist()
    assert p != b and p != d and b != d


def test_tenant_mix_identical_across_processes():
    # kinds/priorities come from their own stream, so swapping the
    # arrival process changes *when*, never *who*
    pois = make_arrivals("poisson", 30, seed=3, priorities=(1.0, 2.0))
    burs = make_arrivals("bursty", 30, seed=3, priorities=(1.0, 2.0))
    assert [a.kind for a in pois] == [a.kind for a in burs]
    assert [a.priority for a in pois] == [a.priority for a in burs]


def test_generator_validation():
    with pytest.raises(ValueError):
        poisson_arrival_times(10, 0.0)
    with pytest.raises(ValueError):
        bursty_arrival_times(10, 100.0, duty=0.0)
    with pytest.raises(ValueError):
        diurnal_arrival_times(10, 100.0, depth=1.0)
    with pytest.raises(ValueError):
        make_arrivals("weekly", 10)


# ---------------------------------------------------------------------------
# serving determinism + permutation stability


def test_serving_run_deterministic_and_permutation_stable():
    arr = make_arrivals("bursty", 24, rate=500.0, seed=5)
    out1 = run_serving(arr, paper_machine(4), "heft", seed=0)
    out2 = run_serving(arr, paper_machine(4), "heft", seed=0)
    assert _fingerprint(out1["engine"]) == _fingerprint(out2["engine"])
    # a permuted arrival list replays identically (canonical submit order)
    rng = np.random.default_rng(0)
    shuffled = [arr[i] for i in rng.permutation(len(arr))]
    out3 = run_serving(shuffled, paper_machine(4), "heft", seed=0)
    assert _fingerprint(out1["engine"]) == _fingerprint(out3["engine"])
    assert out1["report"] == out3["report"]


def test_full_and_incremental_rescoring_place_identically():
    # the dirty-row cache is an optimization, not a policy change: both
    # modes must produce bit-identical schedules
    arr = make_arrivals("poisson", 32, rate=1000.0, seed=2)
    full = run_serving(arr, paper_machine(4), "heft", seed=0, rescore="full")
    inc = run_serving(
        arr, paper_machine(4), "heft", seed=0, rescore="incremental"
    )
    assert _fingerprint(full["engine"]) == _fingerprint(inc["engine"])
    # and the cache must actually be doing less work
    assert inc["rows_built"] < full["rows_built"]


def test_zero_load_single_graph_bit_identical():
    # serving machinery off (the default): a single-graph run through an
    # engine constructed with every new knob at its default equals a run
    # through an engine with the knobs spelled out — the gating contract
    from repro.linalg.cholesky import cholesky_graph

    e1 = Engine(paper_machine(4), resolve("heft"), seed=0, noise=0.05)
    e1.submit(cholesky_graph(6, 256, with_fns=False))
    r1 = e1.run()
    e2 = Engine(
        paper_machine(4), resolve("heft"), seed=0, noise=0.05,
        rescore="off", admission="none", admit_defer_s=0.005,
    )
    e2.submit(cholesky_graph(6, 256, with_fns=False))
    r2 = e2.run()
    assert _fingerprint(e1) == _fingerprint(e2)
    assert r1[0].makespan == r2[0].makespan
    assert r1[0].total_bytes == r2[0].total_bytes


def test_zero_tenant_serving_run():
    eng = Engine(
        paper_machine(2), resolve("heft"), seed=0, rescore="incremental"
    )
    assert eng.run() == []
    out = run_serving([], paper_machine(2), "heft", seed=0)
    assert out["n_arrivals"] == 0
    assert out["report"]["n_tenants"] == 0
    assert out["report"]["jain_fairness"] == 1.0


# ---------------------------------------------------------------------------
# admission control


def _max_ws(catalog) -> int:
    # largest per-tenant working set in the catalog, read off a probe
    # engine's GraphContext accounting
    probe = Engine(paper_machine(2), resolve("heft"), seed=0)
    return max(probe.submit(b()).ws_bytes for b in catalog.values())


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10**6), st.sampled_from(["reject", "defer"]))
def test_admission_never_exceeds_capacity(seed, mode):
    # track the reservation ledger after every arrival: the sum of
    # admitted-but-unfinished working sets never exceeds the aggregate
    # device capacity
    catalog = default_catalog()
    ws = _max_ws(catalog)
    capacity_per_mem = ws  # deliberately tight: forces rejections/deferrals
    arr = make_arrivals("poisson", 16, rate=5000.0, seed=seed)
    machine = paper_machine(4)
    eng = Engine(
        machine, resolve("heft"), seed=0,
        rescore="incremental", admission=mode,
        mem_capacity=capacity_per_mem,
    )
    peaks = []
    orig = eng._arrive

    def watched(ctx):
        orig(ctx)
        peaks.append(eng._active_ws)

    eng._arrive = watched
    for a in arr:
        eng.submit(catalog[a.kind](), at=a.t, priority=a.priority)
    eng.run()
    assert peaks, "no arrivals reached admission"
    assert max(peaks) <= eng._mem_total
    m = eng.metrics
    assert m.n_arrivals == 16
    assert m.n_admitted + m.n_rejected == 16 if mode == "reject" else True
    if mode == "defer":
        # deferred tenants eventually admit (finished graphs release
        # their reservations) and every admitted graph completes
        assert m.n_admitted == 16 - m.n_rejected
    # reservations are all released at the end
    assert eng._active_ws == 0


def test_oversized_tenant_rejected_outright():
    # capacity sized so every single task fits device memory (the memory
    # layer's own at-submit check passes) but the graph's aggregate
    # working set can never be admitted — defer would spin forever, so
    # the controller must reject outright without a single deferral
    catalog = default_catalog()
    big = catalog["chol4"]()
    probe = Engine(paper_machine(1), resolve("heft"), seed=0)
    ws = probe.submit(catalog["chol4"]()).ws_bytes
    eng = Engine(
        paper_machine(1), resolve("heft"), seed=0,
        rescore="incremental", admission="defer",
        mem_capacity=ws // 2,
    )
    assert eng._mem_total < ws
    ctx = eng.submit(big, at=0.0)
    eng.run()
    assert ctx.rejected
    assert eng.metrics.n_rejected == 1
    assert eng.metrics.n_deferred == 0  # too-large never spins on defer


def test_admission_requires_serving_mode():
    with pytest.raises(ValueError, match="admission"):
        Engine(
            paper_machine(2), resolve("heft"), seed=0, admission="reject"
        )


def test_serving_rejects_stealing_strategies():
    with pytest.raises(ValueError, match="work-stealing"):
        Engine(
            paper_machine(2), resolve("ws"), seed=0, rescore="incremental"
        )


def test_max_events_requires_serving_mode():
    eng = Engine(paper_machine(2), resolve("heft"), seed=0)
    with pytest.raises(ValueError, match="max_events"):
        eng.run(max_events=10)


# ---------------------------------------------------------------------------
# arrival-trace JSONL edge cases


def _write(tmp_path, text):
    p = tmp_path / "trace.jsonl"
    p.write_text(text, encoding="utf-8")
    return str(p)


def test_trace_round_trip(tmp_path):
    arr = make_arrivals("diurnal", 12, seed=9, priorities=(1.0, 4.0))
    p = str(tmp_path / "arr.jsonl")
    save_trace(arr, p)
    back = load_trace(p)
    assert back == sorted(arr, key=lambda a: (a.t, a.tenant))
    # default-priority entries omit the field on disk
    lines = [json.loads(line) for line in open(p, encoding="utf-8")]
    assert all(("priority" in o) == (o.get("priority", 1.0) != 1.0) for o in lines)


def test_trace_skips_blank_and_comment_lines(tmp_path):
    p = _write(
        tmp_path,
        '# a comment\n\n{"t": 0.5, "kind": "chol2", "tenant": 1}\n',
    )
    arr = load_trace(p)
    assert arr == [Arrival(0.5, "chol2", 1)]


def test_trace_sorted_by_time_then_tenant(tmp_path):
    p = _write(
        tmp_path,
        '{"t": 1.0, "kind": "a", "tenant": 2}\n'
        '{"t": 0.5, "kind": "b", "tenant": 9}\n'
        '{"t": 1.0, "kind": "c", "tenant": 1}\n',
    )
    arr = load_trace(p)
    assert [(a.t, a.tenant) for a in arr] == [(0.5, 9), (1.0, 1), (1.0, 2)]


@pytest.mark.parametrize(
    "line,frag",
    [
        ("not json", "invalid JSON"),
        ('[1, 2]', "expected a JSON object"),
        ('{"kind": "x", "tenant": 0}', "missing required field 't'"),
        ('{"t": 1.0, "tenant": 0}', "missing required field 'kind'"),
        ('{"t": 1.0, "kind": "x"}', "missing required field 'tenant'"),
        ('{"t": true, "kind": "x", "tenant": 0}', "'t' must be a number"),
        ('{"t": -1, "kind": "x", "tenant": 0}', "must be >= 0"),
        ('{"t": 1, "kind": 3, "tenant": 0}', "'kind' must be a string"),
        ('{"t": 1, "kind": "", "tenant": 0}', "non-empty"),
        ('{"t": 1, "kind": "x", "tenant": 1.5}', "'tenant' must be an integer"),
        ('{"t": 1, "kind": "x", "tenant": -2}', "must be >= 0"),
        ('{"t": 1, "kind": "x", "tenant": 0, "priority": 0}', "must be > 0"),
        ('{"t": 1, "kind": "x", "tenant": 0, "priority": "hi"}', "'priority' must be a number"),
        ('{"t": 1, "kind": "x", "tenant": 0, "extra": 1}', "unknown trace field"),
    ],
)
def test_trace_malformed_lines_rejected_with_lineno(tmp_path, line, frag):
    p = _write(
        tmp_path, '{"t": 0.1, "kind": "ok", "tenant": 0}\n' + line + "\n"
    )
    with pytest.raises(ValueError) as exc:
        load_trace(p)
    msg = str(exc.value)
    assert f"{p}:2" in msg, msg
    assert frag in msg, msg


def test_unknown_kind_rejected_at_submit():
    with pytest.raises(ValueError, match="not in catalog"):
        run_serving([Arrival(0.0, "nope", 0)], paper_machine(2), "heft")
