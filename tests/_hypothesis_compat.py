"""Import shim: run hypothesis-based tests when hypothesis is installed,
skip (only) them when it is not, without losing the rest of the module.

Usage in test files::

    from _hypothesis_compat import given, settings, st

When hypothesis is available these are the real objects. When it is
missing, ``@given(...)`` turns the test into a skip and ``st.*`` produces
inert placeholders so module-level strategy expressions still evaluate.
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            def skipped():
                pytest.skip("hypothesis not installed")

            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped

        return deco

    def settings(*_a, **_k):
        return lambda fn: fn

    class _AnyStrategy:
        """Absorbs any strategy-building expression (st.lists(...), etc.)."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()
