"""The ``repro.sched`` policy API: registry resolution, typed SchedConfig,
back-compat shims, and the two new score-matrix policies.

The back-compat contract is the load-bearing part: ``make_strategy`` /
string specs in ``run_simulation`` must warn *and* produce placements
bit-identical to ``repro.sched.resolve`` — the redesign moves construction,
never decisions.
"""
import numpy as np
import pytest

import repro.sched as sched
from repro.configs.paper_machine import paper_machine
from repro.core import Simulator, make_strategy, run_simulation
from repro.linalg.cholesky import cholesky_graph
from repro.sched import (
    LocalityPolicy,
    Policy,
    RandomPolicy,
    SchedConfig,
    assign_from_scores,
    register,
    registered,
    resolve,
    unregister,
)
from repro.sched.config import _reset_config_cache


def _fingerprint(res):
    return (
        res.makespan,
        res.total_bytes,
        res.n_transfers,
        res.n_steals,
        tuple(sorted(res.busy.items())),
        tuple((iv.tid, iv.rid, iv.start, iv.end) for iv in res.intervals),
    )


# ---------------------------------------------------------------------------
# back-compat shims


LEGACY_NAMES = ["heft", "ws", "dual", "dada"]


@pytest.mark.parametrize("name", LEGACY_NAMES)
def test_make_strategy_shim_bit_identical(name):
    """Cholesky NT=16 trace: the deprecated shim and the registry build
    strategies whose full placement trace is bit-identical."""
    machine = paper_machine(4)
    with pytest.warns(DeprecationWarning, match="make_strategy"):
        legacy = make_strategy(name)
    a = run_simulation(
        cholesky_graph(16, 256, with_fns=False), machine, legacy, seed=0
    )
    b = run_simulation(
        cholesky_graph(16, 256, with_fns=False), machine, resolve(name), seed=0
    )
    assert _fingerprint(a) == _fingerprint(b)


@pytest.mark.parametrize("name", LEGACY_NAMES)
def test_run_simulation_string_shim(name):
    machine = paper_machine(2)
    with pytest.warns(DeprecationWarning, match="deprecated"):
        a = run_simulation(
            cholesky_graph(6, 256, with_fns=False), machine, name, seed=1
        )
    b = run_simulation(
        cholesky_graph(6, 256, with_fns=False), machine, resolve(name), seed=1
    )
    assert _fingerprint(a) == _fingerprint(b)


def test_make_strategy_kwargs_match_query_spec():
    machine = paper_machine(3)
    with pytest.warns(DeprecationWarning):
        legacy = make_strategy("dada", alpha=0.25, use_cp=True)
    spec = resolve("dada?alpha=0.25&use_cp=1")
    assert (legacy.alpha, legacy.use_cp) == (spec.alpha, spec.use_cp)
    a = run_simulation(cholesky_graph(6, 256, with_fns=False), machine, legacy, seed=2)
    b = run_simulation(cholesky_graph(6, 256, with_fns=False), machine, spec, seed=2)
    assert _fingerprint(a) == _fingerprint(b)


def test_make_strategy_unknown_name_keeps_error_shape():
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="unknown strategy 'nope'"):
            make_strategy("nope")


# ---------------------------------------------------------------------------
# registry


def test_registered_names_include_builtins():
    names = registered()
    for expected in ("heft", "dada", "dual", "ws", "random", "locality"):
        assert expected in names


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        register("heft", lambda: None)
    # explicit overwrite is allowed, and undone cleanly
    class Fake:
        name = "fake-heft"

    original = sched.get_factory("heft")
    try:
        register("heft", Fake, overwrite=True)
        assert sched.get_factory("heft") is Fake
    finally:
        register("heft", original, overwrite=True)


def test_register_decorator_and_unregister():
    @register("test-custom-policy")
    class Custom:
        name = "custom"

    try:
        assert "test-custom-policy" in registered()
        assert isinstance(resolve("test-custom-policy"), Custom)
    finally:
        unregister("test-custom-policy")
    assert "test-custom-policy" not in registered()
    with pytest.raises(ValueError, match="unknown policy"):
        resolve("test-custom-policy")


def test_query_string_kwargs_parsed_and_typed():
    s = resolve("dada?alpha=0.25&use_cp=1&max_iters=12&affinity=all_resident")
    assert s.alpha == 0.25 and isinstance(s.alpha, float)
    assert s.use_cp is True
    assert s.max_iters == 12 and isinstance(s.max_iters, int)
    assert s.affinity_name == "all_resident"
    s2 = resolve("dada?use_cp=false")
    assert s2.use_cp is False
    s3 = resolve("random?seed=9")
    assert s3.seed == 9 and isinstance(s3.seed, int)


def test_query_string_errors_are_loud():
    with pytest.raises(ValueError, match="not a number"):
        resolve("dada?alpha=banana")
    with pytest.raises(ValueError, match="not a boolean"):
        resolve("dada?use_cp=maybe")
    with pytest.raises(ValueError, match="unknown parameter"):
        resolve("dada?frobnicate=1")
    with pytest.raises(ValueError, match="unknown policy"):
        resolve("does-not-exist")


def test_resolve_passes_policies_through():
    s = resolve("heft")
    assert resolve(s) is s


def test_resolve_forwards_backend_only_where_accepted():
    s = resolve("heft", backend="numpy")
    assert s.backend_name == "numpy"
    # ws takes no backend parameter: the kwarg must not explode
    resolve("ws", backend="numpy")


# ---------------------------------------------------------------------------
# SchedConfig


def test_sched_config_from_env_defaults():
    cfg = SchedConfig.from_env(env={})
    assert cfg.backend == "numpy"
    assert cfg.jax_min == 32
    assert cfg.lambda_depth is None


def test_sched_config_parses_and_types(monkeypatch):
    cfg = SchedConfig.from_env(
        env={
            "REPRO_SCHED_BACKEND": "jax",
            "REPRO_SCHED_JAX_MIN": "4",
            "REPRO_SCHED_LAMBDA_DEPTH": "3",
            "REPRO_BENCH_NT": "16,32",
            "REPRO_BENCH_FAST": "1",
            "UNRELATED": "ignored",
        }
    )
    assert cfg.backend == "jax"
    assert cfg.jax_min == 4
    assert cfg.lambda_depth == 3
    assert cfg.bench_nt == (16, 32)
    assert cfg.bench_fast is True


def test_sched_config_rejects_malformed_values():
    with pytest.raises(ValueError, match="REPRO_SCHED_LAMBDA_DEPTH"):
        SchedConfig.from_env(env={"REPRO_SCHED_LAMBDA_DEPTH": "banana"})
    with pytest.raises(ValueError, match="REPRO_SCHED_JAX_MIN"):
        SchedConfig.from_env(env={"REPRO_SCHED_JAX_MIN": "junk"})
    with pytest.raises(ValueError, match="REPRO_SCHED_BACKEND"):
        SchedConfig.from_env(env={"REPRO_SCHED_BACKEND": "cuda"})
    with pytest.raises(ValueError, match="REPRO_BENCH_RUNS"):
        SchedConfig.from_env(env={"REPRO_BENCH_RUNS": "many"})


def test_sched_config_env_items_round_trip():
    cfg = SchedConfig(backend="jax", jax_min=4, bench_nt=(16, 32), bench_fast=True)
    env = dict(cfg.env_items())
    assert env == {
        "REPRO_SCHED_BACKEND": "jax",
        "REPRO_SCHED_JAX_MIN": "4",
        "REPRO_BENCH_NT": "16,32",
        "REPRO_BENCH_FAST": "1",
    }
    assert SchedConfig.from_env(env=env) == cfg


def test_sched_config_rejects_unknown_vars():
    with pytest.raises(ValueError, match="REPRO_SCHED_LAMBDA_DEPTX"):
        SchedConfig.from_env(env={"REPRO_SCHED_LAMBDA_DEPTX": "3"})


def test_env_changes_reach_hot_paths(monkeypatch):
    """backend.py reads the memoized config, and monkeypatched env vars
    must be visible immediately (the memo keys on the env snapshot)."""
    from repro.core.backend import backend_name, jax_min_wide

    monkeypatch.delenv("REPRO_SCHED_BACKEND", raising=False)
    monkeypatch.delenv("REPRO_SCHED_JAX_MIN", raising=False)
    _reset_config_cache()
    assert backend_name() == "numpy"
    assert jax_min_wide() == 32
    monkeypatch.setenv("REPRO_SCHED_BACKEND", "jax")
    monkeypatch.setenv("REPRO_SCHED_JAX_MIN", "7")
    assert backend_name() == "jax"
    assert jax_min_wide() == 7
    monkeypatch.setenv("REPRO_SCHED_JAX_MIN", "junk")
    with pytest.raises(ValueError, match="REPRO_SCHED_JAX_MIN"):
        jax_min_wide()


def test_explicit_config_object_threads_through():
    cfg = SchedConfig(backend="jax", jax_min=5)
    from repro.core.backend import backend_name, jax_min_wide

    assert backend_name(config=cfg) == "jax"
    assert jax_min_wide(config=cfg) == 5
    sim = Simulator(
        cholesky_graph(3, 256, with_fns=False),
        paper_machine(1),
        resolve("ws"),
        config=cfg,
    )
    assert sim.config is cfg


# ---------------------------------------------------------------------------
# the generic score-matrix driver and the new policies


def test_assign_from_scores_basic_and_capacity():
    scores = np.array([[0.0, 1.0], [0.0, 1.0], [0.0, 1.0], [0.0, 1.0]])
    # unconstrained: everything goes to column 0
    assert assign_from_scores(scores).tolist() == [0, 0, 0, 0]
    # capacity 2 per column forces a split
    choice = assign_from_scores(scores, capacity=[2, 2])
    assert sorted(choice.tolist()) == [0, 0, 1, 1]
    with pytest.raises(ValueError, match="no eligible column"):
        assign_from_scores(scores, capacity=[1, 1])


def test_assign_from_scores_load_aware():
    scores = np.zeros((4, 2))
    costs = np.full((4, 2), 3.0)
    choice, loads = assign_from_scores(
        scores, loads=[0.0, 1.0], costs=costs, return_loads=True
    )
    # equal scores: items alternate by accumulated load, col 0 first
    assert choice.tolist() == [0, 1, 0, 1]
    assert loads.tolist() == [6.0, 7.0]


@pytest.mark.parametrize("spec", ["random", "random?seed=11", "locality"])
def test_new_policies_deterministic_under_seed(spec):
    machine = paper_machine(4)
    runs = [
        run_simulation(
            cholesky_graph(6, 256, with_fns=False), machine, resolve(spec), seed=3
        )
        for _ in range(2)
    ]
    assert _fingerprint(runs[0]) == _fingerprint(runs[1])
    assert runs[0].makespan > 0


def test_random_policies_differ_across_policy_seeds():
    machine = paper_machine(4)
    a = run_simulation(
        cholesky_graph(6, 256, with_fns=False), machine,
        resolve("random?seed=1"), seed=0,
    )
    b = run_simulation(
        cholesky_graph(6, 256, with_fns=False), machine,
        resolve("random?seed=2"), seed=0,
    )
    assert _fingerprint(a) != _fingerprint(b)


def test_policies_satisfy_protocol():
    for spec in ("heft", "dada", "dual", "ws", "random", "locality"):
        assert isinstance(resolve(spec), Policy), spec


def test_score_matrix_shapes_and_semantics():
    machine = paper_machine(3)
    graph = cholesky_graph(5, 256, with_fns=False)
    n_res = len(machine.resources)
    for spec in ("heft", "dada?use_cp=1", "locality", "random"):
        strat = resolve(spec)
        sim = Simulator(graph, machine, strat, seed=0)
        strat.init(sim)
        ready = graph.roots()
        S = strat.score_matrix(sim, ready)
        assert S is not None and S.shape == (len(ready), n_res), spec
        assert np.isfinite(S).all(), spec
    ws = resolve("ws")
    sim = Simulator(graph, machine, ws, seed=0)
    assert ws.score_matrix(sim, graph.roots()) is None


def test_locality_prefers_resident_data():
    """A task whose inputs sit on one GPU memory must score that GPU
    strictly cheaper than the other accelerators."""
    machine = paper_machine(4)
    graph = cholesky_graph(5, 256, with_fns=False)
    strat = LocalityPolicy()
    sim = Simulator(graph, machine, strat, seed=0)
    gpu = machine.gpus[0]
    root = graph.roots()[0]
    for _, name, _size in sim.arrays.task_reads[root.tid]:
        sim.residency.write(name, gpu.mem)
    S = strat.score_matrix(sim, [root])
    j_gpu = [i for i, r in enumerate(machine.resources) if r.rid == gpu.rid][0]
    other_gpus = [
        i for i, r in enumerate(machine.resources)
        if r.is_accelerator and r.rid != gpu.rid
    ]
    assert all(S[0, j_gpu] < S[0, j] for j in other_gpus)


def test_random_policy_uses_every_resource_eventually():
    machine = paper_machine(4)
    res = run_simulation(
        cholesky_graph(8, 256, with_fns=False), machine,
        RandomPolicy(seed=0), seed=0,
    )
    used = {iv.rid for iv in res.intervals}
    assert len(used) == len(machine.resources)
