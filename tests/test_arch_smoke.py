"""Per-architecture smoke tests: reduced config, one forward / train / decode
step on CPU; asserts output shapes and finiteness (no NaNs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config, smoke_config
from repro.configs.shapes import ShapeSpec
from repro.data.pipeline import SyntheticPipeline
from repro.models.transformer import cache_init, encode, forward, init_params
from repro.optim.adamw import adamw_init
from repro.serve.decode import make_serve_step
from repro.train.step import make_train_step

S, B = 64, 2
SHAPE = ShapeSpec("smoke", S, B, "train")


def _setup(arch):
    cfg = smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    pipe = SyntheticPipeline(cfg, SHAPE, seed=1)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    return cfg, params, batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch):
    cfg, params, batch = _setup(arch)
    enc_out = None
    extra = None
    if cfg.family == "audio":
        enc_out = encode(params, cfg, batch["frontend"])
        assert bool(jnp.isfinite(enc_out).all())
    elif cfg.family == "vlm":
        extra = batch["frontend"]
    logits, _, aux = forward(
        params, cfg, batch["tokens"], extra_embeds=extra, enc_out=enc_out
    )
    exp_s = batch["tokens"].shape[1] + (extra.shape[1] if extra is not None else 0)
    assert logits.shape == (B, exp_s, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN/inf in logits"
    assert bool(jnp.isfinite(aux).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg, params, batch = _setup(arch)
    step = jax.jit(make_train_step(cfg))
    opt = adamw_init(params)
    params2, opt2, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: non-finite loss"
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    delta = sum(
        float(jnp.abs(a - b).sum())
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert delta > 0.0
    # and loss decreases over a few steps on repeated batch (sanity)
    p, o = params2, opt2
    first = float(metrics["loss"])
    for _ in range(3):
        p, o, m = step(p, o, batch)
    assert float(m["loss"]) < first * 1.5  # no blow-up


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_smoke(arch):
    cfg, params, batch = _setup(arch)
    serve = make_serve_step(cfg)
    cache = cache_init(cfg, B, S)
    tok = jnp.zeros((B, 1), jnp.int32)
    enc_out = None
    if cfg.family == "audio":
        enc_out = encode(params, cfg, batch["frontend"])
    nxt, logits, new_cache = jax.jit(serve)(
        params, cache, tok, jnp.int32(S - 1), enc_out
    )
    assert nxt.shape == (B,)
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN in decode logits"
    # cache structure preserved
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_instantiates(arch):
    """Full configs are valid (abstract check only — no allocation)."""
    cfg = get_config(arch)
    assert cfg.n_layers % cfg.period == 0
    n = cfg.params_count()
    assert n > 1e8, f"{arch}: implausibly small param count {n}"
    a = cfg.active_params_count()
    assert a <= n


def test_param_counts_plausible():
    """Sanity: analytic param counts are in the ballpark of the model names."""
    expect = {
        "chatglm3-6b": (4e9, 9e9),
        "gemma-7b": (6e9, 10e9),
        "granite-8b": (6e9, 10e9),
        "minicpm3-4b": (2.5e9, 6e9),
        "jamba-v0.1-52b": (35e9, 65e9),
        "kimi-k2-1t-a32b": (0.7e12, 1.3e12),
        "grok-1-314b": (2.4e11, 3.9e11),
        "xlstm-1.3b": (0.8e9, 2.2e9),
        "internvl2-76b": (55e9, 90e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).params_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.1f}B outside [{lo/1e9},{hi/1e9}]B"
