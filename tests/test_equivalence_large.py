"""Large-graph equivalence: NT=32/64 tile grids on the 32-resource scaled
machine — the regime the jax scoring backend exists for. Asserts
numpy-vs-reference and jax-vs-numpy decision identity (satellite of the
backend tentpole; the paper-size equivalence suite lives in
test_equivalence.py / test_backend.py)."""
import pytest

from repro.configs.paper_machine import scaled_machine
from repro.core import DADA, HEFT, run_simulation
from repro.core._reference import ReferenceDADA, ReferenceHEFT
from repro.linalg.cholesky import cholesky_graph
from repro.linalg.lu import lu_graph
from repro.linalg.qr import qr_graph

KERNELS = {
    "cholesky": cholesky_graph,
    "lu": lu_graph,
    "qr": qr_graph,
}

MACHINE = scaled_machine(n_gpus=24, n_cpus=8)  # 32 resources
assert len(MACHINE.resources) == 32


def _fingerprint(res):
    return (
        res.makespan,
        res.total_bytes,
        res.n_transfers,
        tuple(sorted(res.busy.items())),
        tuple((iv.tid, iv.rid, iv.start, iv.end) for iv in res.intervals),
    )


# ---------------------------------------------------------------------------
# numpy vs frozen scalar reference at NT=32 (the reference is O(n·m·probes)
# scalar Python — NT=32 keeps it inside test-suite budgets)


@pytest.mark.parametrize("kernel", sorted(KERNELS))
def test_numpy_matches_reference_nt32_32res(kernel):
    graph = KERNELS[kernel](32, 512, with_fns=False)
    a = run_simulation(graph, MACHINE, DADA(alpha=0.5, use_cp=True), seed=1)
    b = run_simulation(
        graph, MACHINE, ReferenceDADA(alpha=0.5, use_cp=True), seed=1
    )
    assert _fingerprint(a) == _fingerprint(b)


def test_numpy_heft_matches_reference_nt32_32res():
    graph = cholesky_graph(32, 512, with_fns=False)
    a = run_simulation(graph, MACHINE, HEFT(), seed=1)
    b = run_simulation(graph, MACHINE, ReferenceHEFT(), seed=1)
    assert _fingerprint(a) == _fingerprint(b)


# ---------------------------------------------------------------------------
# jax vs numpy at NT=32 and NT=64 (jax engages on the wide ready waves;
# narrow activations exercise the numpy fast path inside the same run)


@pytest.mark.parametrize("kernel", sorted(KERNELS))
def test_jax_matches_numpy_nt32_32res(kernel, monkeypatch):
    jax = pytest.importorskip("jax")  # noqa: F841
    monkeypatch.setenv("REPRO_SCHED_JAX_MIN", "8")
    graph = KERNELS[kernel](32, 512, with_fns=False)
    a = run_simulation(
        graph, MACHINE, DADA(alpha=0.5, use_cp=True, backend="numpy"), seed=4
    )
    b = run_simulation(
        graph, MACHINE, DADA(alpha=0.5, use_cp=True, backend="jax"), seed=4
    )
    assert _fingerprint(a) == _fingerprint(b)


def test_jax_heft_matches_numpy_nt32_32res(monkeypatch):
    jax = pytest.importorskip("jax")  # noqa: F841
    monkeypatch.setenv("REPRO_SCHED_JAX_MIN", "8")
    graph = cholesky_graph(32, 512, with_fns=False)
    a = run_simulation(graph, MACHINE, HEFT(backend="numpy"), seed=4)
    b = run_simulation(graph, MACHINE, HEFT(backend="jax"), seed=4)
    assert _fingerprint(a) == _fingerprint(b)


def test_jax_matches_numpy_nt64_32res():
    """The acceptance-size configuration: NT=64 Cholesky (45760 tasks) on
    32 resources, wide λ-probe waves on the jax backend."""
    jax = pytest.importorskip("jax")  # noqa: F841
    graph = cholesky_graph(64, 512, with_fns=False)
    a = run_simulation(
        graph, MACHINE, DADA(alpha=0.5, use_cp=True, backend="numpy"), seed=2
    )
    b = run_simulation(
        graph, MACHINE, DADA(alpha=0.5, use_cp=True, backend="jax"), seed=2
    )
    assert _fingerprint(a) == _fingerprint(b)
