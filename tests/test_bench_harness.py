"""Benchmark-harness behavior: empty sweeps, parallel run_many equivalence,
and the scheduler-overhead reporting contract."""
from functools import partial

from repro.configs.paper_machine import paper_machine
from repro.core import DADA, run_many
from repro.linalg.cholesky import cholesky_graph


def test_sweep_empty_gpu_list_returns_no_rows(capsys):
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks.common import STRATEGIES, sweep

    rows = sweep("tmp_empty", "cholesky", STRATEGIES, 3, [])
    assert rows == []
    assert "empty sweep" in capsys.readouterr().out
    rows = sweep("tmp_empty", "cholesky", {}, 3, [2])
    assert rows == []


def test_run_many_parallel_matches_serial():
    machine = paper_machine(2)
    gfac = partial(cholesky_graph, 4, 256, with_fns=False)
    sfac = partial(DADA, alpha=0.5)
    serial = run_many(gfac, machine, sfac, n_runs=4, n_jobs=1)
    parallel = run_many(gfac, machine, sfac, n_runs=4, n_jobs=2)
    assert serial == parallel  # bit-identical summaries


def test_run_many_falls_back_on_unpicklable_factories():
    machine = paper_machine(2)
    local = {"n": 0}

    def gfac():
        local["n"] += 1  # closure: not picklable
        return cholesky_graph(4, 256, with_fns=False)

    s = run_many(gfac, machine, lambda: DADA(alpha=0.5), n_runs=2, n_jobs=2)
    assert s.n == 2
    assert local["n"] >= 1  # ran in-process


def test_sched_overhead_reports_events_per_sec(capsys, monkeypatch):
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    monkeypatch.setenv("REPRO_BENCH_GPUS", "2")
    monkeypatch.setenv("REPRO_BENCH_RUNS", "1")
    import benchmarks.sched_overhead as so

    rows = so.main()
    out = capsys.readouterr().out
    assert "events_per_s=" in out
    assert all(r["events"] > 0 for r in rows)
    assert {r["kernel"] for r in rows} == {"cholesky", "lu", "qr"}
