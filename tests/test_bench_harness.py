"""Benchmark-harness behavior: empty sweeps, parallel run_many equivalence,
and the scheduler-overhead reporting contract."""
from functools import partial

from repro.configs.paper_machine import paper_machine
from repro.core import DADA, run_many
from repro.linalg.cholesky import cholesky_graph


def test_sweep_empty_gpu_list_returns_no_rows(capsys):
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks.common import STRATEGIES, sweep

    rows = sweep("tmp_empty", "cholesky", STRATEGIES, 3, [])
    assert rows == []
    assert "empty sweep" in capsys.readouterr().out
    rows = sweep("tmp_empty", "cholesky", {}, 3, [2])
    assert rows == []


def test_run_many_parallel_matches_serial():
    machine = paper_machine(2)
    gfac = partial(cholesky_graph, 4, 256, with_fns=False)
    sfac = partial(DADA, alpha=0.5)
    serial = run_many(gfac, machine, sfac, n_runs=4, n_jobs=1)
    parallel = run_many(gfac, machine, sfac, n_runs=4, n_jobs=2)
    assert serial == parallel  # bit-identical summaries


def test_run_many_falls_back_on_unpicklable_factories():
    machine = paper_machine(2)
    local = {"n": 0}

    def gfac():
        local["n"] += 1  # closure: not picklable
        return cholesky_graph(4, 256, with_fns=False)

    s = run_many(gfac, machine, lambda: DADA(alpha=0.5), n_runs=2, n_jobs=2)
    assert s.n == 2
    assert local["n"] >= 1  # ran in-process


def test_sched_overhead_reports_events_per_sec(capsys, monkeypatch, tmp_path):
    import json
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    monkeypatch.setenv("REPRO_BENCH_GPUS", "2")
    monkeypatch.setenv("REPRO_BENCH_RUNS", "1")
    monkeypatch.setenv("REPRO_BENCH_LAMBDA", "0")  # skip the NT=64 micro
    monkeypatch.setenv("REPRO_SCHED_BACKENDS", "numpy")
    import benchmarks.common as common
    import benchmarks.sched_overhead as so

    out_json = tmp_path / "BENCH_sched.json"
    monkeypatch.setattr(common, "BENCH_JSON", out_json)
    rows = so.main()
    out = capsys.readouterr().out
    assert "events_per_s=" in out
    assert all(r["events"] > 0 for r in rows)
    assert {r["kernel"] for r in rows} == {
        "cholesky", "lu", "qr", "cholesky-x4stream"
    }
    # backend-free ws is measured once under the stable "none" label
    assert {r["backend"] for r in rows} == {"numpy", "none"}
    assert all(
        r["backend"] == "none" for r in rows if r["strategy"] == "ws"
    )
    # the eviction path has its own capacity-bounded rows (gated by key)
    cap_rows = [r for r in rows if r["capacity"]]
    assert {r["strategy"] for r in cap_rows} == set(
        so.CAPACITY_ROW_STRATEGIES
    )
    assert all(r["capacity"] == so.CAPACITY_ROW_BYTES for r in cap_rows)
    # the 4-tenant streaming row reports per-graph makespans
    (stream,) = [r for r in rows if r["kernel"] == "cholesky-x4stream"]
    assert len(stream["per_graph_makespans"]) == 4
    assert all(m > 0 for m in stream["per_graph_makespans"])
    # the fault path has its own churned rows: both recovery modes, keyed
    # apart from the fault-free rows by the (churn, fault_mode) fields
    churned = [r for r in rows if r["churn"]]
    assert {(r["strategy"], r["fault_mode"]) for r in churned} == {
        (s, m) for s in so.CHURN_STRATEGIES for m in ("drain", "kill")
    }
    assert all(r["churn"] == so.CHURN_RATE for r in churned)
    assert all(r["fault_mode"] == "drain" and r["churn"] == 0.0
               for r in rows if r not in churned)
    # machine-readable perf trajectory (BENCH_sched.json satellite)
    doc = json.loads(out_json.read_text())
    sec = doc["sched_overhead"]
    assert sec["calibration_score"] > 0
    assert len(sec["whole_sim"]) == len(rows)
    assert {"kernel", "strategy", "backend", "nt", "capacity",
            "events_per_s", "wall_s"} <= set(sec["whole_sim"][0])


def test_sched_regression_gate(monkeypatch, tmp_path, capsys):
    """The CI gate fails on a >25% events/sec drop after machine-speed
    calibration, and passes when throughput merely tracks machine speed."""
    import json
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    import benchmarks.check_sched_regression as gate

    def write(path, cal, evs):
        path.write_text(json.dumps({
            "sched_overhead": {
                "calibration_score": cal,
                "whole_sim": [{
                    "kernel": "cholesky", "strategy": "heft",
                    "backend": "numpy", "nt": 16, "n_gpus": 8,
                    "events_per_s": evs,
                }],
            }
        }))

    cur = tmp_path / "cur.json"
    base = tmp_path / "base.json"
    monkeypatch.setattr(gate, "CURRENT", cur)
    monkeypatch.setattr(gate, "BASELINE", base)

    # a slower machine (half calibration) with proportional events/sec: OK
    write(base, 1000.0, 50000.0)
    write(cur, 500.0, 25500.0)
    assert gate.main() == 0
    # a >25% real regression on the same machine: FAIL
    write(cur, 1000.0, 36000.0)
    assert gate.main() == 1
    # missing baseline: skipped, not failed
    base.unlink()
    assert gate.main() == 0
    capsys.readouterr()
