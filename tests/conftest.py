"""Shared pytest setup: hypothesis profiles.

The property suites (test_property_sim, test_residency_property,
test_faults_property) run under the "ci" profile on the dedicated CI
leg (``HYPOTHESIS_PROFILE=ci``): more examples, no per-example deadline
(simulation examples are heavier than the 200 ms default allows, and CI
machines jitter). The default profile keeps local runs fast.
"""
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

try:
    from hypothesis import HealthCheck, settings
except ModuleNotFoundError:
    pass
else:
    settings.register_profile(
        "ci",
        max_examples=50,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
        print_blob=True,
    )
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "default"))
