"""The layered runtime engine: multi-graph streams, capacity-bounded
memories, the memory-pressure signal, and stale-transfer cancellation.

Bit-for-bit equivalence of the unbounded single-graph path is covered by
tests/test_equivalence*.py and tests/test_residency_property.py; this
module tests the new opt-in behaviors.
"""
import pytest

from repro.configs.paper_machine import paper_machine
from repro.core import DataObject, Mode, Simulator, TaskGraph
from repro.linalg.cholesky import cholesky_graph
from repro.linalg.lu import lu_graph
from repro.linalg.qr import qr_graph
from repro.runtime import Engine, predicted_eviction_bytes
from repro.sched import resolve

MB = 1024 * 1024


# ---------------------------------------------------------------------------
# multi-graph streaming


def _submit_four(engine):
    ctxs = []
    for i, gf in enumerate((cholesky_graph, lu_graph, qr_graph, cholesky_graph)):
        at = None if i < 2 else 0.02 * i  # two at t=0, two streamed in later
        ctxs.append(engine.submit(gf(6, 256, with_fns=False), at=at))
    return ctxs


@pytest.mark.parametrize("spec", ["heft", "dada?alpha=0.5&use_cp=1", "ws"])
def test_four_graph_stream_completes_with_per_graph_results(spec):
    eng = Engine(paper_machine(4), resolve(spec), seed=0)
    ctxs = _submit_four(eng)
    results = eng.run()
    assert len(results) == 4
    for ctx, res in zip(ctxs, results):
        assert sorted(iv.tid for iv in res.intervals) == list(
            range(ctx.n_tasks)
        )
        assert res.makespan > 0
        # the graph cannot have finished before it arrived
        assert ctx.finish >= ctx.submit_at
    # streamed graphs really started after their arrival events
    assert all(
        iv.start >= ctx.submit_at - 1e-12
        for ctx in ctxs[2:]
        for iv in ctx.intervals
    )


def test_stream_workers_never_double_booked_across_tenants():
    eng = Engine(paper_machine(3), resolve("heft"), seed=1)
    _submit_four(eng)
    eng.run()
    per_worker = {}
    for iv in eng.intervals:  # engine-global timeline, all tenants
        per_worker.setdefault(iv.rid, []).append((iv.start, iv.end))
    for rid, ivs in per_worker.items():
        ivs.sort()
        for (s1, e1), (s2, e2) in zip(ivs, ivs[1:]):
            assert e1 <= s2 + 1e-9, f"worker {rid} overlaps across graphs"


def test_stream_is_deterministic():
    def fingerprint():
        eng = Engine(paper_machine(4), resolve("dada?alpha=0.5"), seed=3)
        _submit_four(eng)
        return [
            (r.makespan, tuple((iv.tid, iv.rid, iv.start) for iv in r.intervals))
            for r in eng.run()
        ]

    assert fingerprint() == fingerprint()


def test_submit_after_run_start_uses_arrival_event():
    eng = Engine(paper_machine(2), resolve("heft"), seed=0)
    first = eng.submit(cholesky_graph(6, 256, with_fns=False))
    late = eng.submit(lu_graph(5, 256, with_fns=False), at=0.01)
    results = eng.run()
    assert late.submit_at == 0.01
    assert results[1].makespan > 0
    assert min(iv.start for iv in late.intervals) >= 0.01
    assert first.finish > 0


# ---------------------------------------------------------------------------
# submission error paths + serving-mode arrivals under faults


def test_double_submission_rejected():
    eng = Engine(paper_machine(2), resolve("heft"), seed=0)
    g = cholesky_graph(4, 256, with_fns=False)
    eng.submit(g)
    with pytest.raises(ValueError, match="already submitted"):
        eng.submit(g)
    # a fresh graph of the same shape is a different tenant: fine
    eng.submit(cholesky_graph(4, 256, with_fns=False))
    assert len(eng.run()) == 2


def test_mid_run_submit_during_fault_drain():
    """A tenant arriving while a GPU is draining must be admitted, placed
    only on live workers, and completed once the GPU reattaches."""
    detach_t, attach_t = 0.005, 0.08

    def run():
        eng = Engine(
            paper_machine(2), resolve("heft"), seed=0,
            rescore="incremental",
        )
        first = eng.submit(cholesky_graph(8, 256, with_fns=False))
        gpu = eng.machine.gpus[0].rid
        eng.inject("detach", gpu, at=detach_t, mode="drain")
        eng.inject("attach", gpu, at=attach_t)
        # arrives mid-run, inside the dead window
        late = eng.submit(lu_graph(5, 256, with_fns=False), at=0.01)
        eng.run()
        return eng, first, late, gpu

    eng, first, late, gpu = run()
    assert first.n_done == first.n_tasks
    assert late.n_done == late.n_tasks
    assert eng.metrics.n_arrivals == 2
    assert late.submit_at == 0.01
    assert min(iv.start for iv in late.intervals) >= 0.01
    # drain semantics: the task running at detach finishes, but nothing
    # new starts on the dead rid until the attach event
    for iv in eng.intervals:
        if iv.rid == gpu:
            assert not (
                detach_t + 1e-12 < iv.start < attach_t - 1e-12
            ), f"task {iv.tid} started on drained rid {gpu} at {iv.start}"
    # and the whole interleaving is deterministic
    fp = lambda e: [
        (iv.tid, iv.rid, iv.start, iv.end) for iv in e.intervals
    ]
    assert fp(eng) == fp(run()[0])


# ---------------------------------------------------------------------------
# stale-transfer cancellation (REPRO_SCHED_CANCEL_STALE)


def _stale_landing_sim(cancel: bool):
    """A copy of ``d`` is in flight to GPU memory 1 while a task on GPU 0
    overwrites ``d``: with cancellation off the old bytes still land as a
    "valid" copy (the historical modeling artifact); with it on they are
    dropped."""
    g = TaskGraph()
    d = DataObject("d", 50 * MB)  # ~6 ms in flight: lands well after the write
    e = DataObject("e", 1000)
    g.add_task("w", [(e, Mode.R), (d, Mode.W)], flops=1e6)

    class PinGpu0:
        name = "pin0"
        allow_steal = False
        owner_lifo = False

        def init(self, sim):
            self.gpu = sim.machine.gpus[0].rid

        def place(self, sim, ready, src):
            for t in ready:
                sim.push(t, self.gpu)

    sim = Simulator(
        g, paper_machine(2), PinGpu0(), seed=0, noise=0.0,
        cancel_stale=cancel,
    )
    # start the doomed transfer: host copy of d -> memory 1
    sim.request_transfer("d", 50 * MB, 1)
    sim.run()
    return sim


def test_stale_transfer_lands_by_default():
    sim = _stale_landing_sim(cancel=False)
    # the artifact, preserved for bit-for-bit equivalence: stale copy valid
    assert sim.residency.is_resident("d", 1)


def test_cancel_stale_drops_overwritten_inflight_copy():
    sim = _stale_landing_sim(cancel=True)
    assert not sim.residency.is_resident("d", 1)
    # the rewritten copy on GPU 0's memory is the only valid one
    assert sim.residency.locations("d") == {0}


def test_cancel_stale_config_flag(monkeypatch):
    from repro.sched import current_config

    monkeypatch.setenv("REPRO_SCHED_CANCEL_STALE", "1")
    assert current_config().cancel_stale is True
    g = TaskGraph()
    g.add_task("k", [(DataObject("x", 10), Mode.W)], flops=1.0)
    sim = Simulator(g, paper_machine(1), resolve("heft"), seed=0)
    assert sim._cancel_stale is True


def test_equivalence_unaffected_by_cancel_flag_without_races():
    """On a run with no mid-flight overwrites both modes are identical."""
    g1 = cholesky_graph(6, 256, with_fns=False)
    g2 = cholesky_graph(6, 256, with_fns=False)
    m = paper_machine(3)
    a = Simulator(g1, m, resolve("heft"), seed=5, cancel_stale=False).run()
    b = Simulator(g2, m, resolve("heft"), seed=5, cancel_stale=True).run()
    assert [(iv.tid, iv.rid, iv.start, iv.end) for iv in a.intervals] == [
        (iv.tid, iv.rid, iv.start, iv.end) for iv in b.intervals
    ]
    assert a.total_bytes == b.total_bytes


# ---------------------------------------------------------------------------
# capacity configuration and the pressure signal


def test_capacity_too_small_for_one_task_rejected():
    g = TaskGraph()
    g.add_task("big", [(DataObject("x", 100 * MB), Mode.RW)], flops=1e9)
    with pytest.raises(ValueError, match="working set"):
        Simulator(g, paper_machine(1), resolve("heft"), mem_capacity=MB)


def test_unknown_eviction_policy_rejected():
    g = cholesky_graph(4, 256, with_fns=False)
    with pytest.raises(ValueError, match="eviction"):
        Simulator(
            g, paper_machine(1), resolve("heft"),
            mem_capacity=64 * MB, eviction="random",
        )


def test_capacity_env_knobs(monkeypatch):
    from repro.sched import current_config

    monkeypatch.setenv("REPRO_SCHED_MEM_CAPACITY", str(64 * MB))
    monkeypatch.setenv("REPRO_SCHED_EVICTION", "affinity")
    cfg = current_config()
    assert cfg.mem_capacity == 64 * MB
    assert cfg.eviction == "affinity"
    sim = Simulator(
        cholesky_graph(4, 256, with_fns=False), paper_machine(2),
        resolve("heft"), seed=0,
    )
    assert sim.memory.bounded and sim.memory.capacity == 64 * MB
    assert sim.memory.policy == "affinity"
    monkeypatch.setenv("REPRO_SCHED_EVICTION", "banana")
    with pytest.raises(ValueError, match="REPRO_SCHED_EVICTION"):
        current_config()


def test_pressure_matrix_none_when_unbounded():
    from repro.sched import ScoreMatrixPolicy

    sim = Simulator(
        cholesky_graph(4, 256, with_fns=False), paper_machine(2),
        resolve("locality"), seed=0,
    )
    ready = sim.graph.roots()
    assert ScoreMatrixPolicy.pressure_matrix(sim.strategy, sim, ready) is None


def test_pressure_rows_positive_on_crowded_memory():
    sim = Simulator(
        cholesky_graph(8, 512, with_fns=False), paper_machine(2),
        resolve("locality"), seed=0, mem_capacity=8 * MB,
    )
    # fill GPU memory 0 to capacity with tiles the probed tasks don't read
    for name in sim.arrays.data_names[-4:]:  # 4 x 2 MB tiles
        sim.residency.add_copy(name, 0)
    tids = [t.tid for t in sim.graph.tasks[:5]]
    mems = [r.mem for r in sim.machine.resources]
    rows = sim.memory.pressure_rows(
        sim.arrays, tids, mems, sim.residency, sim.transfer_model
    )
    gpu0_col = mems.index(0)
    host_col = mems.index(-1)
    assert (rows[:, host_col] == 0.0).all()  # host is unbounded
    # tasks whose inputs are not on mem 0 would overflow it: positive cost
    assert rows[:, gpu0_col].max() > 0.0
    # and the emptier memory 1 is strictly cheaper for some task
    gpu1_col = mems.index(1)
    assert (rows[:, gpu1_col] <= rows[:, gpu0_col]).all()


def test_pressure_changes_placements_under_capacity():
    """With the signal wired into HEFT's transfer matrix, a capacity-
    bounded run must not place exactly like the unbounded one on a
    pressure-heavy workload (and both must still complete)."""
    def run(cap):
        sim = Simulator(
            cholesky_graph(12, 512, with_fns=False), paper_machine(4),
            resolve("heft"), seed=0, noise=0.0, mem_capacity=cap,
        )
        res = sim.run()
        return [(iv.tid, iv.rid) for iv in res.intervals], res

    unbounded, _ = run(0)
    bounded, res = run(24 * MB)
    assert sorted(t for t, _ in bounded) == sorted(t for t, _ in unbounded)
    assert bounded != unbounded


def test_predicted_eviction_bytes_formula():
    import numpy as np

    out = predicted_eviction_bytes(
        np.array([0.0, 50.0, 120.0]), np.array([30.0, 80.0, 10.0]), 100.0
    )
    assert out.tolist() == [0.0, 30.0, 10.0]


def test_expert_replanning_prices_eviction_cost():
    """The dist bridge shares the eviction-cost formula: a nearly-full
    group repels incoming experts unless they were already there."""
    from repro.dist.sched_bridge import plan_expert_placement

    # e2/e3 are new experts (prev -1): without memory pricing the score
    # tie sends e2 to group 0; with group 0's HBM full the eviction cost
    # steers it to the empty group 1 instead
    mass = [5.0, 5.0, 4.0, 4.0]
    prev = [0, 1, -1, -1]
    kw = dict(prev_assignment=prev, alpha=0.1)
    free = plan_expert_placement(mass, 2, **kw)
    priced = plan_expert_placement(
        mass, 2, **kw,
        expert_bytes=10.0, group_hbm_bytes=15.0,
        group_resident_bytes=[15.0, 5.0],  # group 0 full, group 1 roomy
    )
    assert free.assignment[2] == 0
    assert priced.assignment[2] == 1
    # capacity stays exact (2 slots per group) under pricing
    assert sorted(priced.assignment.tolist()) == [0, 0, 1, 1]
    # previously-placed experts keep their homes (staying is free)
    assert priced.assignment[0] == 0 and priced.assignment[1] == 1


# ---------------------------------------------------------------------------
# jax scoring backend: pressure fold keeps decisions identical to numpy


def _wide_wave(graph):
    depth = [0] * len(graph)
    for t in graph.tasks:
        preds = graph.pred[t.tid]
        depth[t.tid] = (max(depth[p] for p in preds) + 1) if preds else 0
    counts = {}
    for d in depth:
        counts[d] = counts.get(d, 0) + 1
    best = max(counts, key=lambda d: (counts[d], -d))
    return [t for t in graph.tasks if depth[t.tid] == best]


@pytest.mark.parametrize("spec", ["dada?alpha=0.5&use_cp=1", "heft"])
def test_jax_backend_pressure_fold_matches_numpy(spec):
    pytest.importorskip("jax")
    from repro.core.backend import get_backend

    if get_backend("jax") is None:
        pytest.skip("jax backend unavailable")
    graph = cholesky_graph(10, 256, with_fns=False)
    wave = _wide_wave(graph)
    assert len(wave) >= 32  # wide enough for the jax path to engage
    placements = {}
    for backend in ("numpy", "jax"):
        strat = resolve(spec, backend=backend)
        sim = Simulator(
            graph, paper_machine(4), strat, seed=0,
            mem_capacity=4 * MB, eviction="affinity",
        )
        for k, name in enumerate(sim.arrays.data_names):
            if k % 3 == 0:
                sim.residency.write(name, k % 4)
        placed = {}
        sim.push = lambda task, rid, _p=placed: _p.__setitem__(task.tid, rid)
        strat.place(sim, wave, None)
        placements[backend] = placed
    assert placements["jax"] == placements["numpy"]
