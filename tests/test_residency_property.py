"""Property tests: the bitmask Residency against the set-based reference.

Random operation sequences (add_copy / write / initialize) applied to both
implementations must agree on every query (is_resident, locations,
has_any, transfer_hops, bytes_resident) — including the attached-mode
incremental resident-bytes vector against a recomputed ground truth.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import DataObject, GraphArrays, Mode, Residency, TaskGraph
from repro.core._reference import SetResidency
from repro.core.machine import HOST_MEM

NAMES = [f"d{i}" for i in range(6)]
MEMS = [HOST_MEM, 0, 1, 2, 7]


def _apply(ops, res):
    for op, name, mem in ops:
        if op == 0:
            res.add_copy(name, mem)
        elif op == 1:
            res.write(name, mem)
        else:
            res.initialize([name], mem)


def _graph_over(names):
    g = TaskGraph()
    for i, n in enumerate(names):
        g.add_task("touch", [(DataObject(n, 100 + i), Mode.RW)], flops=1.0)
    return g


@settings(max_examples=60, deadline=None)
@given(
    st.lists(
        st.tuples(
            st.integers(0, 2),
            st.sampled_from(NAMES),
            st.sampled_from(MEMS),
        ),
        max_size=40,
    )
)
def test_bitmask_residency_matches_set_reference(ops):
    a = Residency()
    b = SetResidency()
    _apply(ops, a)
    _apply(ops, b)
    sizes = {n: 100 + i for i, n in enumerate(NAMES)}
    for n in NAMES:
        assert a.has_any(n) == b.has_any(n)
        assert a.locations(n) == b.locations(n)
        for m in MEMS:
            assert a.is_resident(n, m) == b.is_resident(n, m)
            assert a.transfer_hops(n, m) == b.transfer_hops(n, m)
    for m in MEMS:
        assert a.bytes_resident(m, sizes) == b.bytes_resident(m, sizes)


@settings(max_examples=40, deadline=None)
@given(
    st.lists(
        st.tuples(
            st.integers(0, 2),
            st.sampled_from(NAMES),
            st.sampled_from(MEMS),
        ),
        max_size=40,
    )
)
def test_attached_incremental_bytes_match_recompute(ops):
    g = _graph_over(NAMES)
    arr = g.arrays()
    res = Residency()
    res.attach(arr)
    _apply(ops, res)
    sizes = {n: int(arr.data_sizes[arr.name_to_id[n]]) for n in NAMES}
    for m in MEMS:
        assert res.bytes_resident(m) == res.bytes_resident(m, sizes)


def test_attach_preserves_existing_state():
    res = Residency()
    res.write("d0", 2)
    res.add_copy("d0", HOST_MEM)
    g = _graph_over(NAMES)
    res.attach(g.arrays())
    assert res.locations("d0") == {2, HOST_MEM}
    assert res.bytes_resident(2) == 100


def test_mask_of_ids_matches_scalar():
    g = _graph_over(NAMES)
    arr = g.arrays()
    res = Residency()
    res.attach(arr)
    res.initialize(NAMES, HOST_MEM)
    res.write("d3", 1)
    ids = np.arange(len(NAMES))
    masks = res.mask_of_ids(ids)
    for n, m in zip(NAMES, masks.tolist()):
        assert m == res.mask(n)


def test_mem_out_of_range_rejected():
    res = Residency()
    with pytest.raises(ValueError):
        res.add_copy("d0", 62)
    with pytest.raises(ValueError):
        res.is_resident("d0", -2)


# ---------------------------------------------------------------------------
# GraphArrays CSR view against the Task-object ground truth


def test_graph_arrays_csr_matches_tasks():
    rng = np.random.default_rng(0)
    datas = [DataObject(f"x{i}", int(rng.integers(1, 1000))) for i in range(8)]
    g = TaskGraph()
    for _ in range(50):
        k = int(rng.integers(1, 4))
        picks = rng.choice(8, size=k, replace=False)
        accesses = []
        for j, di in enumerate(picks):
            mode = Mode.RW if j == 0 else (Mode.R if rng.random() < 0.6 else Mode.W)
            accesses.append((datas[di], mode))
        g.add_task(
            f"kind{int(rng.integers(3))}", accesses, flops=float(rng.uniform(1, 100))
        )
    arr = g.arrays()
    assert arr.n_tasks == len(g)
    for t in g.tasks:
        lo, hi = arr.read_indptr[t.tid], arr.read_indptr[t.tid + 1]
        names = [arr.data_names[i] for i in arr.read_ids[lo:hi]]
        assert names == [d.name for d in t.reads]
        assert arr.read_sizes[lo:hi].tolist() == [d.size_bytes for d in t.reads]
        lo, hi = arr.write_indptr[t.tid], arr.write_indptr[t.tid + 1]
        names = [arr.data_names[i] for i in arr.write_ids[lo:hi]]
        assert names == [d.name for d in t.writes]
        assert arr.kinds[arr.kind_codes[t.tid]] == t.kind
        assert arr.flops[t.tid] == t.flops
        assert [nm for _, nm, _ in arr.task_reads[t.tid]] == [d.name for d in t.reads]
    # data id space matches data_objects()
    objs = g.data_objects()
    assert set(arr.data_names) == set(objs)
    for name, did in arr.name_to_id.items():
        assert int(arr.data_sizes[did]) == objs[name].size_bytes
    # arrays view is cached and invalidated by add_task
    assert g.arrays() is arr
    g.add_task("kind0", [(datas[0], Mode.R)])
    assert g.arrays() is not arr


# ---------------------------------------------------------------------------
# eviction support: drop_copy against an independently tracked ground truth


@settings(max_examples=40, deadline=None)
@given(
    st.lists(
        st.tuples(
            st.integers(0, 3),  # 3 = drop_copy
            st.sampled_from(NAMES),
            st.sampled_from(MEMS),
        ),
        max_size=50,
    )
)
def test_drop_copy_matches_set_semantics(ops):
    """``drop_copy`` (the eviction primitive) is the exact inverse of
    ``add_copy``: against a plain dict-of-sets ground truth, every query
    agrees after any interleaving of add/write/init/drop."""
    res = Residency()
    truth = {}
    for op, name, mem in ops:
        if op == 0:
            res.add_copy(name, mem)
            truth.setdefault(name, set()).add(mem)
        elif op == 1:
            res.write(name, mem)
            truth[name] = {mem}
        elif op == 2:
            res.initialize([name], mem)
            truth[name] = {mem}
        else:
            res.drop_copy(name, mem)
            truth.setdefault(name, set()).discard(mem)
    for n in NAMES:
        assert res.locations(n) == truth.get(n, set())
        assert res.has_any(n) == bool(truth.get(n))


def test_drop_copy_updates_incremental_bytes():
    g = _graph_over(NAMES)
    res = Residency()
    res.attach(g.arrays())
    res.initialize(NAMES, HOST_MEM)
    res.add_copy("d0", 1)
    res.add_copy("d1", 1)
    assert res.bytes_resident(1) == 100 + 101
    res.drop_copy("d0", 1)
    assert res.bytes_resident(1) == 101
    assert res.is_resident("d0", HOST_MEM)  # other copies untouched
    res.drop_copy("d0", 1)  # idempotent
    assert res.bytes_resident(1) == 101


def test_observer_sees_every_mask_change():
    g = _graph_over(NAMES)
    res = Residency()
    res.attach(g.arrays())
    seen = []
    res.observer = lambda did, name, old, new: seen.append((name, old, new))
    res.add_copy("d2", 0)
    res.write("d2", 1)
    res.drop_copy("d2", 1)
    assert [(n, bool(o), bool(w)) for n, o, w in seen] == [
        ("d2", False, True), ("d2", True, True), ("d2", True, False)
    ]
    # no-op changes do not fire
    seen.clear()
    res.drop_copy("d2", 5)
    assert seen == []


# ---------------------------------------------------------------------------
# the capacity-bounded memory layer (repro.runtime.memory): resident bytes
# never exceed capacity, dirty evictions write back before invalidation,
# and an unbounded single-graph engine run is interval-identical to the
# Simulator facade


def _random_graph(seed: int, n_tasks: int = 40, n_data: int = 10):
    from repro.core import DataObject, Mode, TaskGraph

    rng = np.random.default_rng(seed)
    # sizes bounded so a 3-access working set always fits the 500 kB test
    # capacity (the manager rejects capacities below one task's needs)
    datas = [
        DataObject(f"x{i}", int(rng.integers(1_000, 150_000)))
        for i in range(n_data)
    ]
    g = TaskGraph()
    for _ in range(n_tasks):
        k = int(rng.integers(1, 4))
        picks = rng.choice(n_data, size=k, replace=False)
        accesses = []
        for j, di in enumerate(picks):
            mode = Mode.RW if j == 0 else (
                Mode.R if rng.random() < 0.6 else Mode.W
            )
            accesses.append((datas[di], mode))
        g.add_task(
            f"kind{int(rng.integers(3))}", accesses,
            flops=float(rng.uniform(1e6, 1e8)),
        )
    return g


@settings(max_examples=15, deadline=None)
@given(
    st.integers(0, 10_000),
    st.sampled_from(["lru", "affinity"]),
    st.sampled_from(["heft", "dada?alpha=0.5&use_cp=1", "locality"]),
)
def test_capacity_never_exceeded_and_dirty_written_back(seed, policy, spec):
    """Under a tight capacity every device memory's resident bytes stay
    within bounds at all times (high-water mark), every run still
    completes, and any evicted sole copy was written back to host before
    invalidation (it must be re-readable — completion proves it, and the
    write-back traffic is accounted)."""
    from repro.configs.paper_machine import paper_machine
    from repro.core import Simulator
    from repro.sched import resolve

    g = _random_graph(seed)
    cap = 500_000  # a few data objects worth: forces eviction
    sim = Simulator(
        g, paper_machine(3), resolve(spec), seed=seed,
        mem_capacity=cap, eviction=policy,
    )
    res = sim.run()
    assert sorted(iv.tid for iv in res.intervals) == list(range(len(g)))
    for mem, high in sim.memory.max_resident.items():
        assert high <= cap, (mem, high, cap)
    # residency stayed coherent: every object still has a valid copy
    for name in sim.arrays.data_names:
        assert sim.residency.has_any(name)
    if sim.metrics.n_writebacks:
        assert sim.metrics.writeback_bytes > 0
        # write-back traffic is real traffic: accounted in total_bytes
        assert res.total_bytes >= sim.metrics.writeback_bytes


@settings(max_examples=10, deadline=None)
@given(
    st.integers(0, 10_000),
    st.sampled_from(["heft", "dada?alpha=0.5&use_cp=1", "ws"]),
)
def test_unbounded_engine_interval_identical_to_simulator(seed, spec):
    """A single graph submitted to a bare Engine with capacity unbounded
    replays the Simulator facade bit-for-bit: same intervals, same
    transfer totals, same event count."""
    from repro.configs.paper_machine import paper_machine
    from repro.core import Simulator
    from repro.runtime import Engine
    from repro.sched import resolve

    machine = paper_machine(2)
    sim = Simulator(_random_graph(seed), machine, resolve(spec), seed=seed)
    a = sim.run()
    eng = Engine(machine, resolve(spec), seed=seed)
    eng.submit(_random_graph(seed))
    (b,) = eng.run()
    assert [
        (iv.tid, iv.rid, iv.start, iv.end) for iv in a.intervals
    ] == [(iv.tid, iv.rid, iv.start, iv.end) for iv in b.intervals]
    assert a.total_bytes == b.total_bytes
    assert a.n_transfers == b.n_transfers
    assert a.n_steals == b.n_steals
    assert a.n_events == b.n_events


def test_write_back_preserves_sole_copy():
    """Deterministic dirty-eviction scenario: data written on a GPU (sole
    copy) must be written back to host when evicted, not lost."""
    from repro.configs.paper_machine import paper_machine
    from repro.core import DataObject, Mode, Simulator, TaskGraph
    from repro.sched import resolve

    g = TaskGraph()
    mb = 1024 * 1024
    # t0 writes a (sole copy lands on the GPU); filler tasks then flood the
    # GPU memory so `a` is evicted; t_last re-reads `a`
    a = DataObject("a", 4 * mb)
    fillers = [DataObject(f"f{i}", 4 * mb) for i in range(4)]
    g.add_task("w", [(a, Mode.W)], flops=1e9)
    for f in fillers:
        g.add_task("w", [(f, Mode.RW)], flops=1e9)
    g.add_task("r", [(a, Mode.R)], flops=1e9)

    class PinGpu:
        name = "pin0"
        allow_steal = False
        owner_lifo = False

        def init(self, sim):
            self.gpu = sim.machine.gpus[0].rid

        def place(self, sim, ready, src):
            for t in ready:
                sim.push(t, self.gpu)

    sim = Simulator(
        g, paper_machine(1), PinGpu(), seed=0, noise=0.0,
        mem_capacity=10 * mb, eviction="lru",
    )
    res = sim.run()
    assert sorted(iv.tid for iv in res.intervals) == list(range(len(g)))
    assert sim.metrics.n_evictions > 0
    assert sim.metrics.n_writebacks > 0  # `a` (and fillers) were dirty
    assert sim.metrics.writeback_bytes >= 4 * mb
