"""Property tests: the bitmask Residency against the set-based reference.

Random operation sequences (add_copy / write / initialize) applied to both
implementations must agree on every query (is_resident, locations,
has_any, transfer_hops, bytes_resident) — including the attached-mode
incremental resident-bytes vector against a recomputed ground truth.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import DataObject, GraphArrays, Mode, Residency, TaskGraph
from repro.core._reference import SetResidency
from repro.core.machine import HOST_MEM

NAMES = [f"d{i}" for i in range(6)]
MEMS = [HOST_MEM, 0, 1, 2, 7]


def _apply(ops, res):
    for op, name, mem in ops:
        if op == 0:
            res.add_copy(name, mem)
        elif op == 1:
            res.write(name, mem)
        else:
            res.initialize([name], mem)


def _graph_over(names):
    g = TaskGraph()
    for i, n in enumerate(names):
        g.add_task("touch", [(DataObject(n, 100 + i), Mode.RW)], flops=1.0)
    return g


@settings(max_examples=60, deadline=None)
@given(
    st.lists(
        st.tuples(
            st.integers(0, 2),
            st.sampled_from(NAMES),
            st.sampled_from(MEMS),
        ),
        max_size=40,
    )
)
def test_bitmask_residency_matches_set_reference(ops):
    a = Residency()
    b = SetResidency()
    _apply(ops, a)
    _apply(ops, b)
    sizes = {n: 100 + i for i, n in enumerate(NAMES)}
    for n in NAMES:
        assert a.has_any(n) == b.has_any(n)
        assert a.locations(n) == b.locations(n)
        for m in MEMS:
            assert a.is_resident(n, m) == b.is_resident(n, m)
            assert a.transfer_hops(n, m) == b.transfer_hops(n, m)
    for m in MEMS:
        assert a.bytes_resident(m, sizes) == b.bytes_resident(m, sizes)


@settings(max_examples=40, deadline=None)
@given(
    st.lists(
        st.tuples(
            st.integers(0, 2),
            st.sampled_from(NAMES),
            st.sampled_from(MEMS),
        ),
        max_size=40,
    )
)
def test_attached_incremental_bytes_match_recompute(ops):
    g = _graph_over(NAMES)
    arr = g.arrays()
    res = Residency()
    res.attach(arr)
    _apply(ops, res)
    sizes = {n: int(arr.data_sizes[arr.name_to_id[n]]) for n in NAMES}
    for m in MEMS:
        assert res.bytes_resident(m) == res.bytes_resident(m, sizes)


def test_attach_preserves_existing_state():
    res = Residency()
    res.write("d0", 2)
    res.add_copy("d0", HOST_MEM)
    g = _graph_over(NAMES)
    res.attach(g.arrays())
    assert res.locations("d0") == {2, HOST_MEM}
    assert res.bytes_resident(2) == 100


def test_mask_of_ids_matches_scalar():
    g = _graph_over(NAMES)
    arr = g.arrays()
    res = Residency()
    res.attach(arr)
    res.initialize(NAMES, HOST_MEM)
    res.write("d3", 1)
    ids = np.arange(len(NAMES))
    masks = res.mask_of_ids(ids)
    for n, m in zip(NAMES, masks.tolist()):
        assert m == res.mask(n)


def test_mem_out_of_range_rejected():
    res = Residency()
    with pytest.raises(ValueError):
        res.add_copy("d0", 62)
    with pytest.raises(ValueError):
        res.is_resident("d0", -2)


# ---------------------------------------------------------------------------
# GraphArrays CSR view against the Task-object ground truth


def test_graph_arrays_csr_matches_tasks():
    rng = np.random.default_rng(0)
    datas = [DataObject(f"x{i}", int(rng.integers(1, 1000))) for i in range(8)]
    g = TaskGraph()
    for _ in range(50):
        k = int(rng.integers(1, 4))
        picks = rng.choice(8, size=k, replace=False)
        accesses = []
        for j, di in enumerate(picks):
            mode = Mode.RW if j == 0 else (Mode.R if rng.random() < 0.6 else Mode.W)
            accesses.append((datas[di], mode))
        g.add_task(
            f"kind{int(rng.integers(3))}", accesses, flops=float(rng.uniform(1, 100))
        )
    arr = g.arrays()
    assert arr.n_tasks == len(g)
    for t in g.tasks:
        lo, hi = arr.read_indptr[t.tid], arr.read_indptr[t.tid + 1]
        names = [arr.data_names[i] for i in arr.read_ids[lo:hi]]
        assert names == [d.name for d in t.reads]
        assert arr.read_sizes[lo:hi].tolist() == [d.size_bytes for d in t.reads]
        lo, hi = arr.write_indptr[t.tid], arr.write_indptr[t.tid + 1]
        names = [arr.data_names[i] for i in arr.write_ids[lo:hi]]
        assert names == [d.name for d in t.writes]
        assert arr.kinds[arr.kind_codes[t.tid]] == t.kind
        assert arr.flops[t.tid] == t.flops
        assert [nm for _, nm, _ in arr.task_reads[t.tid]] == [d.name for d in t.reads]
    # data id space matches data_objects()
    objs = g.data_objects()
    assert set(arr.data_names) == set(objs)
    for name, did in arr.name_to_id.items():
        assert int(arr.data_sizes[did]) == objs[name].size_bytes
    # arrays view is cached and invalidated by add_task
    assert g.arrays() is arr
    g.add_task("kind0", [(datas[0], Mode.R)])
    assert g.arrays() is not arr
