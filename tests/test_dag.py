"""Data-flow DAG semantics: RAW/WAR/WAW derivation + graph utilities."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import DataObject, Mode, TaskGraph


def _data(name, size=8):
    return DataObject(name, size)


def test_raw_dependency():
    g = TaskGraph()
    x = _data("x")
    t0 = g.add_task("w", [(x, Mode.W)])
    t1 = g.add_task("r", [(x, Mode.R)])
    assert g.pred[t1.tid] == [t0.tid]


def test_waw_dependency():
    g = TaskGraph()
    x = _data("x")
    t0 = g.add_task("w", [(x, Mode.W)])
    t1 = g.add_task("w", [(x, Mode.W)])
    assert g.pred[t1.tid] == [t0.tid]


def test_war_dependency():
    g = TaskGraph()
    x = _data("x")
    t0 = g.add_task("w", [(x, Mode.W)])
    r1 = g.add_task("r", [(x, Mode.R)])
    r2 = g.add_task("r", [(x, Mode.R)])
    w2 = g.add_task("w", [(x, Mode.W)])
    # readers are parallel, the next writer waits on both readers
    # (plus a transitively-redundant WAW edge on the previous writer)
    assert g.pred[r2.tid] == [t0.tid]
    assert {r1.tid, r2.tid} <= set(g.pred[w2.tid])


def test_independent_tasks_have_no_edges():
    g = TaskGraph()
    for i in range(5):
        g.add_task("k", [(_data(f"d{i}"), Mode.RW)])
    assert g.n_edges == 0
    assert len(g.roots()) == 5


def test_rw_chain_serializes():
    g = TaskGraph()
    x = _data("x")
    tids = [g.add_task("k", [(x, Mode.RW)]).tid for _ in range(4)]
    for a, b in zip(tids, tids[1:]):
        assert g.pred[b] == [a]


def test_critical_path():
    g = TaskGraph()
    x, y = _data("x"), _data("y")
    g.add_task("k", [(x, Mode.RW)], flops=2.0)
    g.add_task("k", [(x, Mode.RW)], flops=3.0)
    g.add_task("k", [(y, Mode.RW)], flops=10.0)
    assert g.critical_path_length(lambda t: t.flops) == 10.0


@settings(max_examples=60, deadline=None)
@given(
    st.lists(
        st.tuples(st.integers(0, 5), st.sampled_from(list(Mode))),
        min_size=1,
        max_size=40,
    )
)
def test_topo_order_respects_edges(prog):
    """Property: any access program yields an acyclic graph whose topological
    order puts every predecessor before its successor."""
    g = TaskGraph()
    datas = {i: _data(f"d{i}") for i in range(6)}
    for slot, mode in prog:
        g.add_task("k", [(datas[slot], mode)])
    order = g.topo_order()
    pos = {tid: i for i, tid in enumerate(order)}
    assert len(order) == len(g)
    for t in g.tasks:
        for s in g.succ[t.tid]:
            assert pos[t.tid] < pos[s]
    # edges always point forward in program order (construction invariant)
    for t in g.tasks:
        for s in g.succ[t.tid]:
            assert s > t.tid
