"""End-to-end dry-run test: the real 512-device lower+compile path, run in a
subprocess (the XLA device-count flag must be set before jax initializes)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_dryrun_cell_compiles(tmp_path, mesh):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env["REPRO_RESULTS_DIR"] = str(tmp_path)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "xlstm-1.3b", "--shape", "decode_32k",
         "--mesh", mesh, "--force"],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    pod = "pod1" if mesh == "single" else "pod2"
    rec = json.loads((tmp_path / f"xlstm-1.3b__decode_32k__{pod}.json").read_text())
    assert rec["status"] == "ok"
    assert rec["n_devices"] == (256 if mesh == "single" else 512)
    assert rec["hlo_flops_raw"] > 0
    assert rec["collective_bytes_per_device"]["total"] >= 0
    assert "memory" in rec and rec["memory"]
