"""The determinism/config lint (repro.verify.lint).

Two directions: the shipped ``src/repro`` tree must be clean under every
rule, and synthetic files seeded with each violation class must be
flagged with the right code (and the documented allowlists must hold).
"""
import os
import subprocess
import sys
import tempfile
import textwrap

from repro.verify import lint_paths
from repro.verify.lint import lint_file

SRC = os.path.join(os.path.dirname(__file__), "..", "src", "repro")


def _lint_snippet(code, relpath="scratch/bad.py"):
    """Lint ``code`` as if it lived at ``repro/<relpath>``."""
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "repro", *relpath.split("/"))
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            f.write(textwrap.dedent(code))
        return lint_file(path)


def _codes(findings):
    return [f.code for f in findings]


# ---------------------------------------------------------------------------
# the repo itself is clean


def test_src_repro_lints_clean():
    findings = lint_paths([SRC])
    assert findings == [], "\n".join(str(f) for f in findings)


def test_module_cli_exits_zero_on_clean_tree():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.verify", "lint", SRC],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.join(SRC, "..")},
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_module_cli_exits_nonzero_on_findings():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "bad.py")
        with open(path, "w") as f:
            f.write("import os\nX = os.environ['HOME']\n")
        proc = subprocess.run(
            [sys.executable, "-m", "repro.verify", "lint", path],
            capture_output=True, text=True,
            env={**os.environ, "PYTHONPATH": os.path.join(SRC, "..")},
        )
    assert proc.returncode == 1
    assert "ENV001" in proc.stdout


# ---------------------------------------------------------------------------
# ENV001


def test_env_access_flagged():
    findings = _lint_snippet(
        """
        import os
        A = os.environ.get("REPRO_X")
        B = os.getenv("REPRO_Y")
        """
    )
    assert _codes(findings) == ["ENV001", "ENV001"]
    assert findings[0].line == 3


def test_env_home_and_allowlist_exempt():
    code = "import os\nX = os.environ.get('REPRO_X')\n"
    assert _lint_snippet(code, "sched/config.py") == []
    assert _lint_snippet(code, "launch/dryrun.py") == []
    # the allowlist is exact paths, not whole directories
    assert _codes(_lint_snippet(code, "launch/other.py")) == ["ENV001"]


# ---------------------------------------------------------------------------
# RND001


def test_global_numpy_random_flagged():
    findings = _lint_snippet(
        """
        import numpy as np
        x = np.random.rand(3)
        y = np.random.normal(0.0, 1.0)
        rng = np.random.default_rng()
        """
    )
    assert _codes(findings) == ["RND001", "RND001", "RND001"]


def test_seeded_generator_clean():
    findings = _lint_snippet(
        """
        import numpy as np
        rng = np.random.default_rng(1234)
        x = rng.normal(0.0, 1.0)
        """
    )
    assert findings == []


# ---------------------------------------------------------------------------
# TIME001


def test_wall_clock_reads_flagged():
    findings = _lint_snippet(
        """
        import time
        from datetime import datetime
        t0 = time.time()
        d = datetime.now()
        u = datetime.utcnow()
        """
    )
    assert _codes(findings) == ["TIME001", "TIME001", "TIME001"]


def test_launch_tree_may_read_wall_clock():
    code = "import time\nt0 = time.time()\n"
    assert _lint_snippet(code, "launch/run.py") == []
    # perf_counter is fine anywhere: it is not a wall-clock timestamp
    assert _lint_snippet("import time\nt = time.perf_counter()\n") == []


# ---------------------------------------------------------------------------
# SYNC001


def test_item_in_jitted_function_flagged():
    findings = _lint_snippet(
        """
        import jax

        @jax.jit
        def step(x):
            return x.sum().item()
        """,
        "core/backend.py",
    )
    assert _codes(findings) == ["SYNC001"]


def test_float_on_traced_value_in_jit_wrapped_name_flagged():
    findings = _lint_snippet(
        """
        import jax

        def episode(x):
            return float(x[0]) + float(1.0)

        run = jax.jit(episode)
        """,
        "core/episode.py",
    )
    # float(x[0]) flagged; float(1.0) is a constant, not a sync
    assert _codes(findings) == ["SYNC001"]


def test_sync_rule_scoped_to_jitted_paths():
    code = """
        import jax

        @jax.jit
        def step(x):
            return x.sum().item()
        """
    # same smell outside backend.py/episode.py: other files run eagerly
    assert _lint_snippet(code, "core/other.py") == []


def test_unjitted_host_sync_is_fine():
    findings = _lint_snippet(
        """
        def summarize(arr):
            return float(arr.sum()), arr.max().item()
        """,
        "core/backend.py",
    )
    assert findings == []
