"""Hypothesis property tests over random machines x random task graphs:
the simulator's invariants hold for ANY strategy/topology combination."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    DADA,
    DataObject,
    Mode,
    ResourceClass,
    TaskGraph,
    make_machine,
    make_strategy,
    run_simulation,
)


def _random_graph(rng: np.random.Generator, n_tasks: int, n_data: int) -> TaskGraph:
    g = TaskGraph()
    datas = [DataObject(f"d{i}", int(rng.integers(1, 10_000))) for i in range(n_data)]
    for _ in range(n_tasks):
        k = int(rng.integers(1, min(4, n_data + 1)))
        picks = rng.choice(n_data, size=k, replace=False)
        accesses = []
        for i, di in enumerate(picks):
            mode = Mode.RW if i == 0 else (Mode.R if rng.random() < 0.7 else Mode.W)
            accesses.append((datas[di], mode))
        g.add_task("gemm", accesses, flops=float(rng.uniform(1e8, 1e10)))
    return g


def _random_machine(rng: np.random.Generator):
    m = int(rng.integers(1, 6))
    k = int(rng.integers(0, 5))
    cpu = ResourceClass("cpu", {}, default_rate=float(rng.uniform(5e9, 2e10)))
    gpu = ResourceClass("gpu", {}, default_rate=float(rng.uniform(5e10, 5e11)))
    return make_machine(
        n_cpus=m + k, n_gpus=k, cpu_class=cpu, gpu_class=gpu,
        pcie_bandwidth=float(rng.uniform(1e9, 2e10)), gpu_pins_cpu=True,
    )


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from(["heft", "ws", "dual"]))
def test_invariants_hold_on_random_instances(seed, strat_name):
    rng = np.random.default_rng(seed)
    g = _random_graph(rng, n_tasks=int(rng.integers(3, 40)), n_data=int(rng.integers(2, 10)))
    machine = _random_machine(rng)
    strat = make_strategy(strat_name) if strat_name != "dada" else DADA(alpha=0.5)
    res = run_simulation(g, machine, strat, seed=seed, noise=0.0)
    # 1. every task exactly once
    assert sorted(iv.tid for iv in res.intervals) == list(range(len(g)))
    # 2. precedence respected
    end = {iv.tid: iv.end for iv in res.intervals}
    start = {iv.tid: iv.start for iv in res.intervals}
    for t in g.tasks:
        for p in g.pred[t.tid]:
            assert end[p] <= start[t.tid] + 1e-9
    # 3. no worker double-booked
    per = {}
    for iv in res.intervals:
        per.setdefault(iv.rid, []).append((iv.start, iv.end))
    for ivs in per.values():
        ivs.sort()
        for (s1, e1), (s2, e2) in zip(ivs, ivs[1:]):
            assert e1 <= s2 + 1e-9
    # 4. transfers only when accelerators exist
    if not machine.gpus:
        assert res.total_bytes == 0
    # 5. makespan bounded below by best-case critical path
    classes = machine.classes()
    lb = g.critical_path_length(
        lambda t: min(c.exec_time(t.kind, t.flops) for c in classes)
    )
    assert res.makespan >= lb * (1 - 1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.floats(0.0, 1.0))
def test_dada_handles_any_machine(seed, alpha):
    rng = np.random.default_rng(seed)
    g = _random_graph(rng, 20, 6)
    machine = _random_machine(rng)
    res = run_simulation(g, machine, DADA(alpha=alpha), seed=seed)
    assert len(res.intervals) == len(g)
    assert res.makespan > 0


def test_history_model_calibrates():
    """§2.3: the runtime corrects wrong initial predictions — after a run
    the history model's prediction matches observed (noisy) reality."""
    from repro.core import HistoryPerfModel, Simulator
    from repro.configs.paper_machine import paper_machine
    from repro.linalg.cholesky import cholesky_graph

    g = cholesky_graph(8, 512, with_fns=False)
    machine = paper_machine(4)
    strat = make_strategy("heft")
    sim = Simulator(g, machine, strat, seed=0, noise=0.1)
    sim.run()
    gpu_cls = machine.gpus[0].cls
    gemm = next(t for t in g.tasks if t.kind == "gemm")
    pred = sim.model.predict(gemm, gpu_cls)
    true = gpu_cls.exec_time("gemm", gemm.flops)
    assert abs(pred - true) / true < 0.1  # converged within noise level
    assert sim.model.n_observations() == len(g)
