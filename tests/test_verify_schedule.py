"""Independent schedule verifier (repro.verify) against both engines.

Every audited run of the exact engine — across strategies, capacity
pressure, cancel-stale, multi-graph streaming and GPU churn — must
verify with zero errors, the audit instrumentation must be a bit-level
no-op on the schedule itself, and the JSONL round-trip must preserve
the verdict. The surrogate engine's ``emit_schedule`` leg gets the same
treatment through ``episode_audit_logs``.
"""
import os
import tempfile

import pytest

from repro.configs.paper_machine import paper_machine
from repro.core.simulator import Simulator
from repro.linalg.cholesky import cholesky_graph
from repro.sched import resolve
from repro.verify import errors, verify_audit
from repro.verify.audit import AuditLog

MB = 1024 * 1024


def _graph(nt=8):
    return cholesky_graph(nt, 256, with_fns=False)


def _fp(res):
    return (
        res.makespan,
        res.total_bytes,
        tuple((iv.tid, iv.rid, iv.start, iv.end) for iv in res.intervals),
    )


def _audited(spec="heft", nt=8, n=4, **kw):
    sim = Simulator(
        _graph(nt), paper_machine(n), resolve(spec), seed=0, noise=0.0,
        audit=True, **kw,
    )
    sim.run()
    return sim


# ---------------------------------------------------------------------------
# clean schedules verify clean


@pytest.mark.parametrize("spec", ["heft", "dada?alpha=0.5&use_cp=1", "ws"])
def test_exact_strategies_verify_clean(spec):
    sim = _audited(spec)
    findings = verify_audit(sim.audit)
    assert errors(findings) == []


@pytest.mark.parametrize(
    "capacity,eviction",
    [(64 * MB, "affinity"), (32 * MB, "lru")],
)
def test_capacity_bounded_verifies_clean(capacity, eviction):
    sim = _audited(
        "dada?alpha=0.5&use_cp=1", nt=10,
        mem_capacity=capacity, eviction=eviction,
    )
    findings = verify_audit(sim.audit)
    assert errors(findings) == []


def test_cancel_stale_verifies_clean_with_no_stale_warnings():
    sim = _audited("heft", cancel_stale=True)
    findings = verify_audit(sim.audit)
    assert errors(findings) == []
    # cancel-stale on: stale reads are impossible, so even warnings vanish
    assert not [f for f in findings if f.code == "STALE_READ"]


@pytest.mark.parametrize("mode", ["drain", "kill"])
def test_churned_runs_verify_clean(mode):
    sim = _audited("heft", churn=150.0, fault_mode=mode)
    assert sim.faults.history, "churn produced no events; raise the rate"
    assert errors(verify_audit(sim.audit)) == []


def test_flaky_runs_verify_clean():
    sim = _audited("heft", link_flake=0.35, retry_max=2, backoff_s=1e-4)
    assert sim.audit.retries, "flake rate produced no retries; raise it"
    assert errors(verify_audit(sim.audit)) == []


@pytest.mark.parametrize(
    "spec", ["heft", "dada?alpha=0.5&use_cp=1&recover=1"]
)
def test_noticed_churn_verifies_clean(spec):
    sim = _audited(spec, churn=250.0, fault_mode="drain", notice_s=0.004)
    assert sim.audit.notices, "churn produced no notices; raise the rate"
    assert errors(verify_audit(sim.audit)) == []


@pytest.mark.parametrize("mode", ["drain", "kill"])
def test_scripted_faults_verify_clean(mode):
    graph = _graph()
    base = Simulator(
        graph, paper_machine(4), resolve("heft"), seed=0, noise=0.0
    ).run()
    sim = Simulator(
        graph, paper_machine(4), resolve("heft"), seed=0, noise=0.0,
        audit=True,
    )
    gpus = [r.rid for r in sim.machine.gpus]
    sim.inject("detach", gpus[0], at=base.makespan * 0.25, mode=mode)
    sim.inject("detach", gpus[1], at=base.makespan * 0.4, mode=mode)
    sim.inject("attach", gpus[0], at=base.makespan * 0.6)
    sim.run()
    assert errors(verify_audit(sim.audit)) == []


def test_multi_graph_stream_verifies_clean():
    from repro.runtime import Engine

    eng = Engine(
        paper_machine(4), resolve("dada?alpha=0.5&use_cp=1"), seed=0,
        noise=0.0, audit=True,
    )
    for k in range(3):
        eng.submit(_graph(6), at=None if k == 0 else 0.002 * k)
    eng.run()
    assert errors(verify_audit(eng.audit)) == []


# ---------------------------------------------------------------------------
# the audit log is observational: bit-identical schedules with it on/off


def test_audit_off_is_bit_identical():
    graph = _graph()
    off = Simulator(
        graph, paper_machine(4), resolve("heft"), seed=3, audit=False
    )
    on = Simulator(
        graph, paper_machine(4), resolve("heft"), seed=3, audit=True
    )
    assert off.audit is None and on.audit is not None
    assert _fp(off.run()) == _fp(on.run())


def test_audit_defaults_off():
    sim = Simulator(_graph(4), paper_machine(2), resolve("heft"), seed=0)
    assert sim.audit is None


# ---------------------------------------------------------------------------
# JSONL round-trip


def test_jsonl_roundtrip_preserves_verdict():
    sim = _audited(
        "dada?alpha=0.5&use_cp=1", mem_capacity=64 * MB, eviction="affinity",
        churn=150.0, fault_mode="kill",
    )
    direct = verify_audit(sim.audit)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "audit.jsonl")
        sim.audit.to_jsonl(path)
        back = AuditLog.from_jsonl(path)
    assert back.engine == "exact"
    assert len(back.execs) == len(sim.audit.execs)
    assert len(back.hops) == len(sim.audit.hops)
    replayed = verify_audit(back)
    assert [(f.code, f.severity) for f in replayed] == [
        (f.code, f.severity) for f in direct
    ]
    assert errors(replayed) == []


def test_jsonl_roundtrip_preserves_recovery_records():
    sim = _audited(
        "heft", churn=200.0, fault_mode="kill", notice_s=0.004,
        link_flake=0.3, retry_max=2, backoff_s=1e-4,
    )
    assert sim.audit.notices and sim.audit.retries, (
        "base run too quiet for a recovery round-trip; raise churn/flake"
    )
    direct = verify_audit(sim.audit)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "audit.jsonl")
        sim.audit.to_jsonl(path)
        back = AuditLog.from_jsonl(path)
    assert len(back.notices) == len(sim.audit.notices)
    assert len(back.retries) == len(sim.audit.retries)
    assert len(back.timeouts) == len(sim.audit.timeouts)
    assert back.notices[0] == sim.audit.notices[0]
    assert back.retries[0] == sim.audit.retries[0]
    replayed = verify_audit(back)
    assert [(f.code, f.severity) for f in replayed] == [
        (f.code, f.severity) for f in direct
    ]
    assert errors(replayed) == []


def test_jsonl_rejects_schema_drift():
    sim = _audited(nt=4, n=2)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "audit.jsonl")
        sim.audit.to_jsonl(path)
        lines = open(path).read().splitlines()
        bad = lines[0].replace('"schema": 1', '"schema": 99')
        with open(path, "w") as f:
            f.write("\n".join([bad] + lines[1:]))
        with pytest.raises(ValueError, match="audit.jsonl:1"):
            AuditLog.from_jsonl(path)


# ---------------------------------------------------------------------------
# run_simulation integration: REPRO_SCHED_AUDIT wires verification in


def test_run_simulation_verifies_under_audit_config():
    from repro.core import run_simulation
    from repro.sched.config import SchedConfig

    res = run_simulation(
        _graph(6), paper_machine(4), resolve("heft"), seed=0,
        config=SchedConfig(audit=True),
    )
    assert res.makespan > 0


# ---------------------------------------------------------------------------
# surrogate engine (emit_schedule leg)


def _surrogate_out(specs, emit):
    import numpy as np

    from repro.core import episode as ep

    machine = paper_machine(4)
    graph = _graph(6)
    max_mem = max(r.mem for r in machine.resources if r.is_accelerator)
    plan = ep.build_plan(graph, machine, n_u=max_mem + 2)
    ig, vl, mc, lg = ep.machine_axes(machine, plan.n_res)
    params = [ep.surrogate_params(s) for s in specs]
    B = len(specs)
    batch = ep.EpisodeBatch(
        is_gpu=np.stack([ig] * B), valid_res=np.stack([vl] * B),
        mem_col=np.stack([mc] * B), link_grp=np.stack([lg] * B),
        alpha=np.array([p[0] for p in params]),
        use_cp=np.array([p[1] for p in params]),
        ws_pref=np.array([p[2] for p in params], dtype=bool),
        noise=np.stack([ep.noise_factors(0, 0.0, plan.n, plan.n_pad)] * B),
        cap=np.full(B, np.inf),
    )
    return graph, batch, ep.run_episodes(plan, batch, emit_schedule=emit)


def test_surrogate_schedules_verify_clean():
    pytest.importorskip("jax")
    from repro.core import episode as ep

    specs = ("heft", "dada?alpha=0.5&use_cp=1", "ws")
    graph, batch, out = _surrogate_out(specs, emit=True)
    logs = ep.episode_audit_logs(graph, batch, out)
    assert len(logs) == len(specs)
    for spec, log in zip(specs, logs):
        assert log.engine == "surrogate"
        assert errors(verify_audit(log)) == [], spec


def test_emit_schedule_does_not_perturb_results():
    pytest.importorskip("jax")
    specs = ("heft", "ws")
    _, _, plain = _surrogate_out(specs, emit=False)
    _, _, emitted = _surrogate_out(specs, emit=True)
    assert "schedule" not in plain and "schedule" in emitted
    import numpy as np

    np.testing.assert_array_equal(plain["makespan"], emitted["makespan"])
    np.testing.assert_array_equal(plain["total_bytes"], emitted["total_bytes"])
    np.testing.assert_array_equal(plain["n_placed"], emitted["n_placed"])
