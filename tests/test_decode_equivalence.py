"""Incremental decode must reproduce the full forward pass exactly.

This exercises every cache type end-to-end: GQA KV (grouped decode einsum +
masked-select writes), MLA latent caches, Mamba conv+SSM states, and
mLSTM/sLSTM recurrent states. MoE archs use a generous capacity factor so
token dropping cannot differ between the two paths.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import smoke_config
from repro.models.transformer import cache_init, forward, init_params

ARCHS = ["granite-8b", "chatglm3-6b", "minicpm3-4b", "jamba-v0.1-52b", "xlstm-1.3b"]
S = 24
B = 2


@pytest.mark.parametrize("arch", ARCHS)
def test_incremental_decode_matches_forward(arch):
    cfg = smoke_config(arch)
    if cfg.moe is not None:
        cfg = cfg.scaled(moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    cfg = cfg.scaled(remat=False, compute_dtype="float32", param_dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, (B, S)), jnp.int32
    )

    full_logits, _, _ = forward(params, cfg, tokens)

    cache = cache_init(cfg, B, S)
    step = jax.jit(
        lambda p, c, t, pos: forward(p, cfg, t, cache=c, cache_pos=pos)[:2]
    )
    errs = []
    for i in range(S):
        logits_i, cache = step(params, cache, tokens[:, i : i + 1], jnp.int32(i))
        errs.append(
            float(jnp.abs(logits_i[:, 0] - full_logits[:, i]).max())
        )
    scale = float(jnp.abs(full_logits).max())
    assert max(errs) < 2e-3 * max(scale, 1.0), (
        f"{arch}: decode/forward divergence {max(errs):.2e} (scale {scale:.1f})"
    )
