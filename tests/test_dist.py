"""Distribution-layer tests: sharding rules, DADA expert placement, layer
partitioning, elastic re-planning, gradient compression, stragglers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.registry import get_config
from repro.dist.elastic import choose_mesh_shape, replan
from repro.dist.sched_bridge import (
    expected_a2a_fraction,
    partition_layers,
    plan_expert_placement,
    stage_loads,
)
from repro.dist.straggler import StragglerPlanner
from repro.optim.compression import (
    compress_with_error_feedback,
    ef_state_init,
    quantize_int8,
    dequantize_int8,
)


# ---------------------------------------------------------------------------
# expert placement
def test_expert_placement_balanced_capacity():
    rng = np.random.default_rng(0)
    mass = rng.pareto(1.5, size=64) * 1000
    pl = plan_expert_placement(mass, 8)
    counts = np.bincount(pl.assignment, minlength=8)
    assert (counts == 8).all()  # exact capacity per group
    # permutation is a bijection
    assert sorted(pl.perm.tolist()) == list(range(64))
    assert (pl.perm[pl.inv_perm] == np.arange(64)).all()


def test_expert_placement_balances_load():
    rng = np.random.default_rng(1)
    mass = rng.pareto(1.0, size=32) * 100 + 1
    pl = plan_expert_placement(mass, 4)
    naive = np.array([mass[g::4].sum() for g in range(4)])  # round robin
    assert pl.group_load.max() <= naive.max() * 1.05


def test_expert_placement_affinity_minimizes_movement():
    """Re-planning with mildly-changed load should keep most experts where
    their weights already are (the paper's affinity criterion)."""
    rng = np.random.default_rng(2)
    mass = rng.uniform(10, 20, size=64)  # near-uniform load
    first = plan_expert_placement(mass, 8)
    mass2 = mass * rng.uniform(0.95, 1.05, size=64)
    second = plan_expert_placement(
        mass2, 8, prev_assignment=first.assignment, alpha=1.0
    )
    assert second.moved_experts <= 16  # most of 64 stay put
    fresh = plan_expert_placement(mass2, 8, prev_assignment=None, alpha=0.0)
    moved_fresh = int((fresh.assignment != first.assignment).sum())
    assert second.moved_experts <= moved_fresh


def test_a2a_fraction_drops_with_affinity_placement():
    """Tokens co-located with their favourite experts avoid the all-to-all;
    DADA placement from per-source routing mass should beat round-robin."""
    rng = np.random.default_rng(3)
    G, E = 4, 32
    by_source = rng.pareto(1.0, size=(G, E)) * 10
    # each source group heavily uses a random disjoint expert subset that is
    # NOT aligned with round-robin order
    perm = rng.permutation(E)
    for g in range(G):
        mine = perm[g * (E // G) : (g + 1) * (E // G)]
        by_source[g, mine] *= 20
    total_mass = by_source.sum(axis=0)
    rr = np.arange(E) % G  # round robin
    frac_rr = expected_a2a_fraction(by_source, rr)
    # affinity-aware: residency prior = dominant source group per expert
    dominant = by_source.argmax(axis=0)
    pl = plan_expert_placement(total_mass, G, prev_assignment=dominant, alpha=1.0)
    frac_dada = expected_a2a_fraction(by_source, pl.assignment)
    assert frac_dada < frac_rr


# ---------------------------------------------------------------------------
# layer partitioning (dual approximation)
def test_partition_layers_balanced():
    costs = [1.0] * 16
    starts = partition_layers(costs, 4)
    assert starts == [0, 4, 8, 12]
    loads = stage_loads(costs, starts)
    assert max(loads) == 4.0


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(0.1, 10.0), min_size=4, max_size=40), st.integers(2, 6))
def test_partition_layers_dual_approx_bound(costs, k):
    starts = partition_layers(costs, k)
    loads = stage_loads(costs, starts)
    # classic bound for chains-on-chains dual approximation
    opt_lb = max(max(costs), sum(costs) / k)
    assert max(loads) <= 2.0 * opt_lb + 1e-9
    assert len(starts) == k
    assert starts[0] == 0 and all(a <= b for a, b in zip(starts, starts[1:]))


# ---------------------------------------------------------------------------
# elastic
def test_choose_mesh_shape():
    assert choose_mesh_shape(512) == (32, 16)
    assert choose_mesh_shape(256) == (16, 16)
    assert choose_mesh_shape(300) == (16, 16)  # degraded pod
    assert choose_mesh_shape(17) == (1, 16)


def test_replan_after_failure_keeps_surviving_experts():
    mass = np.ones(64)
    plan0 = replan(256, n_experts=64, routing_mass=mass)
    assert plan0.mesh_shape == (16, 16)
    # lose 128 devices -> (8, 16): same 16 groups, placement may persist
    plan1 = replan(
        128, n_experts=64, routing_mass=mass,
        prev_assignment=plan0.placement.assignment,
    )
    assert plan1.mesh_shape == (8, 16)
    moved = int((plan1.placement.assignment != plan0.placement.assignment).sum())
    assert moved <= 32  # affinity keeps the majority in place


# ---------------------------------------------------------------------------
# compression
def test_quantize_roundtrip_bounded_error():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(1000), jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x).max()
    assert float(err) <= float(s) * 0.5 + 1e-6


def test_error_feedback_removes_bias():
    """Accumulated compressed gradients converge to accumulated true
    gradients (error feedback's defining property)."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.standard_normal((64,)), jnp.float32) * 1e-3
    ef = ef_state_init({"w": g_true})["w"]
    acc_c, acc_t = jnp.zeros(64), jnp.zeros(64)
    state = {"w": ef}
    for _ in range(50):
        comp, state = compress_with_error_feedback({"w": g_true}, state)
        acc_c = acc_c + comp["w"]
        acc_t = acc_t + g_true
    rel = float(jnp.linalg.norm(acc_c - acc_t) / jnp.linalg.norm(acc_t))
    assert rel < 0.05


# ---------------------------------------------------------------------------
# stragglers
def test_straggler_planner_shifts_work():
    p = StragglerPlanner(n_shards=4, total_microbatches=32)
    plan = p.plan()
    assert plan.sum() == 32 and (plan == 8).all()
    # shard 3 is 4x slower
    times = np.array([1.0, 1.0, 1.0, 4.0]) * plan
    p.observe(times, plan)
    plan2 = p.plan()
    assert plan2.sum() == 32
    assert plan2[3] < 8  # slow shard sheds work
    assert p.expected_makespan(plan2) < p.expected_makespan(plan) * 0.95


def test_straggler_zero_cost_microbatches_do_not_blow_up():
    """A shard reporting ~zero time per micro-batch (cache artifact,
    clock skew) must not swallow the whole budget or divide by zero."""
    p = StragglerPlanner(n_shards=3, total_microbatches=12)
    plan = p.plan()
    p.observe(np.array([0.0, 4.0, 4.0]), plan)
    plan2 = p.plan()
    assert plan2.sum() == 12
    assert (plan2 >= 1).all()  # the others still get their minimum
    assert np.isfinite(p.expected_makespan(plan2))


def test_straggler_single_surviving_shard_takes_everything():
    p = StragglerPlanner(n_shards=3, total_microbatches=9)
    p.deactivate(0)
    p.deactivate(2)
    assert p.plan().tolist() == [0, 9, 0]
    with pytest.raises(ValueError, match="last active"):
        p.deactivate(1)
    assert p.active.tolist() == [False, True, False]  # state unchanged
    p.reactivate(0)
    plan = p.plan()
    assert plan[2] == 0 and plan.sum() == 9 and plan[0] >= 1


def test_straggler_deactivated_shard_cost_freezes():
    """EMA stops updating for a shard that reports nothing (plan == 0):
    on reactivation it resumes from its last observed cost, not from a
    corrupted one."""
    p = StragglerPlanner(n_shards=3, total_microbatches=12, ema=1.0)
    plan = p.plan()
    p.observe(np.array([1.0, 8.0, 1.0]) / 12 * 3 * plan, plan)
    slow_cost = p._cost[1]
    p.deactivate(1)
    for _ in range(3):
        plan = p.plan()
        assert plan[1] == 0
        # a dead shard reports zero time: must not be taken as "fast"
        times = plan * np.array([0.5, 0.0, 0.5])
        p.observe(times, plan)
    assert p._cost[1] == slow_cost  # frozen through the outage
    p.reactivate(1)
    plan = p.plan()
    assert plan[1] >= 1
    assert plan[1] < plan[0]  # still remembered as the straggler


def test_straggler_ema_when_observations_stop_mid_run():
    """With partial EMA weight, shards that keep reporting converge while
    a silent shard's estimate stays put."""
    p = StragglerPlanner(n_shards=2, total_microbatches=8, ema=0.5)
    plan = p.plan()
    p.observe(np.array([2.0, 2.0]) * plan / 4, plan)
    frozen = p._cost[1]
    for _ in range(5):
        p.observe(np.array([1.0 * plan[0], 0.0]), np.array([plan[0], 0]))
    assert p._cost[1] == frozen
    assert p._cost[0] != frozen  # the reporting shard kept calibrating


def test_straggler_total_must_cover_active_shards():
    p = StragglerPlanner(n_shards=4, total_microbatches=4)
    assert p.plan().tolist() == [1, 1, 1, 1]
    with pytest.raises(ValueError, match="shard 7 out of range"):
        p.deactivate(7)
    p.deactivate(3)
    plan = p.plan()  # 4 micro-batches over 3 shards still fine
    assert plan.sum() == 4 and plan[3] == 0
