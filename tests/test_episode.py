"""Surrogate episode engine (``REPRO_SCHED_EXACT=0``) vs the exact oracle.

Correctness contract of :mod:`repro.core.episode` is *ranking fidelity*,
not bit-equality: on paper-size traces the surrogate must order the
strategies (makespan and transferred bytes) the way the exact engine
does, for every pair the oracle separates by a clear margin. On top of
that, the padded/batched episode must be provably insensitive to its own
padding: batch-axis permutations, batch padding (``pad_to``) and step
padding (``extra_steps``) are bit-level no-ops.
"""
import dataclasses
from functools import partial

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from _hypothesis_compat import given, settings, st

from repro.configs.paper_machine import paper_machine
from repro.core import cached_graph, run_batch, run_simulation
from repro.core import episode as ep
from repro.linalg.cholesky import cholesky_graph
from repro.linalg.lu import lu_graph
from repro.linalg.qr import qr_graph
from repro.sched import resolve
from repro.sched.config import SchedConfig

CFG = SchedConfig(backend="jax")

SPECS = ("heft", "ws", "dada?alpha=0", "dada?alpha=0.5&use_cp=1")
N_SEEDS = 20
SEEDS = tuple(1234 + i for i in range(N_SEEDS))
NOISE = 0.03
# a pair of strategies counts as "separated" when the oracle's means
# differ by more than this fraction — closer pairs are near-ties
# (cf. C4: HEFT vs dual on QR) whose order sits inside the surrogate's
# documented ~±10% relative error and is not part of the contract
MARGIN = 0.10

KERNELS = {
    "cholesky": cholesky_graph,
    "lu": lu_graph,
    "qr": qr_graph,
}


def _graph(kernel: str, nt: int):
    return cached_graph(partial(KERNELS[kernel], nt, 256, with_fns=False))


def _oracle_means(graph, machine):
    """Mean (makespan, total_bytes) per spec through the exact engine."""
    out = {}
    for spec in SPECS:
        mks, gbs = [], []
        for seed in SEEDS:
            r = run_simulation(
                graph, machine, resolve(spec), seed=seed, noise=NOISE
            )
            mks.append(r.makespan)
            gbs.append(r.total_bytes)
        out[spec] = (float(np.mean(mks)), float(np.mean(gbs)))
    return out


def _surrogate_means(graph, machine):
    items = [
        {"graph": graph, "machine": machine, "strategy": spec,
         "seed": seed, "noise": NOISE}
        for spec in SPECS
        for seed in SEEDS
    ]
    results = run_batch(items, config=CFG)
    out = {}
    for k, spec in enumerate(SPECS):
        rs = results[k * N_SEEDS : (k + 1) * N_SEEDS]
        assert all(r.strategy == spec for r in rs)
        out[spec] = (
            float(np.mean([r.makespan for r in rs])),
            float(np.mean([r.total_bytes for r in rs])),
        )
    return out


def _assert_separated_pairs_ordered_alike(
    oracle, surrogate, axis, label, specs=SPECS
):
    """Every pair the oracle clearly separates, the surrogate orders the
    same way; oracle near-ties impose nothing."""
    for i, a in enumerate(specs):
        for b in specs[i + 1:]:
            oa, ob = oracle[a][axis], oracle[b][axis]
            if abs(oa - ob) <= MARGIN * max(abs(oa), abs(ob)):
                continue
            sa, sb = surrogate[a][axis], surrogate[b][axis]
            assert (oa < ob) == (sa < sb), (
                f"{label}: oracle orders {a} vs {b} as "
                f"{oa:.4g} vs {ob:.4g} but surrogate says "
                f"{sa:.4g} vs {sb:.4g}"
            )


@pytest.mark.parametrize("kernel", sorted(KERNELS))
@pytest.mark.parametrize("nt", [8, 16])
def test_ranking_fidelity(kernel, nt):
    """Strategy orderings (makespan and bytes) survive the surrogate on
    paper-size traces, at a transfer-light and a transfer-heavy machine
    shape, across 20 seeds."""
    graph = _graph(kernel, nt)
    # both machine shapes at the cheap size; the paper shape runs at the
    # transfer-heavy 8-GPU box only (the oracle side is 20 Python sims
    # per strategy, and the 2-GPU orderings are already pinned at NT=8)
    for n_gpus in (2, 8) if nt == 8 else (8,):
        machine = paper_machine(n_gpus)
        oracle = _oracle_means(graph, machine)
        surrogate = _surrogate_means(graph, machine)
        tag = f"{kernel} nt={nt} gpus={n_gpus}"
        _assert_separated_pairs_ordered_alike(
            oracle, surrogate, 0, f"{tag} makespan"
        )
        # bytes ordering is asserted over the affinity family only: blind
        # work stealing's transfer volume in the oracle comes from
        # randomized victim churn, which a deterministic surrogate cannot
        # (and need not) reproduce — the contract for ws is its makespan
        # spread, checked above and below
        _assert_separated_pairs_ordered_alike(
            oracle, surrogate, 1, f"{tag} bytes",
            specs=tuple(s for s in SPECS if s != "ws"),
        )
        # blind work stealing is the paper's known-bad baseline: the
        # surrogate must reproduce it as the clear makespan loser
        worst = max(SPECS, key=lambda s: surrogate[s][0])
        assert worst == "ws", f"{tag}: surrogate worst is {worst}, not ws"


# ---------------------------------------------------------------------------
# invariance properties: padding and batch order are bit-level no-ops


def _small_setup():
    graph = _graph("cholesky", 4)
    machine = paper_machine(2)
    plan = ep.build_plan(graph, machine, n_u=3)
    isg, val, mc, lg = ep.machine_axes(machine, plan.n_res)
    rows = [
        ("heft", 1), ("ws", 2), ("dada?alpha=0", 3),
        ("dada?alpha=0.5&use_cp=1", 4), ("dada?alpha=1", 5),
    ]
    B = len(rows)
    params = [ep.surrogate_params(s) for s, _ in rows]
    batch = ep.EpisodeBatch(
        is_gpu=np.stack([isg] * B),
        valid_res=np.stack([val] * B),
        mem_col=np.stack([mc] * B),
        link_grp=np.stack([lg] * B),
        alpha=np.array([p[0] for p in params]),
        use_cp=np.array([p[1] for p in params]),
        ws_pref=np.array([p[2] for p in params], dtype=bool),
        noise=np.stack(
            [ep.noise_factors(sd, NOISE, plan.n, plan.n_pad) for _, sd in rows]
        ),
        cap=np.full(B, np.inf),
    )
    return plan, batch


def _take(batch, idx):
    return dataclasses.replace(
        batch,
        **{
            f.name: getattr(batch, f.name)[idx]
            for f in dataclasses.fields(batch)
        },
    )


@pytest.fixture(scope="module")
def small_episode():
    plan, batch = _small_setup()
    base = ep.run_episodes(plan, batch, config=CFG)
    return plan, batch, base


@given(pad_to=st.sampled_from([8, 16, 24]), extra=st.sampled_from([0, 7]))
@settings(max_examples=12, deadline=None)
def test_padding_invariance(small_episode, pad_to, extra):
    """Batch padding and step padding never change any configuration's
    result — padded rows and padded steps are provable no-ops."""
    plan, batch, base = small_episode
    out = ep.run_episodes(
        plan, batch, config=CFG, pad_to=pad_to, extra_steps=extra
    )
    for key in ("makespan", "total_bytes", "n_placed"):
        np.testing.assert_array_equal(out[key], base[key], err_msg=key)


@given(perm=st.permutations(list(range(5))))
@settings(max_examples=12, deadline=None)
def test_batch_permutation_invariance(small_episode, perm):
    """Row order on the batch axis is irrelevant: configurations don't
    interact."""
    plan, batch, base = small_episode
    idx = np.array(perm)
    out = ep.run_episodes(plan, _take(batch, idx), config=CFG)
    for key in ("makespan", "total_bytes", "n_placed"):
        np.testing.assert_array_equal(out[key], base[key][idx], err_msg=key)


def test_every_task_placed(small_episode):
    plan, _, base = small_episode
    assert (base["n_placed"] == plan.n).all()


# ---------------------------------------------------------------------------
# engine plumbing


def test_run_batch_preserves_input_order():
    graph = _graph("cholesky", 4)
    m2, m4 = paper_machine(2), paper_machine(4)
    # interleave machines and strategies: grouping must not leak into
    # result order
    items = [
        {"graph": graph, "machine": m, "strategy": s, "seed": sd,
         "noise": NOISE}
        for sd in (1, 2)
        for m in (m2, m4)
        for s in ("heft", "dada?alpha=0.5")
    ]
    fwd = run_batch(items, config=CFG)
    rev = run_batch(list(reversed(items)), config=CFG)
    for a, b in zip(fwd, reversed(rev)):
        assert a.strategy == b.strategy and a.seed == b.seed
        assert a.makespan == b.makespan
        assert a.total_bytes == b.total_bytes


def test_pallas_route_matches_jnp():
    """REPRO_SCHED_PALLAS=1 routes the episode's transfer rows through the
    Pallas CSR kernel (interpret mode on CPU) with identical results."""
    plan, batch = _small_setup()
    off = ep.run_episodes(
        plan, batch, config=dataclasses.replace(CFG, pallas="0")
    )
    on = ep.run_episodes(
        plan, batch, config=dataclasses.replace(CFG, pallas="1")
    )
    np.testing.assert_allclose(on["makespan"], off["makespan"], rtol=1e-6)
    np.testing.assert_array_equal(on["n_placed"], off["n_placed"])
    np.testing.assert_allclose(
        on["total_bytes"], off["total_bytes"], rtol=1e-6
    )


def test_capacity_axis_adds_traffic():
    """A tight device-memory cap can only add transferred bytes (eviction
    write-backs and re-fetches), never remove them."""
    graph = _graph("cholesky", 8)
    machine = paper_machine(2)
    items = [
        {"graph": graph, "machine": machine, "strategy": "dada?alpha=0.5",
         "seed": 7, "noise": NOISE, "capacity": cap}
        for cap in (0, 8 * 1024 * 1024)
    ]
    unbounded, bounded = run_batch(items, config=CFG)
    assert bounded.total_bytes >= unbounded.total_bytes
    assert np.isfinite(bounded.makespan)


def test_surrogate_params_rejects_unmapped_policies():
    with pytest.raises(ValueError, match="surrogate"):
        ep.surrogate_params("random")


def test_exact_knob_validation():
    """REPRO_SCHED_EXACT=0 demands the jax backend; malformed surrogate
    knobs fail loudly."""
    with pytest.raises(ValueError, match="REPRO_SCHED_BACKEND"):
        SchedConfig(backend="numpy", exact=False)
    with pytest.raises(ValueError, match="REPRO_SCHED_BATCH"):
        SchedConfig.from_env({"REPRO_SCHED_BATCH": "0"})
    with pytest.raises(ValueError, match="REPRO_SCHED_EXACT"):
        SchedConfig.from_env({"REPRO_SCHED_EXACT": "maybe"})
    cfg = SchedConfig.from_env(
        {"REPRO_SCHED_EXACT": "0", "REPRO_SCHED_BACKEND": "jax",
         "REPRO_SCHED_BATCH": "64"}
    )
    assert cfg.exact is False and cfg.batch == 64
