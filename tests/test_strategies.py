"""Strategy-level invariants: HEFT ordering, DADA dual-approximation bound,
affinity behavior, and brute-force optimality comparisons on tiny instances."""
import itertools

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    DADA,
    DataObject,
    HEFT,
    Mode,
    ResourceClass,
    Simulator,
    TaskGraph,
    make_machine,
    run_simulation,
)

CPU = ResourceClass("cpu", {}, default_rate=1e9)
GPU = ResourceClass("gpu", {}, default_rate=10e9)


def _machine(m=2, k=2):
    return make_machine(
        n_cpus=m + k, n_gpus=k, cpu_class=CPU, gpu_class=GPU, gpu_pins_cpu=True
    )


def _independent(flops_list):
    g = TaskGraph()
    for i, f in enumerate(flops_list):
        g.add_task("gemm", [(DataObject(f"d{i}", 0), Mode.RW)], flops=f)
    return g


def _opt_makespan(flops_list, m, k):
    """Brute force: minimal makespan over all assignments (independent
    tasks, per-resource sum of exec times)."""
    best = float("inf")
    n_res = m + k
    times = [
        [
            (CPU if r < m else GPU).exec_time("gemm", f)
            for r in range(n_res)
        ]
        for f in flops_list
    ]
    for assign in itertools.product(range(n_res), repeat=len(flops_list)):
        loads = [0.0] * n_res
        for t, r in enumerate(assign):
            loads[r] += times[t][r]
        best = min(best, max(loads))
    return best


@settings(max_examples=25, deadline=None)
@given(
    st.lists(st.floats(1e8, 5e10), min_size=1, max_size=6),
    st.floats(0.0, 1.0),
)
def test_dada_dual_approximation_bound(flops_list, alpha):
    """Property (paper §3.2): the schedule kept by DADA fits within
    (2+alpha) * lambda of the accepted guess, and the resulting makespan is
    within (2+alpha)*(1+eps) of the true optimum on independent tasks."""
    m, k = 2, 2
    g = _independent(flops_list)
    machine = _machine(m, k)
    strat = DADA(alpha=alpha)
    sim = Simulator(g, machine, strat, seed=0, noise=0.0)
    res = sim.run()
    lam = strat.last_lambda
    bound = (2.0 + alpha) * lam
    assert max(strat.last_loads.values()) <= bound + 1e-9
    opt = _opt_makespan(flops_list, m, k)
    # binary search precision eps_rel=0.01 on lambda
    assert res.makespan <= (2.0 + alpha) * opt * 1.02 + 1e-9
    assert res.makespan >= opt * (1 - 1e-9)


def test_heft_matches_optimal_single_task():
    g = _independent([1e10])
    res = run_simulation(g, _machine(2, 2), "heft", seed=0, noise=0.0)
    assert res.makespan == pytest.approx(GPU.exec_time("gemm", 1e10), rel=1e-6)


def test_heft_prefers_gpu_for_high_speedup():
    g = _independent([1e10, 1e10])
    res = run_simulation(g, _machine(2, 2), "heft", seed=0, noise=0.0)
    rids = {iv.rid for iv in res.intervals}
    machine = _machine(2, 2)
    gpu_ids = {r.rid for r in machine.gpus}
    assert rids <= gpu_ids  # both big tasks land on (distinct) GPUs
    assert len(rids) == 2


def test_heft_near_optimal_small_instances():
    rng = np.random.default_rng(0)
    for _ in range(5):
        fl = list(rng.uniform(1e9, 2e10, size=5))
        g = _independent(fl)
        res = run_simulation(g, _machine(2, 2), "heft", seed=0, noise=0.0)
        opt = _opt_makespan(fl, 2, 2)
        assert res.makespan <= 2.0 * opt + 1e-9  # list-scheduling bound


def test_dada_alpha_zero_is_pure_dual():
    from repro.core.dada import DualApprox

    d = DualApprox()
    assert d.alpha == 0.0
    assert d.name == "dual"


def test_dada_affinity_attracts_task_to_resident_gpu():
    """A task writing data resident on GPU g should be placed on g by the
    affinity phase when alpha is high."""
    g = TaskGraph()
    d = DataObject("d", 10**8)
    e = DataObject("e", 10**8)
    g.add_task("gemm", [(d, Mode.RW)], flops=1e9)  # runs somewhere, writes d
    g.add_task("gemm", [(d, Mode.RW), (e, Mode.R)], flops=1e9)  # affinity to d
    machine = _machine(2, 2)
    strat = DADA(alpha=1.0)
    sim = Simulator(g, machine, strat, seed=0, noise=0.0)
    res = sim.run()
    by_tid = {iv.tid: iv.rid for iv in res.intervals}
    r0 = machine.by_id(by_tid[0])
    r1 = machine.by_id(by_tid[1])
    if r0.is_accelerator:  # affinity only counts accelerator residency
        assert by_tid[1] == by_tid[0]
        # and the second task must not re-transfer d
        assert res.total_bytes <= d.size_bytes + e.size_bytes


def test_invalid_alpha_rejected():
    with pytest.raises(ValueError):
        DADA(alpha=1.5)
    with pytest.raises(ValueError):
        DADA(alpha=-0.1)
