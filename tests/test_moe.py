"""MoE dispatch: routing invariants + chunk-local dispatch equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.models.moe import moe_apply, moe_init


def _setup(E=8, K=2, d=32, ff=64, cf=4.0):
    cfg = MoEConfig(n_experts=E, top_k=K, d_ff=ff, capacity_factor=cf)
    params = moe_init(jax.random.PRNGKey(0), d, cfg, jnp.float32)
    x = jnp.asarray(
        np.random.default_rng(0).standard_normal((4, 16, d)), jnp.float32
    )
    return cfg, params, x


def test_moe_output_finite_and_shaped():
    cfg, params, x = _setup()
    y, aux = moe_apply(params, x, moe_cfg=cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(aux) > 0.0


def test_chunked_dispatch_matches_global():
    """With generous capacity (no drops) the chunk-local dispatch is
    numerically identical to the global sort — only the communication
    pattern changes (the point of the Perf optimization)."""
    cfg, params, x = _setup(cf=8.0)
    y1, _ = moe_apply(params, x, moe_cfg=cfg, n_chunks=1)
    y4, _ = moe_apply(params, x, moe_cfg=cfg, n_chunks=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4), atol=1e-5)


def test_expert_perm_is_pure_relabeling():
    """A permutation of expert ids with permuted weights gives identical
    outputs — placement must not change the math."""
    cfg, params, x = _setup(cf=8.0)
    perm = jnp.asarray(np.random.default_rng(1).permutation(cfg.n_experts))
    # permute expert weights to their new slots: new_w[perm[e]] = w[e]
    inv = jnp.argsort(perm)
    params_p = dict(params)
    for k in ("w_up", "w_gate", "w_down"):
        params_p[k] = params[k][inv]
    y_base, _ = moe_apply(params, x, moe_cfg=cfg)
    y_perm, _ = moe_apply(params_p, x, moe_cfg=cfg, expert_perm=perm)
    np.testing.assert_allclose(np.asarray(y_base), np.asarray(y_perm), atol=1e-5)


def test_capacity_drops_tokens_gracefully():
    cfg, params, x = _setup(cf=0.1)  # brutal capacity: most tokens dropped
    y, aux = moe_apply(params, x, moe_cfg=cfg)
    assert bool(jnp.isfinite(y).all())
    # dropped tokens contribute zeros, so the norm shrinks vs generous cap
    y_full, _ = moe_apply(params, x, moe_cfg=dataclasses.replace(cfg, capacity_factor=8.0))
    assert float(jnp.linalg.norm(y)) < float(jnp.linalg.norm(y_full))


def test_gradients_flow_through_dispatch():
    cfg, params, x = _setup(cf=8.0)

    def loss(p):
        y, aux = moe_apply(p, x, moe_cfg=cfg)
        return (y**2).mean() + aux

    g = jax.grad(loss)(params)
    gn = sum(float(jnp.abs(v).sum()) for v in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
