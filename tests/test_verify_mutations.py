"""Mutation testing of the schedule verifier.

A verifier that never fires is worse than none. Each test here takes a
known-good audit log from a real engine run, injects one class of
corruption (hypothesis picks *which* record), and asserts the verifier
flags it with the right invariant code. Together with
tests/test_verify_schedule.py (clean runs verify clean) this pins both
error directions.
"""
import copy
from functools import lru_cache

import pytest

from _hypothesis_compat import given, settings, st

from repro.configs.paper_machine import paper_machine
from repro.core.simulator import Simulator
from repro.linalg.cholesky import cholesky_graph
from repro.sched import resolve
from repro.verify import errors, verify_audit
from repro.verify.schedule import derive_edges

MB = 1024 * 1024


@lru_cache(maxsize=None)
def _base_log():
    sim = Simulator(
        cholesky_graph(8, 256, with_fns=False), paper_machine(4),
        resolve("heft"), seed=0, noise=0.0, audit=True,
    )
    sim.run()
    assert errors(verify_audit(sim.audit)) == []
    return sim.audit


def _mutant():
    return copy.deepcopy(_base_log())


def _codes(log):
    return {f.code for f in errors(verify_audit(log))}


def _pick(salt, seq):
    assert seq, "no mutation candidates — base log too small"
    return seq[salt % len(seq)]


# ---------------------------------------------------------------------------


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=20, deadline=None)
def test_shifted_start_breaks_precedence(salt):
    log = _mutant()
    preds = derive_edges(log.graphs[0]["tasks"])
    exec_of = {r.tid: r for r in log.execs}
    candidates = [
        (r, exec_of[p].end)
        for r in log.execs
        for p in preds[r.tid]
        if p in exec_of and exec_of[p].end > 1e-6
    ]
    rec, pred_end = _pick(salt, candidates)
    # start the task well before its predecessor completed
    rec.start = pred_end * 0.5 - 1e-3
    assert "PRECEDENCE" in _codes(log)


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=20, deadline=None)
def test_duplicate_exec_breaks_exactly_once(salt):
    log = _mutant()
    rec = _pick(salt, log.execs)
    log.execs.append(copy.deepcopy(rec))
    assert "EXACTLY_ONCE" in _codes(log)


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=20, deadline=None)
def test_dropped_exec_breaks_exactly_once(salt):
    log = _mutant()
    del log.execs[salt % len(log.execs)]
    assert "EXACTLY_ONCE" in _codes(log)


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=20, deadline=None)
def test_shrunk_hop_bytes_break_conservation(salt):
    log = _mutant()
    candidates = [h for h in log.hops if h.nbytes > 1]
    hop = _pick(salt, candidates)
    hop.nbytes //= 2
    assert "BYTES" in _codes(log)


def test_inflated_claimed_total_bytes_breaks_conservation():
    log = _mutant()
    log.result["total_bytes"] += 12345
    assert "BYTES" in _codes(log)


def test_dropped_hop_breaks_transfer_count():
    log = _mutant()
    # keep the byte sum intact but lose one hop record: the n_transfers
    # cross-check must still fire
    assert len(log.hops) >= 2
    victim = log.hops.pop()
    log.hops[0].nbytes += victim.nbytes
    assert "BYTES" in _codes(log)


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=20, deadline=None)
def test_dropped_landing_breaks_data_arrival(salt):
    log = _mutant()
    host = log.machine["host_mem"]
    tasks = log.graphs[0]["tasks"]
    # a read served off-host with no write of that datum into the same
    # memory before the read: removing every landing of (name, mem)
    # leaves the read with no resident copy
    writes_at = {
        (n, r.mem)
        for r in log.execs
        for n, _s, m in tasks[r.tid]
        if "w" in m
    }
    candidates = sorted(
        {
            (n, rec.mem)
            for rec in log.execs
            if rec.mem != host
            for n, _s, m in tasks[rec.tid]
            if m == "r" and (n, rec.mem) not in writes_at
        }
    )
    name, mem = _pick(salt, candidates)
    before = len(log.landings)
    log.landings = [
        ld for ld in log.landings if not (ld.name == name and ld.mem == mem)
    ]
    assert len(log.landings) < before
    assert "DATA_ARRIVAL" in _codes(log)


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=20, deadline=None)
def test_execution_in_dead_window_flagged(salt):
    log = _mutant()
    candidates = [r for r in log.execs if r.start > 1e-6]
    rec = _pick(salt, candidates)
    # fabricate a detach→attach window of rec's resource straddling its
    # recorded start: drain lets in-flight work finish but never *starts*
    # work on a dead resource, so this is illegal in either mode
    log.log_fault(rec.start * 0.9, "detach", rec.rid, "drain")
    log.log_fault(rec.end + 1.0, "attach", rec.rid, None)
    assert "DEAD_WINDOW" in _codes(log)


def test_capacity_overflow_flagged():
    log = _mutant()
    # the unbounded base run moved data freely; claiming a 1-byte device
    # capacity after the fact must trip the high-water check
    assert any(h.nbytes > 1 for h in log.hops)
    log.machine["capacity"] = 1
    assert "CAPACITY" in _codes(log)


@given(st.floats(min_value=1.5, max_value=10.0))
@settings(max_examples=20, deadline=None)
def test_scaled_finish_breaks_makespan(factor):
    log = _mutant()
    log.result["per_graph"][0]["finish"] *= factor
    assert "MAKESPAN" in _codes(log)


# ---------------------------------------------------------------------------
# recovery records: notices, retries, timeouts


@lru_cache(maxsize=None)
def _recovery_log():
    sim = Simulator(
        cholesky_graph(8, 256, with_fns=False), paper_machine(4),
        resolve("heft"), seed=2, noise=0.0, audit=True,
        churn=250.0, fault_mode="drain", notice_s=0.004,
        link_flake=0.35, retry_max=2, backoff_s=1e-4,
    )
    sim.run()
    log = sim.audit
    assert log.notices and log.retries, "recovery base log too quiet"
    assert errors(verify_audit(log)) == []
    return log


def _recovery_mutant():
    return copy.deepcopy(_recovery_log())


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=20, deadline=None)
def test_fabricated_notice_over_exec_flagged(salt):
    log = _mutant()
    rec = _pick(salt, [r for r in log.execs if r.start > 1e-3])
    # a notice opens strictly before rec starts and promises death after
    # rec ends: rec.start now sits inside the grace window
    log.log_notice(rec.start * 0.5, rec.rid, "drain", rec.end + 1.0)
    assert "NOTICE_GRACE" in _codes(log)


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=20, deadline=None)
def test_shifted_start_into_notice_window_flagged(salt):
    from bisect import bisect_right

    log = _recovery_mutant()
    fault_ts = {}
    for f in log.faults:
        fault_ts.setdefault(f.rid, []).append(f.t)
    for ts in fault_ts.values():
        ts.sort()
    candidates = []
    for note in log.notices:
        ts = fault_ts.get(note.rid, [])
        i = bisect_right(ts, note.t)
        end = ts[i] if i < len(ts) else note.death_at
        if end - note.t < 1e-5:
            continue
        for rec in log.execs:
            if rec.rid == note.rid:
                candidates.append((rec, note.t, end))
    rec, t0, t1 = _pick(salt, candidates)
    dur = rec.end - rec.start
    rec.start = 0.5 * (t0 + t1)
    rec.end = rec.start + dur
    assert "NOTICE_GRACE" in _codes(log)


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=20, deadline=None)
def test_dropped_retry_record_flagged(salt):
    log = _recovery_mutant()
    del log.retries[salt % len(log.retries)]
    assert "RETRY_BYTES" in _codes(log)


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=20, deadline=None)
def test_shrunk_retry_record_bytes_flagged(salt):
    log = _recovery_mutant()
    rec = _pick(salt, [r for r in log.retries if r.nbytes > 1])
    # the matching 'retry' hop keeps its size: re-charged traffic no
    # longer reconciles byte-for-byte
    rec.nbytes //= 2
    assert "RETRY_BYTES" in _codes(log)


def test_inflated_claimed_retry_count_flagged():
    log = _recovery_mutant()
    log.result["n_retries"] += 1
    assert "RETRY_BYTES" in _codes(log)


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=20, deadline=None)
def test_missing_landing_after_retry_flagged(salt):
    log = _recovery_mutant()
    rec = _pick(salt, log.retries)
    before = len(log.landings)
    log.landings = [
        ld for ld in log.landings
        if not (
            ld.gid == rec.gid and ld.name == rec.name
            and ld.mem == rec.mem and ld.t >= rec.t - 1e-6
        )
    ]
    assert len(log.landings) < before, "retried transfer never landed?"
    assert "TRANSFER_COMPLETES" in _codes(log)


# ---------------------------------------------------------------------------
# serving logs: the ARRIVAL invariant (arrivals, admission, rejections)


@lru_cache(maxsize=None)
def _serving_log():
    from repro.runtime.load import make_arrivals, run_serving

    # capacity holds the largest single-task working set (the memory
    # layer's floor) but only ~6 MB aggregate: overlapping tenants at
    # this rate force admission-control rejections
    out = run_serving(
        make_arrivals("poisson", 16, rate=200.0, seed=1),
        paper_machine(4), "heft", seed=0,
        admission="reject", mem_capacity=1572864, audit=True,
    )
    log = out["engine"].audit
    assert log.arrivals and log.admits, "serving base log too quiet"
    assert log.rejects, "no rejections — tighten the capacity"
    assert errors(verify_audit(log)) == []
    return log


def _serving_mutant():
    return copy.deepcopy(_serving_log())


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=20, deadline=None)
def test_exec_before_arrival_flagged(salt):
    log = _serving_mutant()
    arrive_at = {r.gid: r.t for r in log.arrivals}
    candidates = [
        r for r in log.execs if arrive_at.get(r.gid, 0.0) > 1e-3
    ]
    rec = _pick(salt, candidates)
    rec.start = arrive_at[rec.gid] * 0.5  # before the tenant even arrived
    assert "ARRIVAL" in _codes(log)


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=20, deadline=None)
def test_exec_before_admit_flagged(salt):
    log = _serving_mutant()
    first_start = {}
    for r in log.execs:
        if r.gid not in first_start or r.start < first_start[r.gid].start:
            first_start[r.gid] = r
    candidates = [
        a for a in log.admits
        if a.gid in first_start and first_start[a.gid].end > a.t + 1e-3
    ]
    admit = _pick(salt, candidates)
    # push the admit record past the graph's first execution: the run
    # now claims work started on a tenant that had not been let in
    admit.t = first_start[admit.gid].start + 1e-4
    assert "ARRIVAL" in _codes(log)


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=20, deadline=None)
def test_fabricated_reject_for_executed_graph_flagged(salt):
    log = _serving_mutant()
    already = {r.gid for r in log.rejects}
    candidates = sorted(
        {r.gid for r in log.execs if r.gid not in already}
    )
    gid = _pick(salt, candidates)
    log.log_reject(gid, 0.0, "pressure")
    assert "ARRIVAL" in _codes(log)


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=20, deadline=None)
def test_tampered_claimed_admit_at_flagged(salt):
    log = _serving_mutant()
    admit_at = {r.gid: r.t for r in log.admits}
    candidates = sorted(
        gid for gid, info in log.result["per_graph"].items()
        if not info.get("rejected") and admit_at.get(gid, 0.0) > 1e-6
    )
    gid = _pick(salt, candidates)
    log.result["per_graph"][gid]["admit_at"] = admit_at[gid] * 3.0 + 1.0
    assert "ARRIVAL" in _codes(log)


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=20, deadline=None)
def test_flipped_rejected_flag_flagged(salt):
    log = _serving_mutant()
    candidates = sorted(
        gid for gid, info in log.result["per_graph"].items()
        if not info.get("rejected")
    )
    gid = _pick(salt, candidates)
    log.result["per_graph"][gid]["rejected"] = True
    assert "ARRIVAL" in _codes(log)


def test_serving_round_trip_preserves_arrival_records(tmp_path):
    log = _serving_log()
    p = tmp_path / "serving_audit.jsonl"
    log.to_jsonl(str(p))
    from repro.verify.audit import AuditLog

    back = AuditLog.from_jsonl(str(p))
    assert [(r.gid, r.t) for r in back.arrivals] == [
        (r.gid, r.t) for r in log.arrivals
    ]
    assert [(r.gid, r.t) for r in back.admits] == [
        (r.gid, r.t) for r in log.admits
    ]
    assert [(r.gid, r.t, r.reason) for r in back.rejects] == [
        (r.gid, r.t, r.reason) for r in log.rejects
    ]
    assert errors(verify_audit(back)) == []


# ---------------------------------------------------------------------------
# surrogate logs: same mutation classes through the surrogate subset


@lru_cache(maxsize=None)
def _surrogate_log():
    pytest.importorskip("jax")
    import numpy as np

    from repro.core import episode as ep

    machine = paper_machine(4)
    graph = cholesky_graph(6, 256, with_fns=False)
    max_mem = max(r.mem for r in machine.resources if r.is_accelerator)
    plan = ep.build_plan(graph, machine, n_u=max_mem + 2)
    ig, vl, mc, lg = ep.machine_axes(machine, plan.n_res)
    batch = ep.EpisodeBatch(
        is_gpu=ig[None], valid_res=vl[None], mem_col=mc[None],
        link_grp=lg[None], alpha=np.array([0.5]), use_cp=np.array([1.0]),
        ws_pref=np.array([False]),
        noise=ep.noise_factors(0, 0.0, plan.n, plan.n_pad)[None],
        cap=np.array([np.inf]),
    )
    out = ep.run_episodes(plan, batch, emit_schedule=True)
    (log,) = ep.episode_audit_logs(graph, batch, out)
    assert errors(verify_audit(log)) == []
    return log


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=20, deadline=None)
def test_surrogate_precedence_mutation_flagged(salt):
    log = copy.deepcopy(_surrogate_log())
    preds = derive_edges(log.graphs[0]["tasks"])
    exec_of = {r.tid: r for r in log.execs}
    candidates = [
        (r, exec_of[p].end)
        for r in log.execs
        for p in preds[r.tid]
        if p in exec_of and exec_of[p].end > 1e-4
    ]
    rec, pred_end = _pick(salt, candidates)
    rec.start = -1.0  # unambiguously before any predecessor in f32
    assert "PRECEDENCE" in _codes(log)


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=20, deadline=None)
def test_surrogate_dead_device_placement_flagged(salt):
    log = copy.deepcopy(_surrogate_log())
    rec = _pick(salt, log.execs)
    for r in log.machine["resources"]:
        if r["rid"] == rec.rid:
            r["valid"] = False
    assert "RESOURCE_VALID" in _codes(log)


def test_surrogate_byte_mutation_flagged():
    log = copy.deepcopy(_surrogate_log())
    log.result["total_bytes"] *= 2.0
    assert "BYTES" in _codes(log)
