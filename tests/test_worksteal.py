"""Work-stealing semantics (paper §2.2 / §4.3): owner-LIFO execution
order, the backlog-based victim eligibility rule, and seeded random-victim
determinism."""
import pytest

from repro.configs.paper_machine import paper_machine
from repro.core import Simulator, WorkSteal, run_simulation
from repro.core.dag import DataObject, Mode, TaskGraph
from repro.core.machine import LinkModel, MachineModel, Resource, ResourceClass
from repro.linalg.cholesky import cholesky_graph

CPU = ResourceClass(name="cpu", rates={}, default_rate=1e9)


def _cpu_machine(n: int) -> MachineModel:
    return MachineModel(
        resources=[Resource(rid, CPU, -1, None) for rid in range(n)],
        link=LinkModel(bandwidth=8e9),
    )


def _fan_out_graph(n_children: int) -> TaskGraph:
    """t0 writes n data objects; child i reads object i (all ready at once)."""
    g = TaskGraph()
    objs = [DataObject(f"d{i}", 1024) for i in range(n_children)]
    g.add_task("root", [(o, Mode.W) for o in objs], flops=1e6)
    for i, o in enumerate(objs):
        g.add_task(f"child{i}", [(o, Mode.R)], flops=1e6)
    return g


# ---------------------------------------------------------------------------
# owner-LIFO push order


def test_owner_lifo_executes_newest_first():
    """WorkSteal pushes newly-ready tasks onto the completing worker's own
    queue and the owner pops newest-first: the first child starts the idle
    worker immediately, the backlog then drains in reverse push order."""
    g = _fan_out_graph(4)
    res = run_simulation(g, _cpu_machine(1), WorkSteal(), seed=0, noise=0.0)
    order = [iv.tid for iv in sorted(res.intervals, key=lambda iv: iv.start)]
    # root is tid 0; children are tids 1..4, activated in order 1,2,3,4:
    # 1 starts the idle worker, then LIFO drains 4, 3, 2
    assert order[0] == 0
    assert order[1:] == [1, 4, 3, 2]


def test_owner_lifo_flag_drives_queue_end():
    """The simulator honours Strategy.owner_lifo: the same fan-out graph
    under a FIFO strategy (owner_lifo=False) runs children in push order."""

    class FifoSelf(WorkSteal):
        owner_lifo = False
        allow_steal = False

    g = _fan_out_graph(4)
    res = run_simulation(g, _cpu_machine(1), FifoSelf(), seed=0, noise=0.0)
    order = [iv.tid for iv in sorted(res.intervals, key=lambda iv: iv.start)]
    assert order[1:] == [1, 2, 3, 4]


# ---------------------------------------------------------------------------
# steal eligibility: backlog >= 2, or backlog >= 1 while running


def _sim_with_queues(n_workers: int):
    g = _fan_out_graph(2)
    sim = Simulator(g, _cpu_machine(n_workers), WorkSteal(), seed=0)
    return sim, g


def test_steal_skips_lone_task_when_victim_idle():
    """A victim whose queue holds one task and is not running is not a
    valid target (its lone task's transfers are already under way)."""
    sim, g = _sim_with_queues(2)
    victim, thief = sim.workers
    victim.queue.append(g.tasks[1])
    victim.running = None
    assert sim._steal(thief) is False
    assert sim.n_steals == 0


def test_steal_takes_oldest_from_backlogged_victim():
    sim, g = _sim_with_queues(2)
    victim, thief = sim.workers
    victim.queue.append(g.tasks[1])
    victim.queue.append(g.tasks[2])  # backlog of 2: eligible
    assert sim._steal(thief) is True
    assert sim.n_steals == 1
    # thief takes the OLDEST task; the victim keeps the newest
    assert [t.tid for t in thief.queue] == [1]
    assert [t.tid for t in victim.queue] == [2]


def test_steal_allows_single_queued_task_when_victim_running():
    sim, g = _sim_with_queues(2)
    victim, thief = sim.workers
    victim.queue.append(g.tasks[1])
    victim.running = g.tasks[0]  # running: a backlog of 1 is stealable
    assert sim._steal(thief) is True
    assert [t.tid for t in thief.queue] == [1]


def test_steal_no_eligible_victims_among_many():
    sim, g = _sim_with_queues(4)
    workers = sim.workers
    workers[0].queue.append(g.tasks[1])  # lone task, idle: ineligible
    thief = workers[3]
    assert sim._steal(thief) is False


# ---------------------------------------------------------------------------
# seeded random-victim determinism


def _ws_fingerprint(res):
    return (
        res.makespan,
        res.n_steals,
        tuple((iv.tid, iv.rid, iv.start, iv.end) for iv in res.intervals),
    )


def test_seeded_victim_selection_is_deterministic():
    """All steal randomness flows through the seeded generator: identical
    seeds give identical schedules (victims, steal counts, intervals)."""
    machine = paper_machine(4)
    runs = [
        run_simulation(
            cholesky_graph(6, 256, with_fns=False), machine, WorkSteal(),
            seed=11,
        )
        for _ in range(2)
    ]
    assert _ws_fingerprint(runs[0]) == _ws_fingerprint(runs[1])
    assert runs[0].n_steals > 0  # the scenario actually exercises stealing


def test_different_seeds_reach_different_schedules():
    machine = paper_machine(4)
    a = run_simulation(
        cholesky_graph(6, 256, with_fns=False), machine, WorkSteal(), seed=11
    )
    b = run_simulation(
        cholesky_graph(6, 256, with_fns=False), machine, WorkSteal(), seed=12
    )
    # the victim stream differs; schedules should not be bit-identical
    assert _ws_fingerprint(a) != _ws_fingerprint(b)
