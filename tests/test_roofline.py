"""Roofline infrastructure: HLO collective parser (loop-trip adjusted),
XLA scan-undercount documentation, analytic flop sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.flops import cell_cost, forward_flops_per_tok
from repro.analysis.hlo import collective_bytes, parse_computations, trip_count
from repro.analysis.roofline import analyse_record
from repro.configs.registry import get_config
from repro.configs.shapes import SHAPES


def test_xla_cost_analysis_counts_scan_body_once():
    """Documents WHY the roofline uses analytic FLOPs: XLA counts a while
    body once, so scanned models are undercounted by the trip count."""
    W = jnp.zeros((128, 128), jnp.float32)

    def f_scan(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ W, None), x, None, length=10)
        return y

    def f_unroll(x):
        for _ in range(10):
            x = x @ W
        return x

    def _flops(compiled):
        ca = compiled.cost_analysis()
        if isinstance(ca, list):  # older jax returned [dict]
            ca = ca[0]
        return ca["flops"]

    x = jnp.zeros((128, 128))
    f1 = _flops(jax.jit(f_scan).lower(x).compile())
    f2 = _flops(jax.jit(f_unroll).lower(x).compile())
    assert f2 == pytest.approx(10 * f1, rel=0.01)


def test_hlo_parser_finds_computations_and_trips():
    hlo = """HloModule test
%body.1 (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %c = s32[] constant(1)
}
%cond.1 (p: (s32[], f32[4])) -> pred[] {
  %n = s32[] constant(17)
  ROOT %lt = pred[] compare(%it, %n), direction=LT
}
ENTRY %main (a: f32[4]) -> f32[4] {
  %w = (s32[], f32[4]) while(%t), condition=%cond.1, body=%body.1
}
"""
    comps = parse_computations(hlo)
    assert {"body.1", "cond.1", "main"} <= set(comps)
    assert trip_count(comps["cond.1"]) == 17
    assert comps["main"].while_calls == [("body.1", "cond.1")]


def test_collective_bytes_loop_multiplier():
    hlo = """HloModule test
%body.1 (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %ar = f32[64,4]{1,0} all-reduce(%x), channel_id=1, replica_groups=[2,4]<=[8]
}
%cond.1 (p: (s32[], f32[4])) -> pred[] {
  %n = s32[] constant(5)
}
ENTRY %main (a: f32[4]) -> f32[4] {
  %w = (s32[], f32[4]) while(%t), condition=%cond.1, body=%body.1
  %ag = f32[8,4]{1,0} all-gather(%y), dimensions={0}
}
"""
    got = collective_bytes(hlo)
    # all-reduce: 64*4*4B * 2 (ring) * 5 trips = 10240
    assert got["all-reduce"] == pytest.approx(64 * 4 * 4 * 2 * 5)
    # all-gather: result bytes once
    assert got["all-gather"] == pytest.approx(8 * 4 * 4)


@pytest.mark.parametrize("arch", ["granite-8b", "kimi-k2-1t-a32b", "jamba-v0.1-52b"])
def test_analytic_flops_vs_6nd(arch):
    """Analytic forward flops within 2x of the 6ND/2 rule (attention adds
    the quadratic term, MoE counts active experts only)."""
    cfg = get_config(arch)
    shape = SHAPES["train_4k"]
    cc = cell_cost(cfg, shape)
    assert 0.5 <= cc.flops / cc.model_flops <= 2.5
    # decode flops are tiny relative to train (one token vs full batch)
    dec = cell_cost(cfg, SHAPES["decode_32k"])
    assert dec.flops < cc.flops / 100


def test_analyse_record_terms():
    rec = dict(
        status="ok", arch="a", shape="s", mesh="pod16x16", n_devices=256,
        analytic_flops=197e12 * 256,          # exactly 1s of compute
        analytic_hbm_bytes=819e9 * 256 * 0.5,  # 0.5s of memory
        collective_bytes_per_device={"total": 50e9 * 0.25},  # 0.25s
        model_flops=197e12 * 256 * 0.8,
        hlo_flops_raw=1.0,
    )
    row = analyse_record(rec)
    assert row.bottleneck == "compute"
    assert row.compute_s == pytest.approx(1.0)
    assert row.memory_s == pytest.approx(0.5)
    assert row.collective_s == pytest.approx(0.25)
    assert row.mfu_est == pytest.approx(0.8)


def test_skip_records_ignored():
    assert analyse_record({"status": "skip"}) is None
