"""Property-based fault-injection invariants (hypothesis-gated).

For any fault schedule (random detach/attach sequences, either recovery
mode, or seeded churn):

  * every task completes exactly once — kill-and-requeue never loses or
    duplicates work;
  * no task interval starts on a worker inside its dead window;
  * no dirty byte is lost — every data object ends with at least one
    valid copy, and never only on a detached memory;
  * the run terminates (the engine drains its heap).

Each property also has a fixed-parameter smoke test so the checker
logic itself runs in environments without hypothesis (where @given
turns into a skip).
"""
import math

from _hypothesis_compat import given, settings, st

from repro.configs.paper_machine import paper_machine
from repro.core.simulator import Simulator
from repro.linalg.cholesky import cholesky_graph
from repro.sched import resolve

NT = 6


def _dead_windows(history):
    out = {}
    open_at = {}
    for e in history:
        if e.event == "detach":
            open_at[e.rid] = e.t
        elif e.event == "attach" and e.rid in open_at:
            out.setdefault(e.rid, []).append((open_at.pop(e.rid), e.t))
    for rid, t in open_at.items():
        out.setdefault(rid, []).append((t, math.inf))
    return out


def _check_invariants(sim, res):
    graph = cholesky_graph(NT, 256, with_fns=False)
    # 1. every task completes exactly once
    assert sorted(iv.tid for iv in res.intervals) == list(
        range(len(graph.tasks))
    ), "a task was lost or completed twice"
    # 2. no interval starts inside its worker's dead window
    windows = _dead_windows(sim.faults.history)
    for iv in res.intervals:
        for lo, hi in windows.get(iv.rid, ()):
            assert not (lo <= iv.start < hi), (
                f"task {iv.tid} dispatched to rid {iv.rid} at {iv.start} "
                f"inside dead window [{lo}, {hi})"
            )
    # 3. no data lost: every object has >=1 copy, none only on dead memory
    dead_mems = sim.faults.dead_mems
    for name in sim.arrays.data_names:
        locs = sim.residency.locations(name)
        assert locs, f"data {name!r} has no valid copy after recovery"
        assert locs - dead_mems, (
            f"data {name!r} survives only on detached memory {locs}"
        )
    # 4. workers never double-booked despite requeues
    per_worker = {}
    for iv in res.intervals:
        per_worker.setdefault(iv.rid, []).append((iv.start, iv.end))
    for rid, ivs in per_worker.items():
        ivs.sort()
        for (s1, e1), (s2, e2) in zip(ivs, ivs[1:]):
            assert e1 <= s2 + 1e-9, f"worker {rid} double-booked"


def _run_schedule(spec, schedule, seed=0):
    """schedule: [(frac_of_baseline_makespan, event, gpu_index, mode)]."""
    m = paper_machine(4)
    base = Simulator(
        cholesky_graph(NT, 256, with_fns=False), paper_machine(4),
        resolve(spec), seed=seed, noise=0.0,
    ).run()
    sim = Simulator(
        cholesky_graph(NT, 256, with_fns=False), m, resolve(spec),
        seed=seed, noise=0.0,
    )
    gpus = [r.rid for r in m.gpus]
    down = set()
    for frac, event, gi, mode in schedule:
        rid = gpus[gi % len(gpus)]
        # keep the schedule self-consistent: detach only alive workers
        # (and never the whole machine — CPUs stay up), attach only dead
        if event == "detach":
            if rid in down:
                continue
            down.add(rid)
        else:
            if rid not in down:
                continue
            down.discard(rid)
        sim.inject(event, rid, at=base.makespan * frac, mode=mode)
    res = sim.run()
    _check_invariants(sim, res)
    return sim, res


_EVENT = st.tuples(
    st.floats(min_value=0.02, max_value=1.5),
    st.sampled_from(["detach", "attach"]),
    st.integers(min_value=0, max_value=3),
    st.sampled_from(["drain", "kill"]),
)


@given(
    spec=st.sampled_from(["heft", "dada?alpha=0.5&use_cp=1", "ws"]),
    schedule=st.lists(_EVENT, min_size=1, max_size=6),
    seed=st.integers(min_value=0, max_value=2**16),
)
@settings(max_examples=15)
def test_random_fault_schedules_preserve_invariants(spec, schedule, seed):
    _run_schedule(spec, sorted(schedule, key=lambda e: e[0]), seed=seed)


@given(
    rate=st.floats(min_value=50.0, max_value=500.0),
    seed=st.integers(min_value=0, max_value=2**16),
    mode=st.sampled_from(["drain", "kill"]),
)
@settings(max_examples=15)
def test_seeded_churn_preserves_invariants(rate, seed, mode):
    sim = Simulator(
        cholesky_graph(NT, 256, with_fns=False), paper_machine(4),
        resolve("heft"), seed=seed, noise=0.01, churn=rate, fault_mode=mode,
    )
    res = sim.run()
    _check_invariants(sim, res)


@given(
    schedule=st.lists(_EVENT, min_size=1, max_size=4),
    seed=st.integers(min_value=0, max_value=2**16),
)
@settings(max_examples=10)
def test_kill_and_requeue_conserves_completed_work(schedule, seed):
    """Exactly-once completion under pure kill-mode schedules: the sum of
    completed flops equals the graph's total regardless of aborts."""
    kill_only = [
        (frac, ev, gi, "kill") for frac, ev, gi, _ in
        sorted(schedule, key=lambda e: e[0])
    ]
    sim, res = _run_schedule("heft", kill_only, seed=seed)
    graph = cholesky_graph(NT, 256, with_fns=False)
    assert res.total_flops == graph.total_flops()


# ---------------------------------------------------------------------------
# transient link faults (flaky DMAs with retry/backoff/re-source)


def _check_flake_invariants(sim, res, retry_max):
    graph = cholesky_graph(NT, 256, with_fns=False)
    # every task still runs exactly once — dropped DMAs delay, never lose
    assert sorted(iv.tid for iv in res.intervals) == list(
        range(len(graph.tasks))
    ), "a task was lost or duplicated under link flake"
    assert res.total_flops == graph.total_flops()
    # no transfer retries forever: each chain is bounded by retry_max
    # re-attempts, then must time out into one reliable re-source hop
    for rec in sim.audit.retries:
        assert 1 <= rec.attempt <= retry_max, (
            f"retry attempt {rec.attempt} escaped the budget {retry_max}"
        )
    for rec in sim.audit.timeouts:
        assert rec.attempts == retry_max + 1
    fs = res.faults
    assert fs["n_retries"] == len(sim.audit.retries)
    assert fs["n_timeouts"] == len(sim.audit.timeouts)
    # bytes conserved attempt-for-attempt and every transfer lands: the
    # independent verifier re-checks BYTES / RETRY_BYTES /
    # TRANSFER_COMPLETES from the audit log alone
    from repro.verify import errors, verify_audit

    assert not errors(verify_audit(sim.audit))


@given(
    rate=st.floats(min_value=0.05, max_value=0.9),
    retry_max=st.integers(min_value=0, max_value=4),
    seed=st.integers(min_value=0, max_value=2**16),
)
@settings(max_examples=15)
def test_flaky_links_preserve_invariants(rate, retry_max, seed):
    sim = Simulator(
        cholesky_graph(NT, 256, with_fns=False), paper_machine(4),
        resolve("heft"), seed=seed, noise=0.0,
        link_flake=rate, retry_max=retry_max, backoff_s=1e-4, audit=True,
    )
    res = sim.run()
    _check_flake_invariants(sim, res, retry_max)


@given(
    rate=st.floats(min_value=0.05, max_value=0.5),
    churn=st.floats(min_value=50.0, max_value=400.0),
    notice=st.sampled_from([0.0, 0.003]),
    seed=st.integers(min_value=0, max_value=2**16),
)
@settings(max_examples=10)
def test_flake_churn_and_notice_compose(rate, churn, notice, seed):
    """Flaky links, seeded churn and preemption notices together still
    deliver exactly-once execution, lose no data, and audit clean."""
    sim = Simulator(
        cholesky_graph(NT, 256, with_fns=False), paper_machine(4),
        resolve("heft"), seed=seed, noise=0.0,
        churn=churn, fault_mode="kill", notice_s=notice,
        link_flake=rate, retry_max=2, backoff_s=1e-4, audit=True,
    )
    res = sim.run()
    _check_invariants(sim, res)
    _check_flake_invariants(sim, res, retry_max=2)


# ---------------------------------------------------------------------------
# fixed-parameter smoke tests: validate the checkers without hypothesis


def test_invariant_checker_smoke_programmatic():
    _run_schedule(
        "dada?alpha=0.5&use_cp=1",
        [
            (0.2, "detach", 0, "kill"),
            (0.3, "detach", 1, "drain"),
            (0.55, "attach", 0, "drain"),
            (0.7, "detach", 2, "kill"),
        ],
    )


def test_invariant_checker_smoke_churn():
    sim = Simulator(
        cholesky_graph(NT, 256, with_fns=False), paper_machine(4),
        resolve("heft"), seed=9, noise=0.0, churn=250.0, fault_mode="kill",
    )
    res = sim.run()
    _check_invariants(sim, res)


def test_flake_checker_smoke():
    sim = Simulator(
        cholesky_graph(NT, 256, with_fns=False), paper_machine(4),
        resolve("heft"), seed=9, noise=0.0,
        link_flake=0.4, retry_max=2, backoff_s=1e-4, audit=True,
    )
    res = sim.run()
    _check_flake_invariants(sim, res, retry_max=2)
    assert res.faults["n_retries"] > 0, "flake rate produced no retries"


def test_zero_flake_zero_notice_bit_identical_to_plain():
    """The proactive-recovery machinery is strictly opt-in: with flake
    and notice at 0 the schedule is bit-for-bit the pre-existing one."""
    def _fp(**kw):
        res = Simulator(
            cholesky_graph(NT, 256, with_fns=False), paper_machine(4),
            resolve("heft"), seed=3, noise=0.02, **kw,
        ).run()
        return (
            res.makespan, res.total_bytes,
            tuple((iv.tid, iv.rid, iv.start, iv.end) for iv in res.intervals),
        )

    assert _fp() == _fp(link_flake=0.0, notice_s=0.0, retry_max=5)
    assert _fp(churn=200.0, fault_mode="kill") == _fp(
        churn=200.0, fault_mode="kill", link_flake=0.0, notice_s=0.0
    )
