"""Bit-for-bit equivalence of the array-native scheduling core against the
scalar reference implementations (``repro.core._reference``).

The vectorized HEFT / DADA must produce *identical* placements, interval
timelines, and SimResult metrics — not approximately equal: every floating
point operation order that could change a tie-break is pinned down. Any
divergence here is a scheduling regression, not noise.
"""
import pytest

from repro.configs.paper_machine import paper_machine
from repro.core import DADA, HEFT, run_simulation
from repro.core._reference import ReferenceDADA, ReferenceHEFT
from repro.linalg.cholesky import cholesky_graph
from repro.linalg.lu import lu_graph
from repro.linalg.qr import qr_graph

KERNELS = {
    "cholesky": cholesky_graph,
    "lu": lu_graph,
    "qr": qr_graph,
}

STRATEGY_PAIRS = {
    "heft": (lambda: HEFT(), lambda: ReferenceHEFT()),
    "dada(0)": (lambda: DADA(alpha=0.0), lambda: ReferenceDADA(alpha=0.0)),
    "dada(0.5)": (lambda: DADA(alpha=0.5), lambda: ReferenceDADA(alpha=0.5)),
    "dada(0.5)+cp": (
        lambda: DADA(alpha=0.5, use_cp=True),
        lambda: ReferenceDADA(alpha=0.5, use_cp=True),
    ),
}


def _fingerprint(res):
    return (
        res.makespan,
        res.total_bytes,
        res.n_transfers,
        res.n_steals,
        tuple(sorted(res.busy.items())),
        tuple((iv.tid, iv.rid, iv.start, iv.end) for iv in res.intervals),
    )


@pytest.mark.parametrize("kernel", sorted(KERNELS))
@pytest.mark.parametrize("strat", sorted(STRATEGY_PAIRS))
@pytest.mark.parametrize("n_gpus", [0, 3, 8])
def test_vectorized_matches_reference(kernel, strat, n_gpus):
    machine = paper_machine(n_gpus)
    new_fac, ref_fac = STRATEGY_PAIRS[strat]
    for seed in (0, 7):
        a = run_simulation(
            KERNELS[kernel](6, 256, with_fns=False), machine, new_fac(), seed=seed
        )
        b = run_simulation(
            KERNELS[kernel](6, 256, with_fns=False), machine, ref_fac(), seed=seed
        )
        assert _fingerprint(a) == _fingerprint(b)


def test_dada_lambda_and_loads_match_reference():
    """The accepted λ and final per-resource loads of the last activation
    must match too (they drive load_ts corrections mid-simulation)."""
    machine = paper_machine(4)
    a = DADA(alpha=0.5)
    b = ReferenceDADA(alpha=0.5)
    run_simulation(cholesky_graph(6, 256, with_fns=False), machine, a, seed=3)
    run_simulation(cholesky_graph(6, 256, with_fns=False), machine, b, seed=3)
    assert a.last_lambda == b.last_lambda
    assert a.last_loads == b.last_loads


def test_dada_area_bound_matches_reference():
    machine = paper_machine(4)
    a = run_simulation(
        lu_graph(5, 256, with_fns=False),
        machine,
        DADA(alpha=0.5, area_bound=True),
        seed=1,
    )
    b = run_simulation(
        lu_graph(5, 256, with_fns=False),
        machine,
        ReferenceDADA(alpha=0.5, area_bound=True),
        seed=1,
    )
    assert _fingerprint(a) == _fingerprint(b)


@pytest.mark.parametrize("affinity", ["write_resident", "all_resident",
                                      "missing_bytes", "accel_all"])
def test_dada_nondefault_affinity_matches_reference(affinity):
    """Every registered affinity score (vectorized or scalar-fallback path)
    must reproduce the reference placements."""
    machine = paper_machine(3)
    a = run_simulation(
        cholesky_graph(6, 256, with_fns=False),
        machine,
        DADA(alpha=0.75, affinity=affinity),
        seed=9,
    )
    b = run_simulation(
        cholesky_graph(6, 256, with_fns=False),
        machine,
        ReferenceDADA(alpha=0.75, affinity=affinity),
        seed=9,
    )
    assert _fingerprint(a) == _fingerprint(b)


# ---------------------------------------------------------------------------
# fixed-seed regression fingerprints: catch *any* behavior drift of the
# shipped core on the three paper kernels (values locked at PR time)


def test_fixed_seed_regression_metrics():
    machine = paper_machine(4)
    seen = {}
    for kernel, gf in sorted(KERNELS.items()):
        res = run_simulation(
            gf(6, 256, with_fns=False), machine, DADA(alpha=0.5, use_cp=True), seed=42
        )
        seen[kernel] = (res.makespan, res.total_bytes, res.n_transfers)
        # determinism: a second identical run is bit-identical
        res2 = run_simulation(
            gf(6, 256, with_fns=False), machine, DADA(alpha=0.5, use_cp=True), seed=42
        )
        assert (res2.makespan, res2.total_bytes, res2.n_transfers) == seen[kernel]
        assert res.makespan > 0 and res.total_bytes > 0
