"""Validate the reproduction against the paper's experimental claims
(C1-C6) plus the two runtime-extension claims: C7 (transfer-volume gap
under memory pressure) and C8 (transfer volume and recovery under GPU
churn). Consumes the rows produced by the fig1-fig4 benchmarks and
prints a PASS/FAIL table; quantitative factors are reported as measured.

Runnable directly: ``REPRO_BENCH_FAST=1 python benchmarks/paper_validation.py``
executes the fig1-fig4 sweeps (honouring the REPRO_BENCH_* knobs, see
common.py) and then the claim checks, printing total wall-clock at the end.
"""
from __future__ import annotations

import sys
from functools import partial
from pathlib import Path
from typing import Dict, List

if __package__ in (None, ""):  # `python benchmarks/paper_validation.py`
    _repo = Path(__file__).resolve().parents[1]
    for p in (str(_repo), str(_repo / "src")):
        if p not in sys.path:
            sys.path.insert(0, p)

from repro.configs.paper_machine import paper_machine
from repro.core import run_many
from repro.sched import resolve
from repro.linalg.cholesky import cholesky_graph


def _get(rows: List[dict], strategy: str, n_gpus: int, field: str):
    for r in rows:
        if r["strategy"] == strategy and r["n_gpus"] == n_gpus:
            return r[field]
    raise KeyError((strategy, n_gpus, field))


def _verify_sim(sim) -> int | None:
    """Error count from the independent schedule verifier, or ``None``
    when the run was not audited (``REPRO_SCHED_AUDIT`` off)."""
    if sim.audit is None:
        return None
    from repro.verify import errors, verify_audit

    return len(errors(verify_audit(sim.audit)))


def validate(fig1: List[dict], fig2: List[dict], fig3: List[dict], fig4: List[dict], n_runs: int = 10) -> List[dict]:
    checks: List[dict] = []
    if not (fig1 and fig2 and fig3 and fig4):
        # empty sweeps (e.g. REPRO_BENCH_GPUS=""): nothing to validate
        # against; C6 below runs its own simulations, so keep only that
        print("  (figure sweeps empty — skipping row-based claims C1-C5)")
        return _validate_c6(checks, n_runs)
    gpus = sorted({r["n_gpus"] for r in fig1})
    lo, hi = gpus[0], gpus[-1]

    # C1 — DADA(0) without CP stops scaling with many GPUs -----------------
    try:
        s0 = _get(fig1, "dada(0)", hi, "gflops") / _get(fig1, "dada(0)", lo, "gflops")
        s1 = _get(fig1, "dada(1)", hi, "gflops") / _get(fig1, "dada(1)", lo, "gflops")
        checks.append(
            dict(
                claim="C1 dada(0) scales worse than dada(1)",
                measured=f"speedup {lo}->{hi} gpus: dada(0) {s0:.2f}x vs dada(1) {s1:.2f}x",
                passed=s0 < s1,
            )
        )
    except KeyError:
        pass

    # C2 — higher alpha scales better --------------------------------------
    try:
        perf = [(_a, _get(fig1, f"dada({_a:g})", hi, "gflops")) for _a in (0.25, 0.5, 0.75, 1.0)]
        checks.append(
            dict(
                claim="C2 higher alpha => better at max gpus",
                measured="; ".join(f"a={a:g}:{g:.0f}GF" for a, g in perf),
                passed=perf[-1][1] >= perf[0][1],
            )
        )
    except KeyError:
        pass

    # C3 — LU: DADA(a)+CP moves much less data than HEFT -------------------
    heft_gb = _get(fig3, "heft", hi, "gbytes")
    dada_gb = _get(fig3, "dada(a)+cp", hi, "gbytes")
    heft_gf = _get(fig3, "heft", hi, "gflops")
    dada_gf = _get(fig3, "dada(a)+cp", hi, "gflops")
    factor = heft_gb / dada_gb
    slow = heft_gf / dada_gf
    checks.append(
        dict(
            claim="C3 LU: dada(a)+cp lowest transfers (paper: 3.5x, ~1.13x slowdown)",
            measured=f"transfer factor {factor:.2f}x, perf ratio {slow:.2f}x",
            passed=factor > 1.0 and slow < 1.25,
        )
    )

    # C4 — QR: HEFT outperforms every dual-approximation variant -----------
    duals = ["dada(0)", "dada(a)", "dada(a)+cp"]
    heft_qr = _get(fig4, "heft", hi, "gflops")
    worst = max(_get(fig4, d, hi, "gflops") for d in duals)
    checks.append(
        dict(
            claim="C4 QR: HEFT >= all dual approximations",
            measured=f"heft {heft_qr:.0f}GF vs best dual {worst:.0f}GF",
            passed=heft_qr >= worst * 0.97,
        )
    )

    # C5 — Cholesky: DADA(a) within range of HEFT (similar performance) ----
    heft_ch = _get(fig2, "heft", hi, "gflops")
    dada_ch = _get(fig2, "dada(a)", hi, "gflops")
    checks.append(
        dict(
            claim="C5 Cholesky: dada(a) ~ heft at max gpus",
            measured=f"dada(a) {dada_ch:.0f}GF vs heft {heft_ch:.0f}GF",
            passed=dada_ch >= heft_ch * 0.8,
        )
    )

    return _validate_c6(checks, n_runs)


def _validate_c6(checks: List[dict], n_runs: int) -> List[dict]:
    # C6 — work stealing is cache-unfriendly on small matrices -------------
    machine = paper_machine(4)
    small = partial(cholesky_graph, 8, 512, with_fns=False)  # 4096^2
    from concurrent.futures import ThreadPoolExecutor

    with ThreadPoolExecutor(max_workers=2) as tp:
        ws_f = tp.submit(
            run_many, small, machine, partial(resolve, "ws"), n_runs
        )
        da_f = tp.submit(
            run_many, small, machine, partial(resolve, "dada?alpha=0.5"), n_runs
        )
        ws, da = ws_f.result(), da_f.result()
    checks.append(
        dict(
            claim="C6 small matrix: affinity beats work stealing",
            measured=f"ws {ws.gflops_mean:.0f}GF/{ws.gbytes_mean:.2f}GB vs "
            f"dada(a) {da.gflops_mean:.0f}GF/{da.gbytes_mean:.2f}GB",
            passed=da.gflops_mean > ws.gflops_mean,
        )
    )
    return _validate_c7(checks)


_MB = 1024 * 1024
# capacity sweep points: unbounded (0) down to 32 MB per GPU memory — the
# regime the paper's 2014 hardware forced (a handful of tiles per device)
C7_CAPACITIES = (0, 128 * _MB, 64 * _MB, 32 * _MB)


def capacity_sweep(capacities=C7_CAPACITIES) -> List[dict]:
    """Total transferred bytes of HEFT vs DADA(a)+CP on the Cholesky NT=16
    paper trace as device-memory capacity shrinks.

    The trace is deterministic (noise=0, fixed seed, affinity eviction)
    so the sweep isolates the *eviction/write-back* traffic — the cost
    Kumar et al. measure on real GPUs — from duration noise. One graph
    object is shared: the simulator never mutates it.
    """
    from repro.core import Simulator

    machine = paper_machine(8)
    graph = cholesky_graph(16, 512, with_fns=False)
    rows = []
    for cap in capacities:
        row = dict(capacity=cap)
        for label, spec in (("heft", "heft"), ("dada", "dada?alpha=0.5&use_cp=1")):
            sim = Simulator(
                graph, machine, resolve(spec), seed=0, noise=0.0,
                mem_capacity=cap, eviction="affinity",
            )
            res = sim.run()
            row[label] = res.total_bytes
            row[f"{label}_writeback"] = sim.metrics.writeback_bytes
            ve = _verify_sim(sim)
            if ve is not None:
                row[f"{label}_verify_errors"] = ve
        row["gap"] = row["heft"] - row["dada"]
        rows.append(row)
    return rows


def _validate_c7(checks: List[dict]) -> List[dict]:
    # C7 — the paper's Fig. 5 story under memory pressure: DADA moves no
    # more data than HEFT at every capacity point, and its advantage (the
    # transfer-volume gap) widens monotonically as capacity drops — the
    # affinity phase keeps working sets where they already live, so it
    # pays less eviction/write-back traffic.
    rows = capacity_sweep()
    le_everywhere = all(r["dada"] <= r["heft"] for r in rows)
    gaps = [r["gap"] for r in rows]
    non_shrinking = all(b >= a for a, b in zip(gaps, gaps[1:]))

    def _cap(c):
        return "inf" if c == 0 else f"{c // _MB}MB"

    checks.append(
        dict(
            claim="C7 capacity sweep: DADA bytes <= HEFT, gap non-shrinking as memory shrinks",
            measured="; ".join(
                f"{_cap(r['capacity'])}: heft {r['heft'] / 1e9:.3f}GB "
                f"dada {r['dada'] / 1e9:.3f}GB (gap {r['gap'] / 1e6:+.1f}MB)"
                for r in rows
            ),
            passed=le_everywhere and non_shrinking,
            rows=rows,
        )
    )
    return _validate_c8(checks)


# C8 fault script, as fractions of each strategy's own clairvoyant
# baseline makespan: lose 2 of the 8 GPUs mid-run (one graceful drain,
# one hard kill), get one back late
C8_FAULTS = ((0.25, "detach", 0, "drain"), (0.40, "detach", 1, "kill"),
             (0.60, "attach", 0, None))


def fault_recovery_runs() -> Dict[str, dict]:
    """HEFT vs DADA(a)+CP through the C8 fault script on the deterministic
    Cholesky NT=16 paper trace (seed 0, noise 0): per strategy, a
    clairvoyant no-fault baseline and the faulted run, reduced to the
    recovery report (makespan the faults cost, extra transferred bytes,
    evacuation/requeue counters)."""
    from repro.core import Simulator
    from repro.runtime import recovery_report

    graph = cholesky_graph(16, 512, with_fns=False)
    out = {}
    for label, spec in (("heft", "heft"), ("dada", "dada?alpha=0.5&use_cp=1")):
        base = Simulator(
            graph, paper_machine(8), resolve(spec), seed=0, noise=0.0
        ).run()
        sim = Simulator(
            graph, paper_machine(8), resolve(spec), seed=0, noise=0.0
        )
        gpus = [r.rid for r in sim.machine.gpus]
        for frac, event, gi, mode in C8_FAULTS:
            sim.inject(event, gpus[gi], at=base.makespan * frac, mode=mode)
        res = sim.run()
        out[label] = dict(
            recovery_report(res, base),
            bytes=res.total_bytes, baseline_bytes=base.total_bytes,
        )
        ve = _verify_sim(sim)
        if ve is not None:
            out[label]["verify_errors"] = ve
    return out


def _validate_c8(checks: List[dict]) -> List[dict]:
    # C8 — the paper's transfer-volume story survives resource churn: with
    # 2 of 8 GPUs detached mid-run (and one reattached), the affinity
    # criterion still moves no more data than HEFT — recovery re-transfers
    # and evacuations included — and both recover to completion.
    reps = fault_recovery_runs()
    dada_le = reps["dada"]["bytes"] <= reps["heft"]["bytes"]
    both_recover = all(
        r["slowdown"] > 0 and r["n_detaches"] == 2 for r in reps.values()
    )
    checks.append(
        dict(
            claim="C8 GPU churn: DADA bytes <= HEFT through detach/reattach, both recover",
            measured="; ".join(
                f"{k}: {r['bytes'] / 1e9:.3f}GB ({r['extra_bytes'] / 1e6:+.1f}MB "
                f"over no-fault), recovery +{r['recovery_makespan'] * 1e3:.2f}ms "
                f"({r['slowdown']:.2f}x), evac {r['evacuated_bytes'] / 1e6:.1f}MB, "
                f"requeued {r['n_requeued']:.0f}"
                for k, r in reps.items()
            ),
            passed=dada_le and both_recover,
            rows=reps,
        )
    )
    return _validate_verified(checks)


def _validate_verified(checks: List[dict]) -> List[dict]:
    # CV — with REPRO_SCHED_AUDIT=1, every claim schedule above is also
    # replayed through the independent verifier (repro.verify): the
    # run_simulation hook already hard-fails the fig1-fig4 sweeps on any
    # invariant violation, so here we re-run the claim strategies on the
    # C7/C8 trace with an explicit audit and report the error counts, and
    # do the same for the surrogate engine via emit_schedule.
    from repro.sched import current_config

    if not current_config().audit:
        return checks

    from repro.core import Simulator
    from repro.verify import errors, verify_audit

    graph = cholesky_graph(16, 512, with_fns=False)
    machine = paper_machine(8)
    parts, n_err = [], 0
    for spec in ("heft", "dada?alpha=0.5&use_cp=1", "ws"):
        sim = Simulator(
            graph, machine, resolve(spec), seed=0, noise=0.0, audit=True
        )
        sim.run()
        e = len(errors(verify_audit(sim.audit)))
        n_err += e
        parts.append(f"{spec}: {e} err")
    checks.append(
        dict(
            claim="CV exact-engine claim schedules pass the independent verifier",
            measured="; ".join(parts),
            passed=n_err == 0,
        )
    )

    try:
        import jax  # noqa: F401
    except Exception:
        print("  (jax unavailable — skipping surrogate verifier claim)")
        return checks

    import numpy as np

    from repro.core import episode as ep

    max_mem = max(r.mem for r in machine.resources if r.is_accelerator)
    plan = ep.build_plan(graph, machine, n_u=max_mem + 2)
    ig, vl, mc, lg = ep.machine_axes(machine, plan.n_res)
    specs = ("heft", "dada?alpha=0.5&use_cp=1", "ws")
    params = [ep.surrogate_params(s) for s in specs]
    B = len(specs)
    batch = ep.EpisodeBatch(
        is_gpu=np.stack([ig] * B), valid_res=np.stack([vl] * B),
        mem_col=np.stack([mc] * B), link_grp=np.stack([lg] * B),
        alpha=np.array([p[0] for p in params]),
        use_cp=np.array([p[1] for p in params]),
        ws_pref=np.array([p[2] for p in params], dtype=bool),
        noise=np.stack(
            [ep.noise_factors(0, 0.0, plan.n, plan.n_pad)] * B
        ),
        cap=np.full(B, np.inf),
    )
    out = ep.run_episodes(plan, batch, emit_schedule=True)
    parts, n_err = [], 0
    for spec, log in zip(specs, ep.episode_audit_logs(graph, batch, out)):
        e = len(errors(verify_audit(log)))
        n_err += e
        parts.append(f"{spec}: {e} err")
    checks.append(
        dict(
            claim="CV surrogate claim schedules pass the independent verifier",
            measured="; ".join(parts),
            passed=n_err == 0,
        )
    )
    return checks


def print_checks(checks: List[dict]) -> bool:
    ok = True
    print("\n== paper-claim validation ==")
    for c in checks:
        status = "PASS" if c["passed"] else "FAIL"
        ok &= c["passed"]
        print(f"  [{status}] {c['claim']}\n         measured: {c['measured']}")
    return ok


def main() -> bool:
    """Run the fig1-fig4 sweeps and validate the paper claims end-to-end.

    The four sweeps run on threads: each one mostly blocks on shared
    process-pool futures, so overlapping them keeps the pool saturated
    from the first configuration to the last (progress lines interleave
    across figures; CSVs and returned rows are per-figure as before).
    """
    import importlib
    import time
    from concurrent.futures import ThreadPoolExecutor

    from repro.core import get_pool

    t0 = time.perf_counter()
    # create the shared process pool from the main thread, before any sweep
    # threads exist (fork-after-threads can deadlock forked children)
    get_pool()
    mods = [
        importlib.import_module(f"benchmarks.{m}")
        for m in ("fig1_alpha_sweep", "fig2_cholesky", "fig3_lu", "fig4_qr")
    ]
    with ThreadPoolExecutor(max_workers=len(mods)) as tp:
        figs = [f.result() for f in [tp.submit(m.main) for m in mods]]
    checks = validate(*figs)
    ok = print_checks(checks)
    wall = time.perf_counter() - t0
    print(f"\ntotal wall-clock: {wall:.2f}s")

    # record the run in the machine-readable perf trajectory (satellite of
    # the scheduler-throughput tracking; see benchmarks/README.md)
    from repro.sched import current_config

    from benchmarks.common import update_bench_json

    cfg = current_config()
    update_bench_json(
        # the surrogate run owns its own section so the trajectory file
        # keeps both walls (exact oracle vs REPRO_SCHED_EXACT=0) side by
        # side for the speedup record
        "paper_validation" if cfg.exact else "paper_validation_surrogate",
        dict(
            wall_s=round(wall, 2),
            backend=cfg.backend,
            fast=cfg.bench_fast,
            exact=cfg.exact,
            claims=[
                dict(claim=c["claim"], passed=bool(c["passed"]),
                     measured=c["measured"])
                for c in checks
            ],
            figures={
                name: rows
                for name, rows in zip(("fig1", "fig2", "fig3", "fig4"), figs)
            },
        ),
    )
    return ok


if __name__ == "__main__":
    ok = main()
    if not ok:
        print("WARNING: some paper claims did not reproduce — see above", file=sys.stderr)
        # gate CI on claim regressions; REPRO_BENCH_ALLOW_FAIL=1 opts out
        # (e.g. deliberately tiny smoke configurations on noisy runners)
        from repro.sched import current_config

        if not current_config().bench_allow_fail:
            sys.exit(1)
