"""Validate the reproduction against the paper's experimental claims (C1-C6,
DESIGN.md §1). Consumes the rows produced by the fig1-fig4 benchmarks and
prints a PASS/FAIL table; quantitative factors are reported as measured.
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.paper_machine import paper_machine
from repro.core import DADA, make_strategy, run_many
from repro.linalg.cholesky import cholesky_graph


def _get(rows: List[dict], strategy: str, n_gpus: int, field: str):
    for r in rows:
        if r["strategy"] == strategy and r["n_gpus"] == n_gpus:
            return r[field]
    raise KeyError((strategy, n_gpus, field))


def validate(fig1: List[dict], fig2: List[dict], fig3: List[dict], fig4: List[dict], n_runs: int = 10) -> List[dict]:
    checks: List[dict] = []
    gpus = sorted({r["n_gpus"] for r in fig1})
    lo, hi = gpus[0], gpus[-1]

    # C1 — DADA(0) without CP stops scaling with many GPUs -----------------
    try:
        s0 = _get(fig1, "dada(0)", hi, "gflops") / _get(fig1, "dada(0)", lo, "gflops")
        s1 = _get(fig1, "dada(1)", hi, "gflops") / _get(fig1, "dada(1)", lo, "gflops")
        checks.append(
            dict(
                claim="C1 dada(0) scales worse than dada(1)",
                measured=f"speedup {lo}->{hi} gpus: dada(0) {s0:.2f}x vs dada(1) {s1:.2f}x",
                passed=s0 < s1,
            )
        )
    except KeyError:
        pass

    # C2 — higher alpha scales better --------------------------------------
    try:
        perf = [(_a, _get(fig1, f"dada({_a:g})", hi, "gflops")) for _a in (0.25, 0.5, 0.75, 1.0)]
        checks.append(
            dict(
                claim="C2 higher alpha => better at max gpus",
                measured="; ".join(f"a={a:g}:{g:.0f}GF" for a, g in perf),
                passed=perf[-1][1] >= perf[0][1],
            )
        )
    except KeyError:
        pass

    # C3 — LU: DADA(a)+CP moves much less data than HEFT -------------------
    heft_gb = _get(fig3, "heft", hi, "gbytes")
    dada_gb = _get(fig3, "dada(a)+cp", hi, "gbytes")
    heft_gf = _get(fig3, "heft", hi, "gflops")
    dada_gf = _get(fig3, "dada(a)+cp", hi, "gflops")
    factor = heft_gb / dada_gb
    slow = heft_gf / dada_gf
    checks.append(
        dict(
            claim="C3 LU: dada(a)+cp lowest transfers (paper: 3.5x, ~1.13x slowdown)",
            measured=f"transfer factor {factor:.2f}x, perf ratio {slow:.2f}x",
            passed=factor > 1.0 and slow < 1.25,
        )
    )

    # C4 — QR: HEFT outperforms every dual-approximation variant -----------
    duals = ["dada(0)", "dada(a)", "dada(a)+cp"]
    heft_qr = _get(fig4, "heft", hi, "gflops")
    worst = max(_get(fig4, d, hi, "gflops") for d in duals)
    checks.append(
        dict(
            claim="C4 QR: HEFT >= all dual approximations",
            measured=f"heft {heft_qr:.0f}GF vs best dual {worst:.0f}GF",
            passed=heft_qr >= worst * 0.97,
        )
    )

    # C5 — Cholesky: DADA(a) within range of HEFT (similar performance) ----
    heft_ch = _get(fig2, "heft", hi, "gflops")
    dada_ch = _get(fig2, "dada(a)", hi, "gflops")
    checks.append(
        dict(
            claim="C5 Cholesky: dada(a) ~ heft at max gpus",
            measured=f"dada(a) {dada_ch:.0f}GF vs heft {heft_ch:.0f}GF",
            passed=dada_ch >= heft_ch * 0.8,
        )
    )

    # C6 — work stealing is cache-unfriendly on small matrices -------------
    machine = paper_machine(4)
    small = lambda: cholesky_graph(8, 512, with_fns=False)  # 4096^2
    ws = run_many(small, machine, lambda: make_strategy("ws"), n_runs=n_runs)
    da = run_many(small, machine, lambda: DADA(alpha=0.5), n_runs=n_runs)
    checks.append(
        dict(
            claim="C6 small matrix: affinity beats work stealing",
            measured=f"ws {ws.gflops_mean:.0f}GF/{ws.gbytes_mean:.2f}GB vs "
            f"dada(a) {da.gflops_mean:.0f}GF/{da.gbytes_mean:.2f}GB",
            passed=da.gflops_mean > ws.gflops_mean,
        )
    )
    return checks


def print_checks(checks: List[dict]) -> bool:
    ok = True
    print("\n== paper-claim validation ==")
    for c in checks:
        status = "PASS" if c["passed"] else "FAIL"
        ok &= c["passed"]
        print(f"  [{status}] {c['claim']}\n         measured: {c['measured']}")
    return ok
