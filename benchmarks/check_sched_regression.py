"""Scheduler-throughput regression gate.

Compares the current ``results/BENCH_sched.json`` (produced by
``sched_overhead.py``) against the committed baseline
``results/BENCH_sched_baseline.json`` and exits 1 when events/sec drops
more than ``REPRO_SCHED_REGRESSION_TOL`` (default 0.25 = 25%).

Two levels of comparison, because single-run events/sec on shared boxes is
noisy (the committed baseline itself shows ~25% spread between identical
code paths measured twice in one run):

  * the **aggregate** geometric mean of per-configuration ratios must not
    drop more than the tolerance — per-row noise averages out across the
    ~25 configurations, so this reliably catches broad scheduler
    slowdowns;
  * per-configuration drops are *reported* (marked against 2× the
    tolerance) but gate the build only when ``REPRO_SCHED_ROW_TOL`` is
    set to a fraction (e.g. ``0.5``): single-run rows on shared boxes
    have been observed to swing −70% on identical code, so a hard
    per-row gate is only meaningful on quiet, repetition-averaged
    runners.

Machines differ in raw speed, so both files carry a ``calibration_score``
— a fixed scheduler-independent, interpreter-bound workload — and all
baseline numbers are rescaled by the calibration ratio first.

Additionally, every audited row (``sched_overhead.audit_rows``) carries
an in-run ``audit_overhead`` ratio — audited pass over the paired
uninstrumented pass on the same graphs — that is bounded by
``AUDIT_OVERHEAD_LIMIT`` with no baseline or calibration involved.

The ``serving`` section (produced by ``serving_load.py``) gets three
gates of its own: the in-run incremental-rescoring speedup probe must
clear ``SERVING_SPEEDUP_FLOOR`` (baseline-free — both rescore modes run
in one process), serving events/sec is compared against the baseline
with the same calibrated tolerance, and per-configuration p99 tenant
slowdown — simulated time, so deterministic and uncalibrated — may not
grow past ``P99_SLOWDOWN_TOL``.

Usage (CI runs this right after ``sched_overhead.py``)::

    python benchmarks/sched_overhead.py
    python benchmarks/check_sched_regression.py

Refreshing the baseline after an intentional perf change::

    python benchmarks/sched_overhead.py
    cp benchmarks/results/BENCH_sched.json \
       benchmarks/results/BENCH_sched_baseline.json
"""
from __future__ import annotations

import json
import math
import sys
from pathlib import Path

if __package__ in (None, ""):
    _repo = Path(__file__).resolve().parents[1]
    for _p in (str(_repo), str(_repo / "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)

RESULTS = Path(__file__).resolve().parent / "results"
CURRENT = RESULTS / "BENCH_sched.json"
BASELINE = RESULTS / "BENCH_sched_baseline.json"

KEY_FIELDS = (
    "kernel", "strategy", "backend", "nt", "n_gpus", "capacity",
    "churn", "fault_mode", "flake", "notice", "exact", "audit",
    "tenants", "arrival", "rescore",
)

# hard bound on the measured slowdown of REPRO_SCHED_AUDIT=1 over the
# paired uninstrumented pass (sched_overhead.audit_rows measures both in
# one run, so the ratio is machine-speed-independent and needs no
# calibration scaling or committed baseline)
AUDIT_OVERHEAD_LIMIT = 3.0

# in-run floor on the serving speedup probe (serving_load.speedup_probe):
# incremental dirty-row rescoring vs the full-rescore baseline on the
# same arrival stream, same event cap, same process.  Demonstrated runs
# show ≥9×; the CI floor is deliberately loose so a noisy shared box
# never fails a healthy build, while a broken cache (speedup ≈ 1×)
# always does
SERVING_SPEEDUP_FLOOR = 1.5
# per-row bound on tenant-visible p99 slowdown vs the committed serving
# baseline.  Slowdown is *simulated* time — deterministic for a given
# seed and code — so no calibration scaling applies; growth beyond this
# factor means the scheduler's tail behavior regressed, not the machine
P99_SLOWDOWN_TOL = 0.25


def _rows_by_key(section: dict) -> dict:
    out = {}
    for row in section.get("whole_sim", []):
        # rows recorded before the surrogate engine existed are exact;
        # rows recorded before the audit log existed are unaudited; rows
        # recorded before flaky links / preemption notices existed ran
        # with both off; rows recorded before the serving layer existed
        # are single-tenant with no arrival process and rescoring off
        key = tuple(
            row.get(f, True) if f == "exact" else
            row.get(f, False) if f == "audit" else
            row.get(f, 0.0) if f in ("flake", "notice") else
            row.get(f, 1) if f == "tenants" else
            row.get(f, "none") if f == "arrival" else
            row.get(f, "off") if f == "rescore" else row.get(f)
            for f in KEY_FIELDS
        )
        out[key] = row
    return out


def _serving_rows_by_key(section: dict) -> dict:
    return {
        (row["tenants"], row["arrival"], row["strategy"]): row
        for row in section.get("rows", [])
    }


def _check_serving(cur_doc: dict, base_doc: dict, tol: float) -> bool:
    """Serving-load gates: the in-run incremental-rescoring speedup floor,
    events/sec vs the serving baseline, and the p99-slowdown tail bound.
    True when everything passes (or no serving section was measured)."""
    cur = cur_doc.get("serving")
    if not cur:
        print("no serving section in current results; serving gate skipped")
        return True
    ok = True

    # 1) in-run speedup probe: baseline-free, calibration-free
    probe = cur.get("speedup") or {}
    speedup = probe.get("speedup")
    if speedup is not None:
        mark = "ok  " if speedup >= SERVING_SPEEDUP_FLOOR else "FAIL"
        print(
            f"  [{mark}] serving incremental-rescore speedup at "
            f"{probe.get('tenants')} tenants: {speedup:.2f}x "
            f"(floor {SERVING_SPEEDUP_FLOOR:.1f}x)"
        )
        if speedup < SERVING_SPEEDUP_FLOOR:
            ok = False

    base = base_doc.get("serving")
    if not base:
        print("no serving section in baseline; serving baseline gate skipped")
        return ok

    # 2) events/sec vs the committed serving baseline (calibrated)
    cal_cur = cur.get("calibration_score") or 0.0
    cal_base = base.get("calibration_score") or 0.0
    scale = cal_cur / cal_base if cal_cur > 0 and cal_base > 0 else 1.0
    cur_rows = _serving_rows_by_key(cur)
    base_rows = _serving_rows_by_key(base)
    log_ratios = []
    tail_failures = []
    for key, brow in sorted(base_rows.items()):
        crow = cur_rows.get(key)
        if crow is None:
            continue
        expect = brow["events_per_s"] * scale
        got = crow["events_per_s"]
        if expect > 0 and got > 0:
            log_ratios.append(math.log(got / expect))
        # 3) the tenant-visible tail: deterministic simulated time
        b_p99, c_p99 = brow.get("p99_slowdown"), crow.get("p99_slowdown")
        if b_p99 and c_p99 and c_p99 > b_p99 * (1.0 + P99_SLOWDOWN_TOL):
            tail_failures.append((key, b_p99, c_p99))
            print(
                f"  [FAIL] serving p99 slowdown {'/'.join(map(str, key))}: "
                f"{c_p99:.2f} vs baseline {b_p99:.2f} "
                f"(limit +{P99_SLOWDOWN_TOL:.0%})"
            )
    if log_ratios:
        geo = math.exp(sum(log_ratios) / len(log_ratios))
        mark = "ok  " if geo >= 1.0 - tol else "FAIL"
        print(
            f"  [{mark}] serving events/sec vs baseline: {geo - 1.0:+.1%} "
            f"(geometric mean over {len(log_ratios)} configurations)"
        )
        if geo < 1.0 - tol:
            ok = False
    if tail_failures:
        print(
            f"serving p99 slowdown regressed on {len(tail_failures)} "
            "configuration(s) — gate FAILED"
        )
        ok = False
    return ok


def _check_audit_overhead(cur: dict) -> bool:
    """True when every audited row's in-run overhead ratio is in bounds."""
    ok = True
    for row in cur.get("whole_sim", []):
        ratio = row.get("audit_overhead")
        if ratio is None:
            continue
        mark = "ok  " if ratio <= AUDIT_OVERHEAD_LIMIT else "FAIL"
        print(
            f"  [{mark}] audit overhead {row['kernel']}/{row['strategy']}/"
            f"nt{row['nt']}: {ratio:.2f}x (limit {AUDIT_OVERHEAD_LIMIT:.1f}x)"
        )
        if ratio > AUDIT_OVERHEAD_LIMIT:
            ok = False
    return ok


def main() -> int:
    from repro.sched import current_config

    cfg = current_config()
    tol = cfg.regression_tol
    row_tol = cfg.row_tol
    if not CURRENT.exists():
        print(f"no current results at {CURRENT}; run sched_overhead.py first")
        return 1
    cur_doc = json.loads(CURRENT.read_text())
    cur = cur_doc.get("sched_overhead", {})
    # the audit-overhead bound is in-run (paired instrumented vs plain
    # pass), so it applies even without a committed baseline
    audit_ok = _check_audit_overhead(cur)
    if not audit_ok:
        print(
            f"audit instrumentation slower than {AUDIT_OVERHEAD_LIMIT:.1f}x "
            "the uninstrumented run — gate FAILED"
        )
    if not BASELINE.exists():
        print(f"no committed baseline at {BASELINE}; baseline gate skipped")
        serving_ok = _check_serving(cur_doc, {}, tol)
        return 0 if (audit_ok and serving_ok) else 1
    base_doc = json.loads(BASELINE.read_text())
    base = base_doc.get("sched_overhead", {})
    serving_ok = _check_serving(cur_doc, base_doc, tol)
    if not serving_ok:
        print("serving-load gate FAILED")
    cal_cur = cur.get("calibration_score") or 0.0
    cal_base = base.get("calibration_score") or 0.0
    if cal_cur <= 0 or cal_base <= 0:
        print("missing calibration figures; gate skipped")
        return 0
    scale = cal_cur / cal_base
    row_limit = row_tol if row_tol > 0 else 2 * tol
    print(
        f"calibration: current {cal_cur:.2f}, baseline {cal_base:.2f} "
        f"-> machine-speed scale {scale:.3f}; tolerance {tol:.0%} "
        f"aggregate / {row_limit:.0%} per-configuration"
        + ("" if row_tol > 0 else " (informational)")
    )

    cur_rows = _rows_by_key(cur)
    base_rows = _rows_by_key(base)
    collapsed = []
    log_ratios = []
    for key, brow in sorted(base_rows.items()):
        crow = cur_rows.get(key)
        if crow is None:
            continue  # configuration not measured in this run
        expect = brow["events_per_s"] * scale
        got = crow["events_per_s"]
        if expect <= 0 or got <= 0:
            continue
        ratio = got / expect
        log_ratios.append(math.log(ratio))
        mark = "ok  " if ratio >= 1.0 - row_limit else "FAIL"
        print(
            f"  [{mark}] {'/'.join(str(k) for k in key)}: "
            f"{got:.0f} ev/s vs scaled baseline {expect:.0f} "
            f"({ratio - 1.0:+.0%})"
        )
        if ratio < 1.0 - row_limit:
            collapsed.append(key)
    if not log_ratios:
        print("no overlapping configurations between run and baseline")
        return 0 if (audit_ok and serving_ok) else 1
    geo = math.exp(sum(log_ratios) / len(log_ratios))
    print(
        f"\naggregate events/sec vs baseline: {geo - 1.0:+.1%} "
        f"(geometric mean over {len(log_ratios)} configurations)"
    )
    failed = not (audit_ok and serving_ok)
    if geo < 1.0 - tol:
        print(f"aggregate drop exceeds {tol:.0%} — gate FAILED")
        failed = True
    if collapsed:
        print(
            f"note: {len(collapsed)} configuration(s) dropped more than "
            f"{row_limit:.0%}"
            + (
                " — gate FAILED"
                if row_tol > 0
                else " (informational; set REPRO_SCHED_ROW_TOL to gate on rows)"
            )
        )
        if row_tol > 0:
            failed = True
    if failed:
        return 1
    print("scheduler-throughput gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
