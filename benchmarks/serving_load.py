"""Open-loop serving benchmark: multi-tenant load on one live engine.

Sweeps tenant count × arrival process × strategy over the mixed graph
catalog (``repro.runtime.load.default_catalog``), driving every
configuration through ``run_serving`` with incremental rescoring — the
serving hot path this benchmark regression-gates.  Each row reports

  * engine throughput (events/sec, wall seconds, rows built), and
  * tenant-visible tails — p50/p99 makespan and slowdown vs the
    empty-machine baseline, queueing delay, Jain fairness — plus the
    admission counters,

into the ``serving`` section of ``results/BENCH_sched.json`` (consumed by
``check_sched_regression.py``).

The **speedup probe** is the headline: at 256 tenants the
same arrival stream is replayed twice in this one process — once with
``rescore="full"`` (rebuild every row, every round: the naive O(R·M)
baseline) and once with ``rescore="incremental"`` (dirty rows only) —
both capped at the same event count, so the events/sec ratio isolates
the scoring work the incremental cache elides.  The two modes place
bit-for-bit identically (tests/test_load_property.py pins this), so the
ratio is pure overhead, not a schedule change.

Knobs: REPRO_BENCH_FAST=1 drops the 1024-tenant column.  The arrival
rate is fixed at 2000 arrivals/sec — deep open-loop backlog at every
swept tenant count, so the scheduler (not the load generator) is what
gets measured.
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

if __package__ in (None, ""):
    _repo = Path(__file__).resolve().parents[1]
    for p in (str(_repo), str(_repo / "src")):
        if p not in sys.path:
            sys.path.insert(0, p)

from benchmarks.common import update_bench_json
from benchmarks.sched_overhead import calibration_score

TENANTS_FULL = (16, 64, 256, 1024)
TENANTS_FAST = (16, 64, 256)
ARRIVALS = ("poisson", "bursty", "diurnal")
STRATEGIES = ("heft", "dada?alpha=0.5&use_cp=1", "wfq")
# labels keep the regression key readable and stable across spec tweaks
STRATEGY_LABELS = {
    "heft": "heft",
    "dada?alpha=0.5&use_cp=1": "dada(a)+cp",
    "wfq": "wfq",
}
DEFAULT_RATE = 2000.0
# speedup probe: both rescore modes replay this many events of the same
# arrival stream — large enough that steady-state dirty-row behavior
# dominates, small enough that the full-rescore pass stays affordable
PROBE_EVENTS = 4000
# the probe runs at a fixed 256 tenants (present in fast and full sweeps
# alike): deep enough backlog that scoring dominates, small enough that
# the ready pool fits the cache's sweet spot — at 1024 tenants the pool
# itself (heap churn, dirty fan-out) eats into the win (≈2× vs ≈9×)
PROBE_TENANTS = 256


def serving_rows(tenant_counts, rate: float) -> list:
    from repro.configs.paper_machine import paper_machine
    from repro.runtime.load import make_arrivals, run_serving

    machine = paper_machine(4)
    rows = []
    # slowdown denominators are per (strategy, kind): share them across
    # the sweep so each is computed once
    baselines = {spec: {} for spec in STRATEGIES}
    for tenants in tenant_counts:
        for arrival in ARRIVALS:
            arr = make_arrivals(arrival, tenants, rate=rate, seed=7)
            for spec in STRATEGIES:
                label = STRATEGY_LABELS[spec]
                # best-of-2: a transient stall must not record a phantom
                # slowdown into the perf trajectory (simulated results
                # are seeded — repetitions reproduce the same schedule)
                dt = float("inf")
                out = None
                for _rep in range(2):
                    t0 = time.perf_counter()
                    out = run_serving(
                        arr, machine, spec, seed=0,
                        rescore="incremental",
                        baselines=baselines[spec],
                    )
                    dt = min(dt, time.perf_counter() - t0)
                rep = out["report"]
                row = dict(
                    tenants=tenants, arrival=arrival, strategy=label,
                    rescore="incremental", rate=rate,
                    wall_s=round(dt, 4), events=out["n_events"],
                    events_per_s=(
                        round(out["n_events"] / dt, 1) if dt > 0 else 0.0
                    ),
                    rows_built=out["rows_built"],
                    n_admitted=out["n_admitted"],
                    n_rejected=out["n_rejected"],
                    p50_makespan=rep["p50_makespan"],
                    p99_makespan=rep["p99_makespan"],
                    p50_slowdown=rep["p50_slowdown"],
                    p99_slowdown=rep["p99_slowdown"],
                    p50_queue_delay=rep["p50_queue_delay"],
                    p99_queue_delay=rep["p99_queue_delay"],
                    mean_slowdown=rep["mean_slowdown"],
                    jain_fairness=rep["jain_fairness"],
                )
                rows.append(row)
                print(
                    f"serving/{arrival}/{label}/tenants{tenants},"
                    f"{dt * 1e6:.1f},"
                    f"events_per_s={row['events_per_s']};"
                    f"p99_slowdown={row['p99_slowdown']:.2f};"
                    f"jain={row['jain_fairness']:.3f}"
                )
    return rows


def speedup_probe(tenants: int, rate: float) -> dict:
    """Full-rescore vs incremental events/sec on the same arrival stream,
    same process, same event cap — the incremental-rescoring headline."""
    from repro.configs.paper_machine import paper_machine
    from repro.runtime.load import make_arrivals, run_serving

    machine = paper_machine(4)
    arr = make_arrivals("poisson", tenants, rate=rate, seed=7)
    probe = {}
    for mode in ("full", "incremental"):
        dt = float("inf")
        out = None
        for _rep in range(2):
            t0 = time.perf_counter()
            out = run_serving(
                arr, machine, "heft", seed=0,
                rescore=mode, max_events=PROBE_EVENTS,
            )
            dt = min(dt, time.perf_counter() - t0)
        probe[mode] = dict(
            wall_s=round(dt, 4), events=out["n_events"],
            events_per_s=round(out["n_events"] / dt, 1) if dt > 0 else 0.0,
            rows_built=out["rows_built"],
        )
    full_ev = probe["full"]["events_per_s"]
    incr_ev = probe["incremental"]["events_per_s"]
    speedup = round(incr_ev / full_ev, 2) if full_ev > 0 else 0.0
    result = dict(
        tenants=tenants, arrival="poisson", strategy="heft",
        max_events=PROBE_EVENTS, rate=rate,
        full=probe["full"], incremental=probe["incremental"],
        speedup=speedup,
    )
    print(
        f"serving/speedup/tenants{tenants},"
        f"{probe['incremental']['wall_s'] * 1e6:.1f},"
        f"incremental={incr_ev};full={full_ev};speedup={speedup}x"
    )
    return result


def main() -> dict:
    from repro.sched import current_config

    cfg = current_config()
    fast = cfg.bench_fast
    tenant_counts = list(TENANTS_FAST if fast else TENANTS_FULL)
    rate = DEFAULT_RATE

    print("name,us_per_call,derived")
    rows = serving_rows(tenant_counts, rate)
    probe = speedup_probe(PROBE_TENANTS, rate)
    payload = dict(
        config=dict(tenants=tenant_counts, arrivals=list(ARRIVALS),
                    strategies=list(STRATEGY_LABELS.values()), rate=rate),
        calibration_score=round(calibration_score(), 2),
        rows=rows,
        speedup=probe,
    )
    update_bench_json("serving", payload)
    return payload


if __name__ == "__main__":
    main()
