"""Fig. 1 — impact of the affinity control parameter alpha on Cholesky
(DPOTRF), matrix 8192x8192: performance and transfers vs #GPUs, for several
alpha values, with and without communication prediction."""
from __future__ import annotations

from functools import partial

from repro.sched import resolve

from .common import bench_settings, emit_csv_lines, sweep

ALPHAS = [0.0, 0.25, 0.5, 0.75, 1.0]


def main() -> list:
    runs, gpus = bench_settings()
    strategies = {}
    for a in ALPHAS:
        strategies[f"dada({a:g})"] = partial(resolve, f"dada?alpha={a:g}")
    for a in ALPHAS:
        strategies[f"dada({a:g})+cp"] = partial(
            resolve, f"dada?alpha={a:g}&use_cp=1"
        )
    rows = sweep("fig1_alpha_sweep", "cholesky", strategies, runs, gpus)
    emit_csv_lines(rows)
    return rows


if __name__ == "__main__":
    main()
