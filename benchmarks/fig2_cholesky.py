"""Fig. 2 — Cholesky (DPOTRF) 8192^2: HEFT vs DADA(0) vs DADA(a) vs
DADA(a)+CP (+ the work-stealing baseline discussed in §4.3)."""
from __future__ import annotations

from .common import STRATEGIES, bench_settings, emit_csv_lines, sweep


def main() -> list:
    runs, gpus = bench_settings()
    rows = sweep("fig2_cholesky", "cholesky", STRATEGIES, runs, gpus)
    emit_csv_lines(rows)
    return rows


if __name__ == "__main__":
    main()
