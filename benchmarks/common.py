"""Shared benchmark harness for the paper-figure reproductions.

Methodology mirrors the paper (§4.1): each configuration is run repeatedly
with different seeds; we report mean and 95% CI of GFLOPS and total
transferred GB. Matrix 8192x8192, tile 512 (16x16 tiles), inner block 128,
fp64 item size — the paper's exact problem shape.

Environment knobs:
  REPRO_BENCH_RUNS   repetitions per configuration (default 30, paper-level)
  REPRO_BENCH_GPUS   comma list of GPU counts       (default 1..8)
  REPRO_BENCH_FAST   =1 shrinks to 3 runs x {2,4,8} GPUs for smoke use
  REPRO_BENCH_JOBS   process-pool width for the seeded repetitions
                     (default: CPU count; 1 forces the serial path)

Factories are ``functools.partial`` over module-level callables (not
lambdas) so ``run_many`` can ship them to its process pool.
"""
from __future__ import annotations

import csv
from functools import partial
from pathlib import Path
from typing import Callable, Dict, List

from repro.configs.paper_machine import paper_machine
from repro.core import Summary, default_jobs, get_pool, run_many
from repro.linalg.cholesky import cholesky_graph
from repro.sched import resolve
from repro.linalg.lu import lu_graph
from repro.linalg.qr import qr_graph

MATRIX = 8192
TILE = 512
NT = MATRIX // TILE
RESULTS_DIR = Path(__file__).resolve().parent / "results"
BENCH_JSON = RESULTS_DIR / "BENCH_sched.json"

def graphs_for(nt: int, tile: int = TILE) -> Dict[str, Callable]:
    """Paper-kernel graph factories at an arbitrary tile-grid size NT
    (scheduler-scaling sweeps use NT ∈ {32, 64}; the paper shape is 16)."""
    return {
        "cholesky": partial(cholesky_graph, nt, tile, with_fns=False),
        "lu": partial(lu_graph, nt, tile, with_fns=False),
        "qr": partial(qr_graph, nt, tile, with_fns=False),
    }


GRAPHS: Dict[str, Callable] = graphs_for(NT)


def machine_for(n_gpus: int, n_cpus: int = None):
    """The paper box for paper-sized configs, the scaled 32-resource-class
    platform beyond it (n_gpus > 8 or an explicit CPU count)."""
    from repro.configs.paper_machine import scaled_machine

    if n_cpus is None and 0 <= n_gpus <= 8:
        return paper_machine(n_gpus)
    return scaled_machine(n_gpus=n_gpus, n_cpus=8 if n_cpus is None else n_cpus)


def update_bench_json(section: str, payload) -> Path:
    """Merge one section into ``results/BENCH_sched.json``.

    The file tracks the scheduler-performance trajectory across PRs
    (events/sec per strategy and backend, wall times, λ-probe latencies);
    each producing script owns one top-level key so ``sched_overhead.py``
    and ``paper_validation.py`` can update it independently.
    """
    import json

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    doc = {}
    if BENCH_JSON.exists():
        try:
            doc = json.loads(BENCH_JSON.read_text())
        except (ValueError, OSError) as exc:
            # never silently drop another producer's section: the file is
            # a cross-PR trajectory, so make the reset loud
            print(
                f"warning: {BENCH_JSON} was unreadable ({exc}); "
                f"starting a fresh trajectory file",
                flush=True,
            )
            doc = {}
    doc["schema"] = 1
    doc[section] = payload
    BENCH_JSON.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    return BENCH_JSON


def bench_settings():
    """(runs, gpu_counts) from the validated ``SchedConfig`` (one parse
    for every ``REPRO_BENCH_*`` knob; malformed values fail loudly there)."""
    from repro.sched import current_config

    cfg = current_config()
    runs = cfg.bench_runs if cfg.bench_runs is not None else (3 if cfg.bench_fast else 30)
    if cfg.bench_gpus is not None:
        gpus = list(cfg.bench_gpus)
    else:
        gpus = [2, 4, 8] if cfg.bench_fast else [1, 2, 3, 4, 5, 6, 7, 8]
    return runs, gpus


# one code path for every consumer: specs resolved through the policy
# registry (repro.sched), identical objects to the old direct constructors
STRATEGIES: Dict[str, Callable] = {
    "heft": partial(resolve, "heft"),
    "ws": partial(resolve, "ws"),
    "dada(0)": partial(resolve, "dada?alpha=0"),
    "dada(a)": partial(resolve, "dada?alpha=0.5"),
    "dada(a)+cp": partial(resolve, "dada?alpha=0.5&use_cp=1"),
}


def _sweep_config(graph_factory, machine, sfac, n_runs: int) -> Summary:
    """One (strategy × machine) configuration, run serially (pool worker)."""
    return run_many(graph_factory, machine, sfac, n_runs=n_runs, n_jobs=1)


def spec_of(sfac) -> str:
    """Recover the registry spec string from a ``partial(resolve, spec)``.

    The batched surrogate path needs the *spec*, not a constructed policy
    object: strategy parameters become batch axes, so the episode engine
    re-derives (α, use_cp, ws) from the string."""
    if isinstance(sfac, partial) and sfac.func is resolve and sfac.args:
        return sfac.args[0]
    raise ValueError(
        "batched sweep (REPRO_SCHED_EXACT=0) needs partial(resolve, spec) "
        f"strategy factories, got {sfac!r}; run it on the exact path"
    )


def _ci95(xs) -> float:
    import math

    import numpy as np

    if len(xs) < 2:
        return 0.0
    return 1.96 * float(np.std(xs, ddof=1)) / math.sqrt(len(xs))


def _sweep_batched(configs, graph_factory, n_runs: int) -> List[Summary]:
    """Surrogate path: the whole figure sweep as a handful of dispatches.

    Every (strategy × GPU-count × seed) cell becomes one row of a
    ``run_batch`` call — seeds and strategy parameters are batch axes of
    a single compiled episode, so the sweep cost is a few ``lax.scan``
    dispatches instead of |configs| × n_runs Python event loops.
    """
    from repro.core import cached_graph, run_batch

    graph = cached_graph(graph_factory)
    machines = {}
    items = []
    for n_gpus, label, sfac in configs:
        m = machines.setdefault(n_gpus, machine_for(n_gpus))
        spec = spec_of(sfac)
        for i in range(n_runs):
            items.append(
                {"graph": graph, "machine": m, "strategy": spec,
                 "seed": 1234 + i, "noise": 0.03}
            )
    results = run_batch(items)
    summaries = []
    for k, (n_gpus, label, sfac) in enumerate(configs):
        rs = results[k * n_runs : (k + 1) * n_runs]
        gf = [r.gflops for r in rs]
        gb = [r.gbytes for r in rs]
        summaries.append(
            Summary(
                strategy=label, n=n_runs,
                gflops_mean=float(sum(gf) / len(gf)), gflops_ci95=_ci95(gf),
                gbytes_mean=float(sum(gb) / len(gb)), gbytes_ci95=_ci95(gb),
                makespan_mean=float(sum(r.makespan for r in rs) / len(rs)),
                steals_mean=0.0,
            )
        )
    return summaries


def sweep(
    fig: str,
    kernel: str,
    strategies: Dict[str, Callable],
    n_runs: int,
    gpu_counts: List[int],
) -> List[dict]:
    """Run strategies x gpu-counts; persist CSV; return row dicts.

    Configurations fan out over the shared process pool (one pool task per
    strategy × GPU-count, each running its seeded repetitions serially —
    coarser tasks than per-seed fan-out, so 2 workers stay busy end to
    end). Each configuration is independently seeded, so results are
    bit-identical to the serial loop and are gathered in sweep order.

    An empty sweep (no strategies or no GPU counts, e.g. an empty
    ``REPRO_BENCH_GPUS``) returns ``[]`` with a warning instead of
    crashing on the CSV header row.
    """
    rows = []
    if not strategies or not gpu_counts:
        print(
            f"  {fig} {kernel}: empty sweep "
            f"({len(strategies)} strategies x {len(gpu_counts)} gpu counts) — skipping",
            flush=True,
        )
        return rows
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    out_path = RESULTS_DIR / f"{fig}.csv"
    graph_factory = GRAPHS[kernel]

    configs = [
        (n_gpus, label, sfac)
        for n_gpus in gpu_counts
        for label, sfac in strategies.items()
    ]

    from repro.sched import current_config

    batched = not current_config().exact
    summaries: List[Summary] = (
        _sweep_batched(configs, graph_factory, n_runs) if batched else []
    )
    n_jobs = default_jobs(len(configs))
    futs = None
    if not batched and n_jobs > 1 and len(configs) > 1:
        try:
            import pickle

            pickle.dumps([sfac for _, _, sfac in configs] + [graph_factory])
            pool = get_pool(n_jobs)
            futs = [
                pool.submit(
                    _sweep_config, graph_factory, paper_machine(n_gpus), sfac, n_runs
                )
                for n_gpus, label, sfac in configs
            ]
        except Exception:
            futs = None  # non-picklable factories: run serially below

    for k, (n_gpus, label, sfac) in enumerate(configs):
        if batched:
            s = summaries[k]
        elif futs is not None:
            s = futs[k].result()
        else:
            s = _sweep_config(graph_factory, paper_machine(n_gpus), sfac, n_runs)
        row = dict(
            fig=fig,
            kernel=kernel,
            strategy=label,
            n_gpus=n_gpus,
            n_runs=s.n,
            gflops=round(s.gflops_mean, 2),
            gflops_ci95=round(s.gflops_ci95, 2),
            gbytes=round(s.gbytes_mean, 4),
            gbytes_ci95=round(s.gbytes_ci95, 4),
            makespan_s=round(s.makespan_mean, 5),
            steals=round(s.steals_mean, 1),
        )
        rows.append(row)
        print(
            f"  {fig} {kernel} gpus={n_gpus} {label:12s} "
            f"{row['gflops']:8.1f} GF (±{row['gflops_ci95']}) "
            f"{row['gbytes']:7.3f} GB (±{row['gbytes_ci95']})",
            flush=True,
        )
    with out_path.open("w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)
    return rows


def emit_csv_lines(rows: List[dict]) -> None:
    """Skeleton contract: ``name,us_per_call,derived`` lines on stdout."""
    for r in rows:
        name = f"{r['fig']}/{r['kernel']}/{r['strategy']}/gpus{r['n_gpus']}"
        us = r["makespan_s"] * 1e6
        print(f"{name},{us:.1f},gflops={r['gflops']};gbytes={r['gbytes']}")
