"""Benchmark harness: one function per paper figure/table.

Prints ``name,us_per_call,derived`` CSV rows per configuration, followed by
the paper-claim validation summary. See common.py for env knobs
(REPRO_BENCH_FAST=1 for a quick pass).
"""
from __future__ import annotations

import sys


def main() -> None:
    from . import fig1_alpha_sweep, fig2_cholesky, fig3_lu, fig4_qr, fig_ws_discussion
    from .paper_validation import print_checks, validate

    print("name,us_per_call,derived")
    f1 = fig1_alpha_sweep.main()
    f2 = fig2_cholesky.main()
    f3 = fig3_lu.main()
    f4 = fig4_qr.main()
    print("== §4.3 work-stealing discussion ==")
    fig_ws_discussion.main()
    ok = print_checks(validate(f1, f2, f3, f4))
    if not ok:
        print("WARNING: some paper claims did not reproduce — see above", file=sys.stderr)


if __name__ == "__main__":
    main()
