"""Fig. 3 — LU (DGETRF) 8192^2: HEFT vs dual-approximation variants.

Paper headline: DADA(a)+CP moves ~3.5x less data than HEFT at 8 GPUs for
only ~1.13x slowdown."""
from __future__ import annotations

from .common import STRATEGIES, bench_settings, emit_csv_lines, sweep


def main() -> list:
    runs, gpus = bench_settings()
    rows = sweep("fig3_lu", "lu", STRATEGIES, runs, gpus)
    emit_csv_lines(rows)
    return rows


if __name__ == "__main__":
    main()
