"""Churn scenario matrix: proactive recovery scored against the
clairvoyant bound (claim C9).

Sweeps churn rate × recovery mode × notice window × strategy over seeded
repetitions. Every faulted run is scored by ``recovery_report`` against
its own clairvoyant no-fault baseline — the same graph, machine,
strategy and seed with no detach/attach events — so slowdown and extra
bytes isolate what the faults cost, not what the strategy costs.

Claim C9, checked per (churn, mode, strategy) pair and per cell:

  * **notice helps** — with a preemption-notice window open the engine
    stops feeding the dying device and replicates its sole copies off
    proactively, so mean ``wasted_s`` (kill-mode lost work) and mean
    reactive evacuation bytes (death-time salvage on the critical
    recovery path) must not exceed the blind notice=0 run of the same
    strategy at the same churn level and recovery mode;
  * **C8 persists** — DADA's transfer-volume advantage over HEFT holds
    across the whole matrix: mean faulted total bytes of the
    notice-aware dada(a)+cp+rec (identical to dada(a)+cp while no
    notice is pending) stay at or below HEFT's in every (churn, mode,
    notice) cell. Plain dada(a)+cp is reported too: with a notice open
    its affinity objective keeps pulling work toward the condemned
    device, and the byte gap between the two variants is the measured
    cost of that trap — the reason ``recover=1`` exists.

Uncertainty is reported as seeded-bootstrap 95% CIs (percentile method
over seed means), not normal-theory CIs: slowdown under churn is heavy
tailed — one unlucky detach at the critical-path root dominates a seed.

Results go to ``results/scenario_matrix.csv`` and the
``scenario_matrix`` section of ``results/BENCH_sched.json``; the claim
table prints PASS/FAIL and the process exits 1 on any C9 failure unless
``REPRO_BENCH_ALLOW_FAIL=1``.

Knobs: ``REPRO_BENCH_RUNS`` (seeds per cell, default 20),
``REPRO_BENCH_FAST=1`` (3 seeds, 1×2×2 matrix, NT=6 — the CI smoke
shape), plus the fault knobs the engine itself validates.
"""
from __future__ import annotations

import csv
import sys
import time
from pathlib import Path
from typing import Dict, List, Tuple

if __package__ in (None, ""):  # `python benchmarks/scenario_matrix.py`
    _repo = Path(__file__).resolve().parents[1]
    for p in (str(_repo), str(_repo / "src")):
        if p not in sys.path:
            sys.path.insert(0, p)

import numpy as np

from repro.configs.paper_machine import paper_machine
from repro.core import Simulator
from repro.linalg.lu import lu_graph
from repro.runtime import recovery_report
from repro.sched import current_config, resolve

from benchmarks.common import RESULTS_DIR, update_bench_json

# matrix axes: a light and a heavy churn regime (events per unit sim
# time over a ~0.1 s trace: a few vs dozens of detach cycles), both
# recovery modes, notice window off vs ~one-task-length open
MODES = ("drain", "kill")
NOTICE_W = 0.008
STRATEGIES: Dict[str, str] = {
    "heft": "heft",
    "dada(a)+cp": "dada?alpha=0.5&use_cp=1",
    "dada(a)+cp+rec": "dada?alpha=0.5&use_cp=1&recover=1",
}
SEED0 = 1234
N_BOOT = 2000


def _settings() -> Tuple[int, int, Tuple[float, ...]]:
    """(n_seeds, nt, churn_levels) honouring the bench knobs."""
    cfg = current_config()
    if cfg.bench_fast:
        runs = cfg.bench_runs if cfg.bench_runs is not None else 3
        return runs, 6, (250.0,)
    runs = cfg.bench_runs if cfg.bench_runs is not None else 20
    return runs, 12, (40.0, 150.0)


def _boot_ci(xs: List[float], rng: np.random.Generator) -> Tuple[float, float]:
    """Seeded percentile-bootstrap 95% CI of the mean."""
    arr = np.asarray(xs, dtype=np.float64)
    if arr.size < 2:
        v = float(arr[0]) if arr.size else 0.0
        return v, v
    means = rng.choice(arr, size=(N_BOOT, arr.size), replace=True).mean(axis=1)
    lo, hi = np.percentile(means, (2.5, 97.5))
    return float(lo), float(hi)


def run_matrix() -> Tuple[List[dict], List[dict]]:
    n_seeds, nt, churn_levels = _settings()
    graph = lu_graph(nt, 512, with_fns=False)
    machine_gpus = 8
    print(
        f"scenario matrix: NT={nt}, {n_seeds} seeds, churn {churn_levels}, "
        f"modes {MODES}, notice (0.0, {NOTICE_W:g}), "
        f"{len(STRATEGIES)} strategies",
        flush=True,
    )

    # clairvoyant baselines: per (strategy, seed), fault-free — shared by
    # every cell of that strategy, which is what makes the bound a bound
    baselines = {}
    for label, spec in STRATEGIES.items():
        for i in range(n_seeds):
            baselines[(label, i)] = Simulator(
                graph, paper_machine(machine_gpus), resolve(spec),
                seed=SEED0 + i, noise=0.0,
            ).run()

    rows: List[dict] = []
    cells: Dict[Tuple[float, str, float, str], dict] = {}
    for churn in churn_levels:
        for mode in MODES:
            for notice in (0.0, NOTICE_W):
                for label, spec in STRATEGIES.items():
                    reports = []
                    bytes_f = []
                    for i in range(n_seeds):
                        res = Simulator(
                            graph, paper_machine(machine_gpus), resolve(spec),
                            seed=SEED0 + i, noise=0.0,
                            churn=churn, fault_mode=mode, notice_s=notice,
                        ).run()
                        reports.append(
                            recovery_report(res, baselines[(label, i)])
                        )
                        bytes_f.append(float(res.total_bytes))
                    rng = np.random.default_rng(
                        (SEED0, int(churn), MODES.index(mode),
                         int(notice * 1e6), sorted(STRATEGIES).index(label))
                    )
                    slow = [r["slowdown"] for r in reports]
                    extra = [r["extra_bytes"] for r in reports]
                    s_lo, s_hi = _boot_ci(slow, rng)
                    b_lo, b_hi = _boot_ci(extra, rng)
                    mean = lambda k: float(
                        np.mean([r.get(k, 0.0) for r in reports])
                    )
                    row = dict(
                        kernel="lu", nt=nt, n_gpus=machine_gpus,
                        churn=churn, fault_mode=mode, notice=notice,
                        strategy=label, n_seeds=n_seeds,
                        slowdown_mean=round(float(np.mean(slow)), 4),
                        slowdown_ci95=[round(s_lo, 4), round(s_hi, 4)],
                        extra_bytes_mean=round(float(np.mean(extra)), 1),
                        extra_bytes_ci95=[round(b_lo, 1), round(b_hi, 1)],
                        total_bytes_mean=round(float(np.mean(bytes_f)), 1),
                        wasted_s_mean=round(mean("wasted_s"), 6),
                        reactive_bytes_mean=round(
                            mean("reactive_evacuated_bytes"), 1
                        ),
                        proactive_bytes_mean=round(mean("proactive_bytes"), 1),
                        n_detaches_mean=round(mean("n_detaches"), 2),
                        n_notices_mean=round(mean("n_notices"), 2),
                    )
                    rows.append(row)
                    cells[(churn, mode, notice, label)] = row
                    print(
                        f"  churn={churn:g} {mode:5s} notice={notice:g} "
                        f"{label:14s} slowdown {row['slowdown_mean']:.3f} "
                        f"[{s_lo:.3f},{s_hi:.3f}]  wasted {row['wasted_s_mean']:.4g}s  "
                        f"reactive {row['reactive_bytes_mean'] / 1e6:.1f}MB  "
                        f"proactive {row['proactive_bytes_mean'] / 1e6:.1f}MB",
                        flush=True,
                    )

    # ---- claim C9 --------------------------------------------------------
    checks: List[dict] = []
    for churn in churn_levels:
        for mode in MODES:
            for label in STRATEGIES:
                blind = cells[(churn, mode, 0.0, label)]
                noted = cells[(churn, mode, NOTICE_W, label)]
                ok = (
                    noted["wasted_s_mean"] <= blind["wasted_s_mean"] + 1e-9
                    and noted["reactive_bytes_mean"]
                    <= blind["reactive_bytes_mean"] * 1.05 + 1.0
                )
                checks.append(
                    dict(
                        claim=(
                            f"C9 notice cuts waste: churn={churn:g} {mode} "
                            f"{label}"
                        ),
                        measured=(
                            f"wasted {blind['wasted_s_mean']:.4g}->"
                            f"{noted['wasted_s_mean']:.4g}s, reactive "
                            f"{blind['reactive_bytes_mean'] / 1e6:.1f}->"
                            f"{noted['reactive_bytes_mean'] / 1e6:.1f}MB "
                            f"(proactive {noted['proactive_bytes_mean'] / 1e6:.1f}MB)"
                        ),
                        passed=ok,
                    )
                )
            for notice in (0.0, NOTICE_W):
                heft = cells[(churn, mode, notice, "heft")]
                dada = cells[(churn, mode, notice, "dada(a)+cp+rec")]
                checks.append(
                    dict(
                        claim=(
                            f"C9/C8 dada+rec bytes <= heft: churn={churn:g} "
                            f"{mode} notice={notice:g}"
                        ),
                        measured=(
                            f"dada+rec {dada['total_bytes_mean'] / 1e9:.3f}GB "
                            f"vs heft {heft['total_bytes_mean'] / 1e9:.3f}GB"
                        ),
                        passed=(
                            dada["total_bytes_mean"]
                            <= heft["total_bytes_mean"] * 1.05
                        ),
                    )
                )
            # the recover variant must not lose to notice-blind dada while
            # a notice window is open (the affinity-trap cost it removes)
            cp = cells[(churn, mode, NOTICE_W, "dada(a)+cp")]
            rec = cells[(churn, mode, NOTICE_W, "dada(a)+cp+rec")]
            checks.append(
                dict(
                    claim=(
                        f"C9 recover beats notice-blind dada: churn={churn:g} "
                        f"{mode}"
                    ),
                    measured=(
                        f"bytes {cp['total_bytes_mean'] / 1e9:.3f}->"
                        f"{rec['total_bytes_mean'] / 1e9:.3f}GB, slowdown "
                        f"{cp['slowdown_mean']:.3f}->{rec['slowdown_mean']:.3f}"
                    ),
                    passed=(
                        rec["total_bytes_mean"]
                        <= cp["total_bytes_mean"] * 1.02
                    ),
                )
            )
    return rows, checks


def main() -> int:
    t0 = time.perf_counter()
    rows, checks = run_matrix()

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    out_csv = RESULTS_DIR / "scenario_matrix.csv"
    flat = [
        {
            k: (f"{v[0]}..{v[1]}" if isinstance(v, list) else v)
            for k, v in r.items()
        }
        for r in rows
    ]
    with out_csv.open("w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(flat[0].keys()))
        w.writeheader()
        w.writerows(flat)
    update_bench_json("scenario_matrix", {"rows": rows, "claims": checks})

    print("\n== scenario-matrix claims ==")
    ok = True
    for c in checks:
        status = "PASS" if c["passed"] else "FAIL"
        ok = ok and c["passed"]
        print(f"  [{status}] {c['claim']}\n         measured: {c['measured']}")
    print(f"\ntotal wall-clock {time.perf_counter() - t0:.1f}s -> {out_csv}")
    if not ok and not current_config().bench_allow_fail:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
