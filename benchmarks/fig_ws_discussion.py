"""§4.3 "Comparison with work stealing" discussion, as a benchmark.

The paper reports (without a figure) that naive work stealing is cache-
unfriendly for small matrices while affinity policies handle them well, and
that model-oblivious stealing stays competitive at larger sizes. This
benchmark quantifies both halves across matrix sizes.
"""
from __future__ import annotations

from functools import partial

from repro.configs.paper_machine import paper_machine
from repro.core import run_many
from repro.sched import resolve
from repro.linalg.cholesky import cholesky_graph

from .common import bench_settings


def main() -> list:
    runs, _ = bench_settings()
    machine = paper_machine(4)
    rows = []
    for n in (2048, 4096, 8192, 16384):
        nt = n // 512
        for label, fac in [
            ("ws", partial(resolve, "ws")),
            ("heft", partial(resolve, "heft")),
            ("dada(a)+cp", partial(resolve, "dada?alpha=0.5&use_cp=1")),
        ]:
            s = run_many(
                partial(cholesky_graph, nt, 512, with_fns=False),
                machine, fac, n_runs=max(3, runs // 3),
            )
            rows.append(dict(
                n=n, strategy=label, gflops=round(s.gflops_mean, 1),
                gbytes=round(s.gbytes_mean, 3), steals=s.steals_mean,
            ))
            print(f"  ws_discussion n={n:5d} {label:12s} "
                  f"{s.gflops_mean:7.1f} GF {s.gbytes_mean:7.3f} GB "
                  f"steals={s.steals_mean:.0f}", flush=True)
    return rows


if __name__ == "__main__":
    main()
