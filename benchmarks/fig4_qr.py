"""Fig. 4 — QR (DGEQRF) 8192^2: the kernel where HEFT outperforms every
dual-approximation variant (paper §4.3)."""
from __future__ import annotations

from .common import STRATEGIES, bench_settings, emit_csv_lines, sweep


def main() -> list:
    runs, gpus = bench_settings()
    rows = sweep("fig4_qr", "qr", STRATEGIES, runs, gpus)
    emit_csv_lines(rows)
    return rows


if __name__ == "__main__":
    main()
