"""Roofline report over all dry-run cells (single-pod table per spec;
multi-pod rows appended for the pod-axis collective comparison).

Run after ``python -m repro.launch.dryrun``:
  PYTHONPATH=src python -m benchmarks.roofline
"""
from __future__ import annotations

import csv
from pathlib import Path

from repro.analysis.roofline import load_rows, table

RESULTS = Path(__file__).resolve().parent.parent / "results"


def main() -> None:
    rows = load_rows(RESULTS / "dryrun", mesh="pod1")
    print(table(rows))
    out = RESULTS / "roofline.csv"
    with out.open("w", newline="") as f:
        w = csv.writer(f)
        w.writerow(
            ["arch", "shape", "mesh", "chips", "compute_s", "memory_s",
             "collective_s", "bottleneck", "mfu_est", "model_flops",
             "analytic_flops", "hlo_flops_raw", "useful_ratio"]
        )
        for r in rows + load_rows(RESULTS / "dryrun", mesh="pod2"):
            w.writerow(
                [r.arch, r.shape, r.mesh, r.chips, r.compute_s, r.memory_s,
                 r.collective_s, r.bottleneck, round(r.mfu_est, 4),
                 r.model_flops, r.analytic_flops, r.hlo_flops_raw,
                 round(r.useful_ratio, 4)]
            )
    print(f"\nwrote {out}")
    # hillclimb candidates
    if rows:
        worst = min(rows, key=lambda r: r.mfu_est)
        coll = max(rows, key=lambda r: r.collective_s / max(r.step_s, 1e-12))
        print(f"\nworst MFU_est      : {worst.arch} x {worst.shape} ({worst.mfu_est*100:.1f}%)")
        print(f"most collective-bnd: {coll.arch} x {coll.shape} "
              f"({coll.collective_s/max(coll.step_s,1e-12)*100:.0f}% of step)")


if __name__ == "__main__":
    main()
