"""Scheduler-overhead microbenchmark: events/sec of the scheduling core.

The paper's sweeps are bottlenecked by the scheduler's own per-decision
cost, not by the simulated workload (cf. Amaris et al., arXiv:1711.06433 on
keeping dual-approximation decisions cheap). This benchmark isolates that
cost along two axes:

  * **whole-sim throughput** — for each strategy × backend it runs seeded
    simulations of the paper-shaped kernels (NT from ``REPRO_BENCH_NT``)
    and reports wall-clock, simulator events/sec and tasks/sec. Two extra
    row families gate the layered runtime: a **capacity-bounded** pass
    (32 MB device memories, affinity eviction — the eviction/write-back/
    pressure path), a **multi-graph streaming** row (four tenant DAGs
    interleaving on one ``repro.runtime.Engine``, with per-graph
    makespans), and a **churned** row family (seeded GPU detach/attach at
    ``CHURN_RATE`` under both recovery modes — the fault-handling path),
    a **recovery** row family (flaky links at ``FLAKE_RATE`` — the
    retry/backoff/re-source path — and churn with ``NOTICE_S`` preemption
    notices — grace windows and proactive replication),
    an **audited** row family (``audit=True``: the schedule-verifier's
    audit log live, with the measured ``audit_overhead`` ratio over the
    paired uninstrumented pass — gated by ``AUDIT_OVERHEAD_LIMIT``),
    and a **batched-sweep** row family (``exact=False``): whole strategy ×
    GPU-count × seed sweeps through ``repro.core.run_batch`` — the
    ``REPRO_SCHED_EXACT=0`` surrogate engine — reporting configs/sec,
    per-dispatch batch size and the speedup over the same configurations
    replayed through the exact engine;
  * **λ-probe placement** — one wide ready wave of an NT=64 Cholesky on
    the 32-resource scaled machine, timed through ``DADA.place`` per
    backend: this is the (ready × resources × λ-probes) scoring kernel the
    jax backend batches, and the metric the ≥3× acceptance gate reads. The
    wave's placement decisions are asserted identical across backends.

Results go to stdout (``name,us_per_call,derived`` contract) and to
``results/BENCH_sched.json`` (consumed by ``check_sched_regression.py``).

Knobs: REPRO_BENCH_GPUS (first entry, default 8), REPRO_BENCH_RUNS
(default 3), REPRO_BENCH_NT (comma list, default 16), REPRO_SCHED_BACKENDS
(default ``numpy,jax`` when jax imports, else ``numpy``),
REPRO_BENCH_LAMBDA (=0 skips the λ-probe section), REPRO_BENCH_LAMBDA_NT
(default 64), REPRO_BENCH_LAMBDA_REPS (default 3).
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

if __package__ in (None, ""):
    _repo = Path(__file__).resolve().parents[1]
    for p in (str(_repo), str(_repo / "src")):
        if p not in sys.path:
            sys.path.insert(0, p)

from functools import partial

from repro.core import Simulator
from repro.sched import resolve

from benchmarks.common import graphs_for, machine_for, update_bench_json


def strategies(backend: str):
    """Backend-scored strategies, resolved through the policy registry
    (the same code path every other benchmark and the tests use)."""
    return {
        "heft": partial(resolve, "heft", backend=backend),
        "dada(0)": partial(resolve, "dada?alpha=0", backend=backend),
        "dada(a)": partial(resolve, "dada?alpha=0.5", backend=backend),
        "dada(a)+cp": partial(
            resolve, "dada?alpha=0.5&use_cp=1", backend=backend
        ),
    }


# strategies that use no scoring backend: measured once per kernel, under
# the stable backend label "none" (independent of the backend list).
# `random` and `locality` ride here as extra rows — same schema, so the
# committed baseline (which simply lacks these keys) is unaffected.
BACKEND_FREE_STRATEGIES = {
    "ws": partial(resolve, "ws"),
    "random": partial(resolve, "random"),
    "locality": partial(resolve, "locality"),
}


def available_backends() -> list:
    """Backends to measure: only ones that actually initialise.

    ``get_backend("jax")`` can fall back to numpy (missing jax, init
    failure); measuring that fallback under a ``jax`` label would record
    duplicate-numpy rows into the perf trajectory, so such entries are
    dropped with a notice.
    """
    from repro.core import get_backend
    from repro.sched import current_config

    cfg = current_config()
    names = (
        list(cfg.bench_backends)
        if cfg.bench_backends is not None
        else ["numpy", "jax"]
    )
    out = []
    for name in names:
        try:
            unavailable = name != "numpy" and get_backend(name) is None
        except ValueError:
            print(f"note: unknown backend {name!r} — skipped")
            continue
        if unavailable:
            print(f"note: backend {name!r} unavailable here — skipped")
            continue
        out.append(name)
    return out


# ---------------------------------------------------------------------------
# whole-simulation throughput


_MB = 1024 * 1024
# eviction-path row: device memories bounded to 32 MB (heavy pressure on
# the NT=16 trace), affinity victim selection — regression-gates the
# capacity-bounded engine path (memory manager + pressure scoring)
CAPACITY_ROW_BYTES = 32 * _MB
CAPACITY_ROW_STRATEGIES = ("heft", "dada(a)+cp")


def whole_sim_rows(nts, n_gpus: int, n_runs: int, backends) -> list:
    rows = []
    for nt in nts:
        machine = machine_for(n_gpus)
        for kernel, gfac in graphs_for(nt).items():
            # graph construction excluded: we are measuring the scheduler
            graphs = [gfac() for _ in range(n_runs)]
            passes = [("none", 0, BACKEND_FREE_STRATEGIES)] + [
                (backend, 0, strategies(backend)) for backend in backends
            ]
            if kernel == "cholesky":
                # the eviction path, measured once per NT on the numpy
                # scoring path (jax engages only on wide activations)
                passes.append((
                    "numpy",
                    CAPACITY_ROW_BYTES,
                    {
                        label: sfac
                        for label, sfac in strategies("numpy").items()
                        if label in CAPACITY_ROW_STRATEGIES
                    },
                ))
            for backend, capacity, strats in passes:
                for label, sfac in strats.items():
                    # best-of-2 passes: a transient stall (noisy neighbor,
                    # cgroup throttle) during one pass must not record a
                    # phantom 2× slowdown into the perf trajectory
                    dt = float("inf")
                    for _rep in range(2):
                        events = tasks = 0
                        t0 = time.perf_counter()
                        for i, g in enumerate(graphs):
                            sim = Simulator(
                                g, machine, sfac(), seed=1234 + i,
                                mem_capacity=capacity, eviction="affinity",
                            )
                            res = sim.run()
                            events += res.n_events
                            tasks += len(g)
                        dt = min(dt, time.perf_counter() - t0)
                    us = dt / n_runs * 1e6
                    row = dict(
                        kernel=kernel, strategy=label, backend=backend,
                        nt=nt, n_gpus=n_gpus, runs=n_runs, capacity=capacity,
                        churn=0.0, fault_mode="drain", flake=0.0, notice=0.0,
                        exact=True,
                        wall_s=round(dt, 4), events=events,
                        events_per_s=round(events / dt, 1) if dt > 0 else 0.0,
                        tasks_per_s=round(tasks / dt, 1) if dt > 0 else 0.0,
                    )
                    rows.append(row)
                    cap_tag = f"/cap{capacity // _MB}MB" if capacity else ""
                    print(
                        f"sched_overhead/{kernel}/{label}/gpus{n_gpus}/"
                        f"nt{nt}/{backend}{cap_tag},{us:.1f},"
                        f"events_per_s={row['events_per_s']};"
                        f"tasks_per_s={row['tasks_per_s']}"
                    )
    return rows


# ---------------------------------------------------------------------------
# multi-graph streaming throughput


def streaming_rows(nt: int, n_gpus: int, n_runs: int, n_graphs: int = 4) -> list:
    """Aggregate events/sec of ``n_graphs`` Cholesky DAGs interleaving on
    one engine (two tenants at t=0, the rest streamed in mid-run), plus
    per-graph makespans — the multi-tenant serving shape the layered
    runtime exists for."""
    from repro.runtime import Engine

    machine = machine_for(n_gpus)
    gfac = graphs_for(nt)["cholesky"]
    graph_sets = [
        [gfac() for _ in range(n_graphs)] for _ in range(n_runs)
    ]
    sfac = partial(resolve, "dada?alpha=0.5&use_cp=1", backend="numpy")
    dt = float("inf")
    per_run = []
    for _rep in range(2):
        events = tasks = 0
        per_run = []  # deterministic per seed: reps reproduce the same values
        t0 = time.perf_counter()
        for i, graphs in enumerate(graph_sets):
            eng = Engine(machine, sfac(), seed=1234 + i)
            for k, g in enumerate(graphs):
                # stagger half the tenants into the live run
                eng.submit(g, at=None if k < 2 else 0.002 * k)
            results = eng.run()
            events += eng.n_events
            tasks += sum(len(g) for g in graphs)
            per_run.append([r.makespan for r in results])
        dt = min(dt, time.perf_counter() - t0)
    import statistics

    # per-graph makespans summarized across every seeded run (a regression
    # visible only under one seed must not be masked by the last run)
    per_graph = [
        round(statistics.median(run[k] for run in per_run), 5)
        for k in range(n_graphs)
    ]
    row = dict(
        kernel=f"cholesky-x{n_graphs}stream", strategy="dada(a)+cp",
        backend="numpy", nt=nt, n_gpus=n_gpus, runs=n_runs, capacity=0,
        churn=0.0, fault_mode="drain", flake=0.0, notice=0.0, exact=True,
        n_graphs=n_graphs, wall_s=round(dt, 4), events=events,
        events_per_s=round(events / dt, 1) if dt > 0 else 0.0,
        tasks_per_s=round(tasks / dt, 1) if dt > 0 else 0.0,
        per_graph_makespans=per_graph,
    )
    print(
        f"sched_overhead/{row['kernel']}/dada(a)+cp/gpus{n_gpus}/nt{nt}/numpy,"
        f"{dt / n_runs * 1e6:.1f},events_per_s={row['events_per_s']};"
        f"per_graph_makespans={per_graph}"
    )
    return [row]


# ---------------------------------------------------------------------------
# fault-injected (churned) throughput


# seeded accelerator churn at this rate over the NT=16 Cholesky trace
# yields a handful of detach/attach cycles per run — enough to keep the
# recovery paths (requeue, evacuation, epoch invalidation) on the measured
# critical path without drowning the scheduler signal in fault handling
CHURN_RATE = 150.0
CHURN_STRATEGIES = ("heft", "dada(a)+cp")


def churn_rows(nt: int, n_gpus: int, n_runs: int) -> list:
    """Events/sec with seeded GPU churn live, for both recovery modes —
    regression-gates the fault path (detach/attach handling, kill-and-
    requeue, dirty-data evacuation) the same way the capacity row gates
    eviction. The scoring path is numpy: the fused jax path disengages
    while any resource is dead, so it would measure the wrong thing."""
    machine = machine_for(n_gpus)
    gfac = graphs_for(nt)["cholesky"]
    graphs = [gfac() for _ in range(n_runs)]
    strats = strategies("numpy")
    rows = []
    for mode in ("drain", "kill"):
        for label in CHURN_STRATEGIES:
            sfac = strats[label]
            dt = float("inf")
            faults = None
            for _rep in range(2):
                events = tasks = 0
                t0 = time.perf_counter()
                for i, g in enumerate(graphs):
                    sim = Simulator(
                        g, machine, sfac(), seed=1234 + i,
                        churn=CHURN_RATE, fault_mode=mode,
                    )
                    res = sim.run()
                    events += res.n_events
                    tasks += len(g)
                    faults = res.faults
                dt = min(dt, time.perf_counter() - t0)
            row = dict(
                kernel="cholesky", strategy=label, backend="numpy",
                nt=nt, n_gpus=n_gpus, runs=n_runs, capacity=0,
                churn=CHURN_RATE, fault_mode=mode, flake=0.0, notice=0.0,
                exact=True,
                wall_s=round(dt, 4), events=events,
                events_per_s=round(events / dt, 1) if dt > 0 else 0.0,
                tasks_per_s=round(tasks / dt, 1) if dt > 0 else 0.0,
                n_detaches=faults["n_detaches"] if faults else 0,
            )
            rows.append(row)
            print(
                f"sched_overhead/cholesky/{label}/gpus{n_gpus}/nt{nt}/"
                f"numpy/churn{CHURN_RATE:g}-{mode},{dt / n_runs * 1e6:.1f},"
                f"events_per_s={row['events_per_s']};"
                f"n_detaches={row['n_detaches']}"
            )
    return rows


# ---------------------------------------------------------------------------
# proactive-recovery (flaky links / preemption notices) throughput


# per-hop failure probability for the flake row: high enough that the
# retry/backoff/re-source path dominates the transfer machinery without
# starving the scheduler of real placement work
FLAKE_RATE = 0.2
# notice window for the noticed-churn row: about one task length, so the
# grace-window and proactive-replication paths both stay hot
NOTICE_S = 0.004
RECOVERY_STRATEGIES = ("heft", "dada(a)+cp")


def recovery_rows(nt: int, n_gpus: int, n_runs: int) -> list:
    """Events/sec with the proactive-recovery machinery live — a flaky-
    link family (seeded per-hop failures, retry with backoff, re-source
    on timeout) and a noticed-churn family (preemption notices ahead of
    each detach: grace windows, proactive replication, the decaying
    pressure penalty) — regression-gating those paths the way the churn
    rows gate blind detach/attach handling. Scoring stays on numpy: the
    fused path disengages while a notice is pending."""
    machine = machine_for(n_gpus)
    gfac = graphs_for(nt)["cholesky"]
    graphs = [gfac() for _ in range(n_runs)]
    strats = strategies("numpy")
    rows = []
    for family, kwargs in (
        ("flake", dict(link_flake=FLAKE_RATE)),
        ("notice", dict(churn=CHURN_RATE, fault_mode="drain",
                        notice_s=NOTICE_S)),
    ):
        for label in RECOVERY_STRATEGIES:
            sfac = strats[label]
            dt = float("inf")
            faults = None
            for _rep in range(2):
                events = tasks = 0
                t0 = time.perf_counter()
                for i, g in enumerate(graphs):
                    sim = Simulator(
                        g, machine, sfac(), seed=1234 + i, **kwargs
                    )
                    res = sim.run()
                    events += res.n_events
                    tasks += len(g)
                    faults = res.faults
                dt = min(dt, time.perf_counter() - t0)
            row = dict(
                kernel="cholesky", strategy=label, backend="numpy",
                nt=nt, n_gpus=n_gpus, runs=n_runs, capacity=0,
                churn=kwargs.get("churn", 0.0),
                fault_mode=kwargs.get("fault_mode", "drain"),
                flake=kwargs.get("link_flake", 0.0),
                notice=kwargs.get("notice_s", 0.0),
                exact=True,
                wall_s=round(dt, 4), events=events,
                events_per_s=round(events / dt, 1) if dt > 0 else 0.0,
                tasks_per_s=round(tasks / dt, 1) if dt > 0 else 0.0,
            )
            derived = (
                f"n_retries={faults['n_retries']}"
                if family == "flake"
                else f"n_notices={faults['n_notices']}"
            ) if faults else ""
            rows.append(row)
            print(
                f"sched_overhead/cholesky/{label}/gpus{n_gpus}/nt{nt}/"
                f"numpy/{family},{dt / n_runs * 1e6:.1f},"
                f"events_per_s={row['events_per_s']};{derived}"
            )
    return rows


# ---------------------------------------------------------------------------
# audited (schedule-verifier instrumented) throughput


AUDIT_STRATEGIES = ("heft", "dada(a)+cp")
# audit instrumentation is append-only record keeping on the event loop;
# anything past this factor over the uninstrumented run means the audit
# path grew real work (allocation storms, eager serialization) and the
# "free when off, cheap when on" contract broke
AUDIT_OVERHEAD_LIMIT = 3.0


def audit_rows(nt: int, n_gpus: int, n_runs: int) -> list:
    """Events/sec with ``REPRO_SCHED_AUDIT``-style instrumentation live,
    paired with an uninstrumented pass on the same graphs — the
    ``audit_overhead`` ratio regression-gates the audit log's cost the
    way the capacity/churn rows gate eviction and fault handling. The
    pairing is in-run, so the ratio is immune to machine speed."""
    machine = machine_for(n_gpus)
    gfac = graphs_for(nt)["cholesky"]
    graphs = [gfac() for _ in range(n_runs)]
    strats = strategies("numpy")
    rows = []
    for label in AUDIT_STRATEGIES:
        sfac = strats[label]
        walls = {}
        events = tasks = 0
        for audit in (False, True):
            dt = float("inf")
            for _rep in range(2):
                events = tasks = 0
                t0 = time.perf_counter()
                for i, g in enumerate(graphs):
                    sim = Simulator(
                        g, machine, sfac(), seed=1234 + i, audit=audit
                    )
                    res = sim.run()
                    events += res.n_events
                    tasks += len(g)
                dt = min(dt, time.perf_counter() - t0)
            walls[audit] = dt
        dt = walls[True]
        overhead = round(dt / walls[False], 3) if walls[False] > 0 else 0.0
        row = dict(
            kernel="cholesky", strategy=label, backend="numpy",
            nt=nt, n_gpus=n_gpus, runs=n_runs, capacity=0,
            churn=0.0, fault_mode="drain", flake=0.0, notice=0.0,
            exact=True, audit=True,
            wall_s=round(dt, 4), events=events,
            events_per_s=round(events / dt, 1) if dt > 0 else 0.0,
            tasks_per_s=round(tasks / dt, 1) if dt > 0 else 0.0,
            audit_overhead=overhead,
        )
        rows.append(row)
        print(
            f"sched_overhead/cholesky/{label}/gpus{n_gpus}/nt{nt}/"
            f"numpy/audit,{dt / n_runs * 1e6:.1f},"
            f"events_per_s={row['events_per_s']};"
            f"audit_overhead={overhead}"
        )
    return rows


# ---------------------------------------------------------------------------
# batched surrogate sweep throughput (REPRO_SCHED_EXACT=0 engine)


BATCHED_SWEEP_SPECS = (
    "heft", "ws", "dada?alpha=0", "dada?alpha=0.5", "dada?alpha=0.5&use_cp=1",
)


def batched_sweep_rows(nt: int, n_gpus: int, n_runs: int) -> list:
    """Configs/sec of whole sweeps through ``run_batch`` vs the exact engine.

    One strategy × GPU-count × seed sweep per kernel runs as a handful of
    compiled episode dispatches (the ``REPRO_SCHED_EXACT=0`` path), then
    the *same* configurations replay through ``run_simulation`` — the
    exact-vs-surrogate speedup is the number the batched engine exists
    for. Rows carry ``exact=False`` (the regression key separates the two
    engines) and the per-dispatch batch size.
    """
    try:
        import jax  # noqa: F401
    except Exception:
        print("note: jax unavailable — batched-sweep rows skipped")
        return []
    from repro.core import cached_graph, run_batch, run_simulation
    from repro.sched import current_config

    cfg = current_config()
    gpu_counts = sorted({2, n_gpus})
    machines = {g: machine_for(g) for g in gpu_counts}
    rows = []
    for kernel, gfac in graphs_for(nt).items():
        graph = cached_graph(gfac)
        items = [
            {"graph": graph, "machine": machines[g], "strategy": spec,
             "seed": 1234 + i, "noise": 0.03}
            for g in gpu_counts
            for spec in BATCHED_SWEEP_SPECS
            for i in range(n_runs)
        ]
        run_batch(items, config=cfg)  # warm-up: compile once, measure dispatch
        dt = float("inf")
        for _rep in range(2):
            t0 = time.perf_counter()
            results = run_batch(items, config=cfg)
            dt = min(dt, time.perf_counter() - t0)
        t0 = time.perf_counter()
        for it in items:
            run_simulation(
                it["graph"], it["machine"], resolve(it["strategy"]),
                seed=it["seed"], noise=it["noise"],
            )
        dt_exact = time.perf_counter() - t0
        n_cfg = len(items)
        batch = min(max(1, int(cfg.batch)), 16)
        row = dict(
            kernel=kernel, strategy="sweep-mix", backend="jax",
            nt=nt, n_gpus=n_gpus, runs=n_runs, capacity=0,
            churn=0.0, fault_mode="drain", flake=0.0, notice=0.0,
            exact=False,
            batch=batch, n_configs=n_cfg,
            wall_s=round(dt, 4), events=0, events_per_s=0.0,
            tasks_per_s=round(n_cfg * len(graph) / dt, 1) if dt > 0 else 0.0,
            configs_per_s=round(n_cfg / dt, 2) if dt > 0 else 0.0,
            exact_wall_s=round(dt_exact, 4),
            speedup_vs_exact=round(dt_exact / dt, 2) if dt > 0 else 0.0,
        )
        rows.append(row)
        print(
            f"sched_overhead/{kernel}/sweep-mix/gpus{n_gpus}/nt{nt}/"
            f"jax/batched,{dt / n_cfg * 1e6:.1f},"
            f"configs_per_s={row['configs_per_s']};"
            f"speedup_vs_exact={row['speedup_vs_exact']};batch={batch}"
        )
        del results
    return rows


# ---------------------------------------------------------------------------
# λ-probe placement microbenchmark


def _widest_wave(graph):
    """The largest single ready wave: tasks at the most populous depth
    (for tile Cholesky this is the first syrk/gemm wave, ~NT²/2 tasks)."""
    depth = [0] * len(graph)
    for t in graph.tasks:
        preds = graph.pred[t.tid]
        depth[t.tid] = (max(depth[p] for p in preds) + 1) if preds else 0
    counts = {}
    for d in depth:
        counts[d] = counts.get(d, 0) + 1
    best = max(counts, key=lambda d: (counts[d], -d))
    return [t for t in graph.tasks if depth[t.tid] == best]


def _reset_placement_state(sim, load_ts_snapshot):
    sim.load_ts[:] = load_ts_snapshot
    for w in sim.workers:
        w.queue.clear()
        w.blocked_on = 0
    sim._inflight.clear()
    sim._link_free.clear()
    sim._waiting.clear()
    sim._events.clear()


def lambda_probe_rows(
    nt: int, n_cpus: int, n_gpus: int, reps: int, backends, kernel: str = "cholesky"
) -> list:
    graphs = graphs_for(nt)
    graph = graphs[kernel]()
    machine = machine_for(n_gpus, n_cpus)
    wave = _widest_wave(graph)
    rows = []
    placements = {}
    setups = {}
    for backend in backends:
        strat = resolve("dada?alpha=0.5&use_cp=1", backend=backend)
        sim = Simulator(graph, machine, strat, seed=0)
        # scatter a third of the tiles across GPU memories so affinity and
        # transfer scoring are exercised, not just durations
        for k, name in enumerate(sim.arrays.data_names):
            if k % 3 == 0 and n_gpus:
                sim.residency.write(name, k % n_gpus)
        # isolate the placement *decision* cost: queue pushes trigger the
        # simulator's prefetch/transfer machinery, which is workload
        # simulation (identical for every backend), not scheduler scoring
        placed = {}
        sim.push = lambda task, rid, _p=placed: _p.__setitem__(task.tid, rid)
        snapshot = list(sim.load_ts)
        strat.place(sim, wave, None)  # warm-up (jit compilation for jax)
        placements[backend] = dict(placed)
        _reset_placement_state(sim, snapshot)
        setups[backend] = (strat, sim, snapshot, [])
    # interleave the repetitions across backends: the wall clock on shared
    # boxes drifts, and interleaving keeps the comparison apples-to-apples
    for _ in range(reps):
        for backend in backends:
            strat, sim, snapshot, samples = setups[backend]
            t0 = time.perf_counter()
            strat.place(sim, wave, None)
            samples.append(time.perf_counter() - t0)
            _reset_placement_state(sim, snapshot)
    for backend in backends:
        samples = sorted(setups[backend][3])
        us = samples[len(samples) // 2] * 1e6  # median: the box is noisy
        rows.append(
            dict(
                bench="lambda_probe", kernel=kernel, nt=nt, n_cpus=n_cpus,
                n_gpus=n_gpus, resources=n_cpus + n_gpus, width=len(wave),
                strategy="dada(a)+cp", backend=backend, reps=reps,
                us_per_place=round(us, 1),
            )
        )
    base = next((r for r in rows if r["backend"] == "numpy"), None)
    for r in rows:
        # None (not True) when numpy was not measured: an honest "no
        # comparison happened", never a vacuous pass
        identical = (
            placements[r["backend"]] == placements["numpy"]
            if "numpy" in placements
            else None
        )
        r["decisions_match_numpy"] = identical
        if base is not None and r["us_per_place"] > 0:
            r["speedup_vs_numpy"] = round(
                base["us_per_place"] / r["us_per_place"], 2
            )
        print(
            f"sched_overhead/lambda_probe/{kernel}/nt{nt}/res{r['resources']}/"
            f"dada(a)+cp/{r['backend']},{r['us_per_place']:.1f},"
            f"width={r['width']};speedup_vs_numpy={r.get('speedup_vs_numpy', 1.0)};"
            f"decisions_match_numpy={identical}"
        )
    return rows


# ---------------------------------------------------------------------------


def calibration_score() -> float:
    """Fixed scheduler-independent workload scoring machine speed.

    The regression gate compares events/sec across machines (developer
    boxes, CI runners); dividing by this constant-workload score cancels
    most of the raw CPU-speed difference. Two properties matter: it
    touches none of the scheduler code under test (a uniform scheduler
    slowdown must not drag the calibration down with it, or the gate
    would self-cancel), and it is *interpreter-bound* — heap ops, dict
    lookups, float arithmetic — because that is what events/sec is bound
    by, so the normalisation tracks the right axis of machine speed
    (a box with fast BLAS but a slow interpreter must not look fast).
    """
    import heapq

    acc = 0.0
    best = float("inf")
    # best-of-5: each repetition is timed separately and the fastest one
    # scores (timeit practice) — a noisy-neighbor burst during one rep
    # must not halve the calibration and double every scaled baseline
    for _ in range(5):
        t0 = time.perf_counter()
        heap = []
        table = {}
        x = 1.0
        for i in range(20000):
            x = x * 1.0000001 + 0.5
            heapq.heappush(heap, (x % 97.0, i))
            table[i & 1023] = x
            if i & 7 == 0:
                acc += heapq.heappop(heap)[0]
        acc += sum(table.values())
        best = min(best, time.perf_counter() - t0)
    assert acc != 0.0
    return 2e4 / best if best > 0 else 0.0  # arbitrary units


def main() -> list:
    from repro.sched import current_config

    cfg = current_config()
    n_gpus = cfg.bench_gpus[0] if cfg.bench_gpus else 8
    n_runs = cfg.bench_runs if cfg.bench_runs is not None else 3
    nts = list(cfg.bench_nt)
    backends = available_backends()

    print("name,us_per_call,derived")
    rows = whole_sim_rows(nts, n_gpus, n_runs, backends)
    if nts:  # REPRO_BENCH_NT="" is a valid empty sweep
        rows += streaming_rows(nts[0], n_gpus, n_runs)
        rows += churn_rows(nts[0], n_gpus, n_runs)
        rows += recovery_rows(nts[0], n_gpus, n_runs)
        rows += audit_rows(nts[0], n_gpus, n_runs)
        if "jax" in backends:
            rows += batched_sweep_rows(nts[0], n_gpus, n_runs)
    total_ev = sum(r["events"] for r in rows if r.get("exact", True))
    total_s = sum(r["wall_s"] for r in rows if r.get("exact", True))
    if total_s > 0:
        print(
            f"sched_overhead/total,{total_s * 1e6:.1f},"
            f"events_per_s={total_ev / total_s:.1f}"
        )

    lam_rows = []
    diverged = []
    if cfg.bench_lambda:
        lam_rows = lambda_probe_rows(
            cfg.bench_lambda_nt, 8, 24, cfg.bench_lambda_reps, backends
        )
        diverged = [
            r["backend"] for r in lam_rows
            if r["decisions_match_numpy"] is False
        ]

    update_bench_json(
        "sched_overhead",
        dict(
            config=dict(n_gpus=n_gpus, runs=n_runs, nts=nts, backends=backends),
            calibration_score=round(calibration_score(), 2),
            whole_sim=rows,
            lambda_probe=lam_rows,
        ),
    )
    if diverged:
        # decision divergence is a correctness regression, not a perf
        # number — record it in the JSON above, then fail the run
        print(
            f"ERROR: backend(s) {diverged} placed the λ-probe wave "
            f"differently from numpy — decision identity broken"
        )
        sys.exit(1)
    return rows


if __name__ == "__main__":
    main()
