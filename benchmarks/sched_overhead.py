"""Scheduler-overhead microbenchmark: events/sec of the scheduling core.

The paper's sweeps are bottlenecked by the scheduler's own per-decision
cost, not by the simulated workload (cf. Amaris et al., arXiv:1711.06433 on
keeping dual-approximation decisions cheap). This benchmark isolates that
cost: for each strategy it runs seeded simulations of the paper-shaped
kernels and reports wall-clock, simulator events/sec and tasks/sec —
the scheduler-throughput numbers the array-native core is optimized for.

Runnable directly (``python benchmarks/sched_overhead.py``) or via
``python -m benchmarks.sched_overhead``. Knobs: REPRO_BENCH_GPUS (first
entry is used, default 8) and REPRO_BENCH_RUNS (default 3).

Output follows the ``name,us_per_call,derived`` contract.
"""
from __future__ import annotations

import os
import sys
import time
from pathlib import Path

if __package__ in (None, ""):
    _repo = Path(__file__).resolve().parents[1]
    for p in (str(_repo), str(_repo / "src")):
        if p not in sys.path:
            sys.path.insert(0, p)

from repro.configs.paper_machine import paper_machine
from repro.core import Simulator, make_strategy
from repro.core.dada import DADA

from benchmarks.common import GRAPHS


def strategies():
    return {
        "heft": lambda: make_strategy("heft"),
        "ws": lambda: make_strategy("ws"),
        "dada(0)": lambda: DADA(alpha=0.0),
        "dada(a)": lambda: DADA(alpha=0.5),
        "dada(a)+cp": lambda: DADA(alpha=0.5, use_cp=True),
    }


def main() -> list:
    gpus_env = os.environ.get("REPRO_BENCH_GPUS", "8")
    n_gpus = int(gpus_env.split(",")[0] or 8)
    n_runs = int(os.environ.get("REPRO_BENCH_RUNS", "3"))
    machine = paper_machine(n_gpus)

    print("name,us_per_call,derived")
    rows = []
    for kernel, gfac in GRAPHS.items():
        for label, sfac in strategies().items():
            # graph construction excluded: we are measuring the scheduler
            graphs = [gfac() for _ in range(n_runs)]
            events = tasks = 0
            t0 = time.perf_counter()
            for i, g in enumerate(graphs):
                sim = Simulator(g, machine, sfac(), seed=1234 + i)
                res = sim.run()
                events += res.n_events
                tasks += len(g)
            dt = time.perf_counter() - t0
            ev_s = events / dt if dt > 0 else 0.0
            t_s = tasks / dt if dt > 0 else 0.0
            us = dt / n_runs * 1e6
            row = dict(
                kernel=kernel, strategy=label, n_gpus=n_gpus, runs=n_runs,
                wall_s=round(dt, 4), events=events,
                events_per_s=round(ev_s, 1), tasks_per_s=round(t_s, 1),
            )
            rows.append(row)
            print(
                f"sched_overhead/{kernel}/{label}/gpus{n_gpus},{us:.1f},"
                f"events_per_s={row['events_per_s']};tasks_per_s={row['tasks_per_s']}"
            )
    total_ev = sum(r["events"] for r in rows)
    total_s = sum(r["wall_s"] for r in rows)
    print(
        f"sched_overhead/total,{total_s * 1e6:.1f},"
        f"events_per_s={total_ev / total_s:.1f}" if total_s > 0 else "n/a"
    )
    return rows


if __name__ == "__main__":
    main()
