"""Compare dry-run variants for the §Perf hillclimbing log.

  PYTHONPATH=src python -m benchmarks.perf_compare \
      results/dryrun/kimi-k2-1t-a32b__train_4k__pod1.json \
      results/dryrun/kimi-k2-1t-a32b__train_4k__pod1__moechunks.json
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from repro.analysis.roofline import analyse_record, fmt_s


def row(path: str):
    rec = json.loads(Path(path).read_text())
    r = analyse_record(rec)
    if r is None:
        raise SystemExit(f"{path}: status={rec.get('status')}")
    return rec, r


def main(argv=None) -> None:
    argv = argv or sys.argv[1:]
    base_p, var_p = argv[0], argv[1]
    brec, b = row(base_p)
    vrec, v = row(var_p)
    print(f"cell: {b.arch} x {b.shape} x {b.mesh}")
    print(f"{'term':12s} {'before':>12s} {'after':>12s} {'delta':>8s}")
    for name, x, y in [
        ("compute", b.compute_s, v.compute_s),
        ("memory", b.memory_s, v.memory_s),
        ("collective", b.collective_s, v.collective_s),
        ("step(max)", b.step_s, v.step_s),
    ]:
        d = (y - x) / x * 100 if x else 0.0
        print(f"{name:12s} {fmt_s(x):>12s} {fmt_s(y):>12s} {d:+7.1f}%")
    print(f"{'MFU_est':12s} {b.mfu_est*100:11.2f}% {v.mfu_est*100:11.2f}%")
    for kind in ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                 "collective-permute"):
        x = brec["collective_bytes_per_device"].get(kind, 0.0)
        y = vrec["collective_bytes_per_device"].get(kind, 0.0)
        if x or y:
            print(f"  {kind:20s} {x/1e9:10.2f} GB -> {y/1e9:10.2f} GB")


if __name__ == "__main__":
    main()
