"""Pallas kernel: CSR read-incidence → predicted transfer-time reduction.

This is the scoring hot spot of the ``use_cp`` scheduling strategies: for
every (ready task i, memory space u) pair, sum the per-read transfer times
of the reads that are *not* resident at u —

    X[i, u] = Σ_r  hops(mask[i, r], u) * per_read[i, r]

where ``mask`` holds compact residency codes (bit 0 = a host copy exists,
bit u+1 = a valid copy at unique memory u) and ``hops`` is the paper-era
PCIe path length: 0 if resident (or the data exists nowhere yet), 1 for
host→device / anything→host, 2 for device→host→device.

Layout mirrors ``tile_gemm``: the grid tiles the task axis, each program
reduces its (bt × r_pad) read block into a (bt × n_u) output block. The
reduction is an **in-order fori fold over the read axis**, so every output
entry is bit-equal to the scalar reference in ``repro.core._reference``
(padded reads carry mask 0 → hops 0 → exact +0.0). ``transfer_matrix_jnp``
is the XLA fallback with the identical fold — the CPU path of the jax
scheduling backend, and the reference the Pallas kernel is tested against
(interpret mode on CPU).

TPU note: f64 is unsupported on real TPUs; deploying there means f32
scores, which relaxes the bit-for-bit guarantee to decision-equality (the
backend keeps the numpy path authoritative for the final build either way).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hop_fold(masks, per_read, resident_of, host_col, n_u):
    """Shared in-order read fold: the single home of the hop formula.

    ``resident_of(r)`` returns the (n_pad, n_u) residency booleans of read
    column r; everything else (host short-circuit, 2-hop device→device,
    nowhere-yet data) is identical for the compact- and full-mask callers,
    so the bit-for-bit-critical arithmetic lives exactly once.
    """
    on_host = (masks & 1) != 0
    nowhere = masks == 0
    n_pad = masks.shape[0]

    def body(r, acc):
        skip = resident_of(r) | nowhere[:, r][:, None]
        hops = jnp.where(
            skip,
            0.0,
            jnp.where(
                host_col[None, :],
                1.0,
                jnp.where(on_host[:, r][:, None], 1.0, 2.0),
            ),
        )
        return acc + hops * per_read[:, r][:, None]

    return jax.lax.fori_loop(
        0, masks.shape[1], body, jnp.zeros((n_pad, n_u), dtype=per_read.dtype)
    )


def transfer_matrix_jnp(
    masks: jax.Array,  # (n_pad, r_pad) int32 compact residency codes
    per_read: jax.Array,  # (n_pad, r_pad) per-read transfer times
    col_bits: jax.Array,  # (n_u,) int32, bit u+1 set
    host_col: jax.Array,  # (n_u,) bool, True where unique mem u is the host
) -> jax.Array:
    """XLA reference over compact codes: (n_pad × n_u) transfer times."""
    return _hop_fold(
        masks, per_read,
        lambda r: (masks[:, r][:, None] & col_bits[None, :]) != 0,
        host_col, col_bits.shape[0],
    )


def transfer_matrix_from_full(
    masks: jax.Array,  # (n_pad, r_pad) int64 full residency masks
    per_read: jax.Array,  # (n_pad, r_pad) per-read transfer times
    mem_shift: jax.Array,  # (n_u,) int64, mem+1 shift per unique memory
    host_col: jax.Array,  # (n_u,) bool, True where unique mem u is the host
) -> jax.Array:
    """Same fold straight off the full int64 residency masks — the CPU
    path of the jax scheduling backend (no compact remap needed)."""
    return _hop_fold(
        masks, per_read,
        lambda r: ((masks[:, r][:, None] >> mem_shift[None, :]) & 1) != 0,
        host_col, mem_shift.shape[0],
    )


def _xfer_kernel(masks_ref, pr_ref, bits_ref, host_ref, out_ref, *, r_pad):
    masks = masks_ref[...]  # (bt, r_pad)
    pr = pr_ref[...]
    bits = bits_ref[...]  # (1, n_u)
    hostc = host_ref[...] != 0  # (1, n_u)
    on_host = (masks & 1) != 0
    nowhere = masks == 0
    bt, n_u = out_ref.shape

    def body(r, acc):
        m = jax.lax.dynamic_slice_in_dim(masks, r, 1, axis=1)  # (bt, 1)
        resident = (m & bits) != 0  # (bt, n_u)
        skip = resident | jax.lax.dynamic_slice_in_dim(nowhere, r, 1, axis=1)
        oh = jax.lax.dynamic_slice_in_dim(on_host, r, 1, axis=1)
        hops = jnp.where(
            skip, 0.0, jnp.where(hostc, 1.0, jnp.where(oh, 1.0, 2.0))
        ).astype(pr.dtype)
        prr = jax.lax.dynamic_slice_in_dim(pr, r, 1, axis=1)
        return acc + hops * prr

    out_ref[...] = jax.lax.fori_loop(
        0, r_pad, body, jnp.zeros((bt, n_u), dtype=pr.dtype)
    )


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def transfer_matrix_pallas(
    masks: jax.Array,
    per_read: jax.Array,
    col_bits: jax.Array,
    host_col: jax.Array,
    *,
    bt: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Pallas version of :func:`transfer_matrix_jnp` (same fold order).

    ``bt`` tiles the task axis; reads and memory columns stay whole per
    program (r_pad and n_u are small — a handful of reads per task, ≤ ~32
    memory spaces). ``interpret=True`` runs on CPU for testing.
    """
    n_pad, r_pad = masks.shape
    n_u = col_bits.shape[0]
    bt = min(bt, n_pad)
    assert n_pad % bt == 0, (n_pad, bt)
    grid = (n_pad // bt,)
    bits2 = col_bits.reshape(1, n_u)
    host2 = host_col.astype(jnp.int32).reshape(1, n_u)
    return pl.pallas_call(
        functools.partial(_xfer_kernel, r_pad=r_pad),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, r_pad), lambda i: (i, 0)),  # masks
            pl.BlockSpec((bt, r_pad), lambda i: (i, 0)),  # per-read times
            pl.BlockSpec((1, n_u), lambda i: (0, 0)),  # column bits
            pl.BlockSpec((1, n_u), lambda i: (0, 0)),  # host-column flags
        ],
        out_specs=pl.BlockSpec((bt, n_u), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, n_u), per_read.dtype),
        interpret=interpret,
    )(masks, per_read, bits2, host2)
