"""jit'd public wrappers for the Pallas kernels (ref.py holds the oracles).

On TPU call with interpret=False (default); tests and CPU validation use
interpret=True, which executes the same kernel bodies in Python.
"""
from .flash_attention import flash_attention
from .flash_decode import flash_decode
from .tile_gemm import gemm_update, matmul

__all__ = ["flash_attention", "flash_decode", "gemm_update", "matmul"]
