"""Pallas TPU kernel: tiled GEMM update ``C <- C + alpha * A @ op(B)``.

This is the compute hot spot of every PLASMA tile kernel the paper schedules
(gemm / syrk / ssssm / tsmqr are all GEMM-shaped updates).

TPU mapping (DESIGN.md §2 hardware adaptation):
  * grid = (M/bm, N/bn, K/bk), K innermost ("arbitrary") so the fp32
    accumulator lives in VMEM scratch across K steps while A/B blocks
    stream HBM -> VMEM;
  * block shapes default to 128x128 (MXU-aligned; 8x128 lane/sublane tiles);
  * ``preferred_element_type=float32`` keeps MXU accumulation in fp32 even
    for bf16 inputs.

VMEM budget at defaults: (bm*bk + bk*bn + 2*bm*bn) * 4B = 256 KiB << 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _gemm_kernel(c_in_ref, a_ref, b_ref, c_out_ref, acc_ref, *, alpha, trans_b, n_k):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = c_in_ref[...].astype(jnp.float32)

    a = a_ref[...]
    b = b_ref[...]
    if trans_b:
        b = b.T
    acc_ref[...] += alpha * jax.lax.dot(
        a, b, preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _done():
        c_out_ref[...] = acc_ref[...].astype(c_out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("alpha", "trans_b", "bm", "bn", "bk", "interpret"),
)
def gemm_update(
    c: jax.Array,
    a: jax.Array,
    b: jax.Array,
    *,
    alpha: float = -1.0,
    trans_b: bool = False,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """``C + alpha * A @ B`` (or ``A @ B.T`` when ``trans_b``)."""
    m, k_dim = a.shape
    if trans_b:
        n, kb = b.shape
    else:
        kb, n = b.shape
    assert kb == k_dim, (a.shape, b.shape)
    assert c.shape == (m, n), (c.shape, m, n)
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, k_dim)
    assert m % bm == 0 and n % bn == 0 and k_dim % bk == 0, (
        "shapes must tile evenly",
        (m, n, k_dim),
        (bm, bn, bk),
    )
    n_k = k_dim // bk
    grid = (m // bm, n // bn, n_k)
    b_spec = (
        pl.BlockSpec((bn, bk), lambda i, j, k: (j, k))
        if trans_b
        else pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))
    )
    return pl.pallas_call(
        functools.partial(
            _gemm_kernel, alpha=alpha, trans_b=trans_b, n_k=n_k
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),  # C in
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),  # A
            b_spec,  # B
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(c, a, b)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Plain ``A @ B`` through the same kernel (C = 0, alpha = +1)."""
    m, _ = a.shape
    n = b.shape[1]
    c0 = jnp.zeros((m, n), dtype=a.dtype)
    return gemm_update(
        c0, a, b, alpha=1.0, trans_b=False, bm=bm, bn=bn, bk=bk, interpret=interpret
    )
