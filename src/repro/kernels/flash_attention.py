"""Pallas TPU kernel: causal flash attention with GQA (prefill hot spot).

Online-softmax blocked attention (Rabe-Staats/FlashAttention scheme) adapted
to the TPU memory hierarchy: K/V blocks stream HBM -> VMEM along the
innermost ("arbitrary") grid axis while the running max / normalizer /
accumulator live in VMEM scratch. Q/K/V use (block_q x head_dim) /
(block_k x head_dim) tiles — multiples of (8, 128) for lane/sublane layout.

GQA is handled in the BlockSpec index maps: query head h reads KV head
``h // group`` — no materialized repeat (saves HBM bandwidth, the point of
GQA).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

_NEG = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *, scale, causal, n_k, bq, bk, offset
):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)  # (bq, d)
    k = k_ref[0].astype(jnp.float32)  # (bk, d)
    v = v_ref[0].astype(jnp.float32)  # (bk, d)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)

    if causal:
        i = pl.program_id(1)
        qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        logits = jnp.where(kpos <= qpos + offset, logits, _NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1, keepdims=True))
    p = jnp.exp(logits - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(j == n_k - 1)
    def _done():
        o_ref[0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "scale", "bq", "bk", "interpret")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """q: (hq, sq, d); k, v: (hk, sk, d) with hq % hk == 0. Returns (hq, sq, d)."""
    hq, sq, d = q.shape
    hk, sk, _ = k.shape
    assert hq % hk == 0, (hq, hk)
    group = hq // hk
    if scale is None:
        scale = float(1.0 / (d**0.5))
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, ((sq, sk), (bq, bk))
    n_k = sk // bk
    grid = (hq, sq // bq, n_k)
    return pl.pallas_call(
        functools.partial(
            _flash_kernel,
            scale=scale,
            causal=causal,
            n_k=n_k,
            bq=bq,
            bk=bk,
            offset=sk - sq,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
