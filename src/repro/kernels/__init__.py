"""Pallas TPU kernels for the perf-critical compute layers."""
from .ops import flash_attention, flash_decode, gemm_update, matmul

__all__ = ["flash_attention", "flash_decode", "gemm_update", "matmul"]
