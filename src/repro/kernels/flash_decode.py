"""Pallas TPU kernel: single-token GQA decode attention (flash-decode).

The serving hot spot: one query token per sequence against a long KV cache.
Memory-bound by the cache read, so the kernel streams K/V blocks
HBM -> VMEM along the innermost grid axis with an online-softmax
accumulator in VMEM scratch — one pass over the cache, no (S,) logits
round-trip to HBM.

Layout: q (B, Hq, hd); cache k/v (B, S, Hkv, hd) — the serving cache layout
(seq-major, matching serve/decode.py). Query heads of one KV group are
processed together as the sublane dim of an (group x hd) MXU tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

_NEG = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, acc_ref, m_ref, l_ref,
                   *, scale, n_blk, bk):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)       # (group, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)    # (bk, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)    # (bk, hd)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                  # (group, bk)
    # mask positions beyond the live cache length
    pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    logits = jnp.where(pos < len_ref[0], logits, _NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1, keepdims=True))
    p = jnp.exp(logits - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(j == n_blk - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "bk", "interpret"))
def flash_decode(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    length,
    *,
    scale: float | None = None,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, Hq, hd); k, v: (B, S, Hkv, hd); length: live cache length.

    Returns (B, Hq, hd). Hq % Hkv == 0; positions >= length are masked.
    """
    B, Hq, hd = q.shape
    _, S, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    if scale is None:
        scale = float(1.0 / (hd**0.5))
    bk = min(bk, S)
    assert S % bk == 0, (S, bk)
    n_blk = S // bk
    qg = q.reshape(B, Hkv, group, hd)
    lengths = jnp.full((B, 1), length, jnp.int32)
    grid = (B, Hkv, n_blk)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, n_blk=n_blk, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, group, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, 1), lambda b, h, j: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, hd), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(qg, k, v, lengths)
    return out.reshape(B, Hq, hd)
