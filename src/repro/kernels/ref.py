"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gemm_update_ref(c, a, b, *, alpha=-1.0, trans_b=False):
    bb = b.T if trans_b else b
    acc = c.astype(jnp.float32) + alpha * (
        a.astype(jnp.float32) @ bb.astype(jnp.float32)
    )
    return acc.astype(c.dtype)


def matmul_ref(a, b):
    return (a.astype(jnp.float32) @ b.astype(jnp.float32)).astype(a.dtype)


def flash_attention_ref(q, k, v, *, causal=True, scale=None):
    """Reference attention. q,k,v: (heads, seq_q, d) / (kv_heads, seq_k, d).

    GQA: q heads grouped over kv heads (heads % kv_heads == 0).
    """
    hq, sq, d = q.shape
    hk, sk, _ = k.shape
    assert hq % hk == 0
    group = hq // hk
    if scale is None:
        scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    k = jnp.repeat(k, group, axis=0)
    v = jnp.repeat(v, group, axis=0)
    logits = jnp.einsum(
        "hqd,hkd->hqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), dtype=bool), k=sk - sq)
        logits = jnp.where(mask[None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q, k, v, *, scale=None):
    """One-token decode: q (heads, d), cache k/v (kv_heads, seq, d)."""
    hq, d = q.shape
    hk, s, _ = k.shape
    group = hq // hk
    if scale is None:
        scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    k = jnp.repeat(k, group, axis=0)
    v = jnp.repeat(v, group, axis=0)
    logits = jnp.einsum("hd,hkd->hk", q.astype(jnp.float32), k.astype(jnp.float32))
    p = jax.nn.softmax(logits * scale, axis=-1)
    return jnp.einsum("hk,hkd->hd", p, v.astype(jnp.float32)).astype(q.dtype)


def flash_decode_ref(q, k, v, length, *, scale=None):
    """Batched single-token decode oracle. q (B,Hq,hd); k,v (B,S,Hkv,hd)."""
    B, Hq, hd = q.shape
    _, S, Hkv, _ = k.shape
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (hd**0.5)
    kr = jnp.repeat(k, group, axis=2)  # (B,S,Hq,hd)
    vr = jnp.repeat(v, group, axis=2)
    logits = jnp.einsum(
        "bhd,bshd->bhs", q.astype(jnp.float32), kr.astype(jnp.float32)
    ) * scale
    mask = jnp.arange(S)[None, None, :] < length
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)
