"""Run metrics: counters, execution intervals and :class:`SimResult`.

One :class:`Metrics` instance per engine accumulates the machine-global
counters (transferred bytes, transfer/steal/event counts, per-worker busy
time, the interval timeline). Per-graph attribution lives on each
:class:`~repro.runtime.engine.GraphContext` (its own interval list and
completion time), from which the engine derives per-graph results for
multi-tenant streams.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.core.machine import MachineModel


@dataclass(slots=True)
class ScheduledInterval:
    tid: int
    rid: int
    start: float
    end: float


@dataclass
class SimResult:
    makespan: float
    total_bytes: int
    n_transfers: int
    n_steals: int
    busy: Dict[int, float]
    intervals: List[ScheduledInterval]
    strategy: str
    total_flops: float
    n_events: int = 0
    # fault/recovery counters (None for runs with no fault source active;
    # see Metrics.fault_summary and repro.runtime.faults)
    faults: Optional[Dict[str, float]] = None

    @property
    def gflops(self) -> float:
        if self.makespan <= 0:
            return 0.0
        return self.total_flops / self.makespan / 1e9

    @property
    def gbytes(self) -> float:
        return self.total_bytes / 1e9


class Metrics:
    """Engine-global counters (shared across every submitted graph)."""

    __slots__ = (
        "total_bytes", "n_transfers", "n_steals", "n_events",
        "busy", "intervals", "n_evictions", "n_writebacks", "writeback_bytes",
        "n_detaches", "n_attaches", "n_killed", "n_requeued",
        "n_evacuations", "evacuated_bytes", "wasted_s",
        "n_notices", "n_proactive", "proactive_bytes",
        "n_retries", "n_timeouts", "retry_delay_s",
    )

    def __init__(self, machine: MachineModel) -> None:
        self.total_bytes = 0
        self.n_transfers = 0
        self.n_steals = 0
        self.n_events = 0
        self.busy: Dict[int, float] = {r.rid: 0.0 for r in machine.resources}
        self.intervals: List[ScheduledInterval] = []
        # eviction traffic (capacity-bounded memories only)
        self.n_evictions = 0
        self.n_writebacks = 0
        self.writeback_bytes = 0
        # fault/recovery counters (repro.runtime.faults)
        self.n_detaches = 0
        self.n_attaches = 0
        self.n_killed = 0  # running tasks aborted (kill-and-requeue)
        self.n_requeued = 0  # tasks re-activated off dead workers
        self.n_evacuations = 0  # dirty data salvaged to host at detach
        self.evacuated_bytes = 0  # reactive salvage traffic (at death)
        self.wasted_s = 0.0  # partial execution discarded by kills
        # proactive recovery (preemption notices) and flaky-link retries
        self.n_notices = 0  # advance warnings delivered
        self.n_proactive = 0  # sole copies replicated inside the notice
        self.proactive_bytes = 0
        self.n_retries = 0  # failed hops retried with backoff
        self.n_timeouts = 0  # retry budget exhausted -> re-sourced
        self.retry_delay_s = 0.0  # total backoff delay injected

    def fault_summary(self) -> Dict[str, float]:
        """The fault counters as a plain dict (``SimResult.faults``)."""
        return {
            "n_detaches": self.n_detaches,
            "n_attaches": self.n_attaches,
            "n_killed": self.n_killed,
            "n_requeued": self.n_requeued,
            "n_evacuations": self.n_evacuations,
            "evacuated_bytes": self.evacuated_bytes,
            "wasted_s": self.wasted_s,
            "n_notices": self.n_notices,
            "n_proactive": self.n_proactive,
            "proactive_bytes": self.proactive_bytes,
            "n_retries": self.n_retries,
            "n_timeouts": self.n_timeouts,
            "retry_delay_s": self.retry_delay_s,
        }


def recovery_report(faulted: SimResult, baseline: SimResult) -> Dict[str, float]:
    """Recovery metrics of a faulted run against its clairvoyant no-fault
    baseline (same graph/machine/strategy/seed, no detach/attach events).

    ``recovery_makespan`` is the headline number (claim C8): the makespan
    the faults cost on top of the undisturbed schedule. ``extra_bytes``
    includes both evacuation traffic and the re-transfers that rebuilding
    affinity on the survivors required.

    Evacuation traffic is split by when it moved (claim C9):
    ``proactive_bytes`` — sole copies replicated to host inside a
    preemption-notice window, before the device died — versus
    ``reactive_evacuated_bytes`` — salvage at death, on the critical
    recovery path. Retry/timeout counters from flaky links are surfaced
    here too so benchmarks read one dict instead of re-deriving them
    from audit logs.
    """
    out: Dict[str, float] = {
        "makespan": faulted.makespan,
        "baseline_makespan": baseline.makespan,
        "recovery_makespan": faulted.makespan - baseline.makespan,
        "slowdown": (
            faulted.makespan / baseline.makespan
            if baseline.makespan > 0
            else float("inf")
        ),
        "extra_bytes": faulted.total_bytes - baseline.total_bytes,
    }
    if faulted.faults:
        out.update(faulted.faults)
        out["reactive_evacuated_bytes"] = faulted.faults.get(
            "evacuated_bytes", 0
        )
    return out
