"""Run metrics: counters, execution intervals and :class:`SimResult`.

One :class:`Metrics` instance per engine accumulates the machine-global
counters (transferred bytes, transfer/steal/event counts, per-worker busy
time, the interval timeline). Per-graph attribution lives on each
:class:`~repro.runtime.engine.GraphContext` (its own interval list and
completion time), from which the engine derives per-graph results for
multi-tenant streams.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.core.machine import MachineModel


@dataclass(slots=True)
class ScheduledInterval:
    tid: int
    rid: int
    start: float
    end: float


@dataclass
class SimResult:
    makespan: float
    total_bytes: int
    n_transfers: int
    n_steals: int
    busy: Dict[int, float]
    intervals: List[ScheduledInterval]
    strategy: str
    total_flops: float
    n_events: int = 0
    # fault/recovery counters (None for runs with no fault source active;
    # see Metrics.fault_summary and repro.runtime.faults)
    faults: Optional[Dict[str, float]] = None
    # serving-mode arrival accounting (engine.submit at= / admission)
    submit_at: float = 0.0
    admit_at: float = 0.0
    admitted: bool = True

    @property
    def gflops(self) -> float:
        if self.makespan <= 0:
            return 0.0
        return self.total_flops / self.makespan / 1e9

    @property
    def gbytes(self) -> float:
        return self.total_bytes / 1e9


class Metrics:
    """Engine-global counters (shared across every submitted graph)."""

    __slots__ = (
        "total_bytes", "n_transfers", "n_steals", "n_events",
        "busy", "intervals", "n_evictions", "n_writebacks", "writeback_bytes",
        "n_detaches", "n_attaches", "n_killed", "n_requeued",
        "n_evacuations", "evacuated_bytes", "wasted_s",
        "n_notices", "n_proactive", "proactive_bytes",
        "n_retries", "n_timeouts", "retry_delay_s",
        "n_arrivals", "n_admitted", "n_rejected", "n_deferred",
    )

    def __init__(self, machine: MachineModel) -> None:
        self.total_bytes = 0
        self.n_transfers = 0
        self.n_steals = 0
        self.n_events = 0
        self.busy: Dict[int, float] = {r.rid: 0.0 for r in machine.resources}
        self.intervals: List[ScheduledInterval] = []
        # eviction traffic (capacity-bounded memories only)
        self.n_evictions = 0
        self.n_writebacks = 0
        self.writeback_bytes = 0
        # fault/recovery counters (repro.runtime.faults)
        self.n_detaches = 0
        self.n_attaches = 0
        self.n_killed = 0  # running tasks aborted (kill-and-requeue)
        self.n_requeued = 0  # tasks re-activated off dead workers
        self.n_evacuations = 0  # dirty data salvaged to host at detach
        self.evacuated_bytes = 0  # reactive salvage traffic (at death)
        self.wasted_s = 0.0  # partial execution discarded by kills
        # proactive recovery (preemption notices) and flaky-link retries
        self.n_notices = 0  # advance warnings delivered
        self.n_proactive = 0  # sole copies replicated inside the notice
        self.proactive_bytes = 0
        self.n_retries = 0  # failed hops retried with backoff
        self.n_timeouts = 0  # retry budget exhausted -> re-sourced
        self.retry_delay_s = 0.0  # total backoff delay injected
        # serving-mode arrivals and admission control (repro.runtime.load)
        self.n_arrivals = 0  # tenant graphs that reached the machine
        self.n_admitted = 0  # ... admitted past admission control
        self.n_rejected = 0  # ... turned away (working set vs capacity)
        self.n_deferred = 0  # defer re-posts (one arrival may defer many times)

    def fault_summary(self) -> Dict[str, float]:
        """The fault counters as a plain dict (``SimResult.faults``)."""
        return {
            "n_detaches": self.n_detaches,
            "n_attaches": self.n_attaches,
            "n_killed": self.n_killed,
            "n_requeued": self.n_requeued,
            "n_evacuations": self.n_evacuations,
            "evacuated_bytes": self.evacuated_bytes,
            "wasted_s": self.wasted_s,
            "n_notices": self.n_notices,
            "n_proactive": self.n_proactive,
            "proactive_bytes": self.proactive_bytes,
            "n_retries": self.n_retries,
            "n_timeouts": self.n_timeouts,
            "retry_delay_s": self.retry_delay_s,
        }


def recovery_report(faulted: SimResult, baseline: SimResult) -> Dict[str, float]:
    """Recovery metrics of a faulted run against its clairvoyant no-fault
    baseline (same graph/machine/strategy/seed, no detach/attach events).

    ``recovery_makespan`` is the headline number (claim C8): the makespan
    the faults cost on top of the undisturbed schedule. ``extra_bytes``
    includes both evacuation traffic and the re-transfers that rebuilding
    affinity on the survivors required.

    Evacuation traffic is split by when it moved (claim C9):
    ``proactive_bytes`` — sole copies replicated to host inside a
    preemption-notice window, before the device died — versus
    ``reactive_evacuated_bytes`` — salvage at death, on the critical
    recovery path. Retry/timeout counters from flaky links are surfaced
    here too so benchmarks read one dict instead of re-deriving them
    from audit logs.
    """
    out: Dict[str, float] = {
        "makespan": faulted.makespan,
        "baseline_makespan": baseline.makespan,
        "recovery_makespan": faulted.makespan - baseline.makespan,
        "slowdown": (
            faulted.makespan / baseline.makespan
            if baseline.makespan > 0
            else float("inf")
        ),
        "extra_bytes": faulted.total_bytes - baseline.total_bytes,
    }
    if faulted.faults:
        out.update(faulted.faults)
        out["reactive_evacuated_bytes"] = faulted.faults.get(
            "evacuated_bytes", 0
        )
    return out


# ---------------------------------------------------------------------------
# serving-mode aggregates (multi-tenant open-loop load, repro.runtime.load)


def percentile(values: List[float], q: float) -> float:
    """Nearest-rank percentile (``q`` in [0, 100]); 0.0 for empty input.

    Nearest-rank (not interpolated) so a reported p99 is always a value
    some tenant actually experienced.
    """
    if not values:
        return 0.0
    if not (0.0 <= q <= 100.0):
        raise ValueError(f"percentile q must be in [0, 100], got {q!r}")
    s = sorted(values)
    rank = max(1, -(-len(s) * q // 100))  # ceil(len * q / 100), min 1
    return float(s[int(rank) - 1])


def jain_fairness(values: List[float]) -> float:
    """Jain's fairness index (Σx)² / (n·Σx²) — 1.0 means every tenant got
    identical treatment, 1/n means one tenant got everything; 1.0 for
    empty or all-zero input (nobody was treated unequally)."""
    if not values:
        return 1.0
    total = sum(values)
    sq = sum(v * v for v in values)
    if sq <= 0.0:
        return 1.0
    return (total * total) / (len(values) * sq)


def serving_report(tenants: List[Dict[str, float]]) -> Dict[str, float]:
    """Aggregate per-tenant serving rows (``repro.runtime.load.run_serving``)
    into the p50/p99 + fairness summary benchmarks and BENCH_sched.json
    consume.

    Each row carries ``makespan``, ``slowdown`` (vs the tenant's
    empty-machine baseline) and ``queue_delay`` (first execution start
    minus submit time). Fairness is Jain's index over the slowdowns:
    equal slowdown = perfectly fair service, regardless of how different
    the tenants' graph sizes are.
    """
    slow = [float(r["slowdown"]) for r in tenants]
    qd = [float(r["queue_delay"]) for r in tenants]
    mk = [float(r["makespan"]) for r in tenants]
    n = len(tenants)
    return {
        "n_tenants": n,
        "p50_makespan": percentile(mk, 50),
        "p99_makespan": percentile(mk, 99),
        "p50_slowdown": percentile(slow, 50),
        "p99_slowdown": percentile(slow, 99),
        "mean_slowdown": (sum(slow) / n) if n else 0.0,
        "p50_queue_delay": percentile(qd, 50),
        "p99_queue_delay": percentile(qd, 99),
        "jain_fairness": jain_fairness(slow),
    }
