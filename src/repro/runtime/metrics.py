"""Run metrics: counters, execution intervals and :class:`SimResult`.

One :class:`Metrics` instance per engine accumulates the machine-global
counters (transferred bytes, transfer/steal/event counts, per-worker busy
time, the interval timeline). Per-graph attribution lives on each
:class:`~repro.runtime.engine.GraphContext` (its own interval list and
completion time), from which the engine derives per-graph results for
multi-tenant streams.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

from repro.core.machine import MachineModel


@dataclass(slots=True)
class ScheduledInterval:
    tid: int
    rid: int
    start: float
    end: float


@dataclass
class SimResult:
    makespan: float
    total_bytes: int
    n_transfers: int
    n_steals: int
    busy: Dict[int, float]
    intervals: List[ScheduledInterval]
    strategy: str
    total_flops: float
    n_events: int = 0

    @property
    def gflops(self) -> float:
        if self.makespan <= 0:
            return 0.0
        return self.total_flops / self.makespan / 1e9

    @property
    def gbytes(self) -> float:
        return self.total_bytes / 1e9


class Metrics:
    """Engine-global counters (shared across every submitted graph)."""

    __slots__ = (
        "total_bytes", "n_transfers", "n_steals", "n_events",
        "busy", "intervals", "n_evictions", "n_writebacks", "writeback_bytes",
    )

    def __init__(self, machine: MachineModel) -> None:
        self.total_bytes = 0
        self.n_transfers = 0
        self.n_steals = 0
        self.n_events = 0
        self.busy: Dict[int, float] = {r.rid: 0.0 for r in machine.resources}
        self.intervals: List[ScheduledInterval] = []
        # eviction traffic (capacity-bounded memories only)
        self.n_evictions = 0
        self.n_writebacks = 0
        self.writeback_bytes = 0
