"""``repro.runtime`` — the layered simulation engine.

The monolithic ``repro.core.simulator`` has been decomposed into composable
layers (each owning one concern, each independently testable):

  * :mod:`~repro.runtime.events`    — the event heap and its tie-break clock;
  * :mod:`~repro.runtime.queues`    — per-worker deques and the pop/push/
    steal protocol (plus the :class:`WorkSteal` strategy, which is nothing
    but that protocol);
  * :mod:`~repro.runtime.transfers` — link groups, the per-data in-flight
    index, prefetch and the host-hop routing of ``request_transfer``;
  * :mod:`~repro.runtime.memory`    — NEW: capacity-bounded device memories
    with LRU / affinity-aware eviction, dirty write-back, and the memory-
    pressure signal policies consume;
  * :mod:`~repro.runtime.engine`    — the event loop itself, now accepting
    ``submit(graph)`` so many tenant DAGs interleave on one machine;
  * :mod:`~repro.runtime.faults`    — NEW: resource dynamics — detach/
    attach events, drain vs kill-and-requeue recovery, dirty-data
    evacuation, seeded churn;
  * :mod:`~repro.runtime.traces`    — NEW: JSONL preemption-trace replay
    (the varuna-style spot-instance shape);
  * :mod:`~repro.runtime.load`      — NEW: open-loop serving load —
    seeded arrival generators (Poisson / bursty / diurnal), the JSONL
    arrival-trace format, the mixed graph catalog and the
    :func:`run_serving` driver;
  * :mod:`~repro.runtime.rescore`   — NEW: the serving hot path —
    persistent ready pool with dirty-row incremental rescoring
    (``REPRO_SCHED_RESCORE``);
  * :mod:`~repro.runtime.metrics`   — counters, intervals,
    :class:`SimResult`, the recovery report and the serving p50/p99 +
    fairness aggregates.

The fault-trace helpers keep the unqualified ``load_trace``/``save_trace``
names they shipped with; the arrival-trace equivalents are exported as
``load_arrival_trace``/``save_arrival_trace`` (inside ``repro.runtime.load``
they are plain ``load_trace``/``save_trace``, mirroring ``traces.py``).

``repro.core.Simulator`` remains the single-graph facade over
:class:`Engine` and is bit-for-bit identical to the pre-decomposition
simulator when capacity is unbounded (``tests/test_equivalence*.py`` is
the contract). Capacity limits, eviction and multi-graph streaming are
opt-in via ``repro.sched.SchedConfig`` (``REPRO_SCHED_MEM_CAPACITY``,
``REPRO_SCHED_EVICTION``) or the :class:`Engine` constructor.

See ``docs/runtime_architecture.md`` for the layer diagram and the
submit/eviction lifecycle.
"""
# Pre-register the core package before pulling in the engine: the layers
# import repro.core submodules (dag/machine/perfmodel) while repro.core's
# own __init__ imports the Simulator facade, which subclasses the Engine.
# Starting the core package first lets both partial modules resolve each
# other's submodules through sys.modules instead of re-entering a
# half-initialized repro.runtime.engine.
import repro.core  # noqa: F401  (deliberate cycle-breaking import)

from .engine import Engine, GraphContext, Strategy
from .events import EventQueue
from .faults import FaultManager
from .load import (
    ADMISSION_MODES,
    ARRIVAL_PROCESSES,
    Arrival,
    default_catalog,
    make_arrivals,
    run_serving,
)
from .load import load_trace as load_arrival_trace
from .load import save_trace as save_arrival_trace
from .memory import MemoryManager, predicted_eviction_bytes
from .metrics import (
    Metrics,
    ScheduledInterval,
    SimResult,
    jain_fairness,
    recovery_report,
    serving_report,
)
from .queues import Worker, WorkSteal, eligible_victims
from .rescore import RESCORE_MODES, ServingScheduler
from .traces import FAULT_EVENTS, FAULT_MODES, FaultEvent, load_trace, save_trace
from .transfers import TransferEngine

__all__ = [
    "ADMISSION_MODES",
    "ARRIVAL_PROCESSES",
    "Arrival",
    "Engine",
    "EventQueue",
    "FAULT_EVENTS",
    "FAULT_MODES",
    "FaultEvent",
    "FaultManager",
    "GraphContext",
    "MemoryManager",
    "Metrics",
    "RESCORE_MODES",
    "ScheduledInterval",
    "ServingScheduler",
    "SimResult",
    "Strategy",
    "TransferEngine",
    "Worker",
    "WorkSteal",
    "default_catalog",
    "eligible_victims",
    "jain_fairness",
    "load_arrival_trace",
    "load_trace",
    "make_arrivals",
    "predicted_eviction_bytes",
    "recovery_report",
    "run_serving",
    "save_arrival_trace",
    "save_trace",
    "serving_report",
]
