"""Per-worker ready queues and the pop/push/steal protocol (paper §2.2).

Each worker owns a deque of ready tasks: the owner pops from one end
(newest-first under ``owner_lifo``, oldest-first otherwise) and thieves
always take the *oldest* task from the other end. Victim eligibility is
the backlog rule the paper describes: a queue of ≥ 2, or ≥ 1 while the
victim is actually running — a lone task whose input transfers are already
in flight is not worth stealing, its copies are on their way to the
victim's memory.

The :class:`WorkSteal` strategy (formerly ``repro.core.worksteal``) lives
here because it *is* the queue protocol with no model on top: the paper's
"model oblivious" baseline (§4.3).
"""
from __future__ import annotations

from collections import deque
from typing import List, Optional


class Worker:
    """One worker: a ready deque plus its running/blocked state."""

    __slots__ = ("rid", "queue", "running", "run_start", "blocked_on", "pins")

    def __init__(self, rid: int) -> None:
        self.rid = rid
        self.queue: deque = deque()
        self.running = None
        self.run_start: float = 0.0
        self.blocked_on: int = 0  # pending input transfers for head task
        # (mem, [data ids]) pinned against eviction while the head task is
        # blocked or running; empty outside capacity-bounded mode
        self.pins: Optional[tuple] = None


def eligible_victims(workers: List[Worker], thief_rid: int) -> List[Worker]:
    """Steal-eligible victims: a backlog of >=2, or >=1 while running."""
    return [
        w
        for w in workers
        if w.rid != thief_rid
        and (len(w.queue) >= 2 or (len(w.queue) >= 1 and w.running is not None))
    ]


class WorkSteal:
    """Locality-oblivious random work stealing (paper §4.3).

    ``activate`` pushes newly-ready tasks onto the completing worker's own
    queue (owner executes newest-first); idle workers steal the oldest
    task from a randomly selected victim. No performance or transfer
    model is used — the "model oblivious" baseline the paper discusses.

    Satisfies the :class:`repro.sched.Policy` protocol structurally (the
    ``score_matrix`` view is attached by ``repro.sched.policies``).
    """

    name = "ws"
    allow_steal = True
    owner_lifo = True

    def init(self, sim) -> None:  # pragma: no cover - no state
        pass

    def place(self, sim, ready, src: Optional[int]) -> None:
        rid = src if src is not None else 0
        for t in ready:
            sim.push(t, rid)
