"""The event-driven XKaapi-like runtime engine.

Reproduces the paper's execution flow (§2.1-2.2):
  * each worker owns a local ready-queue (pop / push / steal),
  * completing a task triggers ``activate`` on its newly-ready successors —
    this is where the scheduling strategy runs,
  * idle workers emit steal requests to a randomly selected victim (enabled
    per strategy; HEFT/DADA place every ready task explicitly),
  * transfers to/from accelerator memories are prefetched when a task is
    pushed, overlap with computation, and contend on shared PCIe-switch
    links (FIFO per link group — :mod:`repro.runtime.transfers`),
  * the runtime observes real (noisy) durations and feeds the history-based
    performance model, which therefore calibrates online (§2.3).

Beyond the monolithic simulator this engine adds:

  * **multi-graph streams** — :meth:`Engine.submit` accepts any number of
    task graphs, before or during the run (``at=`` posts the arrival as an
    event), so many tenant DAGs interleave on one machine. Each graph gets
    its own :class:`GraphContext` (residency, calibration caches, interval
    timeline) and its own per-graph :class:`SimResult`;
  * **capacity-bounded memories** — opt-in via ``REPRO_SCHED_MEM_CAPACITY``
    / ``REPRO_SCHED_EVICTION`` (:mod:`repro.runtime.memory`): evictions,
    dirty write-backs and the pressure signal policies consume;
  * **stale-transfer cancellation** — opt-in via
    ``REPRO_SCHED_CANCEL_STALE=1``: an in-flight copy of data that is
    overwritten mid-flight no longer lands as a "valid" copy (the
    historical behavior, preserved by default for equivalence, is a known
    modeling artifact of the original simulator).

Determinism: all randomness flows through one seeded numpy Generator (the
per-task duration noise of each graph is drawn, in tid order, when the
graph is submitted).

With a single graph submitted and capacity unbounded, the engine is
bit-for-bit identical to the monolithic simulator it replaced — the same
event posting order, the same seeded stream consumption, the same IEEE
operation order. ``repro.core.Simulator`` is the thin single-graph facade;
``tests/test_equivalence*.py`` enforce the contract against the frozen
scalar references.
"""
from __future__ import annotations

import heapq
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.dag import GraphArrays, Task, TaskGraph
from repro.core.machine import HOST_MEM, MachineModel, ResourceClass
from repro.core.perfmodel import (
    ClassPredictor,
    HistoryPerfModel,
    Residency,
    TransferModel,
)

from .events import EventQueue
from .faults import FaultManager
from .load import ADMISSION_MODES
from .memory import MemoryManager
from .metrics import Metrics, ScheduledInterval, SimResult
from .queues import Worker, eligible_victims
from .rescore import RESCORE_MODES, ServingScheduler
from .traces import FAULT_EVENTS, FAULT_MODES, load_trace
from .transfers import TransferEngine


class Strategy:
    """Scheduling strategy interface: placement happens in ``activate``."""

    name = "base"
    allow_steal = False
    owner_lifo = False

    def init(self, sim) -> None:  # pragma: no cover - default
        pass

    def place(
        self, sim, ready: List[Task], src: Optional[int]
    ) -> None:  # pragma: no cover - interface
        raise NotImplementedError


class GraphContext:
    """Per-submitted-graph state: one tenant DAG inside the engine."""

    __slots__ = (
        "gid", "graph", "arrays", "residency", "inflight", "waiting",
        "noise_mult", "preds", "succ", "done", "n_done", "n_tasks",
        "rid_static", "predictors", "submit_at", "finish", "intervals",
        "data_version", "readers_left", "attempt",
        "priority", "ws_bytes", "arrived", "admitted", "rejected",
        "admit_at",
    )

    def __init__(self, gid: int, graph: TaskGraph) -> None:
        self.gid = gid
        self.graph = graph
        self.arrays: GraphArrays = graph.arrays()
        self.residency = Residency()
        self.residency.attach(self.arrays)
        # all application data starts in host memory (paper setup)
        self.residency.initialize(self.arrays.data_names, HOST_MEM)
        # in-flight transfers indexed per data name: name -> {dst_mem: t}
        self.inflight: Dict[str, Dict[int, float]] = {}
        self.waiting: Dict[tuple, List[int]] = {}  # (name, mem) -> worker rids
        self.preds = [len(graph.pred[t.tid]) for t in graph.tasks]
        self.succ = [graph.succ[t.tid] for t in graph.tasks]
        self.done = [False] * len(graph)
        self.n_done = 0
        self.n_tasks = len(graph)
        self.predictors: Dict[str, ClassPredictor] = {}
        self.rid_static: List[List[float]] = []
        self.noise_mult: Optional[List[float]] = None
        self.submit_at = 0.0
        self.finish = 0.0
        self.intervals: List[ScheduledInterval] = []
        self.data_version: Dict[str, int] = {}  # bumped per write (cancel-stale)
        self.readers_left: List[int] = []  # per-did pending readers (bounded)
        # per-task execution attempt, bumped when a kill-mode detach aborts
        # the running task: the already-posted "done" event of the aborted
        # execution is recognized as stale by its recorded attempt
        self.attempt: List[int] = [0] * len(graph)
        # serving-mode tenancy state (repro.runtime.load): priority feeds
        # the fairness policies, ws_bytes the admission controller; the
        # arrival/admission flags are only ever set in Engine._arrive, so
        # default-loop runs never touch them
        self.priority = 1.0
        self.ws_bytes = int(self.arrays.data_sizes.sum())
        self.arrived = False
        self.admitted = False
        self.rejected = False
        self.admit_at = 0.0


class Engine:
    """The composable event loop: events + queues + transfers + memory.

    Strategies interact with the engine through the same surface the
    monolithic ``Simulator`` exposed (``push``, ``load_ts``, ``now``,
    ``predictor``, ``residency``, ``arrays``, ``graph``, ``machine``,
    ``transfer_model``, ``model``, ``config``, ``memory``); during an
    activation these views point at the graph whose tasks became ready.
    """

    def __init__(
        self,
        machine: MachineModel,
        strategy,
        seed: int = 0,
        noise: float = 0.03,
        transfer_model: Optional[TransferModel] = None,
        config=None,
        mem_capacity: Optional[int] = None,
        eviction: Optional[str] = None,
        cancel_stale: Optional[bool] = None,
        churn: Optional[float] = None,
        fault_mode: Optional[str] = None,
        fault_trace: Optional[str] = None,
        notice_s: Optional[float] = None,
        link_flake: Optional[float] = None,
        retry_max: Optional[int] = None,
        backoff_s: Optional[float] = None,
        audit: Optional[bool] = None,
        rescore: Optional[str] = None,
        admission: Optional[str] = None,
        admit_defer_s: Optional[float] = None,
    ) -> None:
        self.machine = machine
        self.strategy = strategy
        # the typed scheduling configuration (repro.sched.SchedConfig);
        # strategies and instrumentation read engine.config instead of
        # scattering os.environ lookups through hot paths
        self._config = config
        self.rng = np.random.default_rng(seed)
        self.noise = noise
        self.model = HistoryPerfModel()
        self.transfer_model = transfer_model or TransferModel(
            bandwidth=machine.link.bandwidth, latency=machine.link.latency
        )

        self.now = 0.0
        self.events = EventQueue()
        self._events = self.events.heap  # legacy alias (benchmarks reset it)
        self.workers = [Worker(r.rid) for r in machine.resources]
        # shared predicted-completion time-stamps (paper §2.3)
        self.load_ts = [0.0] * len(self.workers)
        # per-rid memory space / residency bit (avoids by_id() in hot paths)
        self._mem_of = [r.mem for r in machine.resources]
        self._bit_of = [1 << (r.mem + 1) for r in machine.resources]
        self._steal_on = strategy.allow_steal
        self._lifo = strategy.owner_lifo

        self.metrics = Metrics(machine)
        self.transfers = TransferEngine(
            machine, self.transfer_model, self.events, self.metrics
        )
        self._link_free = self.transfers.link_free  # legacy alias

        # opt-in layers: capacity-bounded memories + stale cancellation;
        # explicit arguments win over the (env-derived) SchedConfig
        cfg = self.config
        if mem_capacity is None:
            mem_capacity = cfg.mem_capacity
        if eviction is None:
            eviction = cfg.eviction
        if cancel_stale is None:
            cancel_stale = cfg.cancel_stale
        self.memory = MemoryManager(machine, mem_capacity, eviction)
        self.memory.transfers = self.transfers
        self.transfers.memory = self.memory
        self._bounded = self.memory.bounded
        self._cancel_stale = bool(cancel_stale)
        self.transfers.cancel_stale = self._cancel_stale

        # resource dynamics: detach/attach faults (repro.runtime.faults).
        # The manager is always present but inert until a fault source
        # registers — hot paths check `_faults_on` once, preserving the
        # zero-fault bit-for-bit equivalence contract.
        if fault_mode is None:
            fault_mode = cfg.fault_mode
        self.faults = FaultManager(machine, mode=fault_mode)
        self.transfers.faults = self.faults
        self._faults_on = False
        # preemption-notice window: detaches are announced this many
        # simulated seconds in advance (0 = no warning, the default)
        if notice_s is None:
            notice_s = cfg.notice_s
        self._notice_s = float(notice_s)
        if churn is None:
            churn = cfg.churn
        if churn:
            self.faults.enable_churn(
                churn, seed=seed, mode=fault_mode, notice_s=self._notice_s
            )
            self._faults_on = True
        if fault_trace is None:
            fault_trace = cfg.fault_trace
        if fault_trace:
            self.replay_trace(fault_trace)

        # transient link faults: seeded per-hop failure rate with capped
        # exponential retry backoff (repro.runtime.transfers). Zero-flake
        # engines never touch the flake stream — bit-for-bit identical.
        if link_flake is None:
            link_flake = cfg.link_flake
        if retry_max is None:
            retry_max = cfg.retry_max
        if backoff_s is None:
            backoff_s = cfg.backoff_s
        self._flake_on = float(link_flake) > 0.0
        if self._flake_on:
            self.transfers.enable_flake(
                float(link_flake), int(retry_max), float(backoff_s), seed
            )

        # opt-in structured audit log (repro.verify): placements, hops,
        # landing decisions, evictions and fault windows recorded for the
        # independent schedule verifier. Every hook is behind an
        # `is not None` check, so audit-off runs stay bit-for-bit
        # identical to uninstrumented behavior.
        if audit is None:
            audit = cfg.audit
        self.audit = None
        if audit:
            from repro.verify.audit import AuditLog

            self.audit = AuditLog(engine="exact")
            self.audit.log_machine(
                machine,
                host_mem=HOST_MEM,
                capacity=self.memory.capacity if self._bounded else 0,
                eviction=eviction,
                cancel_stale=self._cancel_stale,
                fault_mode=fault_mode,
                seed=seed,
                noise=noise,
            )
        self.transfers.audit = self.audit

        # serving mode (repro.runtime.rescore / repro.runtime.load):
        # a persistent ready pool with incremental dirty-row rescoring
        # replaces per-activation strategy.place, plus admission control
        # at arrival. rescore="off" (the default) leaves the classic
        # run loop — and its bit-for-bit contract — completely untouched.
        if rescore is None:
            rescore = cfg.rescore
        if admission is None:
            admission = cfg.admission
        if admit_defer_s is None:
            admit_defer_s = cfg.admit_defer_s
        if rescore not in RESCORE_MODES:
            raise ValueError(
                f"rescore mode must be one of {RESCORE_MODES}, got {rescore!r}"
            )
        if admission not in ADMISSION_MODES:
            raise ValueError(
                f"admission mode must be one of {ADMISSION_MODES}, "
                f"got {admission!r}"
            )
        self._serving: Optional[ServingScheduler] = None
        if rescore != "off":
            if strategy.allow_steal:
                raise ValueError(
                    f"serving mode (rescore={rescore!r}) places from the "
                    "shared ready pool; work-stealing strategies "
                    f"({strategy.name!r}) are not supported there"
                )
            self._serving = ServingScheduler(rescore)
        self._admission = admission
        if admission != "none" and self._serving is None:
            raise ValueError(
                f"admission={admission!r} requires serving mode "
                "(rescore='full' or 'incremental'); the classic loop "
                "activates every submitted graph unconditionally"
            )
        if not (float(admit_defer_s) > 0.0):
            raise ValueError(
                f"admit_defer_s must be > 0, got {admit_defer_s!r}"
            )
        self._admit_defer_s = float(admit_defer_s)
        # admission accounting: predicted working-set bytes of admitted,
        # unfinished graphs vs the total device capacity
        self._active_ws = 0
        n_dev = len({r.mem for r in machine.resources if r.mem != HOST_MEM})
        self._mem_total = self.memory.capacity * n_dev
        # optional per-tenant fairness hooks on the strategy (wfq)
        self._retire = getattr(strategy, "retire_tenant", None)

        # submitted graphs
        self._ctxs: List[GraphContext] = []
        self._ctx_of: Dict[int, GraphContext] = {}  # id(task) -> context
        self._cur: Optional[GraphContext] = None
        self._pending: List[GraphContext] = []  # roots placed at run() start
        self._running = False
        # strategy-facing views of the current activation's graph
        self.graph: Optional[TaskGraph] = None
        self.arrays: Optional[GraphArrays] = None
        self.residency: Optional[Residency] = None

    # ------------------------------------------------------------------
    @property
    def config(self):
        """The active ``repro.sched.SchedConfig`` for this engine."""
        if self._config is None:
            from repro.sched.config import current_config

            self._config = current_config()
        return self._config

    # legacy metric views (the counters live on ``self.metrics``)
    @property
    def total_bytes(self) -> int:
        return self.metrics.total_bytes

    @property
    def n_transfers(self) -> int:
        return self.metrics.n_transfers

    @property
    def n_steals(self) -> int:
        return self.metrics.n_steals

    @property
    def n_events(self) -> int:
        return self.metrics.n_events

    @property
    def busy(self) -> Dict[int, float]:
        return self.metrics.busy

    @property
    def intervals(self) -> List[ScheduledInterval]:
        return self.metrics.intervals

    # ------------------------------------------------------------------
    def submit(
        self,
        graph: TaskGraph,
        at: Optional[float] = None,
        priority: float = 1.0,
    ) -> GraphContext:
        """Add a task graph to the run (multi-tenant streaming).

        Before ``run()`` the graph's roots are placed when the run starts;
        with ``at`` (or mid-run) the arrival is an event at that simulated
        time, so tenant DAGs stream into a live machine. ``priority``
        (> 0) weights the tenant for priority/weighted-fair policies and
        is ignored by the classic strategies. Returns the graph's
        :class:`GraphContext` (its per-graph result handle).
        """
        if not (float(priority) > 0.0):
            raise ValueError(f"priority must be > 0, got {priority!r}")
        if graph.tasks and id(graph.tasks[0]) in self._ctx_of:
            raise ValueError(
                "this TaskGraph object is already submitted to the engine; "
                "build a fresh graph per tenant (task identity keys the "
                "per-graph state)"
            )
        ctx = GraphContext(len(self._ctxs), graph)
        # One multiplicative noise factor per task (each task executes
        # exactly once), drawn as a single batched normal at submit, in
        # tid order. For the first graph of a fresh engine this consumes
        # the seeded stream exactly like the monolithic simulator did.
        if self.noise > 0 and len(graph) > 0:
            ctx.noise_mult = np.exp(
                self.rng.normal(0.0, self.noise, size=len(graph))
            ).tolist()
        ctx.priority = float(priority)
        ctx.rid_static = [
            self._predictor(ctx, r.cls).static_list
            for r in self.machine.resources
        ]
        self.memory.attach_ctx(ctx)
        if self._serving is not None:
            self._serving.watch_ctx(ctx)
        ctx_of = self._ctx_of
        for t in graph.tasks:
            ctx_of[id(t)] = ctx
        self._ctxs.append(ctx)
        if self._cur is None:
            self._set_ctx(ctx)
        if at is not None and at > self.now:
            ctx.submit_at = at
            self.events.post(at, "submit", ctx)
        elif self._running:
            ctx.submit_at = self.now
            if self._serving is not None:
                self._arrive(ctx)
            else:
                self._activate_roots(ctx)
                if self._steal_on:
                    self._steal_round()
        else:
            ctx.submit_at = max(0.0, at if at is not None else 0.0)
            self._pending.append(ctx)
        if self.audit is not None:
            self.audit.log_graph(ctx.gid, ctx.submit_at, graph)
        return ctx

    # ------------------------------------------------------------------
    def _set_ctx(self, ctx: GraphContext) -> None:
        self._cur = ctx
        self.graph = ctx.graph
        self.arrays = ctx.arrays
        self.residency = ctx.residency

    def _predictor(self, ctx: GraphContext, cls: ResourceClass) -> ClassPredictor:
        p = ctx.predictors.get(cls.name)
        if p is None:
            p = ctx.predictors[cls.name] = ClassPredictor(
                self.model, cls, ctx.arrays
            )
        return p

    def predictor(self, cls: ResourceClass) -> ClassPredictor:
        """Cached vectorized HistoryPerfModel.predict for ``cls`` (of the
        current activation's graph)."""
        return self._predictor(self._cur, cls)

    # ------------------------------------------------------------------
    # fault injection (repro.runtime.faults)
    def inject(
        self,
        event: str,
        rid: int,
        at: Optional[float] = None,
        mode: Optional[str] = None,
        notice_s: Optional[float] = None,
    ) -> None:
        """Schedule a ``"detach"``/``"attach"`` fault for resource ``rid``.

        ``at`` is simulated time (default: now; past times clamp to now —
        simulated time never rewinds). ``mode`` selects the recovery mode
        for a detach (``"drain"``/``"kill"``; default: the engine's
        ``fault_mode``). ``notice_s`` (detach only; default: the engine's
        ``notice_s``) announces the death that many seconds in advance: a
        ``"notice"`` event fires at ``max(now, at - notice_s)``, opening
        the proactive-recovery window (no new work on the rid, sole-copy
        replication, finite pressure penalty). The fault fires as an
        event inside the run loop, interleaving deterministically with
        transfers and completions.
        """
        if event not in FAULT_EVENTS:
            raise ValueError(
                f"fault event must be one of {FAULT_EVENTS}, got {event!r}"
            )
        if mode is not None and mode not in FAULT_MODES:
            raise ValueError(
                f"fault mode must be one of {FAULT_MODES}, got {mode!r}"
            )
        if notice_s is not None:
            if event != "detach":
                raise ValueError(
                    "notice_s only applies to detach events, got "
                    f"event={event!r}"
                )
            if not (float(notice_s) >= 0.0):
                raise ValueError(f"notice_s must be >= 0, got {notice_s!r}")
        self.faults._check_rid(rid)
        at = self.now if at is None else max(float(at), self.now)
        self.faults.active = True
        self._faults_on = True
        if event == "detach":
            ns = float(notice_s) if notice_s is not None else self._notice_s
            if ns > 0.0:
                t_n = max(self.now, at - ns)
                if t_n < at:
                    # the mode slot carries (mode, scheduled death time)
                    self.events.post(
                        t_n, "fault", ("notice", int(rid), (mode, at))
                    )
        self.events.post(at, "fault", (event, int(rid), mode))

    def replay_trace(self, trace) -> None:
        """Inject every event of a JSONL preemption trace — a path for
        :func:`repro.runtime.traces.load_trace`, or an iterable of
        :class:`~repro.runtime.traces.FaultEvent`."""
        events = load_trace(trace) if isinstance(trace, str) else trace
        for ev in events:
            self.inject(
                ev.event, ev.rid, at=ev.t, mode=ev.mode,
                notice_s=ev.notice_s,
            )

    # ------------------------------------------------------------------
    # queue operations (pop / push / steal)
    def push(self, task: Task, rid: int) -> None:
        """Push ``task`` onto worker ``rid``'s queue (any worker may push
        into any other worker's queue, §2.2)."""
        if self._faults_on and not self.faults.alive[rid]:
            # backstop for fault-oblivious strategies (ws pushes to the
            # completing worker, score policies to an argmin): work aimed
            # at a dead worker lands on the next alive one instead
            rid = self.faults.redirect(rid)
        w = self.workers[rid]
        w.queue.append(task)
        ctx = self._ctx_of[id(task)]
        self.transfers.prefetch(
            ctx, task, self._mem_of[rid], self._bit_of[rid], self.now
        )
        self._try_start(w)

    def _steal(self, thief: Worker) -> bool:
        victims = eligible_victims(self.workers, thief.rid)
        if not victims:
            return False
        v = victims[int(self.rng.integers(len(victims)))]
        task = v.queue.popleft()  # thief takes the oldest task
        self.metrics.n_steals += 1
        thief.queue.append(task)
        ctx = self._ctx_of[id(task)]
        self.transfers.prefetch(
            ctx, task, self._mem_of[thief.rid], self._bit_of[thief.rid], self.now
        )
        return True

    def _steal_round(self) -> None:
        # callers guard on self._steal_on (strategy.allow_steal)
        progress = True
        faults_on = self._faults_on
        while progress:
            progress = False
            for w in self.workers:
                if w.running is None and not w.queue:
                    if faults_on and (
                        not self.faults.alive[w.rid]
                        or w.rid in self.faults.noticed
                    ):
                        continue  # dead/condemned workers do not steal
                    if self._steal(w):
                        self._try_start(w)
                        progress = True

    # ------------------------------------------------------------------
    def _unpin_worker(self, w: Worker) -> None:
        if w.pins is not None:
            mem, dids, ctx = w.pins
            unpin = self.memory.unpin
            for did in dids:
                unpin(ctx, did, mem)
            w.pins = None

    def _try_start(self, w: Worker) -> None:
        if w.running is not None or not w.queue:
            return
        rid = w.rid
        if self._faults_on and (
            not self.faults.alive[rid] or rid in self.faults.noticed
        ):
            # the engine never dispatches to a detached device, and a
            # noticed (condemned) worker starts no new work inside its
            # grace window — the running task drains, queued tasks are
            # re-activated on the survivors at death
            return
        task = w.queue[-1] if self._lifo else w.queue[0]
        ctx = self._ctx_of[id(task)]
        # make sure inputs are (going to be) resident
        mem = self._mem_of[rid]
        bit = self._bit_of[rid]
        mask_list = ctx.residency.mask_list
        inflight = ctx.inflight
        waiting = ctx.waiting
        request = self.transfers.request
        now = self.now
        bounded = self._bounded
        reads = ctx.arrays.task_reads[task.tid]
        if bounded:
            # re-pin this head's currently-resident inputs (and drop pins
            # from a previous head evaluation)
            self._unpin_worker(w)
            pinned: List[int] = []
            protect = frozenset(d for d, _, _ in reads)
        missing = 0
        for did, name, size in reads:
            if not mask_list[did] & bit:
                fl = inflight.get(name)
                if fl is None or mem not in fl:
                    request(ctx, name, size, mem, now,
                            protect if bounded else None)
                waiting.setdefault((name, mem), []).append(rid)
                missing += 1
            elif bounded and mem != HOST_MEM:
                self.memory.pin(ctx, did, mem)
                self.memory.touch(ctx, did, mem)
                pinned.append(did)
        if bounded and (pinned or missing):
            w.pins = (mem, pinned, ctx)
        if missing:
            w.blocked_on = missing
            return
        # pop + execute
        if self._lifo:
            w.queue.pop()
        else:
            w.queue.popleft()
        w.blocked_on = 0
        tid = task.tid
        # ground-truth duration: per-rid static flops/rate (the predictor's
        # cached vector, identical to cls.exec_time incl. the 1e-7 floor)
        # times the task's seeded noise factor
        dur = ctx.rid_static[rid][tid]
        if ctx.noise_mult is not None:
            dur *= ctx.noise_mult[tid]
        w.running = task
        w.run_start = now
        self.events.post(now + dur, "done", (rid, ctx, tid, dur, ctx.attempt[tid]))

    # ------------------------------------------------------------------
    def _complete(self, rid: int, ctx: GraphContext, tid: int, dur: float) -> None:
        w = self.workers[rid]
        res = self.machine.resources[rid]
        task = ctx.graph.tasks[tid]
        w.running = None
        ctx.done[tid] = True
        ctx.n_done += 1
        metrics = self.metrics
        metrics.busy[rid] += dur
        iv = ScheduledInterval(tid, rid, w.run_start, self.now)
        metrics.intervals.append(iv)
        ctx.intervals.append(iv)
        self.model.observe(task, res.cls, dur)
        bit = self._bit_of[rid]
        bounded = self._bounded
        # a drained worker finishing after its detach: its memory is gone,
        # so the outputs are written back to host inside the preemption
        # notice window (charged on the memory's link) instead of landing
        # on the vanished device
        dead_mem = None
        if self._faults_on and not self.faults.alive[rid]:
            m = self._mem_of[rid]
            if m != HOST_MEM and m in self.faults.dead_mems:
                dead_mem = m
        if bounded:
            self._unpin_worker(w)
            mem = self._mem_of[rid]
            if mem != HOST_MEM and dead_mem is None:
                # reserve space for the outputs this completion materializes
                incoming = 0
                mask_list = ctx.residency.mask_list
                for did, _, size in ctx.arrays.task_writes[tid]:
                    if not mask_list[did] & bit:
                        incoming += size
                if incoming:
                    protect = frozenset(
                        d for d, _, _ in ctx.arrays.task_writes[tid]
                    ) | frozenset(d for d, _, _ in ctx.arrays.task_reads[tid])
                    self.memory.ensure_capacity(
                        mem, incoming, self.now, ctx, protect
                    )
        write_id = ctx.residency.write_id
        inflight_pop = ctx.inflight.pop
        cancel_stale = self._cancel_stale
        versions = ctx.data_version
        for did, name, size in ctx.arrays.task_writes[tid]:
            if dead_mem is not None:
                self.transfers.one_hop(
                    size,
                    self.transfers.mem_link.get(dead_mem),
                    self.now,
                    kind="evacuate",
                )
                metrics.n_evacuations += 1
                metrics.evacuated_bytes += size
                write_id(did, name, 1)  # sole valid copy lands on host
            else:
                write_id(did, name, bit)
            # invalidate any stale dedup entries for this data (O(1): the
            # in-flight table is indexed per data name)
            inflight_pop(name, None)
            if cancel_stale:
                versions[name] = versions.get(name, 0) + 1
        if self.audit is not None:
            # logged after the write loop so eviction records emitted by
            # ensure_capacity above carry smaller seq than the write
            # effects the verifier applies at this record
            self.audit.log_exec(
                ctx.gid,
                tid,
                rid,
                self._mem_of[rid],
                w.run_start,
                self.now,
                wrote_host=dead_mem is not None,
            )
        if bounded:
            self.memory.note_task_done(ctx, tid)
        # load time-stamp correction (§2.3: runtime corrects predictions)
        if not w.queue:
            self.load_ts[rid] = self.now

        newly_ready: List[Task] = []
        preds = ctx.preds
        tasks = ctx.graph.tasks
        for s in ctx.succ[tid]:
            preds[s] -= 1
            if preds[s] == 0:
                newly_ready.append(tasks[s])
        if ctx.n_done == ctx.n_tasks:
            ctx.finish = self.now
            if self._serving is not None:
                self._graph_finished(ctx)
        if newly_ready:
            # the *activate* operation — where scheduling decisions happen
            self._place_ready(ctx, newly_ready, rid)
        self._try_start(w)
        if self._steal_on:
            self._steal_round()

    # ------------------------------------------------------------------
    def _place_ready(
        self, ctx: GraphContext, ready: List[Task], src: Optional[int]
    ) -> None:
        """Route an activation: the strategy's ``place`` (classic loop)
        or the serving pool (rescore mode). The one seam every
        newly-ready task flows through."""
        if self._serving is not None:
            self._serving.add_ready(self, ctx, ready)
        else:
            self._set_ctx(ctx)
            self.strategy.place(self, ready, src)

    def _activate_roots(self, ctx: GraphContext) -> None:
        roots = ctx.graph.roots()
        if roots:
            self._place_ready(ctx, roots, None)

    # ------------------------------------------------------------------
    # serving mode: arrivals, admission control, tenant teardown
    def _graph_finished(self, ctx: GraphContext) -> None:
        if self._admission != "none" and ctx.admitted:
            self._active_ws -= ctx.ws_bytes
        if self._retire is not None:
            self._retire(ctx)

    def _arrive(self, ctx: GraphContext) -> None:
        """A tenant graph arrives at ``self.now`` (serving mode only):
        log the arrival once, run admission control, then activate."""
        audit = self.audit
        if not ctx.arrived:
            ctx.arrived = True
            self.metrics.n_arrivals += 1
            if audit is not None:
                audit.log_arrival(ctx.gid, ctx.submit_at)
        if self._admission != "none" and self._bounded:
            ws = ctx.ws_bytes
            total = self._mem_total
            if ws > total:
                # can never fit, under any interleaving: reject outright
                # (defer would retry forever)
                ctx.rejected = True
                self.metrics.n_rejected += 1
                if audit is not None:
                    audit.log_reject(ctx.gid, self.now, "too_large")
                return
            if self._active_ws + ws > total:
                if self._admission == "defer":
                    self.metrics.n_deferred += 1
                    self.events.post(
                        self.now + self._admit_defer_s, "submit", ctx
                    )
                else:
                    ctx.rejected = True
                    self.metrics.n_rejected += 1
                    if audit is not None:
                        audit.log_reject(ctx.gid, self.now, "pressure")
                return
            self._active_ws += ws
        ctx.admitted = True
        ctx.admit_at = self.now
        self.metrics.n_admitted += 1
        if audit is not None:
            audit.log_admit(ctx.gid, self.now)
        self._activate_roots(ctx)

    def _run_loop(self) -> None:
        self._running = True
        self.strategy.init(self)
        self.faults.schedule_churn(self)
        pending, self._pending = self._pending, []
        for ctx in pending:
            self._activate_roots(ctx)
        if self._steal_on:
            self._steal_round()
        events = self.events.heap
        heappop = heapq.heappop
        workers = self.workers
        steal_on = self._steal_on
        bounded = self._bounded
        cancel_stale = self._cancel_stale
        faults = self.faults
        faults_on = self._faults_on
        audit = self.audit
        n_events = 0
        while events:
            t, _, kind, payload = heappop(events)
            self.now = t
            n_events += 1
            if kind == "xfer":
                ctx, name, mem, ver, epoch = payload
                inflight = ctx.inflight
                flights = inflight.get(name)
                if flights is not None:
                    flights.pop(mem, None)
                    if not flights:
                        del inflight[name]
                if bounded and mem != HOST_MEM:
                    self.memory.release(ctx, name, mem)
                if faults_on and mem != HOST_MEM and (
                    mem in faults.dead_mems
                    or epoch != faults.mem_epoch.get(mem, 0)
                ):
                    # the destination device detached while this copy was
                    # in flight: the DMA died with it — drop the landing
                    # (the memory was salvaged and its waiters scrubbed at
                    # detach; a re-attached device must not resurrect it)
                    if audit is not None:
                        audit.log_landing(ctx.gid, name, mem, t, False, "dead")
                elif cancel_stale and ver != ctx.data_version.get(name, 0):
                    # the data was overwritten while this copy was in
                    # flight: the landing is stale and is dropped (the
                    # blocked readers below re-request against the new
                    # version)
                    if audit is not None:
                        audit.log_landing(ctx.gid, name, mem, t, False, "stale")
                else:
                    # NOTE (pre-existing modeling artifact, preserved for
                    # equivalence when cancel-stale is off): a transfer in
                    # flight when its data was overwritten still lands as
                    # a "valid" copy — the simulated runtime does not
                    # cancel stale transfers unless REPRO_SCHED_CANCEL_STALE.
                    if bounded and mem != HOST_MEM:
                        did = ctx.arrays.name_to_id.get(name)
                        if did is not None and not (
                            ctx.residency.mask_list[did] & (1 << (mem + 1))
                        ):
                            self.memory.ensure_capacity(
                                mem,
                                ctx.residency._sizes[did],
                                t,
                                ctx,
                                (did,),
                            )
                    ctx.residency.add_copy(name, mem)
                    if audit is not None:
                        audit.log_landing(ctx.gid, name, mem, t, True, "ok")
                waiters = ctx.waiting.pop((name, mem), None)
                if waiters:
                    if bounded and mem != HOST_MEM:
                        did = ctx.arrays.name_to_id.get(name)
                    for rid in waiters:
                        w = workers[rid]
                        if w.blocked_on > 0:
                            w.blocked_on -= 1
                            if (
                                bounded
                                and mem != HOST_MEM
                                and did is not None
                                and w.pins is not None
                                and w.pins[0] == mem
                                and w.pins[2] is ctx
                                and w.blocked_on > 0
                            ):
                                # keep the freshly landed input of a
                                # still-blocked head pinned until its next
                                # head evaluation (only while the head is
                                # still this graph's task — a steal/LIFO
                                # re-head must not record the pin under
                                # another graph's key, which unpin could
                                # then never release)
                                self.memory.pin(ctx, did, mem)
                                w.pins[1].append(did)
                            if w.blocked_on == 0:
                                self._try_start(w)
                if steal_on:
                    self._steal_round()
            elif kind == "done":
                rid, ctx, tid, dur, att = payload
                # a stale attempt is an execution aborted by a kill-mode
                # detach: the task was re-activated elsewhere, this event
                # is the ghost of its first run
                if att == ctx.attempt[tid]:
                    self._complete(rid, ctx, tid, dur)
            elif kind == "fault":
                action, rid, mode = payload
                faults_on = True
                faults.handle(self, action, rid, mode)
            else:  # "submit": a streamed graph arrives
                ctx = payload
                self._activate_roots(ctx)
                if steal_on:
                    self._steal_round()
        self.metrics.n_events = n_events
        if audit is not None:
            audit.finalize(self)
        self._check_complete()

    def _run_loop_serving(self, max_events: Optional[int] = None) -> bool:
        """Serving-mode run loop: same-timestamp event batching plus one
        placement round per batch over the shared ready pool.

        Events of one simulated instant are drained together and the
        :class:`~repro.runtime.rescore.ServingScheduler` round runs once
        per distinct timestamp — one rescoring pass per instant instead
        of one per event.  Returns ``True`` when ``max_events`` capped
        the run (throughput probes measure a fixed amount of work);
        capped runs skip audit finalization and the completeness check.
        """
        serving = self._serving
        self._running = True
        self.strategy.init(self)
        self.faults.schedule_churn(self)
        pending, self._pending = self._pending, []
        for ctx in pending:
            self._arrive(ctx)
        serving.round(self)
        events = self.events.heap
        heappop = heapq.heappop
        workers = self.workers
        bounded = self._bounded
        cancel_stale = self._cancel_stale
        faults = self.faults
        audit = self.audit
        n_events = 0
        capped = False
        while events and not capped:
            t = events[0][0]
            self.now = t
            while events and events[0][0] == t:
                _, _, kind, payload = heappop(events)
                n_events += 1
                if kind == "xfer":
                    ctx, name, mem, ver, epoch = payload
                    inflight = ctx.inflight
                    flights = inflight.get(name)
                    if flights is not None:
                        flights.pop(mem, None)
                        if not flights:
                            del inflight[name]
                    if bounded and mem != HOST_MEM:
                        self.memory.release(ctx, name, mem)
                    if self._faults_on and mem != HOST_MEM and (
                        mem in faults.dead_mems
                        or epoch != faults.mem_epoch.get(mem, 0)
                    ):
                        if audit is not None:
                            audit.log_landing(
                                ctx.gid, name, mem, t, False, "dead"
                            )
                    elif cancel_stale and ver != ctx.data_version.get(name, 0):
                        if audit is not None:
                            audit.log_landing(
                                ctx.gid, name, mem, t, False, "stale"
                            )
                    else:
                        if bounded and mem != HOST_MEM:
                            did = ctx.arrays.name_to_id.get(name)
                            if did is not None and not (
                                ctx.residency.mask_list[did]
                                & (1 << (mem + 1))
                            ):
                                self.memory.ensure_capacity(
                                    mem,
                                    ctx.residency._sizes[did],
                                    t,
                                    ctx,
                                    (did,),
                                )
                        ctx.residency.add_copy(name, mem)
                        if audit is not None:
                            audit.log_landing(ctx.gid, name, mem, t, True, "ok")
                    waiters = ctx.waiting.pop((name, mem), None)
                    if waiters:
                        if bounded and mem != HOST_MEM:
                            did = ctx.arrays.name_to_id.get(name)
                        for rid in waiters:
                            w = workers[rid]
                            if w.blocked_on > 0:
                                w.blocked_on -= 1
                                if (
                                    bounded
                                    and mem != HOST_MEM
                                    and did is not None
                                    and w.pins is not None
                                    and w.pins[0] == mem
                                    and w.pins[2] is ctx
                                    and w.blocked_on > 0
                                ):
                                    self.memory.pin(ctx, did, mem)
                                    w.pins[1].append(did)
                                if w.blocked_on == 0:
                                    self._try_start(w)
                elif kind == "done":
                    rid, ctx, tid, dur, att = payload
                    if att == ctx.attempt[tid]:
                        self._complete(rid, ctx, tid, dur)
                elif kind == "fault":
                    action, rid, mode = payload
                    faults.handle(self, action, rid, mode)
                    # worker liveness / memory epochs moved: every cached
                    # row's eligible set is suspect — coarse invalidation
                    serving.epoch += 1
                else:  # "submit": a streamed tenant graph arrives
                    self._arrive(payload)
                if max_events is not None and n_events >= max_events:
                    capped = True
                    break
            serving.round(self)
        self.metrics.n_events = n_events
        if capped:
            return True
        if audit is not None:
            audit.finalize(self)
        self._check_complete()
        return False

    def _check_complete(self) -> None:
        for ctx in self._ctxs:
            if getattr(ctx, "rejected", False):
                continue  # admission control turned this tenant away
            if ctx.n_done != ctx.n_tasks:
                missing = [
                    t.tid for t in ctx.graph.tasks if not ctx.done[t.tid]
                ]
                raise RuntimeError(
                    f"simulation stalled: graph {ctx.gid} has "
                    f"{len(missing)} tasks unfinished, e.g. {missing[:5]}"
                    + (
                        " (capacity-bounded run: check REPRO_SCHED_MEM_CAPACITY)"
                        if self._bounded
                        else ""
                    )
                )

    # ------------------------------------------------------------------
    def _graph_result(self, ctx: GraphContext) -> SimResult:
        busy: Dict[int, float] = {r.rid: 0.0 for r in self.machine.resources}
        for iv in ctx.intervals:
            busy[iv.rid] += iv.end - iv.start
        return SimResult(
            makespan=(ctx.finish - ctx.submit_at) if not ctx.rejected else 0.0,
            submit_at=ctx.submit_at,
            admit_at=(
                ctx.admit_at if self._serving is not None else ctx.submit_at
            ),
            admitted=not ctx.rejected,
            # transfer/steal counters are machine-global (links and queues
            # are shared across tenant graphs)
            total_bytes=self.metrics.total_bytes,
            n_transfers=self.metrics.n_transfers,
            n_steals=self.metrics.n_steals,
            busy=busy,
            intervals=ctx.intervals,
            strategy=self.strategy.name,
            total_flops=ctx.graph.total_flops(),
            n_events=self.metrics.n_events,
            faults=(
                self.metrics.fault_summary()
                if (self._faults_on or self._flake_on)
                else None
            ),
        )

    def run(self, max_events: Optional[int] = None) -> List[SimResult]:
        """Run every submitted graph to completion; one result per graph
        (submit order), with per-graph makespans and interval timelines.

        ``max_events`` (serving mode only) caps the number of processed
        events — throughput probes measure a fixed amount of work — and
        returns ``[]``, since per-graph results are meaningless for a
        truncated run."""
        if self._serving is not None:
            capped = self._run_loop_serving(max_events)
            if capped:
                return []
        else:
            if max_events is not None:
                raise ValueError(
                    "max_events requires serving mode "
                    "(rescore='full' or 'incremental')"
                )
            self._run_loop()
        return [self._graph_result(ctx) for ctx in self._ctxs]
