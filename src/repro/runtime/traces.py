"""JSONL preemption traces: replayable detach/attach schedules.

Spot-instance preemption logs (the varuna-style shape: one JSON object
per line, ``{"t": <seconds>, "event": "detach"|"attach", "rid": <id>}``)
drive the fault layer directly, so a recorded real-world churn timeline
can be replayed against the simulator deterministically. The optional
``"mode"`` field selects the recovery mode per event (``"drain"`` or
``"kill"``); omitted, the engine's default applies.

Schema v2 (documented in ``docs/runtime_architecture.md``):

  * ``t``        — simulated seconds (non-negative number), required;
  * ``event``    — ``"detach"`` or ``"attach"``, required;
  * ``rid``      — resource id on the simulated machine (non-negative
    int), required;
  * ``mode``     — ``"drain"`` or ``"kill"``, optional, detach events
    only;
  * ``notice_s`` — advance-warning window in seconds (non-negative
    number), optional, detach events only. A detach with ``notice_s``
    is announced that long before ``t`` (spot-style preemption notice);
    v1 lines simply omit the field and load unchanged.

Malformed lines raise ``ValueError`` naming the file and line number —
the same fail-at-the-edge contract as ``repro.sched.SchedConfig``.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Iterable, List, Optional, Sequence, Union

FAULT_EVENTS = ("detach", "attach")
FAULT_MODES = ("drain", "kill")


@dataclass(frozen=True)
class FaultEvent:
    """One preemption-trace entry: (when, what, which resource)."""

    t: float
    event: str
    rid: int
    mode: Optional[str] = None
    notice_s: Optional[float] = None

    def __post_init__(self) -> None:
        if self.event not in FAULT_EVENTS:
            raise ValueError(
                f"fault event must be one of {FAULT_EVENTS}, got {self.event!r}"
            )
        if self.mode is not None and self.mode not in FAULT_MODES:
            raise ValueError(
                f"fault mode must be one of {FAULT_MODES}, got {self.mode!r}"
            )
        if not (self.t >= 0.0):
            raise ValueError(f"fault time must be >= 0, got {self.t!r}")
        if self.rid < 0:
            raise ValueError(f"fault rid must be >= 0, got {self.rid!r}")
        if self.notice_s is not None:
            if self.event != "detach":
                raise ValueError(
                    "fault notice_s only applies to detach events, got "
                    f"event={self.event!r}"
                )
            if not (self.notice_s >= 0.0):
                raise ValueError(
                    f"fault notice_s must be >= 0, got {self.notice_s!r}"
                )


def _parse_entry(obj, where: str) -> FaultEvent:
    if not isinstance(obj, dict):
        raise ValueError(f"{where}: expected a JSON object, got {type(obj).__name__}")
    unknown = set(obj) - {"t", "event", "rid", "mode", "notice_s"}
    if unknown:
        raise ValueError(f"{where}: unknown trace field(s) {sorted(unknown)}")
    try:
        t = obj["t"]
        event = obj["event"]
        rid = obj["rid"]
    except KeyError as e:
        raise ValueError(f"{where}: missing required field {e.args[0]!r}") from None
    if isinstance(t, bool) or not isinstance(t, (int, float)):
        raise ValueError(f"{where}: 't' must be a number, got {t!r}")
    if isinstance(rid, bool) or not isinstance(rid, int):
        raise ValueError(f"{where}: 'rid' must be an integer, got {rid!r}")
    notice = obj.get("notice_s")
    if notice is not None and (
        isinstance(notice, bool) or not isinstance(notice, (int, float))
    ):
        raise ValueError(f"{where}: 'notice_s' must be a number, got {notice!r}")
    try:
        return FaultEvent(
            float(t), event, rid, obj.get("mode"),
            None if notice is None else float(notice),
        )
    except ValueError as e:
        raise ValueError(f"{where}: {e}") from None


def load_trace(path: str) -> List[FaultEvent]:
    """Parse a JSONL preemption trace, sorted by time (stable).

    Raises ``ValueError`` with the file and line number on the first
    malformed line — a truncated or hand-edited trace must not silently
    replay half a schedule.
    """
    events: List[FaultEvent] = []
    with open(path, "r", encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, start=1):
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            where = f"{path}:{lineno}"
            try:
                obj = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{where}: invalid JSON ({e.msg})") from None
            events.append(_parse_entry(obj, where))
    events.sort(key=lambda e: e.t)
    return events


def save_trace(
    events: Iterable[Union[FaultEvent, Sequence]], path: str
) -> None:
    """Write fault events as a JSONL trace (the load_trace inverse).

    Accepts :class:`FaultEvent` instances or ``(t, event, rid[, mode
    [, notice_s]])`` sequences (e.g. a
    :class:`~repro.runtime.faults.FaultManager` history). Optional fields
    are written only when set, so v1 traces round-trip byte-compatibly.
    """
    with open(path, "w", encoding="utf-8") as fh:
        for ev in events:
            if not isinstance(ev, FaultEvent):
                ev = FaultEvent(*ev)
            obj = {"t": ev.t, "event": ev.event, "rid": ev.rid}
            if ev.mode is not None:
                obj["mode"] = ev.mode
            if ev.notice_s is not None:
                obj["notice_s"] = ev.notice_s
            fh.write(json.dumps(obj) + "\n")
