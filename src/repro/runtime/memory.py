"""Capacity-bounded device memories: eviction, write-back, pressure.

The paper's experiments run on accelerators with small, contended
memories, and the dominant transfer cost Kumar et al. measure on real
GPUs is the *eviction and write-back traffic* a capacity-oblivious model
never sees. This layer makes device-memory capacity a first-class part of
the simulation — opt-in, so the unbounded model (and its bit-for-bit
equivalence contract) is untouched:

  * every device memory gets ``capacity`` bytes (host memory stays
    unbounded, the paper setup);
  * incoming copies *reserve* destination space before their hop is
    scheduled; when resident + reserved + incoming overflows, victims are
    evicted until it fits;
  * victim selection is pluggable: ``lru`` (least-recently-touched) or
    ``affinity`` (fewest remaining reader tasks first — data no pending
    task needs is free to drop, the affinity idea applied to eviction);
  * a victim whose *only* valid copy lives on the evicting memory is
    dirty: it is written back to host over the memory's link (charged as
    real transfer traffic, serialized ahead of the incoming copy) before
    the device copy is invalidated;
  * data a worker's head task is blocked on or currently reading is
    pinned and never victimized.

Policies observe the pressure through :meth:`MemoryManager.pressure_rows`
(the predicted eviction bytes a placement would force, as seconds over
the link), folded into the transfer matrices by the strategies and the
:class:`repro.sched.ScoreMatrixPolicy` hook. The same pure
:func:`predicted_eviction_bytes` formula prices expert moves in
``repro.dist.sched_bridge``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.machine import HOST_MEM, MachineModel

EVICTION_POLICIES = ("lru", "affinity")


def predicted_eviction_bytes(resident_bytes, incoming_bytes, capacity):
    """Bytes that must be evicted from a memory holding ``resident_bytes``
    to fit ``incoming_bytes`` under ``capacity`` (elementwise, >= 0).

    The shared eviction-cost formula: the simulator's pressure signal and
    the MoE expert-replanning bridge both price placements with it.
    """
    free = np.maximum(0.0, np.asarray(capacity, dtype=np.float64) - resident_bytes)
    return np.maximum(0.0, np.asarray(incoming_bytes, dtype=np.float64) - free)


def pressure_rows_for(
    sim, tids: Sequence[int], resources, fault_mask: bool = True
) -> Optional[np.ndarray]:
    """The (ready × resources) memory-pressure penalty for a simulation,
    or ``None`` when its device memories are unbounded and no resource is
    detached.

    The one shared lookup every consumer goes through — the
    ``ScoreMatrixPolicy.pressure_matrix`` hook, HEFT/DADA's transfer-row
    fold, and the attached ``score_matrix`` introspection views — so the
    signal cannot drift between them.

    Detached resources (``repro.runtime.faults``) surface here too: their
    columns mask to +inf, so every score-matrix consumer avoids dead
    devices through the channel it already reads. ``fault_mask=False``
    opts out for consumers that handle liveness explicitly (DADA filters
    its placement pools — an +inf cost row would poison its λ search).

    Preemption-noticed resources (a detach announced but not yet fired)
    get a *finite, linearly decaying* penalty instead: the remaining
    time until the scheduled death, ``max(0, death_at - now)``. New work
    steers away from a condemned device while the warning is fresh, yet
    the column stays comparable — near death the penalty vanishes along
    with the window in which a placement could still matter.
    """
    memory = getattr(sim, "memory", None)
    rows = None
    if memory is not None and memory.bounded:
        rows = memory.pressure_rows(
            sim.arrays,
            tids,
            [r.mem for r in resources],
            sim.residency,
            sim.transfer_model,
        )
    if fault_mask:
        faults = getattr(sim, "faults", None)
        if faults is not None and faults.any_dead:
            if rows is None:
                rows = np.zeros(
                    (len(tids), len(resources)), dtype=np.float64
                )
            dead = faults.dead_rids
            for j, r in enumerate(resources):
                if r.rid in dead:
                    rows[:, j] = np.inf
        if faults is not None and faults.noticed:
            if rows is None:
                rows = np.zeros(
                    (len(tids), len(resources)), dtype=np.float64
                )
            now = sim.now
            noticed = faults.noticed
            for j, r in enumerate(resources):
                pending = noticed.get(r.rid)
                if pending is not None:
                    rows[:, j] += max(0.0, pending[1] - now)
    return rows


def fold_pressure(X, P: Optional[np.ndarray]):
    """Add penalty ``P`` into list-rows ``X`` elementwise (identity when
    ``P`` is None) — the exact host-side fold the jax backend mirrors via
    its ``x_bias`` operand."""
    if P is None:
        return X
    return [
        [x + p for x, p in zip(xrow, prow)]
        for xrow, prow in zip(X, P.tolist())
    ]


def _segment_sum(values: np.ndarray, indptr: np.ndarray, n: int) -> np.ndarray:
    col = np.add.reduceat(np.append(values, 0.0), indptr[:-1])[:n]
    empty = indptr[:-1] == indptr[1:]
    if empty.any():
        col = np.where(empty, 0.0, col)
    return col


class MemoryManager:
    """Tracks residency/reservations per device memory and evicts on demand.

    Unbounded (``capacity`` falsy) instances are inert: every hook is a
    no-op and ``bounded`` is False, so the hot paths skip them entirely.
    """

    def __init__(
        self,
        machine: MachineModel,
        capacity: int = 0,
        policy: str = "lru",
    ) -> None:
        if policy not in EVICTION_POLICIES:
            raise ValueError(
                f"unknown eviction policy {policy!r} "
                f"(choose from {EVICTION_POLICIES})"
            )
        self.machine = machine
        self.capacity = int(capacity or 0)
        self.policy = policy
        self.bounded = self.capacity > 0
        self.transfers = None  # TransferEngine, wired by the engine
        device_mems = sorted(
            {r.mem for r in machine.resources if r.mem != HOST_MEM}
        )
        # per-device-memory state, keyed (GraphContext, data id)
        self._lru: Dict[int, Dict[Tuple[object, int], None]] = {
            mem: {} for mem in device_mems
        }
        self._pins: Dict[int, Dict[Tuple[object, int], int]] = {}
        self._resident: Dict[int, int] = {mem: 0 for mem in device_mems}
        self._reserved: Dict[int, int] = {mem: 0 for mem in device_mems}
        self._reservations: Dict[Tuple[object, str, int], int] = {}
        self.max_resident: Dict[int, int] = {mem: 0 for mem in device_mems}

    # ------------------------------------------------------------------
    # wiring
    def attach_ctx(self, ctx) -> None:
        """Bind a submitted graph: observe its residency, track remaining
        readers, and validate that every task's working set fits."""
        if not self.bounded:
            return
        arr = ctx.arrays
        sizes = ctx.residency._sizes

        def observer(did, name, old, new, _ctx=ctx, _sizes=sizes):
            self._mask_changed(_ctx, did, old, new, _sizes)

        ctx.residency.observer = observer
        n_data = len(arr.data_names)
        if len(arr.read_ids):
            ctx.readers_left = np.bincount(
                arr.read_ids, minlength=n_data
            ).tolist()
        else:
            ctx.readers_left = [0] * n_data
        # a single task whose unique accessed bytes exceed the capacity can
        # never run — fail at submit with a configuration error, not a
        # mid-simulation livelock
        if arr.n_tasks:
            per_task = _segment_sum(
                np.where(arr.acc_first, arr.acc_sizes, 0.0),
                arr.acc_indptr, arr.n_tasks,
            )
            worst = int(per_task.max())
            if worst > self.capacity:
                raise ValueError(
                    f"memory capacity {self.capacity} B is smaller than the "
                    f"largest task working set ({worst} B); raise "
                    "REPRO_SCHED_MEM_CAPACITY"
                )

    def _mask_changed(self, ctx, did: int, old: int, new: int, sizes) -> None:
        changed = (old ^ new) & ~1  # host bit (0) is unbounded: ignored
        while changed:
            low = changed & -changed
            mem = low.bit_length() - 2
            key = (ctx, did)
            lru = self._lru.get(mem)
            if lru is None:  # a memory outside the machine (tests): ignore
                changed ^= low
                continue
            if new & low:
                lru.pop(key, None)
                lru[key] = None  # most-recently-used end
                r = self._resident[mem] + sizes[did]
                self._resident[mem] = r
                if r > self.max_resident[mem]:
                    self.max_resident[mem] = r
            else:
                lru.pop(key, None)
                self._resident[mem] -= sizes[did]
            changed ^= low

    # ------------------------------------------------------------------
    # pins and touches (engine-driven lifecycle)
    def pin(self, ctx, did: int, mem: int) -> None:
        pins = self._pins.setdefault(mem, {})
        key = (ctx, did)
        pins[key] = pins.get(key, 0) + 1

    def unpin(self, ctx, did: int, mem: int) -> None:
        pins = self._pins.get(mem)
        if pins is None:
            return
        key = (ctx, did)
        n = pins.get(key, 0)
        if n <= 1:
            pins.pop(key, None)
        else:
            pins[key] = n - 1

    def touch(self, ctx, did: int, mem: int) -> None:
        lru = self._lru.get(mem)
        if lru is None:
            return
        key = (ctx, did)
        if key in lru:
            del lru[key]
            lru[key] = None

    def note_task_done(self, ctx, tid: int) -> None:
        rl = ctx.readers_left
        for did, _, _ in ctx.arrays.task_reads[tid]:
            rl[did] -= 1

    # ------------------------------------------------------------------
    # reservations (incoming transfers)
    def reserve(
        self, ctx, name: str, size: int, mem: int, now: float, protect=None
    ) -> None:
        key = (ctx, name, mem)
        if key in self._reservations:
            return
        self.ensure_capacity(mem, size, now, ctx, protect)
        self._reservations[key] = size
        self._reserved[mem] += size

    def release(self, ctx, name: str, mem: int) -> None:
        size = self._reservations.pop((ctx, name, mem), None)
        if size is not None:
            self._reserved[mem] -= size

    def drop_mem(self, mem: int) -> None:
        """Forget every reservation targeting ``mem`` (the memory's device
        detached: pending copies toward it will be dropped at landing, so
        their space claims must not survive into a re-attach)."""
        for key in [k for k in self._reservations if k[2] == mem]:
            del self._reservations[key]
        if mem in self._reserved:
            self._reserved[mem] = 0

    # ------------------------------------------------------------------
    # eviction
    def ensure_capacity(
        self,
        mem: int,
        incoming: int,
        now: float,
        protect_ctx=None,
        protect_dids=None,
    ) -> None:
        """Evict until ``incoming`` more bytes fit at ``mem``.

        Reservations are accounted so evictions usually happen *here* (and
        their write-backs serialize ahead of the incoming copy on the
        link), but the hard bound is on **resident** bytes: when a
        prefetch storm has reserved most of a memory and nothing more is
        evictable, the reservation overshoot is tolerated — each copy
        re-ensures space when it lands. Only a resident working set that
        genuinely cannot fit raises.
        """
        cap = self.capacity
        while (
            self._resident[mem] + self._reserved[mem] + incoming > cap
        ):
            victim = self._pick_victim(mem, protect_ctx, protect_dids)
            if victim is None:
                if self._resident[mem] + incoming > cap:
                    raise RuntimeError(
                        f"device memory {mem} over capacity: {cap} B "
                        f"capacity, {self._resident[mem]} B resident + "
                        f"{incoming} B incoming, and no evictable "
                        "(unpinned) data remains — "
                        "REPRO_SCHED_MEM_CAPACITY is too small for this "
                        "workload"
                    )
                break  # over-reservation only: resolved as copies land
            self._evict(mem, victim, now)

    def _pick_victim(self, mem, protect_ctx, protect_dids):
        pins = self._pins.get(mem)
        best = None
        best_readers = None
        for key in self._lru[mem]:
            if pins and pins.get(key):
                continue
            ctx, did = key
            if (
                protect_dids is not None
                and ctx is protect_ctx
                and did in protect_dids
            ):
                continue
            if self.policy == "lru":
                return key  # first = least recently used
            readers = ctx.readers_left[did]
            if best is None or readers < best_readers:
                best, best_readers = key, readers
                if readers == 0:
                    break  # nobody pending: cannot do better
        return best

    def _evict(self, mem: int, key, now: float) -> None:
        ctx, did = key
        residency = ctx.residency
        name = ctx.arrays.data_names[did]
        size = residency._sizes[did]
        bit = 1 << (mem + 1)
        metrics = self.transfers.metrics
        dirty = residency.mask_list[did] == bit
        if dirty:
            # sole valid copy (dirty w.r.t. host): write back before
            # invalidation, charged on this memory's link so the incoming
            # copy that forced the eviction queues behind it.
            # Modeling simplification: the host copy is valid from the
            # eviction instant, not from the write-back's completion — a
            # deferred-validity model would leave a window with no valid
            # copy anywhere (readers crash) or require a transitional
            # state the layer does not track. Host readers in that window
            # see bounded optimism; device re-fetches are unaffected (they
            # queue behind the write-back on the same link).
            self.transfers.one_hop(
                size, self.transfers.mem_link.get(mem), now, kind="writeback"
            )
            residency.add_copy(name, HOST_MEM)
            metrics.n_writebacks += 1
            metrics.writeback_bytes += size
        residency.drop_copy(name, mem)  # observer updates lru + resident
        metrics.n_evictions += 1
        audit = self.transfers.audit
        if audit is not None:
            audit.log_evict(ctx.gid, name, mem, now, dirty)

    # ------------------------------------------------------------------
    # the pressure signal (policy-facing)
    def pressure_rows(
        self,
        arr,
        tids: Sequence[int],
        mems: Sequence[int],
        residency,
        transfer_model,
    ) -> np.ndarray:
        """(len(tids) × len(mems)) predicted eviction seconds.

        Entry (i, j): the bytes placing task i on memory j would evict
        (its non-resident unique accessed bytes beyond the memory's free
        space), over the link bandwidth — the marginal eviction/write-back
        time the placement risks. Host columns are 0 (unbounded).
        """
        n, m = len(tids), len(mems)
        out = np.zeros((n, m), dtype=np.float64)
        if not self.bounded or n == 0:
            return out
        indptr, ids, sizes, first = arr.gather_csr(
            np.asarray(tids, dtype=np.int64),
            arr.acc_indptr, arr.acc_ids, arr.acc_sizes, arr.acc_first,
        )
        if len(ids) == 0:
            return out
        masks = residency.mask_of_ids(ids)
        weights = np.where(first, sizes, 0.0)
        bw = transfer_model.bandwidth
        cap = float(self.capacity)
        cols: Dict[int, np.ndarray] = {}
        for j, mem in enumerate(mems):
            if mem == HOST_MEM:
                continue
            col = cols.get(mem)
            if col is None:
                bit = 1 << (mem + 1)
                missing = (masks & bit) == 0
                incoming = _segment_sum(
                    np.where(missing, weights, 0.0), indptr, n
                )
                used = float(self._resident[mem] + self._reserved[mem])
                col = predicted_eviction_bytes(used, incoming, cap) / bw
                cols[mem] = col
            out[:, j] = col
        return out
