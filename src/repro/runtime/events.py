"""Event heap + clock: the ordering backbone of the engine.

Events are ``(time, seq, kind, payload)`` tuples on a binary heap. ``seq``
is a strictly increasing posting counter, so ties in ``time`` resolve in
posting order and payloads are never compared (they may hold arbitrary
objects, e.g. a :class:`~repro.runtime.engine.GraphContext`).

The counter is the engine's logical tie-break clock: preserving the exact
posting order is part of the bit-for-bit contract with the frozen
reference simulator — two events at the same simulated time must fire in
the same order the monolithic simulator fired them.
"""
from __future__ import annotations

import heapq
from typing import Any, List, Optional, Tuple

Event = Tuple[float, int, str, Any]


class EventQueue:
    """A seeded-tie-break event heap.

    ``heap`` is exposed directly: the engine's run loop pops it with a
    locally bound ``heapq.heappop`` (hot path), and the λ-probe benchmark
    clears it between repetitions.
    """

    __slots__ = ("heap", "seq")

    def __init__(self) -> None:
        self.heap: List[Event] = []
        self.seq = 0

    def post(self, t: float, kind: str, payload: Any) -> None:
        """Schedule ``(kind, payload)`` at simulated time ``t``."""
        self.seq += 1
        heapq.heappush(self.heap, (t, self.seq, kind, payload))

    def next_time(self) -> Optional[float]:
        """Timestamp of the earliest pending event (``None`` when empty).

        The serving loop's same-timestamp batching reads this to drain
        every event of one simulated instant before running a single
        placement round over the merged ready pool — one rescoring pass
        per distinct time instead of one per event.
        """
        return self.heap[0][0] if self.heap else None

    def __len__(self) -> int:
        return len(self.heap)

    def __bool__(self) -> bool:
        return bool(self.heap)
