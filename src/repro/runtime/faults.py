"""Resource dynamics: detach/attach faults, recovery modes, seeded churn.

The paper evaluates HEFT/DADA on a *fixed* machine; this layer makes the
machine model dynamic so affinity-based scheduling can be stressed in its
hardest regime — affinity state that suddenly becomes worthless because
the device holding it disappears (the robustness axis arXiv 1711.06433
argues policy families must be evaluated on). A resource can **detach**
(spot preemption, hardware fault) and later **attach** again; the engine
routes both through its event loop, so faults interleave deterministically
with transfers and completions.

Two recovery modes:

  * ``drain`` — stop dispatching to the device and let its running task
    finish; queued tasks are re-activated on the survivors and the
    device's data is salvaged to host (spot preemption comes with notice:
    the runtime uses it to finish in-flight work and evacuate);
  * ``kill`` — the running task is aborted (its partial execution is
    wasted work, counted in ``metrics.wasted_s``) and re-activated on the
    survivors together with the queued tasks. Data is still salvaged —
    the notice window covers memory evacuation either way — but any copy
    *in flight toward* the dead memory is invalidated: each memory
    carries an epoch counter, bumped at detach, and a landing whose
    recorded epoch is stale is dropped (the per-write data-version
    machinery generalized to whole-memory invalidation).

Dirty-data evacuation reuses the MemoryManager write-back path's pricing:
each sole-copy datum is written back over the dead memory's link (charged
as real transfer traffic) before every device copy is dropped, so a
rejoined device starts affinity-cold and no byte is lost.

Fault sources (all three converge on ``Engine.inject``'s event kind):

  * programmatic — ``engine.inject("detach", rid, at=…, mode=…)``;
  * seeded churn — ``REPRO_SCHED_CHURN=rate`` detaches/attaches random
    accelerators with exponential inter-arrival times (rate events per
    simulated second), drawn from a dedicated generator so zero-churn
    runs consume the engine's seeded stream untouched;
  * trace replay — ``REPRO_SCHED_FAULT_TRACE=file.jsonl``
    (:mod:`repro.runtime.traces`) replays recorded preemption timelines.

Policies observe faults through the shared pressure channel
(:func:`repro.runtime.memory.pressure_rows_for` masks dead columns to
+inf) — HEFT folds it into its transfer rows, score-matrix policies get
it via ``pressure_matrix``, and DADA filters its placement pools directly
(an +inf cost row would poison its λ binary search). Queue-protocol
strategies (``ws``) are covered by the engine itself: pushes aimed at a
dead worker are redirected to the next alive one and dead workers neither
start work nor steal. Observers subscribed via :meth:`FaultManager.subscribe`
(e.g. :class:`repro.dist.elastic.ElasticReplanner`) see every transition.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.machine import HOST_MEM, MachineModel

from .traces import FAULT_EVENTS, FAULT_MODES, FaultEvent

# Dedicated churn stream key: keeps the churn generator's draws disjoint
# from the engine's seeded noise stream for every engine seed.
_CHURN_STREAM = 0xFA017


class FaultManager:
    """Per-engine resource liveness plus the detach/attach procedures.

    Inert (``active`` False) until a fault source registers; the engine's
    hot paths check one boolean before touching any of this state, so the
    zero-fault bit-for-bit equivalence contract is preserved.
    """

    def __init__(self, machine: MachineModel, mode: str = "drain") -> None:
        if mode not in FAULT_MODES:
            raise ValueError(
                f"unknown fault mode {mode!r} (choose from {FAULT_MODES})"
            )
        self.machine = machine
        self.default_mode = mode
        n = len(machine.resources)
        self.alive: List[bool] = [True] * n
        self.n_alive = n
        self.dead_rids: frozenset = frozenset()
        self.any_dead = False
        self.dead_mems: set = set()
        # per-memory detach epoch: transfers record the destination epoch
        # at request time; a landing with a stale epoch is dropped
        self.mem_epoch: dict = {}
        self.active = False
        self.history: List[FaultEvent] = []
        # preemption notices: rid -> (t_notice, death_at). A noticed
        # worker is still alive (its running task drains) but the engine
        # starts no new work on it and policies see a finite decaying
        # pressure penalty on its column (pressure_rows_for).
        self.noticed: Dict[int, Tuple[float, float]] = {}
        self.churn_rate = 0.0
        self.churn_notice_s = 0.0
        self.churn_mode = mode
        self._rng: Optional[np.random.Generator] = None
        self._accel_rids = [r.rid for r in machine.resources if r.is_accelerator]
        self._observers: List[Callable] = []

    # ------------------------------------------------------------------
    def subscribe(self, callback: Callable) -> None:
        """Register ``callback(engine, event, rid, mode)`` for every
        detach/attach transition (e.g. an elastic re-planner)."""
        self._observers.append(callback)

    def _notify(self, engine, event: str, rid: int, mode: Optional[str]) -> None:
        for cb in self._observers:
            cb(engine, event, rid, mode)

    # ------------------------------------------------------------------
    def redirect(self, rid: int) -> int:
        """The next alive rid after ``rid`` (cyclic): the engine's backstop
        so fault-oblivious strategies never enqueue onto a dead worker."""
        n = len(self.alive)
        for k in range(1, n + 1):
            j = (rid + k) % n
            if self.alive[j]:
                return j
        raise RuntimeError("no alive workers to redirect to")

    def _mark(self, rid: int, is_alive: bool) -> None:
        self.alive[rid] = is_alive
        self.n_alive += 1 if is_alive else -1
        self.dead_rids = frozenset(
            i for i, a in enumerate(self.alive) if not a
        )
        self.any_dead = bool(self.dead_rids)

    # ------------------------------------------------------------------
    def enable_churn(
        self,
        rate: float,
        seed: int,
        mode: Optional[str] = None,
        notice_s: float = 0.0,
    ) -> None:
        if rate < 0:
            raise ValueError(f"churn rate must be >= 0, got {rate}")
        if notice_s < 0:
            raise ValueError(f"notice_s must be >= 0, got {notice_s}")
        if mode is not None and mode not in FAULT_MODES:
            raise ValueError(
                f"unknown fault mode {mode!r} (choose from {FAULT_MODES})"
            )
        self.churn_rate = float(rate)
        self.churn_notice_s = float(notice_s)
        self.churn_mode = mode or self.default_mode
        self._rng = np.random.default_rng((int(seed) & 0xFFFFFFFF, _CHURN_STREAM))
        if rate > 0:
            self.active = True

    def schedule_churn(self, engine) -> None:
        """Post the first churn tick (the run loop calls this at start)."""
        if self.churn_rate > 0:
            self._post_tick(engine)

    def _post_tick(self, engine) -> None:
        dt = float(self._rng.exponential(1.0 / self.churn_rate))
        engine.events.post(engine.now + dt, "fault", ("churn", -1, None))

    def _churn_tick(self, engine) -> None:
        # stop self-rescheduling once every submitted graph finished —
        # otherwise the churn stream would keep the event loop alive forever
        if all(ctx.n_done >= ctx.n_tasks for ctx in engine._ctxs):
            return
        rng = self._rng
        # a noticed worker is already condemned: it is excluded from the
        # detach pool (no double-notice) and counted as gone for the
        # last-worker guard, so a delayed churn death can never strand
        # the machine with zero alive workers
        alive_g = [
            r for r in self._accel_rids
            if self.alive[r] and r not in self.noticed
        ]
        dead_g = [r for r in self._accel_rids if not self.alive[r]]
        # never detach the last alive worker; only accelerators churn
        # (CPUs are the stable host pool, the spot-instance setup)
        can_detach = bool(alive_g) and self.n_alive - len(self.noticed) > 1
        if dead_g and (not can_detach or rng.random() < 0.5):
            self.attach(engine, dead_g[int(rng.integers(len(dead_g)))])
        elif can_detach:
            rid = alive_g[int(rng.integers(len(alive_g)))]
            ns = self.churn_notice_s
            if ns > 0:
                # spot-style advance warning: the notice lands now, the
                # death is posted ns seconds out
                death_at = engine.now + ns
                self.notice(engine, rid, death_at, self.churn_mode)
                engine.events.post(
                    death_at, "fault", ("detach", rid, self.churn_mode)
                )
            else:
                self.detach(engine, rid, self.churn_mode)
        self._post_tick(engine)

    # ------------------------------------------------------------------
    def handle(self, engine, action: str, rid: int, mode: Optional[str]) -> None:
        """Dispatch one ``"fault"`` event from the engine's run loop."""
        if action == "churn":
            self._churn_tick(engine)
        elif action == "detach":
            self.detach(engine, rid, mode)
        elif action == "attach":
            self.attach(engine, rid)
        elif action == "notice":
            # the mode slot carries (recovery mode, scheduled death time)
            m, death_at = mode
            self.notice(engine, rid, death_at, m)
        else:  # pragma: no cover - engine only posts the four above
            raise ValueError(f"unknown fault action {action!r}")

    # ------------------------------------------------------------------
    def notice(
        self, engine, rid: int, death_at: float, mode: Optional[str] = None
    ) -> None:
        """Deliver an advance warning: ``rid`` will detach at ``death_at``.

        The worker stays alive (its running task drains) but the engine
        starts no new work on it, and if its memory dies with it every
        sole-copy datum is proactively replicated to host *now* — ranked
        most-pending-readers first, the same affinity signal eviction
        uses — instead of on the critical recovery path at death.
        Idempotent per window: a second notice for a pending death is a
        no-op.
        """
        self._check_rid(rid)
        if not self.alive[rid] or rid in self.noticed:
            return
        now = engine.now
        self.noticed[rid] = (now, float(death_at))
        engine.metrics.n_notices += 1
        if engine.audit is not None:
            engine.audit.log_notice(
                now, rid, mode or self.default_mode, float(death_at)
            )
        # proactive replication only helps when the memory dies with the
        # worker (same sharing test the detach salvage uses; co-noticed
        # sharers are condemned too, so they do not count as survivors)
        mem = engine._mem_of[rid]
        shared = any(
            self.alive[r.rid] and r.rid not in self.noticed
            for r in self.machine.resources
            if r.mem == mem and r.rid != rid
        )
        if mem != HOST_MEM and not shared:
            self._replicate(engine, mem)
        self._notify(engine, "notice", rid, mode)

    def _pending_readers(self, ctx, dids: Sequence[int]) -> Dict[int, int]:
        """Pending-reader counts for ``dids`` (the affinity signal).

        Capacity-bounded runs maintain ``ctx.readers_left`` incrementally;
        unbounded runs compute it here by scanning the not-yet-done tasks
        (notices are rare — this is off every hot path).
        """
        if ctx.readers_left:
            return {d: ctx.readers_left[d] for d in dids}
        want = set(dids)
        counts = {d: 0 for d in dids}
        done = ctx.done
        task_reads = ctx.arrays.task_reads
        for t in ctx.graph.tasks:
            if done[t.tid]:
                continue
            for did, _, _ in task_reads[t.tid]:
                if did in want:
                    counts[did] += 1
        return counts

    def _replicate(self, engine, mem: int) -> None:
        """Replicate every sole-copy datum on ``mem`` to host, most
        pending readers first (inside the notice window, before death)."""
        bit = 1 << (mem + 1)
        metrics = engine.metrics
        transfers = engine.transfers
        group = transfers.mem_link.get(mem)
        now = engine.now
        audit = engine.audit
        for ctx in engine._ctxs:
            residency = ctx.residency
            mask_list = residency.mask_list
            names = ctx.arrays.data_names
            sizes = residency._sizes
            sole = [
                did for did in range(len(names)) if mask_list[did] == bit
            ]
            if not sole:
                continue
            readers = self._pending_readers(ctx, sole)
            sole.sort(key=lambda d: (-readers[d], d))
            for did in sole:
                # same pricing (and the same immediate host-copy validity
                # simplification) as the write-back/evacuation path
                transfers.one_hop(sizes[did], group, now, kind="proactive")
                residency.add_copy(names[did], HOST_MEM)
                metrics.n_proactive += 1
                metrics.proactive_bytes += sizes[did]
                if audit is not None:
                    audit.log_landing(
                        ctx.gid, names[did], HOST_MEM, now, True, "proactive"
                    )

    # ------------------------------------------------------------------
    def detach(self, engine, rid: int, mode: Optional[str] = None) -> None:
        """Remove resource ``rid`` from the machine at ``engine.now``.

        Idempotent: detaching an already-dead resource is a no-op.
        Detaching the last alive worker raises (the run could never
        finish).
        """
        self._check_rid(rid)
        if not self.alive[rid]:
            return
        if self.n_alive <= 1:
            raise RuntimeError(
                f"cannot detach rid {rid}: it is the last alive worker"
            )
        mode = mode or self.default_mode
        if mode not in FAULT_MODES:
            raise ValueError(
                f"unknown fault mode {mode!r} (choose from {FAULT_MODES})"
            )
        now = engine.now
        self._mark(rid, False)
        # a noticed death closes its window: record the realized warning
        # time so a saved history replays the notice at the same instant
        pending = self.noticed.pop(rid, None)
        ns = None if pending is None else now - pending[0]
        self.history.append(FaultEvent(now, "detach", rid, mode, ns))
        if engine.audit is not None:
            engine.audit.log_fault(now, "detach", rid, mode)
        metrics = engine.metrics
        metrics.n_detaches += 1

        # 1) strip the worker: queued tasks will be re-activated on the
        # survivors; under kill the running task is aborted and requeued
        # too (its partial execution is wasted work)
        w = engine.workers[rid]
        requeue = list(w.queue)
        w.queue.clear()
        engine._unpin_worker(w)
        w.blocked_on = 0
        if mode == "kill" and w.running is not None:
            task = w.running
            ctx = engine._ctx_of[id(task)]
            # bump the attempt counter: the already-posted "done" event for
            # this execution is recognized as stale and discarded at fire
            ctx.attempt[task.tid] += 1
            metrics.n_killed += 1
            metrics.wasted_s += now - w.run_start
            w.running = None
            requeue.insert(0, task)

        # 2) salvage the device memory (no alive resource left on it):
        # sole-copy (dirty) data is written back to host over the memory's
        # link before every device copy is dropped, then pending landings
        # are invalidated via the memory epoch
        mem = engine._mem_of[rid]
        shared = any(
            self.alive[r.rid]
            for r in self.machine.resources
            if r.mem == mem and r.rid != rid
        )
        if mem != HOST_MEM and not shared:
            self.dead_mems.add(mem)
            self.mem_epoch[mem] = self.mem_epoch.get(mem, 0) + 1
            self._evacuate(engine, mem)
            for ctx in engine._ctxs:
                inflight = ctx.inflight
                for name in list(inflight):
                    flights = inflight[name]
                    flights.pop(mem, None)
                    if not flights:
                        del inflight[name]
            if engine.memory.bounded:
                engine.memory.drop_mem(mem)

        # 3) scrub the waiting index: nobody is left to wake on the dead
        # memory, and the dead rid must not be double-woken if it re-attaches
        mem_gone = mem != HOST_MEM and not shared
        for ctx in engine._ctxs:
            waiting = ctx.waiting
            if mem_gone:
                for key in [k for k in waiting if k[1] == mem]:
                    del waiting[key]
            for key, rids in list(waiting.items()):
                if rid in rids:
                    rids[:] = [r for r in rids if r != rid]
                    if not rids:
                        del waiting[key]

        # 4) re-activate the stripped work on the survivors (strategy
        # placement, exactly like a fresh activation)
        if requeue:
            metrics.n_requeued += len(requeue)
            by_ctx: List = []
            seen = {}
            for task in requeue:
                ctx = engine._ctx_of[id(task)]
                bucket = seen.get(id(ctx))
                if bucket is None:
                    bucket = (ctx, [])
                    seen[id(ctx)] = bucket
                    by_ctx.append(bucket)
                bucket[1].append(task)
            for ctx, tasks in by_ctx:
                engine._place_ready(ctx, tasks, None)
        if engine._steal_on:
            engine._steal_round()
        self._notify(engine, "detach", rid, mode)

    # ------------------------------------------------------------------
    def attach(self, engine, rid: int) -> None:
        """Rejoin resource ``rid`` at ``engine.now``, affinity-cold.

        Idempotent: attaching an alive resource is a no-op. A still-
        draining worker keeps its running task; its memory was salvaged
        at detach, so the device starts with no resident data either way.
        """
        self._check_rid(rid)
        if self.alive[rid]:
            return
        now = engine.now
        self._mark(rid, True)
        self.noticed.pop(rid, None)  # a rejoining device owes no death
        self.history.append(FaultEvent(now, "attach", rid, None))
        if engine.audit is not None:
            engine.audit.log_fault(now, "attach", rid, None)
        engine.metrics.n_attaches += 1
        mem = engine._mem_of[rid]
        self.dead_mems.discard(mem)
        w = engine.workers[rid]
        if w.running is None:
            engine.load_ts[rid] = now
        else:
            engine.load_ts[rid] = max(engine.load_ts[rid], now)
        if engine._steal_on:
            engine._steal_round()
        self._notify(engine, "attach", rid, None)

    # ------------------------------------------------------------------
    def _check_rid(self, rid: int) -> None:
        if not isinstance(rid, (int, np.integer)) or isinstance(rid, bool):
            raise TypeError(f"rid must be an integer, got {rid!r}")
        if not 0 <= rid < len(self.alive):
            raise ValueError(
                f"rid {rid} out of range for a machine with "
                f"{len(self.alive)} resources"
            )

    def _evacuate(self, engine, mem: int) -> None:
        bit = 1 << (mem + 1)
        metrics = engine.metrics
        transfers = engine.transfers
        group = transfers.mem_link.get(mem)
        now = engine.now
        for ctx in engine._ctxs:
            residency = ctx.residency
            mask_list = residency.mask_list
            names = ctx.arrays.data_names
            sizes = residency._sizes
            for did in range(len(names)):
                m = mask_list[did]
                if not m & bit:
                    continue
                name = names[did]
                if m == bit:
                    # sole valid copy lives here: dirty w.r.t. host —
                    # write back over this memory's link (the preemption
                    # notice window), charged as real transfer traffic
                    transfers.one_hop(sizes[did], group, now, kind="evacuate")
                    residency.add_copy(name, HOST_MEM)
                    metrics.n_evacuations += 1
                    metrics.evacuated_bytes += sizes[did]
                residency.drop_copy(name, mem)
