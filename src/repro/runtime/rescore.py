"""Incremental score maintenance for the serving hot path.

The default engine rescores the full (ready × resources) matrix on every
activation (``strategy.place(self, newly_ready, rid)``): O(R·M) per event,
growing linearly with concurrent tenants.  At serving scale — thousands of
tenant DAGs streaming through one machine — most of those rows are
recomputed unchanged, because a single completion only moves a handful of
residency bits.

:class:`ServingScheduler` replaces the per-activation rebuild with a
persistent ready pool and *dirty-row* rescoring:

  * every ready task holds a :class:`PoolEntry` with its cached affinity
    row ``row[j] = transfer(tid → mem_j) + static_duration(tid, rid_j)
    (+ pressure)`` — everything about the score that does **not** depend
    on the instantaneous backlog;
  * rows are invalidated through the residency observer (a mask change on
    datum ``did`` dirties exactly the pool entries reading ``did``, via
    the ``rev`` reverse-dependency index) and through coarse epochs
    (fault events, capacity pressure) — the *invalidation rules*
    documented in ``docs/runtime_architecture.md``;
  * assignment pops a lazy min-heap ranked by each row's best-case score;
    per-worker backlog (``load_ts``) and the policy's fairness scale are
    applied per pop, so ranking tuples never go stale when a round
    charges a worker;
  * ``by_graph`` is the O(1) per-graph ready-set index (tenant teardown
    and per-graph introspection without scanning the pool).

``mode="full"`` runs the identical round algorithm but marks every entry
dirty each round — the naive rescore-everything baseline, kept first-class
so ``benchmarks/serving_load.py`` can measure both paths in one process
and the equivalence test can assert full and incremental modes place
bit-for-bit identically.

Correctness over cleverness at the cache boundary: any state whose effect
on a row decays with *time* rather than with a countable event (the
noticed-worker penalty, capacity pressure) degrades the round to
full-rescore while it is active, so a cached row never embeds a stale
clock reading.
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Set, Tuple

from .memory import pressure_rows_for

RESCORE_MODES = ("off", "full", "incremental")


class PoolEntry:
    """One ready task waiting in the serving pool."""

    __slots__ = ("ctx", "tid", "task", "row", "version")

    def __init__(self, ctx, tid: int, task) -> None:
        self.ctx = ctx
        self.tid = tid
        self.task = task
        self.row: Optional[List[float]] = None  # None = dirty, never built
        self.version = 0


class ServingScheduler:
    """Persistent ready pool with dirty-row incremental rescoring.

    One instance per serving-mode engine.  The engine calls
    :meth:`add_ready` wherever the default loop would call
    ``strategy.place`` and one :meth:`round` after draining each
    same-timestamp event batch.
    """

    def __init__(self, mode: str) -> None:
        if mode not in RESCORE_MODES:
            raise ValueError(
                f"rescore mode must be one of {RESCORE_MODES}, got {mode!r}"
            )
        self.mode = mode
        # (gid, tid) -> PoolEntry: the ready pool
        self.entries: Dict[Tuple[int, int], PoolEntry] = {}
        # gid -> ready tids: the O(1) per-graph ready-set index
        self.by_graph: Dict[int, Set[int]] = {}
        # (gid, did) -> tids reading did: reverse dependency index for
        # residency-driven invalidation
        self.rev: Dict[Tuple[int, int], Set[int]] = {}
        self.dirty: Set[Tuple[int, int]] = set()
        # lazy min-heap of (best_row_score, gid, tid, version); stale
        # versions are skipped on pop
        self.heap: List[Tuple[float, int, int, int]] = []
        # coarse invalidation epoch: bumped by the engine on fault events
        # (worker liveness changed → every row's eligible set changed)
        self.epoch = 0
        self._seen_epoch = 0
        # instrumentation: how many rows were actually (re)built — the
        # quantity incremental mode exists to shrink
        self.rows_built = 0
        self.n_rounds = 0

    # ------------------------------------------------------------------
    # pool maintenance
    def watch_ctx(self, ctx) -> None:
        """Chain onto ``ctx``'s residency observer: a mask change on
        datum ``did`` dirties exactly the pool entries that read it.

        The capacity-bounded memory layer may have installed its own
        observer at ``memory.attach_ctx``; it is preserved and called
        first (same ``(did, name, old, new)`` signature).
        """
        prev = ctx.residency.observer
        gid = ctx.gid
        rev = self.rev
        dirty = self.dirty

        def observer(did, name, old, new, _prev=prev, _gid=gid):
            if _prev is not None:
                _prev(did, name, old, new)
            tids = rev.get((_gid, did))
            if tids:
                for tid in tids:
                    dirty.add((_gid, tid))

        ctx.residency.observer = observer

    def add_ready(self, engine, ctx, ready) -> None:
        """Admit newly-ready tasks into the pool (rows built lazily at
        the next round)."""
        gid = ctx.gid
        entries = self.entries
        by_graph = self.by_graph.setdefault(gid, set())
        rev = self.rev
        dirty = self.dirty
        task_reads = ctx.arrays.task_reads
        for task in ready:
            tid = task.tid
            key = (gid, tid)
            entries[key] = PoolEntry(ctx, tid, task)
            by_graph.add(tid)
            dirty.add(key)
            for did, _name, _size in task_reads[tid]:
                rev.setdefault((gid, did), set()).add(tid)

    def _remove(self, key: Tuple[int, int]) -> None:
        entry = self.entries.pop(key)
        gid, tid = key
        tids = self.by_graph.get(gid)
        if tids is not None:
            tids.discard(tid)
            if not tids:
                del self.by_graph[gid]
        rev = self.rev
        for did, _name, _size in entry.ctx.arrays.task_reads[tid]:
            bucket = rev.get((gid, did))
            if bucket is not None:
                bucket.discard(tid)
                if not bucket:
                    del rev[(gid, did)]
        self.dirty.discard(key)

    # ------------------------------------------------------------------
    # the round: rebuild dirty rows, then assign from the heap
    def _rebuild(self, engine, keys) -> None:
        """(Re)build the cached affinity rows for ``keys``, grouped per
        graph so the batched transfer-row kernel amortizes."""
        entries = self.entries
        resources = engine.machine.resources
        mems = engine._mem_of
        heap = self.heap
        by_gid: Dict[int, List[PoolEntry]] = {}
        for key in sorted(keys):
            entry = entries.get(key)
            if entry is not None:
                by_gid.setdefault(key[0], []).append(entry)
        for gid in sorted(by_gid):
            group = by_gid[gid]
            ctx = group[0].ctx
            tids = [e.tid for e in group]
            engine._set_ctx(ctx)
            X = engine.transfer_model.task_input_transfer_rows(
                ctx.arrays, tids, mems, ctx.residency
            )
            P = pressure_rows_for(engine, tids, resources)
            rid_static = ctx.rid_static
            for i, entry in enumerate(group):
                xrow = X[i]
                tid = entry.tid
                if P is None:
                    row = [
                        xrow[j] + rid_static[j][tid]
                        for j in range(len(xrow))
                    ]
                else:
                    prow = P[i]
                    row = [
                        xrow[j] + rid_static[j][tid] + prow[j]
                        for j in range(len(xrow))
                    ]
                entry.row = row
                entry.version += 1
                self.rows_built += 1
                heapq.heappush(
                    heap, (min(row), gid, tid, entry.version)
                )

    def round(self, engine) -> None:
        """One placement round over the pool at ``engine.now``.

        Invalidation rules (in order of coarseness):

        1. ``mode="full"`` — everything is dirty, every round (the naive
           baseline).
        2. capacity-bounded memories or an open preemption-notice window
           — the pressure term decays with wall-clock time, so cached
           rows cannot be trusted across rounds: degrade to full.
        3. epoch advanced (a fault event fired) — worker liveness and
           memory epochs moved: rebuild everything once.
        4. otherwise — rebuild exactly the rows the residency observer
           and ``add_ready`` marked dirty.
        """
        if not self.entries:
            self.dirty.clear()
            return
        self.n_rounds += 1
        faults = engine.faults
        if (
            self.mode == "full"
            or engine._bounded
            or (engine._faults_on and faults.noticed)
            or self.epoch != self._seen_epoch
        ):
            self.dirty.update(self.entries)
        self._seen_epoch = self.epoch
        if self.dirty:
            # drain in place: the residency observers hold a reference to
            # THIS set object — rebinding self.dirty would strand them
            # writing into a dead set and rows would silently go stale
            dirty = tuple(self.dirty)
            self.dirty.clear()
            self._rebuild(engine, dirty)

        entries = self.entries
        heap = self.heap
        workers = engine.workers
        load_ts = engine.load_ts
        now = engine.now
        faults_on = engine._faults_on
        alive = faults.alive
        noticed = faults.noticed
        strategy = engine.strategy
        scale_fn = getattr(strategy, "tenant_scale", None)
        charge = getattr(strategy, "charge_tenant", None)
        heappop = heapq.heappop
        while heap:
            item = heap[0]
            _rank, gid, tid, version = item
            entry = entries.get((gid, tid))
            if entry is None or entry.version != version:
                heappop(heap)  # stale: assigned or rebuilt since pushed
                continue
            ctx = entry.ctx
            scale = 1.0 if scale_fn is None else float(scale_fn(engine, ctx))
            row = entry.row
            best_j = -1
            best = 0.0
            for j, w in enumerate(workers):
                if w.queue:
                    continue  # one queued task per worker per pass
                if faults_on and (not alive[j] or j in noticed):
                    continue
                lt = load_ts[j]
                backlog = lt - now if lt > now else 0.0
                s = row[j] + backlog * scale
                if best_j < 0 or s < best:
                    best_j = j
                    best = s
            if best_j < 0:
                # every eligible worker already took a task this round:
                # leave the entry ranked for the next round
                break
            heappop(heap)
            dur = ctx.rid_static[best_j][tid]
            lt = load_ts[best_j]
            load_ts[best_j] = (lt if lt > now else now) + dur
            if charge is not None:
                charge(ctx, dur)
            self._remove((gid, tid))
            engine._set_ctx(ctx)
            engine.push(entry.task, best_j)
