"""Open-loop serving load: seeded arrival generators + JSONL arrival traces.

The multi-tenant measurement layer: thousands of tenant DAGs stream into a
live :class:`~repro.runtime.engine.Engine` as an *open-loop* arrival
process (arrivals do not wait for completions — the serving regime where
placement overhead actually matters).  Three seeded generators cover the
canonical shapes:

  * ``poisson``  — memoryless arrivals at a constant rate;
  * ``bursty``   — an on/off modulated process: tight intra-burst gaps,
    long off periods (flash crowds);
  * ``diurnal``  — a sinusoidally modulated rate, sampled by thinning
    (the day/night load curve, compressed).

Arrival traces share the JSONL shape discipline of
:mod:`repro.runtime.traces`: one object per line
(``{"t": <seconds>, "kind": <catalog key>, "tenant": <id>,
"priority": <float, optional>}``), blank/comment lines skipped, and any
malformed line rejected with a ``path:lineno`` error — a truncated or
hand-edited trace must not silently replay half a workload.

``run_serving`` is the one-call driver: it submits every arrival against a
mixed graph-size catalog, runs the engine (optionally with incremental
rescoring and admission control), and reports per-tenant makespan,
slowdown versus the empty-machine baseline, queueing delay and the
p50/p99 + Jain fairness aggregates from :mod:`repro.runtime.metrics`.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Union

import numpy as np

ARRIVAL_PROCESSES = ("poisson", "bursty", "diurnal")
ADMISSION_MODES = ("none", "reject", "defer")

# sub-stream tags: each generator owns a disjoint seeded stream, so e.g.
# poisson(seed=0) and bursty(seed=0) never alias
_POISSON_STREAM = 0x10AD01
_BURSTY_STREAM = 0x10AD02
_DIURNAL_STREAM = 0x10AD03
_KIND_STREAM = 0x10AD04


@dataclass(frozen=True)
class Arrival:
    """One tenant arrival: (when, which graph kind, who, how important)."""

    t: float
    kind: str
    tenant: int
    priority: float = 1.0

    def __post_init__(self) -> None:
        if not (self.t >= 0.0):
            raise ValueError(f"arrival time must be >= 0, got {self.t!r}")
        if not isinstance(self.kind, str) or not self.kind:
            raise ValueError(
                f"arrival kind must be a non-empty string, got {self.kind!r}"
            )
        if self.tenant < 0:
            raise ValueError(f"arrival tenant must be >= 0, got {self.tenant!r}")
        if not (self.priority > 0.0):
            raise ValueError(
                f"arrival priority must be > 0, got {self.priority!r}"
            )


# ---------------------------------------------------------------------------
# JSONL round-trip (the traces.py shape discipline)


def _parse_entry(obj, where: str) -> Arrival:
    if not isinstance(obj, dict):
        raise ValueError(
            f"{where}: expected a JSON object, got {type(obj).__name__}"
        )
    unknown = set(obj) - {"t", "kind", "tenant", "priority"}
    if unknown:
        raise ValueError(f"{where}: unknown trace field(s) {sorted(unknown)}")
    try:
        t = obj["t"]
        kind = obj["kind"]
        tenant = obj["tenant"]
    except KeyError as e:
        raise ValueError(f"{where}: missing required field {e.args[0]!r}") from None
    if isinstance(t, bool) or not isinstance(t, (int, float)):
        raise ValueError(f"{where}: 't' must be a number, got {t!r}")
    if not isinstance(kind, str):
        raise ValueError(f"{where}: 'kind' must be a string, got {kind!r}")
    if isinstance(tenant, bool) or not isinstance(tenant, int):
        raise ValueError(f"{where}: 'tenant' must be an integer, got {tenant!r}")
    priority = obj.get("priority")
    if priority is not None and (
        isinstance(priority, bool) or not isinstance(priority, (int, float))
    ):
        raise ValueError(
            f"{where}: 'priority' must be a number, got {priority!r}"
        )
    try:
        return Arrival(
            float(t), kind, tenant,
            1.0 if priority is None else float(priority),
        )
    except ValueError as e:
        raise ValueError(f"{where}: {e}") from None


def load_trace(path: str) -> List[Arrival]:
    """Parse a JSONL arrival trace, sorted by (time, tenant) (stable).

    Raises ``ValueError`` with the file and line number on the first
    malformed line.
    """
    arrivals: List[Arrival] = []
    with open(path, "r", encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, start=1):
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            where = f"{path}:{lineno}"
            try:
                obj = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{where}: invalid JSON ({e.msg})") from None
            arrivals.append(_parse_entry(obj, where))
    arrivals.sort(key=lambda a: (a.t, a.tenant))
    return arrivals


def save_trace(
    arrivals: Iterable[Union[Arrival, Sequence]], path: str
) -> None:
    """Write arrivals as a JSONL trace (the :func:`load_trace` inverse).

    Accepts :class:`Arrival` instances or ``(t, kind, tenant[, priority])``
    sequences. The default priority is omitted on disk, so traces without
    priorities round-trip byte-compatibly.
    """
    with open(path, "w", encoding="utf-8") as fh:
        for a in arrivals:
            if not isinstance(a, Arrival):
                a = Arrival(*a)
            obj = {"t": a.t, "kind": a.kind, "tenant": a.tenant}
            if a.priority != 1.0:
                obj["priority"] = a.priority
            fh.write(json.dumps(obj) + "\n")


# ---------------------------------------------------------------------------
# seeded open-loop generators


def _rng(seed: int, stream: int) -> np.random.Generator:
    return np.random.default_rng((int(seed) & 0xFFFFFFFF, stream))


def poisson_arrival_times(n: int, rate: float, seed: int = 0) -> np.ndarray:
    """``n`` arrival times of a homogeneous Poisson process at ``rate``
    arrivals per simulated second (exponential inter-arrival gaps)."""
    if n < 0:
        raise ValueError(f"n must be >= 0, got {n}")
    if not (rate > 0.0):
        raise ValueError(f"rate must be > 0, got {rate!r}")
    gaps = _rng(seed, _POISSON_STREAM).exponential(1.0 / rate, size=n)
    return np.cumsum(gaps)


def bursty_arrival_times(
    n: int,
    rate: float,
    seed: int = 0,
    burst: int = 8,
    duty: float = 0.25,
) -> np.ndarray:
    """``n`` arrival times of an on/off (interrupted Poisson) process.

    Geometric bursts of mean size ``burst`` arrive back-to-back at the
    fast *on* rate ``rate / duty``; between bursts the source goes quiet
    long enough that the long-run average rate is still ``rate``. Smaller
    ``duty`` = spikier load at the same average throughput.
    """
    if n < 0:
        raise ValueError(f"n must be >= 0, got {n}")
    if not (rate > 0.0):
        raise ValueError(f"rate must be > 0, got {rate!r}")
    if burst < 1:
        raise ValueError(f"burst must be >= 1, got {burst}")
    if not (0.0 < duty <= 1.0):
        raise ValueError(f"duty must be in (0, 1], got {duty!r}")
    rng = _rng(seed, _BURSTY_STREAM)
    on_rate = rate / duty
    # mean off gap sized so the cycle average matches `rate`:
    # burst arrivals per cycle, cycle length = burst/on_rate + off_gap
    off_gap = burst * (1.0 / rate - 1.0 / on_rate)
    times: List[float] = []
    t = 0.0
    while len(times) < n:
        size = 1 + rng.geometric(1.0 / burst)
        gaps = rng.exponential(1.0 / on_rate, size=size)
        for g in gaps:
            t += float(g)
            times.append(t)
            if len(times) == n:
                break
        t += float(rng.exponential(off_gap))
    return np.asarray(times, dtype=np.float64)


def diurnal_arrival_times(
    n: int,
    rate: float,
    seed: int = 0,
    period: float = 1.0,
    depth: float = 0.9,
) -> np.ndarray:
    """``n`` arrival times of a sinusoidally modulated Poisson process.

    Instantaneous rate ``λ(t) = rate · (1 + depth · sin(2πt/period))``,
    sampled by thinning against the peak rate — the compressed day/night
    curve. ``depth`` in [0, 1) sets how deep the troughs go.
    """
    if n < 0:
        raise ValueError(f"n must be >= 0, got {n}")
    if not (rate > 0.0):
        raise ValueError(f"rate must be > 0, got {rate!r}")
    if not (period > 0.0):
        raise ValueError(f"period must be > 0, got {period!r}")
    if not (0.0 <= depth < 1.0):
        raise ValueError(f"depth must be in [0, 1), got {depth!r}")
    rng = _rng(seed, _DIURNAL_STREAM)
    peak = rate * (1.0 + depth)
    times: List[float] = []
    t = 0.0
    while len(times) < n:
        t += float(rng.exponential(1.0 / peak))
        lam = rate * (1.0 + depth * math.sin(2.0 * math.pi * t / period))
        if rng.random() * peak <= lam:
            times.append(t)
    return np.asarray(times, dtype=np.float64)


def make_arrivals(
    process: str,
    n: int,
    rate: float = 50.0,
    seed: int = 0,
    kinds: Optional[Sequence[str]] = None,
    priorities: Sequence[float] = (1.0,),
    **kwargs,
) -> List[Arrival]:
    """``n`` tenant arrivals from the named process, with graph kinds and
    priorities drawn from their own seeded stream (so the same seed gives
    the same tenant mix under every arrival process)."""
    if process == "poisson":
        times = poisson_arrival_times(n, rate, seed, **kwargs)
    elif process == "bursty":
        times = bursty_arrival_times(n, rate, seed, **kwargs)
    elif process == "diurnal":
        times = diurnal_arrival_times(n, rate, seed, **kwargs)
    else:
        raise ValueError(
            f"arrival process must be one of {ARRIVAL_PROCESSES}, "
            f"got {process!r}"
        )
    if kinds is None:
        kinds = tuple(sorted(default_catalog()))
    rng = _rng(seed, _KIND_STREAM)
    kind_ix = rng.integers(len(kinds), size=n)
    prio_ix = rng.integers(len(priorities), size=n)
    return [
        Arrival(
            float(times[i]),
            kinds[int(kind_ix[i])],
            i,
            float(priorities[int(prio_ix[i])]),
        )
        for i in range(n)
    ]


# ---------------------------------------------------------------------------
# graph catalog + the serving driver


def default_catalog() -> Dict[str, Callable[[], object]]:
    """The mixed graph-size catalog tenants draw from: small dense-linalg
    DAGs (5–30 tasks), sized so thousand-tenant sweeps stay tractable."""
    from repro.linalg.cholesky import cholesky_graph
    from repro.linalg.lu import lu_graph
    from repro.linalg.qr import qr_graph

    return {
        "chol2": lambda: cholesky_graph(2, 256, with_fns=False),
        "chol4": lambda: cholesky_graph(4, 256, with_fns=False),
        "lu3": lambda: lu_graph(3, 256, with_fns=False),
        "qr3": lambda: qr_graph(3, 256, with_fns=False),
    }


def run_serving(
    arrivals: Sequence[Arrival],
    machine=None,
    strategy: Union[str, object] = "heft",
    *,
    seed: int = 0,
    noise: float = 0.0,
    rescore: str = "incremental",
    admission: str = "none",
    mem_capacity: Optional[int] = None,
    catalog: Optional[Dict[str, Callable[[], object]]] = None,
    audit: Optional[bool] = None,
    max_events: Optional[int] = None,
    baselines: Optional[Dict[str, float]] = None,
) -> Dict[str, object]:
    """Drive one serving run: submit every arrival, run, report.

    Arrivals are submitted in canonical ``(t, tenant)`` order, so a
    permuted arrival list produces a bit-identical run (the permutation-
    stability property tests rely on this). ``baselines`` optionally
    memoizes the per-kind empty-machine makespans across calls (the
    slowdown denominators); pass a shared dict when sweeping.
    """
    from repro.runtime.engine import Engine
    from repro.sched import resolve

    from .metrics import serving_report

    if machine is None:
        from repro.configs.paper_machine import paper_machine

        machine = paper_machine(4)
    catalog = default_catalog() if catalog is None else catalog
    spec = strategy if isinstance(strategy, str) else None
    strat = resolve(strategy) if isinstance(strategy, str) else strategy
    engine = Engine(
        machine, strat, seed=seed, noise=noise, rescore=rescore,
        admission=admission, mem_capacity=mem_capacity, audit=audit,
    )
    ordered = sorted(arrivals, key=lambda a: (a.t, a.tenant))
    ctxs = []
    for a in ordered:
        builder = catalog.get(a.kind)
        if builder is None:
            raise ValueError(
                f"arrival kind {a.kind!r} not in catalog "
                f"(known: {sorted(catalog)})"
            )
        ctxs.append(
            (a, engine.submit(builder(), at=a.t, priority=a.priority))
        )
    results = engine.run(max_events=max_events)

    # empty-machine baselines per kind: the slowdown denominator
    # (skipped for event-capped throughput probes — no tenant finishes
    # are reported from a truncated run)
    if baselines is None:
        baselines = {}
    if max_events is None:
        for a, _ctx in ctxs:
            if a.kind not in baselines:
                base = Engine(
                    machine, resolve(spec or "heft"), seed=seed, noise=0.0
                )
                base.submit(catalog[a.kind]())
                baselines[a.kind] = base.run()[0].makespan

    tenants: List[Dict[str, float]] = []
    for a, ctx in ctxs:
        if max_events is not None:
            break
        if ctx.rejected or ctx.n_done != ctx.n_tasks:
            continue
        makespan = ctx.finish - ctx.submit_at
        base = baselines[a.kind]
        first_start = min(iv.start for iv in ctx.intervals)
        tenants.append(
            {
                "tenant": a.tenant,
                "kind": a.kind,
                "priority": a.priority,
                "submit_at": ctx.submit_at,
                "admit_at": ctx.admit_at,
                "makespan": makespan,
                "slowdown": makespan / base if base > 0 else float("inf"),
                "queue_delay": first_start - ctx.submit_at,
            }
        )
    m = engine.metrics
    return {
        "engine": engine,
        "results": results,
        "tenants": tenants,
        "report": serving_report(tenants),
        "n_events": m.n_events,
        "n_arrivals": m.n_arrivals,
        "n_admitted": m.n_admitted,
        "n_rejected": m.n_rejected,
        "n_deferred": m.n_deferred,
        "rows_built": (
            engine._serving.rows_built if engine._serving is not None else None
        ),
    }
