"""Transfer layer: link groups, the in-flight index, prefetch routing.

Lifted from the monolithic simulator's ``request_transfer`` / ``_one_hop``:

  * transfers serialize FIFO on their *link group* (GPUs sharing a PCIe
    switch share its bandwidth — ``link_free`` tracks when each group
    drains);
  * the in-flight index is kept per graph context and per data name
    (``ctx.inflight[name] -> {dst_mem: done_t}``), so duplicate requests
    dedup in O(1) and a write invalidates stale entries in O(copies);
  * GPU→GPU moves route through the host (two hops, the paper-era PCIe
    path), reusing an already-in-flight host hop when one exists.

Capacity-bounded memories (``repro.runtime.memory``) hook in at request
time: space at the destination is reserved *before* the hop is scheduled,
so any eviction write-back the reservation triggers serializes ahead of
the incoming copy on the same link — exactly how a coherent runtime
staging area behaves.

Transient link faults (opt-in via ``REPRO_SCHED_LINK_FLAKE``): each
demand hop fails with a seeded per-hop probability — the DMA ran, held
the link, and was dropped in flight. Failed hops retry with capped
exponential backoff (``REPRO_SCHED_BACKOFF_S`` base, doubling per
attempt, capped at 64×); when the ``REPRO_SCHED_RETRY_MAX`` budget is
exhausted the transfer *times out* and is re-sourced from another live
copy or host, modeled as one final reliable hop. Every attempt occupies
the link and is charged as real traffic (audited as ``retry`` /
``resource`` hops), so byte conservation holds attempt-for-attempt. The
flake generator lives on its own seeded stream: zero-flake runs consume
nothing and stay bit-for-bit identical.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.core.machine import HOST_MEM, LinkModel, MachineModel

from .events import EventQueue
from .metrics import Metrics

# Dedicated flake stream key: keeps per-hop failure draws disjoint from
# the engine's noise stream and the churn stream for every engine seed.
_FLAKE_STREAM = 0xF1A4E


class TransferEngine:
    """Link timing + transfer routing for one engine."""

    __slots__ = (
        "machine", "model", "events", "metrics", "memory",
        "mem_link", "link_free", "_plain_link", "_link_lat", "_link_bw",
        "cancel_stale", "faults", "audit",
        "flake_rate", "retry_max", "backoff_s", "_flake_rng", "_flake_on",
    )

    def __init__(
        self,
        machine: MachineModel,
        transfer_model,
        events: EventQueue,
        metrics: Metrics,
    ) -> None:
        self.machine = machine
        self.model = transfer_model
        self.events = events
        self.metrics = metrics
        self.memory = None  # MemoryManager, wired by the engine
        self.faults = None  # FaultManager, wired by the engine
        self.audit = None  # repro.verify AuditLog, wired by the engine
        self.cancel_stale = False
        # transient link faults (inert until enable_flake)
        self.flake_rate = 0.0
        self.retry_max = 0
        self.backoff_s = 0.0
        self._flake_rng: Optional[np.random.Generator] = None
        self._flake_on = False
        self.link_free: Dict[int, float] = {}
        # accelerator memory -> link group (first resource on that memory)
        self.mem_link: Dict[int, Optional[int]] = {}
        for r in machine.resources:
            if r.is_accelerator:
                self.mem_link.setdefault(r.mem, r.link)
        # inlined link timing (hot path); only valid for a plain LinkModel
        self._plain_link = type(machine.link) is LinkModel
        self._link_lat = machine.link.latency
        self._link_bw = machine.link.bandwidth

    # ------------------------------------------------------------------
    def one_hop(
        self, nbytes: int, group: Optional[int], t: float, kind: str = "copy"
    ) -> float:
        """Serialize the transfer on its link group (FIFO = shared bandwidth)."""
        start = max(t, self.link_free.get(group, 0.0)) if group is not None else t
        if self._plain_link:
            dur = 0.0 if nbytes <= 0 else self._link_lat + nbytes / self._link_bw
        else:
            dur = self.machine.link.time(nbytes)
        done = start + dur
        if group is not None:
            self.link_free[group] = done
        self.metrics.total_bytes += nbytes
        self.metrics.n_transfers += 1
        if self.audit is not None:
            self.audit.log_hop(kind, nbytes, group, t, done)
        return done

    # ------------------------------------------------------------------
    def enable_flake(
        self, rate: float, retry_max: int, backoff_s: float, seed: int
    ) -> None:
        """Arm the seeded per-hop failure model (the engine wires this
        when ``link_flake`` > 0; reliable engines never call it)."""
        if not (0.0 <= rate <= 1.0):
            raise ValueError(f"flake rate must be in [0, 1], got {rate}")
        if retry_max < 0:
            raise ValueError(f"retry_max must be >= 0, got {retry_max}")
        if backoff_s < 0:
            raise ValueError(f"backoff_s must be >= 0, got {backoff_s}")
        self.flake_rate = float(rate)
        self.retry_max = int(retry_max)
        self.backoff_s = float(backoff_s)
        self._flake_rng = np.random.default_rng(
            (int(seed) & 0xFFFFFFFF, _FLAKE_STREAM)
        )
        self._flake_on = self.flake_rate > 0.0

    def _flaky_hop(
        self,
        ctx,
        name: str,
        nbytes: int,
        group: Optional[int],
        t: float,
        dst_mem: int,
    ) -> float:
        """One demand hop under the flake model: retry with capped
        exponential backoff, re-source on timeout.

        Every attempt (the failed ones included) ran on the wire: it
        serializes on the link group and is charged as real traffic, so
        bytes are conserved attempt-for-attempt. The whole chain is
        priced synchronously — ``one_hop`` occupies links eagerly, and
        only the final landing is posted as an event — which keeps the
        event-loop structure (and the zero-flake path) untouched.
        """
        done = self.one_hop(nbytes, group, t)
        attempt = 0
        rng = self._flake_rng
        rate = self.flake_rate
        metrics = self.metrics
        while rng.random() < rate:
            if attempt >= self.retry_max:
                # retry budget exhausted: the transfer times out and is
                # re-sourced from another live copy or host — one final
                # reliable hop, so every transfer eventually lands
                metrics.n_timeouts += 1
                if self.audit is not None:
                    self.audit.log_timeout(
                        ctx.gid, name, dst_mem, done, attempt + 1, nbytes
                    )
                return self.one_hop(nbytes, group, done, kind="resource")
            attempt += 1
            delay = min(
                self.backoff_s * (2.0 ** (attempt - 1)),
                self.backoff_s * 64.0,
            )
            metrics.n_retries += 1
            metrics.retry_delay_s += delay
            if self.audit is not None:
                self.audit.log_retry(
                    ctx.gid, name, dst_mem, done, attempt, delay, nbytes
                )
            done = self.one_hop(nbytes, group, done + delay, kind="retry")
        return done

    # ------------------------------------------------------------------
    def request(
        self,
        ctx,
        name: str,
        size: int,
        dst_mem: int,
        now: float,
        protect=None,
    ) -> Optional[float]:
        """Ensure a valid copy of ``name`` will exist at ``dst_mem``.

        Returns the completion time, or None if already resident.
        ``protect`` (capacity-bounded mode) names data ids of ``ctx`` that
        the reservation's eviction pass must not victimize — the
        requesting task's own working set.
        """
        residency = ctx.residency
        mask = residency._mask.get(name, 0)
        if mask & (1 << (dst_mem + 1)):
            return None  # already resident
        inflight = ctx.inflight
        flights = inflight.get(name)
        if flights is not None:
            done = flights.get(dst_mem)
            if done is not None:
                return done
        if mask == 0:
            raise RuntimeError(f"no valid copy of {name} anywhere")
        memory = self.memory
        if memory is not None and memory.bounded and dst_mem != HOST_MEM:
            # reserve destination space first: eviction write-backs queue
            # on the link ahead of this copy
            memory.reserve(ctx, name, size, dst_mem, now, protect)
        ver = ctx.data_version.get(name, 0) if self.cancel_stale else 0
        # the destination memory's detach epoch (repro.runtime.faults):
        # a landing posted before a detach carries a stale epoch and is
        # dropped — the DMA died with the device. 0 whenever faults are
        # inactive (host memory never detaches, so host hops stay 0).
        faults = self.faults
        epoch = (
            faults.mem_epoch.get(dst_mem, 0)
            if faults is not None and faults.active
            else 0
        )
        mem_link = self.mem_link
        post = self.events.post
        flake = self._flake_on
        if (mask & 1) and dst_mem != HOST_MEM:
            # a host copy exists: single host->device hop
            done = (
                self._flaky_hop(
                    ctx, name, size, mem_link.get(dst_mem), now, dst_mem
                )
                if flake
                else self.one_hop(size, mem_link.get(dst_mem), now)
            )
        elif dst_mem == HOST_MEM:
            src = (mask & -mask).bit_length() - 2  # lowest-numbered location
            done = (
                self._flaky_hop(
                    ctx, name, size, mem_link.get(src), now, HOST_MEM
                )
                if flake
                else self.one_hop(size, mem_link.get(src), now)
            )
        else:
            # GPU -> host -> GPU (two hops, paper-era PCIe path)
            src = (mask & -mask).bit_length() - 2
            if flights is not None and HOST_MEM in flights:
                mid = flights[HOST_MEM]
            else:
                mid = (
                    self._flaky_hop(
                        ctx, name, size, mem_link.get(src), now, HOST_MEM
                    )
                    if flake
                    else self.one_hop(size, mem_link.get(src), now)
                )
                if flights is None:
                    flights = inflight[name] = {}
                flights[HOST_MEM] = mid
                post(mid, "xfer", (ctx, name, HOST_MEM, ver, 0))
                if self.audit is not None:
                    self.audit.note_request(ctx.gid, name, HOST_MEM, mid, now)
            done = (
                self._flaky_hop(
                    ctx, name, size, mem_link.get(dst_mem), mid, dst_mem
                )
                if flake
                else self.one_hop(size, mem_link.get(dst_mem), mid)
            )
        if flights is None:
            flights = inflight[name] = {}
        flights[dst_mem] = done
        post(done, "xfer", (ctx, name, dst_mem, ver, epoch))
        if self.audit is not None:
            self.audit.note_request(ctx.gid, name, dst_mem, done, now)
        return done

    # ------------------------------------------------------------------
    def prefetch(self, ctx, task, mem: int, bit: int, now: float) -> None:
        """Start transfers for every non-resident input of ``task``."""
        mask_list = ctx.residency.mask_list
        inflight = ctx.inflight
        reads = ctx.arrays.task_reads[task.tid]
        protect = None
        for did, name, size in reads:
            if not mask_list[did] & bit:
                fl = inflight.get(name)
                if fl is None or mem not in fl:
                    if protect is None and self.memory is not None and self.memory.bounded:
                        protect = frozenset(d for d, _, _ in reads)
                    self.request(ctx, name, size, mem, now, protect)
