"""Training step builder: loss, grads, clipping, AdamW, aux losses.

``make_train_step`` returns a pure function suitable for jit/pjit; the
distribution layer (dist/) wraps it with shardings; launch/dryrun.py lowers
it for every (arch x shape x mesh) cell.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import encode, forward
from repro.optim.adamw import adamw_update, clip_by_global_norm, cosine_schedule


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray, mask=None):
    """logits (B,S,V) fp32; targets (B,S) int. Mean CE over masked positions.

    Implemented as one-hot contractions, NOT take_along_axis: a gather over
    the vocab dim forces SPMD to all-gather vocab-sharded logits (terabytes
    at 4k x 256 batch), while one-hot reductions partition cleanly — each
    vocab shard contributes a masked partial sum, and only (B,S) scalars
    cross devices (§Perf iteration 0).
    """
    V = logits.shape[-1]
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    onehot = jax.nn.one_hot(targets, V, dtype=logits.dtype)
    tgt = jnp.sum(shifted * onehot, axis=-1)
    nll = lse - tgt
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def make_loss_fn(cfg: ModelConfig, expert_perm: Optional[jnp.ndarray] = None, moe_chunks: int = 1):
    def loss_fn(params, batch: Dict) -> Tuple[jnp.ndarray, Dict]:
        tokens = batch["tokens"]
        enc_out = None
        extra = None
        if cfg.family == "audio":  # encoder-decoder over frame embeddings
            enc_out = encode(params, cfg, batch["frontend"])
        elif cfg.family == "vlm":
            extra = batch["frontend"]
        logits, _, aux = forward(
            params, cfg, tokens, extra_embeds=extra, enc_out=enc_out,
            expert_perm=expert_perm, moe_chunks=moe_chunks,
        )
        P = extra.shape[1] if extra is not None else 0
        # next-token prediction on the text region
        pred = logits[:, P : P + tokens.shape[1] - 1]
        tgt = tokens[:, 1:]
        ce = cross_entropy(pred, tgt)
        loss = ce + aux
        return loss, {"ce": ce, "aux": aux}

    return loss_fn


def make_train_step(
    cfg: ModelConfig,
    *,
    base_lr: float = 3e-4,
    warmup: int = 100,
    total_steps: int = 10000,
    max_grad_norm: float = 1.0,
    expert_perm: Optional[jnp.ndarray] = None,
    grad_transform=None,
    micro_batches: int = 1,
    moe_chunks: int = 1,
    accum_dtype=jnp.float32,
):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``micro_batches`` > 1 splits the batch and accumulates gradients with a
    scan — the activation-memory lever for the large dry-run shapes.
    ``grad_transform(grads) -> grads`` is the hook where cross-pod gradient
    compression (optim/compression.py) plugs in.
    """
    loss_fn = make_loss_fn(cfg, expert_perm, moe_chunks)

    def grads_of(params, batch):
        if micro_batches == 1:
            return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

        def split(x):
            B = x.shape[0]
            assert B % micro_batches == 0, (B, micro_batches)
            # (B,...) -> (B/m, m, ...) -> transpose to (m, B/m, ...).
            # Reshaping (B,) -> (m, B/m) directly would split the *sharded*
            # batch dim across microbatches (micro 0 = rows 0..B/m live on a
            # few devices only) and SPMD falls back to full replication
            # inside the accumulation loop; splitting as (B/m, m) keeps each
            # device's contiguous block intact and the transpose is
            # sharding-clean (§Perf log).
            return x.reshape(B // micro_batches, micro_batches, *x.shape[1:]).swapaxes(0, 1)

        micro = jax.tree.map(split, batch)

        def acc_step(carry, mb):
            g_acc, l_acc, p_acc = carry
            (l, parts), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            g_acc = jax.tree.map(lambda a, b: (a + b.astype(a.dtype)), g_acc, g)
            return (g_acc, l_acc + l, jax.tree.map(lambda a, b: a + b, p_acc, parts)), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
        p0 = {"ce": jnp.zeros((), jnp.float32), "aux": jnp.zeros((), jnp.float32)}
        (g, l, parts), _ = jax.lax.scan(acc_step, (g0, jnp.zeros(()), p0), micro)
        inv = 1.0 / micro_batches
        return (l * inv, jax.tree.map(lambda a: a * inv, parts)), jax.tree.map(
            lambda a: a * inv, g
        )

    def train_step(params, opt_state, batch):
        (loss, parts), grads = grads_of(params, batch)
        if grad_transform is not None:
            grads = grad_transform(grads)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = cosine_schedule(
            opt_state["step"], base_lr=base_lr, warmup=warmup, total=total_steps
        )
        params, opt_state = adamw_update(grads, opt_state, params, lr)
        metrics = {
            "loss": loss,
            "ce": parts["ce"],
            "aux": parts["aux"],
            "grad_norm": gnorm,
            "lr": lr,
        }
        return params, opt_state, metrics

    return train_step
