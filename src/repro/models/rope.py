"""Rotary position embeddings: full (llama-style) and half/2d (chatglm,
minicpm-style: only the first half of head_dim is rotated)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_table(positions, rot_dim: int, theta: float = 10000.0):
    """cos/sin tables for `positions` (any shape) over `rot_dim` dims."""
    inv = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., rot_dim/2)
    return jnp.cos(ang), jnp.sin(ang)


def _rotate(x, cos, sin):
    """x: (..., rot_dim) -> rotated (interleaved-pair convention)."""
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1)
    return out.reshape(x.shape)


def apply_rope(x, cos, sin, style: str = "full"):
    """x: (B, S, H, hd); cos/sin: (S, rot/2) or (B, S, rot/2)."""
    if style == "none":
        return x
    hd = x.shape[-1]
    rot = hd if style == "full" else hd // 2
    if cos.ndim == 2:  # (S, rot/2) -> broadcast over batch and heads
        c = cos[None, :, None, :]
        s = sin[None, :, None, :]
    else:  # (B, S, rot/2)
        c = cos[:, :, None, :]
        s = sin[:, :, None, :]
    xr = _rotate(x[..., :rot].astype(jnp.float32), c, s).astype(x.dtype)
    if rot == hd:
        return xr
    return jnp.concatenate([xr, x[..., rot:]], axis=-1)
