"""GQA/MQA attention with KV cache, causal/bidirectional/cross variants.

jnp einsum path is the default (lowerable on any backend, used by the
dry-run); the Pallas flash kernel (kernels/flash_attention.py) is the
TPU-executable hot path, validated against the same math in tests.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init
from .rope import apply_rope


def attn_init(key, d: int, n_heads: int, n_kv: int, hd: int, dtype) -> Dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, (d, n_heads * hd), dtype),
        "wk": dense_init(kk, (d, n_kv * hd), dtype),
        "wv": dense_init(kv, (d, n_kv * hd), dtype),
        "wo": dense_init(ko, (n_heads * hd, d), dtype),
    }


_CHUNK_Q = 1024


def _repeat_kv(k, group: int):
    """GQA: expand KV heads to match Q heads. A plain repeat keeps the Q-head
    dim cleanly shardable over 'model' (reshaping H into (Hkv, group) breaks
    SPMD propagation when Hkv < mesh model size — seen as involuntary
    full-rematerialization in the dry run)."""
    return jnp.repeat(k, group, axis=2) if group > 1 else k


def _sdpa_block(q, k, v, *, causal: bool, q_offset, scale):
    """q: (B,bq,H,hd); k,v: (B,Sk,H,hd) — exact softmax over full keys."""
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if causal:
        bq, sk = q.shape[1], k.shape[1]
        qpos = q_offset + jnp.arange(bq)[:, None]
        kpos = jnp.arange(sk)[None, :]
        logits = jnp.where((kpos <= qpos)[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def _sdpa(q, k, v, *, causal: bool, offset: int = 0):
    """q: (B,Sq,H,hd); k,v: (B,Sk,Hkv,hd).

    Long sequences scan over query chunks (flash-style O(Sq/chunk x Sk)
    working set) — the jnp analogue of kernels/flash_attention.py; the
    Pallas kernel is the TPU-executable twin of the same math.
    """
    B, Sq, H, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    k = _repeat_kv(k, H // Hkv)
    v = _repeat_kv(v, H // Hkv)
    scale = 1.0 / (hd**0.5)
    if Sq <= _CHUNK_Q:
        return _sdpa_block(q, k, v, causal=causal, q_offset=offset, scale=scale)
    nblk = Sq // _CHUNK_Q
    assert Sq % _CHUNK_Q == 0, (Sq, _CHUNK_Q)

    # dynamic_slice on the (unsharded) seq dim keeps batch/head shardings
    # intact across chunks — reshaping/transposing the sharded tensor into a
    # stacked scan input forces SPMD to reshard every iteration (§Perf log)
    def one(acc, i):
        qi = jax.lax.dynamic_slice_in_dim(q, i * _CHUNK_Q, _CHUNK_Q, axis=1)
        o = _sdpa_block(
            qi, k, v, causal=causal, q_offset=offset + i * _CHUNK_Q, scale=scale
        )
        acc = jax.lax.dynamic_update_slice_in_dim(acc, o, i * _CHUNK_Q, axis=1)
        return acc, None

    acc0 = jnp.zeros_like(q)
    out, _ = jax.lax.scan(one, acc0, jnp.arange(nblk))
    return out


def attn_apply_kv(
    params: Dict,
    x: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    n_heads: int,
    n_kv: int,
    hd: int,
) -> jnp.ndarray:
    """Cross-attention against precomputed K/V (B,Sk,Hkv,hd) — the decode
    fast path: K/V of the encoder memory are computed once per request, not
    once per token (§Perf, seamless decode cell)."""
    B, Sq, _ = x.shape
    q = (x @ params["wq"]).reshape(B, Sq, n_heads, hd)
    out = _sdpa(q, k, v, causal=False)
    return out.reshape(B, Sq, n_heads * hd) @ params["wo"]


def attn_apply(
    params: Dict,
    x: jnp.ndarray,
    *,
    n_heads: int,
    n_kv: int,
    hd: int,
    rope_cos=None,
    rope_sin=None,
    rope_style: str = "full",
    causal: bool = True,
    cache: Optional[Dict] = None,
    cache_pos: Optional[jnp.ndarray] = None,
    kv_source: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Self- or cross-attention.

    cache: {"k","v"} of shape (B, S_cache, Hkv, hd). In decode mode
    (x is (B,1,d)), the new K/V is written at ``cache_pos`` and attention
    runs over the whole cache buffer with position masking.
    ``kv_source``: encoder output for cross-attention (no cache update).
    """
    B, Sq, _ = x.shape
    src = kv_source if kv_source is not None else x
    q = (x @ params["wq"]).reshape(B, Sq, n_heads, hd)
    k = (src @ params["wk"]).reshape(B, src.shape[1], n_kv, hd)
    v = (src @ params["wv"]).reshape(B, src.shape[1], n_kv, hd)
    if rope_cos is not None and kv_source is None:
        # in decode mode the caller passes tables for the current position
        q = apply_rope(q, rope_cos, rope_sin, rope_style)
        k = apply_rope(k, rope_cos, rope_sin, rope_style)
    new_cache = None
    if cache is not None:
        # decode: write the new K/V at cache_pos, attend over the buffer.
        # Masked select, NOT dynamic_update_index: scattering at a traced
        # index into a sequence-sharded cache makes SPMD gather the whole
        # buffer (16 GB/step on chatglm decode — §Perf log); the select is
        # elementwise and stays local on every shard.
        assert Sq == 1, "cache path is single-token decode"
        sel = (jnp.arange(cache["k"].shape[1]) == cache_pos)[None, :, None, None]
        kbuf = jnp.where(sel, k.astype(cache["k"].dtype), cache["k"])
        vbuf = jnp.where(sel, v.astype(cache["v"].dtype), cache["v"])
        new_cache = {"k": kbuf, "v": vbuf}
        Sk = kbuf.shape[1]
        scale = 1.0 / (hd**0.5)
        group = n_heads // n_kv
        # decode uses the grouped-GQA einsum directly on the bf16 cache:
        # repeat_kv here would materialize a group-x (16x for chatglm) f32
        # copy of the whole cache (§Perf log); f32 only in the MXU
        # accumulator via preferred_element_type
        qg = q.reshape(B, Sq, n_kv, group, hd)
        logits = jnp.einsum(
            "bqhgd,bshd->bhgqs", qg, kbuf,
            preferred_element_type=jnp.float32,
        ) * scale
        kpos = jnp.arange(Sk)[None, :]
        qpos = cache_pos + jnp.arange(Sq)[:, None]
        logits = jnp.where((kpos <= qpos)[None, None, None], logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum(
            "bhgqs,bshd->bqhgd", p.astype(vbuf.dtype), vbuf,
            preferred_element_type=jnp.float32,
        )
        out = out.reshape(B, Sq, n_heads, hd).astype(x.dtype)
    else:
        out = _sdpa(q, k, v, causal=causal and kv_source is None)
    y = out.reshape(B, Sq, n_heads * hd) @ params["wo"]
    return y, new_cache
