"""xLSTM blocks: mLSTM (matrix memory, attention-like) and sLSTM (scalar
memory, true recurrence) — per Beck et al. 2024 (arXiv:2405.04517).

TPU adaptation: both cells run as ``jax.lax.scan`` recurrences with
exponential-gating stabilizers (m state). The mLSTM's matrix state is
(B, H, hd, hd); the chunk-parallel training form is an optimization the
hillclimb log discusses — the scan form is the exact oracle.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init
from .recurrent import chunked_scan


# ---------------------------------------------------------------------------
# mLSTM
def mlstm_init(key, d: int, n_heads: int, dtype) -> Dict:
    """mLSTM block: up-proj (2x), cell over one stream, gated by the other."""
    din = 2 * d
    hd = din // n_heads
    ks = jax.random.split(key, 7)
    return {
        "w_up": dense_init(ks[0], (d, 2 * din), dtype),
        "wq": dense_init(ks[1], (din, din), dtype),
        "wk": dense_init(ks[2], (din, din), dtype),
        "wv": dense_init(ks[3], (din, din), dtype),
        "w_if": dense_init(ks[4], (din, 2 * n_heads), dtype),
        "b_if": jnp.zeros((2 * n_heads,), jnp.float32),
        "w_o": dense_init(ks[5], (din, din), dtype),
        "w_down": dense_init(ks[6], (din, d), dtype),
    }


def mlstm_apply(
    params: Dict,
    x: jnp.ndarray,
    *,
    n_heads: int,
    state: Optional[Dict] = None,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    B, S, d = x.shape
    din = 2 * d
    hd = din // n_heads
    up = x @ params["w_up"]
    u, gate = up[..., :din], up[..., din:]

    q = (u @ params["wq"]).reshape(B, S, n_heads, hd) / (hd**0.5)
    k = (u @ params["wk"]).reshape(B, S, n_heads, hd) / (hd**0.5)
    v = (u @ params["wv"]).reshape(B, S, n_heads, hd)
    gf = (u @ params["w_if"]).astype(jnp.float32) + params["b_if"]
    log_i = gf[..., :n_heads]  # (B,S,H) input gate (pre-exp)
    log_f = jax.nn.log_sigmoid(gf[..., n_heads:])  # forget gate

    def step(carry, inp):
        C, n, m = carry  # (B,H,hd,hd) (B,H,hd) (B,H)
        q_t, k_t, v_t, li_t, lf_t = inp
        m_new = jnp.maximum(lf_t + m, li_t)
        i_p = jnp.exp(li_t - m_new)[..., None]  # (B,H,1)
        f_p = jnp.exp(lf_t + m - m_new)[..., None]
        n = f_p * n + i_p * k_t
        C = f_p[..., None] * C + (i_p * v_t)[..., None] * k_t[:, :, None, :]
        num = jnp.einsum("bhvk,bhk->bhv", C, q_t)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q_t)), 1.0)
        h = num / den[..., None]
        return (C, n, m_new), h

    if state is None:
        C0 = jnp.zeros((B, n_heads, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, n_heads, hd), jnp.float32)
        m0 = jnp.full((B, n_heads), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]
    seq = (
        q.astype(jnp.float32).swapaxes(0, 1),
        k.astype(jnp.float32).swapaxes(0, 1),
        v.astype(jnp.float32).swapaxes(0, 1),
        log_i.swapaxes(0, 1),
        log_f.swapaxes(0, 1),
    )
    (CT, nT, mT), hs = chunked_scan(step, (C0, n0, m0), seq)
    h = hs.swapaxes(0, 1).reshape(B, S, din).astype(x.dtype)
    h = h @ params["w_o"]
    y = (h * jax.nn.silu(gate)) @ params["w_down"]
    new_state = {"C": CT, "n": nT, "m": mT} if state is not None else None
    return y, new_state


def mlstm_state_init(B: int, d: int, n_heads: int) -> Dict:
    din = 2 * d
    hd = din // n_heads
    return {
        "C": jnp.zeros((B, n_heads, hd, hd), jnp.float32),
        "n": jnp.zeros((B, n_heads, hd), jnp.float32),
        "m": jnp.full((B, n_heads), -1e30, jnp.float32),
    }


# ---------------------------------------------------------------------------
# sLSTM
def slstm_init(key, d: int, n_heads: int, dtype) -> Dict:
    ks = jax.random.split(key, 3)
    return {
        "w_gates": dense_init(ks[0], (d, 4 * d), dtype),  # z i f o
        "r_gates": dense_init(ks[1], (d, 4 * d), dtype),  # recurrent
        "b_gates": jnp.zeros((4 * d,), jnp.float32),
        "w_out": dense_init(ks[2], (d, d), dtype),
    }


def slstm_apply(
    params: Dict,
    x: jnp.ndarray,
    *,
    state: Optional[Dict] = None,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    B, S, d = x.shape
    wx = (x @ params["w_gates"]).astype(jnp.float32)  # (B,S,4d)

    def step(carry, wx_t):
        c, n, h, m = carry  # all (B,d) except m (B,d)
        g = wx_t + (h.astype(x.dtype) @ params["r_gates"]).astype(jnp.float32) + params["b_gates"]
        z, i, f, o = jnp.split(g, 4, axis=-1)
        z = jnp.tanh(z)
        o = jax.nn.sigmoid(o)
        log_f = jax.nn.log_sigmoid(f)
        m_new = jnp.maximum(log_f + m, i)
        i_p = jnp.exp(i - m_new)
        f_p = jnp.exp(log_f + m - m_new)
        c = f_p * c + i_p * z
        n = f_p * n + i_p
        h_new = o * c / jnp.maximum(n, 1.0)
        return (c, n, h_new, m_new), h_new

    if state is None:
        zeros = jnp.zeros((B, d), jnp.float32)
        carry0 = (zeros, zeros, zeros, jnp.full((B, d), -1e30, jnp.float32))
    else:
        carry0 = (state["c"], state["n"], state["h"], state["m"])
    carryT, hs = chunked_scan(step, carry0, wx.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(x.dtype) @ params["w_out"]
    new_state = (
        {"c": carryT[0], "n": carryT[1], "h": carryT[2], "m": carryT[3]}
        if state is not None
        else None
    )
    return y, new_state


def slstm_state_init(B: int, d: int) -> Dict:
    zeros = jnp.zeros((B, d), jnp.float32)
    return {"c": zeros, "n": zeros, "h": zeros, "m": jnp.full((B, d), -1e30, jnp.float32)}
