"""Shared layers: norms, MLPs, embeddings (pure-JAX param-dict style)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}[name]


def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    if scale is None:
        scale = 1.0 / (fan_in**0.5)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rmsnorm_init(d: int, dtype) -> Dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    # variance reduced in f32, but x itself stays in its compute dtype: a
    # full f32 copy of the residual stream would get fused into the TP
    # all-reduces and double their wire bytes (§Perf log, iteration 3)
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * params["scale"].astype(x.dtype)


def layernorm_init(d: int, dtype) -> Dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    mu = mu.astype(x.dtype)
    return (x - mu) * inv * params["scale"].astype(x.dtype) + params["bias"].astype(x.dtype)


def norm_init(kind: str, d: int, dtype):
    return rmsnorm_init(d, dtype) if kind == "rmsnorm" else layernorm_init(d, dtype)


def norm_apply(kind: str, params, x):
    return rmsnorm(params, x) if kind == "rmsnorm" else layernorm(params, x)


# ---------------------------------------------------------------------------
def mlp_init(key, d: int, d_ff: int, act: str, dtype) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(k1, (d, d_ff), dtype),
        "w_down": dense_init(k2, (d_ff, d), dtype),
    }
    if act in ("silu", "geglu"):  # gated variants carry a gate projection
        p["w_gate"] = dense_init(k3, (d, d_ff), dtype)
    return p


def mlp_apply(params, x, act: str):
    up = x @ params["w_up"]
    if act == "silu":
        g = jax.nn.silu(x @ params["w_gate"])
        h = g * up
    elif act == "geglu":
        g = jax.nn.gelu(x @ params["w_gate"], approximate=True)
        h = g * up
    else:
        h = jax.nn.gelu(up, approximate=True)
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
def embed_init(key, vocab: int, d: int, dtype) -> Dict:
    return {"table": dense_init(key, (vocab, d), dtype, scale=1.0)}


def embed_apply(params, tokens):
    return params["table"][tokens]


def unembed_apply(params, x, tie_table=None):
    w = tie_table if tie_table is not None else params["table"]
    return x @ w.T.astype(x.dtype)
