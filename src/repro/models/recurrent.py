"""Chunked recurrent scan with per-chunk checkpointing.

``jax.lax.scan`` AD saves the carry at *every* step; for matrix-state cells
(mLSTM: (B,H,hd,hd) per step) that is S x state bytes — 135 GB/device for
xlstm train_4k (measured, §Perf memory log). Scanning over chunks with a
checkpointed inner scan stores one carry per chunk and recomputes inside:
memory drops by the chunk factor for ~1 extra forward of the cell.
"""
from __future__ import annotations

import jax


def chunked_scan(step, carry, seq, chunk: int = 256):
    """Equivalent to ``jax.lax.scan(step, carry, seq)`` (seq leaves (S,...));
    saves carries only at chunk boundaries."""
    leaves = jax.tree.leaves(seq)
    S = leaves[0].shape[0]
    if S <= chunk or S % chunk:
        return jax.lax.scan(step, carry, seq)
    n = S // chunk
    seq_c = jax.tree.map(lambda a: a.reshape(n, chunk, *a.shape[1:]), seq)

    @jax.checkpoint
    def chunk_body(c, xs):
        return jax.lax.scan(step, c, xs)

    carry, ys = jax.lax.scan(chunk_body, carry, seq_c)
    ys = jax.tree.map(lambda a: a.reshape(n * chunk, *a.shape[2:]), ys)
    return carry, ys
