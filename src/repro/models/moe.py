"""Mixture-of-Experts MLP: sort-based capacity dispatch (pjit-friendly).

Top-k routing; assignments are sorted by expert, bucketed into a fixed
per-expert capacity buffer (E, C, d) that XLA SPMD reshards onto the expert-
sharded mesh axis (this resharding IS the all-to-all the roofline measures).
Overflow tokens are dropped (capacity_factor controls the drop rate), the
standard GShard/Switch discipline.

The expert->device placement is a first-class input: ``expert_perm`` (from
dist/sched_bridge.py, computed by DADA from routing statistics) permutes
expert ids so co-activated experts land on the same device group, shrinking
the all-to-all volume — the paper's affinity idea applied at LM scale.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


from .layers import dense_init


def moe_init(key, d: int, moe_cfg, dtype) -> Dict:
    E, ff = moe_cfg.n_experts, moe_cfg.d_ff
    kr, k1, k2, k3 = jax.random.split(key, 4)
    return {
        "router": dense_init(kr, (d, E), jnp.float32),
        "w_up": dense_init(k1, (E, d, ff), dtype),
        "w_gate": dense_init(k2, (E, d, ff), dtype),
        "w_down": dense_init(k3, (E, ff, d), dtype),
    }


def moe_apply(
    params: Dict,
    x: jnp.ndarray,
    *,
    moe_cfg,
    expert_perm: Optional[jnp.ndarray] = None,
    n_chunks: int = 1,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss).

    ``n_chunks`` > 1 is the §Perf "chunk-local dispatch" optimization: the
    argsort/scatter bucketing runs independently per data-shard-aligned
    token chunk (no cross-device sort), so the only cross-device movement
    left is the (chunks, E, C, d) -> expert-sharded reshard — the actual
    all-to-all. Set n_chunks = number of data shards.
    """
    B, S, d = x.shape
    E, K = moe_cfg.n_experts, moe_cfg.top_k
    T = B * S
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32)) @ params["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, K)  # (T, K)
    gates = gates / (gates.sum(-1, keepdims=True) + 1e-9)
    if expert_perm is not None:
        idx = expert_perm[idx]  # affinity-driven relabeling (DADA placement)

    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(axis=0)  # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (T * K)
    aux = moe_cfg.aux_loss_weight * E * jnp.sum(me * ce)

    X = n_chunks if (n_chunks > 1 and T % n_chunks == 0) else 1
    Tc = T // X
    C = max(8, int((Tc * K / E) * moe_cfg.capacity_factor + 0.999))

    xtc = xt.reshape(X, Tc, d)
    flat_e = idx.reshape(X, Tc * K)
    order = jnp.argsort(flat_e, axis=-1, stable=True)  # per-chunk local sort
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    counts = jnp.zeros((X, E), jnp.int32).at[
        jnp.arange(X)[:, None], flat_e
    ].add(1)
    starts = jnp.cumsum(counts, axis=-1) - counts
    rank = jnp.arange(Tc * K, dtype=jnp.int32)[None] - jnp.take_along_axis(
        starts, sorted_e, axis=-1
    )
    tok = order // K
    slot = jnp.where(rank < C, rank, C)  # overflow -> scratch slot C

    chunk_ix = jnp.arange(X)[:, None]
    buf = (
        jnp.zeros((X, E, C + 1, d), x.dtype)
        .at[chunk_ix, sorted_e, slot]
        .set(xtc[chunk_ix, tok])
    )
    buf = buf[:, :, :C]  # (X, E, C, d) — reshard to expert axis = all-to-all

    # ---- expert FFN (gated) ----------------------------------------------
    up = jnp.einsum("xecd,edf->xecf", buf, params["w_up"])
    gate = jax.nn.silu(jnp.einsum("xecd,edf->xecf", buf, params["w_gate"]))
    y_exp = jnp.einsum("xecf,efd->xecd", gate * up, params["w_down"])

    # ---- combine back ------------------------------------------------------
    y_pad = jnp.concatenate(
        [y_exp, jnp.zeros((X, E, 1, d), y_exp.dtype)], axis=2
    )
    y_sorted = y_pad[chunk_ix, sorted_e, slot]  # (X, Tc*K, d)
    y_flat = (
        jnp.zeros((X, Tc * K, d), y_exp.dtype)
        .at[chunk_ix, order]
        .set(y_sorted)
    )
    yk = y_flat.reshape(T, K, d)
    y = (yk * gates[..., None].astype(yk.dtype)).sum(axis=1)
    return y.reshape(B, S, d), aux
