"""Model assembly: composable blocks -> full architectures.

Layer stacks are scanned over *periods* (one period = cfg.block_pattern,
e.g. Jamba's 8-layer Mamba/attention interleave): params and caches carry a
leading n_periods axis, which keeps HLO size O(period), not O(depth) — the
property that makes 80-layer dry-runs compilable and is also the remat unit.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain_batch

from . import attention as attn
from . import mamba as mb
from . import mla as mla_mod
from . import moe as moe_mod
from . import xlstm as xl
from .layers import _dtype, dense_init, embed_apply, embed_init, norm_apply, norm_init
from .rope import rope_table


# ---------------------------------------------------------------------------
# per-block init / apply
def _has_mlp(kind: str) -> bool:
    return kind in ("attn", "mamba")


def _is_moe_position(cfg: ModelConfig, j: int) -> bool:
    return (
        cfg.moe is not None
        and _has_mlp(cfg.block_pattern[j])
        and (j % cfg.moe.every == cfg.moe.every - 1)
    )


def block_init(cfg: ModelConfig, kind: str, j: int, key) -> Dict:
    dt = _dtype(cfg.param_dtype)
    d = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": norm_init(cfg.norm, d, dt)}
    if kind == "attn":
        if cfg.mla is not None:
            p["mla"] = mla_mod.mla_init(k1, d, cfg.n_heads, cfg.mla, dt)
        else:
            p["attn"] = attn.attn_init(k1, d, cfg.n_heads, cfg.n_kv_heads, cfg.hd, dt)
    elif kind == "mamba":
        p["mamba"] = mb.mamba_init(
            k1, d, expand=cfg.mamba_expand, d_state=cfg.mamba_d_state,
            d_conv=cfg.mamba_d_conv, dtype=dt,
        )
    elif kind == "mlstm":
        p["cell"] = xl.mlstm_init(k1, d, cfg.n_heads, dt)
    elif kind == "slstm":
        p["cell"] = xl.slstm_init(k1, d, cfg.n_heads, dt)
    else:
        raise ValueError(kind)
    if _has_mlp(kind):
        p["norm2"] = norm_init(cfg.norm, d, dt)
        if _is_moe_position(cfg, j):
            p["moe"] = moe_mod.moe_init(k2, d, cfg.moe, dt)
        else:
            from .layers import mlp_init

            p["mlp"] = mlp_init(k2, d, cfg.d_ff, cfg.act, dt)
    return p


def block_apply(
    cfg: ModelConfig,
    kind: str,
    j: int,
    params: Dict,
    x,
    *,
    rope_cos,
    rope_sin,
    cache: Optional[Dict] = None,
    cache_pos=None,
    expert_perm=None,
    moe_chunks: int = 1,
) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = norm_apply(cfg.norm, params["norm1"], x)
    new_cache = None
    if kind == "attn":
        if cfg.mla is not None:
            y, new_cache = mla_mod.mla_apply(
                params["mla"], h, n_heads=cfg.n_heads, mla_cfg=cfg.mla,
                rope_cos=rope_cos, rope_sin=rope_sin,
                cache=cache, cache_pos=cache_pos,
            )
        else:
            y, new_cache = attn.attn_apply(
                params["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                hd=cfg.hd, rope_cos=rope_cos, rope_sin=rope_sin,
                rope_style=cfg.rope_style, causal=True,
                cache=cache, cache_pos=cache_pos,
            )
    elif kind == "mamba":
        y, new_cache = mb.mamba_apply(
            params["mamba"], h, expand=cfg.mamba_expand,
            d_state=cfg.mamba_d_state, d_conv=cfg.mamba_d_conv, state=cache,
        )
    elif kind == "mlstm":
        y, new_cache = xl.mlstm_apply(params["cell"], h, n_heads=cfg.n_heads, state=cache)
    elif kind == "slstm":
        y, new_cache = xl.slstm_apply(params["cell"], h, state=cache)
    else:
        raise ValueError(kind)
    x = x + y
    if _has_mlp(kind):
        h = norm_apply(cfg.norm, params["norm2"], x)
        if "moe" in params:
            y, aux = moe_mod.moe_apply(
                params["moe"], h, moe_cfg=cfg.moe, expert_perm=expert_perm,
                n_chunks=moe_chunks,
            )
        else:
            from .layers import mlp_apply

            y = mlp_apply(params["mlp"], h, cfg.act)
        x = x + y
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# cache init (per pattern position)
def block_cache_init(cfg: ModelConfig, kind: str, B: int, S: int) -> Optional[Dict]:
    dt = _dtype(cfg.compute_dtype)
    if kind == "attn":
        if cfg.mla is not None:
            return mla_mod.mla_cache_init(B, S, cfg.mla, dt)
        return {
            "k": jnp.zeros((B, S, cfg.n_kv_heads, cfg.hd), dt),
            "v": jnp.zeros((B, S, cfg.n_kv_heads, cfg.hd), dt),
        }
    if kind == "mamba":
        return mb.mamba_state_init(
            B, cfg.d_model, expand=cfg.mamba_expand,
            d_state=cfg.mamba_d_state, d_conv=cfg.mamba_d_conv, dtype=dt,
        )
    if kind == "mlstm":
        return xl.mlstm_state_init(B, cfg.d_model, cfg.n_heads)
    if kind == "slstm":
        return xl.slstm_state_init(B, cfg.d_model)
    raise ValueError(kind)


def cache_init(cfg: ModelConfig, B: int, S: int) -> Dict:
    """Stacked cache pytree: {"p{j}": leaves with leading n_periods axis}."""
    n_periods = cfg.n_layers // cfg.period
    out = {}
    for j, kind in enumerate(cfg.block_pattern):
        c = block_cache_init(cfg, kind, B, S)
        out[f"p{j}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_periods,) + a.shape), c
        )
    return out


# ---------------------------------------------------------------------------
# full-model init
def init_params(cfg: ModelConfig, key) -> Dict:
    dt = _dtype(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": embed_init(keys[0], cfg.vocab, cfg.d_model, dt),
        "final_norm": norm_init(cfg.norm, cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], (cfg.d_model, cfg.vocab), dt)
    n_periods = cfg.n_layers // cfg.period
    blocks = {}
    for j, kind in enumerate(cfg.block_pattern):
        pkeys = jax.random.split(jax.random.fold_in(keys[2], j), n_periods)
        blocks[f"p{j}"] = jax.vmap(lambda k, j=j, kind=kind: block_init(cfg, kind, j, k))(pkeys)
    params["blocks"] = blocks
    if cfg.enc_layers:
        ekeys = jax.random.split(keys[3], cfg.enc_layers)
        params["enc_blocks"] = jax.vmap(
            lambda k: {
                "norm1": norm_init(cfg.norm, cfg.d_model, dt),
                "attn": attn.attn_init(
                    jax.random.fold_in(k, 0), cfg.d_model, cfg.n_heads,
                    cfg.n_kv_heads, cfg.hd, dt,
                ),
                "norm2": norm_init(cfg.norm, cfg.d_model, dt),
                "mlp": __import__("repro.models.layers", fromlist=["mlp_init"]).mlp_init(
                    jax.random.fold_in(k, 1), cfg.d_model, cfg.d_ff, cfg.act, dt
                ),
            }
        )(ekeys)
        params["enc_norm"] = norm_init(cfg.norm, cfg.d_model, dt)
        # decoder cross-attention (one per decoder layer, scanned)
        ckeys = jax.random.split(keys[4], cfg.n_layers // cfg.period)
        params["cross"] = jax.vmap(
            lambda k: {
                "norm": norm_init(cfg.norm, cfg.d_model, dt),
                "attn": attn.attn_init(k, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, dt),
            }
        )(ckeys)
    if cfg.frontend_dim and cfg.frontend_dim != cfg.d_model:
        params["frontend_proj"] = dense_init(keys[5], (cfg.frontend_dim, cfg.d_model), dt)
    return params


# ---------------------------------------------------------------------------
# forward passes
def _rope_tables(cfg: ModelConfig, positions):
    if cfg.mla is not None:
        rot = cfg.mla.qk_rope_dim
    elif cfg.rope_style == "half":
        rot = cfg.hd // 2
    elif cfg.rope_style == "none":
        return None, None
    else:
        rot = cfg.hd
    return rope_table(positions, rot, cfg.rope_theta)



def _cast_floats(tree, dtype):
    """Cast floating params to the compute dtype (bf16 MXU policy)."""
    return jax.tree.map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating) else a,
        tree,
    )

def encode(params: Dict, cfg: ModelConfig, enc_x: jnp.ndarray) -> jnp.ndarray:
    """Bidirectional encoder over frontend embeddings (B, S_enc, d*)."""
    params = _cast_floats(params, _dtype(cfg.compute_dtype))
    if "frontend_proj" in params:
        enc_x = enc_x.astype(_dtype(cfg.compute_dtype)) @ params["frontend_proj"]
    enc_x = constrain_batch(enc_x.astype(_dtype(cfg.compute_dtype)))
    S = enc_x.shape[1]
    cos, sin = _rope_tables(cfg, jnp.arange(S))

    def body(x, lp):
        h = norm_apply(cfg.norm, lp["norm1"], x)
        y, _ = attn.attn_apply(
            lp["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, hd=cfg.hd,
            rope_cos=cos, rope_sin=sin, rope_style=cfg.rope_style, causal=False,
        )
        x = x + y
        h = norm_apply(cfg.norm, lp["norm2"], x)
        from .layers import mlp_apply

        return x + mlp_apply(lp["mlp"], h, cfg.act), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, enc_x, params["enc_blocks"])
    return norm_apply(cfg.norm, params["enc_norm"], x)


def forward(
    params: Dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    *,
    extra_embeds: Optional[jnp.ndarray] = None,
    enc_out: Optional[jnp.ndarray] = None,
    cache: Optional[Dict] = None,
    cache_pos=None,
    expert_perm=None,
    moe_chunks: int = 1,
    remat: Optional[bool] = None,
    last_logit_only: bool = False,
    cross_cache: Optional[Dict] = None,
) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    """Decoder forward. Returns (logits, new_cache, aux_loss).

    train/prefill: cache=None, tokens (B,S).
    decode: cache pytree + cache_pos scalar; tokens (B,1).
    ``extra_embeds``: (B,P,d_frontend) modality-stub embeddings, prepended.
    ``enc_out``: encoder memory for cross-attention (encoder-decoder archs).
    """
    cdt = _dtype(cfg.compute_dtype)
    params = _cast_floats(params, cdt)
    if cfg.tie_embeddings:
        # vocab-sharded table: one-hot contraction partitions cleanly (each
        # vocab shard contributes a partial (B,S,d) sum); a gather on a
        # vocab-sharded table hits SPMD's full-remat fallback instead
        oh = jax.nn.one_hot(tokens, cfg.vocab, dtype=cdt)
        x = oh @ params["embed"]["table"].astype(cdt)
    else:
        x = embed_apply(params["embed"], tokens).astype(cdt)
    if cfg.name.startswith("gemma"):
        x = x * (cfg.d_model**0.5)
    if extra_embeds is not None:
        pe = extra_embeds
        if "frontend_proj" in params:
            pe = pe @ params["frontend_proj"]
        x = jnp.concatenate([pe.astype(cdt), x], axis=1)
    # re-pin batch sharding: embedding gathers drop index sharding
    # (dist/sharding batch hints)
    x = constrain_batch(x)
    B, S, _ = x.shape
    if cache is None:
        positions = jnp.arange(S)
    else:
        positions = jnp.asarray(cache_pos) + jnp.arange(S)
    cos, sin = _rope_tables(cfg, positions)
    use_remat = cfg.remat if remat is None else remat
    have_cross = enc_out is not None or cross_cache is not None
    have_cc = cross_cache is not None
    have_cache = cache is not None

    def body(x, xs):
        bp, cp, pc, cc = xs
        aux_total = jnp.zeros((), jnp.float32)
        new_pc = {}
        for j, kind in enumerate(cfg.block_pattern):
            x, nc, aux = block_apply(
                cfg, kind, j, bp[f"p{j}"], x,
                rope_cos=cos, rope_sin=sin,
                cache=pc[f"p{j}"] if have_cache else None,
                cache_pos=cache_pos,
                expert_perm=expert_perm,
                moe_chunks=moe_chunks,
            )
            if have_cache:
                new_pc[f"p{j}"] = nc
            aux_total = aux_total + aux
        if have_cross:
            h = norm_apply(cfg.norm, cp["norm"], x)
            if have_cc:
                # decode fast path: cross-K/V precomputed once per request
                y = attn.attn_apply_kv(
                    cp["attn"], h, cc["k"], cc["v"],
                    n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, hd=cfg.hd,
                )
            else:
                y, _ = attn.attn_apply(
                    cp["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                    hd=cfg.hd, kv_source=enc_out, causal=False,
                )
            x = x + y
        return x, (aux_total, new_pc if have_cache else {})

    if use_remat:
        # save weight-matmul outputs (the post-all-reduce activations):
        # recomputing them in the backward pass would re-run every TP
        # collective a third time (§Perf log); elementwise/attention
        # internals still rematerialize, keeping memory bounded
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    xs = (
        params["blocks"],
        params["cross"] if have_cross else {},
        cache if have_cache else {},
        cross_cache if have_cc else {},
    )
    x, (auxs, new_cache) = jax.lax.scan(body, x, xs)
    aux = auxs.sum()
    if not have_cache:
        new_cache = None

    x = norm_apply(cfg.norm, params["final_norm"], x)
    if last_logit_only:
        x = x[:, -1:]
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T.astype(x.dtype)
    else:
        logits = x @ params["lm_head"].astype(x.dtype)
    return logits.astype(jnp.float32), new_cache, aux
