"""Mamba (selective SSM) block — Jamba's sub-quadratic component.

TPU adaptation: the CUDA selective-scan kernel becomes a ``jax.lax.scan``
recurrence (decode/state-carrying exact form). The (B, S, d_inner, N)
discretized tensors are never materialized: A_bar/B_bar are built per step
inside the scan body, so the working set is the O(B * d_inner * N) state.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init
from .recurrent import chunked_scan


def mamba_init(key, d: int, *, expand: int, d_state: int, d_conv: int, dtype) -> Dict:
    din = expand * d
    dt_rank = max(1, d // 16)
    ks = jax.random.split(key, 6)
    return {
        "w_in": dense_init(ks[0], (d, 2 * din), dtype),
        "conv_w": dense_init(ks[1], (d_conv, din), dtype, scale=1.0 / d_conv),
        "conv_b": jnp.zeros((din,), dtype),
        "w_x": dense_init(ks[2], (din, dt_rank + 2 * d_state), dtype),
        "w_dt": dense_init(ks[3], (dt_rank, din), dtype),
        "dt_bias": jnp.zeros((din,), jnp.float32),
        "a_log": jnp.log(
            jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32), (din, 1))
        ),
        "d_skip": jnp.ones((din,), jnp.float32),
        "w_out": dense_init(ks[4], (din, d), dtype),
    }


def _conv1d_causal(x, w, b):
    """Depthwise causal conv. x: (B,S,din), w: (width,din)."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):  # width is 4 — unrolled taps
        out = out + pad[:, i : i + x.shape[1]] * w[i]
    return out + b


def mamba_apply(
    params: Dict,
    x: jnp.ndarray,
    *,
    expand: int,
    d_state: int,
    d_conv: int,
    state: Optional[Dict] = None,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """x: (B,S,d). ``state`` = {"ssm": (B,din,N), "conv": (B,width-1,din)}
    enables single-step decode; None runs the full sequence."""
    B, S, d = x.shape
    din = expand * d
    dt_rank = max(1, d // 16)
    xz = x @ params["w_in"]
    xs, z = xz[..., :din], xz[..., din:]

    if state is not None:
        assert S == 1
        conv_ctx = jnp.concatenate([state["conv"], xs], axis=1)  # (B,width,din)
        new_conv = conv_ctx[:, 1:]
        xc = (conv_ctx * params["conv_w"][None]).sum(axis=1, keepdims=True) + params["conv_b"]
    else:
        new_conv = None
        xc = _conv1d_causal(xs, params["conv_w"], params["conv_b"])
    xc = jax.nn.silu(xc)

    proj = xc @ params["w_x"]  # (B,S,dt_rank+2N)
    dt_r = proj[..., :dt_rank]
    Bm = proj[..., dt_rank : dt_rank + d_state]
    Cm = proj[..., dt_rank + d_state :]
    dt = jax.nn.softplus(dt_r @ params["w_dt"] + params["dt_bias"])  # (B,S,din)
    A = -jnp.exp(params["a_log"])  # (din, N)

    def step(h, inp):
        dt_t, b_t, c_t, x_t = inp  # (B,din) (B,N) (B,N) (B,din)
        a_bar = jnp.exp(dt_t[..., None] * A[None])  # (B,din,N)
        bx = (dt_t * x_t)[..., None] * b_t[:, None, :]  # (B,din,N)
        h = a_bar * h + bx
        y = (h * c_t[:, None, :]).sum(-1)  # (B,din)
        return h, y

    xs_f32 = xc.astype(jnp.float32)
    seq = (
        dt.astype(jnp.float32).swapaxes(0, 1),
        Bm.astype(jnp.float32).swapaxes(0, 1),
        Cm.astype(jnp.float32).swapaxes(0, 1),
        xs_f32.swapaxes(0, 1),
    )
    h0 = state["ssm"] if state is not None else jnp.zeros((B, din, d_state), jnp.float32)
    hT, ys = chunked_scan(step, h0, seq)
    y = ys.swapaxes(0, 1) + xs_f32 * params["d_skip"]  # (B,S,din)
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ params["w_out"]
    new_state = {"ssm": hT, "conv": new_conv} if state is not None else None
    return y, new_state


def mamba_state_init(B: int, d: int, *, expand: int, d_state: int, d_conv: int, dtype) -> Dict:
    din = expand * d
    return {
        "ssm": jnp.zeros((B, din, d_state), jnp.float32),
        "conv": jnp.zeros((B, d_conv - 1, din), dtype),
    }
