"""Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style).

Q goes through a low-rank bottleneck (q_lora_rank); K/V are compressed into
a shared latent c_kv (kv_lora_rank) plus a small shared rotary key
(qk_rope_dim). The decode cache stores only (c_kv, k_rope) — the memory win
that makes MLA matter at 32k+ context.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init, rmsnorm, rmsnorm_init
from .rope import apply_rope


def mla_init(key, d: int, n_heads: int, mla_cfg, dtype) -> Dict:
    m = mla_cfg
    ks = jax.random.split(key, 7)
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    return {
        "w_dq": dense_init(ks[0], (d, m.q_lora_rank), dtype),
        "q_norm": rmsnorm_init(m.q_lora_rank, dtype),
        "w_uq": dense_init(ks[1], (m.q_lora_rank, n_heads * qk_dim), dtype),
        "w_dkv": dense_init(ks[2], (d, m.kv_lora_rank), dtype),
        "kv_norm": rmsnorm_init(m.kv_lora_rank, dtype),
        "w_kr": dense_init(ks[3], (d, m.qk_rope_dim), dtype),
        "w_uk": dense_init(ks[4], (m.kv_lora_rank, n_heads * m.qk_nope_dim), dtype),
        "w_uv": dense_init(ks[5], (m.kv_lora_rank, n_heads * m.v_head_dim), dtype),
        "wo": dense_init(ks[6], (n_heads * m.v_head_dim, d), dtype),
    }


def mla_apply(
    params: Dict,
    x: jnp.ndarray,
    *,
    n_heads: int,
    mla_cfg,
    rope_cos,
    rope_sin,
    cache: Optional[Dict] = None,
    cache_pos=None,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    m = mla_cfg
    B, S, _ = x.shape
    qk_dim = m.qk_nope_dim + m.qk_rope_dim

    q_lat = rmsnorm(params["q_norm"], x @ params["w_dq"])
    q = (q_lat @ params["w_uq"]).reshape(B, S, n_heads, qk_dim)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]
    q_rope = apply_rope(q_rope, rope_cos, rope_sin, "full")

    c_kv = rmsnorm(params["kv_norm"], x @ params["w_dkv"])  # (B,S,r_kv)
    k_rope = (x @ params["w_kr"]).reshape(B, S, 1, m.qk_rope_dim)
    k_rope = apply_rope(k_rope, rope_cos, rope_sin, "full")

    new_cache = None
    if cache is not None:
        assert S == 1
        # masked select keeps the write local on sequence-sharded caches
        # (see models/attention.py; §Perf log)
        sel2 = (jnp.arange(cache["c_kv"].shape[1]) == cache_pos)[None, :, None]
        c_buf = jnp.where(sel2, c_kv.astype(cache["c_kv"].dtype), cache["c_kv"])
        kr_buf = jnp.where(
            sel2, k_rope[:, :, 0].astype(cache["k_rope"].dtype), cache["k_rope"]
        )
        new_cache = {"c_kv": c_buf, "k_rope": kr_buf}
        c_kv_all, k_rope_all = c_buf, kr_buf
        Sk = c_buf.shape[1]
    else:
        c_kv_all, k_rope_all = c_kv, k_rope
        Sk = S
    if k_rope_all.ndim == 4:
        k_rope_all = k_rope_all.reshape(B, Sk, m.qk_rope_dim)

    # expand latents to per-head keys/values
    k_nope = (c_kv_all @ params["w_uk"]).reshape(B, Sk, n_heads, m.qk_nope_dim)
    v = (c_kv_all @ params["w_uv"]).reshape(B, Sk, n_heads, m.v_head_dim)

    scale = 1.0 / (qk_dim**0.5)

    def _block(qn, qr, q_offset):
        """Exact attention for a query block against all Sk keys."""
        bq = qn.shape[1]
        logits = (
            jnp.einsum("bqhd,bkhd->bhqk", qn, k_nope, preferred_element_type=jnp.float32)
            + jnp.einsum("bqhd,bkd->bhqk", qr, k_rope_all, preferred_element_type=jnp.float32)
        ) * scale
        if cache is not None:
            mask = jnp.arange(Sk)[None, :] <= (cache_pos + q_offset + jnp.arange(bq)[:, None])
        else:
            mask = jnp.arange(Sk)[None, :] <= (q_offset + jnp.arange(bq)[:, None])
        logits = jnp.where(mask[None, None], logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum(
            "bhqk,bkhd->bqhd", p.astype(v.dtype), v,
            preferred_element_type=jnp.float32,
        ).astype(x.dtype)

    CHUNK = 1024
    if S <= CHUNK:
        out = _block(q_nope, q_rope, 0)
    else:
        # chunked prefill: the full (B,H,S,S) logits tensor at 32k context is
        # terabytes (measured 131 GB/device of XLA temps — §Perf memory log);
        # dynamic_slice on the unsharded seq dim keeps shardings intact
        assert S % CHUNK == 0, (S, CHUNK)

        def one(acc, i):
            qn = jax.lax.dynamic_slice_in_dim(q_nope, i * CHUNK, CHUNK, axis=1)
            qr = jax.lax.dynamic_slice_in_dim(q_rope, i * CHUNK, CHUNK, axis=1)
            o = _block(qn, qr, i * CHUNK)
            return jax.lax.dynamic_update_slice_in_dim(acc, o, i * CHUNK, axis=1), None

        acc0 = jnp.zeros((B, S, n_heads, m.v_head_dim), x.dtype)
        out, _ = jax.lax.scan(one, acc0, jnp.arange(S // CHUNK))
    y = out.reshape(B, S, n_heads * m.v_head_dim) @ params["wo"]
    return y, new_cache


def mla_cache_init(B: int, S: int, mla_cfg, dtype) -> Dict:
    return {
        "c_kv": jnp.zeros((B, S, mla_cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((B, S, mla_cfg.qk_rope_dim), dtype),
    }
