"""Deterministic sharded synthetic data pipeline.

Every batch is a pure function of (seed, step, shard) — restart-exact
(fault-tolerance property tested in tests/test_ckpt.py) and shardable across
data-parallel hosts with no coordination. Token stream is Zipf-tilted to
give non-degenerate losses.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec


@dataclass
class SyntheticPipeline:
    cfg: ModelConfig
    shape: ShapeSpec
    shard_id: int = 0
    n_shards: int = 1
    seed: int = 0

    def __post_init__(self):
        assert self.shape.global_batch % self.n_shards == 0
        self.local_batch = self.shape.global_batch // self.n_shards

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard_id])
        )

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = self._rng(step)
        B = self.local_batch
        S = self.shape.seq_len
        cfg = self.cfg
        out: Dict[str, np.ndarray] = {}
        P = cfg.frontend_tokens if cfg.family in ("vlm", "audio") else 0
        if cfg.family == "audio":  # encoder-decoder: frames + decoder tokens
            out["frontend"] = rng.standard_normal(
                (B, P, cfg.frontend_dim), dtype=np.float32
            )
            n_tok = S
        elif cfg.family == "vlm":  # patches prepended to text tokens
            out["frontend"] = rng.standard_normal(
                (B, P, cfg.frontend_dim), dtype=np.float32
            )
            n_tok = S - P
        else:
            n_tok = S
        # Zipf-tilted token ids
        u = rng.random((B, n_tok))
        toks = ((cfg.vocab - 1) * u**3).astype(np.int32)
        out["tokens"] = toks
        return out

    def state(self) -> Dict:
        return {"seed": self.seed, "shard_id": self.shard_id, "n_shards": self.n_shards}
