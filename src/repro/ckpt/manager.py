"""Checkpoint manager: atomic, resumable, background-capable.

Layout: <dir>/step_<N>/ containing one .npy blob per leaf (path-keyed) and a
manifest.json. Writes go to a hidden tmp dir that is os.rename()d into place
— a crash never leaves a partially-visible checkpoint (fault-tolerance
contract tested in tests/test_ckpt.py). ``keep`` bounds disk usage.

bfloat16 leaves are stored as raw uint16 with the true dtype recorded in the
manifest (numpy-portable without ml_dtypes at load time).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _leaf_key(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3) -> None:
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for p in self.dir.iterdir():
            if p.is_dir() and p.name.startswith("step_"):
                try:
                    out.append(int(p.name.split("_", 1)[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # ------------------------------------------------------------------
    def _write(self, step: int, flat: Dict[str, np.ndarray], meta: Dict) -> None:
        tmp = self.dir / f".tmp_step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        manifest = {"step": step, "leaves": {}, "meta": meta}
        for i, (key, arr) in enumerate(sorted(flat.items())):
            dtype = str(arr.dtype)
            save_arr = arr
            if dtype == "bfloat16":
                save_arr = arr.view(np.uint16)
            fname = f"leaf_{i:05d}.npy"
            np.save(tmp / fname, save_arr, allow_pickle=False)
            manifest["leaves"][key] = {
                "file": fname,
                "dtype": dtype,
                "shape": list(arr.shape),
            }
        with (tmp / "manifest.json").open("w") as f:
            json.dump(manifest, f)
        final = self.dir / f"step_{step}"
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, meta: Optional[Dict] = None, blocking: bool = True) -> None:
        """Snapshot ``tree`` at ``step``. With blocking=False the device->host
        copy happens now but serialization runs on a background thread."""
        flat = {
            _leaf_key(path): np.asarray(leaf)
            for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]
        }
        self.wait()
        if blocking:
            self._write(step, flat, meta or {})
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, meta or {}), daemon=True
            )
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------
    def restore(self, template: Any, step: Optional[int] = None) -> Tuple[int, Any, Dict]:
        """Rebuild a pytree shaped like ``template`` from disk."""
        self.wait()
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
        cdir = self.dir / f"step_{step}"
        with (cdir / "manifest.json").open() as f:
            manifest = json.load(f)
        paths, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for path, tmpl_leaf in paths:
            key = _leaf_key(path)
            info = manifest["leaves"][key]
            arr = np.load(cdir / info["file"], allow_pickle=False)
            if info["dtype"] == "bfloat16":
                arr = arr.view(jnp.bfloat16)
            got_shape = tuple(info["shape"])
            want = tuple(np.shape(tmpl_leaf))
            if got_shape != want:
                raise ValueError(
                    f"checkpoint leaf {key} shape {got_shape} != template {want}"
                )
            leaves.append(jnp.asarray(arr))
        return step, jax.tree_util.tree_unflatten(treedef, leaves), manifest.get("meta", {})
