"""Serving: prefill and single-token decode steps with typed caches.

decode_32k / long_500k lower ``serve_step`` — one new token against a cache
of seq_len — exactly as the shape spec requires. Encoder-decoder archs carry
a precomputed cross-KV cache (computed once from the encoder memory).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models.layers import _dtype
from repro.models.transformer import cache_init, encode, forward


def make_prefill_step(cfg: ModelConfig, moe_chunks: int = 1):
    """Prefill returns last-position logits only: materializing (B, S, V)
    fp32 logits at 32k context would be terabytes (e.g. gemma's 256k vocab);
    serving only ever samples from the final position."""

    def prefill_step(params, batch):
        enc_out = None
        extra = None
        if cfg.family == "audio":
            enc_out = encode(params, cfg, batch["frontend"])
        elif cfg.family == "vlm":
            extra = batch["frontend"]
        logits, _, _ = forward(
            params, cfg, batch["tokens"], extra_embeds=extra, enc_out=enc_out,
            remat=False, last_logit_only=True, moe_chunks=moe_chunks,
        )
        return logits

    return prefill_step


def make_decode_cache(cfg: ModelConfig, B: int, S: int) -> Dict:
    """Allocate the stacked cache pytree (zeros; dry-run uses eval_shape)."""
    return cache_init(cfg, B, S)


def make_cross_cache(params, cfg: ModelConfig, enc_out: jnp.ndarray) -> Dict:
    """Precompute per-layer cross-attention K/V from encoder memory."""

    def one_layer(cp):
        B, S, _ = enc_out.shape
        k = (enc_out @ cp["attn"]["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
        v = (enc_out @ cp["attn"]["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
        return {"k": k, "v": v}

    return jax.vmap(one_layer)(params["cross"]) if "cross" in params else None


def make_serve_step(cfg: ModelConfig, moe_chunks: int = 1):
    """serve_step(params, cache, tokens, pos[, enc_out]) ->
    (next_token, logits, new_cache).

    Encoder-decoder archs pass the encoder memory ``enc_out``; the baseline
    recomputes cross-K/V from it each step (precomputing them once via
    make_cross_cache is an optimization discussed in EXPERIMENTS.md §Perf).
    """

    def serve_step(params, cache, tokens, pos, enc_out=None, cross_cache=None):
        logits, new_cache, _ = forward(
            params, cfg, tokens, cache=cache, cache_pos=pos,
            enc_out=enc_out, remat=False, moe_chunks=moe_chunks,
            cross_cache=cross_cache,
        )
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, logits, new_cache

    return serve_step
