"""Machine model: heterogeneous resources, memory spaces, links.

Faithful to the paper's platform abstraction:
  * ``m`` homogeneous CPUs sharing host memory (no transfer among them),
  * ``k`` homogeneous GPUs, each with a private memory, attached to the host
    through PCIe switches; two GPUs on one switch share the 16x bandwidth,
  * each *running* GPU monopolizes one CPU core to manage its worker
    (paper §4.1), so ``k`` GPUs leave ``total_cores - k`` compute CPUs.

The same abstraction covers the TPU adaptation (device groups connected by
ICI/DCN links); see configs/paper_machine.py and dist/sched_bridge.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

HOST_MEM = -1  # memory-space id of host memory


@dataclass(frozen=True)
class ResourceClass:
    """A class of homogeneous processors with per-task-kind rates.

    ``rates`` maps task kind -> effective FLOP/s for that kind on this class.
    ``default_rate`` is used for unknown kinds.
    """

    name: str
    rates: Dict[str, float]
    default_rate: float

    def rate(self, kind: str) -> float:
        return self.rates.get(kind, self.default_rate)

    def exec_time(self, kind: str, flops: float) -> float:
        r = self.rate(kind)
        if flops <= 0.0:
            return 1e-7  # bookkeeping tasks are cheap but not free
        return flops / r


@dataclass(frozen=True)
class Resource:
    """One worker: a CPU core or a GPU (with its manager core)."""

    rid: int
    cls: ResourceClass
    mem: int  # memory space id: HOST_MEM for CPUs, >=0 for GPU memories
    link: Optional[int] = None  # PCIe switch / ICI link group id (None: none)

    @property
    def is_accelerator(self) -> bool:
        return self.mem != HOST_MEM

    def __repr__(self) -> str:
        return f"{self.cls.name}{self.rid}"


@dataclass
class LinkModel:
    """Asymptotic-bandwidth + latency transfer model (StarPU-like).

    ``bandwidth`` is per *switch group* (bytes/s); GPUs sharing a switch share
    it. ``latency`` is the fixed per-transfer cost.
    """

    bandwidth: float
    latency: float = 1e-5

    def time(self, nbytes: int, sharing: int = 1) -> float:
        if nbytes <= 0:
            return 0.0
        return self.latency + nbytes / (self.bandwidth / max(1, sharing))


@dataclass
class MachineModel:
    resources: List[Resource]
    link: LinkModel
    # link group id -> list of resource ids attached (for contention)
    link_groups: Dict[int, List[int]] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if not self.link_groups:
            groups: Dict[int, List[int]] = {}
            for r in self.resources:
                if r.link is not None:
                    groups.setdefault(r.link, []).append(r.rid)
            self.link_groups = groups
        # cached partitions (resources never change after construction)
        self._cpus = [r for r in self.resources if not r.is_accelerator]
        self._gpus = [r for r in self.resources if r.is_accelerator]

    # ------------------------------------------------------------------
    @property
    def cpus(self) -> List[Resource]:
        return self._cpus

    @property
    def gpus(self) -> List[Resource]:
        return self._gpus

    def by_id(self, rid: int) -> Resource:
        return self.resources[rid]

    def classes(self) -> List[ResourceClass]:
        seen: Dict[str, ResourceClass] = {}
        for r in self.resources:
            seen.setdefault(r.cls.name, r.cls)
        return list(seen.values())

    def link_sharing(self, rid: int, active_per_group: Dict[int, int]) -> int:
        """How many *active* transfers share this resource's link group."""
        r = self.by_id(rid)
        if r.link is None:
            return 1
        return max(1, active_per_group.get(r.link, 1))


def make_machine(
    n_cpus: int,
    n_gpus: int,
    cpu_class: ResourceClass,
    gpu_class: ResourceClass,
    pcie_bandwidth: float = 8e9,
    pcie_latency: float = 1e-5,
    gpus_per_switch: int = 2,
    gpu_pins_cpu: bool = True,
) -> MachineModel:
    """Build the paper-style machine.

    ``n_cpus`` is the number of *cores in the box*; if ``gpu_pins_cpu`` each
    GPU removes one compute core (paper: "Each running GPU monopolizes a CPU
    to manage its worker").
    """
    compute_cpus = n_cpus - n_gpus if gpu_pins_cpu else n_cpus
    if compute_cpus < 0:
        raise ValueError("more GPUs than cores to pin")
    resources: List[Resource] = []
    rid = 0
    for _ in range(compute_cpus):
        resources.append(Resource(rid, cpu_class, HOST_MEM, None))
        rid += 1
    for g in range(n_gpus):
        # Up to 4 switches; with <=4 GPUs each gets its own switch (paper:
        # "Experiments using up to 4 GPUs avoid this bandwidth constraint").
        switch = g % 4 if n_gpus <= 4 else g // gpus_per_switch
        resources.append(Resource(rid, gpu_class, mem=g, link=switch))
        rid += 1
    return MachineModel(
        resources=resources,
        link=LinkModel(bandwidth=pcie_bandwidth, latency=pcie_latency),
    )
