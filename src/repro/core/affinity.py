"""Affinity score functions (paper §3.2 + the "other affinity functions"
future-work direction).

The paper's definition: "they were computed using the amount of data updated
by each task. For instance, a task that writes or modifies a data stored on a
resource R has a high score and is prone to be scheduled on R."
"""
from __future__ import annotations

from typing import Callable, Dict, List, Sequence

import numpy as np

from .dag import GraphArrays, Task
from .machine import Resource
from .perfmodel import Residency

AffinityFn = Callable[[Task, Resource, Residency], float]
# matrix form: (arrays, ready tids, resources, residency) -> (tasks × resources)
AffinityMatrixFn = Callable[
    [GraphArrays, np.ndarray, Sequence[Resource], Residency], np.ndarray
]


def score_write_resident(task: Task, resource: Resource, residency: Residency) -> float:
    """Paper default: bytes of W/RW accesses whose data is resident on R."""
    return float(
        sum(
            d.size_bytes
            for d in task.writes
            if residency.is_resident(d.name, resource.mem)
        )
    )


def score_all_resident(task: Task, resource: Resource, residency: Residency) -> float:
    """Beyond-paper variant: count all resident accessed bytes, writes double.

    (The conclusion calls for studying other affinity functions.)
    """
    s = 0.0
    seen = set()
    for a in task.accesses:
        if a.data.name in seen:
            continue
        seen.add(a.data.name)
        if residency.is_resident(a.data.name, resource.mem):
            w = 2.0 if a.mode.writes else 1.0
            s += w * a.data.size_bytes
    return s


def score_missing_bytes(task: Task, resource: Resource, residency: Residency) -> float:
    """Beyond-paper variant: negative of bytes that would need transferring."""
    missing = 0
    for d in task.reads:
        if not residency.is_resident(d.name, resource.mem):
            missing += d.size_bytes * residency.transfer_hops(d.name, resource.mem)
    return -float(missing)


def score_accel_write(task: Task, resource: Resource, residency: Residency) -> float:
    """Paper score restricted to accelerator memories (the default here).

    Host-resident data confers no affinity: every CPU reaches host memory at
    zero transfer cost, so "the data is on the host" carries no locality
    signal — the point of affinity is avoiding PCIe/ICI transfers
    (adaptation recorded in DESIGN.md §2).
    """
    if not resource.is_accelerator:
        return 0.0
    return score_write_resident(task, resource, residency)


def score_accel_all(task: Task, resource: Resource, residency: Residency) -> float:
    """Accelerator-only, reads + writes (writes weighted double)."""
    if not resource.is_accelerator:
        return 0.0
    return score_all_resident(task, resource, residency)


AFFINITY_FUNCTIONS: Dict[str, AffinityFn] = {
    "write_resident": score_write_resident,
    "all_resident": score_all_resident,
    "missing_bytes": score_missing_bytes,
    "accel_write": score_accel_write,
    "accel_all": score_accel_all,
}


# ---------------------------------------------------------------------------
# Vectorized (tasks × resources) score matrices over the CSR incidence.
#
# Each matrix function reproduces its scalar counterpart entry-by-entry:
# scores are sums of exact byte counts (integers held in float64, well below
# 2^53), so the batched sums are bit-equal to the scalar loops regardless
# of accumulation order.

def _segment_sum(values: np.ndarray, indptr: np.ndarray, n: int) -> np.ndarray:
    """Sum ``values`` per CSR segment (empty segments yield 0)."""
    col = np.add.reduceat(np.append(values, 0.0), indptr[:-1])[:n]
    empty = indptr[:-1] == indptr[1:]
    if empty.any():
        col = np.where(empty, 0.0, col)
    return col


def _resident_weighted(
    arr: GraphArrays,
    tids: np.ndarray,
    resources: Sequence[Resource],
    residency: Residency,
    indptr_full: np.ndarray,
    ids_full: np.ndarray,
    weights_full: np.ndarray,
    accel_only: bool,
) -> np.ndarray:
    indptr, ids, weights = arr.gather_csr(tids, indptr_full, ids_full, weights_full)
    n = len(tids)
    out = np.zeros((n, len(resources)), dtype=np.float64)
    if len(ids) == 0:
        return out
    masks = residency.mask_of_ids(ids)
    for j, r in enumerate(resources):
        if accel_only and not r.is_accelerator:
            continue
        bit = 1 << (r.mem + 1)
        resident = (masks & bit) != 0
        out[:, j] = _segment_sum(np.where(resident, weights, 0.0), indptr, n)
    return out


def score_write_resident_matrix(
    arr: GraphArrays,
    tids: np.ndarray,
    resources: Sequence[Resource],
    residency: Residency,
) -> np.ndarray:
    return _resident_weighted(
        arr, tids, resources, residency,
        arr.write_indptr, arr.write_ids, arr.write_sizes, accel_only=False,
    )


def score_accel_write_matrix(
    arr: GraphArrays,
    tids: np.ndarray,
    resources: Sequence[Resource],
    residency: Residency,
) -> np.ndarray:
    return _resident_weighted(
        arr, tids, resources, residency,
        arr.write_indptr, arr.write_ids, arr.write_sizes, accel_only=True,
    )


def _all_resident_weights(arr: GraphArrays) -> np.ndarray:
    """Per-access weight for the all_resident score: first occurrence of a
    name within a task counts (2x for writes), duplicates count 0."""
    w = arr.cache.get("all_resident_weights")
    if w is None:
        w = np.where(
            arr.acc_first, np.where(arr.acc_writes, 2.0, 1.0), 0.0
        ) * arr.acc_sizes
        arr.cache["all_resident_weights"] = w
    return w


def score_all_resident_matrix(
    arr: GraphArrays,
    tids: np.ndarray,
    resources: Sequence[Resource],
    residency: Residency,
) -> np.ndarray:
    return _resident_weighted(
        arr, tids, resources, residency,
        arr.acc_indptr, arr.acc_ids, _all_resident_weights(arr), accel_only=False,
    )


def score_accel_all_matrix(
    arr: GraphArrays,
    tids: np.ndarray,
    resources: Sequence[Resource],
    residency: Residency,
) -> np.ndarray:
    return _resident_weighted(
        arr, tids, resources, residency,
        arr.acc_indptr, arr.acc_ids, _all_resident_weights(arr), accel_only=True,
    )


def score_missing_bytes_matrix(
    arr: GraphArrays,
    tids: np.ndarray,
    resources: Sequence[Resource],
    residency: Residency,
) -> np.ndarray:
    indptr, ids, sizes = arr.gather_csr(
        tids, arr.read_indptr, arr.read_ids, arr.read_sizes
    )
    n = len(tids)
    out = np.zeros((n, len(resources)), dtype=np.float64)
    if len(ids) == 0:
        return out
    masks = residency.mask_of_ids(ids)
    on_host = (masks & 1) != 0
    nowhere = masks == 0
    from .machine import HOST_MEM

    for j, r in enumerate(resources):
        bit = 1 << (r.mem + 1)
        resident = (masks & bit) != 0
        if r.mem == HOST_MEM:
            hops = np.where(resident | nowhere, 0.0, 1.0)
        else:
            hops = np.where(resident | nowhere, 0.0, np.where(on_host, 1.0, 2.0))
        missing = np.where(resident, 0.0, sizes * hops)
        out[:, j] = -_segment_sum(missing, indptr, n)
    return out


def affinity_csr_source(name: str, arr: GraphArrays):
    """(indptr, ids, weights, accel_only) backing a resident-weighted score.

    This is the data the accelerated scoring backend folds on-device; the
    weights are the exact per-access floats the matrix functions above use,
    so backend scores stay bit-equal. Returns ``None`` for scores outside
    the resident-weighted family (``missing_bytes`` has its own hop
    formula) — callers fall back to :func:`affinity_rows`.
    """
    if name in ("write_resident", "accel_write"):
        return (
            arr.write_indptr, arr.write_ids, arr.write_sizes,
            name == "accel_write",
        )
    if name in ("all_resident", "accel_all"):
        return (
            arr.acc_indptr, arr.acc_ids, _all_resident_weights(arr),
            name == "accel_all",
        )
    return None


AFFINITY_MATRIX_FUNCTIONS: Dict[str, AffinityMatrixFn] = {
    "write_resident": score_write_resident_matrix,
    "all_resident": score_all_resident_matrix,
    "missing_bytes": score_missing_bytes_matrix,
    "accel_write": score_accel_write_matrix,
    "accel_all": score_accel_all_matrix,
}


def affinity_rows(
    name: str,
    arr: GraphArrays,
    tids: Sequence[int],
    tasks: Sequence[Task],
    resources: Sequence[Resource],
    residency: Residency,
) -> List[List[float]]:
    """(tasks × resources) affinity scores as list rows.

    Wide activations use the batched matrix functions; narrow ones (the
    common case) take a scalar path: the two write-resident scores walk
    the prebuilt per-task write lists with bitmask tests, any other score
    falls back to the registered scalar function. All paths produce the
    same exact byte-count floats.
    """
    n = len(tids)
    matrix_fn = AFFINITY_MATRIX_FUNCTIONS.get(name)
    if matrix_fn is not None and n >= 32:
        return matrix_fn(
            arr, np.asarray(tids, dtype=np.int64), resources, residency
        ).tolist()
    if name in ("accel_write", "write_resident"):
        accel_only = name == "accel_write"
        masks = residency._mask
        # 0 is not a valid memory bit, so it doubles as the skip sentinel
        # for non-accelerator columns
        res_bits = [
            0 if (accel_only and not r.is_accelerator) else 1 << (r.mem + 1)
            for r in resources
        ]
        active = [(j, bit) for j, bit in enumerate(res_bits) if bit]
        union = 0
        for _, bit in active:
            union |= bit
        zero_row = [0.0] * len(resources)
        out = []
        for tid in tids:
            writes = [(masks.get(nm, 0), sz) for _, nm, sz in arr.task_writes[tid]]
            any_mask = 0
            for m, _ in writes:
                any_mask |= m
            if not any_mask & union:
                # nothing this task writes is resident on a scored memory:
                # the row is all zeros (shared; rows are read-only)
                out.append(zero_row)
                continue
            row = zero_row.copy()
            for j, bit in active:
                total = 0
                for m, sz in writes:
                    if m & bit:
                        total += sz
                if total:
                    row[j] = float(total)
            out.append(row)
        return out
    fn = AFFINITY_FUNCTIONS[name]
    return [[fn(t, r, residency) for r in resources] for t in tasks]
