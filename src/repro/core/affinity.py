"""Affinity score functions (paper §3.2 + the "other affinity functions"
future-work direction).

The paper's definition: "they were computed using the amount of data updated
by each task. For instance, a task that writes or modifies a data stored on a
resource R has a high score and is prone to be scheduled on R."
"""
from __future__ import annotations

from typing import Callable, Dict

from .dag import Task
from .machine import Resource
from .perfmodel import Residency

AffinityFn = Callable[[Task, Resource, Residency], float]


def score_write_resident(task: Task, resource: Resource, residency: Residency) -> float:
    """Paper default: bytes of W/RW accesses whose data is resident on R."""
    return float(
        sum(
            d.size_bytes
            for d in task.writes
            if residency.is_resident(d.name, resource.mem)
        )
    )


def score_all_resident(task: Task, resource: Resource, residency: Residency) -> float:
    """Beyond-paper variant: count all resident accessed bytes, writes double.

    (The conclusion calls for studying other affinity functions.)
    """
    s = 0.0
    seen = set()
    for a in task.accesses:
        if a.data.name in seen:
            continue
        seen.add(a.data.name)
        if residency.is_resident(a.data.name, resource.mem):
            w = 2.0 if a.mode.writes else 1.0
            s += w * a.data.size_bytes
    return s


def score_missing_bytes(task: Task, resource: Resource, residency: Residency) -> float:
    """Beyond-paper variant: negative of bytes that would need transferring."""
    missing = 0
    for d in task.reads:
        if not residency.is_resident(d.name, resource.mem):
            missing += d.size_bytes * residency.transfer_hops(d.name, resource.mem)
    return -float(missing)


def score_accel_write(task: Task, resource: Resource, residency: Residency) -> float:
    """Paper score restricted to accelerator memories (the default here).

    Host-resident data confers no affinity: every CPU reaches host memory at
    zero transfer cost, so "the data is on the host" carries no locality
    signal — the point of affinity is avoiding PCIe/ICI transfers
    (adaptation recorded in DESIGN.md §2).
    """
    if not resource.is_accelerator:
        return 0.0
    return score_write_resident(task, resource, residency)


def score_accel_all(task: Task, resource: Resource, residency: Residency) -> float:
    """Accelerator-only, reads + writes (writes weighted double)."""
    if not resource.is_accelerator:
        return 0.0
    return score_all_resident(task, resource, residency)


AFFINITY_FUNCTIONS: Dict[str, AffinityFn] = {
    "write_resident": score_write_resident,
    "all_resident": score_all_resident,
    "missing_bytes": score_missing_bytes,
    "accel_write": score_accel_write,
    "accel_all": score_accel_all,
}
