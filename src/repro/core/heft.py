"""HEFT — Heterogeneous Earliest Finish Time, XKaapi variant (paper §3.1).

Both phases run inside ``activate`` (Algorithm 1):
  * task prioritizing: ready tasks sorted by decreasing GPU speedup
    ``S_i = p_i^CPU / p_i^GPU`` (the paper replaces upward-rank with this),
  * worker selection: each task goes to the worker with the earliest
    predicted finish time, *always* including predicted transfer time
    ("HEFT strategy always computes the earliest finish time of a task
    taking into account the time to transfer data", §4.1).
"""
from __future__ import annotations

from typing import List, Optional

from .dag import Task
from .simulator import Simulator, Strategy


class HEFT(Strategy):
    name = "heft"
    allow_steal = False
    owner_lifo = False

    def place(self, sim: Simulator, ready: List[Task], src: Optional[int]) -> None:
        machine = sim.machine
        cpus = machine.cpus
        gpus = machine.gpus
        cpu_cls = cpus[0].cls if cpus else gpus[0].cls
        gpu_cls = gpus[0].cls if gpus else cpu_cls

        # --- task prioritizing: decreasing speedup -----------------------
        scored = []
        for t in ready:
            p_cpu = sim.model.predict(t, cpu_cls)
            p_gpu = sim.model.predict(t, gpu_cls)
            s = p_cpu / p_gpu if p_gpu > 0 else 1.0
            scored.append((-s, t.tid, t))
        scored.sort()

        # --- worker selection: earliest finish time ----------------------
        for _, _, t in scored:
            best_eft = float("inf")
            best_rid = machine.resources[0].rid
            for r in machine.resources:
                start = max(sim.now, sim.load_ts[r.rid])
                xfer = sim.transfer_model.task_input_transfer_time(
                    t, r, sim.residency
                )
                eft = start + xfer + sim.model.predict(t, r.cls)
                if eft < best_eft - 1e-15:
                    best_eft = eft
                    best_rid = r.rid
            sim.load_ts[best_rid] = best_eft
            sim.push(t, best_rid)
