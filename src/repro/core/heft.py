"""HEFT — Heterogeneous Earliest Finish Time, XKaapi variant (paper §3.1).

Both phases run inside ``activate`` (Algorithm 1):
  * task prioritizing: ready tasks sorted by decreasing GPU speedup
    ``S_i = p_i^CPU / p_i^GPU`` (the paper replaces upward-rank with this),
  * worker selection: each task goes to the worker with the earliest
    predicted finish time, *always* including predicted transfer time
    ("HEFT strategy always computes the earliest finish time of a task
    taking into account the time to transfer data", §4.1).

Array-native: per-class predicted durations come from the cached vector
predictor (class durations are invariant within an activation, so they are
hoisted out of the EFT loop entirely) and the (ready × resources) transfer
estimates come from the CSR read incidence + residency bitmasks — batched
numpy for wide activations, a scalar pass over the same arrays for narrow
ones (``activate`` usually wakes 1-3 tasks, where per-call numpy setup
would dominate). The per-task EFT selection keeps the strict-improvement
scan of the scalar reference, so placements (including tie-breaks within
1e-15) are bit-identical to ``repro.core._reference.ReferenceHEFT``.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from .backend import ScoringBackendMixin
from .dag import Task
from .simulator import Simulator, Strategy

_WIDE = 32  # ready-set size from which the batched numpy path wins


class HEFT(ScoringBackendMixin, Strategy):
    name = "heft"
    allow_steal = False
    owner_lifo = False

    def __init__(self, backend: Optional[str] = None, config=None) -> None:
        """``backend``: placement-scoring backend (``numpy``/``jax``);
        default follows the scheduling configuration (``config`` or the
        environment-derived ``repro.sched.SchedConfig``). The jax backend
        computes the transfer matrix in one fused dispatch and runs the
        sequential EFT selection as a jitted scan on wide activations —
        placements (including the 1e-15 strict-improvement tie-break) are
        bit-identical to the scalar loop."""
        self._init_backend(backend, config)

    def place(self, sim: Simulator, ready: List[Task], src: Optional[int]) -> None:
        machine = sim.machine
        resources = machine.resources
        cpus = machine.cpus
        gpus = machine.gpus
        cpu_cls = cpus[0].cls if cpus else gpus[0].cls
        gpu_cls = gpus[0].cls if gpus else cpu_cls

        n = len(ready)
        tids = [t.tid for t in ready]

        # --- per-class predicted durations (activation-invariant) --------
        if n >= _WIDE:
            tids_arr = np.asarray(tids, dtype=np.int64)
            p_cpu = sim.predictor(cpu_cls).times(tids_arr).tolist()
            p_gpu = sim.predictor(gpu_cls).times(tids_arr).tolist()
        else:
            p_cpu = sim.predictor(cpu_cls).times_list(tids)
            p_gpu = sim.predictor(gpu_cls).times_list(tids)

        # --- task prioritizing: decreasing speedup -----------------------
        speed = [pc / pg if pg > 0 else 1.0 for pc, pg in zip(p_cpu, p_gpu)]
        order = sorted(range(n), key=lambda i: (-speed[i], tids[i]))

        # per-resource duration columns (only two classes exist in the
        # paper machine, so this is two lookups, not a per-resource model
        # call)
        cls_times = {cpu_cls.name: p_cpu, gpu_cls.name: p_gpu}
        cols = []
        for r in resources:
            col = cls_times.get(r.cls.name)
            if col is None:
                col = sim.predictor(r.cls).times_list(tids)
                cls_times[r.cls.name] = col
            cols.append(col)

        # memory-pressure penalty (capacity-bounded memories, plus the
        # +inf mask over detached resources): predicted eviction seconds
        # folded into the transfer matrix, on the numpy and jax scoring
        # paths alike
        from repro.runtime.memory import fold_pressure, pressure_rows_for

        P = pressure_rows_for(sim, tids, resources)

        # under active faults the scalar path runs (dead columns carry
        # +inf, which the fused backend's kernels do not model — and a
        # pending preemption notice adds a time-varying finite penalty
        # the kernels do not model either); with no resource detached or
        # noticed the fused path is untouched, preserving cross-backend
        # equivalence
        faults = getattr(sim, "faults", None)
        any_dead = faults is not None and (
            faults.any_dead or bool(faults.noticed)
        )

        # accelerated path (wide activations, jax backend): fused transfer
        # matrix + jitted sequential EFT scan, bit-identical placements
        be = self._scoring_backend()
        if be is not None and n >= be.min_wide and not any_dead:
            fused = be.score_matrices(
                sim, tids, resources, use_cp=True, x_rows=True, x_bias=P
            )
            if fused is not None:
                load_ts = sim.load_ts
                colsT = np.asarray(cols, dtype=np.float64).T  # (n, n_res)
                X_np = fused["X_np"]
                rids, efts = be.heft_select(
                    colsT[order], X_np[order], load_ts, sim.now
                )
                for k, i in enumerate(order):
                    rid = int(rids[k])
                    load_ts[rid] = float(efts[k])
                    sim.push(ready[i], rid)
                return

        X = fold_pressure(
            sim.transfer_model.task_input_transfer_rows(
                sim.arrays, tids, [r.mem for r in resources], sim.residency
            ),
            P,
        )

        # --- worker selection: earliest finish time ----------------------
        load_ts = sim.load_ts
        now = sim.now
        n_res = len(resources)
        first_rid = resources[0].rid
        inf = float("inf")
        for i in order:
            xrow = X[i]
            best_eft = inf
            best_rid = first_rid
            for rid in range(n_res):
                lt = load_ts[rid]
                start = now if now > lt else lt
                eft = start + xrow[rid] + cols[rid][i]
                if eft < best_eft - 1e-15:
                    best_eft = eft
                    best_rid = rid
            load_ts[best_rid] = best_eft
            sim.push(ready[i], best_rid)
