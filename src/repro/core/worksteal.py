"""Locality-oblivious random work stealing (paper §4.3 "Comparison with
work stealing scheduling algorithm").

``activate`` pushes newly-ready tasks onto the completing worker's own queue
(owner executes newest-first); idle workers steal the oldest task from a
randomly selected victim. No performance or transfer model is used — the
"model oblivious" baseline the paper discusses.
"""
from __future__ import annotations

from typing import List, Optional

from .dag import Task
from .simulator import Simulator, Strategy


class WorkSteal(Strategy):
    name = "ws"
    allow_steal = True
    owner_lifo = True

    def place(self, sim: Simulator, ready: List[Task], src: Optional[int]) -> None:
        rid = src if src is not None else 0
        for t in ready:
            sim.push(t, rid)
