"""Batched surrogate episodes: whole list-scheduling runs as one jax dispatch.

The exact engine (:mod:`repro.runtime.engine`) is a Python event loop —
the verification oracle, bit-for-bit pinned to the reference simulator.
This module is the opt-in approximation behind ``REPRO_SCHED_EXACT=0``
(:class:`repro.sched.SchedConfig`): it compiles a *whole* greedy
list-scheduling placement episode — ready-set maintenance over the padded
CSR incidence, fused per-resource score rows, argmin assignment, EFT/clock
advance and residency bitmask updates — into a single ``lax.scan`` over
task steps with fixed-shape padded state, and batches it over a leading
axis of configurations (seeds × α/cp parameters × machine shapes ×
capacities). Scatter updates inside the step are ``jax.vmap``-ed over the
batch axis; the transfer-cost rows are computed batch-wide through the
shared hop fold of :mod:`repro.kernels.sched_score` (the Pallas kernel
when ``REPRO_SCHED_PALLAS`` selects it, interpret mode on CPU), so every
step's residency→transfer math lives exactly once in the codebase.

What the surrogate relaxes (and why rankings still transfer):

* **Tie-breaking** — deterministic index-order argmin/argmax instead of
  the oracle's per-strategy tie rules; list order is a static upward-rank
  priority instead of event-driven activation order.
* **Online calibration** — scores use the static ``flops/rate`` estimate
  (the oracle's history model converges to the same mean under the seeded
  multiplicative noise, which the surrogate applies to the *executed*
  durations from the identical ``default_rng(seed)`` stream).
* **Transfer overlap** — a placement pays its transfer time serially
  before executing instead of overlapping with prefetch. Link contention
  *is* modeled to first order: transfers serialize FIFO on the
  destination resource's PCIe switch group (a per-group free clock, the
  oracle's ``link_free``), which is what makes affinity pay off at high
  GPU counts; the source leg of a two-hop move does not occupy the
  source's group. Strategies pay the same relaxation, so *orderings*
  (DADA vs HEFT makespan and transferred bytes) survive; absolute
  makespans carry a reported relative error (see
  ``tests/test_episode.py``).
* **Eviction** — capacity pressure uses a bounded per-step LRU pass
  (at most ``_K_EVICT`` victims per placement) instead of the exact
  reservation protocol.

Correctness is therefore *ranking fidelity*, asserted against the oracle
in CI, not bit-equality.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.backend import _bucket
from repro.core.dag import TaskGraph
from repro.core.machine import HOST_MEM, MachineModel

# indegree sentinel for padded task rows: never ready
_NEVER = np.int32(1 << 30)
# LRU eviction budget per placement step (capacity-bounded batches only)
_K_EVICT = 8


# ---------------------------------------------------------------------------
# host-side plan: one graph × one machine template, shared by a whole batch


@dataclass
class EpisodePlan:
    """Padded device-ready arrays for one (graph, machine-template) pair.

    Shared across every configuration in a batch: configurations vary the
    resource composition (``is_gpu``/``mem_col``), the strategy parameters
    and the seeds — not the incidence structure.
    """

    n: int
    n_pad: int
    r_pad: int
    w_pad: int
    s_pad: int
    n_data: int
    n_u: int
    n_res: int
    read_ids: np.ndarray  # (n_pad, r_pad) int32, padded entries -> n_data
    read_t: np.ndarray  # (n_pad, r_pad) f64 per-read one-hop seconds
    read_sz: np.ndarray  # (n_pad, r_pad) f64 bytes
    write_ids: np.ndarray  # (n_pad, w_pad) int32, padded entries -> n_data
    write_sz: np.ndarray  # (n_pad, w_pad) f64 bytes
    succ_ids: np.ndarray  # (n_pad, s_pad) int32, padded entries -> n_pad
    indeg0: np.ndarray  # (n_pad + 1,) int32 (+1: dummy scatter slot)
    prio: np.ndarray  # (n_pad,) f64 upward rank (higher = earlier)
    dur_cpu: np.ndarray  # (n_pad,) f64 static exec times (1e-7 floor)
    dur_gpu: np.ndarray
    sizes: np.ndarray  # (n_data + 1,) f64 bytes (dummy slot 0)
    col_bits: np.ndarray  # (n_u,) int32: bit 0 host, bit 1+g device g
    host_col: np.ndarray  # (n_u,) bool
    bandwidth: float
    latency: float
    total_flops: float


def _pad2(rows: List[List[Tuple[int, float]]], n_pad: int, width: int, fill_id: int):
    # pad slot j carries the *distinct* dummy id fill_id + j: indices stay
    # unique within a row, so every scatter in the compiled episode can
    # promise unique_indices (XLA CPU scatters are scalar loops otherwise)
    # and rely on mode="drop" to discard the out-of-bounds dummies
    ids = np.tile(fill_id + np.arange(width, dtype=np.int32), (n_pad, 1))
    val = np.zeros((n_pad, width), dtype=np.float64)
    for t, row in enumerate(rows):
        for j, (i, v) in enumerate(row):
            ids[t, j] = i
            val[t, j] = v
    return ids, val


def build_plan(
    graph: TaskGraph, machine: MachineModel, n_u: Optional[int] = None
) -> EpisodePlan:
    """Build (and memoize on ``arrays().cache``) the padded episode plan.

    ``machine`` is a *template*: it supplies the CPU/GPU resource classes
    and the link model. ``n_u`` is the unique-memory column count the
    batch needs (1 + the largest device-memory id across the batch);
    defaults to this machine's own layout.
    """
    arr = graph.arrays()
    cpu_cls = next((r.cls for r in machine.resources if not r.is_accelerator), None)
    gpu_cls = next((r.cls for r in machine.resources if r.is_accelerator), None)
    if cpu_cls is None:
        cpu_cls = gpu_cls
    if gpu_cls is None:
        gpu_cls = cpu_cls
    max_mem = max((r.mem for r in machine.resources if r.is_accelerator), default=-1)
    if n_u is None:
        n_u = max_mem + 2
    key = (
        "episode_plan", n_u, len(machine.resources),
        cpu_cls.name, gpu_cls.name,
        machine.link.bandwidth, machine.link.latency,
    )
    plan = arr.cache.get(key)
    if plan is not None:
        return plan

    n = arr.n_tasks
    # multiples of 128 (not pow2): the scan walks (B, n_pad) state every
    # step, so a 1496-task trace padded to 2048 would pay 37% dead traffic
    n_pad = max(128, -(-n // 128) * 128)
    n_data = len(arr.data_sizes)
    lat, bw = machine.link.latency, machine.link.bandwidth

    reads = [
        [(did, 0.0 if sz <= 0 else lat + sz / bw) for did, _, sz in row]
        for row in arr.task_reads
    ]
    r_pad = _bucket(max((len(r) for r in reads), default=1), lo=2)
    read_ids, read_t = _pad2(reads, n_pad, r_pad, n_data)
    _, read_sz = _pad2(
        [[(did, float(sz)) for did, _, sz in row] for row in arr.task_reads],
        n_pad, r_pad, n_data,
    )
    writes = [[(did, float(sz)) for did, _, sz in row] for row in arr.task_writes]
    w_pad = _bucket(max((len(w) for w in writes), default=1), lo=2)
    write_ids, write_sz = _pad2(writes, n_pad, w_pad, n_data)

    succ = [graph.succ[t.tid] for t in graph.tasks]
    s_pad = _bucket(max((len(s) for s in succ), default=1), lo=2)
    succ_ids = np.tile(n_pad + np.arange(s_pad, dtype=np.int32), (n_pad, 1))
    for t, ss in enumerate(succ):
        succ_ids[t, : len(ss)] = ss

    indeg0 = np.full(n_pad + 1, _NEVER, dtype=np.int32)
    indeg0[:n] = [len(graph.pred[t.tid]) for t in graph.tasks]

    # static exec-time vectors, identical to ClassPredictor's bootstrap
    def _static(cls) -> np.ndarray:
        rates = np.array([cls.rate(k) for k in arr.kinds], dtype=np.float64)
        est = arr.flops / rates[arr.kind_codes]
        est = np.where(arr.flops <= 0.0, 1e-7, est)
        out = np.zeros(n_pad, dtype=np.float64)
        out[:n] = est
        return out

    dur_cpu = _static(cpu_cls)
    dur_gpu = _static(gpu_cls)

    # upward rank over machine-average durations + produced-data transfer
    # time: a static critical-path-aware list priority (arxiv 1711.06433's
    # generic list-scheduling formulation)
    avg = (dur_cpu[:n] + dur_gpu[:n]) / 2.0
    comm = np.array(
        [
            max((lat + sz / bw for _, _, sz in row if sz > 0), default=0.0)
            for row in arr.task_writes
        ]
    )
    prio = np.zeros(n_pad, dtype=np.float64)
    for tid in reversed(graph.topo_order()):
        down = max((prio[s] for s in graph.succ[tid]), default=0.0)
        prio[tid] = avg[tid] + comm[tid] + down

    sizes = np.zeros(n_data + 1, dtype=np.float64)
    sizes[:n_data] = arr.data_sizes

    col_bits = np.array([1 << u for u in range(n_u)], dtype=np.int32)
    host_col = np.zeros(n_u, dtype=bool)
    host_col[0] = True

    plan = EpisodePlan(
        n=n, n_pad=n_pad, r_pad=r_pad, w_pad=w_pad, s_pad=s_pad,
        n_data=n_data, n_u=n_u, n_res=len(machine.resources),
        read_ids=read_ids, read_t=read_t, read_sz=read_sz,
        write_ids=write_ids, write_sz=write_sz, succ_ids=succ_ids,
        indeg0=indeg0, prio=prio, dur_cpu=dur_cpu, dur_gpu=dur_gpu,
        sizes=sizes, col_bits=col_bits, host_col=host_col,
        bandwidth=bw, latency=lat, total_flops=graph.total_flops(),
    )
    arr.cache[key] = plan
    return plan


# ---------------------------------------------------------------------------
# per-configuration batch axes


@dataclass
class EpisodeBatch:
    """Stacked per-configuration inputs (leading axis = batch)."""

    is_gpu: np.ndarray  # (B, R) bool
    valid_res: np.ndarray  # (B, R) bool
    mem_col: np.ndarray  # (B, R) int32 unique-memory column per resource
    link_grp: np.ndarray  # (B, R) int32 link group per resource (< R)
    alpha: np.ndarray  # (B,) f64 affinity weight
    use_cp: np.ndarray  # (B,) f64 0/1: transfer prediction in the score
    ws_pref: np.ndarray  # (B,) bool: parent-worker (LIFO) preference
    noise: np.ndarray  # (B, n_pad) f64 multiplicative duration factors
    cap: np.ndarray  # (B,) f64 device-memory bytes (+inf = unbounded)

    def __len__(self) -> int:
        return len(self.alpha)


def surrogate_params(spec: str) -> Tuple[float, float, bool]:
    """Map a policy spec to surrogate (alpha, use_cp, ws_pref) axes.

    Only list-scheduling strategies have a surrogate form: ``heft`` is
    EFT with transfer prediction, ``dada``/``dual`` add the α-weighted
    write-affinity bonus, ``ws`` is blind EFT with a parent-worker (LIFO
    locality) preference. Randomized policies have no mapping — the
    exact engine remains their only path.
    """
    from repro.sched.registry import parse_spec

    name, raw = parse_spec(spec)
    truthy = ("1", "true", "yes", "on")
    if name == "heft":
        return 0.0, 1.0, False
    if name == "ws":
        return 0.0, 0.0, True
    if name in ("dada", "dual"):
        alpha = 0.0 if name == "dual" else 0.5
        if "alpha" in raw:
            alpha = float(raw["alpha"])
        use_cp = 1.0 if str(raw.get("use_cp", "0")).lower() in truthy else 0.0
        return alpha, use_cp, False
    raise ValueError(
        f"strategy {spec!r} has no surrogate episode mapping "
        "(supported: heft, ws, dada, dual); run it on the exact engine"
    )


def noise_factors(seed: int, noise: float, n: int, n_pad: int) -> np.ndarray:
    """The oracle's per-task duration factors, from the identical stream
    (``Engine.submit`` draws one batched normal in tid order)."""
    out = np.ones(n_pad, dtype=np.float64)
    if noise > 0 and n > 0:
        out[:n] = np.exp(np.random.default_rng(seed).normal(0.0, noise, size=n))
    return out


def machine_axes(
    machine: MachineModel, n_res: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """(is_gpu, valid, mem_col, link_grp) rows for one machine, padded to
    ``n_res``.

    ``link_grp`` densely renumbers the machine's PCIe switch groups and
    gives every CPU its own group — transfers into a resource serialize
    FIFO against others on the same group (the oracle's ``link_free``),
    and host-side pulls don't contend with each other. Group ids stay
    below the resource count, so the episode's link clock is (B, R).
    """
    is_gpu = np.zeros(n_res, dtype=bool)
    valid = np.zeros(n_res, dtype=bool)
    mem_col = np.zeros(n_res, dtype=np.int32)
    link_grp = np.zeros(n_res, dtype=np.int32)
    groups: Dict[int, int] = {}
    for r in machine.resources:
        if r.is_accelerator and r.link is not None:
            groups.setdefault(r.link, len(groups))
    n_sw = len(groups)
    for r in machine.resources:
        is_gpu[r.rid] = r.is_accelerator
        valid[r.rid] = True
        mem_col[r.rid] = 0 if r.mem == HOST_MEM else r.mem + 1
        if r.is_accelerator and r.link is not None:
            link_grp[r.rid] = groups[r.link]
        else:
            n_sw += 1
            link_grp[r.rid] = min(n_sw - 1, n_res - 1)
    return is_gpu, valid, mem_col, link_grp


# ---------------------------------------------------------------------------
# the compiled episode: lax.scan over steps, batch axis across configs

_EPISODE_CACHE: Dict[tuple, object] = {}
_DISK_CACHE_SET = False


def _enable_disk_cache() -> None:
    """Point jax's persistent compilation cache at a stable directory.

    The episode jit compiles in ~1-2s per (kernel, shape) — the dominant
    cost of a cold fast-validation run. The persistent cache makes every
    later process start warm. Respects an explicit
    ``JAX_COMPILATION_CACHE_DIR`` (read through ``SchedConfig`` — this
    module does not touch ``os.environ``); best-effort otherwise.
    """
    global _DISK_CACHE_SET
    if _DISK_CACHE_SET:
        return
    _DISK_CACHE_SET = True
    import os
    import tempfile

    try:
        import jax

        from repro.sched.config import current_config

        cache_dir = current_config().jax_cache_dir
        if not cache_dir:
            cache_dir = os.path.join(tempfile.gettempdir(), "repro-jax-cache")
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.2)
    except Exception:
        pass  # older jax or read-only tmp: compiles stay in-process only


def _pallas_mode(config) -> Tuple[bool, bool]:
    """(use_pallas, interpret) from the validated config."""
    import jax

    mode = config.pallas
    platform = jax.default_backend()
    if mode in ("0", "off", "false"):
        return False, False
    if mode == "1":
        return True, platform == "cpu"
    return platform in ("gpu", "tpu"), False  # auto: native only


def _build_episode_fn(shape_key: tuple):
    _enable_disk_cache()
    import jax
    import jax.numpy as jnp

    from repro.kernels.sched_score import (
        transfer_matrix_jnp,
        transfer_matrix_pallas,
    )

    (B, n_pad, r_pad, w_pad, s_pad, R, n_u, nd1, n_steps,
     use_cap, use_pallas, interpret, emit) = shape_key

    def xfer_rows(masks, per_read, col_bits, host_col):
        if use_pallas:
            bt = min(128, B)
            return transfer_matrix_pallas(
                masks, per_read, col_bits, host_col, bt=bt, interpret=interpret
            )
        return transfer_matrix_jnp(masks, per_read, col_bits, host_col)

    # batch-axis scatters, vmapped over configurations. Indices are unique
    # within a row by construction (distinct out-of-range dummies for pads
    # and masked-off steps), so XLA gets the unique_indices promise and
    # drop semantics — without them CPU scatters fall back to a guarded
    # scalar loop that dominates the whole scan
    _HINTS = dict(mode="drop", unique_indices=True)
    scat_set = jax.vmap(lambda a, i, v: a.at[i].set(v, **_HINTS))
    scat_add = jax.vmap(lambda a, i, v: a.at[i].add(v, **_HINTS))
    scat_max = jax.vmap(lambda a, i, v: a.at[i].max(v, **_HINTS))
    row_of = jax.vmap(lambda a, i: a[i])  # a: (B, X, Y), i: (B,) -> (B, Y)
    scat_row_set = jax.vmap(lambda a, u, i, v: a.at[u, i].set(v, **_HINTS))

    def pick(mat, idx):  # (B, X), (B,) -> (B,)
        return jnp.take_along_axis(mat, idx[:, None], axis=1)[:, 0]

    def gather_rows(mat, idx):  # clamped: pad ids sit past the last slot
        return jnp.take_along_axis(
            mat, jnp.minimum(idx, mat.shape[1] - 1), axis=1
        )

    def episode(
        read_ids, read_t, read_sz, write_ids, write_sz, succ_ids,
        indeg0, prio, dur_cpu, dur_gpu, sizes, col_bits, host_col,
        is_gpu, valid_res, mem_col, link_grp, alpha, use_cp, ws_pref,
        noise, cap, bandwidth,
    ):
        rr = jnp.arange(R, dtype=jnp.int32)
        iota_n = jnp.arange(n_pad, dtype=jnp.int32)
        iota_nd = jnp.arange(nd1, dtype=jnp.int32)

        def step(carry, k):
            (load, tcount, pready, ready_t, indeg, res_mask, touch, resbytes,
             writer, link_free, total_b, mk, npl) = carry
            tb_in = total_b  # for the emitted per-step eviction bytes

            # pready carries the ready set directly: prio where ready,
            # -inf otherwise. max + first-match iota-min instead of argmax:
            # XLA's CPU argmax lowers to a scalar index-tracking loop (~4x
            # slower than these two vectorized reduces), and the max value
            # doubles as the activity test
            best = jnp.max(pready, axis=1)
            t = jnp.min(
                jnp.where(pready == best[:, None], iota_n, n_pad - 1), axis=1
            ).astype(jnp.int32)
            act = best > -jnp.inf  # padded steps: no-op

            rids = read_ids[t]  # (B, r_pad)
            prt = read_t[t]
            rsz = read_sz[t]
            wids = write_ids[t]  # (B, w_pad)
            wsz = write_sz[t]
            masks = gather_rows(res_mask, rids)
            wmasks = gather_rows(res_mask, wids)

            # fused score row pieces -------------------------------------
            X = xfer_rows(masks, prt, col_bits, host_col)  # (B, n_u) s
            aff = (
                ((wmasks[:, :, None] & col_bits[None, None, :]) != 0)
                * wsz[:, :, None]
            ).sum(axis=1) / bandwidth
            aff = jnp.where(host_col[None, :], 0.0, aff)  # accel_write

            est = pick(ready_t, t)
            dur_r = jnp.where(is_gpu, dur_gpu[t][:, None], dur_cpu[t][:, None])
            X_r = jnp.take_along_axis(X, mem_col, axis=1)
            aff_r = jnp.take_along_axis(aff, mem_col, axis=1)
            base = jnp.maximum(est[:, None], load)
            score = base + use_cp[:, None] * X_r + dur_r
            score = score - alpha[:, None] * aff_r
            score = jnp.where(valid_res, score, jnp.inf)
            r_sel = jnp.argmin(score, axis=1).astype(jnp.int32)

            # work-stealing surrogate: blind stealing spreads tasks by
            # *count*, not time — CPUs absorb the same share as GPUs —
            # with xkaapi's LIFO rule keeping a child on its parent's
            # worker unless that worker is clearly backlogged
            tscore = jnp.where(valid_res, tcount.astype(jnp.float32), jnp.inf)
            ws_sel = jnp.argmin(tscore, axis=1).astype(jnp.int32)
            pref = pick(writer, rids[:, 0])
            pref_c = jnp.clip(pref, 0, R - 1)
            pref_ok = (
                (pref >= 0)
                & pick(valid_res, pref_c)
                & (pick(tscore, pref_c) <= jnp.min(tscore, axis=1) + 1.0)
            )
            ws_sel = jnp.where(pref_ok, pref_c, ws_sel)
            r_sel = jnp.where(ws_pref, ws_sel, r_sel)

            u = pick(mem_col, r_sel)
            dst_bit = col_bits[u]  # (B,)
            dst_host = host_col[u]

            # ground-truth advance: per-read hops to the chosen memory
            resident = (masks & dst_bit[:, None]) != 0
            nowhere = masks == 0
            on_host = (masks & 1) != 0
            hops = jnp.where(
                resident | nowhere,
                0.0,
                jnp.where(dst_host[:, None] | on_host, 1.0, 2.0),
            )
            xfer_t = (hops * prt).sum(axis=1)
            xfer_b = (hops * rsz).sum(axis=1)

            dur_sel = pick(dur_r, r_sel) * pick(noise, t)
            # transfers serialize FIFO on the destination's link group
            # (the oracle's link_free clock): contention on shared PCIe
            # switches is what makes affinity pay off at high GPU counts
            grp = pick(link_grp, r_sel)
            has_x = xfer_t > 0.0
            start = jnp.maximum(est, pick(load, r_sel))
            start = jnp.maximum(
                start, jnp.where(has_x, pick(link_free, grp), 0.0)
            )
            fin = start + xfer_t + dur_sel
            grp_eff = jnp.where(act & has_x, grp, R)  # OOB: dropped
            link_free = scat_set(
                link_free, grp_eff[:, None], (start + xfer_t)[:, None]
            )

            # clock / ready-set updates ----------------------------------
            sel_hot = (rr[None, :] == r_sel[:, None]) & act[:, None]
            load = jnp.where(sel_hot, fin[:, None], load)
            tcount = tcount + sel_hot.astype(jnp.int32)
            npl = npl + act.astype(jnp.int32)
            # retire the chosen task (scatter -inf), decrement successor
            # indegrees, and light up successors that just became ready;
            # dummy successor slots and inactive steps index past the
            # state's edge and are dropped by the scatter mode
            pready = scat_set(
                pready, jnp.where(act, t, n_pad)[:, None],
                jnp.full((B, 1), -jnp.inf, pready.dtype),
            )
            succs = succ_ids[t] + jnp.where(act, 0, n_pad + s_pad)[:, None]
            indeg = scat_add(indeg, succs, jnp.full_like(succs, -1))
            now_ready = gather_rows(indeg, succs) == 0
            pready = scat_max(
                pready, succs,
                jnp.where(now_ready, prio[jnp.minimum(succs, n_pad - 1)], -jnp.inf),
            )
            ready_t = scat_max(
                ready_t, succs, jnp.broadcast_to(fin[:, None], succs.shape)
            )
            mk = jnp.maximum(mk, jnp.where(act, fin, 0.0))
            total_b = total_b + jnp.where(act, xfer_b, 0.0)

            # residency updates: reads land copies, writes invalidate ----
            new_rmask = (
                masks
                | jnp.where(hops > 0, dst_bit[:, None], 0)
                | (hops == 2).astype(jnp.int32)
            )
            rids_eff = rids + jnp.where(act, 0, nd1)[:, None]
            res_mask = scat_set(res_mask, rids_eff, new_rmask)
            wids_eff = wids + jnp.where(act, 0, nd1)[:, None]
            res_mask = scat_set(
                res_mask, wids_eff, jnp.broadcast_to(dst_bit[:, None], wids.shape)
            )
            res_mask = res_mask.at[:, nd1 - 1].set(1)  # dummy slot stays host
            writer = scat_set(
                writer, wids_eff, jnp.broadcast_to(r_sel[:, None], wids.shape)
            )
            writer = writer.at[:, nd1 - 1].set(-1)

            if use_cap:
                onehot_u = (jnp.arange(n_u)[None, :] == u[:, None])
                rd_new = (jnp.where(hops > 0, rsz, 0.0)).sum(axis=1)
                host_new = (jnp.where(hops == 2, rsz, 0.0)).sum(axis=1)
                w_res = (wmasks[:, :, None] & col_bits[None, None, :]) != 0
                w_drop = jnp.where(w_res, wsz[:, :, None], 0.0).sum(axis=1)
                w_tot = wsz.sum(axis=1)
                delta = (
                    onehot_u * (rd_new + w_tot)[:, None]
                    - w_drop
                    + host_col[None, :] * host_new[:, None]
                )
                resbytes = resbytes + jnp.where(act[:, None], delta, 0.0)
                touch = scat_row_set(touch, u, rids_eff, jnp.full_like(rids, k))
                touch = scat_row_set(touch, u, wids_eff, jnp.full_like(wids, k))

                def evict(_, st):
                    res_mask, resbytes, total_b = st
                    need = act & ~dst_host & (pick(resbytes, u) > cap)
                    res_at = (res_mask & dst_bit[:, None]) != 0
                    touch_u = row_of(touch, u)  # (B, nd1)
                    cand = res_at & (touch_u < k) & (sizes[None, :] > 0)
                    key = jnp.where(cand, touch_u, _NEVER)
                    km = jnp.min(key, axis=1)
                    v = jnp.min(
                        jnp.where(key == km[:, None], iota_nd, nd1 - 1), axis=1
                    ).astype(jnp.int32)
                    can = need & (km < _NEVER)
                    vsz = jnp.where(can, sizes[v], 0.0)
                    vmask = pick(res_mask, v)
                    dirty = vmask == dst_bit  # sole device copy: write back
                    total_b = total_b + jnp.where(can & dirty, vsz, 0.0)
                    newm = jnp.where(
                        can, (vmask | dirty.astype(jnp.int32)) & ~dst_bit, vmask
                    )
                    v_eff = jnp.where(can, v, nd1)  # dropped unless evicting
                    res_mask = scat_set(
                        res_mask, v_eff[:, None], newm[:, None]
                    )
                    resbytes = resbytes - onehot_u * vsz[:, None]
                    return res_mask, resbytes, total_b

                res_mask, resbytes, total_b = jax.lax.fori_loop(
                    0, _K_EVICT, evict, (res_mask, resbytes, total_b)
                )

            # schedule emission (audit schema for repro.verify): the
            # chosen task/resource and its timeline per step. Off by
            # default — emit changes the compiled shape, so it is part of
            # the cache key and costs nothing when disabled.
            if emit:
                evict_b = total_b - tb_in - jnp.where(act, xfer_b, 0.0)
                ys = (t, r_sel, act, start, xfer_t, fin,
                      jnp.where(act, xfer_b, 0.0), evict_b)
            else:
                ys = None
            return (
                (load, tcount, pready, ready_t, indeg, res_mask, touch,
                 resbytes, writer, link_free, total_b, mk, npl),
                ys,
            )

        f32 = jnp.float32
        carry0 = (
            jnp.zeros((B, R), f32),
            jnp.zeros((B, R), jnp.int32),
            jnp.broadcast_to(
                jnp.where(indeg0[None, :n_pad] == 0, prio[None, :], -jnp.inf),
                (B, n_pad),
            ).astype(f32),
            jnp.zeros((B, n_pad + 1), f32),
            jnp.broadcast_to(indeg0[None, :], (B, n_pad + 1)).astype(jnp.int32),
            jnp.ones((B, nd1), jnp.int32),  # everything starts on host
            jnp.full((B, n_u if use_cap else 1, nd1 if use_cap else 1), -1, jnp.int32),
            jnp.zeros((B, n_u), f32),
            jnp.full((B, nd1), -1, jnp.int32),
            jnp.zeros((B, R), f32),  # per-link-group free clock
            jnp.zeros((B,), f32),
            jnp.zeros((B,), f32),
            jnp.zeros((B,), jnp.int32),
        )
        carry, ys = jax.lax.scan(
            step, carry0, jnp.arange(n_steps, dtype=jnp.int32)
        )
        total_b, mk, npl = carry[-3], carry[-2], carry[-1]
        if emit:
            return mk, total_b, npl, ys
        return mk, total_b, npl

    return jax.jit(episode)


def run_episodes(
    plan: EpisodePlan,
    batch: EpisodeBatch,
    *,
    config=None,
    extra_steps: int = 0,
    pad_to: Optional[int] = None,
    emit_schedule: bool = False,
) -> Dict[str, np.ndarray]:
    """Run every configuration of ``batch`` through one compiled episode.

    Returns ``makespan`` / ``total_bytes`` / ``n_placed`` arrays aligned
    with the batch. ``extra_steps`` and ``pad_to`` (batch-axis padding)
    exist for the padding-invariance property suite: padded steps and
    padded batch rows are provably no-ops.

    ``emit_schedule`` additionally returns a ``"schedule"`` dict of
    (B, n_steps) arrays — per-step chosen task/resource and timeline in
    the audit schema (see :func:`episode_audit_logs`). It is part of the
    compile-cache key, so the default path's compiled episode is
    unchanged.
    """
    import jax
    import jax.numpy as jnp

    if config is None:
        from repro.sched.config import current_config

        config = current_config()

    B = len(batch)
    B_pad = pad_to if pad_to is not None else _bucket(B, lo=8)
    if B_pad < B:
        raise ValueError(f"pad_to={B_pad} smaller than batch ({B})")
    use_cap = bool(np.isfinite(batch.cap).any())
    use_pallas, interpret = _pallas_mode(config)
    n_steps = plan.n + int(extra_steps)

    def padb(a: np.ndarray, fill=0) -> np.ndarray:
        if B_pad == B:
            return a
        pad = np.full((B_pad - B,) + a.shape[1:], fill, dtype=a.dtype)
        return np.concatenate([a, pad], axis=0)

    shape_key = (
        B_pad, plan.n_pad, plan.r_pad, plan.w_pad, plan.s_pad,
        plan.n_res, plan.n_u, plan.n_data + 1, n_steps,
        use_cap, use_pallas, interpret, bool(emit_schedule),
    )
    fn = _EPISODE_CACHE.get(shape_key)
    if fn is None:
        fn = _EPISODE_CACHE[shape_key] = _build_episode_fn(shape_key)

    # the surrogate runs in f32: it reports *rankings* and relative error,
    # and halving the scan's state traffic is most of its speed advantage
    f32 = np.float32
    res = fn(
        jnp.asarray(plan.read_ids), jnp.asarray(plan.read_t, dtype=f32),
        jnp.asarray(plan.read_sz, dtype=f32), jnp.asarray(plan.write_ids),
        jnp.asarray(plan.write_sz, dtype=f32), jnp.asarray(plan.succ_ids),
        jnp.asarray(plan.indeg0), jnp.asarray(plan.prio, dtype=f32),
        jnp.asarray(plan.dur_cpu, dtype=f32),
        jnp.asarray(plan.dur_gpu, dtype=f32),
        jnp.asarray(plan.sizes, dtype=f32), jnp.asarray(plan.col_bits),
        jnp.asarray(plan.host_col),
        # padded batch rows: no valid resources -> every step inactive
        jnp.asarray(padb(batch.is_gpu)),
        jnp.asarray(padb(batch.valid_res)),
        jnp.asarray(padb(batch.mem_col)),
        jnp.asarray(padb(batch.link_grp)),
        jnp.asarray(padb(batch.alpha), dtype=f32),
        jnp.asarray(padb(batch.use_cp), dtype=f32),
        jnp.asarray(padb(batch.ws_pref)),
        jnp.asarray(padb(batch.noise, fill=1), dtype=f32),
        jnp.asarray(padb(batch.cap, fill=np.inf), dtype=f32),
        jnp.asarray(plan.bandwidth, dtype=f32),
    )
    mk, total_b, n_placed = res[0], res[1], res[2]
    out = {
        "makespan": np.asarray(mk)[:B].astype(np.float64),
        "total_bytes": np.asarray(total_b)[:B].astype(np.float64),
        "n_placed": np.asarray(n_placed)[:B],
    }
    if emit_schedule:
        # scan stacks along the step axis: (n_steps, B) -> (B, n_steps)
        names = ("tid", "rid", "act", "start", "xfer_t", "fin", "xfer_b",
                 "evict_b")
        out["schedule"] = {
            name: np.asarray(col)[:, :B].T for name, col in zip(names, res[3])
        }
    return out


def episode_audit_logs(graph, batch: EpisodeBatch, out: Dict[str, np.ndarray]):
    """Convert an ``emit_schedule`` run into per-configuration audit logs.

    Each batch row becomes one ``repro.verify.audit.AuditLog`` with
    ``engine="surrogate"``: per-step placements as exec records (start
    after the step's transfer time, end at the step's finish), demand
    transfers and capacity write-backs as hop records, and the episode's
    claimed makespan/total-bytes as the result footer — the same schema
    the exact engine emits, so ``repro.verify.verify_audit`` re-checks
    surrogate schedules with no engine-specific code.
    """
    from repro.verify.audit import AuditLog, graph_accesses

    sched = out["schedule"]
    accesses = graph_accesses(graph)
    n = len(accesses)
    n_res = batch.mem_col.shape[1]
    logs = []
    for b in range(len(batch)):
        log = AuditLog(engine="surrogate")
        log.machine = {
            "host_mem": 0,
            "resources": [
                {
                    "rid": r,
                    "mem": int(batch.mem_col[b, r]),
                    "valid": bool(batch.valid_res[b, r]),
                    "link": int(batch.link_grp[b, r]),
                }
                for r in range(n_res)
            ],
        }
        log.graphs[0] = {"submit_at": 0.0, "tasks": accesses}
        for k in range(sched["tid"].shape[1]):
            if not sched["act"][b, k]:
                continue
            tid = int(sched["tid"][b, k])
            if tid >= n:
                continue  # padded step ids never activate; defensive
            rid = int(sched["rid"][b, k])
            start = float(sched["start"][b, k])
            xt = float(sched["xfer_t"][b, k])
            xb = float(sched["xfer_b"][b, k])
            eb = float(sched["evict_b"][b, k])
            fin = float(sched["fin"][b, k])
            log.log_exec(0, tid, rid, int(batch.mem_col[b, rid]), start + xt, fin)
            grp = int(batch.link_grp[b, rid])
            if xb > 0:
                log.log_hop("copy", int(round(xb)), grp, start, start + xt)
            if eb > 0:
                log.log_hop("writeback", int(round(eb)), grp, start, fin)
        log.result = {
            "total_bytes": float(out["total_bytes"][b]),
            "n_transfers": None,
            "makespan": float(out["makespan"][b]),
            "per_graph": {
                0: {"finish": float(out["makespan"][b]), "submit_at": 0.0}
            },
        }
        logs.append(log)
    return logs
