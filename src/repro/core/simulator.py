"""The single-graph simulation facade over :class:`repro.runtime.Engine`.

Historically this module *was* the runtime — a 460-line monolith holding
the event loop, the worker queues, the transfer machinery, the metrics and
the steal protocol. Those layers now live in :mod:`repro.runtime`
(``events`` / ``queues`` / ``transfers`` / ``memory`` / ``engine`` /
``metrics``); :class:`Simulator` remains the stable single-graph surface:
construct with one graph, ``run()`` one :class:`SimResult`.

With capacity unbounded (the default) a ``Simulator`` run is bit-for-bit
identical to the pre-decomposition simulator — same event order, same
seeded stream consumption, same IEEE operation order — which is what the
equivalence suites against ``repro.core._reference`` enforce. Capacity
limits and eviction (``REPRO_SCHED_MEM_CAPACITY`` /
``REPRO_SCHED_EVICTION`` or the ``mem_capacity=`` / ``eviction=``
arguments) and stale-transfer cancellation (``REPRO_SCHED_CANCEL_STALE``)
are opt-in; multi-graph streaming is the engine's own surface
(``Engine.submit``).
"""
from __future__ import annotations

from typing import Optional

from repro.runtime.engine import Engine, GraphContext, Strategy
from repro.runtime.metrics import ScheduledInterval, SimResult

from .dag import TaskGraph
from .machine import MachineModel
from .perfmodel import TransferModel

__all__ = ["ScheduledInterval", "SimResult", "Simulator", "Strategy"]


class Simulator(Engine):
    """One task graph on one machine: the paper's simulation setup."""

    def __init__(
        self,
        graph: TaskGraph,
        machine: MachineModel,
        strategy: Strategy,
        seed: int = 0,
        noise: float = 0.03,
        transfer_model: Optional[TransferModel] = None,
        config=None,
        mem_capacity: Optional[int] = None,
        eviction: Optional[str] = None,
        cancel_stale: Optional[bool] = None,
        churn: Optional[float] = None,
        fault_mode: Optional[str] = None,
        fault_trace: Optional[str] = None,
        notice_s: Optional[float] = None,
        link_flake: Optional[float] = None,
        retry_max: Optional[int] = None,
        backoff_s: Optional[float] = None,
        audit: Optional[bool] = None,
    ) -> None:
        super().__init__(
            machine,
            strategy,
            seed=seed,
            noise=noise,
            transfer_model=transfer_model,
            config=config,
            mem_capacity=mem_capacity,
            eviction=eviction,
            cancel_stale=cancel_stale,
            churn=churn,
            fault_mode=fault_mode,
            fault_trace=fault_trace,
            notice_s=notice_s,
            link_flake=link_flake,
            retry_max=retry_max,
            backoff_s=backoff_s,
            audit=audit,
        )
        self._primary: GraphContext = self.submit(graph)
        # legacy aliases (instrumentation and benchmarks reset these
        # between measured placements)
        self._inflight = self._primary.inflight
        self._waiting = self._primary.waiting

    # ------------------------------------------------------------------
    def request_transfer(self, name: str, size: int, dst_mem: int):
        """Ensure a valid copy of ``name`` will exist at ``dst_mem``.

        Returns the completion time, or None if already resident.
        """
        return self.transfers.request(
            self._primary, name, size, dst_mem, self.now
        )

    # ------------------------------------------------------------------
    def run(self) -> SimResult:
        self._run_loop()
        m = self.metrics
        return SimResult(
            makespan=self.now,
            total_bytes=m.total_bytes,
            n_transfers=m.n_transfers,
            n_steals=m.n_steals,
            busy=dict(m.busy),
            intervals=m.intervals,
            strategy=self.strategy.name,
            total_flops=self._primary.graph.total_flops(),
            n_events=m.n_events,
            faults=(
                m.fault_summary()
                if (self._faults_on or self._flake_on)
                else None
            ),
        )
