"""Event-driven XKaapi-like runtime simulator.

Reproduces the paper's execution flow (§2.1-2.2):
  * each worker owns a local ready-queue (pop / push / steal),
  * completing a task triggers ``activate`` on its newly-ready successors —
    this is where the scheduling strategy runs,
  * idle workers emit steal requests to a randomly selected victim (enabled
    per strategy; HEFT/DADA place every ready task explicitly),
  * transfers to/from accelerator memories are prefetched when a task is
    pushed, overlap with computation, and contend on shared PCIe-switch
    links (FIFO per link group),
  * the runtime observes real (noisy) durations and feeds the history-based
    performance model, which therefore calibrates online (§2.3).

Determinism: all randomness flows through one seeded numpy Generator.
"""
from __future__ import annotations

import heapq
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .dag import Task, TaskGraph
from .machine import HOST_MEM, MachineModel, Resource
from .perfmodel import HistoryPerfModel, Residency, TransferModel


@dataclass
class ScheduledInterval:
    tid: int
    rid: int
    start: float
    end: float


@dataclass
class SimResult:
    makespan: float
    total_bytes: int
    n_transfers: int
    n_steals: int
    busy: Dict[int, float]
    intervals: List[ScheduledInterval]
    strategy: str
    total_flops: float

    @property
    def gflops(self) -> float:
        if self.makespan <= 0:
            return 0.0
        return self.total_flops / self.makespan / 1e9

    @property
    def gbytes(self) -> float:
        return self.total_bytes / 1e9


class Strategy:
    """Scheduling strategy interface: placement happens in ``activate``."""

    name = "base"
    allow_steal = False
    owner_lifo = False

    def init(self, sim: "Simulator") -> None:  # pragma: no cover - default
        pass

    def place(
        self, sim: "Simulator", ready: List[Task], src: Optional[int]
    ) -> None:  # pragma: no cover - interface
        raise NotImplementedError


class _Worker:
    __slots__ = ("rid", "queue", "running", "run_start", "blocked_on")

    def __init__(self, rid: int) -> None:
        self.rid = rid
        self.queue: deque = deque()
        self.running: Optional[Task] = None
        self.run_start: float = 0.0
        self.blocked_on: int = 0  # pending input transfers for head task


class Simulator:
    def __init__(
        self,
        graph: TaskGraph,
        machine: MachineModel,
        strategy: Strategy,
        seed: int = 0,
        noise: float = 0.03,
        transfer_model: Optional[TransferModel] = None,
    ) -> None:
        self.graph = graph
        self.machine = machine
        self.strategy = strategy
        self.rng = np.random.default_rng(seed)
        self.noise = noise
        self.model = HistoryPerfModel()
        self.transfer_model = transfer_model or TransferModel(
            bandwidth=machine.link.bandwidth, latency=machine.link.latency
        )
        self.residency = Residency()
        # all application data starts in host memory (paper setup)
        self.residency.initialize(graph.data_objects().keys(), HOST_MEM)

        self.now = 0.0
        self._events: List[Tuple[float, int, str, Any]] = []
        self._seq = 0
        self.workers = [_Worker(r.rid) for r in machine.resources]
        # shared predicted-completion time-stamps (paper §2.3)
        self.load_ts = [0.0] * len(self.workers)
        self._n_unfinished_preds = {
            t.tid: len(graph.pred[t.tid]) for t in graph.tasks
        }
        self._done = [False] * len(graph)
        self._start_times: Dict[int, float] = {}
        # transfers: (name, dst_mem) -> completion time (in flight)
        self._inflight: Dict[Tuple[str, int], float] = {}
        self._link_free: Dict[int, float] = {}
        self._waiting: Dict[Tuple[str, int], List[int]] = {}  # -> worker rids
        # metrics
        self.total_bytes = 0
        self.n_transfers = 0
        self.n_steals = 0
        self.busy = {r.rid: 0.0 for r in machine.resources}
        self.intervals: List[ScheduledInterval] = []
        self._n_done = 0

    # ------------------------------------------------------------------
    # event plumbing
    def _post(self, t: float, kind: str, payload: Any) -> None:
        self._seq += 1
        heapq.heappush(self._events, (t, self._seq, kind, payload))

    # ------------------------------------------------------------------
    # transfers
    def _gpu_link_group(self, mem: int) -> Optional[int]:
        for r in self.machine.resources:
            if r.mem == mem and r.is_accelerator:
                return r.link
        return None

    def _one_hop(self, nbytes: int, group: Optional[int], t: float) -> float:
        """Serialize the transfer on its link group (FIFO = shared bandwidth)."""
        start = max(t, self._link_free.get(group, 0.0)) if group is not None else t
        dur = self.machine.link.time(nbytes)
        done = start + dur
        if group is not None:
            self._link_free[group] = done
        self.total_bytes += nbytes
        self.n_transfers += 1
        return done

    def request_transfer(self, name: str, size: int, dst_mem: int) -> Optional[float]:
        """Ensure a valid copy of ``name`` will exist at ``dst_mem``.

        Returns the completion time, or None if already resident.
        """
        if self.residency.is_resident(name, dst_mem):
            return None
        key = (name, dst_mem)
        if key in self._inflight:
            return self._inflight[key]
        locs = self.residency.locations(name)
        if not locs:
            raise RuntimeError(f"no valid copy of {name} anywhere")
        t = self.now
        if HOST_MEM in locs and dst_mem != HOST_MEM:
            done = self._one_hop(size, self._gpu_link_group(dst_mem), t)
        elif dst_mem == HOST_MEM:
            src = next(iter(sorted(locs)))
            done = self._one_hop(size, self._gpu_link_group(src), t)
        else:
            # GPU -> host -> GPU (two hops, paper-era PCIe path)
            src = next(iter(sorted(locs)))
            host_key = (name, HOST_MEM)
            if host_key in self._inflight:
                mid = self._inflight[host_key]
            else:
                mid = self._one_hop(size, self._gpu_link_group(src), t)
                self._inflight[host_key] = mid
                self._post(mid, "xfer", (name, HOST_MEM))
            done = self._one_hop(size, self._gpu_link_group(dst_mem), mid)
        self._inflight[key] = done
        self._post(done, "xfer", (name, dst_mem))
        return done

    def _prefetch(self, task: Task, rid: int) -> None:
        mem = self.machine.by_id(rid).mem
        for d in task.reads:
            self.request_transfer(d.name, d.size_bytes, mem)

    # ------------------------------------------------------------------
    # queue operations (pop / push / steal)
    def push(self, task: Task, rid: int) -> None:
        """Push ``task`` onto worker ``rid``'s queue (any worker may push
        into any other worker's queue, §2.2)."""
        w = self.workers[rid]
        w.queue.append(task)
        self._prefetch(task, rid)
        self._try_start(w)

    def _steal(self, thief: _Worker) -> bool:
        # Eligible victims: a backlog of >=2, or >=1 while actually running.
        # (A lone task whose transfers are in flight is not stolen — the
        # copy is already on its way to the victim's memory.)
        victims = [
            w
            for w in self.workers
            if w.rid != thief.rid
            and (len(w.queue) >= 2 or (len(w.queue) >= 1 and w.running is not None))
        ]
        if not victims:
            return False
        v = victims[int(self.rng.integers(len(victims)))]
        task = v.queue.popleft()  # thief takes the oldest task
        self.n_steals += 1
        thief.queue.append(task)
        self._prefetch(task, thief.rid)
        return True

    # ------------------------------------------------------------------
    def _true_duration(self, task: Task, res: Resource) -> float:
        base = res.cls.exec_time(task.kind, task.flops)
        if self.noise > 0:
            base *= float(np.exp(self.rng.normal(0.0, self.noise)))
        return base

    def _try_start(self, w: _Worker) -> None:
        if w.running is not None or not w.queue:
            return
        res = self.machine.by_id(w.rid)
        task = w.queue[0] if not self.strategy.owner_lifo else w.queue[-1]
        # make sure inputs are (going to be) resident
        missing = 0
        for d in task.reads:
            if not self.residency.is_resident(d.name, res.mem):
                self.request_transfer(d.name, d.size_bytes, res.mem)
                key = (d.name, res.mem)
                self._waiting.setdefault(key, []).append(w.rid)
                missing += 1
        if missing:
            w.blocked_on = missing
            return
        # pop + execute
        if self.strategy.owner_lifo:
            w.queue.pop()
        else:
            w.queue.popleft()
        w.blocked_on = 0
        dur = self._true_duration(task, res)
        w.running = task
        w.run_start = self.now
        self._post(self.now + dur, "done", (w.rid, task.tid, dur))

    # ------------------------------------------------------------------
    def _complete(self, rid: int, tid: int, dur: float) -> None:
        w = self.workers[rid]
        res = self.machine.by_id(rid)
        task = self.graph.tasks[tid]
        assert w.running is task
        w.running = None
        self._done[tid] = True
        self._n_done += 1
        self.busy[rid] += dur
        self.intervals.append(ScheduledInterval(tid, rid, w.run_start, self.now))
        self.model.observe(task, res.cls, dur)
        for d in task.writes:
            self.residency.write(d.name, res.mem)
            # invalidate any stale dedup entries for this data
            for key in [k for k in self._inflight if k[0] == d.name]:
                del self._inflight[key]
        # load time-stamp correction (§2.3: runtime corrects predictions)
        if not w.queue:
            self.load_ts[rid] = self.now

        newly_ready: List[Task] = []
        for s in self.graph.succ[tid]:
            self._n_unfinished_preds[s] -= 1
            if self._n_unfinished_preds[s] == 0:
                newly_ready.append(self.graph.tasks[s])
        if newly_ready:
            # the *activate* operation — where scheduling decisions happen
            self.strategy.place(self, newly_ready, rid)
        self._try_start(w)
        self._steal_round()

    def _steal_round(self) -> None:
        if not self.strategy.allow_steal:
            return
        progress = True
        while progress:
            progress = False
            for w in self.workers:
                if w.running is None and not w.queue:
                    if self._steal(w):
                        self._try_start(w)
                        progress = True

    # ------------------------------------------------------------------
    def run(self) -> SimResult:
        self.strategy.init(self)
        roots = self.graph.roots()
        if roots:
            self.strategy.place(self, roots, None)
        self._steal_round()
        while self._events:
            t, _, kind, payload = heapq.heappop(self._events)
            self.now = t
            if kind == "done":
                rid, tid, dur = payload
                self._complete(rid, tid, dur)
            elif kind == "xfer":
                name, mem = payload
                self._inflight.pop((name, mem), None)
                self.residency.add_copy(name, mem)
                for rid in self._waiting.pop((name, mem), []):
                    w = self.workers[rid]
                    if w.blocked_on > 0:
                        w.blocked_on -= 1
                        if w.blocked_on == 0:
                            self._try_start(w)
                self._steal_round()
        if self._n_done != len(self.graph):
            missing = [t.tid for t in self.graph.tasks if not self._done[t.tid]]
            raise RuntimeError(
                f"simulation stalled: {len(missing)} tasks unfinished, e.g. {missing[:5]}"
            )
        return SimResult(
            makespan=self.now,
            total_bytes=self.total_bytes,
            n_transfers=self.n_transfers,
            n_steals=self.n_steals,
            busy=dict(self.busy),
            intervals=self.intervals,
            strategy=self.strategy.name,
            total_flops=self.graph.total_flops(),
        )
