"""Event-driven XKaapi-like runtime simulator.

Reproduces the paper's execution flow (§2.1-2.2):
  * each worker owns a local ready-queue (pop / push / steal),
  * completing a task triggers ``activate`` on its newly-ready successors —
    this is where the scheduling strategy runs,
  * idle workers emit steal requests to a randomly selected victim (enabled
    per strategy; HEFT/DADA place every ready task explicitly),
  * transfers to/from accelerator memories are prefetched when a task is
    pushed, overlap with computation, and contend on shared PCIe-switch
    links (FIFO per link group),
  * the runtime observes real (noisy) durations and feeds the history-based
    performance model, which therefore calibrates online (§2.3).

Determinism: all randomness flows through one seeded numpy Generator.

Hot paths run against the graph's structure-of-arrays view
(``TaskGraph.arrays()``): per-task read/write lists are prebuilt instead of
re-deriving tuples from ``Task.accesses``, residency tests are bitmask
ops, in-flight transfers are indexed per data name (write invalidation is
O(copies) instead of O(all in-flight keys)), and strategies get cached
per-class vectorized predictions via :meth:`Simulator.predictor`.
"""
from __future__ import annotations

import heapq
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .dag import GraphArrays, Task, TaskGraph
from .machine import HOST_MEM, MachineModel, ResourceClass
from .perfmodel import ClassPredictor, HistoryPerfModel, Residency, TransferModel


@dataclass(slots=True)
class ScheduledInterval:
    tid: int
    rid: int
    start: float
    end: float


@dataclass
class SimResult:
    makespan: float
    total_bytes: int
    n_transfers: int
    n_steals: int
    busy: Dict[int, float]
    intervals: List[ScheduledInterval]
    strategy: str
    total_flops: float
    n_events: int = 0

    @property
    def gflops(self) -> float:
        if self.makespan <= 0:
            return 0.0
        return self.total_flops / self.makespan / 1e9

    @property
    def gbytes(self) -> float:
        return self.total_bytes / 1e9


class Strategy:
    """Scheduling strategy interface: placement happens in ``activate``."""

    name = "base"
    allow_steal = False
    owner_lifo = False

    def init(self, sim: "Simulator") -> None:  # pragma: no cover - default
        pass

    def place(
        self, sim: "Simulator", ready: List[Task], src: Optional[int]
    ) -> None:  # pragma: no cover - interface
        raise NotImplementedError


class _Worker:
    __slots__ = ("rid", "queue", "running", "run_start", "blocked_on")

    def __init__(self, rid: int) -> None:
        self.rid = rid
        self.queue: deque = deque()
        self.running: Optional[Task] = None
        self.run_start: float = 0.0
        self.blocked_on: int = 0  # pending input transfers for head task


class Simulator:
    def __init__(
        self,
        graph: TaskGraph,
        machine: MachineModel,
        strategy: Strategy,
        seed: int = 0,
        noise: float = 0.03,
        transfer_model: Optional[TransferModel] = None,
        config=None,
    ) -> None:
        self.graph = graph
        self.arrays: GraphArrays = graph.arrays()
        self.machine = machine
        self.strategy = strategy
        # the typed scheduling configuration (repro.sched.SchedConfig);
        # resolved lazily from the environment when not supplied, so
        # strategies and instrumentation read sim.config instead of
        # scattering os.environ lookups through hot paths
        self._config = config
        self.rng = np.random.default_rng(seed)
        self.noise = noise
        # One multiplicative noise factor per task (each task executes
        # exactly once), drawn as a single batched normal at startup.
        # NOTE: this consumes the seeded stream in tid order rather than
        # execution order (the pre-vectorization simulator drew per task at
        # start time), so seeded results differ numerically from pre-PR-1
        # runs — a deliberate trade recorded in CHANGES.md. Equivalence
        # guarantees are against repro.core._reference under THIS stream.
        if noise > 0 and len(graph) > 0:
            self._noise_mult = np.exp(
                self.rng.normal(0.0, noise, size=len(graph))
            ).tolist()
        else:
            self._noise_mult = None
        self.model = HistoryPerfModel()
        self.transfer_model = transfer_model or TransferModel(
            bandwidth=machine.link.bandwidth, latency=machine.link.latency
        )
        self.residency = Residency()
        self.residency.attach(self.arrays)
        # all application data starts in host memory (paper setup)
        self.residency.initialize(self.arrays.data_names, HOST_MEM)

        self.now = 0.0
        self._events: List[Tuple[float, int, str, Any]] = []
        self._seq = 0
        self.workers = [_Worker(r.rid) for r in machine.resources]
        # shared predicted-completion time-stamps (paper §2.3)
        self.load_ts = [0.0] * len(self.workers)
        self._n_unfinished_preds = [
            len(graph.pred[t.tid]) for t in graph.tasks
        ]
        self._succ = [graph.succ[t.tid] for t in graph.tasks]
        self._done = [False] * len(graph)
        self._start_times: Dict[int, float] = {}
        # in-flight transfers indexed per data name: name -> {dst_mem: done_t}
        self._inflight: Dict[str, Dict[int, float]] = {}
        self._link_free: Dict[int, float] = {}
        self._waiting: Dict[Tuple[str, int], List[int]] = {}  # -> worker rids
        # accelerator memory -> link group (first resource on that memory)
        self._mem_link: Dict[int, Optional[int]] = {}
        for r in machine.resources:
            if r.is_accelerator:
                self._mem_link.setdefault(r.mem, r.link)
        # inlined link timing (hot path); only valid for a plain LinkModel
        from .machine import LinkModel as _LM

        self._plain_link = type(machine.link) is _LM
        self._link_lat = machine.link.latency
        self._link_bw = machine.link.bandwidth
        # per-rid memory space / resource class (avoids by_id() in hot paths)
        self._mem_of = [r.mem for r in machine.resources]
        self._bit_of = [1 << (r.mem + 1) for r in machine.resources]
        self._steal_on = strategy.allow_steal
        self._lifo = strategy.owner_lifo
        # per-resource-class vectorized predictors (lazy)
        self._predictors: Dict[str, ClassPredictor] = {}
        # per-rid ground-truth static durations (flops/rate, 1e-7 floor)
        self._rid_static = [
            self.predictor(r.cls).static_list for r in machine.resources
        ]
        # metrics
        self.total_bytes = 0
        self.n_transfers = 0
        self.n_steals = 0
        self.n_events = 0
        self.busy = {r.rid: 0.0 for r in machine.resources}
        self.intervals: List[ScheduledInterval] = []
        self._n_done = 0

    # ------------------------------------------------------------------
    @property
    def config(self):
        """The active ``repro.sched.SchedConfig`` for this simulation."""
        if self._config is None:
            from repro.sched.config import current_config

            self._config = current_config()
        return self._config

    # ------------------------------------------------------------------
    def predictor(self, cls: ResourceClass) -> ClassPredictor:
        """Cached vectorized HistoryPerfModel.predict for ``cls``."""
        p = self._predictors.get(cls.name)
        if p is None:
            p = ClassPredictor(self.model, cls, self.arrays)
            self._predictors[cls.name] = p
        return p

    # ------------------------------------------------------------------
    # event plumbing
    def _post(self, t: float, kind: str, payload: Any) -> None:
        self._seq += 1
        heapq.heappush(self._events, (t, self._seq, kind, payload))

    # ------------------------------------------------------------------
    # transfers
    def _gpu_link_group(self, mem: int) -> Optional[int]:
        return self._mem_link.get(mem)

    def _one_hop(self, nbytes: int, group: Optional[int], t: float) -> float:
        """Serialize the transfer on its link group (FIFO = shared bandwidth)."""
        start = max(t, self._link_free.get(group, 0.0)) if group is not None else t
        if self._plain_link:
            dur = 0.0 if nbytes <= 0 else self._link_lat + nbytes / self._link_bw
        else:
            dur = self.machine.link.time(nbytes)
        done = start + dur
        if group is not None:
            self._link_free[group] = done
        self.total_bytes += nbytes
        self.n_transfers += 1
        return done

    def request_transfer(self, name: str, size: int, dst_mem: int) -> Optional[float]:
        """Ensure a valid copy of ``name`` will exist at ``dst_mem``.

        Returns the completion time, or None if already resident.
        """
        mask = self.residency._mask.get(name, 0)
        if mask & (1 << (dst_mem + 1)):
            return None  # already resident
        flights = self._inflight.get(name)
        if flights is not None:
            done = flights.get(dst_mem)
            if done is not None:
                return done
        if mask == 0:
            raise RuntimeError(f"no valid copy of {name} anywhere")
        t = self.now
        mem_link = self._mem_link
        if (mask & 1) and dst_mem != HOST_MEM:
            # a host copy exists: single host->device hop
            done = self._one_hop(size, mem_link.get(dst_mem), t)
        elif dst_mem == HOST_MEM:
            src = (mask & -mask).bit_length() - 2  # lowest-numbered location
            done = self._one_hop(size, mem_link.get(src), t)
        else:
            # GPU -> host -> GPU (two hops, paper-era PCIe path)
            src = (mask & -mask).bit_length() - 2
            if flights is not None and HOST_MEM in flights:
                mid = flights[HOST_MEM]
            else:
                mid = self._one_hop(size, mem_link.get(src), t)
                if flights is None:
                    flights = self._inflight[name] = {}
                flights[HOST_MEM] = mid
                self._post(mid, "xfer", (name, HOST_MEM))
            done = self._one_hop(size, mem_link.get(dst_mem), mid)
        if flights is None:
            flights = self._inflight[name] = {}
        flights[dst_mem] = done
        self._post(done, "xfer", (name, dst_mem))
        return done

    def _prefetch(self, task: Task, rid: int) -> None:
        mem = self._mem_of[rid]
        bit = self._bit_of[rid]
        mask_list = self.residency.mask_list
        inflight = self._inflight
        for did, name, size in self.arrays.task_reads[task.tid]:
            if not mask_list[did] & bit:
                fl = inflight.get(name)
                if fl is None or mem not in fl:
                    self.request_transfer(name, size, mem)

    # ------------------------------------------------------------------
    # queue operations (pop / push / steal)
    def push(self, task: Task, rid: int) -> None:
        """Push ``task`` onto worker ``rid``'s queue (any worker may push
        into any other worker's queue, §2.2)."""
        w = self.workers[rid]
        w.queue.append(task)
        self._prefetch(task, rid)
        self._try_start(w)

    def _steal(self, thief: _Worker) -> bool:
        # Eligible victims: a backlog of >=2, or >=1 while actually running.
        # (A lone task whose transfers are in flight is not stolen — the
        # copy is already on its way to the victim's memory.)
        victims = [
            w
            for w in self.workers
            if w.rid != thief.rid
            and (len(w.queue) >= 2 or (len(w.queue) >= 1 and w.running is not None))
        ]
        if not victims:
            return False
        v = victims[int(self.rng.integers(len(victims)))]
        task = v.queue.popleft()  # thief takes the oldest task
        self.n_steals += 1
        thief.queue.append(task)
        self._prefetch(task, thief.rid)
        return True

    # ------------------------------------------------------------------
    def _try_start(self, w: _Worker) -> None:
        if w.running is not None or not w.queue:
            return
        rid = w.rid
        task = w.queue[-1] if self._lifo else w.queue[0]
        # make sure inputs are (going to be) resident
        mem = self._mem_of[rid]
        bit = self._bit_of[rid]
        mask_list = self.residency.mask_list
        inflight = self._inflight
        missing = 0
        for did, name, size in self.arrays.task_reads[task.tid]:
            if not mask_list[did] & bit:
                fl = inflight.get(name)
                if fl is None or mem not in fl:
                    self.request_transfer(name, size, mem)
                self._waiting.setdefault((name, mem), []).append(rid)
                missing += 1
        if missing:
            w.blocked_on = missing
            return
        # pop + execute
        if self._lifo:
            w.queue.pop()
        else:
            w.queue.popleft()
        w.blocked_on = 0
        tid = task.tid
        # ground-truth duration: per-rid static flops/rate (the predictor's
        # cached vector, identical to cls.exec_time incl. the 1e-7 floor)
        # times the task's seeded noise factor
        dur = self._rid_static[rid][tid]
        if self._noise_mult is not None:
            dur *= self._noise_mult[tid]
        w.running = task
        w.run_start = self.now
        self._seq += 1
        heapq.heappush(self._events, (self.now + dur, self._seq, "done", (rid, tid, dur)))

    # ------------------------------------------------------------------
    def _complete(self, rid: int, tid: int, dur: float) -> None:
        w = self.workers[rid]
        res = self.machine.resources[rid]
        task = self.graph.tasks[tid]
        w.running = None
        self._done[tid] = True
        self._n_done += 1
        self.busy[rid] += dur
        self.intervals.append(ScheduledInterval(tid, rid, w.run_start, self.now))
        self.model.observe(task, res.cls, dur)
        bit = self._bit_of[rid]
        write_id = self.residency.write_id
        inflight_pop = self._inflight.pop
        for did, name, size in self.arrays.task_writes[tid]:
            write_id(did, name, bit)
            # invalidate any stale dedup entries for this data (O(1): the
            # in-flight table is indexed per data name)
            inflight_pop(name, None)
        # load time-stamp correction (§2.3: runtime corrects predictions)
        if not w.queue:
            self.load_ts[rid] = self.now

        newly_ready: List[Task] = []
        preds = self._n_unfinished_preds
        tasks = self.graph.tasks
        for s in self._succ[tid]:
            preds[s] -= 1
            if preds[s] == 0:
                newly_ready.append(tasks[s])
        if newly_ready:
            # the *activate* operation — where scheduling decisions happen
            self.strategy.place(self, newly_ready, rid)
        self._try_start(w)
        if self._steal_on:
            self._steal_round()

    def _steal_round(self) -> None:
        if not self.strategy.allow_steal:
            return
        progress = True
        while progress:
            progress = False
            for w in self.workers:
                if w.running is None and not w.queue:
                    if self._steal(w):
                        self._try_start(w)
                        progress = True

    # ------------------------------------------------------------------
    def run(self) -> SimResult:
        self.strategy.init(self)
        roots = self.graph.roots()
        if roots:
            self.strategy.place(self, roots, None)
        self._steal_round()
        events = self._events
        heappop = heapq.heappop
        inflight = self._inflight
        add_copy = self.residency.add_copy
        waiting = self._waiting
        workers = self.workers
        steal_on = self.strategy.allow_steal
        n_events = 0
        while events:
            t, _, kind, payload = heappop(events)
            self.now = t
            n_events += 1
            if kind == "xfer":
                name, mem = payload
                flights = inflight.get(name)
                if flights is not None:
                    flights.pop(mem, None)
                    if not flights:
                        del inflight[name]
                # NOTE (pre-existing modeling artifact, preserved for
                # equivalence): a transfer that was in flight when its data
                # was overwritten still lands as a "valid" copy here — the
                # simulated runtime does not cancel stale transfers.
                add_copy(name, mem)
                waiters = waiting.pop((name, mem), None)
                if waiters:
                    for rid in waiters:
                        w = workers[rid]
                        if w.blocked_on > 0:
                            w.blocked_on -= 1
                            if w.blocked_on == 0:
                                self._try_start(w)
                if steal_on:
                    self._steal_round()
            else:  # "done"
                rid, tid, dur = payload
                self._complete(rid, tid, dur)
        self.n_events = n_events
        if self._n_done != len(self.graph):
            missing = [t.tid for t in self.graph.tasks if not self._done[t.tid]]
            raise RuntimeError(
                f"simulation stalled: {len(missing)} tasks unfinished, e.g. {missing[:5]}"
            )
        return SimResult(
            makespan=self.now,
            total_bytes=self.total_bytes,
            n_transfers=self.n_transfers,
            n_steals=self.n_steals,
            busy=dict(self.busy),
            intervals=self.intervals,
            strategy=self.strategy.name,
            total_flops=self.graph.total_flops(),
            n_events=self.n_events,
        )
