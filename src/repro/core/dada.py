"""DADA — Distributed Affinity Dual Approximation (paper §3.2, Algorithm 2).

Binary search on a makespan guess ``λ``; for each guess:

  * **local affinity phase** — ready tasks are placed on their max-affinity
    processor (affinity = bytes the task writes that are resident there),
    loading each processor up to *overreaching* ``α·λ``;
  * **global balance phase** — a ρ=2 dual approximation on the rest: tasks
    that only fit one class are dedicated; flexible tasks go to GPUs by
    decreasing speedup until the GPU loads overreach ``λ``; the remainder
    goes to CPUs with an earliest-finish-time rule;
  * the guess is accepted iff every processor's load fits ``(2+α)·λ``.

``α = 0`` disables the affinity phase: DADA(0) is the plain dual
approximation. ``use_cp=True`` (the paper's "+CP") adds communication
prediction (asymptotic-bandwidth model) to every load/finish-time estimate.

Array-native: everything λ-independent is batched once per activation —
per-class duration vectors from the cached vector predictor, the
(ready × resources) transfer matrix from the CSR read incidence +
residency bitmasks, the affinity score matrix, the speedup sort keys and
the full cost matrix ``C = p + xfer``. Each λ-probe of ``try_build`` then
runs over plain float rows with no model calls at all, which is what makes
the ~30-probe binary search cheap. Decisions (including tie-breaks) are
bit-identical to ``repro.core._reference.ReferenceDADA``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from .affinity import AFFINITY_FUNCTIONS, AffinityFn, affinity_rows
from .backend import ScoringBackendMixin
from .dag import Task
from .simulator import Simulator, Strategy

_TINY = 1e-12
_WIDE = 32  # ready-set size from which the batched numpy path wins


class DADA(ScoringBackendMixin, Strategy):
    allow_steal = False
    owner_lifo = False

    def __init__(
        self,
        alpha: float = 0.5,
        use_cp: bool = False,
        affinity: str = "accel_write",
        eps_rel: float = 0.01,
        max_iters: int = 30,
        area_bound: bool = False,
        recover: bool = False,
        backend: Optional[str] = None,
        config=None,
    ) -> None:
        """``area_bound``: also reject a guess λ when the total work area
        exceeds λ x (number of resources) — a valid no-schedule certificate
        that keeps λ (and hence the affinity budget α·λ) near the true
        optimum instead of descending to OPT/(2+α). Off by default (the
        paper's Algorithm 2 rejects only on the big-task criterion); the
        expert-placement bridge turns it on.

        ``recover``: notice-aware placement (``resolve("dada?recover=1")``).
        A preemption-noticed resource (detach announced, not yet fired —
        see ``repro.runtime.faults``) has its cost column charged the
        remaining notice window and is skipped by the affinity phase, so
        new work and fresh affinity steer off a condemned device *before*
        it dies instead of being requeued off it afterwards. Off by
        default; with no pending notice the recover path is untouched, so
        ``recover=True`` is bit-identical to ``recover=False`` outside
        notice windows.

        ``backend``: placement-scoring backend (``numpy``/``jax``); default
        follows the scheduling configuration (``config`` or the
        environment-derived ``repro.sched.SchedConfig``). The jax backend
        batches the score matrices and the λ-probe search on wide
        activations; placements are bit-identical either way (see
        ``repro.core.backend``)."""
        if not 0.0 <= alpha <= 1.0:
            raise ValueError("alpha must be within [0, 1]")
        self.alpha = alpha
        self.use_cp = use_cp
        self.affinity_name = affinity
        self.affinity_fn: AffinityFn = AFFINITY_FUNCTIONS[affinity]
        self.eps_rel = eps_rel
        self.max_iters = max_iters
        self.area_bound = area_bound
        self.recover = recover
        self._init_backend(backend, config)
        cp = "+cp" if use_cp else ""
        rec = "+rec" if recover else ""
        self.name = f"dada({alpha:g}){cp}{rec}"

    # ------------------------------------------------------------------
    def place(self, sim: Simulator, ready: List[Task], src: Optional[int]) -> None:
        machine = sim.machine
        resources = machine.resources
        cpus = machine.cpus
        gpus = machine.gpus
        cpu_cls = cpus[0].cls if cpus else gpus[0].cls
        gpu_cls = gpus[0].cls if gpus else cpu_cls
        n_res = len(resources)
        n = len(ready)
        tids = [t.tid for t in ready]

        # --- λ-independent precomputation (batched for wide activations,
        # --- scalar over the same arrays for narrow ones) ----------------
        if n >= _WIDE:
            tids_arr = np.asarray(tids, dtype=np.int64)
            p_cpu = sim.predictor(cpu_cls).times(tids_arr).tolist()
            p_gpu = sim.predictor(gpu_cls).times(tids_arr).tolist()
        else:
            p_cpu = sim.predictor(cpu_cls).times_list(tids)
            p_gpu = sim.predictor(gpu_cls).times_list(tids)

        # memory-pressure penalty under +CP (capacity-bounded memories):
        # predicted eviction seconds folded into the transfer matrix on
        # the numpy and jax scoring paths alike. fault_mask=False: DADA
        # handles detached resources by filtering its placement pools
        # below — an +inf fold would blow up `upper` (the λ search's
        # feasibility anchor) and every probe's load updates
        from repro.runtime.memory import fold_pressure, pressure_rows_for

        P = (
            pressure_rows_for(sim, tids, resources, fault_mask=False)
            if self.use_cp
            else None
        )

        # detached resources (repro.runtime.faults): excluded from every
        # placement pool and load update; with no resource detached the
        # sets below are unchanged and the fused path stays available
        faults = getattr(sim, "faults", None)
        dead = (
            faults.dead_rids
            if faults is not None and faults.any_dead
            else frozenset()
        )

        # notice-aware recovery (recover=True only): a condemned column
        # pays the remaining notice window, by resource position — the
        # same finite decaying signal pressure_rows_for feeds score-matrix
        # policies, folded into C below so every phase of the λ search
        # steers off a dying device. Empty whenever no notice is pending,
        # keeping recover=True bit-identical outside notice windows.
        noticed_pen: Dict[int, float] = {}
        if self.recover and faults is not None and faults.noticed:
            for j, r in enumerate(resources):
                pending = faults.noticed.get(r.rid)
                if pending is not None:
                    p = pending[1] - sim.now
                    if p > 0.0:
                        noticed_pen[j] = p

        # accelerated fused scoring (wide activations, jax backend): C, X
        # and the affinity matrix come out of one jitted dispatch, bit-equal
        # to the numpy formulas below (skipped under active faults or
        # pending notices — the backend kernels do not model liveness)
        be = self._scoring_backend()
        fused = None
        if be is not None and n >= be.min_wide and not dead and not noticed_pen:
            fused = be.score_matrices(
                sim, tids, resources,
                p_cpu=p_cpu, p_gpu=p_gpu,
                use_cp=self.use_cp,
                affinity=self.affinity_name if self.alpha > 0.0 else None,
                x_bias=P,
            )
        use_backend_search = fused is not None

        if fused is not None:
            X = None  # worst-case transfer bound: fused["X_rowmax"] below
            C_rows = fused["C"]
        elif self.use_cp:
            X = fold_pressure(
                sim.transfer_model.task_input_transfer_rows(
                    sim.arrays, tids, [r.mem for r in resources], sim.residency
                ),
                P,
            )
        else:
            X = None

        # cost matrix C[i][rid] = duration-on-class + predicted transfer
        if fused is None:
            gpu_pos = [j for j, r in enumerate(resources) if r.is_accelerator]
            if X is None:
                C_rows = []
                for pc, pg in zip(p_cpu, p_gpu):
                    row = [pc] * n_res
                    for j in gpu_pos:
                        row[j] = pg
                    C_rows.append(row)
            else:
                C_rows = []
                for pc, pg, xrow in zip(p_cpu, p_gpu, X):
                    row = [pc + x for x in xrow]
                    for j in gpu_pos:
                        row[j] = pg + xrow[j]
                    C_rows.append(row)
        if noticed_pen:
            # condemned columns pay the remaining notice window (the fused
            # path is disabled above, so C_rows is always the list form)
            for row in C_rows:
                for j, p in noticed_pen.items():
                    row[j] += p
        offsets = [
            lt - sim.now if lt - sim.now > 0.0 else 0.0
            for lt in (sim.load_ts[r.rid] for r in resources)
        ]
        if dead:
            # dead resources receive no load and contribute no backlog
            # (their stale load_ts must not gate the λ feasibility test)
            for j, r in enumerate(resources):
                if r.rid in dead:
                    offsets[j] = 0.0

        # affinity preferences per task, with the placement cost prefetched
        pref: List[Tuple[float, int, int, float]] = []  # (score, tid, rid, cost)
        S_np = fused["S_np"] if fused is not None else None
        if self.alpha > 0.0 and S_np is not None:
            # vectorized best-resource selection: one pass per resource
            # column reproduces the scalar rid-ascending tolerance scan
            # row-by-row, and the (-score, tid) lexsort matches sorted()
            # because tids are unique
            best = np.zeros(n, dtype=np.float64)
            best_rid = np.full(n, -1, dtype=np.int64)
            for rid in range(n_res):
                col = S_np[:, rid]
                upd = col > best + _TINY
                if upd.any():
                    best[upd] = col[upd]
                    best_rid[upd] = rid
            sel = np.nonzero(best_rid >= 0)[0]
            if len(sel):
                scores = best[sel]
                prids = best_rid[sel]
                ptids = np.asarray(tids, dtype=np.int64)[sel]
                pcosts = fused["C_np"][sel, prids]
                order_p = np.lexsort((ptids, -scores))
                by_score = list(
                    zip(
                        scores[order_p].tolist(),
                        ptids[order_p].tolist(),
                        prids[order_p].tolist(),
                        pcosts[order_p].tolist(),
                    )
                )
            else:
                by_score = []
        else:
            if self.alpha > 0.0:
                S_rows = affinity_rows(
                    self.affinity_name, sim.arrays, tids, ready, resources,
                    sim.residency,
                )
                for i, row in enumerate(S_rows):
                    if not any(row):
                        continue  # all-zero (C-level falsy) row: no preference
                    best_score, best_rid = 0.0, -1
                    for rid in range(n_res):
                        if rid in dead:
                            continue  # affinity to a vanished memory is void
                        if rid in noticed_pen:
                            # affinity to a condemned memory is a trap:
                            # the data is leaving with the device
                            continue
                        s = row[rid]
                        if s > best_score + _TINY:
                            best_score, best_rid = s, rid
                    if best_rid >= 0:
                        pref.append(
                            (best_score, tids[i], best_rid, C_rows[i][best_rid])
                        )
            by_score = sorted(pref, key=lambda x: (-x[0], x[1]))

        # speedup sort keys for the flexible phase (λ-independent)
        skey = [-(pc / max(pg, _TINY)) for pc, pg in zip(p_cpu, p_gpu)]

        cpu_rids = [r.rid for r in cpus if r.rid not in dead]
        gpu_rids = [r.rid for r in gpus if r.rid not in dead]
        any_rids = cpu_rids or gpu_rids
        if not any_rids:
            raise RuntimeError("DADA: every resource is detached")
        have_both = bool(cpu_rids and gpu_rids)
        no_cpus = not cpu_rids
        no_gpus = not gpu_rids

        if self.area_bound:
            area = sum(min(pc, pg) for pc, pg in zip(p_cpu, p_gpu))
            off_total = sum(offsets)

        all_idx = list(range(n))
        # global flex order (λ-independent): per-probe flex sets are subsets
        # of ready, so filtering this order equals sorting each subset.
        # (skey, tid) keys are unique per task (tids are unique), so the
        # wide-activation lexsort yields the identical permutation.
        if n >= _WIDE:
            flex_order = np.lexsort(
                (np.asarray(tids, dtype=np.int64), np.asarray(skey))
            ).tolist()
        else:
            flex_order = sorted(all_idx, key=lambda i: (skey[i], tids[i]))
        alpha = self.alpha
        two_alpha = 2.0 + alpha
        area_bound = self.area_bound
        max_off = max(offsets, default=0.0)
        n_res_alive = n_res - len(dead)

        # ------------------------------------------------------------------
        def try_build(lam: float) -> Optional[Tuple[Dict[int, int], List[float]]]:
            # try_build is pure (touches only its locals), so the acceptance
            # test `all(load <= (2+α)λ)` is folded into every load update:
            # loads only grow, hence the first overflow already decides the
            # probe — same verdict as building fully, minus the wasted work.
            cap = two_alpha * lam + _TINY
            if max_off > cap:
                return None
            if area_bound:
                capacity = lam * n_res_alive - off_total
                if area > capacity + _TINY:
                    return None  # certificate: no λ-schedule exists
            loads = offsets.copy()
            assign: Dict[int, int] = {}

            # ---- local affinity phase (line 5-7) -------------------------
            if by_score:
                budget = alpha * lam + _TINY
                for sc, tid, rid, c in by_score:
                    if loads[rid] <= budget:
                        assign[tid] = rid
                        v = loads[rid] + c
                        if v > cap:
                            return None
                        loads[rid] = v

            # ---- global balance phase (line 8-9) -------------------------
            if assign:
                rem = [i for i in all_idx if tids[i] not in assign]
            else:
                rem = all_idx
            for i in rem:  # reject if a task is larger than λ everywhere
                big_cpu = no_cpus or p_cpu[i] > lam
                big_gpu = no_gpus or p_gpu[i] > lam
                if big_cpu and big_gpu:
                    return None

            flex = None
            if have_both:
                flex = bytearray(n)
                for i in rem:
                    if p_cpu[i] > lam:
                        pool_rids = gpu_rids  # dedicated to GPUs
                    elif p_gpu[i] > lam:
                        pool_rids = cpu_rids  # dedicated to CPUs
                    else:
                        flex[i] = 1
                        continue
                    # earliest finish time; first minimum wins (== min by
                    # (finish, rid): pool rids are ascending)
                    crow = C_rows[i]
                    best_v = float("inf")
                    best_rid = pool_rids[0]
                    for rid in pool_rids:
                        v = loads[rid] + crow[rid]
                        if v < best_v:
                            best_v = v
                            best_rid = rid
                    if best_v > cap:
                        return None
                    assign[tids[i]] = best_rid
                    loads[best_rid] = best_v
            else:
                for i in rem:
                    crow = C_rows[i]
                    best_v = float("inf")
                    best_rid = any_rids[0]
                    for rid in any_rids:
                        v = loads[rid] + crow[rid]
                        if v < best_v:
                            best_v = v
                            best_rid = rid
                    if best_v > cap:
                        return None
                    assign[tids[i]] = best_rid
                    loads[best_rid] = best_v

            # flexible tasks: largest speedup first, to GPUs up to
            # overreaching λ, the rest to CPUs (earliest finish time)
            if flex is not None:
                gpu_budget = lam + _TINY
                for i in flex_order:
                    if not flex[i]:
                        continue
                    if gpu_rids:
                        g = gpu_rids[0]
                        gl = loads[g]
                        for rid in gpu_rids[1:]:
                            if loads[rid] < gl:
                                gl = loads[rid]
                                g = rid
                        if gl <= gpu_budget:
                            v = gl + C_rows[i][g]
                            if v > cap:
                                return None
                            assign[tids[i]] = g
                            loads[g] = v
                            continue
                    crow = C_rows[i]
                    best_v = float("inf")
                    best_rid = any_rids[0]
                    for rid in any_rids:
                        v = loads[rid] + crow[rid]
                        if v < best_v:
                            best_v = v
                            best_rid = rid
                    if best_v > cap:
                        return None
                    assign[tids[i]] = best_rid
                    loads[best_rid] = best_v

            # acceptance (line 10) already enforced incrementally above
            return assign, loads

        # ------------------------------------------------------------------
        # binary search on λ (classical dual-approximation driver)
        worst_xfer = 0.0
        if fused is not None and fused["X_rowmax"] is not None:
            # device-reduced per-row maxima equal max(xrow) (max is
            # order-independent); the host fold order is unchanged
            for v in fused["X_rowmax"]:
                worst_xfer += v
        elif X is not None:
            for xrow in X:
                worst_xfer += max(xrow)
        upper = (
            sum(max(pc, pg) for pc, pg in zip(p_cpu, p_gpu))
            + max_off
            + worst_xfer
            + _TINY
        )
        if noticed_pen:
            # the notice penalties inflate C, so the feasibility anchor
            # must cover them too (λ=upper stays provably feasible)
            upper += n * max(noticed_pen.values())
        lower = 0.0
        kept: Optional[Tuple[Dict[int, int], List[float]]] = None
        searched = False
        if use_backend_search:
            # the whole λ binary search runs as one backend dispatch; the
            # returned λ is bit-identical to the Python loop's final
            # upper, and the placement is rebuilt by try_build so decisions
            # (including tie-breaks) cannot drift
            lam_final = be.dada_lambda_search(
                n=n,
                n_res=n_res,
                offsets=offsets,
                C_dev=fused["C_dev"],
                p_cpu=p_cpu,
                p_gpu=p_gpu,
                by_score=by_score,
                tid_index={tid: i for i, tid in enumerate(tids)},
                flex_order=flex_order,
                resources=resources,
                have_both=have_both,
                no_cpus=no_cpus,
                no_gpus=no_gpus,
                alpha=alpha,
                area_bound=area_bound,
                area=(area if area_bound else 0.0),
                off_total=(off_total if area_bound else 0.0),
                max_off=max_off,
                eps_rel=self.eps_rel,
                max_iters=self.max_iters,
                upper0=upper,
            )
            built = try_build(lam_final)
            if built is not None:
                upper = lam_final
                kept = built
                searched = True
            # else: defensive — a divergent verdict would leave an
            # infeasible λ; fall back to the Python search below
        if not searched:
            it = 0
            while upper - lower > self.eps_rel * upper and it < self.max_iters:
                lam = (upper + lower) / 2.0
                built = try_build(lam)
                if built is not None:
                    upper = lam
                    kept = built
                else:
                    lower = lam
                it += 1
            if kept is None:
                kept = try_build(upper)
                assert kept is not None, "λ=upper must always be feasible"

        assign, loads = kept
        # expose the accepted guess for tests / introspection
        self.last_lambda = upper
        self.last_loads = {r.rid: loads[j] for j, r in enumerate(resources)}
        for t in ready:
            rid = assign[t.tid]
            sim.push(t, rid)
        for j, r in enumerate(resources):
            sim.load_ts[r.rid] = sim.now + loads[j]


class DualApprox(DADA):
    """Plain ρ=2 dual approximation — DADA with the affinity phase off."""

    def __init__(self, use_cp: bool = False, **kw) -> None:
        super().__init__(alpha=0.0, use_cp=use_cp, **kw)
        self.name = "dual" + ("+cp" if use_cp else "")
