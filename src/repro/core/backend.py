"""Pluggable placement-scoring backends (``REPRO_SCHED_BACKEND=numpy|jax``).

The scheduling strategies (``dada.py``, ``heft.py``) are written against the
numpy/scalar scoring path; this module adds an optional JAX backend that
accelerates the two placement hot spots on wide activations:

  * **fused score matrices** — the (ready × resources) duration / transfer /
    affinity matrices come out of one jitted call over padded CSR slices
    (reads and writes are padded to static shapes so retraces stay bounded),
    with the CSR-incidence → transfer-time reduction optionally running
    through the Pallas kernel in ``repro.kernels.sched_score`` on
    accelerator platforms;
  * **batched λ-probe search** — DADA's binary search on the makespan guess
    λ runs as **one jitted dispatch** (a ``lax.while_loop``, no Python
    loop): each iteration computes the 2^d−1 midpoints reachable within
    the next ``d`` bisection steps (a speculative midpoint tree), evaluates
    the whole λ grid in one vmapped sweep of the feasibility verdict, and
    walks the tree with the verdicts. The λ trajectory (every probe value,
    every accept/reject and the final accepted λ) is bit-identical to the
    Python binary-search loop. On CPU the default depth is 1 (the tree
    degenerates to plain bisection — speculative probes cost real time on
    a single core); on gpu/tpu it is 5, where the 31-probe vmap rides the
    accelerator for free.

Bit-for-bit contract: the backend only ever computes *score values* (which
are IEEE-f64 op-for-op identical to the numpy path) and *feasibility
verdicts*; the placement for the accepted λ is always rebuilt by the
strategy's own Python ``try_build``, so decisions — including tie-breaks —
cannot drift. ``tests/test_backend.py`` enforces both levels.

The feasibility verdict reproduces ``try_build``'s boolean without its
early exits (overflow flags are sticky, loads accumulate through the same
op sequence), which admits structural speedups that keep bit-equal
results:

  * the **affinity phase decomposes into per-resource chains**: each
    by-score entry only reads/writes its own resource's load, so the
    n-entry sequential loop becomes a (max-chain-length × resources) scan
    — entries of different resources advance in parallel lanes — and the
    per-task assignment flags come back through one gather;
  * the flexible phase runs on **split CPU/GPU load lanes** (the paper's
    Algorithm 2 only ever takes a min over one class at a time), with
    first-occurrence ``argmin`` preserving the scalar tie-break;
  * probes that are already infeasible (and the usually-empty dedicated
    pass) **skip the remaining scans** via ``lax.cond``.

The backend is selected per strategy instance (``DADA(backend="jax")``),
falling back to the scheduling configuration (``repro.sched.SchedConfig``,
itself parsed once from ``REPRO_SCHED_BACKEND`` et al. with validation)
and defaulting to numpy. JAX is imported lazily; when it is missing the
jax backend degrades to numpy with a one-time warning so dependency-light
environments keep working.

Knobs (all parsed/validated by ``SchedConfig.from_env``; this module never
reads ``os.environ`` directly):
  REPRO_SCHED_BACKEND       numpy (default) | jax
  REPRO_SCHED_JAX_MIN       ready-set width from which the jax path engages
                            (default 32; set 1 to force it everywhere)
  REPRO_SCHED_LAMBDA_DEPTH  speculative bisection depth d (default: 1 on
                            cpu, 5 on gpu/tpu; 1-8)
  REPRO_SCHED_PALLAS        auto (default: Pallas on gpu/tpu, XLA fold on
                            cpu) | 1 (force, interpret-mode on cpu) | 0
"""
from __future__ import annotations

import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .machine import HOST_MEM

DEFAULT_JAX_MIN = 32


def _resolve_config(config=None):
    """The active ``SchedConfig`` (lazy import: repro.sched.policies
    imports this module back for the strategy classes)."""
    if config is not None:
        return config
    from repro.sched.config import current_config

    return current_config()

_TINY = 1e-12  # must match dada._TINY

# scan unrolling amortizes the per-step XLA loop overhead that dominates the
# sequential phases on CPU; it changes code size only, never op order/results
_UNROLL = 16

_BACKENDS = ("numpy", "jax")


def backend_name(explicit: Optional[str] = None, config=None) -> str:
    """Resolve the backend name: explicit arg > SchedConfig > ``numpy``."""
    if explicit is None:
        return _resolve_config(config).backend
    name = explicit.lower()
    if name not in _BACKENDS:
        raise ValueError(
            f"unknown scheduling backend {name!r} (choose from {_BACKENDS})"
        )
    return name


def jax_min_wide(config=None) -> int:
    """Ready-set width from which the jax path engages (config-tunable)."""
    return _resolve_config(config).jax_min


# built backends keyed by the config fields the backend actually consumes
# (lambda_depth, pallas) — the typical process uses one config and hence
# one instance (its jit caches are the expensive part), but an explicit
# per-strategy SchedConfig must not silently inherit the first caller's
# depth/pallas settings
_JAX_BACKENDS: Dict[tuple, "JaxScoringBackend"] = {}
_JAX_FAILED = False
_WARNED_FALLBACK = False


def get_backend(explicit: Optional[str] = None, config=None):
    """Return the scoring backend: ``None`` for numpy, else the jax backend.

    A missing/broken jax degrades to numpy with a single warning — tier-1
    environments without jax keep working unchanged.
    """
    config = _resolve_config(config)
    if backend_name(explicit, config) == "numpy":
        return None
    global _JAX_FAILED, _WARNED_FALLBACK
    if _JAX_FAILED:
        return None
    key = (config.lambda_depth, config.pallas, config.jax_min)
    be = _JAX_BACKENDS.get(key)
    if be is None:
        try:
            be = _JAX_BACKENDS[key] = JaxScoringBackend(config)
        except Exception as exc:  # ImportError or accelerator init failure
            _JAX_FAILED = True
            if not _WARNED_FALLBACK:
                _WARNED_FALLBACK = True
                warnings.warn(
                    "REPRO_SCHED_BACKEND=jax requested but the jax backend "
                    f"could not be initialised ({exc!r}); falling back to "
                    "the numpy scoring path",
                    RuntimeWarning,
                    stacklevel=2,
                )
            return None
    return be


def _reset_backend_cache() -> None:
    """Test hook: forget failed (or built) backends."""
    global _JAX_FAILED, _WARNED_FALLBACK
    _JAX_BACKENDS.clear()
    _JAX_FAILED = False
    _WARNED_FALLBACK = False


def _bucket(n: int, lo: int = 8) -> int:
    """Next power-of-two ≥ n (≥ lo): bounds distinct jit signatures."""
    b = lo
    while b < n:
        b *= 2
    return b


class ScoringBackendMixin:
    """Lazy, cached scoring-backend resolution shared by the strategy
    classes (DADA, HEFT): one place defines the fallback semantics.

    ``config`` is the typed :class:`repro.sched.SchedConfig`; when None
    the process-wide environment-derived config applies at resolution
    time (not at construction, so strategies stay picklable and cheap)."""

    def _init_backend(self, backend: Optional[str], config=None) -> None:
        self.backend_name = backend
        self.config = config
        self._backend = None
        self._backend_resolved = False

    def _scoring_backend(self):
        if not self._backend_resolved:
            self._backend = get_backend(self.backend_name, self.config)
            self._backend_resolved = True
        return self._backend


def _x64_scoped(method):
    """Run a backend method under a temporarily-enabled x64 context so the
    f64 scoring math never leaks into the process-wide jax config."""
    import functools

    @functools.wraps(method)
    def wrapper(self, *args, **kwargs):
        with self._x64():
            return method(self, *args, **kwargs)

    return wrapper


class JaxScoringBackend:
    """JAX implementation of the placement-scoring hot paths.

    All public methods take/return host-side numpy/python data (plus opaque
    device handles threaded between the matrices call and the λ search);
    device placement, padding to static shapes and jit-cache management are
    internal. Methods return ``None`` when an activation or machine shape
    is outside the supported envelope (caller falls back to numpy).
    """

    name = "jax"

    # compact residency codes (Pallas path) are int32: bit 0 = host,
    # bit u+1 = unique mem u
    _MAX_UNIQ_MEMS = 30

    def __init__(self, config=None) -> None:
        import jax  # lazy: numpy-only environments never pay this
        import jax.numpy as jnp

        config = _resolve_config(config)

        # x64 is scoped per backend call (see _x64), never flipped
        # process-wide: the repo's other jax stacks (models, linalg tiles,
        # Pallas kernels) must keep their f32 defaults regardless of
        # whether a scheduling strategy was instantiated first
        from jax.experimental import enable_x64 as _enable_x64

        with _enable_x64():
            jnp.asarray(0.0)  # fail fast if the context is unsupported

        self.jax = jax
        self.jnp = jnp
        self._x64 = _enable_x64
        platform = jax.default_backend()
        default_depth = 1 if platform == "cpu" else 5
        self.depth = (
            config.lambda_depth if config.lambda_depth is not None else default_depth
        )
        self._min_wide = config.jax_min
        pallas = config.pallas
        if pallas == "1":
            self.pallas_mode = "interpret" if platform == "cpu" else "native"
        elif pallas in ("0", "off", "false"):
            self.pallas_mode = "off"
        else:  # auto
            self.pallas_mode = "native" if platform in ("gpu", "tpu") else "off"
        self._matrix_fns: Dict[tuple, object] = {}
        self._search_fns: Dict[tuple, object] = {}
        self._heft_fns: Dict[tuple, object] = {}
        self._machine_cache: Dict[tuple, dict] = {}

    # ------------------------------------------------------------------
    @property
    def min_wide(self) -> int:
        # frozen at construction from the resolved SchedConfig: per-call
        # environment scans have no place on the activation hot path, and
        # an explicitly threaded config's jax_min must win (get_backend
        # keys its cache on it)
        return self._min_wide

    # ------------------------------------------------------------------
    @_x64_scoped
    def _machine_arrays(self, resources, transfer_model) -> Optional[dict]:
        """Activation-invariant per-machine device arrays (cached)."""
        mems = tuple(r.mem for r in resources)
        accel = tuple(r.is_accelerator for r in resources)
        key = (mems, accel, transfer_model.latency, transfer_model.bandwidth)
        m = self._machine_cache.get(key)
        if m is not None:
            return m
        uniq, col_of, _ = transfer_model.mem_plan(mems)
        if len(uniq) > self._MAX_UNIQ_MEMS:
            return None
        jnp = self.jnp
        cpu_idx = [j for j, a in enumerate(accel) if not a]
        gpu_idx = [j for j, a in enumerate(accel) if a]
        m = dict(
            uniq=tuple(uniq),
            col_of=jnp.asarray(col_of, dtype=jnp.int32),
            # full-mask residency tests shift by mem+1 per unique memory
            mem_shift=jnp.asarray(
                [u + 1 for u in uniq], dtype=jnp.int64
            ),
            col_bits=jnp.asarray(
                [1 << (u + 1) for u in range(len(uniq))], dtype=jnp.int32
            ),
            host_col=jnp.asarray([mem == HOST_MEM for mem in uniq], dtype=bool),
            accel_res=jnp.asarray(accel, dtype=bool),
            cpu_idx=jnp.asarray(cpu_idx, dtype=jnp.int32),
            gpu_idx=jnp.asarray(gpu_idx, dtype=jnp.int32),
            n_cpu=len(cpu_idx),
            n_gpu=len(gpu_idx),
            latency=transfer_model.latency,
            bandwidth=transfer_model.bandwidth,
        )
        self._machine_cache[key] = m
        return m

    @staticmethod
    def _pad_csr(
        indptr: np.ndarray, values: Sequence[np.ndarray], n_pad: int, r_pad: int
    ) -> List[np.ndarray]:
        """Scatter gathered CSR rows into dense (n_pad × r_pad) blocks."""
        n = len(indptr) - 1
        counts = indptr[1:] - indptr[:-1]
        rows = np.repeat(np.arange(n, dtype=np.int64), counts)
        cols = np.arange(int(indptr[-1]), dtype=np.int64) - np.repeat(
            indptr[:-1], counts
        )
        out = []
        for v in values:
            dense = np.zeros((n_pad, r_pad), dtype=v.dtype)
            dense[rows, cols] = v
            out.append(dense)
        return out

    # ------------------------------------------------------------------
    @_x64_scoped
    def score_matrices(
        self,
        sim,
        tids: Sequence[int],
        resources,
        *,
        p_cpu: Optional[Sequence[float]] = None,
        p_gpu: Optional[Sequence[float]] = None,
        use_cp: bool = False,
        affinity: Optional[str] = None,
        x_rows: bool = False,
        x_bias: Optional[np.ndarray] = None,
    ) -> Optional[dict]:
        """Fused (ready × resources) scoring matrices.

        ``x_bias`` (optional, capacity-bounded memories): an additive
        (n × resources) penalty — predicted eviction seconds — folded
        into the transfer matrix on device before ``C`` / ``X`` / the
        per-row maxima are derived, so jax scores stay bit-equal to the
        numpy path's ``x + bias`` fold.

        Returns ``{"C": list rows|None, "C_np": array|None, "C_dev":
        device array|None, "X_np": array|None, "X_rowmax": list|None,
        "S_np": array|None}``: cost ``C`` (duration + predicted transfer)
        when per-class durations are supplied, transfer times ``X`` when
        ``use_cp`` (full rows only with ``x_rows=True`` — HEFT needs them;
        DADA only needs the per-row maxima for its λ upper bound, reduced
        on-device), affinity scores ``S`` when ``affinity`` names a
        resident-weighted score. Every entry is bit-equal to the numpy
        path (same IEEE op order); the device-resident ``C_dev`` (padded
        to the same bucket the λ search uses) avoids a host round-trip
        between the two calls. ``None`` means unsupported (caller takes
        the numpy path).
        """
        from .affinity import affinity_csr_source

        mach = self._machine_arrays(resources, sim.transfer_model)
        if mach is None:
            return None
        arr = sim.arrays
        residency = sim.residency
        n = len(tids)
        n_pad = _bucket(n)
        tids_arr = np.asarray(tids, dtype=np.int64)
        uniq = mach["uniq"]
        jnp = self.jnp

        want_x = use_cp
        aff_src = affinity_csr_source(affinity, arr) if affinity else None
        want_s = aff_src is not None
        if not (want_x or want_s or p_cpu is not None):
            return None
        want_bias = want_x and x_bias is not None
        if want_bias:
            bias = np.zeros((n_pad, len(resources)), dtype=np.float64)
            bias[:n] = x_bias
        else:
            bias = np.zeros((1, 1), dtype=np.float64)

        if want_x:
            r_indptr, r_ids, r_sizes = arr.gather_csr(
                tids_arr, arr.read_indptr, arr.read_ids, arr.read_sizes
            )
            r_pad = _bucket(int((r_indptr[1:] - r_indptr[:-1]).max(initial=1)), lo=1)
            r_masks = residency.mask_of_ids(r_ids)
            read_masks, read_sizes = self._pad_csr(
                r_indptr, [r_masks, r_sizes], n_pad, r_pad
            )
        else:
            r_pad = 0
            read_masks = read_sizes = np.zeros((n_pad, 1))

        if want_s:
            w_indptr_full, w_ids_full, w_weights_full, accel_only = aff_src
            w_indptr, w_ids, w_weights = arr.gather_csr(
                tids_arr, w_indptr_full, w_ids_full, w_weights_full
            )
            w_pad = _bucket(int((w_indptr[1:] - w_indptr[:-1]).max(initial=1)), lo=1)
            w_masks = residency.mask_of_ids(w_ids)
            write_masks, write_weights = self._pad_csr(
                w_indptr, [w_masks, w_weights.astype(np.float64)], n_pad, w_pad
            )
        else:
            w_pad = 0
            accel_only = False
            write_masks = write_weights = np.zeros((n_pad, 1))

        want_c = p_cpu is not None
        if want_c:
            pc = np.zeros(n_pad, dtype=np.float64)
            pg = np.zeros(n_pad, dtype=np.float64)
            pc[:n] = p_cpu
            pg[:n] = p_gpu
        else:
            pc = pg = np.zeros(n_pad, dtype=np.float64)

        key = (n_pad, r_pad, w_pad, len(uniq), len(resources),
               want_x, bool(x_rows), want_s, want_c, accel_only, want_bias)
        fn = self._matrix_fns.get(key)
        if fn is None:
            fn = self._build_matrix_fn(key)
            self._matrix_fns[key] = fn
        C, X, X_max, S = fn(
            jnp.asarray(read_masks), jnp.asarray(read_sizes),
            jnp.asarray(write_masks), jnp.asarray(write_weights),
            jnp.asarray(pc), jnp.asarray(pg), jnp.asarray(bias),
            mach["mem_shift"], mach["col_bits"], mach["host_col"],
            mach["col_of"], mach["accel_res"],
            jnp.float64(mach["latency"]), jnp.float64(mach["bandwidth"]),
        )
        out = dict(C=None, C_np=None, C_dev=None, X_np=None,
                   X_rowmax=None, S_np=None)
        if want_c:
            out["C_dev"] = C
            out["C_np"] = np.asarray(C)[:n]
            out["C"] = out["C_np"].tolist()
        if want_x and x_rows:
            out["X_np"] = np.asarray(X)[:n]
        if want_x and not x_rows:
            out["X_rowmax"] = np.asarray(X_max)[:n].tolist()
        if want_s:
            out["S_np"] = np.asarray(S)[:n]
        return out

    def _build_matrix_fn(self, key):
        (n_pad, r_pad, w_pad, n_u, n_res,
         want_x, x_rows, want_s, want_c, accel_only, want_bias) = key
        jax, jnp = self.jax, self.jnp
        pallas_mode = self.pallas_mode

        def fn(read_masks, read_sizes, write_masks, write_weights,
               p_cpu, p_gpu, x_bias, mem_shift, col_bits, host_col, col_of,
               accel_res, latency, bandwidth):
            X_res = None
            X_max = None
            if want_x:
                per_read = jnp.where(
                    read_sizes <= 0.0, 0.0, latency + read_sizes / bandwidth
                )
                if pallas_mode != "off":
                    from repro.kernels.sched_score import transfer_matrix_pallas

                    compact = _compact_masks_jnp(
                        jnp, read_masks, mem_shift
                    )
                    X_u = transfer_matrix_pallas(
                        compact, per_read, col_bits, host_col,
                        interpret=pallas_mode == "interpret",
                    )
                else:
                    # in-order fold over the read axis: bit-equal to the
                    # reduceat fold of the numpy matrix path (hops come
                    # straight off the full residency masks; the formula
                    # lives once, in repro.kernels.sched_score)
                    from repro.kernels.sched_score import (
                        transfer_matrix_from_full,
                    )

                    X_u = transfer_matrix_from_full(
                        read_masks, per_read, mem_shift, host_col
                    )
                X_res = X_u[:, col_of]
                if want_bias:
                    # memory-pressure penalty: the same host-computed
                    # addend the numpy path folds, applied before C and
                    # the per-row maxima derive from X
                    X_res = X_res + x_bias
                if not x_rows:
                    # max is order-independent: equals max(row) on host
                    X_max = jnp.max(X_res, axis=1)
            S_res = None
            if want_s:
                def wbody(r, acc):
                    m = write_masks[:, r][:, None]
                    resident = ((m >> mem_shift[None, :]) & 1) != 0
                    w = write_weights[:, r][:, None]
                    return acc + jnp.where(resident, w, 0.0)

                S_u = jax.lax.fori_loop(
                    0, w_pad, wbody, jnp.zeros((n_pad, n_u), dtype=jnp.float64)
                )
                S_res = S_u[:, col_of]
                if accel_only:
                    S_res = jnp.where(accel_res[None, :], S_res, 0.0)
            C = None
            if want_c:
                base = jnp.where(
                    accel_res[None, :], p_gpu[:, None], p_cpu[:, None]
                )
                C = base + X_res if want_x else jnp.broadcast_to(
                    base, (n_pad, n_res)
                )
            return C, X_res, X_max, S_res

        return jax.jit(fn)

    # ------------------------------------------------------------------
    # DADA λ-probe search
    # ------------------------------------------------------------------
    @_x64_scoped
    def dada_lambda_search(
        self,
        *,
        n: int,
        n_res: int,
        offsets: Sequence[float],
        C_dev,
        p_cpu: Sequence[float],
        p_gpu: Sequence[float],
        by_score: Sequence[Tuple[float, int, int, float]],
        tid_index: Dict[int, int],
        flex_order,
        resources,
        have_both: bool,
        no_cpus: bool,
        no_gpus: bool,
        alpha: float,
        area_bound: bool,
        area: float,
        off_total: float,
        max_off: float,
        eps_rel: float,
        max_iters: int,
        upper0: float,
    ) -> float:
        """Run DADA's binary search on λ entirely on the backend.

        Returns the final ``upper`` — identical (bit-for-bit) to the value
        the Python loop in ``dada.place`` would settle on, because every
        probe value and every feasibility verdict is reproduced exactly.
        The caller then rebuilds the placement at that λ with its own
        ``try_build``. ``C_dev`` is the device-resident padded cost matrix
        from :meth:`score_matrices` (same ``_bucket(n)`` padding).
        """
        jnp = self.jnp
        n_pad = _bucket(n)
        assert C_dev.shape == (n_pad, n_res), (C_dev.shape, n_pad, n_res)

        accel = [r.is_accelerator for r in resources]
        cpu_idx = np.asarray(
            [j for j, a in enumerate(accel) if not a], dtype=np.int32
        )
        gpu_idx = np.asarray(
            [j for j, a in enumerate(accel) if a], dtype=np.int32
        )

        pc = np.zeros(n_pad, dtype=np.float64)
        pg = np.zeros(n_pad, dtype=np.float64)
        pc[:n] = p_cpu
        pg[:n] = p_gpu
        valid = np.zeros(n_pad, dtype=bool)
        valid[:n] = True
        # padded flex_order entries point at row 0; the search masks them
        # with the position-validity of `valid` (True exactly for k < n)
        ford = np.zeros(n_pad, dtype=np.int32)
        ford[:n] = flex_order

        # Affinity phase → per-resource chains: entry k of by_score only
        # reads/writes loads[rid_k], so entries of different resources are
        # independent; within one resource the by-score order is preserved
        # by the stable sort. The scan then runs max-chain-length steps
        # with one lane per resource instead of len(by_score) steps, and
        # each task reads its own take-flag back through one gather
        # (task_slot points at the task's (chain position, rid) cell; the
        # appended always-False cell absorbs tasks without a preference).
        m = len(by_score)
        task_slot = np.full(n_pad, 0, dtype=np.int32)
        if m:
            rids = np.fromiter((e[2] for e in by_score), np.int64, m)
            costs = np.fromiter((e[3] for e in by_score), np.float64, m)
            tis = np.fromiter(
                (tid_index[e[1]] for e in by_score), np.int64, m
            )
            perm = np.argsort(rids, kind="stable")
            srid = rids[perm]
            first = np.searchsorted(srid, srid, side="left")
            pos = np.arange(m, dtype=np.int64) - first
            chain_pad = _bucket(int(pos.max()) + 1, lo=1)
            chain_cost = np.zeros((chain_pad, n_res), dtype=np.float64)
            chain_valid = np.zeros((chain_pad, n_res), dtype=bool)
            chain_cost[pos, srid] = costs[perm]
            chain_valid[pos, srid] = True
            task_slot[:] = chain_pad * n_res  # the appended False cell
            task_slot[tis[perm]] = (pos * n_res + srid).astype(np.int32)
        else:
            chain_pad = 0
            chain_cost = np.zeros((1, n_res), dtype=np.float64)
            chain_valid = np.zeros((1, n_res), dtype=bool)

        key = (n_pad, chain_pad, n_res, len(cpu_idx), len(gpu_idx),
               bool(have_both), bool(area_bound), self.depth)
        fn = self._search_fns.get(key)
        if fn is None:
            fn = self._build_search_fn(key)
            self._search_fns[key] = fn
        upper = fn(
            jnp.asarray(offsets, dtype=jnp.float64),
            C_dev,
            jnp.asarray(pc), jnp.asarray(pg), jnp.asarray(valid),
            jnp.asarray(ford),
            jnp.asarray(chain_cost), jnp.asarray(chain_valid),
            jnp.asarray(task_slot),
            jnp.asarray(cpu_idx), jnp.asarray(gpu_idx),
            jnp.bool_(no_cpus), jnp.bool_(no_gpus),
            jnp.float64(alpha), jnp.float64(2.0 + alpha),
            jnp.float64(area), jnp.float64(off_total), jnp.float64(max_off),
            jnp.float64(float(n_res)),
            jnp.float64(eps_rel), jnp.int32(max_iters), jnp.float64(upper0),
        )
        return float(upper)

    def _build_search_fn(self, key):
        (n_pad, chain_pad, n_res, n_cpu, n_gpu,
         have_both, area_bound, depth) = key
        jax, jnp = self.jax, self.jnp
        lax = jax.lax
        K = 2 ** depth - 1
        INF = float("inf")

        def fn(loads0, C, p_cpu, p_gpu, valid, flex_ord,
               chain_cost, chain_valid, task_slot,
               cpu_idx, gpu_idx, no_cpus, no_gpus,
               alpha, two_alpha, area, off_total, max_off, n_res_f,
               eps_rel, max_iters, upper0):
            # probe-invariant gathers, done once per search
            if have_both:
                C_g = C[:, gpu_idx]
                C_c = C[:, cpu_idx]
                Cf_g = C_g[flex_ord]
                Cf_c = C_c[flex_ord]
                gpu_mask = jnp.zeros((n_res,), bool).at[gpu_idx].set(True)
                cpu_mask = ~gpu_mask

            def verdict(lam):
                """Feasibility of guess λ — the exact boolean dada's
                ``try_build(lam) is not None`` yields (early-exit order
                differs, the verdict cannot: overflow flags are sticky and
                loads accumulate through the same op sequence)."""
                cap = two_alpha * lam + _TINY
                bad = max_off > cap
                if area_bound:
                    bad = bad | (area > (lam * n_res_f - off_total) + _TINY)
                loads = loads0

                if chain_pad:
                    budget = alpha * lam + _TINY

                    def astep(carry, x):
                        loads, bad = carry
                        costs, av = x
                        take = av & (loads <= budget)
                        v = loads + costs
                        bad = bad | jnp.any(take & (v > cap))
                        loads = jnp.where(take, v, loads)
                        return (loads, bad), take

                    (loads, bad), takes = lax.scan(
                        astep, (loads, bad), (chain_cost, chain_valid),
                        unroll=min(_UNROLL, chain_pad),
                    )
                    flat = jnp.append(takes.reshape(-1), False)
                    assigned = flat[task_slot]
                else:
                    assigned = jnp.zeros((n_pad,), dtype=bool)

                rem = valid & ~assigned
                big_cpu = no_cpus | (p_cpu > lam)
                big_gpu = no_gpus | (p_gpu > lam)
                bad = bad | jnp.any(rem & big_cpu & big_gpu)

                def balance(args):
                    loads, bad = args
                    if have_both:
                        flex = rem & (p_cpu <= lam) & (p_gpu <= lam)
                        ded = rem & ~flex
                        ded_gpu = p_cpu > lam
                        lanes = jnp.arange(n_res)

                        def dstep(carry, x):
                            loads, bad = carry
                            on, to_gpu, crow = x
                            pool = jnp.where(to_gpu, gpu_mask, cpu_mask)
                            vm = jnp.where(pool, loads + crow, INF)
                            # one-hot select: jnp.min equals vm[argmin]
                            # bitwise, first-occurrence argmin keeps the
                            # scalar tie-break
                            hot = lanes == jnp.argmin(vm)
                            bv = jnp.min(vm)
                            bad = bad | (on & (bv > cap))
                            loads = jnp.where(hot & on, bv, loads)
                            return (loads, bad), None

                        def ded_pass(args):
                            (loads, bad), _ = lax.scan(
                                dstep, args, (ded, ded_gpu, C), unroll=_UNROLL
                            )
                            return loads, bad

                        # the dedicated pass is usually empty for feasible
                        # λ guesses — skip its n-step scan when it is
                        loads, bad = lax.cond(
                            jnp.any(ded), ded_pass, lambda a: a, (loads, bad)
                        )

                        # flexible phase on split class lanes: Algorithm 2
                        # only ever takes the min over one class at a time
                        loads_g = loads[gpu_idx]
                        loads_c = loads[cpu_idx]
                        gpu_budget = lam + _TINY
                        # `valid` is a position mask (True exactly for
                        # k < n), so it also masks padded flex positions
                        flex_o = flex[flex_ord] & valid

                        def fstep(carry, x):
                            loads_g, loads_c, bad = carry
                            on, crow_g, crow_c = x
                            g = jnp.argmin(loads_g)
                            gl = loads_g[g]
                            use_gpu = on & (gl <= gpu_budget)
                            vg = gl + crow_g[g]
                            bad = bad | (use_gpu & (vg > cap))
                            loads_g = loads_g.at[g].set(
                                jnp.where(use_gpu, vg, gl)
                            )
                            vm = loads_c + crow_c
                            j = jnp.argmin(vm)
                            bv = vm[j]
                            use_eft = on & ~use_gpu
                            bad = bad | (use_eft & (bv > cap))
                            loads_c = loads_c.at[j].set(
                                jnp.where(use_eft, bv, loads_c[j])
                            )
                            return (loads_g, loads_c, bad), None

                        (loads_g, loads_c, bad), _ = lax.scan(
                            fstep, (loads_g, loads_c, bad),
                            (flex_o, Cf_g, Cf_c), unroll=_UNROLL,
                        )
                        # `loads` is returned un-merged: only `bad` is read
                        # after the balance phase
                    else:
                        # single-class machine: the EFT pool is every
                        # resource, processed in index order
                        def sstep(carry, x):
                            loads, bad = carry
                            on, crow = x
                            vm = loads + crow
                            j = jnp.argmin(vm)
                            bv = vm[j]
                            bad = bad | (on & (bv > cap))
                            loads = loads.at[j].set(
                                jnp.where(on, bv, loads[j])
                            )
                            return (loads, bad), None

                        (loads, bad), _ = lax.scan(
                            sstep, (loads, bad), (rem, C), unroll=_UNROLL
                        )
                    return loads, bad

                # a probe that already failed skips the balance scans
                loads, bad = lax.cond(
                    bad, lambda a: a, balance, (loads, bad)
                )
                return bad

            feasible_grid = jax.vmap(lambda lam: ~verdict(lam))

            def cond(state):
                lower, upper, it = state
                return (upper - lower > eps_rel * upper) & (it < max_iters)

            def body(state):
                lower, upper, it = state
                # speculative midpoint tree (heap layout): node k covers an
                # interval; its midpoint is the probe the bisection would
                # make on reaching it. Depth-d tree = the next d probes for
                # every possible verdict path — all evaluated in one
                # vmapped sweep of the λ grid.
                lo = [None] * K
                hi = [None] * K
                mid = [None] * K
                lo[0], hi[0] = lower, upper
                for k in range(K):
                    mid[k] = (lo[k] + hi[k]) / 2.0
                    if 2 * k + 2 < K:
                        lo[2 * k + 1], hi[2 * k + 1] = lo[k], mid[k]
                        lo[2 * k + 2], hi[2 * k + 2] = mid[k], hi[k]
                mids = jnp.stack(mid)
                if K == 1:
                    # no vmap at depth 1: gathers/updates inside the
                    # verdict stay scalar-indexed (cheap on CPU) instead
                    # of turning into batched scatters
                    feas = jnp.reshape(~verdict(mids[0]), (1,))
                else:
                    feas = feasible_grid(mids)
                # walk ≤ depth bisection steps, re-checking the stopping
                # rule before each (exactly like the Python while loop)
                idx = jnp.int32(0)
                for _ in range(depth):
                    go = (upper - lower > eps_rel * upper) & (it < max_iters)
                    safe = jnp.minimum(idx, K - 1)
                    f = feas[safe]
                    lam = mids[safe]
                    lower = jnp.where(go & ~f, lam, lower)
                    upper = jnp.where(go & f, lam, upper)
                    it = it + go.astype(jnp.int32)
                    idx = jnp.where(go, 2 * idx + jnp.where(f, 1, 2), idx)
                return lower, upper, it

            _, upper, _ = lax.while_loop(
                cond, body, (jnp.float64(0.0), upper0, jnp.int32(0))
            )
            return upper

        return jax.jit(fn)

    # ------------------------------------------------------------------
    # HEFT earliest-finish-time selection
    # ------------------------------------------------------------------
    @_x64_scoped
    def heft_select(
        self,
        D_ord: np.ndarray,
        X_ord: np.ndarray,
        load_ts: Sequence[float],
        now: float,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Sequential EFT worker selection over tasks in priority order.

        ``D_ord``/``X_ord`` are (n × n_res) duration / transfer rows already
        gathered in priority order. Returns (chosen rid, eft) per task —
        the same values (1e-15 strict-improvement tie-break included) the
        scalar loop in ``heft.place`` computes.
        """
        jnp = self.jnp
        n, n_res = D_ord.shape
        n_pad = _bucket(n)
        D = np.zeros((n_pad, n_res), dtype=np.float64)
        X = np.zeros((n_pad, n_res), dtype=np.float64)
        valid = np.zeros(n_pad, dtype=bool)
        D[:n] = D_ord
        X[:n] = X_ord
        valid[:n] = True
        key = (n_pad, n_res)
        fn = self._heft_fns.get(key)
        if fn is None:
            fn = self._build_heft_fn(key)
            self._heft_fns[key] = fn
        rids, efts = fn(
            jnp.asarray(D), jnp.asarray(X), jnp.asarray(valid),
            jnp.asarray(load_ts, dtype=jnp.float64), jnp.float64(now),
        )
        return np.asarray(rids)[:n], np.asarray(efts)[:n]

    def _build_heft_fn(self, key):
        n_pad, n_res = key
        jax, jnp = self.jax, self.jnp
        INF = float("inf")

        def fn(D, X, valid, load_ts, now):
            def step(lts, x):
                drow, xrow, on = x
                start = jnp.where(now > lts, now, lts)
                eft = (start + xrow) + drow
                # the 1e-15 strict-improvement rule is a left fold over the
                # resource lanes; n_res is small and static, so unroll it
                # into scalar selects (no fori machinery per task)
                if n_res <= 64:
                    bv = jnp.float64(INF)
                    bj = jnp.int32(0)
                    for r in range(n_res):
                        e = eft[r]
                        upd = e < bv - 1e-15
                        bv = jnp.where(upd, e, bv)
                        bj = jnp.where(upd, jnp.int32(r), bj)
                else:
                    def rstep(r, st):
                        bv, bj = st
                        e = eft[r]
                        upd = e < bv - 1e-15
                        return (
                            jnp.where(upd, e, bv),
                            jnp.where(upd, r, bj),
                        )

                    bv, bj = jax.lax.fori_loop(
                        0, n_res, rstep, (jnp.float64(INF), jnp.int32(0))
                    )
                lts = lts.at[bj].set(jnp.where(on, bv, lts[bj]))
                return lts, (bj, bv)

            _, (rids, efts) = jax.lax.scan(
                step, load_ts, (D, X, valid), unroll=_UNROLL
            )
            return rids, efts

        return jax.jit(fn)


def _compact_masks_jnp(jnp, full_masks, mem_shift):
    """int32 residency codes from full int64 masks (Pallas-kernel input):
    bit 0 = host copy, bit u+1 = a valid copy at unique memory u."""
    out = (full_masks & 1).astype(jnp.int32)
    n_u = mem_shift.shape[0]
    for u in range(n_u):
        out = out | (
            ((full_masks >> mem_shift[u]) & 1).astype(jnp.int32) << (u + 1)
        )
    return out
