"""Core: the paper's contribution — data-flow scheduling with affinity.

Exports the task-graph model, machine/performance models, the XKaapi-like
simulator, and the scheduling strategies (HEFT, DADA, dual approximation,
work stealing).
"""
from .affinity import AFFINITY_FUNCTIONS
from .api import Summary, make_strategy, run_many, run_simulation
from .dada import DADA, DualApprox
from .dag import Access, DataObject, Mode, Task, TaskGraph
from .heft import HEFT
from .machine import (
    HOST_MEM,
    LinkModel,
    MachineModel,
    Resource,
    ResourceClass,
    make_machine,
)
from .perfmodel import HistoryPerfModel, Residency, TransferModel
from .simulator import SimResult, Simulator, Strategy
from .worksteal import WorkSteal

__all__ = [
    "AFFINITY_FUNCTIONS", "Access", "DADA", "DataObject", "DualApprox",
    "HEFT", "HOST_MEM", "HistoryPerfModel", "LinkModel", "MachineModel",
    "Mode", "Residency", "Resource", "ResourceClass", "SimResult",
    "Simulator", "Strategy", "Summary", "Task", "TaskGraph", "TransferModel",
    "WorkSteal", "make_machine", "make_strategy", "run_many", "run_simulation",
]
