"""Core: the paper's contribution — data-flow scheduling with affinity.

Exports the task-graph model, machine/performance models, the XKaapi-like
simulator, and the scheduling strategies (HEFT, DADA, dual approximation,
work stealing).
"""
from .affinity import AFFINITY_FUNCTIONS, AFFINITY_MATRIX_FUNCTIONS
from .api import (
    BatchResult,
    Summary,
    cached_graph,
    default_jobs,
    get_pool,
    make_strategy,
    run_batch,
    run_many,
    run_simulation,
)
from .backend import backend_name, get_backend
from .dada import DADA, DualApprox
from .dag import Access, DataObject, GraphArrays, Mode, Task, TaskGraph
from .heft import HEFT
from .machine import (
    HOST_MEM,
    LinkModel,
    MachineModel,
    Resource,
    ResourceClass,
    make_machine,
)
from .perfmodel import ClassPredictor, HistoryPerfModel, Residency, TransferModel
from .simulator import SimResult, Simulator, Strategy

# WorkSteal is the queue protocol itself and lives with it in the layered
# runtime (repro.runtime.queues); re-exported here unchanged
from repro.runtime.queues import WorkSteal

# importing the policy package last (it imports the strategy classes
# above) registers the built-in policies and attaches the score_matrix
# views, so `HEFT().score_matrix` / `repro.sched.resolve` work however
# the packages are first imported
from repro import sched as _sched  # noqa: E402  (deliberate tail import)

__all__ = [
    "AFFINITY_FUNCTIONS", "AFFINITY_MATRIX_FUNCTIONS", "Access", "BatchResult",
    "ClassPredictor", "DADA", "DataObject", "DualApprox", "GraphArrays",
    "HEFT", "HOST_MEM", "HistoryPerfModel", "LinkModel", "MachineModel",
    "Mode", "Residency", "Resource", "ResourceClass", "SimResult",
    "Simulator", "Strategy", "Summary", "Task", "TaskGraph", "TransferModel",
    "WorkSteal", "backend_name", "cached_graph", "default_jobs", "get_backend",
    "get_pool", "make_machine", "make_strategy", "run_batch", "run_many",
    "run_simulation",
]
