"""Performance models: history-based task timing + bandwidth transfer model.

Paper §2.3: "Our task prediction relies on an history-based model, and
transfer time estimation is based on asymptotic bandwidth". The runtime
observes real durations and corrects erroneous predictions online (StarPU
does the same). Here the *observed* durations come from the simulator's
ground-truth rates (with seeded noise), so the model genuinely calibrates
at runtime instead of being an oracle.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from .dag import Task
from .machine import MachineModel, Resource, ResourceClass


@dataclass
class HistoryPerfModel:
    """Per (task kind, resource class) running mean of observed durations.

    Before any observation the model falls back to a static estimate
    ``flops / class_rate`` — the same bootstrap StarPU/XKaapi use before
    calibration kicks in.
    """

    _stats: Dict[Tuple[str, str], Tuple[int, float]] = field(default_factory=dict)

    def predict(self, task: Task, cls: ResourceClass) -> float:
        key = (task.kind, cls.name)
        st = self._stats.get(key)
        if st is not None and st[0] > 0:
            return st[1]
        return cls.exec_time(task.kind, task.flops)

    def observe(self, task: Task, cls: ResourceClass, duration: float) -> None:
        key = (task.kind, cls.name)
        n, mean = self._stats.get(key, (0, 0.0))
        n += 1
        mean += (duration - mean) / n
        self._stats[key] = (n, mean)

    def n_observations(self) -> int:
        return sum(n for n, _ in self._stats.values())


@dataclass
class TransferModel:
    """Asymptotic-bandwidth estimator for host<->device transfers.

    ``predict`` ignores contention (a *prediction*, as in the paper — the
    simulator's ground truth does model switch contention, which is exactly
    the modeling error the paper discusses).
    """

    bandwidth: float
    latency: float = 1e-5

    def time(self, nbytes: int) -> float:
        if nbytes <= 0:
            return 0.0
        return self.latency + nbytes / self.bandwidth

    def task_input_transfer_time(
        self,
        task: Task,
        resource: Resource,
        residency: "Residency",
    ) -> float:
        """Predicted time to bring missing inputs of ``task`` to ``resource``."""
        total = 0.0
        for d in task.reads:
            if not residency.is_resident(d.name, resource.mem):
                hops = residency.transfer_hops(d.name, resource.mem)
                total += hops * self.time(d.size_bytes)
        return total


class Residency:
    """Tracks which memory spaces hold a *valid* copy of each data object.

    Writes invalidate all other copies (MSI-like, matching a runtime that
    manages coherent transfers).
    """

    def __init__(self) -> None:
        self._where: Dict[str, set] = {}

    def is_resident(self, name: str, mem: int) -> bool:
        return mem in self._where.get(name, set())

    def locations(self, name: str) -> set:
        return set(self._where.get(name, set()))

    def has_any(self, name: str) -> bool:
        return bool(self._where.get(name))

    def transfer_hops(self, name: str, dst_mem: int) -> int:
        """1 hop if a copy is on host or dst is host; 2 hops for GPU->GPU
        (device -> host -> device, the paper-era PCIe path)."""
        from .machine import HOST_MEM

        locs = self._where.get(name, set())
        if not locs or dst_mem in locs:
            return 0
        if dst_mem == HOST_MEM or HOST_MEM in locs:
            return 1
        return 2

    def add_copy(self, name: str, mem: int) -> None:
        self._where.setdefault(name, set()).add(mem)

    def write(self, name: str, mem: int) -> None:
        self._where[name] = {mem}

    def initialize(self, names, mem: int) -> None:
        for n in names:
            self.write(n, mem)

    def bytes_resident(self, mem: int, sizes: Dict[str, int]) -> int:
        return sum(sz for n, sz in sizes.items() if self.is_resident(n, mem))
