"""Performance models: history-based task timing + bandwidth transfer model.

Paper §2.3: "Our task prediction relies on an history-based model, and
transfer time estimation is based on asymptotic bandwidth". The runtime
observes real durations and corrects erroneous predictions online (StarPU
does the same). Here the *observed* durations come from the simulator's
ground-truth rates (with seeded noise), so the model genuinely calibrates
at runtime instead of being an oracle.

This module is array-native: ``Residency`` stores one bitmask per data
object (bit ``mem+1`` set ⇔ a valid copy lives in memory space ``mem``; the
host, ``HOST_MEM = -1``, is bit 0) and maintains an incremental
resident-bytes vector, so ``is_resident`` / ``transfer_hops`` are O(1) bit
tests and whole (tasks × resources) transfer/affinity matrices come out of
a handful of numpy ops over the CSR incidence of a
:class:`~repro.core.dag.GraphArrays`. The scalar reference implementations
live in ``repro.core._reference``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .dag import GraphArrays, Task
from .machine import HOST_MEM, MachineModel, Resource, ResourceClass

# Residency masks live in int64 arrays: bit 0 is the host, bit (mem+1) is
# device memory ``mem``; 62 device memories fit before the sign bit.
_MAX_MEM = 61


def _mem_bit(mem: int) -> int:
    if not -1 <= mem <= _MAX_MEM:
        raise ValueError(f"memory id {mem} outside supported range [-1, {_MAX_MEM}]")
    return 1 << (mem + 1)


@dataclass
class HistoryPerfModel:
    """Per (task kind, resource class) running mean of observed durations.

    Before any observation the model falls back to a static estimate
    ``flops / class_rate`` — the same bootstrap StarPU/XKaapi use before
    calibration kicks in.

    ``version`` increments on every ``observe`` so vectorized consumers
    (:class:`ClassPredictor`) know when their per-kind cache is stale.
    """

    _stats: Dict[Tuple[str, str], Tuple[int, float]] = field(default_factory=dict)
    version: int = 0

    def predict(self, task: Task, cls: ResourceClass) -> float:
        key = (task.kind, cls.name)
        st = self._stats.get(key)
        if st is not None and st[0] > 0:
            return st[1]
        return cls.exec_time(task.kind, task.flops)

    def observe(self, task: Task, cls: ResourceClass, duration: float) -> None:
        key = (task.kind, cls.name)
        n, mean = self._stats.get(key, (0, 0.0))
        n += 1
        mean += (duration - mean) / n
        self._stats[key] = (n, mean)
        self.version += 1

    def n_observations(self) -> int:
        return sum(n for n, _ in self._stats.values())

    def kind_table(
        self, cls: ResourceClass, kinds: Sequence[str]
    ) -> Tuple[List[float], List[bool]]:
        """(means, observed) per kind for resource class ``cls`` (plain
        lists: rebuilt on every observation, so no numpy allocation)."""
        means = []
        observed = []
        stats = self._stats
        name = cls.name
        for kind in kinds:
            st = stats.get((kind, name))
            if st is not None and st[0] > 0:
                means.append(st[1])
                observed.append(True)
            else:
                means.append(0.0)
                observed.append(False)
        return means, observed


class ClassPredictor:
    """Cached vectorized ``HistoryPerfModel.predict`` for one resource class.

    The static fallback ``flops / rate`` is a per-task constant, computed
    once per graph; the per-kind observed means are rebuilt lazily whenever
    the model's version moves (each rebuild is a loop over the handful of
    task kinds, not over tasks). ``times(tids)`` then reproduces
    ``predict`` elementwise: the observed running mean where one exists,
    the static estimate otherwise — the identical IEEE operations, just
    batched.
    """

    def __init__(self, model: HistoryPerfModel, cls: ResourceClass, arr: GraphArrays):
        self.model = model
        self.cls = cls
        self.arr = arr
        rates = np.array([cls.rate(k) for k in arr.kinds], dtype=np.float64)
        # exec_time: flops / rate, with the 1e-7 bookkeeping floor
        static = arr.flops / rates[arr.kind_codes]
        self.static_times = np.where(arr.flops <= 0.0, 1e-7, static)
        self.static_list = self.static_times.tolist()
        self._codes_list = arr.kind_codes.tolist()
        self._version = -1
        self._means_list: List[float] = []
        self._observed_list: List[bool] = []

    def _refresh(self) -> None:
        if self._version != self.model.version:
            self._means_list, self._observed_list = self.model.kind_table(
                self.cls, self.arr.kinds
            )
            self._version = self.model.version

    def times(self, tids: np.ndarray) -> np.ndarray:
        """Predicted durations for tasks ``tids`` (bit-equal to ``predict``)."""
        self._refresh()
        codes = self.arr.kind_codes[tids]
        means = np.asarray(self._means_list, dtype=np.float64)
        observed = np.asarray(self._observed_list, dtype=bool)
        return np.where(
            observed[codes], means[codes], self.static_times[tids]
        )

    def times_list(self, tids: Sequence[int]) -> List[float]:
        """Scalar fast path of :meth:`times` for narrow activations."""
        self._refresh()
        codes = self._codes_list
        means = self._means_list
        observed = self._observed_list
        static = self.static_list
        out = []
        for tid in tids:
            c = codes[tid]
            out.append(means[c] if observed[c] else static[tid])
        return out


@dataclass
class TransferModel:
    """Asymptotic-bandwidth estimator for host<->device transfers.

    ``predict`` ignores contention (a *prediction*, as in the paper — the
    simulator's ground truth does model switch contention, which is exactly
    the modeling error the paper discusses).
    """

    bandwidth: float
    latency: float = 1e-5

    def __post_init__(self) -> None:
        # memoized unique-memory decompositions, keyed by the mems tuple
        self._mem_plans: Dict[tuple, tuple] = {}

    def time(self, nbytes: int) -> float:
        if nbytes <= 0:
            return 0.0
        return self.latency + nbytes / self.bandwidth

    def mem_plan(self, mems: tuple) -> tuple:
        """Decompose a resource→memory list into (unique mems, column-of,
        already-unique flag). Memoized; shared by the numpy matrix path and
        the jax scoring backend so both see the identical column layout."""
        cached = self._mem_plans.get(mems)
        if cached is None:
            uniq: List[int] = []
            col_of: List[int] = []
            seen: Dict[int, int] = {}
            for mem in mems:
                j = seen.get(mem)
                if j is None:
                    j = seen[mem] = len(uniq)
                    uniq.append(mem)
                col_of.append(j)
            cached = (uniq, col_of, len(uniq) == len(mems))
            self._mem_plans[mems] = cached
        return cached

    def task_input_transfer_time(
        self,
        task: Task,
        resource: Resource,
        residency: "Residency",
    ) -> float:
        """Predicted time to bring missing inputs of ``task`` to ``resource``."""
        total = 0.0
        for d in task.reads:
            if not residency.is_resident(d.name, resource.mem):
                hops = residency.transfer_hops(d.name, resource.mem)
                total += hops * self.time(d.size_bytes)
        return total

    # ------------------------------------------------------------------
    def task_input_transfer_rows(
        self,
        arr: GraphArrays,
        tids: Sequence[int],
        mems: Sequence[int],
        residency: "Residency",
    ) -> List[List[float]]:
        """(len(tids) × len(mems)) predicted input-transfer times, as rows.

        Same values as :meth:`task_input_transfer_matrix`; narrow
        activations (the common case — ``activate`` usually wakes 1-3
        tasks) take a scalar path over the per-task read lists and the
        residency bitmasks, wide ones take the batched numpy path. Both
        compute ``hops * (latency + size/bandwidth)`` summed in access
        order, so every entry is bit-equal to the scalar reference.
        """
        # resources sharing a memory space (all CPUs see host memory) share
        # a column: compute per unique memory, then expand
        uniq, col_of, full = self.mem_plan(tuple(mems))

        n = len(tids)
        if n >= 32:
            arr_tids = np.asarray(tids, dtype=np.int64)
            rows = self.task_input_transfer_matrix(
                arr, arr_tids, uniq, residency
            ).tolist()
        else:
            masks = residency._mask
            # per-task (read name, per-hop time) pairs are graph-static:
            # precompute once per (model, graph) and only refresh the
            # residency masks per activation
            key = ("read_times", self.latency, self.bandwidth)
            prep = arr.cache.get(key)
            if prep is None:
                latency = self.latency
                bandwidth = self.bandwidth
                prep = [
                    [
                        (name, 0.0 if size <= 0 else latency + size / bandwidth)
                        for _, name, size in reads
                    ]
                    for reads in arr.task_reads
                ]
                arr.cache[key] = prep
            rows = []
            for tid in tids:
                reads = [(masks.get(name, 0), t) for name, t in prep[tid]]
                row = []
                for mem in uniq:
                    bit = 1 << (mem + 1)
                    total = 0.0
                    for m, t in reads:
                        if m & bit or m == 0:
                            continue
                        if mem == HOST_MEM or m & 1:
                            total += t
                        else:
                            total += 2 * t
                    row.append(total)
                rows.append(row)
        if full:
            return rows
        return [[row[j] for j in col_of] for row in rows]

    def task_input_transfer_matrix(
        self,
        arr: GraphArrays,
        tids: np.ndarray,
        mems: Sequence[int],
        residency: "Residency",
    ) -> np.ndarray:
        """(len(tids) × len(mems)) predicted input-transfer times.

        Column ``j`` is ``task_input_transfer_time`` against memory space
        ``mems[j]``, computed from the read-CSR slice and the residency
        bitmasks. Per-read contributions are summed in access order, so
        each entry is bit-equal to the scalar loop.
        """
        indptr, ids, sizes = arr.gather_csr(
            tids, arr.read_indptr, arr.read_ids, arr.read_sizes
        )
        n, m = len(tids), len(mems)
        if len(ids) == 0:
            return np.zeros((n, m), dtype=np.float64)
        masks = residency.mask_of_ids(ids)
        # per-read transfer time (latency + size/bw; 0 for empty reads)
        per_read = np.where(sizes <= 0, 0.0, self.latency + sizes / self.bandwidth)
        on_host = (masks & 1) != 0
        nowhere = masks == 0
        out = np.empty((n, m), dtype=np.float64)
        # reduceat quirks: an empty segment yields the element at its start
        # (fixed up below), and a start index == len(contrib) is invalid
        # (avoided by the appended 0.0, which also absorbs harmlessly into
        # the sum of the final non-empty segment).
        empty_seg = indptr[:-1] == indptr[1:]
        fix_empty = bool(empty_seg.any())
        for j, mem in enumerate(mems):
            bit = _mem_bit(mem)
            resident = (masks & bit) != 0
            if mem == HOST_MEM:
                hops = np.where(resident | nowhere, 0.0, 1.0)
            else:
                hops = np.where(
                    resident | nowhere, 0.0, np.where(on_host, 1.0, 2.0)
                )
            contrib = hops * per_read
            col = np.add.reduceat(np.append(contrib, 0.0), indptr[:-1])[:n]
            if fix_empty:
                col = np.where(empty_seg, 0.0, col)
            out[:, j] = col
        return out


class Residency:
    """Tracks which memory spaces hold a *valid* copy of each data object.

    Writes invalidate all other copies (MSI-like, matching a runtime that
    manages coherent transfers).

    Storage is one int bitmask per data object. Standalone instances keep a
    name-keyed dict; :meth:`attach` binds the tracker to a
    :class:`GraphArrays` id space, adding a dense ``int64`` mask array
    (``mask_arr``) for vectorized consumers and an incrementally maintained
    per-memory resident-bytes vector, so ``bytes_resident`` is O(1) instead
    of a sweep over every data object.
    """

    def __init__(self) -> None:
        self._mask: Dict[str, int] = {}
        # attached-mode state (set by attach())
        self._name_to_id: Optional[Dict[str, int]] = None
        self.mask_list: Optional[List[int]] = None
        self._sizes: Optional[List[int]] = None
        self._resident_bytes: List[int] = [0] * (_MAX_MEM + 2)
        # optional mask-change callback ``(did, name, old, new)`` —
        # installed by the capacity-bounded memory layer
        # (repro.runtime.memory) to mirror residency into its per-memory
        # LRU/accounting; None (the default) keeps the hot paths untouched
        self.observer = None

    # ------------------------------------------------------------------
    def attach(self, arr: GraphArrays) -> None:
        """Bind to a graph's data-id space (enables the array fast paths)."""
        self._name_to_id = arr.name_to_id
        self.mask_list = [0] * len(arr.data_names)
        self._sizes = arr.data_sizes.tolist()
        self._resident_bytes = [0] * (_MAX_MEM + 2)
        for name, did in arr.name_to_id.items():
            m = self._mask.get(name)
            if m:
                self.mask_list[did] = m
                for mem in self._decode(m):
                    self._resident_bytes[mem + 1] += self._sizes[did]

    @staticmethod
    def _decode(mask: int) -> List[int]:
        mems = []
        mem = -1
        while mask:
            if mask & 1:
                mems.append(mem)
            mask >>= 1
            mem += 1
        return mems

    def _set_mask(self, name: str, new: int) -> None:
        old = self._mask.get(name, 0)
        if old == new:
            return
        self._mask[name] = new
        if self._name_to_id is not None:
            did = self._name_to_id.get(name)
            if did is not None:
                self.mask_list[did] = new
                size = self._sizes[did]
                rb = self._resident_bytes
                changed = old ^ new
                while changed:
                    low = changed & -changed
                    idx = low.bit_length() - 1  # == mem + 1
                    if new & low:
                        rb[idx] += size
                    else:
                        rb[idx] -= size
                    changed ^= low
                if self.observer is not None:
                    self.observer(did, name, old, new)

    # ------------------------------------------------------------------
    def is_resident(self, name: str, mem: int) -> bool:
        if not -1 <= mem <= _MAX_MEM:
            raise ValueError(f"memory id {mem} outside supported range")
        return bool(self._mask.get(name, 0) & (1 << (mem + 1)))

    def mask(self, name: str) -> int:
        return self._mask.get(name, 0)

    def mask_of_ids(self, ids: np.ndarray) -> np.ndarray:
        """Bitmask vector for data ids (attached mode only)."""
        ml = self.mask_list
        return np.fromiter(map(ml.__getitem__, ids), dtype=np.int64, count=len(ids))

    def locations(self, name: str) -> set:
        return set(self._decode(self._mask.get(name, 0)))

    def has_any(self, name: str) -> bool:
        return self._mask.get(name, 0) != 0

    def transfer_hops(self, name: str, dst_mem: int) -> int:
        """1 hop if a copy is on host or dst is host; 2 hops for GPU->GPU
        (device -> host -> device, the paper-era PCIe path)."""
        m = self._mask.get(name, 0)
        if m == 0 or m & _mem_bit(dst_mem):
            return 0
        if dst_mem == HOST_MEM or m & 1:
            return 1
        return 2

    def add_copy(self, name: str, mem: int) -> None:
        if not -1 <= mem <= _MAX_MEM:
            raise ValueError(f"memory id {mem} outside supported range")
        self._set_mask(name, self._mask.get(name, 0) | (1 << (mem + 1)))

    def write(self, name: str, mem: int) -> None:
        if not -1 <= mem <= _MAX_MEM:
            raise ValueError(f"memory id {mem} outside supported range")
        self._set_mask(name, 1 << (mem + 1))

    def write_id(self, did: int, name: str, new_mask: int) -> None:
        """Attached-mode fast write: caller supplies the data id and the
        (validated) single-bit mask. Semantically ``write(name, mem)``."""
        ml = self.mask_list
        old = ml[did]
        if old == new_mask:
            return
        self._mask[name] = new_mask
        ml[did] = new_mask
        size = self._sizes[did]
        rb = self._resident_bytes
        changed = old ^ new_mask
        while changed:
            low = changed & -changed
            idx = low.bit_length() - 1  # == mem + 1
            if new_mask & low:
                rb[idx] += size
            else:
                rb[idx] -= size
            changed ^= low
        if self.observer is not None:
            self.observer(did, name, old, new_mask)

    def drop_copy(self, name: str, mem: int) -> None:
        """Invalidate the copy of ``name`` at ``mem`` (eviction support).

        The inverse of :meth:`add_copy`: clears one residency bit, leaving
        any other valid copies untouched. A no-op when no copy is there.
        """
        if not -1 <= mem <= _MAX_MEM:
            raise ValueError(f"memory id {mem} outside supported range")
        self._set_mask(name, self._mask.get(name, 0) & ~(1 << (mem + 1)))

    def initialize(self, names: Iterable[str], mem: int) -> None:
        for n in names:
            self.write(n, mem)

    def bytes_resident(self, mem: int, sizes: Optional[Dict[str, int]] = None) -> int:
        """Bytes with a valid copy in ``mem``.

        With an explicit ``sizes`` dict this sums exactly those names (the
        original contract); attached instances answer the no-argument form
        from the incremental per-memory vector in O(1).
        """
        if sizes is not None:
            return sum(sz for n, sz in sizes.items() if self.is_resident(n, mem))
        if self._name_to_id is None:
            raise ValueError("bytes_resident() without sizes requires attach()")
        return self._resident_bytes[mem + 1]
