"""Public API of the scheduling core.

Strategy construction lives in ``repro.sched`` (the Policy registry);
``make_strategy`` and the string form of ``run_simulation`` survive here
as thin deprecated shims with bit-identical results.
"""
from __future__ import annotations

import math
import os
import pickle
import threading
import warnings
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .dag import TaskGraph
from .machine import MachineModel
from .simulator import SimResult, Simulator, Strategy


def make_strategy(name: str, backend: Optional[str] = None, **kwargs) -> Strategy:
    """Deprecated shim: build a strategy from a short spec.

    Use :func:`repro.sched.resolve` instead — it accepts the same names
    (``heft`` | ``ws`` | ``dual`` | ``dada`` …) plus query-string kwargs
    (``"dada?alpha=0.5&use_cp=1"``) and the full registered-policy set.
    This wrapper delegates to the registry, so the constructed strategy —
    and every placement it makes — is bit-identical to ``resolve(name)``.
    """
    warnings.warn(
        "make_strategy() is deprecated; use repro.sched.resolve "
        "(same names, plus query-string kwargs like 'dada?alpha=0.5')",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.sched import resolve
    from repro.sched.registry import get_factory, parse_spec

    # keep the historical error wording for unknown names only; real
    # validation errors (bad alpha, unknown affinity) must pass through
    try:
        get_factory(parse_spec(name)[0])
    except ValueError as exc:
        raise ValueError(f"unknown strategy {name.lower()!r}") from exc
    return resolve(name, backend=backend, **kwargs)


def run_simulation(
    graph: TaskGraph,
    machine: MachineModel,
    strategy,
    seed: int = 0,
    noise: float = 0.03,
    config=None,
) -> SimResult:
    if isinstance(strategy, str):
        warnings.warn(
            "passing a strategy name string to run_simulation() is "
            "deprecated; pass repro.sched.resolve(spec) instead",
            DeprecationWarning,
            stacklevel=2,
        )
        from repro.sched import resolve

        strategy = resolve(strategy)
    sim = Simulator(graph, machine, strategy, seed=seed, noise=noise, config=config)
    res = sim.run()
    if sim.audit is not None:
        # REPRO_SCHED_AUDIT=1: every simulation is re-checked by the
        # independent verifier (repro.verify) — precedence, hazards,
        # capacity, byte conservation, fault windows — and a violation is
        # a hard failure, not a benchmark footnote
        from repro.verify import errors as _verify_errors
        from repro.verify import verify_audit

        errs = _verify_errors(verify_audit(sim.audit))
        if errs:
            detail = "; ".join(f"{f.code}: {f.message}" for f in errs[:5])
            raise RuntimeError(
                f"schedule verification failed ({len(errs)} error(s)): {detail}"
            )
    return res


@dataclass
class Summary:
    """Mean + 95% confidence interval over repeated runs (paper methodology:
    >=30 runs per configuration, mean and 95% CI reported)."""

    strategy: str
    n: int
    gflops_mean: float
    gflops_ci95: float
    gbytes_mean: float
    gbytes_ci95: float
    makespan_mean: float
    steals_mean: float

    def row(self) -> str:
        return (
            f"{self.strategy},{self.n},{self.gflops_mean:.2f},{self.gflops_ci95:.2f},"
            f"{self.gbytes_mean:.3f},{self.gbytes_ci95:.3f},{self.makespan_mean:.4f},"
            f"{self.steals_mean:.1f}"
        )


# ---------------------------------------------------------------------------
# parallel seeded runs


_GRAPH_CACHE: Dict[tuple, TaskGraph] = {}


def cached_graph(factory) -> TaskGraph:
    """Memoize graphs built by ``functools.partial`` factories.

    A sweep runs many (strategy × machine) configurations over the *same*
    kernel graph; within one process the graph and its structure-of-arrays
    view are built once per distinct factory signature instead of once per
    configuration. Eviction is LRU one-at-a-time — a full-cache clear used
    to drop *every* graph the moment a 17th signature appeared, which made
    large sweeps (NT=64 interleaved with small kernels) rebuild identical
    multi-second graphs mid-flight. Non-partial factories (closures,
    lambdas) are not memoized.
    """
    try:
        key = (factory.func, factory.args, tuple(sorted(factory.keywords.items())))
        hash(key)
    except (AttributeError, TypeError):
        return factory()
    g = _GRAPH_CACHE.get(key)
    if g is None:
        while len(_GRAPH_CACHE) >= 16:
            _GRAPH_CACHE.pop(next(iter(_GRAPH_CACHE)))
        _GRAPH_CACHE[key] = g = factory()
    else:
        # refresh recency so steady sweep graphs outlive one-off builds
        _GRAPH_CACHE.pop(key)
        _GRAPH_CACHE[key] = g
    return g


_cached_graph = cached_graph  # historical private name


def _run_chunk(
    graph_factory, machine, strategy_factory, seeds: Sequence[int], noise: float
) -> List[Tuple[float, float, float, float, str]]:
    """A chunk of seeded simulations, reduced to summary metrics.

    The task graph is immutable during simulation (all mutable state —
    residency, queues, history model — lives in the Simulator), so one
    graph and its structure-of-arrays view are shared across the chunk's
    seeds (and memoized across chunks with the same partial-factory
    signature); per-run results are identical to building a fresh graph
    per seed.
    """
    graph = _cached_graph(graph_factory)
    out = []
    for seed in seeds:
        strat = strategy_factory()
        res = run_simulation(graph, machine, strat, seed=seed, noise=noise)
        out.append(
            (res.gflops, res.gbytes, res.makespan, float(res.n_steals), strat.name)
        )
    return out


def default_jobs(n_runs: int, config=None) -> int:
    """Worker count for run_many: REPRO_BENCH_JOBS (via SchedConfig),
    else min(cpus, runs). A malformed value raises at config parse time
    (``SchedConfig.from_env``) instead of silently using the CPU count."""
    if config is None:
        from repro.sched.config import current_config

        config = current_config()
    if config.bench_jobs is not None:
        return max(1, config.bench_jobs)
    return max(1, min(os.cpu_count() or 1, n_runs))


_POOL = None
_POOL_JOBS = 0
_POOL_LOCK = threading.Lock()


def get_pool(n_jobs: Optional[int] = None):
    """Public handle on the shared simulation process pool.

    Creating it early — before spawning any threads that will submit to
    it — also sidesteps the fork-after-threads hazard (forking workers
    while sibling threads hold allocator/stdio locks can deadlock the
    children on some platforms).
    """
    if n_jobs is None:
        n_jobs = default_jobs(os.cpu_count() or 1)
    return _get_pool(n_jobs)


def _get_pool(n_jobs: int):
    """Lazily build (and reuse) one process pool; fork context when available
    so repeated run_many calls don't pay per-call interpreter startup.

    Thread-safe: concurrent sweeps share the same executor. The pool is
    sized once, at first use, from REPRO_BENCH_JOBS (or the CPU count) —
    it is never resized or shut down afterwards, because cancelling would
    kill in-flight futures belonging to other threads."""
    global _POOL, _POOL_JOBS
    with _POOL_LOCK:
        if _POOL is not None:
            return _POOL
        import concurrent.futures as cf
        import multiprocessing as mp

        ctx = None
        if "fork" in mp.get_all_start_methods():
            ctx = mp.get_context("fork")
        # stable width independent of any one call's n_jobs, so the first
        # caller doesn't pin concurrent sweeps to an undersized pool
        workers = max(n_jobs, default_jobs(os.cpu_count() or 1))
        _POOL = cf.ProcessPoolExecutor(max_workers=workers, mp_context=ctx)
        _POOL_JOBS = workers
        return _POOL


def run_many(
    graph_factory,
    machine: MachineModel,
    strategy_factory,
    n_runs: int = 30,
    noise: float = 0.03,
    base_seed: int = 1234,
    n_jobs: Optional[int] = None,
) -> Summary:
    """Run ``n_runs`` seeded simulations and summarize (mean, 95% CI).

    ``graph_factory`` and ``strategy_factory`` are callables so each run gets
    fresh graph/strategy state (the history model calibrates within a run).

    Runs fan out over a process pool (``n_jobs`` workers; default from
    ``REPRO_BENCH_JOBS`` or the CPU count). Each run is independently
    seeded, so the summary is bit-identical to the serial path regardless
    of worker count; results are gathered in seed order. Falls back to the
    serial loop when ``n_jobs == 1``, when the factories are not picklable
    (e.g. test-local closures), or when the pool cannot be created.
    """
    if n_jobs is None:
        n_jobs = default_jobs(n_runs)
    seeds = [base_seed + i for i in range(n_runs)]

    futs = None
    if n_jobs > 1 and n_runs > 1:
        # contiguous seed chunks, one per worker; gathered in order, so the
        # summary is bit-identical to the serial path
        n_chunks = min(n_jobs, n_runs)
        bounds = [round(i * n_runs / n_chunks) for i in range(n_chunks + 1)]
        chunks = [seeds[a:b] for a, b in zip(bounds, bounds[1:]) if b > a]
        try:
            pickle.dumps((graph_factory, machine, strategy_factory))
            pool = _get_pool(n_jobs)
            futs = [
                pool.submit(_run_chunk, graph_factory, machine, strategy_factory, c, noise)
                for c in chunks
            ]
        except Exception:
            futs = None  # non-picklable factories or pool failure: go serial
    if futs is not None:
        # gathered outside the guard: a simulation error in a worker is a
        # real failure and must propagate, not trigger a serial re-run
        rows = [r for f in futs for r in f.result()]
    else:
        rows = _run_chunk(graph_factory, machine, strategy_factory, seeds, noise)

    gf = [r[0] for r in rows]
    gb = [r[1] for r in rows]
    mk = [r[2] for r in rows]
    st = [r[3] for r in rows]
    name = rows[-1][4] if rows else ""

    def ci95(xs: Sequence[float]) -> float:
        if len(xs) < 2:
            return 0.0
        return 1.96 * float(np.std(xs, ddof=1)) / math.sqrt(len(xs))

    return Summary(
        strategy=name,
        n=n_runs,
        gflops_mean=float(np.mean(gf)),
        gflops_ci95=ci95(gf),
        gbytes_mean=float(np.mean(gb)),
        gbytes_ci95=ci95(gb),
        makespan_mean=float(np.mean(mk)),
        steals_mean=float(np.mean(st)),
    )

# ---------------------------------------------------------------------------
# batched surrogate episodes (REPRO_SCHED_EXACT=0)


@dataclass(frozen=True)
class BatchResult:
    """One configuration's surrogate-episode outcome.

    Mirrors the :class:`SimResult` metric surface (``gflops`` / ``gbytes``
    derived the same way) so sweep code can consume either engine's
    results through one row schema.
    """

    strategy: str
    seed: int
    makespan: float
    total_bytes: float
    total_flops: float
    n_steals: int = 0

    @property
    def gflops(self) -> float:
        if self.makespan <= 0:
            return 0.0
        return self.total_flops / self.makespan / 1e9

    @property
    def gbytes(self) -> float:
        return self.total_bytes / 1e9


def run_batch(configs: Sequence[dict], config=None) -> List[BatchResult]:
    """Run a batch of scheduling configurations as a few compiled dispatches.

    Each item of ``configs`` is a mapping::

        {"graph": TaskGraph | partial-factory, "machine": MachineModel,
         "strategy": "dada?alpha=0.5&use_cp=1",  # heft | ws | dada | dual
         "seed": 1234, "noise": 0.03, "capacity": 0}

    Items are grouped by (graph, machine template) — machine *shapes*
    (GPU counts), strategy parameters, seeds and capacities are batch
    axes inside a group — then each group runs through the surrogate
    episode engine (:mod:`repro.core.episode`) in chunks of at most
    ``SchedConfig.batch`` (``REPRO_SCHED_BATCH``) configurations per
    dispatch. Results come back in input order.

    This is the approximate engine: placements relax the oracle's
    tie-breaking (see the module docstring of ``repro.core.episode``),
    so use it for sweeps and searches, and the exact engine
    (:func:`run_simulation` / :func:`run_many`) for verification. It
    requires the jax backend; a numpy-only environment raises instead
    of silently falling back to the exact path.
    """
    from repro.core import episode as ep

    if config is None:
        from repro.sched.config import current_config

        config = current_config()
    try:
        import jax  # noqa: F401
    except Exception as exc:  # pragma: no cover - jax baked into CI images
        raise RuntimeError(
            "run_batch needs the jax backend for the batched surrogate "
            "engine; install jax or use run_many on the exact path"
        ) from exc

    # resolve graphs and group by (graph, machine template)
    items = []
    for i, c in enumerate(configs):
        g = c["graph"]
        if not isinstance(g, TaskGraph):
            g = cached_graph(g)
        items.append((i, g, c))

    groups: Dict[tuple, list] = {}
    for i, g, c in items:
        m: MachineModel = c["machine"]
        cpu = next((r.cls for r in m.resources if not r.is_accelerator), None)
        gpu = next((r.cls for r in m.resources if r.is_accelerator), None)
        key = (
            id(g), len(m.resources),
            cpu.name if cpu else None, gpu.name if gpu else None,
            m.link.bandwidth, m.link.latency,
        )
        groups.setdefault(key, []).append((i, g, c))

    out: List[Optional[BatchResult]] = [None] * len(items)
    chunk_cap = max(1, int(config.batch))
    for group in groups.values():
        g = group[0][1]
        machines = {}
        max_mem = -1
        for _, _, c in group:
            m = c["machine"]
            if id(m) not in machines:
                machines[id(m)] = m
            max_mem = max(
                max_mem,
                max((r.mem for r in m.resources if r.is_accelerator), default=-1),
            )
        plan = ep.build_plan(g, group[0][2]["machine"], n_u=max_mem + 2)
        axes = {
            mid: ep.machine_axes(m, plan.n_res) for mid, m in machines.items()
        }
        # One dispatch shape for the whole group: episode cost is linear
        # in the batch axis (no fixed-overhead amortisation from bigger
        # batches), so split into same-shaped chunks — one compile per
        # (kernel, shape) key — and fan the dispatches out over threads
        # (XLA drops the GIL during execution).
        from repro.core.backend import _bucket

        # 16 rows per dispatch: episode cost per config is flat across
        # B∈{16..256} on CPU, so narrow chunks minimise padding waste and
        # let every group share one compiled shape; REPRO_SCHED_BATCH
        # caps it lower for memory-constrained runs
        n_workers = min(8, os.cpu_count() or 1)
        size = min(chunk_cap, 16)
        pad_to = _bucket(min(size, len(group)), lo=8)
        chunks = [group[lo : lo + size] for lo in range(0, len(group), size)]

        def dispatch(chunk):
            isg, val, mc, lg = [], [], [], []
            al, cp, ws, nz, cap = [], [], [], [], []
            for _, _, c in chunk:
                a, u, w = ep.surrogate_params(c["strategy"])
                ig, vl, m_c, l_g = axes[id(c["machine"])]
                isg.append(ig)
                val.append(vl)
                mc.append(m_c)
                lg.append(l_g)
                al.append(a)
                cp.append(u)
                ws.append(w)
                nz.append(
                    ep.noise_factors(
                        int(c.get("seed", 0)), float(c.get("noise", 0.03)),
                        plan.n, plan.n_pad,
                    )
                )
                capacity = float(c.get("capacity", 0) or 0)
                cap.append(capacity if capacity > 0 else np.inf)
            batch = ep.EpisodeBatch(
                is_gpu=np.stack(isg), valid_res=np.stack(val),
                mem_col=np.stack(mc), link_grp=np.stack(lg),
                alpha=np.array(al),
                use_cp=np.array(cp), ws_pref=np.array(ws, dtype=bool),
                noise=np.stack(nz), cap=np.array(cap),
            )
            return ep.run_episodes(plan, batch, config=config, pad_to=pad_to)

        if len(chunks) > 1 and n_workers > 1:
            # warm the compile on the first chunk, then dispatch the rest
            # concurrently against the cached executable
            results = [dispatch(chunks[0])]
            with ThreadPoolExecutor(max_workers=n_workers) as tp:
                results += list(tp.map(dispatch, chunks[1:]))
        else:
            results = [dispatch(ch) for ch in chunks]

        for chunk, res in zip(chunks, results):
            for j, (i, _, c) in enumerate(chunk):
                out[i] = BatchResult(
                    strategy=c["strategy"],
                    seed=int(c.get("seed", 0)),
                    makespan=float(res["makespan"][j]),
                    total_bytes=float(res["total_bytes"][j]),
                    total_flops=plan.total_flops,
                )
    return out  # type: ignore[return-value]
