"""Public API of the scheduling core.

Strategy construction lives in ``repro.sched`` (the Policy registry);
``make_strategy`` and the string form of ``run_simulation`` survive here
as thin deprecated shims with bit-identical results.
"""
from __future__ import annotations

import math
import os
import pickle
import threading
import warnings
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .dag import TaskGraph
from .machine import MachineModel
from .simulator import SimResult, Simulator, Strategy


def make_strategy(name: str, backend: Optional[str] = None, **kwargs) -> Strategy:
    """Deprecated shim: build a strategy from a short spec.

    Use :func:`repro.sched.resolve` instead — it accepts the same names
    (``heft`` | ``ws`` | ``dual`` | ``dada`` …) plus query-string kwargs
    (``"dada?alpha=0.5&use_cp=1"``) and the full registered-policy set.
    This wrapper delegates to the registry, so the constructed strategy —
    and every placement it makes — is bit-identical to ``resolve(name)``.
    """
    warnings.warn(
        "make_strategy() is deprecated; use repro.sched.resolve "
        "(same names, plus query-string kwargs like 'dada?alpha=0.5')",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.sched import resolve
    from repro.sched.registry import get_factory, parse_spec

    # keep the historical error wording for unknown names only; real
    # validation errors (bad alpha, unknown affinity) must pass through
    try:
        get_factory(parse_spec(name)[0])
    except ValueError as exc:
        raise ValueError(f"unknown strategy {name.lower()!r}") from exc
    return resolve(name, backend=backend, **kwargs)


def run_simulation(
    graph: TaskGraph,
    machine: MachineModel,
    strategy,
    seed: int = 0,
    noise: float = 0.03,
    config=None,
) -> SimResult:
    if isinstance(strategy, str):
        warnings.warn(
            "passing a strategy name string to run_simulation() is "
            "deprecated; pass repro.sched.resolve(spec) instead",
            DeprecationWarning,
            stacklevel=2,
        )
        from repro.sched import resolve

        strategy = resolve(strategy)
    sim = Simulator(graph, machine, strategy, seed=seed, noise=noise, config=config)
    return sim.run()


@dataclass
class Summary:
    """Mean + 95% confidence interval over repeated runs (paper methodology:
    >=30 runs per configuration, mean and 95% CI reported)."""

    strategy: str
    n: int
    gflops_mean: float
    gflops_ci95: float
    gbytes_mean: float
    gbytes_ci95: float
    makespan_mean: float
    steals_mean: float

    def row(self) -> str:
        return (
            f"{self.strategy},{self.n},{self.gflops_mean:.2f},{self.gflops_ci95:.2f},"
            f"{self.gbytes_mean:.3f},{self.gbytes_ci95:.3f},{self.makespan_mean:.4f},"
            f"{self.steals_mean:.1f}"
        )


# ---------------------------------------------------------------------------
# parallel seeded runs


_GRAPH_CACHE: Dict[tuple, TaskGraph] = {}


def _cached_graph(factory) -> TaskGraph:
    """Memoize graphs built by ``functools.partial`` factories.

    A sweep runs many (strategy × machine) configurations over the *same*
    kernel graph; within one (worker) process the graph and its
    structure-of-arrays view are built once per distinct factory signature
    instead of once per configuration. Non-partial factories (closures,
    lambdas) are not memoized.
    """
    try:
        key = (factory.func, factory.args, tuple(sorted(factory.keywords.items())))
        hash(key)
    except (AttributeError, TypeError):
        return factory()
    g = _GRAPH_CACHE.get(key)
    if g is None:
        if len(_GRAPH_CACHE) >= 16:
            _GRAPH_CACHE.clear()
        _GRAPH_CACHE[key] = g = factory()
    return g


def _run_chunk(
    graph_factory, machine, strategy_factory, seeds: Sequence[int], noise: float
) -> List[Tuple[float, float, float, float, str]]:
    """A chunk of seeded simulations, reduced to summary metrics.

    The task graph is immutable during simulation (all mutable state —
    residency, queues, history model — lives in the Simulator), so one
    graph and its structure-of-arrays view are shared across the chunk's
    seeds (and memoized across chunks with the same partial-factory
    signature); per-run results are identical to building a fresh graph
    per seed.
    """
    graph = _cached_graph(graph_factory)
    out = []
    for seed in seeds:
        strat = strategy_factory()
        res = run_simulation(graph, machine, strat, seed=seed, noise=noise)
        out.append(
            (res.gflops, res.gbytes, res.makespan, float(res.n_steals), strat.name)
        )
    return out


def default_jobs(n_runs: int, config=None) -> int:
    """Worker count for run_many: REPRO_BENCH_JOBS (via SchedConfig),
    else min(cpus, runs). A malformed value raises at config parse time
    (``SchedConfig.from_env``) instead of silently using the CPU count."""
    if config is None:
        from repro.sched.config import current_config

        config = current_config()
    if config.bench_jobs is not None:
        return max(1, config.bench_jobs)
    return max(1, min(os.cpu_count() or 1, n_runs))


_POOL = None
_POOL_JOBS = 0
_POOL_LOCK = threading.Lock()


def get_pool(n_jobs: Optional[int] = None):
    """Public handle on the shared simulation process pool.

    Creating it early — before spawning any threads that will submit to
    it — also sidesteps the fork-after-threads hazard (forking workers
    while sibling threads hold allocator/stdio locks can deadlock the
    children on some platforms).
    """
    if n_jobs is None:
        n_jobs = default_jobs(os.cpu_count() or 1)
    return _get_pool(n_jobs)


def _get_pool(n_jobs: int):
    """Lazily build (and reuse) one process pool; fork context when available
    so repeated run_many calls don't pay per-call interpreter startup.

    Thread-safe: concurrent sweeps share the same executor. The pool is
    sized once, at first use, from REPRO_BENCH_JOBS (or the CPU count) —
    it is never resized or shut down afterwards, because cancelling would
    kill in-flight futures belonging to other threads."""
    global _POOL, _POOL_JOBS
    with _POOL_LOCK:
        if _POOL is not None:
            return _POOL
        import concurrent.futures as cf
        import multiprocessing as mp

        ctx = None
        if "fork" in mp.get_all_start_methods():
            ctx = mp.get_context("fork")
        # stable width independent of any one call's n_jobs, so the first
        # caller doesn't pin concurrent sweeps to an undersized pool
        workers = max(n_jobs, default_jobs(os.cpu_count() or 1))
        _POOL = cf.ProcessPoolExecutor(max_workers=workers, mp_context=ctx)
        _POOL_JOBS = workers
        return _POOL


def run_many(
    graph_factory,
    machine: MachineModel,
    strategy_factory,
    n_runs: int = 30,
    noise: float = 0.03,
    base_seed: int = 1234,
    n_jobs: Optional[int] = None,
) -> Summary:
    """Run ``n_runs`` seeded simulations and summarize (mean, 95% CI).

    ``graph_factory`` and ``strategy_factory`` are callables so each run gets
    fresh graph/strategy state (the history model calibrates within a run).

    Runs fan out over a process pool (``n_jobs`` workers; default from
    ``REPRO_BENCH_JOBS`` or the CPU count). Each run is independently
    seeded, so the summary is bit-identical to the serial path regardless
    of worker count; results are gathered in seed order. Falls back to the
    serial loop when ``n_jobs == 1``, when the factories are not picklable
    (e.g. test-local closures), or when the pool cannot be created.
    """
    if n_jobs is None:
        n_jobs = default_jobs(n_runs)
    seeds = [base_seed + i for i in range(n_runs)]

    futs = None
    if n_jobs > 1 and n_runs > 1:
        # contiguous seed chunks, one per worker; gathered in order, so the
        # summary is bit-identical to the serial path
        n_chunks = min(n_jobs, n_runs)
        bounds = [round(i * n_runs / n_chunks) for i in range(n_chunks + 1)]
        chunks = [seeds[a:b] for a, b in zip(bounds, bounds[1:]) if b > a]
        try:
            pickle.dumps((graph_factory, machine, strategy_factory))
            pool = _get_pool(n_jobs)
            futs = [
                pool.submit(_run_chunk, graph_factory, machine, strategy_factory, c, noise)
                for c in chunks
            ]
        except Exception:
            futs = None  # non-picklable factories or pool failure: go serial
    if futs is not None:
        # gathered outside the guard: a simulation error in a worker is a
        # real failure and must propagate, not trigger a serial re-run
        rows = [r for f in futs for r in f.result()]
    else:
        rows = _run_chunk(graph_factory, machine, strategy_factory, seeds, noise)

    gf = [r[0] for r in rows]
    gb = [r[1] for r in rows]
    mk = [r[2] for r in rows]
    st = [r[3] for r in rows]
    name = rows[-1][4] if rows else ""

    def ci95(xs: Sequence[float]) -> float:
        if len(xs) < 2:
            return 0.0
        return 1.96 * float(np.std(xs, ddof=1)) / math.sqrt(len(xs))

    return Summary(
        strategy=name,
        n=n_runs,
        gflops_mean=float(np.mean(gf)),
        gflops_ci95=ci95(gf),
        gbytes_mean=float(np.mean(gb)),
        gbytes_ci95=ci95(gb),
        makespan_mean=float(np.mean(mk)),
        steals_mean=float(np.mean(st)),
    )
