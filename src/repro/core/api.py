"""Public API of the scheduling core."""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from .dag import TaskGraph
from .dada import DADA, DualApprox
from .heft import HEFT
from .machine import MachineModel
from .simulator import SimResult, Simulator, Strategy
from .worksteal import WorkSteal


def make_strategy(name: str, **kwargs) -> Strategy:
    """Build a strategy from a short spec.

    ``heft`` | ``ws`` | ``dual`` | ``dada`` (kwargs: alpha, use_cp, affinity).
    """
    name = name.lower()
    if name == "heft":
        return HEFT()
    if name == "ws":
        return WorkSteal()
    if name == "dual":
        return DualApprox(**kwargs)
    if name == "dada":
        return DADA(**kwargs)
    raise ValueError(f"unknown strategy {name!r}")


def run_simulation(
    graph: TaskGraph,
    machine: MachineModel,
    strategy,
    seed: int = 0,
    noise: float = 0.03,
) -> SimResult:
    if isinstance(strategy, str):
        strategy = make_strategy(strategy)
    sim = Simulator(graph, machine, strategy, seed=seed, noise=noise)
    return sim.run()


@dataclass
class Summary:
    """Mean + 95% confidence interval over repeated runs (paper methodology:
    >=30 runs per configuration, mean and 95% CI reported)."""

    strategy: str
    n: int
    gflops_mean: float
    gflops_ci95: float
    gbytes_mean: float
    gbytes_ci95: float
    makespan_mean: float
    steals_mean: float

    def row(self) -> str:
        return (
            f"{self.strategy},{self.n},{self.gflops_mean:.2f},{self.gflops_ci95:.2f},"
            f"{self.gbytes_mean:.3f},{self.gbytes_ci95:.3f},{self.makespan_mean:.4f},"
            f"{self.steals_mean:.1f}"
        )


def run_many(
    graph_factory,
    machine: MachineModel,
    strategy_factory,
    n_runs: int = 30,
    noise: float = 0.03,
    base_seed: int = 1234,
) -> Summary:
    """Run ``n_runs`` seeded simulations and summarize (mean, 95% CI).

    ``graph_factory`` and ``strategy_factory`` are callables so each run gets
    fresh graph/strategy state (the history model calibrates within a run).
    """
    gf: List[float] = []
    gb: List[float] = []
    mk: List[float] = []
    st: List[float] = []
    name = ""
    for i in range(n_runs):
        graph = graph_factory()
        strat = strategy_factory()
        name = strat.name
        res = run_simulation(graph, machine, strat, seed=base_seed + i, noise=noise)
        gf.append(res.gflops)
        gb.append(res.gbytes)
        mk.append(res.makespan)
        st.append(res.n_steals)

    def ci95(xs: Sequence[float]) -> float:
        if len(xs) < 2:
            return 0.0
        return 1.96 * float(np.std(xs, ddof=1)) / math.sqrt(len(xs))

    return Summary(
        strategy=name,
        n=n_runs,
        gflops_mean=float(np.mean(gf)),
        gflops_ci95=ci95(gf),
        gbytes_mean=float(np.mean(gb)),
        gbytes_ci95=ci95(gb),
        makespan_mean=float(np.mean(mk)),
        steals_mean=float(np.mean(st)),
    )
