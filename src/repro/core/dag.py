"""Data-flow task graph, XKaapi-style.

Tasks declare typed accesses (READ / WRITE / RW) on named data objects.
Dependencies are derived from access modes in *program order*, exactly as a
data-flow runtime does it:

  RAW: a reader depends on the last writer of the data.
  WAW: a writer depends on the last writer.
  WAR: a writer depends on every reader since the last writer.

This mirrors XKaapi semantics ("parallelism is explicit while the detection
of synchronizations is implicit").
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple


class Mode(enum.Enum):
    R = "r"
    W = "w"
    RW = "rw"

    @property
    def reads(self) -> bool:
        return self in (Mode.R, Mode.RW)

    @property
    def writes(self) -> bool:
        return self in (Mode.W, Mode.RW)


@dataclass(frozen=True)
class DataObject:
    """A named, sized piece of data (e.g. a matrix tile)."""

    name: str
    size_bytes: int
    # Free-form payload handle used by executors (e.g. tile coordinates).
    meta: Any = None

    def __repr__(self) -> str:  # keep logs short
        return f"Data({self.name},{self.size_bytes}B)"


@dataclass(frozen=True)
class Access:
    data: DataObject
    mode: Mode


@dataclass
class Task:
    """A unit of work with data accesses and per-kind cost metadata."""

    tid: int
    kind: str
    accesses: Tuple[Access, ...]
    flops: float = 0.0
    # Optional: callable executed by the JAX executor; signature
    # fn(*input_arrays) -> tuple of output arrays matching write accesses.
    fn: Optional[Callable] = None
    tag: Any = None

    @property
    def reads(self) -> Tuple[DataObject, ...]:
        return tuple(a.data for a in self.accesses if a.mode.reads)

    @property
    def writes(self) -> Tuple[DataObject, ...]:
        return tuple(a.data for a in self.accesses if a.mode.writes)

    @property
    def read_bytes(self) -> int:
        return sum(d.size_bytes for d in self.reads)

    @property
    def write_bytes(self) -> int:
        return sum(d.size_bytes for d in self.writes)

    def __repr__(self) -> str:
        return f"Task({self.tid}:{self.kind})"


class TaskGraph:
    """A DAG built by appending tasks in program order (data-flow semantics)."""

    def __init__(self) -> None:
        self.tasks: List[Task] = []
        self.succ: Dict[int, List[int]] = {}
        self.pred: Dict[int, List[int]] = {}
        # data-flow bookkeeping (program-order construction state)
        self._last_writer: Dict[str, int] = {}
        self._readers_since_write: Dict[str, List[int]] = {}

    # ------------------------------------------------------------------
    def add_task(
        self,
        kind: str,
        accesses: Sequence[Tuple[DataObject, Mode]],
        flops: float = 0.0,
        fn: Optional[Callable] = None,
        tag: Any = None,
    ) -> Task:
        tid = len(self.tasks)
        task = Task(
            tid=tid,
            kind=kind,
            accesses=tuple(Access(d, m) for d, m in accesses),
            flops=flops,
            fn=fn,
            tag=tag,
        )
        self.tasks.append(task)
        self.succ[tid] = []
        self.pred[tid] = []

        deps: set = set()
        for acc in task.accesses:
            key = acc.data.name
            if acc.mode.reads:
                lw = self._last_writer.get(key)
                if lw is not None:
                    deps.add(lw)  # RAW
            if acc.mode.writes:
                lw = self._last_writer.get(key)
                if lw is not None:
                    deps.add(lw)  # WAW
                for r in self._readers_since_write.get(key, ()):  # WAR
                    deps.add(r)
        deps.discard(tid)
        for d in sorted(deps):
            self.succ[d].append(tid)
            self.pred[tid].append(d)

        # update construction state *after* dep computation
        for acc in task.accesses:
            key = acc.data.name
            if acc.mode.writes:
                self._last_writer[key] = tid
                self._readers_since_write[key] = []
            if acc.mode.reads and not acc.mode.writes:
                self._readers_since_write.setdefault(key, []).append(tid)
        return task

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.tasks)

    @property
    def n_edges(self) -> int:
        return sum(len(s) for s in self.succ.values())

    def roots(self) -> List[Task]:
        return [t for t in self.tasks if not self.pred[t.tid]]

    def data_objects(self) -> Dict[str, DataObject]:
        out: Dict[str, DataObject] = {}
        for t in self.tasks:
            for a in t.accesses:
                out[a.data.name] = a.data
        return out

    def total_flops(self) -> float:
        return sum(t.flops for t in self.tasks)

    def topo_order(self) -> List[int]:
        """Kahn topological order (deterministic: ready set kept sorted)."""
        indeg = {t.tid: len(self.pred[t.tid]) for t in self.tasks}
        ready = sorted(tid for tid, d in indeg.items() if d == 0)
        order: List[int] = []
        import heapq

        heapq.heapify(ready)
        while ready:
            tid = heapq.heappop(ready)
            order.append(tid)
            for s in self.succ[tid]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    heapq.heappush(ready, s)
        if len(order) != len(self.tasks):
            raise ValueError("cycle detected in task graph")
        return order

    def critical_path_length(self, cost: Callable[[Task], float]) -> float:
        """Longest path using per-task cost (a makespan lower bound)."""
        dist: Dict[int, float] = {}
        for tid in self.topo_order():
            t = self.tasks[tid]
            base = max((dist[p] for p in self.pred[tid]), default=0.0)
            dist[tid] = base + cost(t)
        return max(dist.values(), default=0.0)
