"""Data-flow task graph, XKaapi-style.

Tasks declare typed accesses (READ / WRITE / RW) on named data objects.
Dependencies are derived from access modes in *program order*, exactly as a
data-flow runtime does it:

  RAW: a reader depends on the last writer of the data.
  WAW: a writer depends on the last writer.
  WAR: a writer depends on every reader since the last writer.

This mirrors XKaapi semantics ("parallelism is explicit while the detection
of synchronizations is implicit").
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np


class Mode(enum.Enum):
    R = "r"
    W = "w"
    RW = "rw"

    @property
    def reads(self) -> bool:
        return self in (Mode.R, Mode.RW)

    @property
    def writes(self) -> bool:
        return self in (Mode.W, Mode.RW)


@dataclass(frozen=True)
class DataObject:
    """A named, sized piece of data (e.g. a matrix tile)."""

    name: str
    size_bytes: int
    # Free-form payload handle used by executors (e.g. tile coordinates).
    meta: Any = None

    def __repr__(self) -> str:  # keep logs short
        return f"Data({self.name},{self.size_bytes}B)"


@dataclass(frozen=True)
class Access:
    data: DataObject
    mode: Mode


@dataclass
class Task:
    """A unit of work with data accesses and per-kind cost metadata."""

    tid: int
    kind: str
    accesses: Tuple[Access, ...]
    flops: float = 0.0
    # Optional: callable executed by the JAX executor; signature
    # fn(*input_arrays) -> tuple of output arrays matching write accesses.
    fn: Optional[Callable] = None
    tag: Any = None

    @property
    def reads(self) -> Tuple[DataObject, ...]:
        return tuple(a.data for a in self.accesses if a.mode.reads)

    @property
    def writes(self) -> Tuple[DataObject, ...]:
        return tuple(a.data for a in self.accesses if a.mode.writes)

    @property
    def read_bytes(self) -> int:
        return sum(d.size_bytes for d in self.reads)

    @property
    def write_bytes(self) -> int:
        return sum(d.size_bytes for d in self.writes)

    def __repr__(self) -> str:
        return f"Task({self.tid}:{self.kind})"


class GraphArrays:
    """Structure-of-arrays view of a :class:`TaskGraph`.

    Built once per graph and shared by every consumer that wants batched
    (numpy) access instead of walking ``Task`` objects: int-coded task
    kinds, a flops vector, and CSR read/write incidence over int-coded
    data objects. Sizes are stored *per access* (``read_sizes`` aligns
    with ``read_ids``) so graphs that rebind a name to a differently
    sized object keep the exact per-access semantics of ``Task.reads``.
    """

    __slots__ = (
        "n_tasks", "kinds", "kind_codes", "flops",
        "data_names", "name_to_id", "data_sizes",
        "read_indptr", "read_ids", "read_sizes",
        "write_indptr", "write_ids", "write_sizes",
        "acc_indptr", "acc_ids", "acc_sizes", "acc_writes", "acc_first",
        "task_reads", "task_writes", "cache",
    )

    def __init__(self, graph: "TaskGraph") -> None:
        tasks = graph.tasks
        n = len(tasks)
        self.n_tasks = n
        kind_index: Dict[str, int] = {}
        kind_codes = np.empty(n, dtype=np.int32)
        flops = np.empty(n, dtype=np.float64)
        self.name_to_id: Dict[str, int] = {}
        self.data_names: List[str] = []
        sizes: List[int] = []

        r_indptr = np.empty(n + 1, dtype=np.int64)
        w_indptr = np.empty(n + 1, dtype=np.int64)
        a_indptr = np.empty(n + 1, dtype=np.int64)
        r_ids: List[int] = []
        r_sizes: List[int] = []
        w_ids: List[int] = []
        w_sizes: List[int] = []
        a_ids: List[int] = []
        a_sizes: List[int] = []
        a_writes: List[bool] = []
        a_first: List[bool] = []
        # per-task (data_id, name, size_bytes) triples for scalar hot loops
        self.task_reads: List[List[Tuple[int, str, int]]] = []
        self.task_writes: List[List[Tuple[int, str, int]]] = []

        r_indptr[0] = w_indptr[0] = a_indptr[0] = 0
        for t in tasks:
            kind_codes[t.tid] = kind_index.setdefault(t.kind, len(kind_index))
            flops[t.tid] = t.flops
            tr: List[Tuple[int, str, int]] = []
            tw: List[Tuple[int, str, int]] = []
            seen: set = set()
            for a in t.accesses:
                name = a.data.name
                did = self.name_to_id.get(name)
                if did is None:
                    did = len(self.data_names)
                    self.name_to_id[name] = did
                    self.data_names.append(name)
                    sizes.append(a.data.size_bytes)
                else:
                    # match TaskGraph.data_objects(): last access wins
                    sizes[did] = a.data.size_bytes
                a_ids.append(did)
                a_sizes.append(a.data.size_bytes)
                a_writes.append(a.mode.writes)
                a_first.append(name not in seen)
                seen.add(name)
                if a.mode.reads:
                    r_ids.append(did)
                    r_sizes.append(a.data.size_bytes)
                    tr.append((did, name, a.data.size_bytes))
                if a.mode.writes:
                    w_ids.append(did)
                    w_sizes.append(a.data.size_bytes)
                    tw.append((did, name, a.data.size_bytes))
            r_indptr[t.tid + 1] = len(r_ids)
            w_indptr[t.tid + 1] = len(w_ids)
            a_indptr[t.tid + 1] = len(a_ids)
            self.task_reads.append(tr)
            self.task_writes.append(tw)

        self.kinds: List[str] = [k for k, _ in sorted(kind_index.items(), key=lambda kv: kv[1])]
        self.kind_codes = kind_codes
        self.flops = flops
        self.data_sizes = np.asarray(sizes, dtype=np.int64)
        self.read_indptr = r_indptr
        self.read_ids = np.asarray(r_ids, dtype=np.int64)
        self.read_sizes = np.asarray(r_sizes, dtype=np.float64)
        self.write_indptr = w_indptr
        self.write_ids = np.asarray(w_ids, dtype=np.int64)
        self.write_sizes = np.asarray(w_sizes, dtype=np.float64)
        self.acc_indptr = a_indptr
        self.acc_ids = np.asarray(a_ids, dtype=np.int64)
        self.acc_sizes = np.asarray(a_sizes, dtype=np.float64)
        self.acc_writes = np.asarray(a_writes, dtype=bool)
        self.acc_first = np.asarray(a_first, dtype=bool)
        # scratch space for consumers that cache derived arrays (affinity
        # weights, per-class static times, ...) keyed by their own tags
        self.cache: Dict[Any, Any] = {}

    # ------------------------------------------------------------------
    def gather_csr(
        self, tids: np.ndarray, indptr: np.ndarray, *arrays: np.ndarray
    ) -> Tuple[np.ndarray, ...]:
        """Gather CSR rows ``tids``: returns (row_indptr, gathered arrays...).

        ``row_indptr`` has ``len(tids)+1`` entries delimiting each task's
        slice in the concatenated output, preserving per-access order.
        """
        starts = indptr[tids]
        ends = indptr[tids + 1]
        counts = ends - starts
        out_indptr = np.empty(len(tids) + 1, dtype=np.int64)
        out_indptr[0] = 0
        np.cumsum(counts, out=out_indptr[1:])
        total = int(out_indptr[-1])
        if total == 0:
            flat = np.empty(0, dtype=np.int64)
            return (out_indptr,) + tuple(
                np.empty(0, dtype=a.dtype) for a in arrays
            )
        # flat index vector: for each row, starts[i] + [0..counts[i])
        flat = np.repeat(starts - out_indptr[:-1], counts) + np.arange(total)
        return (out_indptr,) + tuple(a[flat] for a in arrays)


class TaskGraph:
    """A DAG built by appending tasks in program order (data-flow semantics)."""

    def __init__(self) -> None:
        self.tasks: List[Task] = []
        self.succ: Dict[int, List[int]] = {}
        self.pred: Dict[int, List[int]] = {}
        # data-flow bookkeeping (program-order construction state)
        self._last_writer: Dict[str, int] = {}
        self._readers_since_write: Dict[str, List[int]] = {}
        self._arrays: Optional[GraphArrays] = None

    # ------------------------------------------------------------------
    def add_task(
        self,
        kind: str,
        accesses: Sequence[Tuple[DataObject, Mode]],
        flops: float = 0.0,
        fn: Optional[Callable] = None,
        tag: Any = None,
    ) -> Task:
        tid = len(self.tasks)
        task = Task(
            tid=tid,
            kind=kind,
            accesses=tuple(Access(d, m) for d, m in accesses),
            flops=flops,
            fn=fn,
            tag=tag,
        )
        self.tasks.append(task)
        self.succ[tid] = []
        self.pred[tid] = []
        self._arrays = None  # invalidate the structure-of-arrays view

        deps: set = set()
        for acc in task.accesses:
            key = acc.data.name
            if acc.mode.reads:
                lw = self._last_writer.get(key)
                if lw is not None:
                    deps.add(lw)  # RAW
            if acc.mode.writes:
                lw = self._last_writer.get(key)
                if lw is not None:
                    deps.add(lw)  # WAW
                for r in self._readers_since_write.get(key, ()):  # WAR
                    deps.add(r)
        deps.discard(tid)
        for d in sorted(deps):
            self.succ[d].append(tid)
            self.pred[tid].append(d)

        # update construction state *after* dep computation
        for acc in task.accesses:
            key = acc.data.name
            if acc.mode.writes:
                self._last_writer[key] = tid
                self._readers_since_write[key] = []
            if acc.mode.reads and not acc.mode.writes:
                self._readers_since_write.setdefault(key, []).append(tid)
        return task

    # ------------------------------------------------------------------
    def arrays(self) -> GraphArrays:
        """Structure-of-arrays view (built once, invalidated by add_task)."""
        if self._arrays is None:
            self._arrays = GraphArrays(self)
        return self._arrays

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.tasks)

    @property
    def n_edges(self) -> int:
        return sum(len(s) for s in self.succ.values())

    def roots(self) -> List[Task]:
        return [t for t in self.tasks if not self.pred[t.tid]]

    def data_objects(self) -> Dict[str, DataObject]:
        out: Dict[str, DataObject] = {}
        for t in self.tasks:
            for a in t.accesses:
                out[a.data.name] = a.data
        return out

    def total_flops(self) -> float:
        return sum(t.flops for t in self.tasks)

    def topo_order(self) -> List[int]:
        """Kahn topological order (deterministic: ready set kept sorted)."""
        indeg = {t.tid: len(self.pred[t.tid]) for t in self.tasks}
        ready = sorted(tid for tid, d in indeg.items() if d == 0)
        order: List[int] = []
        import heapq

        heapq.heapify(ready)
        while ready:
            tid = heapq.heappop(ready)
            order.append(tid)
            for s in self.succ[tid]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    heapq.heappush(ready, s)
        if len(order) != len(self.tasks):
            raise ValueError("cycle detected in task graph")
        return order

    def critical_path_length(self, cost: Callable[[Task], float]) -> float:
        """Longest path using per-task cost (a makespan lower bound)."""
        dist: Dict[int, float] = {}
        for tid in self.topo_order():
            t = self.tasks[tid]
            base = max((dist[p] for p in self.pred[tid]), default=0.0)
            dist[tid] = base + cost(t)
        return max(dist.values(), default=0.0)
