"""Scalar reference implementations of the scheduling core.

These are the pre-vectorization HEFT / DADA strategies and the set-based
Residency, kept verbatim (modulo renames) as the ground truth for the
bit-for-bit equivalence suite (``tests/test_equivalence.py``,
``tests/test_residency_property.py``). They are *not* exported from
``repro.core``; production code uses the array-native versions.

Do not "improve" this file: its value is that it computes placements with
the exact same floating-point operation order the original per-task loops
used, so any divergence in the vectorized core is a real regression.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from .affinity import AFFINITY_FUNCTIONS, AffinityFn
from .dag import Task
from .simulator import Simulator, Strategy

_TINY = 1e-12


class SetResidency:
    """Set-based residency tracker (the original implementation)."""

    def __init__(self) -> None:
        self._where: Dict[str, set] = {}

    def is_resident(self, name: str, mem: int) -> bool:
        return mem in self._where.get(name, set())

    def locations(self, name: str) -> set:
        return set(self._where.get(name, set()))

    def has_any(self, name: str) -> bool:
        return bool(self._where.get(name))

    def transfer_hops(self, name: str, dst_mem: int) -> int:
        from .machine import HOST_MEM

        locs = self._where.get(name, set())
        if not locs or dst_mem in locs:
            return 0
        if dst_mem == HOST_MEM or HOST_MEM in locs:
            return 1
        return 2

    def add_copy(self, name: str, mem: int) -> None:
        self._where.setdefault(name, set()).add(mem)

    def write(self, name: str, mem: int) -> None:
        self._where[name] = {mem}

    def initialize(self, names, mem: int) -> None:
        for n in names:
            self.write(n, mem)

    def bytes_resident(self, mem: int, sizes: Dict[str, int]) -> int:
        return sum(sz for n, sz in sizes.items() if self.is_resident(n, mem))


class ReferenceHEFT(Strategy):
    """Per-task-loop HEFT (paper §3.1), original implementation."""

    name = "heft"
    allow_steal = False
    owner_lifo = False

    def place(self, sim: Simulator, ready: List[Task], src: Optional[int]) -> None:
        machine = sim.machine
        cpus = machine.cpus
        gpus = machine.gpus
        cpu_cls = cpus[0].cls if cpus else gpus[0].cls
        gpu_cls = gpus[0].cls if gpus else cpu_cls

        # --- task prioritizing: decreasing speedup -----------------------
        scored = []
        for t in ready:
            p_cpu = sim.model.predict(t, cpu_cls)
            p_gpu = sim.model.predict(t, gpu_cls)
            s = p_cpu / p_gpu if p_gpu > 0 else 1.0
            scored.append((-s, t.tid, t))
        scored.sort()

        # --- worker selection: earliest finish time ----------------------
        for _, _, t in scored:
            best_eft = float("inf")
            best_rid = machine.resources[0].rid
            for r in machine.resources:
                start = max(sim.now, sim.load_ts[r.rid])
                xfer = sim.transfer_model.task_input_transfer_time(
                    t, r, sim.residency
                )
                eft = start + xfer + sim.model.predict(t, r.cls)
                if eft < best_eft - 1e-15:
                    best_eft = eft
                    best_rid = r.rid
            sim.load_ts[best_rid] = best_eft
            sim.push(t, best_rid)


class ReferenceDADA(Strategy):
    """Per-task-loop DADA (paper §3.2, Algorithm 2), original implementation."""

    allow_steal = False
    owner_lifo = False

    def __init__(
        self,
        alpha: float = 0.5,
        use_cp: bool = False,
        affinity: str = "accel_write",
        eps_rel: float = 0.01,
        max_iters: int = 30,
        area_bound: bool = False,
    ) -> None:
        if not 0.0 <= alpha <= 1.0:
            raise ValueError("alpha must be within [0, 1]")
        self.alpha = alpha
        self.use_cp = use_cp
        self.affinity_fn: AffinityFn = AFFINITY_FUNCTIONS[affinity]
        self.eps_rel = eps_rel
        self.max_iters = max_iters
        self.area_bound = area_bound
        cp = "+cp" if use_cp else ""
        self.name = f"dada({alpha:g}){cp}"

    # ------------------------------------------------------------------
    def place(self, sim: Simulator, ready: List[Task], src: Optional[int]) -> None:
        machine = sim.machine
        resources = machine.resources
        cpus = machine.cpus
        gpus = machine.gpus
        cpu_cls = cpus[0].cls if cpus else gpus[0].cls
        gpu_cls = gpus[0].cls if gpus else cpu_cls

        p_cpu = {t.tid: sim.model.predict(t, cpu_cls) for t in ready}
        p_gpu = {t.tid: sim.model.predict(t, gpu_cls) for t in ready}

        xfer_cache: Dict[Tuple[int, int], float] = {}

        def xfer(t: Task, rid: int) -> float:
            if not self.use_cp:
                return 0.0
            key = (t.tid, rid)
            if key not in xfer_cache:
                xfer_cache[key] = sim.transfer_model.task_input_transfer_time(
                    t, machine.by_id(rid), sim.residency
                )
            return xfer_cache[key]

        def cost(t: Task, rid: int) -> float:
            r = machine.by_id(rid)
            p = p_cpu[t.tid] if not r.is_accelerator else p_gpu[t.tid]
            return p + xfer(t, rid)

        offsets = {
            r.rid: max(0.0, sim.load_ts[r.rid] - sim.now) for r in resources
        }

        # affinity preferences (resource of max score, per task)
        pref: Dict[int, Tuple[float, int]] = {}
        if self.alpha > 0.0:
            for t in ready:
                best_score, best_rid = 0.0, -1
                for r in resources:
                    s = self.affinity_fn(t, r, sim.residency)
                    if s > best_score + _TINY:
                        best_score, best_rid = s, r.rid
                if best_rid >= 0:
                    pref[t.tid] = (best_score, best_rid)

        # ------------------------------------------------------------------
        def try_build(lam: float) -> Optional[Tuple[Dict[int, int], Dict[int, float]]]:
            if self.area_bound:
                area = sum(min(p_cpu[t.tid], p_gpu[t.tid]) for t in ready)
                capacity = lam * len(resources) - sum(offsets.values())
                if area > capacity + _TINY:
                    return None  # certificate: no λ-schedule exists
            loads = dict(offsets)
            assign: Dict[int, int] = {}

            # ---- local affinity phase (line 5-7) -------------------------
            if self.alpha > 0.0:
                by_score = sorted(
                    ((sc, tid, rid) for tid, (sc, rid) in pref.items()),
                    key=lambda x: (-x[0], x[1]),
                )
                for sc, tid, rid in by_score:
                    if loads[rid] <= self.alpha * lam + _TINY:
                        t = sim.graph.tasks[tid]
                        assign[tid] = rid
                        loads[rid] += cost(t, rid)

            # ---- global balance phase (line 8-9) -------------------------
            rem = [t for t in ready if t.tid not in assign]
            for t in rem:  # reject if a task is larger than λ everywhere
                big_cpu = (not cpus) or p_cpu[t.tid] > lam
                big_gpu = (not gpus) or p_gpu[t.tid] > lam
                if big_cpu and big_gpu:
                    return None

            def eft_assign(t: Task, pool) -> None:
                best_rid = min(
                    pool, key=lambda r: (loads[r.rid] + cost(t, r.rid), r.rid)
                ).rid
                assign[t.tid] = best_rid
                loads[best_rid] += cost(t, best_rid)

            flex: List[Task] = []
            for t in rem:
                if cpus and gpus:
                    if p_cpu[t.tid] > lam:
                        eft_assign(t, gpus)  # dedicated to GPUs
                    elif p_gpu[t.tid] > lam:
                        eft_assign(t, cpus)  # dedicated to CPUs
                    else:
                        flex.append(t)
                else:
                    eft_assign(t, cpus or gpus)

            # flexible tasks: largest speedup first, to GPUs up to
            # overreaching λ, the rest to CPUs (earliest finish time)
            flex.sort(
                key=lambda t: (-(p_cpu[t.tid] / max(p_gpu[t.tid], _TINY)), t.tid)
            )
            for t in flex:
                g = min(gpus, key=lambda r: (loads[r.rid], r.rid)) if gpus else None
                if g is not None and loads[g.rid] <= lam + _TINY:
                    assign[t.tid] = g.rid
                    loads[g.rid] += cost(t, g.rid)
                else:
                    eft_assign(t, cpus or gpus)

            # ---- acceptance test (line 10) -------------------------------
            bound = (2.0 + self.alpha) * lam
            if all(l <= bound + _TINY for l in loads.values()):
                return assign, loads
            return None

        # ------------------------------------------------------------------
        # binary search on λ (classical dual-approximation driver)
        max_off = max(offsets.values(), default=0.0)
        worst_xfer = 0.0
        if self.use_cp:
            for t in ready:
                worst_xfer += max(xfer(t, r.rid) for r in resources)
        upper = (
            sum(max(p_cpu[t.tid], p_gpu[t.tid]) for t in ready)
            + max_off
            + worst_xfer
            + _TINY
        )
        lower = 0.0
        kept: Optional[Tuple[Dict[int, int], Dict[int, float]]] = None
        it = 0
        while upper - lower > self.eps_rel * upper and it < self.max_iters:
            lam = (upper + lower) / 2.0
            built = try_build(lam)
            if built is not None:
                upper = lam
                kept = built
            else:
                lower = lam
            it += 1
        if kept is None:
            kept = try_build(upper)
            assert kept is not None, "λ=upper must always be feasible"

        assign, loads = kept
        # expose the accepted guess for tests / introspection
        self.last_lambda = upper
        self.last_loads = dict(loads)
        for t in ready:
            rid = assign[t.tid]
            sim.push(t, rid)
        for rid, load in loads.items():
            sim.load_ts[rid] = sim.now + load
