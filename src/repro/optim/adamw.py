"""AdamW + schedules + global-norm clipping (pure pytree ops, no optax)."""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def adamw_init(params) -> Dict:
    zeros = lambda p: jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(
    grads,
    opt_state: Dict,
    params,
    lr,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Tuple[Any, Dict]:
    step = opt_state["step"] + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return m, v, (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    flat_p = jax.tree.leaves(params)
    new_m, new_v, new_p = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        m2, v2, p2 = upd(g, m, v, p)
        new_m.append(m2)
        new_v.append(v2)
        new_p.append(p2)
    return (
        jax.tree.unflatten(treedef, new_p),
        {
            "m": jax.tree.unflatten(treedef, new_m),
            "v": jax.tree.unflatten(treedef, new_v),
            "step": step,
        },
    )


def cosine_schedule(step, *, base_lr: float, warmup: int, total: int, min_ratio: float = 0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1.0) / max(1, warmup))
    prog = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(math.pi * prog))
    return base_lr * warm * (min_ratio + (1 - min_ratio) * cos)
