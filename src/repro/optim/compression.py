"""Error-feedback gradient compression for the slow cross-pod axis.

int8 stochastic-free linear quantization with per-leaf scale + local error
feedback (residual carried to the next step). Applied as a
``grad_transform`` hook in train/step.py: quantize -> (the cross-pod
all-reduce moves int8, 4x fewer bytes) -> dequantize, residual kept locally.

The compression itself is exact-arithmetic testable (tests/test_dist.py):
compress->decompress error is bounded by the quantization step, and error
feedback makes the *accumulated* bias vanish over steps.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_state_init(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_error_feedback(grads, ef_state):
    """Returns (compressed-then-decompressed grads, new ef_state).

    The decompressed value is what the cross-pod all-reduce would carry
    (int8 wire format); the quantization error stays in ef_state and is
    added back next step.
    """

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = quantize_int8(g32)
        deq = dequantize_int8(q, scale)
        return deq.astype(g.dtype), g32 - deq

    flat_g, td = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(td, [o[0] for o in outs])
    new_e = jax.tree.unflatten(td, [o[1] for o in outs])
    return new_g, new_e


def wire_bytes_saved(params) -> Tuple[int, int]:
    """fp32 vs int8 bytes for one cross-pod gradient all-reduce."""
    n = sum(int(jnp.prod(jnp.array(l.shape))) for l in jax.tree.leaves(params))
    return 4 * n, 1 * n
