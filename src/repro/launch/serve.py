"""Serving driver: prefill a batch of requests, then decode tokens.

``python -m repro.launch.serve --arch chatglm3-6b --smoke --tokens 16``
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config, smoke_config
from repro.models.transformer import cache_init, encode, forward, init_params
from repro.serve.decode import make_serve_step


def prefill_into_cache(params, cfg, tokens, cache_len):
    """Run the prompt through decode steps to fill the cache (simple path;
    a fused prefill kernel is the production optimization)."""
    B, S = tokens.shape
    cache = cache_init(cfg, B, cache_len)
    serve = jax.jit(make_serve_step(cfg))
    last = None
    for i in range(S):
        last, _, cache = serve(params, cache, tokens[:, i : i + 1], jnp.int32(i))
    return last, cache


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="chatglm3-6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rng = np.random.default_rng(0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B = args.batch
    cache_len = args.prompt_len + args.tokens
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab, size=(B, args.prompt_len)), jnp.int32
    )

    t0 = time.time()
    last_tok, cache = prefill_into_cache(params, cfg, prompt, cache_len)
    print(f"prefill {args.prompt_len} tokens x {B} reqs: {time.time()-t0:.2f}s")

    serve = jax.jit(make_serve_step(cfg))
    out = [last_tok]
    t0 = time.time()
    for i in range(args.tokens - 1):
        pos = jnp.int32(args.prompt_len + i)
        nxt, _, cache = serve(params, cache, out[-1][:, None], pos)
        out.append(nxt)
    dt = time.time() - t0
    toks = jnp.stack(out, axis=1)
    print(f"decoded {args.tokens-1} steps x {B} reqs in {dt:.2f}s "
          f"({B*(args.tokens-1)/max(dt,1e-9):.1f} tok/s)")
    print("sample:", np.asarray(toks[0])[:12].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
