"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state. Single pod: 16x16 = 256 chips (data, model). Multi-pod: 2 pods x 256
chips with a leading "pod" axis — the slow (cross-pod ICI/DCN) dimension
that the sharding rules treat as pure data parallelism.
"""
from __future__ import annotations

import numpy as np

import jax


def _mesh(shape, axes, devices):
    """Version-tolerant mesh construction.

    ``jax.make_mesh(..., axis_types=AxisType.Auto)`` only exists on recent
    jax; older releases spell the same thing as a plain ``Mesh`` over a
    reshaped device array (Auto is their only behavior).
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            shape, axes, devices=devices,
            axis_types=(axis_type.Auto,) * len(axes),
        )
    return jax.sharding.Mesh(
        np.asarray(devices, dtype=object).reshape(shape), axes
    )


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — the "
            "dry-run entrypoint must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count before any jax import"
        )
    return _mesh(shape, axes, devices[:n])


def make_smoke_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh over however many real devices exist (tests)."""
    devices = jax.devices()[: n_data * n_model]
    return _mesh((n_data, n_model), ("data", "model"), devices)


def data_axes(mesh) -> tuple:
    """Axes that shard the batch: ('pod','data') when a pod axis exists."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
