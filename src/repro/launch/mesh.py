"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state. Single pod: 16x16 = 256 chips (data, model). Multi-pod: 2 pods x 256
chips with a leading "pod" axis — the slow (cross-pod ICI/DCN) dimension
that the sharding rules treat as pure data parallelism.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — the "
            "dry-run entrypoint must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count before any jax import"
        )
    return jax.make_mesh(
        shape, axes, devices=devices[:n],
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def make_smoke_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh over however many real devices exist (tests)."""
    devices = jax.devices()[: n_data * n_model]
    return jax.make_mesh(
        (n_data, n_model), ("data", "model"), devices=devices,
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def data_axes(mesh) -> tuple:
    """Axes that shard the batch: ('pod','data') when a pod axis exists."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
