import os

# Merge, don't clobber: the user's own XLA_FLAGS (dump paths, autotune
# knobs) must survive; only the host-device-count flag is replaced — the
# dry-run's mesh math requires exactly 512 host devices. MUST run before
# any jax import: jax locks the device count at first initialization
# (see MULTI-POD DRY-RUN spec).
_flags = [
    f
    for f in os.environ.get("XLA_FLAGS", "").split()
    if not f.startswith("--xla_force_host_platform_device_count")
]
_flags.append("--xla_force_host_platform_device_count=512")
os.environ["XLA_FLAGS"] = " ".join(_flags)
del _flags

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.analysis.flops import cell_cost  # noqa: E402
from repro.analysis.hlo import collective_bytes  # noqa: E402
from repro.configs.registry import ARCH_IDS, get_config  # noqa: E402
from repro.configs.shapes import SHAPES, applicable  # noqa: E402
from repro.dist.sharding import (  # noqa: E402
    batch_specs,
    cache_specs,
    clear_hints,
    opt_specs,
    param_specs,
    set_hints,
    to_named,
)
from repro.launch.input_specs import (  # noqa: E402
    batch_sds,
    decode_sds,
    opt_sds,
    params_sds,
    tree_bytes,
)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.serve.decode import make_prefill_step, make_serve_step  # noqa: E402
from repro.train.step import make_train_step  # noqa: E402

RESULTS_DIR = Path(
    os.environ.get(
        "REPRO_RESULTS_DIR",
        Path(__file__).resolve().parents[3] / "results" / "dryrun",
    )
)


def micro_batches_for(cfg, shape, mesh) -> int:
    """Pick gradient-accumulation depth: ~2 sequences per data shard."""
    dsize = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    local = max(1, shape.global_batch // dsize)
    micro = max(1, local // 2)
    while local % micro:
        micro -= 1
    return micro


def build_and_compile(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    *,
    moe_chunks: int = 1,
    decode_fsdp: bool = True,
    cross_cache: bool = False,
    ep_pods: bool = False,
    accum_bf16: bool = False,
):
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = applicable(cfg, shape)
    rec = dict(
        arch=arch, shape=shape_name,
        mesh="pod2x16x16" if multi_pod else "pod16x16",
        n_devices=int(mesh.size),
    )
    if not ok:
        rec.update(status="skip", reason=reason)
        return rec

    set_hints(mesh, ("pod", "data") if multi_pod else ("data",))
    p_sds = params_sds(cfg)
    fsdp = True if shape.kind == "train" else decode_fsdp
    pspec = param_specs(cfg, p_sds, mesh, fsdp=fsdp, ep_pods=ep_pods)
    pnamed = to_named(mesh, pspec)
    micro = 1
    t0 = time.time()

    if shape.kind == "train":
        micro = micro_batches_for(cfg, shape, mesh)
        fn = make_train_step(
            cfg, micro_batches=micro, moe_chunks=moe_chunks,
            accum_dtype=jnp.bfloat16 if accum_bf16 else jnp.float32,
        )
        o_sds = opt_sds(p_sds)
        onamed = to_named(mesh, opt_specs(pspec))
        b_sds = batch_sds(cfg, shape)
        bnamed = to_named(mesh, batch_specs(cfg, mesh, b_sds))
        jf = jax.jit(
            fn,
            in_shardings=(pnamed, onamed, bnamed),
            out_shardings=(pnamed, onamed, None),
            donate_argnums=(0, 1),
        )
        lowered = jf.lower(p_sds, o_sds, b_sds)
    elif shape.kind == "prefill":
        fn = make_prefill_step(cfg, moe_chunks=moe_chunks)
        b_sds = batch_sds(cfg, shape)
        bnamed = to_named(mesh, batch_specs(cfg, mesh, b_sds))
        jf = jax.jit(fn, in_shardings=(pnamed, bnamed))
        lowered = jf.lower(p_sds, b_sds)
    else:  # decode
        d = decode_sds(cfg, shape)
        cnamed = to_named(mesh, cache_specs(cfg, mesh, d["cache"]))
        tnamed = to_named(mesh, batch_specs(cfg, mesh, {"tokens": d["tokens"]}))["tokens"]
        serve = make_serve_step(cfg, moe_chunks=moe_chunks)
        args = [p_sds, d["cache"], d["tokens"], d["pos"]]
        in_sh = [pnamed, cnamed, tnamed, None]
        if "enc_out" in d:
            if cross_cache:
                # §Perf variant: precomputed cross-K/V instead of raw memory
                from repro.serve.decode import make_cross_cache

                cc_sds = jax.eval_shape(
                    lambda p, e: make_cross_cache(p, cfg, e), p_sds, d["enc_out"]
                )
                args.append(None)   # enc_out unused
                in_sh.append(None)
                args.append(cc_sds)
                in_sh.append(to_named(mesh, cache_specs(cfg, mesh, cc_sds)))
            else:
                args.append(d["enc_out"])
                in_sh.append(
                    to_named(mesh, batch_specs(cfg, mesh, {"e": d["enc_out"]}))["e"]
                )
        jf = jax.jit(
            serve,
            in_shardings=tuple(in_sh),
            out_shardings=(None, None, cnamed),
            donate_argnums=(1,),
        )
        lowered = jf.lower(*args)

    compiled = lowered.compile()
    clear_hints()
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):  # older jax returned [dict]
        cost = cost[0] if cost else {}
    coll = collective_bytes(compiled.as_text())
    ana = cell_cost(cfg, shape, micro_batches=micro)

    mem_rec = {}
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
        "peak_memory_in_bytes",
    ):
        v = getattr(mem, attr, None)
        if v is not None:
            mem_rec[attr] = int(v)

    rec.update(
        status="ok",
        memory=mem_rec,
        micro_batches=micro,
        compile_s=round(compile_s, 1),
        hlo_flops_raw=float(cost.get("flops", -1.0)),
        hlo_bytes_raw=float(cost.get("bytes accessed", -1.0)),
        collective_bytes_per_device=coll,
        analytic_flops=ana.flops,
        analytic_hbm_bytes=ana.hbm_bytes,
        model_flops=ana.model_flops,
        param_bytes_global=tree_bytes(p_sds),
    )
    return rec


def cell_path(arch, shape_name, multi_pod) -> Path:
    mesh = "pod2" if multi_pod else "pod1"
    return RESULTS_DIR / f"{arch}__{shape_name}__{mesh}.json"


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", nargs="*", default=ARCH_IDS)
    ap.add_argument("--shape", nargs="*", default=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--moe-chunks", type=int, default=1,
                    help="chunk-local MoE dispatch (perf variant; = data shards)")
    ap.add_argument("--no-fsdp-decode", action="store_true",
                    help="TP-only params for decode cells (perf variant)")
    ap.add_argument("--cross-cache", action="store_true",
                    help="precomputed cross-K/V for enc-dec decode (perf variant)")
    ap.add_argument("--ep-pods", action="store_true",
                    help="expert parallelism across the pod axis too (perf variant)")
    ap.add_argument("--accum-bf16", action="store_true",
                    help="bf16 gradient accumulation (perf variant)")
    ap.add_argument("--suffix", default="",
                    help="result-file suffix for perf variants")
    args = ap.parse_args()

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    pods = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    failures = 0
    for arch in args.arch:
        for shape_name in args.shape:
            for multi_pod in pods:
                path = cell_path(arch, shape_name, multi_pod)
                if args.suffix:
                    path = path.with_name(path.stem + "__" + args.suffix + ".json")
                if path.exists() and not args.force:
                    rec = json.loads(path.read_text())
                    print(f"[cached] {path.name}: {rec.get('status')}")
                    continue
                label = f"{arch} x {shape_name} x {'pod2' if multi_pod else 'pod1'}"
                print(f"[lower+compile] {label} ...", flush=True)
                try:
                    rec = build_and_compile(
                        arch, shape_name, multi_pod,
                        moe_chunks=args.moe_chunks,
                        decode_fsdp=not args.no_fsdp_decode,
                        cross_cache=args.cross_cache,
                        ep_pods=args.ep_pods,
                        accum_bf16=args.accum_bf16,
                    )
                except Exception as e:  # record failures — they are bugs
                    rec = dict(
                        arch=arch, shape=shape_name,
                        mesh="pod2x16x16" if multi_pod else "pod16x16",
                        status="error", error=f"{type(e).__name__}: {e}",
                        trace=traceback.format_exc()[-2000:],
                    )
                    failures += 1
                path.write_text(json.dumps(rec, indent=1))
                print(f"  -> {rec['status']}" + (
                    f" compile={rec.get('compile_s')}s flops={rec.get('hlo_flops_raw'):.3g}"
                    if rec["status"] == "ok" else f" ({rec.get('reason', rec.get('error'))})"
                ), flush=True)
    print(f"done; failures={failures}")
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
