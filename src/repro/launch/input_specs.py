"""ShapeDtypeStruct stand-ins for every model input — shardable, weak-type
correct, zero allocation. The dry-run lowers against these."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec
from repro.models.layers import _dtype
from repro.models.transformer import cache_init, init_params
from repro.optim.adamw import adamw_init

SDS = jax.ShapeDtypeStruct


def batch_sds(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, SDS]:
    """Inputs for a train/prefill step."""
    B, S = shape.global_batch, shape.seq_len
    out: Dict[str, SDS] = {}
    if cfg.family == "audio":
        out["frontend"] = SDS((B, S, cfg.frontend_dim), jnp.float32)
        out["tokens"] = SDS((B, S), jnp.int32)
    elif cfg.family == "vlm":
        P = cfg.frontend_tokens
        out["frontend"] = SDS((B, P, cfg.frontend_dim), jnp.float32)
        out["tokens"] = SDS((B, S - P), jnp.int32)
    else:
        out["tokens"] = SDS((B, S), jnp.int32)
    return out


def decode_sds(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Inputs for a serve (decode) step: 1 new token + caches of seq_len."""
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: cache_init(cfg, B, S))
    out = {
        "tokens": SDS((B, 1), jnp.int32),
        "pos": SDS((), jnp.int32),
        "cache": cache,
    }
    if cfg.family == "audio":
        out["enc_out"] = SDS((B, S, cfg.d_model), _dtype(cfg.compute_dtype))
    return out


def params_sds(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def opt_sds(params):
    return jax.eval_shape(adamw_init, params)


def tree_bytes(tree) -> int:
    return sum(
        int(jnp.dtype(l.dtype).itemsize) * int(jnp.prod(jnp.array(l.shape)))
        if l.shape else int(jnp.dtype(l.dtype).itemsize)
        for l in jax.tree.leaves(tree)
    )
