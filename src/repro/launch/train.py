"""Training driver: ``python -m repro.launch.train --arch granite-8b ...``

Runs a real (small-scale) training loop on the available devices with the
full production stack: config registry, deterministic sharded data,
AdamW + cosine, checkpointing with restart, straggler-aware microbatching,
and DADA expert placement for MoE archs.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.manager import CheckpointManager
from repro.configs.registry import ARCH_IDS, get_config, smoke_config
from repro.configs.shapes import ShapeSpec
from repro.data.pipeline import SyntheticPipeline
from repro.dist.sched_bridge import plan_expert_placement
from repro.models.transformer import init_params
from repro.optim.adamw import adamw_init
from repro.train.step import make_train_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="granite-8b")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--micro-batches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--d-model", type=int, default=0, help="override width")
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.d_model:
        hd = max(16, args.d_model // cfg.n_heads)
        cfg = cfg.scaled(d_model=args.d_model, head_dim=hd)
    shape = ShapeSpec("cli", args.seq_len, args.batch, "train")
    pipe = SyntheticPipeline(cfg, shape, seed=0)

    expert_perm = None
    if cfg.moe is not None:
        # initial DADA placement from a uniform routing prior
        pl = plan_expert_placement(np.ones(cfg.moe.n_experts), 1)
        expert_perm = jnp.asarray(pl.inv_perm)

    step_fn = jax.jit(
        make_train_step(
            cfg, base_lr=args.lr, total_steps=args.steps,
            micro_batches=args.micro_batches, expert_perm=expert_perm,
        )
    )

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    start = 0
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if mgr and args.resume and mgr.latest_step() is not None:
        start, state, _ = mgr.restore({"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        print(f"resumed from step {start}")

    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M steps={args.steps}")
    t0 = time.time()
    for s in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(s).items()}
        params, opt, m = step_fn(params, opt, batch)
        if (s + 1) % args.log_every == 0 or s == start:
            dt = time.time() - t0
            print(
                f"step {s+1:5d} loss={float(m['loss']):.4f} "
                f"ce={float(m['ce']):.4f} gnorm={float(m['grad_norm']):.3f} "
                f"lr={float(m['lr']):.2e} ({dt:.1f}s)",
                flush=True,
            )
        if mgr and (s + 1) % args.ckpt_every == 0:
            mgr.save(s + 1, {"params": params, "opt": opt}, blocking=False)
    if mgr:
        mgr.save(args.steps, {"params": params, "opt": opt})
        mgr.wait()
    print(f"done in {time.time()-t0:.1f}s; final loss {float(m['loss']):.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
