"""Straggler mitigation: history-calibrated micro-batch re-balancing.

The paper's history-based performance model (§2.3) at the data-parallel
level: shards report observed step times, the planner learns per-shard
per-microbatch cost and re-apportions the fixed global micro-batch budget
inversely to it — a persistent straggler sheds work instead of stalling
every all-reduce. This is the same earliest-finish-time load balancing the
scheduling core applies to tasks, with micro-batches as the unit of work.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np


class StragglerPlanner:
    """Plans per-shard micro-batch counts from observed step times.

    ``plan()`` returns an integer allocation summing to
    ``total_microbatches``; before any observation it is uniform. Each
    ``observe(times, plan)`` updates the per-shard per-microbatch cost
    estimate (exponential moving average, ``ema`` weight on the new
    sample), and subsequent plans allocate proportionally to shard speed
    (largest-remainder rounding keeps the total exact).

    Shards lost to preemption are taken out of rotation with
    :meth:`deactivate` (their allocation drops to zero and their cost
    estimate freezes) and rejoin with :meth:`reactivate`, resuming from
    the frozen estimate — the planner-level mirror of the runtime's
    detach/attach (``repro.runtime.faults``).
    """

    def __init__(
        self,
        n_shards: int,
        total_microbatches: int,
        ema: float = 1.0,
    ) -> None:
        if n_shards <= 0 or total_microbatches < n_shards:
            raise ValueError(
                "need at least one micro-batch per shard "
                f"(shards={n_shards}, total={total_microbatches})"
            )
        self.n_shards = n_shards
        self.total = total_microbatches
        self.ema = ema
        # relative per-microbatch cost per shard; uniform until observed
        self._cost = np.ones(n_shards, dtype=np.float64)
        self._active = np.ones(n_shards, dtype=bool)
        self.n_observations = 0

    # ------------------------------------------------------------------
    def _check_shard(self, i: int) -> int:
        i = int(i)
        if not 0 <= i < self.n_shards:
            raise ValueError(f"shard {i} out of range [0, {self.n_shards})")
        return i

    def deactivate(self, i: int) -> None:
        """Take shard ``i`` out of rotation (idempotent). Its cost
        estimate freezes at the last observed value."""
        i = self._check_shard(i)
        self._active[i] = False
        if not self._active.any():
            self._active[i] = True
            raise ValueError("cannot deactivate the last active shard")

    def reactivate(self, i: int) -> None:
        """Return shard ``i`` to rotation (idempotent), resuming from
        its frozen cost estimate."""
        self._active[self._check_shard(i)] = True

    @property
    def active(self) -> np.ndarray:
        """Boolean active mask (copy)."""
        return self._active.copy()

    @property
    def n_active(self) -> int:
        return int(np.count_nonzero(self._active))

    # ------------------------------------------------------------------
    def observe(
        self, times: Sequence[float], plan: Sequence[int]
    ) -> None:
        """Record one step: ``times[i]`` seconds for ``plan[i]`` micro-batches."""
        times = np.asarray(times, dtype=np.float64)
        plan = np.asarray(plan, dtype=np.float64)
        if times.shape != (self.n_shards,) or plan.shape != (self.n_shards,):
            raise ValueError("times/plan must have one entry per shard")
        ran = plan > 0
        sample = np.where(ran, times / np.where(ran, plan, 1.0), self._cost)
        self._cost = (1.0 - self.ema) * self._cost + self.ema * sample
        self.n_observations += 1

    # ------------------------------------------------------------------
    def plan(self) -> np.ndarray:
        """Integer micro-batch allocation ∝ shard speed, summing exactly.

        Only active shards receive work (inactive allocations are 0);
        the total must still cover one micro-batch per active shard.
        """
        act = np.flatnonzero(self._active)
        if self.total < act.size:
            raise ValueError(
                "need at least one micro-batch per active shard "
                f"(active={act.size}, total={self.total})"
            )
        speed = 1.0 / np.maximum(self._cost[act], 1e-12)
        raw = self.total * speed / speed.sum()
        base = np.floor(raw).astype(np.int64)
        # every shard keeps at least one micro-batch: a starved shard
        # would never report a fresh time and could stay mis-calibrated
        base = np.maximum(base, 1)
        surplus = int(base.sum()) - self.total
        if surplus > 0:
            # take back from the slowest shards' rounded-up minimums
            for i in np.argsort(raw):
                while surplus > 0 and base[i] > 1:
                    take = min(surplus, int(base[i] - 1))
                    base[i] -= take
                    surplus -= take
                if surplus == 0:
                    break
        elif surplus < 0:
            frac = raw - np.floor(raw)
            for i in np.argsort(-frac, kind="stable"):
                base[i] += 1
                surplus += 1
                if surplus == 0:
                    break
            while surplus < 0:  # more remainder than shards: round-robin
                for i in np.argsort(-frac, kind="stable"):
                    base[i] += 1
                    surplus += 1
                    if surplus == 0:
                        break
        out = np.zeros(self.n_shards, dtype=np.int64)
        out[act] = base
        return out

    # ------------------------------------------------------------------
    def expected_makespan(self, plan: Sequence[int]) -> float:
        """Predicted step time: the slowest shard under ``plan``."""
        plan = np.asarray(plan, dtype=np.float64)
        return float(np.max(plan * self._cost))
