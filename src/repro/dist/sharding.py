"""Sharding rules: every model pytree → PartitionSpec tree, by rule not table.

One rule set covers the whole architecture pool (dense GQA, MLA, MoE,
hybrid Mamba/attention, xLSTM, encoder-decoder, modality stubs), so adding
an arch never means adding a spec table. The rules are divisibility-gated:
a dimension is only sharded when the mesh axis divides it, and anything
unshardable replicates — lowering must never fail on an exotic shape.

Placement policy (the production 16×16 pod, optional leading ``pod`` axis):

  * **parameters** — tensor parallelism on the ``model`` axis (the largest
    divisible dimension, preferring the last on ties: the conventional
    column-parallel layout), FSDP on the ``data`` axis over the first
    remaining divisible dimension (``fsdp=False`` drops it, e.g. TP-only
    decode); stacked period trees (``blocks``/``cross``/``enc_blocks``)
    keep the leading scan axis unsharded;
  * **embeddings** — untied tables shard ``d_model`` (gathers stay local:
    vocab-sharded gathers hit SPMD's full-remat fallback), tied tables
    shard the vocab dim (the one-hot contraction in ``forward`` partitions
    cleanly and the lm_head matmul reuses the shards);
  * **MoE experts** — expert parallelism on ``model`` (spanning
    ``("pod", "model")`` with ``ep_pods=True``) when the expert count
    divides, else tensor parallelism *inside* each expert on the widest
    divisible inner dimension (grok-style few-expert models);
  * **activations/batch** — the batch dimension over the data axes
    (``("pod", "data")`` on multi-pod meshes); when the batch itself is
    indivisible (``long_500k`` has batch 1) the sequence dimension takes
    the data axes instead;
  * **KV caches** — batch over ``data``; KV heads over ``model`` when they
    divide (GQA with enough heads), else the sequence dimension
    (sequence-sharded KV, the long-context layout); other recurrent state
    (Mamba/xLSTM/MLA) shards its largest divisible dimension.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

# pytrees whose leaves carry a leading stacked-period axis (scanned)
_STACKED_ROOTS = ("blocks", "cross", "enc_blocks")


def _axis_size(mesh, name: str) -> int:
    return dict(mesh.shape).get(name, 1)


def _data_axes(mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _trim(entries) -> P:
    """PartitionSpec with trailing Nones dropped (the canonical short form
    for activation specs; parameter/cache specs stay full-rank)."""
    out = list(entries)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def _largest_divisible(
    shape: Sequence[int], size: int, taken: Sequence[int], lo: int = 0
) -> Optional[int]:
    """Index of the largest dim (>= ``lo``) divisible by ``size``; ties go
    to the rightmost dim (conventional column-parallel layout)."""
    best, best_dim = None, -1
    for i in range(lo, len(shape)):
        if i in taken or size <= 1:
            continue
        if shape[i] % size == 0 and shape[i] >= best_dim:
            best, best_dim = i, shape[i]
    return best


def _first_divisible(
    shape: Sequence[int], size: int, taken: Sequence[int], lo: int = 0
) -> Optional[int]:
    for i in range(lo, len(shape)):
        if i not in taken and size > 1 and shape[i] % size == 0:
            return i
    return None


# ---------------------------------------------------------------------------
# parameters


def _param_spec(
    cfg: ModelConfig,
    path_names: Tuple[str, ...],
    shape: Tuple[int, ...],
    mesh,
    *,
    fsdp: bool,
    ep_pods: bool,
) -> P:
    model = _axis_size(mesh, "model")
    data = _axis_size(mesh, "data")
    pod = _axis_size(mesh, "pod")
    stacked = bool(path_names) and path_names[0] in _STACKED_ROOTS
    off = 1 if stacked else 0  # leading period axis stays unsharded
    ndim = len(shape)
    spec: list = [None] * ndim
    leaf = path_names[-1] if path_names else ""

    # 1-d (biases, norm scales) and scalars: replicate
    if ndim - off <= 1:
        return P(*spec)

    # embeddings: gather-friendly layouts, never FSDP (see module doc)
    if "embed" in path_names and leaf == "table":
        vocab, d = shape
        if cfg.tie_embeddings:
            if vocab % model == 0:
                spec[0] = "model"
        elif d % model == 0:
            spec[1] = "model"
        return P(*spec)

    taken: list = []
    # MoE expert tensors: (periods, E, a, b) — expert parallelism first
    if "moe" in path_names and ndim - off == 3:
        E = shape[off]
        if ep_pods and pod > 1 and E % (pod * model) == 0:
            spec[off] = ("pod", "model")
            taken.append(off)
        elif E % model == 0:
            spec[off] = "model"
            taken.append(off)
        else:  # few-expert models: TP inside each expert
            j = _largest_divisible(shape, model, taken, lo=off + 1)
            if j is not None:
                spec[j] = "model"
                taken.append(j)
        if fsdp:
            j = _first_divisible(shape, data, taken, lo=off + 1)
            if j is not None:
                spec[j] = "data"
        return P(*spec)

    # generic matrices: TP on the largest divisible dim, FSDP on the first
    # remaining divisible dim
    j = _largest_divisible(shape, model, taken, lo=off)
    if j is not None:
        spec[j] = "model"
        taken.append(j)
    if fsdp:
        j = _first_divisible(shape, data, taken, lo=off)
        if j is not None:
            spec[j] = "data"
    return P(*spec)


def param_specs(
    cfg: ModelConfig,
    params,
    mesh,
    *,
    fsdp: bool = True,
    ep_pods: bool = False,
):
    """PartitionSpec tree matching ``params`` (arrays or ShapeDtypeStructs)."""

    def one(path, leaf) -> P:
        names = tuple(
            k.key for k in path if isinstance(k, jax.tree_util.DictKey)
        )
        return _param_spec(
            cfg, names, tuple(leaf.shape), mesh, fsdp=fsdp, ep_pods=ep_pods
        )

    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------------
# activations / batch


def batch_specs(cfg: ModelConfig, mesh, batch) -> Dict[str, Any]:
    """Specs for step inputs: batch dim over the data axes; indivisible
    batch (e.g. ``long_500k``'s batch of 1) falls through to the sequence
    dimension."""
    axes = _data_axes(mesh)
    shard = math.prod(_axis_size(mesh, a) for a in axes)

    def one(leaf) -> P:
        shape = tuple(leaf.shape)
        spec: list = [None] * len(shape)
        if shard > 1:
            for i, dim in enumerate(shape):
                if dim % shard == 0 and dim >= shard:
                    spec[i] = axes
                    break
        return _trim(spec)

    return jax.tree_util.tree_map(one, batch)


# ---------------------------------------------------------------------------
# decode caches / recurrent state


def _cache_spec(
    path_names: Tuple[str, ...], shape: Tuple[int, ...], mesh
) -> P:
    model = _axis_size(mesh, "model")
    daxes = _data_axes(mesh)
    dshard = math.prod(_axis_size(mesh, a) for a in daxes)
    ndim = len(shape)
    spec: list = [None] * ndim
    off = 1  # leading stacked-period axis
    taken: list = [0]
    leaf = path_names[-1] if path_names else ""

    if ndim > off and dshard > 1 and shape[off] % dshard == 0:
        spec[off] = daxes
        taken.append(off)

    if model > 1:
        if leaf in ("k", "v") and ndim - off == 4:
            # (B, S, kv_heads, head_dim): heads when they divide (GQA with
            # enough heads), else sequence-sharded KV
            if shape[off + 2] % model == 0:
                spec[off + 2] = "model"
            elif shape[off + 1] % model == 0:
                spec[off + 1] = "model"
        else:
            j = _largest_divisible(shape, model, taken, lo=off + 1)
            if j is not None:
                spec[j] = "model"
    return P(*spec)


def cache_specs(cfg: ModelConfig, mesh, cache):
    """Specs for the decode cache pytree (``{"p{j}": state leaves}``)."""

    def one(path, leaf) -> P:
        names = tuple(
            k.key for k in path if isinstance(k, jax.tree_util.DictKey)
        )
        return _cache_spec(names, tuple(leaf.shape), mesh)

    return jax.tree_util.tree_map_with_path(one, cache)


# ---------------------------------------------------------------------------
# optimizer state / materialisation


def opt_specs(pspec):
    """AdamW state specs: moments inherit the parameter layout, the step
    counter replicates."""
    return {"m": pspec, "v": pspec, "step": P()}


def to_named(mesh, spec_tree):
    """PartitionSpec tree → NamedSharding tree over ``mesh``."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# batch-sharding hints (folded in from the retired ``repro.dist.hints``)
#
# Model code calls :func:`constrain_batch` unconditionally (embedding
# gathers and concatenations drop index sharding, so the batch dimension
# must be re-pinned after them). Outside a configured mesh — unit tests,
# single-host smoke runs — the helpers are identity functions, so the
# model code never has to branch on "am I distributed?".

_MESH = None
_BATCH_AXES: Optional[Tuple[str, ...]] = None


def set_hints(mesh, batch_axes: Sequence[str]) -> None:
    """Install ``mesh`` and the axis names the batch dim shards over."""
    global _MESH, _BATCH_AXES
    _MESH = mesh
    _BATCH_AXES = tuple(batch_axes)


def clear_hints() -> None:
    """Remove the active mesh; ``constrain_batch`` becomes the identity."""
    global _MESH, _BATCH_AXES
    _MESH = None
    _BATCH_AXES = None


def active_mesh():
    return _MESH


def batch_axes() -> Optional[Tuple[str, ...]]:
    return _BATCH_AXES


def constrain_batch(x):
    """Constrain the leading (batch) dimension of ``x`` to the hinted axes.

    Identity when no mesh is installed, when the array is rank-0, or when
    the hinted axes do not divide the batch dimension (a smoke-size batch
    on a production mesh must not fail lowering).
    """
    if _MESH is None or _BATCH_AXES is None:
        return x
    ndim = getattr(x, "ndim", None)
    if not ndim:  # scalars (or non-arrays) pass through
        return x
    shard = 1
    for ax in _BATCH_AXES:
        shard *= dict(_MESH.shape).get(ax, 1)
    if shard <= 1 or x.shape[0] % shard != 0:
        return x
    spec = P(_BATCH_AXES, *([None] * (ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))
