"""Distribution layer (minimal surface).

Only the ``hints`` module is implemented so far: it carries the
batch-sharding constraint helpers the model code calls unconditionally.
The remaining submodules named by the roadmap (``sharding``, ``elastic``,
``sched_bridge``, ``straggler``) land in later PRs; importers should treat
them as optional (tests gate on ``pytest.importorskip``).
"""
from . import hints

__all__ = ["hints"]
