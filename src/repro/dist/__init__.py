"""Distribution layer: sharding rules, scheduler bridge, elasticity.

Built on the ``repro.sched`` policy API: ``sched_bridge`` maps the Policy
score mechanism to expert/shard placement (including the capacity-pressure
eviction cost shared with ``repro.runtime.memory``), ``sharding`` holds
the rule-based PartitionSpec derivations for every model pytree plus the
batch-sharding constraint helpers the model code calls unconditionally
(formerly ``hints``, folded in now that the package is real), ``elastic``
re-plans mesh + placement after device-count changes (and, via
``ElasticReplanner``, follows a live fault-injected engine's
detach/attach stream), and ``straggler`` re-balances micro-batches from
observed step times with preempted shards taken out of rotation.
"""
from . import elastic, sched_bridge, sharding, straggler

__all__ = ["elastic", "sched_bridge", "sharding", "straggler"]
