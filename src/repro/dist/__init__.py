"""Distribution layer: sharding rules, scheduler bridge, elasticity.

Built on the ``repro.sched`` policy API: ``sched_bridge`` maps the Policy
score mechanism to expert/shard placement, ``sharding`` holds the
rule-based PartitionSpec derivations for every model pytree, ``elastic``
re-plans mesh + placement after device-count changes, ``straggler``
re-balances micro-batches from observed step times, and ``hints`` carries
the batch-sharding constraint helpers the model code calls unconditionally.
"""
from . import elastic, hints, sched_bridge, sharding, straggler

__all__ = ["elastic", "hints", "sched_bridge", "sharding", "straggler"]
