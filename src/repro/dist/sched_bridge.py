"""Bridge: the Policy score mechanism applied to LM-scale shard placement.

The paper's scheduler decides task→resource placement from (task ×
resource) score matrices (``repro.sched``). The same mechanism plans
layout at the distribution layer:

  * **expert placement** (:func:`plan_expert_placement`) — MoE experts →
    device groups from per-expert routing mass, via the shared
    :func:`repro.sched.assign_from_scores` kernel: a (experts × groups)
    affinity score matrix (DADA's local-affinity phase: moving an expert
    away from where its weights already live costs ``α·mass``) plus
    load-aware greedy balance (the global phase) under an exact per-group
    capacity (``E / G`` experts each, so the dispatch buffer keeps a
    static shape). The result feeds ``moe_apply``'s ``expert_perm``;
  * **layer partitioning** (:func:`partition_layers`) — pipeline stages by
    the classic dual approximation: binary search on the bottleneck guess
    λ, greedy maximal-prefix fill per probe (chains-on-chains, the same
    shape as DADA's λ search over task loads);
  * **all-to-all accounting** (:func:`expected_a2a_fraction`) — the
    fraction of routed tokens that cross group boundaries under a
    placement, i.e. the transfer volume a placement is scored on.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from repro.sched import assign_from_scores


@dataclass(frozen=True)
class ExpertPlacement:
    """Expert → device-group plan.

    ``assignment[e]`` is the group of expert ``e``; ``perm`` lists experts
    grouped by device (``perm[g*cap:(g+1)*cap]`` live on group ``g``) with
    ``inv_perm`` its inverse — the permutation ``moe_apply`` consumes.
    ``moved_experts`` counts differences against the previous assignment
    (0 when none was given).
    """

    assignment: np.ndarray
    group_load: np.ndarray
    perm: np.ndarray
    inv_perm: np.ndarray
    moved_experts: int


def plan_expert_placement(
    routing_mass: Sequence[float],
    n_groups: int,
    prev_assignment: Optional[Sequence[int]] = None,
    alpha: float = 1.0,
    expert_bytes: Optional[float] = None,
    group_hbm_bytes: Optional[float] = None,
    group_resident_bytes: Optional[Sequence[float]] = None,
    mem_penalty: float = 1.0,
) -> ExpertPlacement:
    """Place experts on device groups from routing statistics.

    ``routing_mass[e]`` is the observed token mass routed to expert ``e``.
    Experts are placed heaviest-first (LPT) onto the group minimizing
    ``affinity_score + current_load`` with exactly ``E / G`` slots per
    group; with a ``prev_assignment`` the affinity score makes staying
    free and moving cost ``alpha * mass`` — DADA's affinity phase, so
    mildly-changed loads keep most experts where their weights already
    are. ``alpha = 0`` ignores history entirely.

    With ``expert_bytes`` and ``group_hbm_bytes`` the replan also prices
    memory pressure with the simulator's eviction-cost formula
    (:func:`repro.runtime.memory.predicted_eviction_bytes`): *moving* an
    expert to group ``g`` forces ``predicted_eviction_bytes(resident_g,
    expert_bytes, group_hbm_bytes)`` bytes of weights/activations out of
    that group's HBM; staying put costs nothing. ``group_resident_bytes``
    (default: experts currently assigned × ``expert_bytes``) is each
    group's occupancy and ``mem_penalty`` scales evicted bytes into the
    score's mass units.
    """
    mass = np.asarray(routing_mass, dtype=np.float64)
    E = len(mass)
    if E == 0 or n_groups <= 0 or E % n_groups != 0:
        raise ValueError(
            f"need experts divisible by groups, got E={E}, G={n_groups}"
        )
    cap = E // n_groups

    # affinity scores: staying put is free, moving costs alpha * mass
    scores = np.zeros((E, n_groups), dtype=np.float64)
    prev = None
    if prev_assignment is not None and alpha > 0.0:
        prev = np.asarray(prev_assignment, dtype=np.int64)
        if len(prev) != E:
            raise ValueError("prev_assignment length != number of experts")
        move_cost = alpha * mass
        scores += move_cost[:, None]
        valid = (prev >= 0) & (prev < n_groups)
        scores[np.nonzero(valid)[0], prev[valid]] = 0.0

    if expert_bytes is not None and group_hbm_bytes is not None:
        from repro.runtime.memory import predicted_eviction_bytes

        if group_resident_bytes is not None:
            resident = np.asarray(group_resident_bytes, dtype=np.float64)
            if len(resident) != n_groups:
                raise ValueError("group_resident_bytes length != n_groups")
        elif prev is not None:
            valid = (prev >= 0) & (prev < n_groups)
            resident = np.bincount(
                prev[valid], minlength=n_groups
            ).astype(np.float64) * float(expert_bytes)
        else:
            resident = np.zeros(n_groups, dtype=np.float64)
        # the same eviction cost the scheduler's pressure signal charges:
        # bytes this expert's weights would push out of the target HBM
        evict = predicted_eviction_bytes(
            resident, float(expert_bytes), float(group_hbm_bytes)
        )
        pressure = np.broadcast_to(
            mem_penalty * evict[None, :], (E, n_groups)
        ).copy()
        if prev is not None:
            valid = (prev >= 0) & (prev < n_groups)
            pressure[np.nonzero(valid)[0], prev[valid]] = 0.0  # staying is free
        scores += pressure

    # heaviest-first (stable on ties) through the shared placement kernel
    order = np.lexsort((np.arange(E), -mass))
    choice, loads = assign_from_scores(
        scores,
        loads=np.zeros(n_groups),
        costs=np.broadcast_to(mass[:, None], (E, n_groups)),
        capacity=np.full(n_groups, cap, dtype=np.int64),
        order=order,
        return_loads=True,
    )
    assignment = np.asarray(choice, dtype=np.int64)
    # loads include the affinity zeros only through costs=mass: recompute
    # the true per-group mass for reporting
    group_load = np.bincount(assignment, weights=mass, minlength=n_groups)
    perm = np.argsort(assignment, kind="stable")
    inv_perm = np.argsort(perm, kind="stable")
    moved = int((assignment != prev).sum()) if prev is not None else 0
    return ExpertPlacement(
        assignment=assignment,
        group_load=group_load,
        perm=perm,
        inv_perm=inv_perm,
        moved_experts=moved,
    )


def expected_a2a_fraction(
    mass_by_source: np.ndarray, assignment: Sequence[int]
) -> float:
    """Fraction of routed token mass that crosses device groups.

    ``mass_by_source[g, e]``: mass routed from tokens resident on group
    ``g`` to expert ``e``. Mass staying on its own group skips the
    all-to-all; everything else pays it.
    """
    m = np.asarray(mass_by_source, dtype=np.float64)
    a = np.asarray(assignment, dtype=np.int64)
    G, E = m.shape
    total = m.sum()
    if total <= 0:
        return 0.0
    local = sum(float(m[g, a == g].sum()) for g in range(G))
    return float(1.0 - local / total)


# ---------------------------------------------------------------------------
# pipeline-stage partitioning (chains-on-chains dual approximation)


def stage_loads(costs: Sequence[float], starts: Sequence[int]) -> List[float]:
    """Per-stage cost sums for stage boundaries ``starts`` (first must be
    0; stage ``i`` spans ``starts[i]:starts[i+1]``)."""
    bounds = list(starts) + [len(costs)]
    return [float(sum(costs[a:b])) for a, b in zip(bounds, bounds[1:])]


def _greedy_starts(costs: Sequence[float], lam: float) -> List[int]:
    """Maximal-prefix fill: new stage exactly when adding the next layer
    would overreach λ (greedy is stage-minimal among ≤λ partitions)."""
    starts = [0]
    acc = 0.0
    for i, c in enumerate(costs):
        if acc + c > lam and acc > 0.0:
            starts.append(i)
            acc = 0.0
        acc += c
    return starts


def partition_layers(costs: Sequence[float], k: int) -> List[int]:
    """Split a layer chain into ``k`` pipeline stages (dual approximation).

    Binary search on the bottleneck guess λ within
    ``[max(max_cost, total/k), total]``; each probe greedily fills stages
    up to λ and is feasible iff it needs ≤ k stages. The accepted
    partition satisfies the classic bound
    ``max(stage) ≤ 2 * max(max_cost, total/k)``. Returns exactly ``k``
    stage starts (surplus stages are empty tail stages on short chains).
    """
    costs = [float(c) for c in costs]
    if k <= 0:
        raise ValueError("need at least one stage")
    total = sum(costs)
    lo = max(max(costs, default=0.0), total / k)
    hi = total
    if not costs or lo <= 0.0:
        return [0] + [len(costs)] * (k - 1)
    best = _greedy_starts(costs, lo)
    if len(best) > k:  # lo infeasible: bisect up to the minimal feasible λ
        best = _greedy_starts(costs, hi)
        for _ in range(100):
            if hi - lo <= 1e-12 * hi:
                break
            mid = (lo + hi) / 2.0
            s = _greedy_starts(costs, mid)
            if len(s) <= k:
                hi = mid
                best = s
            else:
                lo = mid
    starts = best + [len(costs)] * (k - len(best))
    return starts[:k]
