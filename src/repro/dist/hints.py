"""Sharding-hint plumbing: pin the batch axis of activations to the mesh.

Model code calls :func:`constrain_batch` unconditionally (embedding gathers
and concatenations drop index sharding, so the batch dimension must be
re-pinned after them). Outside a configured mesh — unit tests, single-host
smoke runs — the helpers are identity functions, so the model code never
has to branch on "am I distributed?".

``set_hints(mesh, batch_axes)`` installs the active mesh and the mesh-axis
tuple the leading (batch) dimension is sharded over; ``clear_hints()``
uninstalls them. Both are idempotent.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

_MESH = None
_BATCH_AXES: Optional[Tuple[str, ...]] = None


def set_hints(mesh, batch_axes: Sequence[str]) -> None:
    """Install ``mesh`` and the axis names the batch dim shards over."""
    global _MESH, _BATCH_AXES
    _MESH = mesh
    _BATCH_AXES = tuple(batch_axes)


def clear_hints() -> None:
    """Remove the active mesh; ``constrain_batch`` becomes the identity."""
    global _MESH, _BATCH_AXES
    _MESH = None
    _BATCH_AXES = None


def active_mesh():
    return _MESH


def batch_axes() -> Optional[Tuple[str, ...]]:
    return _BATCH_AXES


def constrain_batch(x):
    """Constrain the leading (batch) dimension of ``x`` to the hinted axes.

    Identity when no mesh is installed, when the array is rank-0, or when
    the hinted axes do not divide the batch dimension (a smoke-size batch
    on a production mesh must not fail lowering).
    """
    if _MESH is None or _BATCH_AXES is None:
        return x
    ndim = getattr(x, "ndim", None)
    if not ndim:  # scalars (or non-arrays) pass through
        return x
    import jax
    from jax.sharding import NamedSharding, PartitionSpec

    shard = 1
    for ax in _BATCH_AXES:
        shard *= dict(_MESH.shape).get(ax, 1)
    if shard <= 1 or x.shape[0] % shard != 0:
        return x
    spec = PartitionSpec(_BATCH_AXES, *([None] * (ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))
