"""Elastic re-planning: device loss/gain → mesh shape + expert placement.

A pod that loses devices (preemption, hardware fault) must keep serving:
``choose_mesh_shape`` picks the largest supported (data, model) mesh that
fits the surviving device count, and ``replan`` rebuilds the expert
placement *with affinity to the previous plan* — the paper's criterion
applied to failure recovery: experts whose weights already live on
surviving groups stay put, so the re-shard moves a minimum of bytes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np

from .sched_bridge import ExpertPlacement, plan_expert_placement

MODEL_AXIS = 16  # the TP group: fixed by kernel tiling, never degraded


def choose_mesh_shape(n_devices: int) -> Tuple[int, int]:
    """Largest (data, model) mesh fitting ``n_devices``.

    The model axis stays 16 (TP layouts are compiled for it); the data
    axis degrades to the largest power of two that fits, so a 300-device
    degraded pod runs as (16, 16) and a 17-device remnant as (1, 16).
    """
    if n_devices < MODEL_AXIS:
        raise ValueError(
            f"need at least {MODEL_AXIS} devices for one TP group, "
            f"got {n_devices}"
        )
    data = 1
    while data * 2 * MODEL_AXIS <= n_devices:
        data *= 2
    return (data, MODEL_AXIS)


@dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: Tuple[int, int]
    n_devices: int  # devices actually used
    placement: ExpertPlacement


def replan(
    n_devices: int,
    *,
    n_experts: int,
    routing_mass: Optional[Sequence[float]] = None,
    prev_assignment: Optional[Sequence[int]] = None,
    alpha: float = 1.0,
) -> ElasticPlan:
    """Re-plan mesh + expert placement after a device-count change.

    Expert groups ride the model axis (the all-to-all stays inside a
    pod's fast interconnect); when the expert count does not divide the
    axis, the group count halves until it does. ``prev_assignment``
    (from the plan being replaced) engages the affinity phase so
    surviving experts keep their weights in place.
    """
    shape = choose_mesh_shape(n_devices)
    groups = shape[1]
    while groups > 1 and n_experts % groups:
        groups //= 2
    if routing_mass is None:
        mass = np.ones(n_experts, dtype=np.float64)  # no stats yet: uniform
    else:
        mass = np.asarray(routing_mass, dtype=np.float64)
    if len(mass) != n_experts:
        raise ValueError("routing_mass length != n_experts")
    prev = prev_assignment
    if prev is not None:
        prev = np.asarray(prev, dtype=np.int64)
        # groups that no longer exist carry no affinity
        prev = np.where(prev < groups, prev, -1)
    placement = plan_expert_placement(mass, groups, prev_assignment=prev, alpha=alpha)
    return ElasticPlan(
        mesh_shape=shape,
        n_devices=shape[0] * shape[1],
        placement=placement,
    )
