"""Elastic re-planning: device loss/gain → mesh shape + expert placement.

A pod that loses devices (preemption, hardware fault) must keep serving:
``choose_mesh_shape`` picks the largest supported (data, model) mesh that
fits the surviving device count, and ``replan`` rebuilds the expert
placement *with affinity to the previous plan* — the paper's criterion
applied to failure recovery: experts whose weights already live on
surviving groups stay put, so the re-shard moves a minimum of bytes.

:class:`ElasticReplanner` closes the loop with the fault-injected
runtime (``repro.runtime.faults``): it subscribes to an engine's
detach/attach notifications and re-plans on every membership change,
carrying the previous assignment forward so each recovery step is
affinity-minimal.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .sched_bridge import ExpertPlacement, plan_expert_placement

MODEL_AXIS = 16  # the TP group: fixed by kernel tiling, never degraded


def choose_mesh_shape(n_devices: int, model_axis: int = MODEL_AXIS) -> Tuple[int, int]:
    """Largest (data, model) mesh fitting ``n_devices``.

    The model axis stays fixed (TP layouts are compiled for it; default
    16); the data axis degrades to the largest power of two that fits,
    so a 300-device degraded pod runs as (16, 16) and a 17-device
    remnant as (1, 16).
    """
    if model_axis < 1:
        raise ValueError(f"model_axis must be >= 1, got {model_axis}")
    if n_devices < model_axis:
        raise ValueError(
            f"need at least {model_axis} devices for one TP group, "
            f"got {n_devices}"
        )
    data = 1
    while data * 2 * model_axis <= n_devices:
        data *= 2
    return (data, model_axis)


@dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: Tuple[int, int]
    n_devices: int  # devices actually used
    placement: ExpertPlacement


def replan(
    n_devices: int,
    *,
    n_experts: int,
    routing_mass: Optional[Sequence[float]] = None,
    prev_assignment: Optional[Sequence[int]] = None,
    alpha: float = 1.0,
    model_axis: int = MODEL_AXIS,
) -> ElasticPlan:
    """Re-plan mesh + expert placement after a device-count change.

    Expert groups ride the model axis (the all-to-all stays inside a
    pod's fast interconnect); when the expert count does not divide the
    axis, the group count halves until it does. ``prev_assignment``
    (from the plan being replaced) engages the affinity phase so
    surviving experts keep their weights in place.
    """
    shape = choose_mesh_shape(n_devices, model_axis)
    groups = shape[1]
    while groups > 1 and n_experts % groups:
        groups //= 2
    if routing_mass is None:
        mass = np.ones(n_experts, dtype=np.float64)  # no stats yet: uniform
    else:
        mass = np.asarray(routing_mass, dtype=np.float64)
    if len(mass) != n_experts:
        raise ValueError("routing_mass length != n_experts")
    prev = prev_assignment
    if prev is not None:
        prev = np.asarray(prev, dtype=np.int64)
        # groups that no longer exist carry no affinity
        prev = np.where(prev < groups, prev, -1)
    placement = plan_expert_placement(mass, groups, prev_assignment=prev, alpha=alpha)
    return ElasticPlan(
        mesh_shape=shape,
        n_devices=shape[0] * shape[1],
        placement=placement,
    )


def moved_experts(
    prev: Optional[ElasticPlan], new: Optional[ElasticPlan]
) -> int:
    """Experts whose group changed between two plans (weight moves).

    Experts mapped to groups that no longer exist count as moved; with
    either plan missing every expert of the other plan moves.
    """
    if new is None:
        return 0 if prev is None else len(prev.placement.assignment)
    if prev is None:
        return len(new.placement.assignment)
    a = np.asarray(prev.placement.assignment, dtype=np.int64)
    b = np.asarray(new.placement.assignment, dtype=np.int64)
    if a.shape != b.shape:
        raise ValueError("plans place different expert counts")
    return int(np.count_nonzero(a != b))


class ElasticReplanner:
    """Live elastic re-planning driven by the fault-injected runtime.

    Subscribes to an engine's :class:`~repro.runtime.faults.FaultManager`
    and re-plans the mesh + expert placement on every accelerator
    detach/attach, mapping each surviving accelerator to
    ``devices_per_worker`` pod devices. Every step passes the previous
    assignment through, so the affinity phase keeps surviving experts'
    weights in place and ``total_moved`` measures exactly the re-shard
    traffic the paper's criterion saves.

    When the surviving device count drops below one TP group the pod
    cannot serve; the event is still recorded (with plan ``None``) and
    ``current`` keeps the last viable plan so a later attach resumes
    with affinity to it.
    """

    def __init__(
        self,
        *,
        devices_per_worker: int,
        n_experts: int,
        model_axis: int = MODEL_AXIS,
        routing_mass: Optional[Sequence[float]] = None,
        alpha: float = 1.0,
    ) -> None:
        if devices_per_worker < 1:
            raise ValueError("devices_per_worker must be >= 1")
        self.devices_per_worker = devices_per_worker
        self.n_experts = n_experts
        self.model_axis = model_axis
        self.routing_mass = routing_mass
        self.alpha = alpha
        self.current: Optional[ElasticPlan] = None
        #: (time, event, n_devices, plan-or-None) per membership change
        self.history: List[Tuple[float, str, int, Optional[ElasticPlan]]] = []
        self.total_moved = 0

    # ------------------------------------------------------------------
    def attach_to(self, engine) -> "ElasticReplanner":
        """Wire to a live engine: plan for the current membership, then
        follow every detach/attach through ``engine.faults``."""
        engine.faults.subscribe(self._on_fault)
        self._replan(engine, float(engine.now), "init")
        return self

    def _on_fault(self, engine, event: str, rid: int, mode) -> None:
        if event in ("detach", "attach"):
            self._replan(engine, float(engine.now), event)

    # ------------------------------------------------------------------
    def _alive_accels(self, engine) -> int:
        dead = engine.faults.dead_rids
        return sum(1 for r in engine.machine.gpus if r.rid not in dead)

    def _replan(self, engine, t: float, event: str) -> None:
        n_devices = self._alive_accels(engine) * self.devices_per_worker
        if n_devices >= self.model_axis:
            prev = (
                None
                if self.current is None
                else self.current.placement.assignment
            )
            plan = replan(
                n_devices,
                n_experts=self.n_experts,
                routing_mass=self.routing_mass,
                prev_assignment=prev,
                alpha=self.alpha,
                model_axis=self.model_axis,
            )
            if self.current is not None:
                self.total_moved += moved_experts(self.current, plan)
            self.current = plan
        else:
            plan = None  # below one TP group: keep last viable plan
        self.history.append((t, event, n_devices, plan))
