"""Three-term roofline from the dry-run artifacts (TPU v5e targets).

  compute term    = FLOPs / (chips x 197 TFLOP/s bf16)
  memory term     = HBM bytes / (chips x 819 GB/s)
  collective term = per-device collective wire bytes / 50 GB/s-link

FLOPs/bytes use the analytic accounting (analysis/flops.py) because XLA's
cost_analysis counts while-loop bodies once (tests/test_roofline.py); the
raw HLO numbers are carried alongside for reference. Collective bytes come
from the compiled HLO with loop-trip multipliers (analysis/hlo.py).

The estimated step time is max(terms) (perfect-overlap ideal); the score
metric is MFU_est = model_flops / (chips x peak x step_time) — the fraction
of the chips' roofline the step actually converts into model FLOPs.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
LINK_BW = 50e9  # bytes/s / ICI link


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    mfu_est: float
    model_flops: float
    analytic_flops: float
    hlo_flops_raw: float
    useful_ratio: float  # model / analytic
    note: str

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


_NOTES = {
    "compute": "compute-bound: reduce recompute (remat policy) or shrink the"
    " useful-ratio gap (fusion, avoiding fp32 matmuls)",
    "memory": "HBM-bound: shrink resident traffic (KV-cache quantization,"
    " bf16 states, fewer param re-reads per microbatch)",
    "collective": "ICI-bound: cut wire bytes (affinity expert placement,"
    " gradient compression, reduce-scatter instead of all-reduce)",
}


def analyse_record(rec: Dict) -> Optional[RooflineRow]:
    if rec.get("status") != "ok":
        return None
    chips = rec["n_devices"]
    comp = rec["analytic_flops"] / (chips * PEAK_FLOPS)
    mem = rec["analytic_hbm_bytes"] / (chips * HBM_BW)
    coll_dev = rec["collective_bytes_per_device"]["total"]
    coll = coll_dev / LINK_BW
    terms = {"compute": comp, "memory": mem, "collective": coll}
    bottleneck = max(terms, key=terms.get)
    step = max(terms.values())
    mfu = rec["model_flops"] / (chips * PEAK_FLOPS * step) if step > 0 else 0.0
    return RooflineRow(
        arch=rec["arch"],
        shape=rec["shape"],
        mesh=rec["mesh"],
        chips=chips,
        compute_s=comp,
        memory_s=mem,
        collective_s=coll,
        bottleneck=bottleneck,
        mfu_est=mfu,
        model_flops=rec["model_flops"],
        analytic_flops=rec["analytic_flops"],
        hlo_flops_raw=rec["hlo_flops_raw"],
        useful_ratio=rec["model_flops"] / max(rec["analytic_flops"], 1.0),
        note=_NOTES[bottleneck],
    )


def load_rows(results_dir: Path, mesh: str = "pod1") -> List[RooflineRow]:
    rows = []
    for p in sorted(results_dir.glob(f"*__{mesh}.json")):
        row = analyse_record(json.loads(p.read_text()))
        if row is not None:
            rows.append(row)
    return rows


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:7.2f}s "
    if x >= 1e-3:
        return f"{x*1e3:7.2f}ms"
    return f"{x*1e6:7.1f}us"


def table(rows: List[RooflineRow]) -> str:
    hdr = (
        f"{'arch':22s} {'shape':12s} {'compute':9s} {'memory':9s} "
        f"{'collective':10s} {'bound':10s} {'MFU_est':8s} {'useful':7s}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:22s} {r.shape:12s} {fmt_s(r.compute_s)} {fmt_s(r.memory_s)} "
            f"{fmt_s(r.collective_s)}  {r.bottleneck:10s} {r.mfu_est*100:6.1f}% "
            f"{r.useful_ratio*100:6.1f}%"
        )
    return "\n".join(lines)
