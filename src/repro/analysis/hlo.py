"""Post-optimization HLO parsing: collective bytes with while-loop trip
multipliers.

``compiled.as_text()`` gives the SPMD-partitioned module where collectives
(all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute)
appear with *per-device* operand shapes. Collectives inside a ``while`` body
execute once per trip, so we recover each loop's trip count from its
condition computation (the ``iter < N`` constant) and multiply.

Validated against unrolled lowerings in tests/test_roofline.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z]+\d*(?:e\d+m\d+(?:fn)?)?)\[([\d,]*)\]")
# header like: "%region_0.1_spmd (param: (s32[], f32[...])) -> (...) {"
# (nested parens in the arg list, hence the greedy middle)
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(.*->.*\{\s*$")
_CALL_ATTR = re.compile(r"(?:body|condition|to_apply|called_computations=\{)[=]?%?([\w\.\-]+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class Computation:
    name: str
    lines: List[str] = field(default_factory=list)
    while_calls: List[Tuple[str, str]] = field(default_factory=list)  # (body, cond)
    other_calls: List[str] = field(default_factory=list)


def parse_computations(hlo_text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        m = _COMP_HDR.match(line) if (line and not line.startswith(" ")) else None
        if m is None and stripped.endswith("{") and "->" in stripped and not line.startswith(" "):
            m = _COMP_HDR.match(stripped)
        if m:
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            continue
        if cur is None or not stripped:
            continue
        cur.lines.append(stripped)
        if " while(" in stripped or stripped.startswith("while("):
            body = re.search(r"body=%?([\w\.\-]+)", stripped)
            cond = re.search(r"condition=%?([\w\.\-]+)", stripped)
            if body and cond:
                cur.while_calls.append((body.group(1), cond.group(1)))
        else:
            for cm in re.finditer(r"(?:to_apply|calls)=%?([\w\.\-]+)", stripped):
                cur.other_calls.append(cm.group(1))
            fm = re.search(r"fusion\(.*?\), kind=\w+, calls=%?([\w\.\-]+)", stripped)
            if fm:
                cur.other_calls.append(fm.group(1))
    return comps


def trip_count(cond: Computation) -> int:
    """Largest s32/u32 scalar constant in the loop condition (the bound of
    the canonical ``iter < N`` compare)."""
    best = 1
    for line in cond.lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            v = int(m.group(1))
            if 1 < v <= 10_000_000:
                best = max(best, v)
    return best


def _entry_name(comps: Dict[str, Computation], hlo_text: str) -> Optional[str]:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo_text, re.M)
    if m:
        return m.group(1)
    return next(iter(comps)) if comps else None


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device collective operand bytes by op kind, loop-trip adjusted."""
    comps = parse_computations(hlo_text)
    entry = _entry_name(comps, hlo_text)
    mult: Dict[str, float] = {}

    def visit(name: str, m: float) -> None:
        if name not in comps:
            return
        # a computation may be reached multiple times; accumulate the
        # largest multiplier (call sites dominate)
        if mult.get(name, 0) >= m:
            return
        mult[name] = m
        c = comps[name]
        for body, cond in c.while_calls:
            trips = trip_count(comps[cond]) if cond in comps else 1
            visit(body, m * trips)
            visit(cond, m * trips)
        for callee in c.other_calls:
            visit(callee, m)

    if entry:
        visit(entry, 1.0)

    out = {k: 0.0 for k in COLLECTIVE_OPS}
    out["total"] = 0.0
    for name, comp in comps.items():
        m = mult.get(name, 1.0)
        for line in comp.lines:
            for op in COLLECTIVE_OPS:
                # def line: "%x = f32[..]{..} all-reduce(%y), replica_groups=..."
                token = None
                for t in (f" {op}(", f" {op}-start("):
                    if t in line:
                        token = t
                        break
                if token is None:
                    continue
                head = line.split(token, 1)[0]  # result tuple lives here
                result_bytes = sum(
                    _shape_bytes(sm.group(1), sm.group(2))
                    for sm in _SHAPE_RE.finditer(head)
                )
                # per-device wire bytes by op semantics
                wire = result_bytes
                gm = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
                group_size = int(gm.group(2)) if gm else 0
                if op == "reduce-scatter" and group_size:
                    wire = result_bytes * group_size  # operand is G x result
                elif op == "all-reduce":
                    wire = 2.0 * result_bytes  # ring: reduce-scatter + gather
                if "_promoted" in line and " f32[" in head + " ":
                    # CPU backend promotes bf16 reductions to f32
                    # (to_apply=%add.*_promoted); TPU reduces in bf16 —
                    # count at native width
                    wire *= 0.5
                out[op] += wire * m
                out["total"] += wire * m
                break
    return out


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))
