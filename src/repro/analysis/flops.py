"""Analytic FLOP / byte accounting per (arch x shape) cell.

Why analytic: XLA's ``cost_analysis()`` counts while-loop bodies ONCE
(verified in tests/test_roofline.py), so any scanned model (layer stacks,
microbatch accumulation, SSM/xLSTM recurrences) is undercounted by the trip
count. The roofline therefore uses these closed-form counts as the compute/
memory terms, reports the raw HLO numbers alongside, and cross-checks the
two on scan-free lowerings.

Conventions: 1 MAC = 2 FLOPs; causal attention scores cost S_ctx/2 per
query on average during train/prefill and S_ctx per query at decode.
Train multiplier = 4x forward (fwd + 2x bwd + 1x remat recompute when
cfg.remat) — the standard accounting.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec


def _attn_flops_per_tok(cfg: ModelConfig, ctx: float) -> float:
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    if cfg.mla is not None:
        m = cfg.mla
        qk = m.qk_nope_dim + m.qk_rope_dim
        proj = 2 * (
            d * m.q_lora_rank
            + m.q_lora_rank * H * qk
            + d * (m.kv_lora_rank + m.qk_rope_dim)
            + m.kv_lora_rank * H * (m.qk_nope_dim + m.v_head_dim)
            + H * m.v_head_dim * d
        )
        scores = 2 * H * (qk + m.v_head_dim) * ctx
        return proj + scores
    proj = 2 * d * (H * hd + 2 * Hkv * hd) + 2 * H * hd * d
    scores = 2 * H * hd * ctx * 2  # QK^T + PV
    return proj + scores


def _mlp_flops_per_tok(cfg: ModelConfig) -> float:
    mult = 3 if cfg.act in ("silu", "geglu") else 2
    return 2 * mult * cfg.d_model * cfg.d_ff


def _moe_flops_per_tok(cfg: ModelConfig) -> float:
    m = cfg.moe
    return 2 * cfg.d_model * m.n_experts + m.top_k * 2 * 3 * cfg.d_model * m.d_ff


def _mamba_flops_per_tok(cfg: ModelConfig) -> float:
    d = cfg.d_model
    din = cfg.mamba_expand * d
    N = cfg.mamba_d_state
    rank = max(1, d // 16)
    return (
        2 * d * 2 * din  # in_proj
        + 2 * cfg.mamba_d_conv * din
        + 2 * din * (rank + 2 * N)
        + 2 * rank * din
        + 8 * din * N  # scan update + readout
        + 2 * din * d  # out_proj
    )


def _mlstm_flops_per_tok(cfg: ModelConfig) -> float:
    d = cfg.d_model
    din = 2 * d
    H = cfg.n_heads
    hd = din // H
    return (
        2 * d * 2 * din  # up
        + 3 * 2 * din * din  # q,k,v
        + 8 * H * hd * hd  # C update + C q readout
        + 2 * din * din  # o proj
        + 2 * din * d  # down
    )


def _slstm_flops_per_tok(cfg: ModelConfig) -> float:
    d = cfg.d_model
    return 2 * d * 4 * d + 2 * d * 4 * d + 2 * d * d + 20 * d


def forward_flops_per_tok(cfg: ModelConfig, ctx: float) -> float:
    """Decoder-stack forward FLOPs for one token with context ``ctx``."""
    total = 0.0
    for i in range(cfg.n_layers):
        kind = cfg.block_pattern[i % cfg.period]
        if kind == "attn":
            total += _attn_flops_per_tok(cfg, ctx)
        elif kind == "mamba":
            total += _mamba_flops_per_tok(cfg)
        elif kind == "mlstm":
            total += _mlstm_flops_per_tok(cfg)
        elif kind == "slstm":
            total += _slstm_flops_per_tok(cfg)
        if kind in ("attn", "mamba"):
            if cfg.moe is not None and (i % cfg.period) % cfg.moe.every == cfg.moe.every - 1:
                total += _moe_flops_per_tok(cfg)
            else:
                total += _mlp_flops_per_tok(cfg)
    return total


@dataclass
class CellCost:
    flops: float  # best-estimate executed FLOPs for the whole step
    hbm_bytes: float  # best-estimate HBM traffic for the whole step
    model_flops: float  # 6*N_active*D headline
    notes: str = ""


def cell_cost(cfg: ModelConfig, shape: ShapeSpec, micro_batches: int = 1) -> CellCost:
    B, S = shape.global_batch, shape.seq_len
    P = cfg.frontend_tokens if cfg.family in ("vlm", "audio") else 0
    pbytes = {"float32": 4, "bfloat16": 2}[cfg.param_dtype]
    n_params = cfg.params_count()

    if shape.kind == "train":
        toks = B * S
        fwd = toks * (forward_flops_per_tok(cfg, S / 2) + 2 * cfg.d_model * cfg.vocab)
        if cfg.enc_layers:
            enc = toks * cfg.enc_layers * (
                _attn_flops_per_tok(cfg, S / 2) + _mlp_flops_per_tok(cfg)
            )
            fwd += enc + toks * cfg.n_layers * _attn_flops_per_tok(cfg, S / 2)  # cross
        mult = 4.0 if cfg.remat else 3.0
        flops = fwd * mult
        # params read fwd+bwd per microbatch, grads written once per micro,
        # optimizer read/write m,v (fp32) + params once per step
        hbm = n_params * pbytes * (2 * micro_batches + 1) + n_params * 4 * 5
        # activations: rough 14 bytes/token/layer-d (bf16 remat residuals)
        hbm += toks * cfg.d_model * (cfg.n_layers + cfg.enc_layers) * 4
        model_flops = 6 * cfg.active_params_count() * toks
        return CellCost(flops, hbm, model_flops)

    if shape.kind == "prefill":
        toks = B * S
        flops = toks * forward_flops_per_tok(cfg, S / 2) + B * 2 * cfg.d_model * cfg.vocab
        if cfg.enc_layers:
            flops += toks * cfg.enc_layers * (
                _attn_flops_per_tok(cfg, S / 2) + _mlp_flops_per_tok(cfg)
            ) + toks * cfg.n_layers * _attn_flops_per_tok(cfg, S / 2)
        hbm = n_params * pbytes + toks * cfg.d_model * cfg.n_layers * 2
        model_flops = 2 * cfg.active_params_count() * toks
        return CellCost(flops, hbm, model_flops)

    # decode: one token against a cache of length S
    toks = B
    flops = toks * (forward_flops_per_tok(cfg, S) + 2 * cfg.d_model * cfg.vocab)
    if cfg.enc_layers:
        # cross-attention K/V recomputed from encoder memory (baseline)
        flops += toks * cfg.n_layers * _attn_flops_per_tok(cfg, S)
        flops += B * S * cfg.n_layers * 2 * 2 * cfg.d_model * cfg.n_kv_heads * cfg.hd
    # params: MoE decode touches min(B*top_k, E) experts per moe layer
    active_param_bytes = n_params * pbytes
    if cfg.moe is not None:
        m = cfg.moe
        n_moe = sum(
            1 for i in range(cfg.n_layers)
            if cfg.block_pattern[i % cfg.period] in ("attn", "mamba")
            and (i % cfg.period) % m.every == m.every - 1
        )
        expert_bytes = 3 * cfg.d_model * m.d_ff * pbytes
        touched = min(B * m.top_k, m.n_experts)
        active_param_bytes = (
            n_params - n_moe * m.n_experts * 3 * cfg.d_model * m.d_ff
        ) * pbytes + n_moe * touched * expert_bytes
    hbm = active_param_bytes + cache_bytes(cfg, B, S) * 1.0 + toks * cfg.d_model * cfg.n_layers * 8
    model_flops = 2 * cfg.active_params_count() * toks
    return CellCost(flops, hbm, model_flops)


def cache_bytes(cfg: ModelConfig, B: int, S: int) -> float:
    """Total decode-cache bytes (read once per decode step)."""
    cbytes = {"float32": 4, "bfloat16": 2}[cfg.compute_dtype]
    total = 0.0
    for i in range(cfg.n_layers):
        kind = cfg.block_pattern[i % cfg.period]
        if kind == "attn":
            if cfg.mla is not None:
                total += B * S * (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim) * cbytes
            else:
                total += 2 * B * S * cfg.n_kv_heads * cfg.hd * cbytes
        elif kind == "mamba":
            total += B * cfg.mamba_expand * cfg.d_model * cfg.mamba_d_state * 4
        elif kind == "mlstm":
            din = 2 * cfg.d_model
            total += B * cfg.n_heads * (din // cfg.n_heads) ** 2 * 4
        elif kind == "slstm":
            total += 4 * B * cfg.d_model * 4
    return total
