"""CLI for the static-analysis layer.

    python -m repro.verify lint [paths...]
    python -m repro.verify schedule AUDIT.jsonl [more.jsonl...]

``lint`` defaults to the installed ``repro`` package tree and exits 1
on any finding.  ``schedule`` verifies audit logs previously written
with ``AuditLog.to_jsonl`` and exits 1 when any log has errors
(warnings are printed but do not fail).
"""

from __future__ import annotations

import argparse
import sys
from typing import Optional, Sequence

from repro.verify.audit import AuditLog
from repro.verify.lint import lint_paths
from repro.verify.schedule import errors, verify_audit


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.verify", description=__doc__
    )
    sub = parser.add_subparsers(dest="cmd", required=True)
    p_lint = sub.add_parser("lint", help="AST determinism/config lint")
    p_lint.add_argument("paths", nargs="*", help="files or directories (default: repro package)")
    p_sched = sub.add_parser("schedule", help="verify audit-log JSONL files")
    p_sched.add_argument("logs", nargs="+", help="audit logs written by AuditLog.to_jsonl")
    args = parser.parse_args(argv)

    if args.cmd == "lint":
        findings = lint_paths(args.paths)
        for f in findings:
            print(f)
        print(f"lint: {len(findings)} finding(s)")
        return 1 if findings else 0

    failed = False
    for path in args.logs:
        log = AuditLog.from_jsonl(path)
        findings = verify_audit(log)
        errs = errors(findings)
        for f in findings:
            print(f"{path}: {f}")
        print(
            f"{path}: engine={log.engine} "
            f"{len(errs)} error(s), {len(findings) - len(errs)} warning(s)"
        )
        failed = failed or bool(errs)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
