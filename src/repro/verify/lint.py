"""AST-based determinism/config lint for ``src/repro``.

Run as ``python -m repro.verify lint`` (exit 1 on findings).  Rules:

- ``ENV001``  ``os.environ`` / ``os.getenv`` outside ``sched/config.py``.
  ``SchedConfig`` is the single validated environment source; ad-hoc
  reads bypass its schema, snapshot memoization and subprocess
  propagation (``env_items``).  Allowlist: ``launch/dryrun.py`` —
  ``XLA_FLAGS`` must be set before the first jax import (earlier than
  any config object can exist) and ``REPRO_RESULTS_DIR`` is a
  launcher-only output path.
- ``RND001``  global-state numpy randomness: any ``np.random.<fn>()``
  call on the module-level generator, or ``np.random.default_rng()``
  with no seed.  Everything stochastic must thread an explicit seed.
- ``TIME001`` wall-clock reads (``time.time``, ``datetime.now``,
  ``datetime.utcnow``) — simulated time must come from the event loop,
  never the host clock.  Allowlist: ``launch/`` (real training/serving
  entry points legitimately read wall time).
- ``SYNC001`` host-sync smells inside jitted paths of
  ``core/backend.py`` / ``core/episode.py``: ``.item()`` calls or
  ``float(...)``/``int(...)`` on non-constant arguments inside a
  function that is wrapped by ``jax.jit`` (direct call, decorator, or
  ``partial(jax.jit, ...)``).  Each forces a device→host transfer and a
  blocking sync per trace.
"""

from __future__ import annotations

import ast
import os
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

DEFAULT_ROOT = os.path.join(os.path.dirname(os.path.dirname(__file__)))

ENV_HOME = "sched/config.py"
ENV_ALLOW = {
    # XLA_FLAGS must be exported before the first jax import, which is
    # earlier than SchedConfig can run; REPRO_RESULTS_DIR is an output
    # path for the launcher only.
    "launch/dryrun.py",
}
TIME_ALLOW_PREFIXES = ("launch/",)
SYNC_SUFFIXES = ("backend.py", "episode.py")

# numpy module-level generator functions (implicit global state)
_GLOBAL_RANDOM_FNS = {
    "random",
    "rand",
    "randn",
    "randint",
    "random_sample",
    "ranf",
    "sample",
    "choice",
    "shuffle",
    "permutation",
    "uniform",
    "normal",
    "standard_normal",
    "exponential",
    "poisson",
    "beta",
    "binomial",
    "gamma",
    "seed",
    "bytes",
}


@dataclass
class LintFinding:
    path: str
    line: int
    code: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"


def _rel(path: str) -> str:
    """Path relative to the ``repro`` package root, '/'-separated."""
    norm = path.replace(os.sep, "/")
    marker = "repro/"
    idx = norm.rfind(marker)
    if idx >= 0:
        return norm[idx + len(marker):]
    return os.path.basename(norm)


def _attr_chain(node: ast.AST) -> Optional[List[str]]:
    """Dotted name of an attribute chain, e.g. np.random.rand -> [...]."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        parts.reverse()
        return parts
    return None


def _is_jax_jit(node: ast.AST) -> bool:
    chain = _attr_chain(node)
    return chain is not None and chain[-1] == "jit" and chain[0] in ("jax", "jit")


def _jit_wrapped_names(tree: ast.Module) -> Set[str]:
    """Names of functions passed to jax.jit(...) anywhere in the module."""
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _is_jax_jit(node.func):
            for arg in node.args[:1]:
                if isinstance(arg, ast.Name):
                    names.add(arg.id)
    return names


def _is_jitted_def(fn: ast.AST, jit_names: Set[str]) -> bool:
    if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
        return False
    if fn.name in jit_names:
        return True
    for dec in fn.decorator_list:
        if _is_jax_jit(dec):
            return True
        if isinstance(dec, ast.Call):
            if _is_jax_jit(dec.func):
                return True
            # partial(jax.jit, ...) / functools.partial(jax.jit, ...)
            chain = _attr_chain(dec.func)
            if chain and chain[-1] == "partial":
                if any(_is_jax_jit(a) for a in dec.args):
                    return True
    return False


def _check_env(tree: ast.Module, rel: str, out: List[LintFinding]) -> None:
    if rel == ENV_HOME or rel in ENV_ALLOW:
        return
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute):
            chain = _attr_chain(node)
            if chain == ["os", "environ"]:
                out.append(
                    LintFinding(
                        rel,
                        node.lineno,
                        "ENV001",
                        "os.environ access outside sched/config.py "
                        "(route through SchedConfig)",
                    )
                )
        elif isinstance(node, ast.Call):
            chain = _attr_chain(node.func)
            if chain == ["os", "getenv"]:
                out.append(
                    LintFinding(
                        rel,
                        node.lineno,
                        "ENV001",
                        "os.getenv outside sched/config.py "
                        "(route through SchedConfig)",
                    )
                )


def _check_random(tree: ast.Module, rel: str, out: List[LintFinding]) -> None:
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if not chain or len(chain) < 3:
            continue
        if chain[0] in ("np", "numpy") and chain[1] == "random":
            fn = chain[2]
            if fn in _GLOBAL_RANDOM_FNS:
                out.append(
                    LintFinding(
                        rel,
                        node.lineno,
                        "RND001",
                        f"np.random.{fn}() uses the unseeded module-level "
                        "generator (thread an explicit Generator/seed)",
                    )
                )
            elif fn == "default_rng" and not node.args and not node.keywords:
                out.append(
                    LintFinding(
                        rel,
                        node.lineno,
                        "RND001",
                        "np.random.default_rng() without a seed is "
                        "nondeterministic (pass an explicit seed)",
                    )
                )


def _check_time(tree: ast.Module, rel: str, out: List[LintFinding]) -> None:
    if rel.startswith(TIME_ALLOW_PREFIXES):
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if chain is None:
            continue
        if chain == ["time", "time"] or (
            len(chain) >= 2
            and chain[-2] == "datetime"
            and chain[-1] in ("now", "utcnow")
        ):
            out.append(
                LintFinding(
                    rel,
                    node.lineno,
                    "TIME001",
                    f"wall-clock read {'.'.join(chain)}() in simulation code "
                    "(simulated time must come from the event loop)",
                )
            )


def _check_host_sync(tree: ast.Module, rel: str, out: List[LintFinding]) -> None:
    if not rel.endswith(SYNC_SUFFIXES):
        return
    jit_names = _jit_wrapped_names(tree)
    for node in ast.walk(tree):
        if not _is_jitted_def(node, jit_names):
            continue
        for inner in ast.walk(node):
            if not isinstance(inner, ast.Call):
                continue
            if isinstance(inner.func, ast.Attribute) and inner.func.attr == "item":
                out.append(
                    LintFinding(
                        rel,
                        inner.lineno,
                        "SYNC001",
                        f".item() inside jitted function {node.name!r} forces "
                        "a host sync",
                    )
                )
            elif (
                isinstance(inner.func, ast.Name)
                and inner.func.id in ("float", "int")
                and inner.args
                and not isinstance(inner.args[0], ast.Constant)
            ):
                out.append(
                    LintFinding(
                        rel,
                        inner.lineno,
                        "SYNC001",
                        f"{inner.func.id}() on a traced value inside jitted "
                        f"function {node.name!r} forces a host sync",
                    )
                )


_CHECKS = (_check_env, _check_random, _check_time, _check_host_sync)


def lint_file(path: str) -> List[LintFinding]:
    rel = _rel(path)
    with open(path, "r", encoding="utf-8") as fh:
        source = fh.read()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as exc:
        return [LintFinding(rel, exc.lineno or 0, "PARSE", f"syntax error: {exc.msg}")]
    out: List[LintFinding] = []
    for check in _CHECKS:
        check(tree, rel, out)
    out.sort(key=lambda f: (f.path, f.line, f.code))
    return out


def _iter_py(paths: Iterable[str]) -> Iterable[str]:
    for p in paths:
        if os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = [d for d in dirnames if d != "__pycache__"]
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        yield os.path.join(dirpath, fn)
        elif p.endswith(".py"):
            yield p


def lint_paths(paths: Optional[Sequence[str]] = None) -> List[LintFinding]:
    """Lint files/directories (default: the installed repro package)."""
    if not paths:
        paths = [DEFAULT_ROOT]
    findings: List[LintFinding] = []
    for path in _iter_py(paths):
        findings.extend(lint_file(path))
    return findings
