"""Independent schedule verifier.

Consumes an :class:`repro.verify.audit.AuditLog` and re-checks, from
first principles, that the recorded schedule is legal.  Nothing here
imports or reuses engine code: dependency edges are re-derived from the
static task access lists via the Bernstein conditions, residency is
reconstructed by replaying landings/writes/evictions/fault salvage, and
every invariant below is checked against that reconstruction.

Invariants (exact engine):

- ``EXACTLY_ONCE``   every submitted task executed exactly once (kill
  mode may retry attempts, but only one completion may be recorded).
- ``PRECEDENCE``     no task starts before every predecessor (RAW, WAW
  and WAR edges) has completed.
- ``DATA_ARRIVAL``   every datum a task reads was resident in the
  executing resource's memory at task start.
- ``STALE_READ``     a read observed a copy whose version predates the
  latest completed write.  Warning by default: with cancel-stale off
  (the default) the engine deliberately lands in-flight copies of
  overwritten data — a documented modeling artifact.  An error when the
  log says cancel-stale was on.
- ``CAPACITY``       per-device-memory resident bytes never exceed the
  configured capacity.
- ``DEAD_LANDING``   no transfer recorded as landed in a dead memory.
- ``DEAD_WINDOW``    no execution starts strictly inside a detach→attach
  window of its resource (drain lets in-flight work finish; kill must
  requeue, so a start inside the window is always a bug).
- ``BYTES``          sum of logged hop bytes equals the engine's claimed
  ``total_bytes``, and the hop count equals ``n_transfers``.
- ``NOTICE_GRACE``   no execution starts strictly inside a preemption
  notice window — (notice, next detach/attach) of its resource.  A
  noticed worker may finish in-flight work but must accept no new work.
- ``RETRY_BYTES``    every retry record pairs with a ``retry`` hop and
  every timeout record with a ``resource`` hop, byte-for-byte and
  count-for-count (retried traffic is re-charged on the wire, never
  silently absorbed); claimed ``n_retries``/``n_timeouts`` match the
  record counts when the result reports them.
- ``TRANSFER_COMPLETES``  every retried or timed-out transfer is
  followed by a landing record for the same (graph, datum, memory) at
  or after the retry/timeout time — no transfer retries forever.
- ``MAKESPAN``       each graph's recorded finish time equals the max
  recorded execution end for that graph.
- ``ARRIVAL``        no execution of a graph's task starts before the
  graph's submit time (and, in serving mode, before its admit time); a
  graph admission control rejected must show no executions at all, and
  the claimed per-graph admission accounting (admit_at / rejected in
  the result) must agree with the arrival/admit/reject records.

The surrogate engine logs coarser records (no per-copy landings), so it
gets the subset that is meaningful there: EXACTLY_ONCE, PRECEDENCE,
RESOURCE_VALID, BYTES and MAKESPAN, with float32-scaled tolerances.
"""

from __future__ import annotations

import math
from bisect import bisect_right, insort
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

from repro.verify.audit import AuditLog, ExecRecord


@dataclass
class Finding:
    code: str
    severity: str  # "error" | "warning"
    message: str

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return f"[{self.severity}] {self.code}: {self.message}"


def errors(findings: Sequence[Finding]) -> List[Finding]:
    return [f for f in findings if f.severity == "error"]


def derive_edges(tasks: Sequence[Sequence[Tuple[str, int, str]]]) -> List[List[int]]:
    """Re-derive per-task predecessor lists from access lists.

    Bernstein conditions on sequential task-creation order: a reader
    depends on the last writer (RAW); a writer depends on the last
    writer (WAW) and on every reader since that write (WAR).  This is an
    independent re-statement of the data-flow semantics, not a call into
    ``core.dag``.
    """
    last_writer: Dict[str, int] = {}
    readers: Dict[str, List[int]] = {}
    preds: List[List[int]] = []
    for tid, accesses in enumerate(tasks):
        dep: Set[int] = set()
        for name, _size, mode in accesses:
            r = "r" in mode
            w = "w" in mode
            if r or w:
                lw = last_writer.get(name)
                if lw is not None:
                    dep.add(lw)
            if w:
                dep.update(readers.get(name, ()))
        dep.discard(tid)
        preds.append(sorted(dep))
        for name, _size, mode in accesses:
            r = "r" in mode
            w = "w" in mode
            if w:
                last_writer[name] = tid
                readers[name] = []
            elif r:
                readers.setdefault(name, []).append(tid)
    return preds


def verify_audit(log: AuditLog) -> List[Finding]:
    """Run every applicable invariant; returns findings (may be empty)."""
    if log.engine == "surrogate":
        return _verify_surrogate(log)
    return _verify_exact(log)


# ----------------------------------------------------------------------
# helpers shared by both paths
# ----------------------------------------------------------------------
def _reads_writes(
    accesses: Sequence[Tuple[str, int, str]]
) -> Tuple[List[str], List[str]]:
    reads = [n for n, _s, m in accesses if "r" in m]
    writes = [n for n, _s, m in accesses if "w" in m]
    return reads, writes


def _exec_index(
    log: AuditLog, out: List[Finding]
) -> Dict[Tuple[int, int], ExecRecord]:
    """EXACTLY_ONCE check; returns the (gid, tid) -> record map."""
    seen: Dict[Tuple[int, int], int] = {}
    index: Dict[Tuple[int, int], ExecRecord] = {}
    # admission-rejected graphs legitimately never execute; the ARRIVAL
    # invariant separately errors if they *do* show executions
    rejected = {r.gid for r in log.rejects}
    for rec in log.execs:
        key = (rec.gid, rec.tid)
        seen[key] = seen.get(key, 0) + 1
        index.setdefault(key, rec)
        ginfo = log.graphs.get(rec.gid)
        if ginfo is None or not (0 <= rec.tid < len(ginfo["tasks"])):
            out.append(
                Finding(
                    "EXACTLY_ONCE",
                    "error",
                    f"execution recorded for unknown task g{rec.gid}/t{rec.tid}",
                )
            )
    for gid, ginfo in log.graphs.items():
        if gid in rejected:
            continue
        for tid in range(len(ginfo["tasks"])):
            n = seen.get((gid, tid), 0)
            if n != 1:
                out.append(
                    Finding(
                        "EXACTLY_ONCE",
                        "error",
                        f"task g{gid}/t{tid} executed {n} times (want exactly 1)",
                    )
                )
    return index


def _check_bytes(log: AuditLog, out: List[Finding], rel_tol: float = 0.0) -> None:
    claimed = log.result.get("total_bytes")
    if claimed is None:
        return
    logged = sum(h.nbytes for h in log.hops)
    if rel_tol:
        ok = math.isclose(logged, claimed, rel_tol=rel_tol, abs_tol=1.0)
    else:
        ok = logged == claimed
    if not ok:
        out.append(
            Finding(
                "BYTES",
                "error",
                f"logged hop bytes {logged} != claimed total_bytes {claimed}",
            )
        )
    n_claimed = log.result.get("n_transfers")
    if n_claimed is not None and len(log.hops) != n_claimed:
        out.append(
            Finding(
                "BYTES",
                "error",
                f"logged hop count {len(log.hops)} != claimed n_transfers {n_claimed}",
            )
        )


# ----------------------------------------------------------------------
# exact engine
# ----------------------------------------------------------------------
class _Intervals:
    """Residency intervals for one (gid, name, mem): versioned, queryable."""

    __slots__ = ("starts", "items")

    def __init__(self) -> None:
        self.starts: List[float] = []
        self.items: List[List[float]] = []  # [t0, t1, version], t1 = inf while open

    def open(self, t: float, version: int) -> None:
        if self.items and self.items[-1][1] == math.inf:
            # wholesale replacement (e.g. stale landing over a live copy)
            self.items[-1][1] = t
        insort(self.starts, t)
        self.items.append([t, math.inf, float(version)])
        self.items.sort(key=lambda iv: iv[0])

    def close(self, t: float) -> None:
        if self.items and self.items[-1][1] == math.inf:
            self.items[-1][1] = t

    def covering(self, t: float, eps: float) -> Optional[List[float]]:
        # closed-interval membership with tolerance; latest-opened wins
        for iv in reversed(self.items):
            if iv[0] - eps <= t <= iv[1] + eps:
                return iv
        return None


def _fault_windows(
    log: AuditLog, resources: Sequence[Dict[str, Any]], host: int
) -> Tuple[
    Dict[int, List[Tuple[float, float]]], Dict[int, List[Tuple[float, float, int]]]
]:
    """Replay fault records into per-rid and per-mem dead windows.

    A memory dies when its last alive resource detaches (host never
    dies), and revives when any resource on it re-attaches — the same
    shared-memory rule the fault manager applies, re-derived from the
    static machine shape.  Memory windows carry the seq of the detach
    record that killed them, so salvage effects replay in log order.
    """
    mem_of = {r["rid"]: r["mem"] for r in resources}
    alive: Dict[int, bool] = {r["rid"]: True for r in resources}
    rid_windows: Dict[int, List[Tuple[float, float]]] = {}
    mem_windows: Dict[int, List[Tuple[float, float, int]]] = {}
    rid_open: Dict[int, float] = {}
    mem_open: Dict[int, Tuple[float, int]] = {}
    for rec in sorted(log.faults, key=lambda f: (f.t, f.seq)):
        rid = rec.rid
        mem = mem_of.get(rid)
        if rec.event == "detach":
            if rid in rid_open:
                continue
            rid_open[rid] = rec.t
            alive[rid] = False
            if (
                mem is not None
                and mem != host
                and mem not in mem_open
                and not any(
                    alive[r["rid"]] for r in resources if r["mem"] == mem
                )
            ):
                mem_open[mem] = (rec.t, rec.seq)
        elif rec.event == "attach":
            if rid in rid_open:
                rid_windows.setdefault(rid, []).append((rid_open.pop(rid), rec.t))
            alive[rid] = True
            if mem is not None and mem in mem_open:
                t0, seq0 = mem_open.pop(mem)
                mem_windows.setdefault(mem, []).append((t0, rec.t, seq0))
    for rid, t0 in rid_open.items():
        rid_windows.setdefault(rid, []).append((t0, math.inf))
    for mem, (t0, seq0) in mem_open.items():
        mem_windows.setdefault(mem, []).append((t0, math.inf, seq0))
    return rid_windows, mem_windows


def _verify_exact(log: AuditLog) -> List[Finding]:
    out: List[Finding] = []
    machine = log.machine or {}
    resources = machine.get("resources", [])
    host = int(machine.get("host_mem", 0))
    capacity = int(machine.get("capacity") or 0)
    cancel_stale = bool(machine.get("cancel_stale"))
    mem_of_rid = {r["rid"]: r["mem"] for r in resources}

    max_t = max(
        [r.end for r in log.execs]
        + [h.done for h in log.hops]
        + [log.result.get("makespan", 0.0), 1.0]
    )
    eps = 1e-9 * max(1.0, max_t)

    exec_of = _exec_index(log, out)
    _check_bytes(log, out)

    # arrival / admission ------------------------------------------------
    arrive_at = {r.gid: r.t for r in log.arrivals}
    admit_at = {r.gid: r.t for r in log.admits}
    rejected_at = {r.gid: r.t for r in log.rejects}
    for gid in rejected_at:
        if gid in admit_at:
            out.append(
                Finding(
                    "ARRIVAL",
                    "error",
                    f"graph {gid} carries both an admit and a reject record",
                )
            )
    for rec in log.execs:
        ginfo = log.graphs.get(rec.gid)
        submit = (
            float(ginfo.get("submit_at", 0.0)) if ginfo is not None else None
        )
        t0 = arrive_at.get(rec.gid, submit)
        if t0 is not None and rec.start < t0 - eps:
            out.append(
                Finding(
                    "ARRIVAL",
                    "error",
                    f"g{rec.gid}/t{rec.tid} starts at {rec.start:.6g} before "
                    f"the graph's arrival at {t0:.6g}",
                )
            )
        ta = admit_at.get(rec.gid)
        if ta is not None and rec.start < ta - eps:
            out.append(
                Finding(
                    "ARRIVAL",
                    "error",
                    f"g{rec.gid}/t{rec.tid} starts at {rec.start:.6g} before "
                    f"the graph was admitted at {ta:.6g}",
                )
            )
        if rec.gid in rejected_at:
            out.append(
                Finding(
                    "ARRIVAL",
                    "error",
                    f"g{rec.gid}/t{rec.tid} executed but admission control "
                    f"rejected graph {rec.gid} at {rejected_at[rec.gid]:.6g}",
                )
            )
    # claimed per-graph admission accounting must agree with the records
    pg = log.result.get("per_graph", {})
    for gid in log.graphs:
        info = pg.get(gid, pg.get(str(gid)))
        if info is None:
            continue
        claimed_admit = info.get("admit_at")
        ta = admit_at.get(gid)
        if (
            claimed_admit is not None
            and ta is not None
            and not math.isclose(
                float(claimed_admit), ta, rel_tol=1e-9, abs_tol=eps
            )
        ):
            out.append(
                Finding(
                    "ARRIVAL",
                    "error",
                    f"graph {gid} claims admit_at {float(claimed_admit):.6g} "
                    f"but the admit record says {ta:.6g}",
                )
            )
        if bool(info.get("rejected")) != (gid in rejected_at):
            out.append(
                Finding(
                    "ARRIVAL",
                    "error",
                    f"graph {gid} claimed rejected={bool(info.get('rejected'))} "
                    "but the reject records disagree",
                )
            )

    # static context -----------------------------------------------------
    sizes: Dict[Tuple[int, str], int] = {}
    for gid, ginfo in log.graphs.items():
        for accesses in ginfo["tasks"]:
            for name, size, _mode in accesses:
                sizes[(gid, name)] = size

    # precedence ---------------------------------------------------------
    for gid, ginfo in log.graphs.items():
        preds = derive_edges(ginfo["tasks"])
        for tid, plist in enumerate(preds):
            rec = exec_of.get((gid, tid))
            if rec is None:
                continue
            for pid in plist:
                prec = exec_of.get((gid, pid))
                if prec is None:
                    continue
                if rec.start < prec.end - eps:
                    out.append(
                        Finding(
                            "PRECEDENCE",
                            "error",
                            f"g{gid}/t{tid} starts at {rec.start:.6g} before "
                            f"predecessor t{pid} completes at {prec.end:.6g}",
                        )
                    )

    # fault windows ------------------------------------------------------
    rid_windows, mem_windows = _fault_windows(log, resources, host)

    def _mem_dead_at(mem: int, t: float) -> bool:
        for t0, t1, _seq0 in mem_windows.get(mem, ()):  # strictly inside
            if t0 + eps < t < t1 - eps:
                return True
        return False

    for rec in log.execs:
        for t0, t1 in rid_windows.get(rec.rid, ()):
            if t0 + eps < rec.start < t1 - eps:
                out.append(
                    Finding(
                        "DEAD_WINDOW",
                        "error",
                        f"g{rec.gid}/t{rec.tid} starts at {rec.start:.6g} inside "
                        f"dead window ({t0:.6g}, {t1:.6g}) of resource {rec.rid}",
                    )
                )

    # notice grace windows -----------------------------------------------
    if log.notices:
        fault_ts: Dict[int, List[float]] = {}
        for f in log.faults:
            fault_ts.setdefault(f.rid, []).append(f.t)
        for ts in fault_ts.values():
            ts.sort()
        for note in log.notices:
            # the grace window closes at the first fault event after the
            # notice (the promised detach, or an attach cancelling it);
            # if none was recorded, the promised death time bounds it
            ts = fault_ts.get(note.rid, [])
            i = bisect_right(ts, note.t)
            end = ts[i] if i < len(ts) else note.death_at
            for rec in log.execs:
                if rec.rid != note.rid:
                    continue
                if note.t + eps < rec.start < end - eps:
                    out.append(
                        Finding(
                            "NOTICE_GRACE",
                            "error",
                            f"g{rec.gid}/t{rec.tid} starts at {rec.start:.6g} "
                            f"inside notice window ({note.t:.6g}, {end:.6g}) "
                            f"of resource {note.rid}",
                        )
                    )

    # retry / timeout accounting -----------------------------------------
    for kind, recs, claimed_key in (
        ("retry", log.retries, "n_retries"),
        ("resource", log.timeouts, "n_timeouts"),
    ):
        hops = [h for h in log.hops if h.kind == kind]
        if hops or recs:
            hop_bytes = sum(h.nbytes for h in hops)
            rec_bytes = sum(r.nbytes for r in recs)
            if len(hops) != len(recs) or hop_bytes != rec_bytes:
                out.append(
                    Finding(
                        "RETRY_BYTES",
                        "error",
                        f"{len(hops)} '{kind}' hops ({hop_bytes} bytes) vs "
                        f"{len(recs)} records ({rec_bytes} bytes): every "
                        "re-attempt must be re-charged on the wire",
                    )
                )
        n_claimed = log.result.get(claimed_key)
        if n_claimed is not None and len(recs) != n_claimed:
            out.append(
                Finding(
                    "RETRY_BYTES",
                    "error",
                    f"claimed {claimed_key} {n_claimed} != "
                    f"{len(recs)} recorded events",
                )
            )
    if log.retries or log.timeouts:
        land_ts: Dict[Tuple[int, str, int], List[float]] = {}
        for land in log.landings:
            land_ts.setdefault((land.gid, land.name, land.mem), []).append(land.t)
        for ts in land_ts.values():
            ts.sort()

        def _completes(recs: Sequence[Any], what: str) -> None:
            for rec in recs:
                ts = land_ts.get((rec.gid, rec.name, rec.mem))
                if not ts or ts[-1] < rec.t - eps:
                    out.append(
                        Finding(
                            "TRANSFER_COMPLETES",
                            "error",
                            f"g{rec.gid}/{rec.name} {what} at t={rec.t:.6g} "
                            f"toward memory {rec.mem} but no landing was "
                            "recorded at or after it",
                        )
                    )

        _completes(log.retries, "retried")
        _completes(log.timeouts, "timed out")

    # write-end times per datum, for version-at-time queries -------------
    write_ends: Dict[Tuple[int, str], List[float]] = {}
    for rec in sorted(log.execs, key=lambda r: (r.end, r.seq)):
        ginfo = log.graphs.get(rec.gid)
        if ginfo is None or not (0 <= rec.tid < len(ginfo["tasks"])):
            continue
        _reads, writes = _reads_writes(ginfo["tasks"][rec.tid])
        for name in writes:
            write_ends.setdefault((rec.gid, name), []).append(rec.end)

    def _version_at(gid: int, name: str, t: float) -> int:
        ends = write_ends.get((gid, name))
        if not ends:
            return 0
        # writes completed at or before t: a request issued at the very
        # instant a write completes sees the post-write state (the engine
        # processes the completion, then the request, in the same event)
        return bisect_right(ends, t + eps)

    # residency reconstruction -------------------------------------------
    # event kinds replayed in (t, seq) order:
    #   land   -> open copy (version as of request time)
    #   exec   -> write effects: written data becomes exclusive at target
    #   evict  -> drop copy, dirty adds host copy (same version)
    #   fault  -> memory death salvages sole copies to host, drops the rest
    events: List[Tuple[float, int, str, Any]] = []
    for land in log.landings:
        events.append((land.t, land.seq, "land", land))
    for rec in log.execs:
        events.append((rec.end, rec.seq, "exec", rec))
    for ev in log.evictions:
        events.append((ev.t, ev.seq, "evict", ev))
    for mem, wins in mem_windows.items():
        for t0, _t1, seq0 in wins:
            events.append((t0, seq0, "memdeath", mem))
    events.sort(key=lambda e: (e[0], e[1]))

    copies: Dict[Tuple[int, str], Dict[int, _Intervals]] = {}
    live: Dict[Tuple[int, str], Dict[int, int]] = {}  # mem -> version
    resident_bytes: Dict[int, int] = {}
    high_water: Dict[int, int] = {}
    cap_reported: Set[int] = set()

    def _ivs(gid: int, name: str, mem: int) -> _Intervals:
        return copies.setdefault((gid, name), {}).setdefault(mem, _Intervals())

    def _add_copy(gid: int, name: str, mem: int, t: float, version: int) -> None:
        key = (gid, name)
        mems = live.setdefault(key, {})
        fresh = mem not in mems
        mems[mem] = version
        _ivs(gid, name, mem).open(t, version)
        if fresh and mem != host:
            size = sizes.get(key, 0)
            resident_bytes[mem] = resident_bytes.get(mem, 0) + size
            high_water[mem] = max(high_water.get(mem, 0), resident_bytes[mem])
            if capacity and resident_bytes[mem] > capacity and mem not in cap_reported:
                cap_reported.add(mem)
                out.append(
                    Finding(
                        "CAPACITY",
                        "error",
                        f"memory {mem} resident bytes {resident_bytes[mem]} exceed "
                        f"capacity {capacity} at t={t:.6g}",
                    )
                )

    def _drop_copy(gid: int, name: str, mem: int, t: float) -> Optional[int]:
        key = (gid, name)
        mems = live.get(key, {})
        version = mems.pop(mem, None)
        if version is None:
            return None
        ivs = copies.get(key, {}).get(mem)
        if ivs is not None:
            ivs.close(t)
        if mem != host:
            resident_bytes[mem] = resident_bytes.get(mem, 0) - sizes.get(key, 0)
        return version

    # all data starts resident at host, version 0
    for (gid, name) in sizes:
        t0 = log.graphs[gid].get("submit_at", 0.0)
        _add_copy(gid, name, host, t0 - 1.0, 0)

    for t, _seq, kind, payload in events:
        if kind == "land":
            land = payload
            if not land.landed:
                continue
            if _mem_dead_at(land.mem, t):
                out.append(
                    Finding(
                        "DEAD_LANDING",
                        "error",
                        f"copy of g{land.gid}/{land.name} recorded as landed in "
                        f"dead memory {land.mem} at t={t:.6g}",
                    )
                )
            t_req = land.t_req if land.t_req is not None else t
            _add_copy(land.gid, land.name, land.mem, t, _version_at(land.gid, land.name, t_req))
        elif kind == "exec":
            rec = payload
            ginfo = log.graphs.get(rec.gid)
            if ginfo is None or not (0 <= rec.tid < len(ginfo["tasks"])):
                continue
            _reads, writes = _reads_writes(ginfo["tasks"][rec.tid])
            target = host if rec.wrote_host else rec.mem
            for name in writes:
                key = (rec.gid, name)
                for mem in list(live.get(key, {})):
                    if mem != target:
                        _drop_copy(rec.gid, name, mem, t)
                new_ver = len(
                    [e for e in write_ends.get(key, ()) if e <= t + eps]
                )
                if target in live.get(key, {}):
                    # exclusive overwrite in place: close + reopen at new version
                    _ivs(rec.gid, name, target).close(t)
                    live[key][target] = new_ver
                    _ivs(rec.gid, name, target).open(t, new_ver)
                else:
                    _add_copy(rec.gid, name, target, t, new_ver)
        elif kind == "evict":
            ev = payload
            version = _drop_copy(ev.gid, ev.name, ev.mem, t)
            if ev.dirty and version is not None:
                _add_copy(ev.gid, ev.name, host, t, version)
        elif kind == "memdeath":
            mem = payload
            for key, mems in list(live.items()):
                if mem in mems:
                    sole = len(mems) == 1
                    version = _drop_copy(key[0], key[1], mem, t)
                    if sole and version is not None:
                        _add_copy(key[0], key[1], host, t, version)

    # data arrival + stale reads -----------------------------------------
    stale_sev = "error" if cancel_stale else "warning"
    for rec in log.execs:
        ginfo = log.graphs.get(rec.gid)
        if ginfo is None or not (0 <= rec.tid < len(ginfo["tasks"])):
            continue
        reads, _writes = _reads_writes(ginfo["tasks"][rec.tid])
        for name in reads:
            ivs = copies.get((rec.gid, name), {}).get(rec.mem)
            iv = ivs.covering(rec.start, eps) if ivs is not None else None
            if iv is None:
                out.append(
                    Finding(
                        "DATA_ARRIVAL",
                        "error",
                        f"g{rec.gid}/t{rec.tid} reads {name} at t={rec.start:.6g} "
                        f"but no copy was resident in memory {rec.mem}",
                    )
                )
                continue
            current = _version_at(rec.gid, name, rec.start)
            if iv[2] < current:
                out.append(
                    Finding(
                        "STALE_READ",
                        stale_sev,
                        f"g{rec.gid}/t{rec.tid} reads {name} version "
                        f"{int(iv[2])} in memory {rec.mem} at t={rec.start:.6g} "
                        f"but version {current} was already written"
                        + (
                            ""
                            if cancel_stale
                            else " (cancel-stale off: documented modeling artifact)"
                        ),
                    )
                )

    # makespan ------------------------------------------------------------
    per_graph = log.result.get("per_graph", {})
    for gid, ginfo in log.graphs.items():
        info = per_graph.get(gid, per_graph.get(str(gid)))
        if info is None:
            continue
        ends = [r.end for r in log.execs if r.gid == gid]
        if not ends:
            continue
        finish = float(info.get("finish", math.nan))
        if not math.isclose(finish, max(ends), rel_tol=1e-9, abs_tol=eps):
            out.append(
                Finding(
                    "MAKESPAN",
                    "error",
                    f"graph {gid} claims finish {finish:.6g} but last recorded "
                    f"execution ends at {max(ends):.6g}",
                )
            )
    return out


# ----------------------------------------------------------------------
# surrogate engine
# ----------------------------------------------------------------------
def _verify_surrogate(log: AuditLog) -> List[Finding]:
    out: List[Finding] = []
    machine = log.machine or {}
    resources = machine.get("resources", [])
    valid = {r["rid"]: bool(r.get("valid", True)) for r in resources}

    max_t = max([r.end for r in log.execs] + [1.0])
    # f32 episode state: relative tolerance scaled to the horizon
    eps = 1e-3 * max(1.0, max_t) + 1e-6

    exec_of = _exec_index(log, out)
    _check_bytes(log, out, rel_tol=1e-3)

    for gid, ginfo in log.graphs.items():
        preds = derive_edges(ginfo["tasks"])
        for tid, plist in enumerate(preds):
            rec = exec_of.get((gid, tid))
            if rec is None:
                continue
            for pid in plist:
                prec = exec_of.get((gid, pid))
                if prec is None:
                    continue
                if rec.start < prec.end - eps:
                    out.append(
                        Finding(
                            "PRECEDENCE",
                            "error",
                            f"g{gid}/t{tid} starts at {rec.start:.6g} before "
                            f"predecessor t{pid} completes at {prec.end:.6g}",
                        )
                    )

    for rec in log.execs:
        if not valid.get(rec.rid, True):
            out.append(
                Finding(
                    "RESOURCE_VALID",
                    "error",
                    f"g{rec.gid}/t{rec.tid} placed on invalid resource {rec.rid}",
                )
            )

    per_graph = log.result.get("per_graph", {})
    for gid in log.graphs:
        info = per_graph.get(gid, per_graph.get(str(gid)))
        if info is None:
            continue
        ends = [r.end for r in log.execs if r.gid == gid]
        if not ends:
            continue
        finish = float(info.get("finish", math.nan))
        if not math.isclose(finish, max(ends), rel_tol=1e-3, abs_tol=eps):
            out.append(
                Finding(
                    "MAKESPAN",
                    "error",
                    f"graph {gid} claims makespan {finish:.6g} but last placement "
                    f"ends at {max(ends):.6g}",
                )
            )
    return out
