"""Static-analysis layer over both scheduling engines.

Two independent halves:

- ``repro.verify.audit`` / ``repro.verify.schedule``: an opt-in audit
  log (``REPRO_SCHED_AUDIT=1``) records every placement, transfer hop,
  landing decision, eviction and fault window from the exact runtime
  engine, and ``core/episode.py`` emits its surrogate placements in the
  same schema.  The verifier reconstructs a residency timeline from the
  log alone — zero engine-code reuse, pure stdlib — and re-checks
  precedence, data hazards, capacity, byte conservation, exactly-once
  execution and dead-worker windows from first principles.
- ``repro.verify.lint``: AST-based repo lint (``python -m repro.verify
  lint``) enforcing the determinism/config contract: no ``os.environ``
  outside ``sched/config.py``, no unseeded global ``np.random``, no
  wall-clock reads in ``src/repro``, no host-sync smells inside jitted
  paths.

See docs/verification.md for the invariant list and audit schema.
"""

from repro.verify.audit import AuditLog, graph_accesses
from repro.verify.lint import LintFinding, lint_paths
from repro.verify.schedule import Finding, errors, verify_audit

__all__ = [
    "AuditLog",
    "Finding",
    "LintFinding",
    "errors",
    "graph_accesses",
    "lint_paths",
    "verify_audit",
]
