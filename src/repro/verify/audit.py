"""Structured schedule audit log shared by both engines.

The runtime engine (``repro.runtime.engine``) and the batched surrogate
episode engine (``repro.core.episode``) both emit this schema when
``REPRO_SCHED_AUDIT=1``.  The log is *observational*: it records what an
engine claims happened (who ran where and when, which bytes moved, which
copies landed or were dropped, which resources died) plus enough static
context (machine shape, per-graph task access lists) for the verifier in
``repro.verify.schedule`` to re-derive legality from first principles.

Deliberately stdlib-only — no numpy, no imports from ``repro.core`` or
``repro.runtime`` — so the verifier consuming it shares no code with the
engines it checks.

Every record carries a monotonically increasing ``seq`` assigned in log
order.  Engines process same-timestamp events in a deterministic order;
``seq`` preserves that order so the verifier can replay state changes at
equal timestamps without re-implementing engine tie-breaking.
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass
from typing import Any, Dict, List, Optional, Tuple

SCHEMA_VERSION = 1


@dataclass
class ExecRecord:
    """One task execution (attempt that completed)."""

    seq: int
    gid: int
    tid: int
    rid: int
    mem: int
    start: float
    end: float
    # kill/drain salvage: outputs were written back to host because the
    # executing resource's memory died before completion
    wrote_host: bool = False


@dataclass
class HopRecord:
    """One accounted link occupation (the only place bytes count).

    ``kind``: "copy" (demand transfer hop), "writeback" (dirty
    eviction), "evacuate" (fault salvage), "proactive" (notice-window
    replication), "retry" (flaky hop re-attempt), "resource"
    (post-timeout re-source from another live copy or host).
    """

    seq: int
    kind: str
    nbytes: int
    group: Optional[int]
    t: float
    done: float


@dataclass
class LandRecord:
    """A transfer arrival event and the engine's landing decision.

    ``reason``: "ok" (copy became resident), "dead" (target memory died
    or its epoch advanced mid-flight), "stale" (cancel-stale mode
    dropped an outdated version).  ``t_req`` is the time the transfer
    was requested, matched from the request site.
    """

    seq: int
    gid: int
    name: str
    mem: int
    t: float
    landed: bool
    reason: str
    t_req: Optional[float] = None


@dataclass
class EvictRecord:
    """A capacity eviction; ``dirty`` means a write-back hop preceded."""

    seq: int
    gid: int
    name: str
    mem: int
    t: float
    dirty: bool


@dataclass
class FaultRecord:
    """A detach/attach event on a resource."""

    seq: int
    t: float
    event: str
    rid: int
    mode: Optional[str]


@dataclass
class NoticeRecord:
    """A preemption notice: ``rid`` will detach at ``death_at``.

    Opens the grace window ``(t, death_at)`` inside which the engine
    must start no new execution on ``rid`` (the NOTICE_GRACE invariant).
    """

    seq: int
    t: float
    rid: int
    mode: Optional[str]
    death_at: float


@dataclass
class RetryRecord:
    """A flaky demand hop failed and was retried with backoff.

    ``attempt`` is 1-based; ``delay_s`` the backoff injected before the
    re-attempt; ``nbytes`` must match a same-sized ``retry`` hop (the
    RETRY_BYTES invariant: every retried byte is re-charged on the wire).
    """

    seq: int
    gid: int
    name: str
    mem: int
    t: float
    attempt: int
    delay_s: float
    nbytes: int


@dataclass
class TimeoutRecord:
    """A transfer exhausted its retry budget and was re-sourced.

    ``attempts`` counts the failed tries; the transfer must still land —
    a matching ``resource`` hop and a later landing record close it (the
    TRANSFER_COMPLETES invariant).
    """

    seq: int
    gid: int
    name: str
    mem: int
    t: float
    attempts: int
    nbytes: int


@dataclass
class ArrivalRecord:
    """A tenant graph reached the machine (serving mode): ``t`` is its
    submit time — no execution of the graph may start before it (the
    ARRIVAL invariant)."""

    seq: int
    gid: int
    t: float


@dataclass
class AdmitRecord:
    """Admission control let the tenant in at ``t``; executions must not
    start before the admit time either (deferred tenants wait)."""

    seq: int
    gid: int
    t: float


@dataclass
class RejectRecord:
    """Admission control turned the tenant away: the graph must show no
    executions at all.  ``reason``: "too_large" (working set exceeds the
    machine's aggregate capacity outright) or "pressure" (no room amid
    currently-admitted tenants)."""

    seq: int
    gid: int
    t: float
    reason: str


_RECORD_TYPES = {
    "exec": ExecRecord,
    "hop": HopRecord,
    "land": LandRecord,
    "evict": EvictRecord,
    "fault": FaultRecord,
    "notice": NoticeRecord,
    "retry": RetryRecord,
    "timeout": TimeoutRecord,
    "arrival": ArrivalRecord,
    "admit": AdmitRecord,
    "reject": RejectRecord,
}


def graph_accesses(graph: Any) -> List[List[Tuple[str, int, str]]]:
    """Extract the static per-task access lists from a TaskGraph.

    Returns one ``(data_name, size_bytes, mode)`` list per task, with
    ``mode`` in {"r", "w", "rw"} — everything the verifier needs to
    re-derive dependency edges and data sizes without importing the DAG
    machinery.
    """
    return [
        [(a.data.name, int(a.data.size_bytes), a.mode.value) for a in t.accesses]
        for t in graph.tasks
    ]


class AuditLog:
    """Accumulates records from one engine run; see module docstring."""

    def __init__(self, engine: str = "exact"):
        self.engine = engine
        self.machine: Dict[str, Any] = {}
        self.graphs: Dict[int, Dict[str, Any]] = {}
        self.execs: List[ExecRecord] = []
        self.hops: List[HopRecord] = []
        self.landings: List[LandRecord] = []
        self.evictions: List[EvictRecord] = []
        self.faults: List[FaultRecord] = []
        self.notices: List[NoticeRecord] = []
        self.retries: List[RetryRecord] = []
        self.timeouts: List[TimeoutRecord] = []
        self.arrivals: List[ArrivalRecord] = []
        self.admits: List[AdmitRecord] = []
        self.rejects: List[RejectRecord] = []
        self.result: Dict[str, Any] = {}
        self._seq = 0
        # (gid, name, dst_mem, done_t) -> request time, popped on landing
        self._pending_req: Dict[Tuple[int, str, int, float], float] = {}

    # ------------------------------------------------------------------
    # producer API (called from the engines)
    # ------------------------------------------------------------------
    def _next_seq(self) -> int:
        self._seq += 1
        return self._seq

    def log_machine(self, machine: Any, **info: Any) -> None:
        resources = [
            {
                "rid": int(r.rid),
                "mem": int(r.mem),
                "is_accelerator": bool(r.is_accelerator),
                "link": getattr(r, "link", None),
            }
            for r in machine.resources
        ]
        self.machine = dict(info, resources=resources)

    def log_graph(self, gid: int, submit_at: float, graph: Any) -> None:
        self.graphs[int(gid)] = {
            "submit_at": float(submit_at),
            "tasks": graph_accesses(graph),
        }

    def log_exec(
        self,
        gid: int,
        tid: int,
        rid: int,
        mem: int,
        start: float,
        end: float,
        wrote_host: bool = False,
    ) -> None:
        self.execs.append(
            ExecRecord(
                self._next_seq(),
                int(gid),
                int(tid),
                int(rid),
                int(mem),
                float(start),
                float(end),
                bool(wrote_host),
            )
        )

    def log_hop(
        self, kind: str, nbytes: int, group: Optional[int], t: float, done: float
    ) -> None:
        self.hops.append(
            HopRecord(
                self._next_seq(),
                kind,
                int(nbytes),
                None if group is None else int(group),
                float(t),
                float(done),
            )
        )

    def note_request(
        self, gid: int, name: str, dst_mem: int, done: float, t_req: float
    ) -> None:
        self._pending_req[(int(gid), name, int(dst_mem), float(done))] = float(t_req)

    def log_landing(
        self, gid: int, name: str, mem: int, t: float, landed: bool, reason: str
    ) -> None:
        t_req = self._pending_req.pop((int(gid), name, int(mem), float(t)), None)
        self.landings.append(
            LandRecord(
                self._next_seq(),
                int(gid),
                name,
                int(mem),
                float(t),
                bool(landed),
                reason,
                t_req,
            )
        )

    def log_evict(self, gid: int, name: str, mem: int, t: float, dirty: bool) -> None:
        self.evictions.append(
            EvictRecord(self._next_seq(), int(gid), name, int(mem), float(t), bool(dirty))
        )

    def log_fault(self, t: float, event: str, rid: int, mode: Optional[str]) -> None:
        self.faults.append(FaultRecord(self._next_seq(), float(t), event, int(rid), mode))

    def log_notice(
        self, t: float, rid: int, mode: Optional[str], death_at: float
    ) -> None:
        self.notices.append(
            NoticeRecord(
                self._next_seq(), float(t), int(rid), mode, float(death_at)
            )
        )

    def log_retry(
        self,
        gid: int,
        name: str,
        mem: int,
        t: float,
        attempt: int,
        delay_s: float,
        nbytes: int,
    ) -> None:
        self.retries.append(
            RetryRecord(
                self._next_seq(), int(gid), name, int(mem), float(t),
                int(attempt), float(delay_s), int(nbytes),
            )
        )

    def log_timeout(
        self, gid: int, name: str, mem: int, t: float, attempts: int, nbytes: int
    ) -> None:
        self.timeouts.append(
            TimeoutRecord(
                self._next_seq(), int(gid), name, int(mem), float(t),
                int(attempts), int(nbytes),
            )
        )

    def log_arrival(self, gid: int, t: float) -> None:
        self.arrivals.append(ArrivalRecord(self._next_seq(), int(gid), float(t)))

    def log_admit(self, gid: int, t: float) -> None:
        self.admits.append(AdmitRecord(self._next_seq(), int(gid), float(t)))

    def log_reject(self, gid: int, t: float, reason: str) -> None:
        self.rejects.append(
            RejectRecord(self._next_seq(), int(gid), float(t), reason)
        )

    def finalize(self, engine: Any) -> None:
        """Snapshot the engine's claimed result after the run loop ends."""
        per_graph: Dict[int, Dict[str, Any]] = {}
        for ctx in engine._ctxs:
            gid = int(ctx.gid)
            per_graph[gid] = {
                "submit_at": float(ctx.submit_at),
                "finish": float(ctx.finish),
                "n_done": int(ctx.n_done),
            }
            # serving-mode arrival accounting (surrogate contexts carry
            # no admission state — default to plain admitted-at-submit)
            if getattr(ctx, "rejected", False):
                per_graph[gid]["rejected"] = True
            if getattr(ctx, "admitted", False):
                per_graph[gid]["admit_at"] = float(ctx.admit_at)
            if gid in self.graphs:
                self.graphs[gid]["submit_at"] = float(ctx.submit_at)
        self.result = {
            "total_bytes": int(engine.metrics.total_bytes),
            "n_transfers": int(engine.metrics.n_transfers),
            "makespan": float(engine.now),
            "n_retries": int(engine.metrics.n_retries),
            "n_timeouts": int(engine.metrics.n_timeouts),
            "per_graph": per_graph,
        }

    # ------------------------------------------------------------------
    # JSONL round-trip
    # ------------------------------------------------------------------
    def to_jsonl(self, path: str) -> None:
        with open(path, "w", encoding="utf-8") as fh:
            header = {
                "type": "header",
                "schema": SCHEMA_VERSION,
                "engine": self.engine,
                "machine": self.machine,
                "result": self.result,
            }
            fh.write(json.dumps(header) + "\n")
            for gid, info in sorted(self.graphs.items()):
                fh.write(
                    json.dumps({"type": "graph", "gid": gid, **info}) + "\n"
                )
            for tag, records in (
                ("exec", self.execs),
                ("hop", self.hops),
                ("land", self.landings),
                ("evict", self.evictions),
                ("fault", self.faults),
                ("notice", self.notices),
                ("retry", self.retries),
                ("timeout", self.timeouts),
                ("arrival", self.arrivals),
                ("admit", self.admits),
                ("reject", self.rejects),
            ):
                for rec in records:
                    fh.write(json.dumps({"type": tag, **asdict(rec)}) + "\n")

    @classmethod
    def from_jsonl(cls, path: str) -> "AuditLog":
        log = cls()
        with open(path, "r", encoding="utf-8") as fh:
            for lineno, line in enumerate(fh, start=1):
                line = line.strip()
                if not line:
                    continue
                try:
                    obj = json.loads(line)
                except json.JSONDecodeError as exc:
                    raise ValueError(f"{path}:{lineno}: invalid JSON: {exc}") from None
                kind = obj.pop("type", None)
                if kind == "header":
                    if obj.get("schema") != SCHEMA_VERSION:
                        raise ValueError(
                            f"{path}:{lineno}: unsupported audit schema "
                            f"{obj.get('schema')!r} (want {SCHEMA_VERSION})"
                        )
                    log.engine = obj.get("engine", "exact")
                    log.machine = obj.get("machine", {})
                    log.result = obj.get("result", {})
                elif kind == "graph":
                    gid = int(obj.pop("gid"))
                    obj["tasks"] = [
                        [(n, int(s), m) for n, s, m in task] for task in obj["tasks"]
                    ]
                    log.graphs[gid] = obj
                elif kind in _RECORD_TYPES:
                    rec_cls = _RECORD_TYPES[kind]
                    try:
                        rec = rec_cls(**obj)
                    except TypeError as exc:
                        raise ValueError(f"{path}:{lineno}: bad {kind} record: {exc}")
                    getattr(
                        log,
                        {
                            "exec": "execs",
                            "hop": "hops",
                            "land": "landings",
                            "evict": "evictions",
                            "fault": "faults",
                            "notice": "notices",
                            "retry": "retries",
                            "timeout": "timeouts",
                            "arrival": "arrivals",
                            "admit": "admits",
                            "reject": "rejects",
                        }[kind],
                    ).append(rec)
                    log._seq = max(log._seq, rec.seq)
                else:
                    raise ValueError(f"{path}:{lineno}: unknown record type {kind!r}")
        return log
