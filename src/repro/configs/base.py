"""Model/config schema for the assigned architectures.

One dataclass covers the whole pool: dense GQA transformers, MLA, MoE,
hybrid Mamba/attention, xLSTM, encoder-decoder, and modality-stub archs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int  # per-expert hidden dim
    every: int = 1  # MoE MLP every `every`-th layer (others dense)
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_dim: int
    qk_rope_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    act: str = "silu"  # silu | geglu | gelu
    norm: str = "rmsnorm"
    rope_style: str = "half"  # full | half (2d, chatglm/minicpm) | none
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    # layer pattern, repeated over depth; entries: attn | mamba | mlstm | slstm
    block_pattern: Tuple[str, ...] = ("attn",)
    # Mamba
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_d_conv: int = 4
    # encoder-decoder
    enc_layers: int = 0  # >0 => encoder-decoder; n_layers is decoder depth
    # modality stub (audio frames / vision patches prepended as embeddings)
    frontend_tokens: int = 0
    frontend_dim: int = 0
    # numerics / memory policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    # sub-quadratic? (drives long_500k applicability)
    subquadratic: bool = False
    # scan period (len(block_pattern) must divide n_layers)
    max_seq: int = 532480  # rope table upper bound

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def period(self) -> int:
        return len(self.block_pattern)

    def __post_init__(self):
        assert self.n_layers % self.period == 0, (
            f"{self.name}: n_layers {self.n_layers} not divisible by "
            f"pattern period {self.period}"
        )
        assert self.n_heads % self.n_kv_heads == 0

    def scaled(self, **overrides) -> "ModelConfig":
        return dataclasses.replace(self, **overrides)

    def params_count(self) -> float:
        """Analytic parameter count (for MODEL_FLOPS and memory estimates)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        total = emb
        per = {}
        # per-block params by kind
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (
            self.n_heads * hd
        ) * d
        if self.mla is not None:
            m = self.mla
            attn = (
                d * m.q_lora_rank
                + m.q_lora_rank * self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
                + d * (m.kv_lora_rank + m.qk_rope_dim)
                + m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
                + self.n_heads * m.v_head_dim * d
            )
        dense_mlp = 3 * d * self.d_ff if self.act in ("silu", "geglu") else 2 * d * self.d_ff
        d_inner = self.mamba_expand * d
        mamba = (
            d * 2 * d_inner  # in_proj
            + d_inner * self.mamba_d_conv  # conv
            + d_inner * (2 * self.mamba_d_state + d_inner // 16 + 1)  # ssm projs
            + d_inner * d  # out_proj
        )
        mlstm = d * 2 * d_inner + 4 * d_inner * (d_inner // max(1, self.n_heads)) + d_inner * d
        slstm = 4 * d * d + 4 * d * d + d * self.d_ff if self.d_ff else 8 * d * d
        n_blocks = self.n_layers + self.enc_layers
        for i in range(self.n_layers):
            kind = self.block_pattern[i % self.period]
            if kind == "attn":
                total += attn
            elif kind == "mamba":
                total += mamba
            elif kind == "mlstm":
                total += mlstm
            elif kind == "slstm":
                total += slstm
            # MLP (attn/mamba blocks carry an MLP; xlstm blocks do not)
            if kind in ("attn", "mamba"):
                if self.moe is not None and (i % self.moe.every == self.moe.every - 1):
                    total += self.moe.n_experts * 3 * d * self.moe.d_ff
                else:
                    total += dense_mlp
        total += self.enc_layers * (attn + dense_mlp)
        return float(total)

    def active_params_count(self) -> float:
        """Active parameters per token (MoE: only top_k experts count)."""
        if self.moe is None:
            return self.params_count()
        d = self.d_model
        full = self.params_count()
        n_moe_layers = sum(
            1
            for i in range(self.n_layers)
            if self.block_pattern[i % self.period] in ("attn", "mamba")
            and i % self.moe.every == self.moe.every - 1
        )
        all_experts = n_moe_layers * self.moe.n_experts * 3 * d * self.moe.d_ff
        active = n_moe_layers * self.moe.top_k * 3 * d * self.moe.d_ff
        return float(full - all_experts + active)
