"""Architecture registry: ``--arch <id>`` -> ModelConfig (+ smoke variants)."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from .base import MLAConfig, ModelConfig, MoEConfig

_MODULES = {
    "chatglm3-6b": "chatglm3_6b",
    "gemma-7b": "gemma_7b",
    "granite-8b": "granite_8b",
    "minicpm3-4b": "minicpm3_4b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "grok-1-314b": "grok_1_314b",
    "xlstm-1.3b": "xlstm_1p3b",
    "internvl2-76b": "internvl2_76b",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: one pattern period (or
    two tiny layers), narrow width, few experts, tiny vocab/frontend."""
    cfg = get_config(arch)
    over: Dict = dict(
        n_layers=cfg.period * (1 if cfg.period > 1 else 2),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=0 if cfg.d_ff == 0 else 256,
        vocab=512,
        remat=False,
        max_seq=512,
    )
    if cfg.moe is not None:
        over["moe"] = MoEConfig(
            n_experts=4, top_k=min(2, cfg.moe.top_k), d_ff=64, every=cfg.moe.every
        )
    if cfg.mla is not None:
        over["mla"] = MLAConfig(
            q_lora_rank=48, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=16,
            v_head_dim=32,
        )
    if cfg.enc_layers:
        over["enc_layers"] = 2
    if cfg.frontend_tokens:
        over["frontend_tokens"] = 8
        over["frontend_dim"] = 48
    over["param_dtype"] = "float32"
    return cfg.scaled(**over)
