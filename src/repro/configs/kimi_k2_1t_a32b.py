"""kimi-k2-1t-a32b [moe]: 61L d7168 64H (GQA kv=8) expert-ff2048
vocab163840, MoE 384 experts top-8 — trillion-param MoE (paper-table).
[arXiv:2501.kimi2; unverified]"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab=163840, head_dim=128,
    act="silu", rope_style="full",
    moe=MoEConfig(n_experts=384, top_k=8, d_ff=2048, every=1,
                  capacity_factor=1.25),
    param_dtype="bfloat16",  # 1T fp32 params cannot fit 512 chips
)
