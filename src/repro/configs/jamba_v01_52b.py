"""jamba-v0.1-52b [hybrid]: 32L d4096 32H (GQA kv=8) ff14336 vocab65536,
Mamba:attention 7:1 interleave, MoE 16e top-2 every other layer.
[arXiv:2403.19887; hf]"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=65536, head_dim=128,
    act="silu", rope_style="none",  # Jamba uses no positional encoding
    block_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff=14336, every=2),
    mamba_d_state=16, mamba_expand=2, mamba_d_conv=4,
    subquadratic=True,
)
