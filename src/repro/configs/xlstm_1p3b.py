"""xlstm-1.3b [ssm]: 48L d2048 4H vocab50304, sLSTM + mLSTM blocks in a
7:1 ratio (xLSTM[7:1]); no separate FFN (d_ff=0 per spec — cells carry
their own up/down projections). [arXiv:2405.04517; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304, head_dim=512,
    act="gelu", rope_style="none",
    block_pattern=("mlstm", "mlstm", "mlstm", "mlstm",
                   "mlstm", "mlstm", "mlstm", "slstm"),
    subquadratic=True, tie_embeddings=True,
)
