"""chatglm3-6b [dense]: 28L d4096 32H (GQA kv=2) ff13696 vocab65024, RoPE-2d.
[arXiv:2406.12793; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab=65024, head_dim=128,
    act="silu", rope_style="half", norm="rmsnorm",
)
