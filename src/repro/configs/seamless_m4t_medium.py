"""seamless-m4t-medium [audio]: 12L enc + 12L dec, d1024 16H ff4096
vocab256206, encoder-decoder; audio frontend STUBBED (input_specs provides
precomputed frame embeddings). [arXiv:2308.11596; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206, head_dim=64,
    act="gelu", norm="layernorm", rope_style="full",
    frontend_tokens=1024, frontend_dim=160,  # fbank-frame stub width
)
